"""Sharded, async, elastic checkpointing.

* ``save``: gathers each pytree leaf to host (optionally on a background
  thread), writes one ``.npz`` per top-level group + a JSON manifest, then
  atomically renames the step directory — a killed save never corrupts the
  latest-complete checkpoint.
* ``restore``: reads the manifest, rebuilds the pytree, and ``device_put``s
  each leaf with the *target* sharding — which may belong to a different
  mesh than the one that saved it (elastic resharding: N pods -> M pods).
* ``latest_step`` / ``cleanup``: retention of the last k checkpoints.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
from pathlib import Path
from typing import Any, Optional

import jax
import numpy as np


def _flatten(tree: Any):
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in leaves:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out[key] = leaf
    return out, treedef


def save(
    ckpt_dir: str | Path,
    step: int,
    tree: Any,
    *,
    blocking: bool = True,
) -> threading.Thread | None:
    """Write checkpoint for `step`. Non-blocking mode gathers to host
    synchronously (cheap) and writes on a daemon thread (overlaps the next
    training steps)."""
    ckpt_dir = Path(ckpt_dir)
    ckpt_dir.mkdir(parents=True, exist_ok=True)
    flat, _ = _flatten(tree)
    host = {k: np.asarray(jax.device_get(v)) for k, v in flat.items()}

    def _write():
        tmp = ckpt_dir / f".tmp_step_{step:08d}"
        final = ckpt_dir / f"step_{step:08d}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        np.savez(tmp / "leaves.npz", **host)
        manifest = {
            "step": step,
            "keys": sorted(host),
            "shapes": {k: list(v.shape) for k, v in host.items()},
            "dtypes": {k: str(v.dtype) for k, v in host.items()},
        }
        (tmp / "manifest.json").write_text(json.dumps(manifest))
        if final.exists():
            shutil.rmtree(final)
        os.replace(tmp, final)  # atomic publish

    if blocking:
        _write()
        return None
    t = threading.Thread(target=_write, daemon=True)
    t.start()
    return t


def latest_step(ckpt_dir: str | Path) -> Optional[int]:
    ckpt_dir = Path(ckpt_dir)
    if not ckpt_dir.exists():
        return None
    steps = [
        int(p.name.split("_")[1])
        for p in ckpt_dir.iterdir()
        if p.name.startswith("step_") and (p / "manifest.json").exists()
    ]
    return max(steps) if steps else None


def restore(
    ckpt_dir: str | Path,
    step: int,
    target_tree: Any,
    shardings: Any = None,
) -> Any:
    """Rebuild `target_tree`-shaped pytree from disk; reshard onto
    `shardings` (same structure) if given — the elastic-resume path."""
    d = Path(ckpt_dir) / f"step_{step:08d}"
    data = np.load(d / "leaves.npz")
    flat_target, treedef = _flatten(target_tree)
    sh_flat = None
    if shardings is not None:
        sh_map, _ = _flatten(shardings)
        sh_flat = sh_map
    out = {}
    for key, tgt in flat_target.items():
        arr = data[key]
        assert arr.shape == tuple(tgt.shape), (key, arr.shape, tgt.shape)
        if sh_flat is not None and key in sh_flat:
            out[key] = jax.device_put(arr.astype(tgt.dtype), sh_flat[key])
        else:
            out[key] = jax.numpy.asarray(arr.astype(tgt.dtype))
    # _flatten preserves tree_flatten_with_path's canonical leaf order.
    return jax.tree_util.tree_unflatten(treedef, list(out.values()))


def cleanup(ckpt_dir: str | Path, keep: int = 3) -> None:
    ckpt_dir = Path(ckpt_dir)
    if not ckpt_dir.exists():
        return
    steps = sorted(
        int(p.name.split("_")[1])
        for p in ckpt_dir.iterdir()
        if p.name.startswith("step_")
    )
    for s in steps[:-keep]:
        shutil.rmtree(ckpt_dir / f"step_{s:08d}", ignore_errors=True)
