"""One-time weight prepacking for the weight-stationary photonic engine.

The DPU programs its weight MRR banks once per tile and then streams
inputs (paper §III-A); re-quantizing — and, for the Pallas backend,
re-padding — the *static* weight operand on every forward call is pure
hot-path waste.  :func:`prepack_params` walks a parameter tree against
its definition tree, finds every dense site the engine's policy routes,
and replaces the float (or int8-stored) weight with a
:class:`PackedDense` leaf:

* per-column symmetric int8 quantization (bit-identical to the per-call
  ``quantize_symmetric(w, bits, axis=0)`` it replaces — contraction-axis
  reduction only, so stacked ``(layers, K, C)`` defs pack layerwise),
* for the ``pallas`` backend the weight is stored tile-padded in the
  kernel's ``(Kp, Cp)`` layout (:func:`repro.photonic.engine.pallas_tiling`
  is activation-independent, which is what makes this legal), so decode
  steps never pad or re-slice the weight again.

``PackedDense`` is a registered pytree whose array leaves carry any
leading stack dims — ``jax.lax.scan`` over a stacked layer tree slices
straight through it.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.dpu import quantize_symmetric
from repro.photonic.engine import PhotonicEngine, pallas_tiling


@dataclasses.dataclass(frozen=True)
class ReprogramCost:
    """Latency/energy to (re)program one weight tile onto a DPU's rings.

    This is the weight-stationary cost the prepacking below exists to
    amortize: the tile is EO-tuned once (``latency_s``, Table VI) and
    then streamed against for free.  The mapper prices replication with
    it (``AcceleratorConfig.weight_reprogram_cost``) — a row-split
    replica re-programs the full tile chain, so it must stream long
    enough to cover its own reprogramming.
    """

    latency_s: float
    energy_j: float
    rings: int


def reprogram_cost(
    rings: int, *, tune_latency_s: float, tune_power_w_per_ring: float
) -> ReprogramCost:
    """Cost of programming ``rings`` weight rings in one tuning pass.
    (Energy is spelled ``(power x latency) x rings`` to stay bit-identical
    with the legacy simulator's tune-energy accounting.)"""
    return ReprogramCost(
        latency_s=tune_latency_s,
        energy_j=tune_power_w_per_ring * tune_latency_s * rings,
        rings=rings,
    )


@jax.tree_util.register_pytree_node_class
class PackedDense:
    """A prepacked dense weight: int8 slices + per-column dequant scale.

    ``wq``      — int8, ``(..., K, C)`` (raw) or ``(..., Kp, Cp)`` when
                  ``tiling`` is set (Pallas tile-padded layout).  With
                  ``shards > 1`` the stored rows are the concatenation of
                  the per-shard banks: ``(..., shards * Kp_local, Cp)``,
                  each bank independently tile-padded for its *local*
                  tiling, so a row-wise ``PartitionSpec`` hands every mesh
                  shard exactly its padded bank.
    ``w_scale`` — float32 ``(..., C)`` per-column symmetric scale.  Always
                  the *global* (full-K) per-column scale — replicated over
                  the mesh; shard partials dequantize consistently.
    ``k, c``    — the *logical* (unpadded, global) contraction/output dims.
    ``tiling``  — ``None`` or the static ``(n_chunk, tile_k, tile_c)``
                  the weight was padded for (shard-local when sharded).
    ``shards``  — K-shard count of the stored layout (1 = unsharded).
    """

    __slots__ = ("wq", "w_scale", "k", "c", "tiling", "shards")

    def __init__(
        self,
        wq,
        w_scale,
        k: int,
        c: int,
        tiling: Optional[Tuple[int, int, int]] = None,
        shards: int = 1,
    ):
        self.wq = wq
        self.w_scale = w_scale
        self.k = k
        self.c = c
        self.tiling = tiling
        self.shards = shards

    def tree_flatten(self):
        return (self.wq, self.w_scale), (self.k, self.c, self.tiling, self.shards)

    @classmethod
    def tree_unflatten(cls, aux, children):
        wq, w_scale = children
        return cls(wq, w_scale, *aux)

    @property
    def k_local(self) -> int:
        """Per-shard logical contraction length."""
        return self.k // self.shards

    def dequant(self) -> jax.Array:
        """The float32 weight this pack represents (logical K x C)."""
        wq = self.wq
        if self.shards > 1:
            lead = wq.shape[:-2]
            kp_local = wq.shape[-2] // self.shards
            wq = wq.reshape(*lead, self.shards, kp_local, wq.shape[-1])
            wq = wq[..., : self.k_local, : self.c]
            wq = wq.reshape(*lead, self.k, self.c)
        else:
            wq = wq[..., : self.k, : self.c]
        return wq.astype(jnp.float32) * self.w_scale.astype(jnp.float32)[..., None, :]

    def __repr__(self):
        return (
            f"PackedDense(k={self.k}, c={self.c}, stored={tuple(self.wq.shape)}, "
            f"tiling={self.tiling}, shards={self.shards})"
        )


def site_name(path: Tuple[str, ...]) -> str:
    """Dotted site name of a dense def at ``path``, normalized to the name
    the model code passes to ``dense(site=...)`` at call time — routing
    decisions made here and there must agree for any policy, not just the
    default.  Wrapper components ("layers", "first_block", "dec_layers",
    "mamba", ...) are stripped by keeping the suffix from the last
    "attn"/"ffn" module component; a trailing "cross" dict (whisper's
    decoder cross-attention) is consumed through the shared attention call
    sites and maps to "attn.<leaf>"; everything else is its leaf name.
    """
    parts = list(path)
    if not parts:
        return "root"
    for i in range(len(parts) - 1, -1, -1):
        if parts[i] in ("attn", "ffn"):
            return ".".join(parts[i:])
        if parts[i] == "cross" and i == len(parts) - 2:
            return "attn." + parts[-1]
    return parts[-1]


def _is_dense_def(node: Any) -> bool:
    if not isinstance(node, dict) or "w" not in node:
        return False
    w = node["w"]
    return (not isinstance(w, dict) and hasattr(w, "shape") and len(w.shape) >= 2)


def pack_dense(
    params: dict,
    engine: PhotonicEngine,
    *,
    already_quantized: bool = False,
    shards: int = 1,
) -> dict:
    """Pack one dense-layer param dict ``{"w": ..., ["w_scale"], ["b"]}``.

    ``already_quantized`` selects the int8-stored layout (``w`` int8 +
    per-column ``w_scale``, see :func:`repro.models.common.quantize_params`)
    — the existing quantization is reused bit-for-bit, only the layout
    changes.  Float weights are quantized per column exactly like the
    per-call path (``quantize_symmetric(w, operand_bits, axis=-2)``).

    ``shards > 1`` stores the K-sharded layout: quantization stays global
    (bit-identical scales), then the int8 bank is split into ``shards``
    row blocks of ``K/shards`` and each block is tile-padded for the
    *shard-local* engine (``pallas_tiling`` of ``dpu.shard_local``), so
    the concatenated rows shard contiguously over a mesh axis.
    """
    w = params["w"]
    if already_quantized or "w_scale" in params:
        wq = w
        scale = params["w_scale"].astype(jnp.float32)
    else:
        # No dtype cast: bitwise-identical to the per-call
        # quantize_symmetric(w, operand_bits, axis=0) it replaces.
        wq, s = quantize_symmetric(w, engine.dpu.operand_bits, axis=-2)
        scale = jnp.squeeze(s, axis=-2)
    k, c = wq.shape[-2], wq.shape[-1]
    if shards > 1 and k % shards:
        raise ValueError(f"K={k} is not divisible by shards={shards}")
    k_local = k // shards
    tiling = None
    if engine.backend == "pallas":
        tile_dpu = engine.dpu.shard_local(k_local) if shards > 1 else engine.dpu
        n_chunk, tile_k, tile_c = pallas_tiling(tile_dpu, k_local, c)
        kp = -(-k_local // tile_k) * tile_k
        cp = -(-c // tile_c) * tile_c
        lead = wq.shape[:-2]
        if shards > 1:
            wq = wq.reshape(*lead, shards, k_local, c)
        pad = [(0, 0)] * (wq.ndim - 2) + [(0, kp - k_local), (0, cp - c)]
        wq = jnp.pad(wq, pad)
        if shards > 1:
            wq = wq.reshape(*lead, shards * kp, cp)
        tiling = (n_chunk, tile_k, tile_c)
    out = {"w": PackedDense(wq, scale, k, c, tiling, shards)}
    if "b" in params:
        out["b"] = params["b"]
    return out


def fuse_qkv_params(attn: dict, engine: PhotonicEngine) -> dict:
    """Fuse a self-attention dict's ``wq``/``wk``/``wv`` into one ``wqkv``.

    The Q/K/V projections share the streaming activation; as three sites
    they cost three engine dispatches and three activation quantizations
    per token.  Fused into one ``(K, Cq+Ck+Cv)`` bank they cost one —
    ``models/attention.py::_qkv_proj`` splits the output columns back.

    Bitwise contract: per-column quantization, the K-chunked accumulation
    (:func:`~repro.photonic.engine.pallas_tiling` chunks by ``(cfg, K)``
    only) and the fused epilogue are all column-independent, so under a
    deterministic channel the fused call equals the three separate calls
    bit-for-bit, column by column.  Only the *noisy* channel diverges:
    the noise stream is seeded per site ("attn.wqkv" vs three names), a
    different but equally valid draw.

    Accepts prepacked (:class:`PackedDense`, unsharded), int8-stored
    (``w`` + per-column ``w_scale``) or float parts — mixed layouts or
    K-sharded packs are an error.  Biases must be all present or all
    absent (``qkv_bias``).  Leading stack dims pass through, so stacked
    layer trees fuse in one call.
    """
    names = ("wq", "wk", "wv")
    missing = [n for n in names if n not in attn]
    if missing:
        raise KeyError(f"fuse_qkv_params: attention dict lacks {missing}")
    parts = [attn[n] for n in names]
    packed = [isinstance(p["w"], PackedDense) for p in parts]
    scaled = ["w_scale" in p for p in parts]
    if (any(packed) and not all(packed)) or (any(scaled) and not all(scaled)):
        raise ValueError("fuse_qkv_params: mixed Q/K/V weight layouts")
    with_bias = ["b" in p for p in parts]
    if any(with_bias) and not all(with_bias):
        raise ValueError("fuse_qkv_params: bias on only some of Q/K/V")

    if all(packed):
        packs = [p["w"] for p in parts]
        if any(pk.shards != 1 for pk in packs):
            raise ValueError("fuse_qkv_params: K-sharded packs not supported")
        k = packs[0].k
        if any(pk.k != k for pk in packs):
            raise ValueError(
                f"fuse_qkv_params: mismatched K {[pk.k for pk in packs]}"
            )
        # Slice each bank to its logical columns (drops per-site tile
        # padding), concatenate, re-pad once for the fused width.
        wq = jnp.concatenate(
            [pk.wq[..., : pk.k, : pk.c] for pk in packs], axis=-1
        )
        scale = jnp.concatenate([pk.w_scale for pk in packs], axis=-1)
        c = sum(pk.c for pk in packs)
        tiling = None
        if engine.backend == "pallas":
            n_chunk, tile_k, tile_c = pallas_tiling(engine.dpu, k, c)
            kp = -(-k // tile_k) * tile_k
            cp = -(-c // tile_c) * tile_c
            pad = [(0, 0)] * (wq.ndim - 2) + [(0, kp - k), (0, cp - c)]
            wq = jnp.pad(wq, pad)
            tiling = (n_chunk, tile_k, tile_c)
        fused = {"w": PackedDense(wq, scale, k, c, tiling, 1)}
    elif all(scaled):
        # int8-stored layout: columns (and their dequant scales) just
        # concatenate; the engine wraps the result on the fly as before.
        fused = {
            "w": jnp.concatenate([p["w"] for p in parts], axis=-1),
            "w_scale": jnp.concatenate([p["w_scale"] for p in parts], axis=-1),
        }
    else:
        # Float weights: per-column quantization at call time is column-
        # independent, so concatenation alone preserves the contract.
        fused = {"w": jnp.concatenate([p["w"] for p in parts], axis=-1)}

    if all(with_bias):
        fused["b"] = jnp.concatenate([p["b"] for p in parts], axis=-1)
    out = {name: val for name, val in attn.items() if name not in names}
    out["wqkv"] = fused
    return out


def prepack_params(
    params: Any,
    defs: Any,
    engine: PhotonicEngine,
    *,
    mesh=None,
    axis: str = "model",
) -> Any:
    """Prepack every policy-routed dense site of a model parameter tree.

    ``defs`` is the matching param-definition tree (``P`` leaves, see
    ``repro.models.common``); it identifies dense sites and their dotted
    names, so routing decisions here agree with the site names the model
    code passes to ``dense(...)`` at call time.  Non-routed sites (e.g.
    the MoE ``router`` under the default policy) are left untouched and
    keep executing digitally.

    With ``mesh`` (and the ``axis`` mesh axis sized > 1) the int8 banks
    are stored in the K-sharded layout and placed with the repo's
    logical-axis sharding rules (``runtime/sharding.py``: weight fan-in
    on the tensor-parallel axis, per-column scales replicated), ready for
    :mod:`repro.photonic.sharded` execution.  Sites whose K does not
    divide the axis fall back to the unsharded layout (and stay on the
    single-device path at call time).
    """
    shards = 1
    if mesh is not None and axis in mesh.shape:
        shards = int(mesh.shape[axis])

    def place(packed: dict) -> dict:
        """device_put the pack onto the mesh via the logical-axis rules."""
        from repro.runtime import sharding as shd

        rules = {"fanin": axis, "out": None}
        pd = packed["w"]
        lead = (None,) * (pd.wq.ndim - 2)
        wq_sh = shd.named_sharding(mesh, pd.wq.shape, lead + ("fanin", "out"), rules)
        sc_sh = shd.named_sharding(
            mesh, pd.w_scale.shape, (None,) * pd.w_scale.ndim, rules
        )
        packed = dict(packed)
        packed["w"] = PackedDense(
            jax.device_put(pd.wq, wq_sh),
            jax.device_put(pd.w_scale, sc_sh),
            pd.k,
            pd.c,
            pd.tiling,
            pd.shards,
        )
        return packed

    def walk(p, d, path):
        if _is_dense_def(d):
            if engine.routes(site_name(path)):
                k = p["w"].shape[-2]
                site_shards = shards if k % shards == 0 else 1
                packed = pack_dense(
                    p,
                    engine,
                    already_quantized="w_scale" in d,
                    shards=site_shards,
                )
                return place(packed) if site_shards > 1 else packed
            return p
        if isinstance(d, dict):
            return {k: walk(p[k], d[k], path + (k,)) for k in d}
        return p

    return walk(params, defs, ())
