"""Engine-side attention-core surface for the fused photonic hot path.

``models/attention.py`` may not import ``repro.kernels`` (RPR003 — kernel
backends are reachable only through the ``repro.photonic`` surface), so
the flash-attention kernel is exported to models from here.  This is the
second half of the fused QKV prototype (DESIGN.md §14): the QKV
projections run as one fused-epilogue photonic GEMM
(:func:`repro.photonic.packing.fuse_qkv_params`), and its float output
feeds the Pallas flash kernel directly — Q/K/V tiles stream from the
projection into the attention kernel's VMEM working set instead of
round-tripping through an HBM-resident scores matrix, and the whole
attention core is one dispatch instead of a per-KV-chunk scan.

Selected per model with ``ModelConfig.attn_impl = "flash"``; the default
("chunked") keeps the jnp online-softmax scan.  The two cores are the
same math with different block partitions, so they agree to float
tolerance, not bitwise — decode (R=1) and the paged paths stay on their
explicit-softmax/chunked cores either way.
"""

from __future__ import annotations

from repro.kernels.flash_attention.ops import flash_attention

__all__ = ["flash_attention"]
