"""Bit-slicing execution mode spec (DESIGN.md §15).

The DPU already bit-slices: every ``operand_bits``-bit operand is
decomposed into ``ceil(operand_bits / B)`` signed-magnitude slices of
the analog precision ``B`` and recombined with exact digital shifts
(paper §III).  :class:`SlicingSpec` makes the *plane width an execution
choice decoupled from the hardware's B*: slicing int8 operands into
2-bit planes runs 16 analog passes instead of 4, but each pass's
product full-scale is ``(2^p - 1)^2`` psum LSBs instead of
``(2^B - 1)^2`` — the detector sigma, referred to that full-scale,
shrinks by the same ratio, and the digital shift-add recombination is
exact.  That trades throughput for fidelity past the per-pass ENOB wall
(arXiv 2407.06134's escape hatch from the 4-bit saturation measured in
``benchmarks/org_accuracy.py``).

``resolve_slicing`` is the single normalization point for the
``slicing=`` argument accepted across the engine GEMM surface
(``int_gemm`` / ``matmul`` / ``matmul_float`` / ``models.common.dense``):
``None`` means "hardware slicing only" (today's behavior, bitwise
unchanged), an int or digit-string is the plane width, and a
:class:`SlicingSpec` passes through.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Union

_VALID_PLANE_BITS = (1, 2, 4, 8)


@dataclasses.dataclass(frozen=True)
class SlicingSpec:
    """Bit-sliced execution mode (frozen, hashable; rides jit closures).

    ``plane_bits`` is the per-pass operand plane width p.  Each GEMM runs
    ``num_planes(operand_bits)**2`` plane-pair passes through the analog
    channel re-referred to the p-bit full-scale
    (:func:`repro.noise.sliced_channel`), recombined with exact shifts —
    under an ideal channel the result is bit-identical to the unsliced
    exact GEMM.
    """

    plane_bits: int = 2

    def __post_init__(self):
        if self.plane_bits not in _VALID_PLANE_BITS:
            raise ValueError(
                f"plane_bits must be one of {_VALID_PLANE_BITS}, got "
                f"{self.plane_bits!r}"
            )

    def num_planes(self, operand_bits: int) -> int:
        """Planes per operand: ceil(operand_bits / plane_bits)."""
        return -(-int(operand_bits) // self.plane_bits)

    def __str__(self) -> str:
        return f"{self.plane_bits}b-planes"


def resolve_slicing(
    slicing: Union[None, int, str, SlicingSpec],
) -> Optional[SlicingSpec]:
    """THE normalization point for the ``slicing=`` mode argument.

    ``None`` / ``"none"`` -> ``None`` (unsliced, today's datapath);
    an int or digit-string -> ``SlicingSpec(plane_bits)``; a spec passes
    through.  Anything else raises ``ValueError`` eagerly, mirroring
    ``repro.orgs.resolve`` / ``repro.platforms.resolve``.
    """
    if slicing is None:
        return None
    if isinstance(slicing, SlicingSpec):
        return slicing
    if isinstance(slicing, bool):  # bool is an int; reject it explicitly
        raise ValueError(
            f"slicing must be None, an int, or SlicingSpec, got {slicing!r}"
        )
    if isinstance(slicing, int):
        return SlicingSpec(plane_bits=slicing)
    if isinstance(slicing, str):
        text = slicing.strip().lower()
        if text in ("", "none", "off"):
            return None
        if text.isdigit():
            return SlicingSpec(plane_bits=int(text))
    raise ValueError(
        f"slicing must be None, plane bits (int or digit string), or a "
        f"SlicingSpec, got {slicing!r}"
    )
