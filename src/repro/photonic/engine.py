"""The photonic execution engine: one dispatcher for every DPU GEMM.

The paper's DPUs are *weight-stationary*: weight MRRs are programmed once
per tile, then inputs stream through at the symbol rate (crossbar MRR
accelerators program weights into ring banks, arXiv:2401.16072; the
bit-sliced integer representation that makes the weight operand
prepackable is the byte-size integer GEMM decomposition of
arXiv:2407.06134).  :class:`PhotonicEngine` is the software image of that
operating point:

* a :class:`~repro.core.dpu.DPUConfig` (organization — any
  ``str | OrgSpec`` the :func:`repro.orgs.resolve` point accepts,
  including orderings the paper never studied — precision, rate, analog
  channel),
* a backend (``ref`` oracle / ``pallas`` TPU kernel / ``exact`` upper
  bound),
* a :class:`SitePolicy` deciding which *named GEMM sites* ("attn.wq",
  "ffn.wi", "lm_head", ...) execute photonically — expert-routing
  projections ("router") stay digital by default,
* deterministic site-folded seed derivation, so same-shaped GEMMs at
  different sites (or different layers of a scanned stack) draw
  decorrelated noise from one ``noise_seed``/``prng_key``.

Contracts (DESIGN.md §8/§9): with an ideal channel every backend is
bit-identical to :func:`~repro.kernels.photonic_gemm.ref.exact_int_gemm`;
deterministic analog stages are bitwise across backends; noisy calls need
``prng_key`` or ``DPUConfig.noise_seed`` (same source + same site/fold =>
bitwise-equal).  ``site=None, fold=None`` reproduces the legacy
pre-engine seed derivation bit-for-bit, which is what keeps
``repro.kernels.photonic_gemm.ops`` a thin compatibility wrapper.
"""

from __future__ import annotations

import dataclasses
import fnmatch
import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

# Moved to the Level-2 contract passes in PR-6; re-exported for existing
# call sites (tests, benchmarks) that import it from the engine.
from repro.analysis.contracts import count_weight_round_ops  # noqa: F401
from repro.core.dpu import (
    DPUConfig,
    bit_slices,
    quant_scale,
    quantize_symmetric,
    quantize_with_scale,
)
from repro.kernels.photonic_gemm.epilogue import (
    ACTIVATIONS,
    Epilogue,
    EpilogueArgs,
    EpilogueSpec,
    apply_epilogue,
    as_epilogue,
)
from repro.kernels.photonic_gemm.kernel import (
    photonic_gemm_fused_pallas,
    photonic_gemm_pallas,
)
from repro.kernels.photonic_gemm.ref import exact_int_gemm, photonic_gemm_ref
from repro.noise.channel import sliced_channel
from repro.noise.stages import (
    data_tweak,
    fold_seed,
    key_zero_cotangent,
    seed_from_key,
)
from repro.photonic.slicing import SlicingSpec, resolve_slicing

BACKENDS = ("ref", "pallas", "exact")

# Stream-domain tag folded in ahead of a shard index, so the (site, fold=i)
# and (site, shard=i) streams never coincide (repro.photonic.sharded folds
# the mesh-axis index of each K-shard through this).
SHARD_STREAM_TAG = 0x5348

# Stream-domain tag for the bit-plane index of a sliced GEMM (DESIGN.md
# §15): each plane-pair pass folds (tag, plane) behind the site/fold/shard
# scheme, so plane streams decorrelate from each other and never collide
# with a layer-fold or shard stream.
PLANE_STREAM_TAG = 0x504C


def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m


# The epilogue as its own compilation unit.  The Pallas kernel's fused
# epilogue always runs compiled (the kernel entry is jitted), so the
# ref/exact backends apply theirs through this jitted twin — same op
# sequence, same compilation regime — which keeps the backends
# bitwise-aligned in every calling context even for the FMA-contractable
# bias/activation stages (see the epilogue module docstring).  Under an
# outer ``jit`` this inlines, exactly as the interpret-mode kernel body
# does; the rescale-only default is contraction-free either way.
_jit_apply_epilogue = functools.partial(jax.jit, static_argnames="spec")(
    apply_epilogue
)


def _digital_reference(x, wf, bias, spec: EpilogueSpec) -> jax.Array:
    """Non-routed fallback: the exact digital op order the models used
    before epilogue fusion existed — matmul in ``x.dtype``, bias added in
    the *output* dtype, activation from the shared table — so excluded
    sites stay bitwise-stable against the pre-fusion path."""
    y = x @ wf
    if bias is not None:
        y = y + bias.astype(y.dtype)
    if spec.activation is not None:
        y = ACTIVATIONS[spec.activation](y)
    return y


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


def site_hash(site: str) -> int:
    """Stable 32-bit FNV-1a of a site name (process-independent)."""
    h = 2166136261
    for ch in site.encode():
        h = (h ^ ch) * 16777619 & 0xFFFFFFFF
    return h


def pallas_tiling(cfg: DPUConfig, k: int, c: int) -> Tuple[int, int, int]:
    """Static Pallas tiling ``(n_chunk, tile_k, tile_c)`` for a (K, C) weight.

    Depends only on the config and the weight shape — never on the
    activations — which is what makes the padded weight layout prepackable
    (:mod:`repro.photonic.packing`).  Matches the historical
    ``photonic_gemm_int`` tile selection bit-for-bit.
    """
    channel = cfg.effective_channel()
    analog = channel is not None and channel.analog
    adc_bits = channel.adc_bits if channel is not None else cfg.adc_bits
    if adc_bits is None and not analog:
        # Chunking numerically irrelevant -> MXU-aligned tiles.
        tile_k = 512 if k >= 512 else _round_up(max(k, 128), 128)
        n_chunk = min(128, tile_k)
    else:
        # DPU-faithful chunking at the achievable DPE size N.
        n = cfg.n
        n_chunk = n
        tile_k = n * max(1, 512 // n)
    tile_c = min(128, _round_up(c, 128))
    return n_chunk, tile_k, tile_c


@dataclasses.dataclass(frozen=True)
class SitePolicy:
    """Which named GEMM sites execute on the photonic DPU.

    Patterns are matched (``fnmatch``-style) against the full dotted site
    name ("ffn.router") *and* its final component ("router"), so
    leaf-level patterns compose across models.  A ``None`` site (caller
    did not name the GEMM) always routes — backward compatible with the
    pre-engine behavior.

    The default excludes ``router``: MoE expert-routing decisions are
    control flow, not bulk compute, and a noisy analog channel would
    perturb top-k selection; opt it in with ``exclude=()`` (or
    ``ModelConfig.photonic_exclude=()``).
    """

    include: Tuple[str, ...] = ("*",)
    exclude: Tuple[str, ...] = ("router",)

    def routes(self, site: Optional[str]) -> bool:
        if site is None:
            return True
        return self._match(self.include, site) and not self._match(self.exclude, site)

    @staticmethod
    def _match(patterns: Tuple[str, ...], site: str) -> bool:
        leaf = site.rsplit(".", 1)[-1]
        return any(
            fnmatch.fnmatchcase(site, p) or fnmatch.fnmatchcase(leaf, p)
            for p in patterns
        )


@dataclasses.dataclass(frozen=True)
class EngineInfo:
    """Structured ``PhotonicEngine.describe()`` result (PR-9 API redesign).

    A frozen snapshot of the engine's operating point — org, platform,
    backend, slicing mode, channel provenance — consumable as data
    (:meth:`to_dict`, e.g. for the dry-run manifest) while ``str(info)``
    renders the exact human-readable line ``describe()`` historically
    returned, so f-string/logging call sites are unchanged.
    """

    backend: str
    organization: str
    platform: str
    blocks: Tuple[str, ...]
    through_devices: str
    bits: int
    n: int
    datarate_gs: float
    channel: str  # "analog" | "ideal"
    slicing: Optional[int]  # plane bits, or None (unsliced)
    include: Tuple[str, ...]
    exclude: Tuple[str, ...]

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    def __str__(self) -> str:
        # Byte-identical to the historical describe() text at the SOI /
        # unsliced defaults; non-default platform or slicing is inserted
        # between the channel and sites fields.
        extra = "" if self.platform == "SOI" else f"platform={self.platform}, "
        if self.slicing is not None:
            extra += f"slicing={self.slicing}b planes, "
        return (
            f"{self.backend} backend, {self.organization} "
            f"(blocks {'->'.join(self.blocks)}, through {self.through_devices}) "
            f"B={self.bits} N={self.n} @ {self.datarate_gs} GS/s, "
            f"channel={self.channel}, {extra}"
            f"sites include={list(self.include)} "
            f"exclude={list(self.exclude)}"
        )


@dataclasses.dataclass(frozen=True)
class PhotonicEngine:
    """Frozen photonic operating point + routing policy (hashable, so it
    can ride through ``jit`` closures and ``custom_vjp`` static args).

    ``slicing`` selects the bit-sliced execution mode (DESIGN.md §15):
    when set, every routed GEMM decomposes its int operands into
    ``plane_bits``-wide signed-magnitude planes, runs each plane pair
    through the analog channel re-referred to the plane full-scale, and
    recombines with exact digital shifts.  Under an ideal channel the
    result is bit-identical to the unsliced exact GEMM; under a noisy
    channel each plane pass draws a decorrelated stream (the plane index
    folds behind the site/fold/shard scheme).
    """

    dpu: DPUConfig = DPUConfig()
    backend: str = "ref"
    policy: SitePolicy = SitePolicy()
    slicing: Optional[SlicingSpec] = None

    def __post_init__(self):
        if self.backend not in BACKENDS:
            raise ValueError(
                f"unknown photonic backend {self.backend!r}; expected one of "
                f"{BACKENDS}"
            )
        # Normalize through THE slicing resolution point (None | int |
        # str | SlicingSpec -> Optional[SlicingSpec], eager ValueError).
        object.__setattr__(self, "slicing", resolve_slicing(self.slicing))

    # -- policy --------------------------------------------------------------
    def routes(self, site: Optional[str]) -> bool:
        return self.policy.routes(site)

    def with_slicing(self, slicing) -> "PhotonicEngine":
        """This engine with a different slicing mode (frozen-replace)."""
        spec = resolve_slicing(slicing)
        if spec == self.slicing:
            return self
        return dataclasses.replace(self, slicing=spec)

    def describe(self) -> EngineInfo:
        d = self.dpu
        ch = d.effective_channel()
        spec = d.org_spec
        return EngineInfo(
            backend=self.backend,
            organization=d.organization,
            platform=ch.platform if ch is not None else d.platform,
            blocks=tuple(spec.blocks),
            through_devices=spec.through_devices,
            bits=d.bits,
            n=d.n,
            datarate_gs=d.datarate_gs,
            channel="analog" if ch is not None and ch.analog else "ideal",
            slicing=None if self.slicing is None else self.slicing.plane_bits,
            include=tuple(self.policy.include),
            exclude=tuple(self.policy.exclude),
        )

    # -- seed derivation -----------------------------------------------------
    def stream_seed(
        self,
        site: Optional[str],
        fold,
        prng_key: Optional[jax.Array],
        xq: jax.Array,
        wq: jax.Array,
        shard=None,
        plane=None,
    ) -> jax.Array:
        """uint32 noise-stream seed for one GEMM call.

        Precedence matches :meth:`DPUConfig.noise_seed_array` (explicit
        ``prng_key`` wins over ``noise_seed``; neither => the documented
        ``ValueError``).  The site name and an optional traced ``fold``
        index (e.g. the layer counter of a ``lax.scan`` stack) are folded
        in *before* the operand-content tweak, so same-shaped, same-seed
        GEMMs at different sites/layers decorrelate even when their
        operand contents coincide.  ``shard`` is the (traced) mesh-axis
        index of a K-sharded call, folded behind a tag so shards draw
        decorrelated noise and the shard stream never collides with a
        layer-fold stream; ``plane`` is the plane-pair index of a
        bit-sliced call, folded behind its own tag the same way.
        ``site=None, fold=None, shard=None, plane=None`` is bitwise the
        legacy derivation.
        """
        if prng_key is not None:
            key = prng_key
            if site is not None:
                key = jax.random.fold_in(key, site_hash(site) & 0x7FFFFFFF)
            if fold is not None:
                key = jax.random.fold_in(key, fold)
            if shard is not None:
                key = jax.random.fold_in(key, SHARD_STREAM_TAG)
                key = jax.random.fold_in(key, shard)
            if plane is not None:
                key = jax.random.fold_in(key, PLANE_STREAM_TAG)
                key = jax.random.fold_in(key, plane)
            seed = seed_from_key(key)
        else:
            seed = self.dpu.noise_seed_array(None)
            if site is not None:
                seed = fold_seed(seed, jnp.uint32(site_hash(site)))
            if fold is not None:
                seed = fold_seed(seed, fold)
            if shard is not None:
                seed = fold_seed(seed, jnp.uint32(SHARD_STREAM_TAG), shard)
            if plane is not None:
                seed = fold_seed(
                    seed, jnp.uint32(PLANE_STREAM_TAG), jnp.uint32(plane)
                )
        # Operand-content tweak (zero-padding is hash-neutral, so padded
        # prepacked weights derive the same stream as per-call operands).
        return data_tweak(seed, xq, wq)

    # -- integer datapath (single implementation for every caller) -----------
    def int_gemm(
        self,
        xq: jax.Array,  # (R, K) int — quantized inputs
        wq: jax.Array,  # (K, C) int, or (Kp, Cp) prepacked tile-padded
        *,
        site: Optional[str] = None,
        fold=None,
        shard=None,
        plane=None,
        prng_key: Optional[jax.Array] = None,
        logical_kc: Optional[Tuple[int, int]] = None,
        tiling: Optional[Tuple[int, int, int]] = None,
        interpret: Optional[bool] = None,
        tile_r: int = 128,
        tile_c: int = 128,
        epilogue: Optional[EpilogueArgs] = None,
        slicing=None,
    ) -> jax.Array:
        """Integer GEMM through the DPU datapath; int32 (R, C).

        ``logical_kc``/``tiling`` describe a prepacked, tile-padded weight
        (see :class:`repro.photonic.packing.PackedDense`); without them
        the weight is taken at face value and padded per call.  ``shard``
        is the mesh-axis index of a K-sharded call and ``plane`` the
        plane-pair index of a bit-sliced one (see :meth:`stream_seed`);
        both only perturb the noise stream.

        ``slicing`` overrides the engine's bit-slicing mode for this call
        (``None`` inherits ``self.slicing``; pass ``"none"`` to force the
        unsliced datapath).  The ``exact`` backend ignores slicing — the
        plane decomposition is exact, so sliced-exact == exact.

        With ``epilogue`` this is the *fused hot path* (DESIGN.md §14):
        ``xq`` may be a float activation — quantized against
        ``epilogue.x_scale`` in-kernel on the Pallas backend, digitally
        (same op sequence) elsewhere — and the int32 accumulator is
        rescaled / biased / activated before it ever materializes,
        returning f32 ``(R, C)``.  Without it the historical integer
        contract is unchanged: int in, int32 out.
        """
        mode = self.slicing if slicing is None else resolve_slicing(slicing)
        if mode is not None and self.backend != "exact":
            return self._sliced_int_gemm(
                mode,
                xq,
                wq,
                site=site,
                fold=fold,
                shard=shard,
                prng_key=prng_key,
                logical_kc=logical_kc,
                interpret=interpret,
                tile_r=tile_r,
                tile_c=tile_c,
                epilogue=epilogue,
            )
        k, c = logical_kc if logical_kc is not None else wq.shape[-2:]
        cfg = self.dpu
        channel = cfg.effective_channel()
        analog = channel is not None and channel.analog
        adc_bits = channel.adc_bits if channel is not None else cfg.adc_bits
        noisy = analog and channel.detector_sigma_lsb > 0.0

        if jnp.issubdtype(xq.dtype, jnp.floating):
            if epilogue is None:
                raise TypeError(
                    "int_gemm got float activations without an EpilogueArgs; "
                    "quantize explicitly or pass epilogue= (fused hot path)"
                )
            if noisy or self.backend != "pallas":
                # The noise-stream seed hashes the *integer* activation
                # image, and only the Pallas kernel has an in-kernel
                # prologue — everywhere else quantize digitally (the same
                # op sequence as the in-kernel ``quantize_tile``).
                xq = quantize_with_scale(xq, epilogue.x_scale, cfg.operand_bits)

        if self.backend == "exact":
            acc = exact_int_gemm(xq, wq[:k, :c])
            return acc if epilogue is None else _finish(acc, epilogue)

        seed = (
            self.stream_seed(site, fold, prng_key, xq, wq, shard=shard, plane=plane)
            if noisy
            else None
        )

        if self.backend == "ref":
            acc = photonic_gemm_ref(
                xq,
                wq[:k, :c],
                slice_bits=cfg.bits,
                num_slices=cfg.num_slices,
                n_chunk=cfg.n,
                adc_bits=adc_bits,
                channel=channel,
                seed=seed,
            )
            return acc if epilogue is None else _finish(acc, epilogue)

        assert self.backend == "pallas", self.backend
        if interpret is None:
            interpret = _on_cpu()
        r = xq.shape[0]
        if tiling is not None:
            n_chunk, tile_k, tc = tiling  # prepacked layout is authoritative
        else:
            n_chunk, tile_k, _ = pallas_tiling(cfg, k, c)
            # Honour the caller's tile_c bound exactly as the legacy entry
            # point did (values above 128 are legal).
            tc = min(tile_c, _round_up(c, 128))
        tr = min(tile_r, _round_up(r, 8))
        rp, kp, cp = _round_up(r, tr), _round_up(k, tile_k), _round_up(c, tc)
        xp = jnp.pad(xq, ((0, rp - r), (0, kp - k)))
        if wq.shape != (kp, cp):
            wq = jnp.pad(wq[:k, :c], ((0, kp - k), (0, cp - c)))
        ch = channel
        seed_arr = None if seed is None else seed.astype(jnp.int32).reshape(1)
        stages = dict(
            slice_bits=cfg.bits,
            num_slices=cfg.num_slices,
            n_chunk=n_chunk,
            adc_bits=adc_bits,
            noise_sigma=ch.detector_sigma_lsb if analog else 0.0,
            filter_alpha=ch.filter_alpha if analog else 0.0,
            intermod_eps=ch.intermod_eps if analog else 0.0,
            crossweight_eps=ch.crossweight_eps if analog else 0.0,
            valid_chunks=-(-k // n_chunk) if noisy else None,
            tile_r=tr,
            tile_c=tc,
            tile_k=tile_k,
            interpret=interpret,
        )
        if epilogue is None:
            out = photonic_gemm_pallas(xp, wq, seed_arr, **stages)
            return out[:r, :c]
        ws = epilogue.w_scale.astype(jnp.float32).reshape(-1)
        bias = epilogue.bias
        out = photonic_gemm_fused_pallas(
            xp,
            wq,
            epilogue.x_scale,
            jnp.pad(ws, (0, cp - c)),
            None if bias is None else jnp.pad(bias.astype(jnp.float32), (0, cp - c)),
            seed_arr,
            operand_bits=cfg.operand_bits,
            activation=epilogue.spec.activation,
            out_dtype=jnp.float32,
            **stages,
        )
        return out[:r, :c]

    def _sliced_int_gemm(
        self,
        mode: SlicingSpec,
        xq: jax.Array,
        wq: jax.Array,
        *,
        site,
        fold,
        shard,
        prng_key,
        logical_kc,
        interpret,
        tile_r,
        tile_c,
        epilogue: Optional[EpilogueArgs],
    ) -> jax.Array:
        """Bit-sliced execution (DESIGN.md §15): decompose both operands
        into ``mode.plane_bits``-wide signed-magnitude planes, run every
        plane pair through the analog channel re-referred to the plane
        full-scale (:func:`repro.noise.sliced_channel`), recombine with
        exact digital shifts.  Each pass folds its plane-pair index into
        the noise stream, so plane passes decorrelate; under an ideal
        channel the shift-add recombination is bit-identical to
        :func:`exact_int_gemm`.

        Prepacked tilings are dropped — plane passes run at the plane
        engine's own tiling over the logical ``(K, C)`` region (the plane
        operands are re-materialized per call anyway).
        """
        cfg = self.dpu
        k, c = logical_kc if logical_kc is not None else wq.shape[-2:]
        if jnp.issubdtype(xq.dtype, jnp.floating):
            if epilogue is None:
                raise TypeError(
                    "int_gemm got float activations without an EpilogueArgs; "
                    "quantize explicitly or pass epilogue= (fused hot path)"
                )
            # Planes are precomputed digitally, so the activation is
            # always quantized up front (same op sequence as in-kernel).
            xq = quantize_with_scale(xq, epilogue.x_scale, cfg.operand_bits)
        plane_eng = _plane_engine(self, mode)
        p = mode.plane_bits
        planes = mode.num_planes(cfg.operand_bits)
        x_pl = bit_slices(xq, p, planes)  # (P, R, K) int8
        w_pl = bit_slices(wq[:k, :c], p, planes)  # (P, K, C) int8
        acc = jnp.zeros((xq.shape[0], c), jnp.int32)
        for si in range(planes):
            for ti in range(planes):
                part = plane_eng.int_gemm(
                    x_pl[si],
                    w_pl[ti],
                    site=site,
                    fold=fold,
                    shard=shard,
                    plane=si * planes + ti,
                    prng_key=prng_key,
                    interpret=interpret,
                    tile_r=tile_r,
                    tile_c=tile_c,
                )
                # Exact digital recombination: q = sum_s plane_s * 2^(p*s)
                # per operand => plane-pair products shift by p*(si+ti).
                acc = acc + part * (1 << (p * (si + ti)))
        return acc if epilogue is None else _finish(acc, epilogue)

    # -- float entry points (STE-differentiable) -----------------------------
    def matmul_float(
        self,
        x: jax.Array,
        w: jax.Array,
        *,
        site: Optional[str] = None,
        fold=None,
        prng_key: Optional[jax.Array] = None,
        epilogue=None,
        slicing=None,
        bias: Optional[jax.Array] = None,
        activation: Optional[str] = None,
    ) -> jax.Array:
        """Float GEMM, quantizing *both* operands per call (QAT/train path).

        ``epilogue=`` (an :class:`EpilogueSpec` or :class:`Epilogue`) is
        the blessed spelling of the fused epilogue request (DESIGN.md
        §14); the legacy ``bias=``/``activation=`` keywords remain as
        bitwise-identical deprecation shims (:func:`as_epilogue` is the
        single normalization point).  ``slicing`` overrides the engine's
        bit-slicing mode for this call.  Non-routed sites fall back to
        the exact digital op order.
        """
        spec, bias = as_epilogue(epilogue, bias=bias, activation=activation)
        eng = self if slicing is None else self.with_slicing(slicing)
        if not eng.routes(site):
            return _digital_reference(x, w.astype(x.dtype), bias, spec)
        fold = None if fold is None else jnp.asarray(fold, jnp.int32)
        return _float_matmul((eng, site, spec), x, w, bias, fold, prng_key)

    def matmul(
        self,
        x: jax.Array,
        packed,  # PackedDense
        *,
        site: Optional[str] = None,
        fold=None,
        prng_key: Optional[jax.Array] = None,
        epilogue=None,
        slicing=None,
        bias: Optional[jax.Array] = None,
        activation: Optional[str] = None,
    ) -> jax.Array:
        """Float GEMM against a prepacked weight — the weight-stationary
        hot path: only the activation is quantized per call, and with a
        float32 activation the quantization itself is deferred into the
        Pallas kernel prologue.

        Accepts the unified ``epilogue=``/``slicing=`` surface exactly as
        :meth:`matmul_float` (legacy ``bias=``/``activation=`` keywords
        are bitwise-identical shims).  Non-routed sites execute the
        dequantized digital matmul.
        """
        spec, bias = as_epilogue(epilogue, bias=bias, activation=activation)
        eng = self if slicing is None else self.with_slicing(slicing)
        if not eng.routes(site):
            return _digital_reference(x, packed.dequant().astype(x.dtype), bias, spec)
        fold = None if fold is None else jnp.asarray(fold, jnp.int32)
        meta = (eng, site, packed.k, packed.c, packed.tiling, spec)
        return _packed_matmul(meta, x, packed.wq, packed.w_scale, bias, fold, prng_key)


@functools.lru_cache(maxsize=None)
def _plane_engine(engine: PhotonicEngine, mode: SlicingSpec) -> PhotonicEngine:
    """The single-plane-pass engine of a sliced ``engine``: analog
    precision = plane width (one slice pass, no hardware re-slicing),
    geometry frozen at the parent's achievable N (slicing is an execution
    mode, not a different accelerator), channel re-referred to the plane
    full-scale.  Cached so jit retraces see one frozen engine identity.
    """
    cfg = engine.dpu
    p = mode.plane_bits
    updates = dict(bits=p, operand_bits=p, dpe_size=cfg.n)
    if cfg.channel is not None:
        updates["channel"] = sliced_channel(cfg.channel, p)
    elif cfg.noise_sigma_lsb > 0.0:
        # Legacy raw-sigma configs: sigma is referred to the product
        # full-scale, which shrinks with the plane width.
        scale = float((2**p - 1) ** 2) / float((2**cfg.bits - 1) ** 2)
        updates["noise_sigma_lsb"] = cfg.noise_sigma_lsb * scale
    return dataclasses.replace(
        engine, dpu=dataclasses.replace(cfg, **updates), slicing=None
    )


@functools.lru_cache(maxsize=None)
def engine_for(
    dpu: DPUConfig,
    backend: str,
    include: Tuple[str, ...] = ("*",),
    exclude: Tuple[str, ...] = ("router",),
    slicing=None,
) -> PhotonicEngine:
    """Cached engine construction (one frozen engine per operating point,
    so ``jit`` retraces don't multiply)."""
    return PhotonicEngine(
        dpu=dpu,
        backend=backend,
        policy=SitePolicy(include, exclude),
        slicing=resolve_slicing(slicing),
    )


# ---------------------------------------------------------------------------
# Shared float-entry forward (the quant / dequant shoulder logic lives once)
# ---------------------------------------------------------------------------
def _finish(acc: jax.Array, e: EpilogueArgs) -> jax.Array:
    """Apply the fused epilogue to a digital int32 accumulator, through the
    jitted twin so the compilation regime matches the Pallas kernel's."""
    return _jit_apply_epilogue(
        acc, e.x_scale, e.w_scale.astype(jnp.float32), e.bias, e.spec
    )


def _stream_gemm(
    eng: "PhotonicEngine",
    site,
    spec: EpilogueSpec,
    x,
    wq,
    w_scale,
    bias,
    fold,
    prng_key,
    *,
    logical_kc=None,
    tiling=None,
):
    """One forward through the fused hot path, shared by the per-call and
    prepacked float entry points (previously duplicated in both impls).

    Quantizes the streaming activation — *deferred* for f32 streams, where
    only the scale is computed here (bitwise `quantize_symmetric`'s) and
    the rounding happens in the Pallas prologue or digitally inside
    ``int_gemm`` — then runs the integer datapath with the epilogue fused.
    """
    lead = x.shape[:-1]
    xr = x.reshape(-1, x.shape[-1])
    if xr.dtype == jnp.float32:
        xs, sx = xr, quant_scale(xr, eng.dpu.operand_bits)
    else:
        # Non-f32 floats divide by the raw-dtype scale inside
        # quantize_symmetric (see its docstring) — not expressible as a
        # deferred f32-scale prologue, so quantize digitally up front.
        xs, sx = quantize_symmetric(xr, eng.dpu.operand_bits)
    cols = logical_kc[1] if logical_kc is not None else wq.shape[1]
    y = eng.int_gemm(
        xs,
        wq,
        site=site,
        fold=fold,
        prng_key=prng_key,
        logical_kc=logical_kc,
        tiling=tiling,
        epilogue=EpilogueArgs(spec, sx, w_scale, bias),
    )
    return y.reshape(*lead, cols).astype(x.dtype)


def _epilogue_bwd(spec: EpilogueSpec, g2, x2, wf, bias):
    """Backward of the epilogue under the engine's STE convention: straight
    through the quantized GEMM (pre-activation recomputed from the float
    operands), exact through bias and activation.  Returns the gradient at
    the GEMM output and the bias cotangent (``None`` when bias is)."""
    if spec.activation is not None:
        pre = x2 @ wf
        if bias is not None:
            pre = pre + bias.astype(jnp.float32)
        _, act_vjp = jax.vjp(ACTIVATIONS[spec.activation], pre)
        (g2,) = act_vjp(g2)
    db = None if bias is None else g2.sum(axis=0).astype(bias.dtype)
    return g2, db


# ---------------------------------------------------------------------------
# STE custom-VJP wrappers (module level: stable identity across jit traces)
# ---------------------------------------------------------------------------
@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _float_matmul(meta, x, w, bias, fold, prng_key):
    return _float_fwd_impl(meta, x, w, bias, fold, prng_key)


def _float_fwd_impl(meta, x, w, bias, fold, prng_key):
    eng, site, spec = meta
    wq, sw = quantize_symmetric(w, eng.dpu.operand_bits, axis=0)
    return _stream_gemm(eng, site, spec, x, wq, sw, bias, fold, prng_key)


def _float_fwd(meta, x, w, bias, fold, prng_key):
    y = _float_fwd_impl(meta, x, w, bias, fold, prng_key)
    return y, (x, w, bias, fold, prng_key)


def _float_bwd(meta, res, g):
    _, _, spec = meta
    x, w, bias, fold, prng_key = res
    g2 = g.reshape(-1, g.shape[-1]).astype(jnp.float32)
    x2 = x.reshape(-1, x.shape[-1]).astype(jnp.float32)
    wf = w.astype(jnp.float32)
    g2, db = _epilogue_bwd(spec, g2, x2, wf, bias)
    dx = (g2 @ wf.T).reshape(x.shape).astype(x.dtype)
    dw = (x2.T @ g2).astype(w.dtype)
    return dx, dw, db, key_zero_cotangent(fold), key_zero_cotangent(prng_key)


_float_matmul.defvjp(_float_fwd, _float_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _packed_matmul(meta, x, wq, w_scale, bias, fold, prng_key):
    return _packed_fwd_impl(meta, x, wq, w_scale, bias, fold, prng_key)


def _packed_fwd_impl(meta, x, wq, w_scale, bias, fold, prng_key):
    eng, site, k, c, tiling, spec = meta
    return _stream_gemm(
        eng,
        site,
        spec,
        x,
        wq,
        w_scale,
        bias,
        fold,
        prng_key,
        logical_kc=(k, c),
        tiling=tiling,
    )


def _packed_fwd(meta, x, wq, w_scale, bias, fold, prng_key):
    y = _packed_fwd_impl(meta, x, wq, w_scale, bias, fold, prng_key)
    return y, (x, wq, w_scale, bias, fold, prng_key)


def _packed_bwd(meta, res, g):
    _, site, k, c, _, spec = meta
    x, wq, w_scale, bias, fold, prng_key = res
    wf = wq[:k, :c].astype(jnp.float32) * w_scale.astype(jnp.float32)[None, :]
    g2 = g.reshape(-1, g.shape[-1]).astype(jnp.float32)
    x2 = x.reshape(-1, x.shape[-1]).astype(jnp.float32)
    g2, db = _epilogue_bwd(spec, g2, x2, wf, bias)
    dx = (g2 @ wf.T).reshape(x.shape).astype(x.dtype)
    # Prepacked weights are frozen serving state: int8 slices get the
    # mandatory float0 cotangent, the scale a plain zero.
    return (
        dx,
        key_zero_cotangent(wq),
        jnp.zeros_like(w_scale),
        db,
        key_zero_cotangent(fold),
        key_zero_cotangent(prng_key),
    )


_packed_matmul.defvjp(_packed_fwd, _packed_bwd)
