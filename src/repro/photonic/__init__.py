"""`repro.photonic` — the weight-stationary photonic execution engine.

One subsystem owns "which GEMMs run on the photonic DPU, and how":

* :class:`~repro.photonic.engine.PhotonicEngine` — frozen operating point
  (DPUConfig + backend + per-site routing policy + site-folded seed
  derivation).  Every photonic GEMM in the repo dispatches through it.
* :mod:`~repro.photonic.packing` — one-time weight prepacking
  (:func:`prepack_params`): per-column int8 quantization + per-backend
  layout (tile-padded for Pallas) producing :class:`PackedDense` leaves
  the engine consumes without re-quantizing the static operand.

The paper's DPUs are weight-stationary (weight MRRs are programmed once
per tile, inputs stream at the symbol rate); prepacking is the software
image of that: quantize/pack the weight once, stream activations through.
"""

# The epilogue vocabulary is re-exported here (its home is a leaf module
# under repro.kernels) so models/ can speak EpilogueSpec without importing
# kernel internals (RPR003).
from repro.kernels.photonic_gemm.epilogue import (
    ACTIVATIONS,
    Epilogue,
    EpilogueArgs,
    EpilogueSpec,
    as_epilogue,
)
from repro.photonic.engine import (
    EngineInfo,
    PhotonicEngine,
    SitePolicy,
    engine_for,
)
from repro.photonic.packing import (
    PackedDense,
    fuse_qkv_params,
    pack_dense,
    prepack_params,
)
from repro.photonic.sharded import (
    manual_tp,
    psum_int_gemm,
    shard_local_engine,
    tensor_parallel,
)
from repro.photonic.slicing import SlicingSpec, resolve_slicing

__all__ = [
    "ACTIVATIONS",
    "EngineInfo",
    "Epilogue",
    "EpilogueArgs",
    "EpilogueSpec",
    "PhotonicEngine",
    "SitePolicy",
    "SlicingSpec",
    "PackedDense",
    "as_epilogue",
    "engine_for",
    "fuse_qkv_params",
    "manual_tp",
    "pack_dense",
    "prepack_params",
    "psum_int_gemm",
    "resolve_slicing",
    "shard_local_engine",
    "tensor_parallel",
]
