"""Tensor-parallel photonic execution: shard-local channels over the mesh.

The paper's fifth signal manipulation — **Summation** — accumulates each
DPE's analog partial dot-product in the electrical/digital domain.  That
is exactly the semantics of sharding a GEMM's reduction axis K over a
device mesh: every shard evaluates its local fan-in and the partials meet
in one digital ``psum``.  The correspondence is physical, not just
notational — Table II crosstalk and the Table III loss chain scale with
the *per-DPE* fan-in, so a K-sharded GEMM must evaluate its
:class:`~repro.noise.ChannelModel` at ``N_local = min(N, K/shards)``
rather than the global ``N`` (the circuit-level N-partitioning argument
of arXiv:2407.06134, lifted to the system-sharding level).  Sharding
*helps* the analog channel: fewer rings per waveguide, shorter
propagation, more delivered power per psum.  The rebuild goes through
:func:`repro.noise.shard_local_channel`, whose builder provenance records
the canonical organization name — so sharding works identically for the
paper-studied orders and any :class:`repro.orgs.OrgSpec` ordering.

Execution modes (both dispatch from ``models.common.dense`` via
:func:`maybe_tp_matmul`):

* **GSPMD mode** — :func:`tensor_parallel` ``(mesh, axis)``: each routed
  GEMM wraps itself in a ``shard_map`` over the tensor-parallel axis.
  Activations shard on K, prepacked int8 banks shard on their fan-in
  rows (``repro.photonic.packing.prepack_params(mesh=...)``), per-column
  scales replicate.  Quantization scales are ``pmax``-reduced to the
  global abs-max, so every shard quantizes bitwise-identically to the
  unsharded path.
* **manual mode** — :func:`manual_tp` ``(axis)``: for call sites already
  inside a ``shard_map`` body (``runtime/dp_step.py``), where a nested
  ``shard_map`` is illegal.  Operands arrive replicated; each device
  slices its K block by ``axis_index`` and the same collective core runs.

Contracts (DESIGN.md §10, ``tests/test_sharded_engine.py``):

* ideal channel ⇒ K-sharded output is **bitwise equal** to the unsharded
  engine on every backend (integer psum is associative; max-based scales
  are reduction-order exact);
* each shard's channel model equals ``build_channel_model`` evaluated at
  its ``N_local`` (:func:`repro.noise.shard_local_channel`);
* noisy calls stay deterministic per ``noise_seed``/``prng_key`` and
  decorrelate across shards — the (site, layer, shard) triple is folded
  into the noise stream (:data:`repro.photonic.engine.SHARD_STREAM_TAG`).
"""

from __future__ import annotations

import contextlib
import dataclasses
import functools
import threading
from typing import Optional

import jax
import jax.numpy as jnp

from repro import compat
from repro.compat import PartitionSpec as P
from repro.core.dpu import quantize_symmetric
from repro.kernels.photonic_gemm.epilogue import apply_epilogue, as_epilogue
from repro.noise.stages import key_zero_cotangent
from repro.photonic.engine import PhotonicEngine, _epilogue_bwd
from repro.photonic.packing import PackedDense


# ---------------------------------------------------------------------------
# Shard-local operating points
# ---------------------------------------------------------------------------
@functools.lru_cache(maxsize=None)
def shard_local_engine(engine: PhotonicEngine, k_local: int) -> PhotonicEngine:
    """The engine one K-shard executes: same backend/policy, DPU rebuilt
    at the shard-local fan-in (:meth:`repro.core.dpu.DPUConfig.shard_local`
    — the channel model re-derived at ``N_local``)."""
    return dataclasses.replace(engine, dpu=engine.dpu.shard_local(k_local))


# ---------------------------------------------------------------------------
# Tensor-parallel context
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class TPContext:
    """Active tensor-parallel scope: a mesh axis to K-shard over.

    ``mesh=None`` is *manual* mode — the caller is already inside a
    ``shard_map`` body and the axis name is bound there.
    """

    axis: str
    mesh: Optional[compat.Mesh] = None

    @property
    def manual(self) -> bool:
        return self.mesh is None

    def size(self) -> int:
        if self.mesh is not None:
            return int(self.mesh.shape[self.axis])
        return int(compat.axis_size(self.axis))


class _Ctx(threading.local):
    current: Optional[TPContext] = None


_CTX = _Ctx()


def current_tp() -> Optional[TPContext]:
    """The active TP context, or ``None`` (single-device execution)."""
    return _CTX.current


@contextlib.contextmanager
def tensor_parallel(mesh: compat.Mesh, axis: str = "model"):
    """Run policy-routed ``dense()`` GEMMs K-sharded over ``mesh[axis]``.

    GSPMD mode: every routed GEMM wraps its own ``shard_map`` over
    ``axis`` (legal under an enclosing ``jit``; illegal inside another
    ``shard_map`` — use :func:`manual_tp` there).
    """
    if axis not in mesh.shape:
        raise ValueError(
            f"mesh has no axis {axis!r}; axes are {tuple(mesh.axis_names)}"
        )
    prev = _CTX.current
    _CTX.current = TPContext(axis=axis, mesh=mesh)
    try:
        yield
    finally:
        _CTX.current = prev


@contextlib.contextmanager
def manual_tp(axis: str = "model"):
    """TP for call sites already inside a ``shard_map`` body
    (``runtime/dp_step.py``): operands arrive replicated, each device
    slices its K block by ``axis_index`` and partials meet in ``psum``."""
    prev = _CTX.current
    _CTX.current = TPContext(axis=axis, mesh=None)
    try:
        yield
    finally:
        _CTX.current = prev


# ---------------------------------------------------------------------------
# The collective core (runs with mesh axes bound, i.e. inside shard_map)
# ---------------------------------------------------------------------------
def psum_int_gemm(
    engine: PhotonicEngine,
    xq: jax.Array,  # (R, K_local) int — this shard's activation block
    wq: jax.Array,  # (K_local, C) int, or the shard's padded bank
    *,
    axis: str,
    site: Optional[str] = None,
    fold=None,
    prng_key: Optional[jax.Array] = None,
    logical_kc=None,
    tiling=None,
) -> jax.Array:
    """Shard-local integer GEMM + digital-domain ``psum`` — Summation.

    Must run with ``axis`` bound (inside ``shard_map``).  The shard
    executes ``engine`` rebuilt at its local fan-in, folds its mesh index
    into the noise stream (shards decorrelate), and the int32 partials
    accumulate exactly — bitwise equal to the unsharded engine whenever
    the channel is ideal.
    """
    k_local = int((logical_kc or wq.shape[-2:])[0])
    local = shard_local_engine(engine, k_local)
    shard = jax.lax.axis_index(axis)
    out = local.int_gemm(
        xq,
        wq,
        site=site,
        fold=fold,
        shard=shard,
        prng_key=prng_key,
        logical_kc=logical_kc,
        tiling=tiling,
    )
    return jax.lax.psum(out, axis)


# ---------------------------------------------------------------------------
# shard_map plumbing: optional fold/key operands need static arity
# ---------------------------------------------------------------------------
def _row_sharding(mesh, axis, rows):
    """How the non-contraction (row/batch) dim shards in GSPMD mode.

    Returns the mesh axes to spread rows over — every axis except the TP
    axis — so a DP+TP mesh keeps its data parallelism instead of
    replicating the batch into every TP group; ``None`` (replicate) when
    the row count does not divide, mirroring ``runtime/sharding.py``'s
    divisibility fallback.
    """
    dp_axes = tuple(a for a in mesh.axis_names if a != axis)
    if not dp_axes:
        return None
    dp_size = 1
    for a in dp_axes:
        dp_size *= int(mesh.shape[a])
    if dp_size == 1 or rows % dp_size:
        return None
    return dp_axes


def _run_shard_map(
    mesh, axis, body, args, specs, fold, prng_key, out_spec=P(), bias=None
):
    """Invoke ``body(*main, bias=..., fold=..., prng_key=...)`` under
    shard_map.

    ``bias``/``fold``/``prng_key`` may be ``None`` (absent), an array, or
    (for ``prng_key``) a typed PRNG key; they ride as replicated trailing
    operands so the body signature stays static per presence combination.
    """
    args = list(args)
    specs = list(specs)
    has_bias = bias is not None
    if has_bias:
        args.append(bias)
        specs.append(P())
    has_fold = fold is not None
    if has_fold:
        args.append(jnp.asarray(fold, jnp.int32))
        specs.append(P())
    has_key = prng_key is not None
    typed_key = False
    if has_key:
        if jnp.issubdtype(prng_key.dtype, jax.dtypes.prng_key):
            args.append(jax.random.key_data(prng_key))
            typed_key = True
        else:
            args.append(prng_key)
        specs.append(P())
    n_main = len(args) - int(has_bias) - int(has_fold) - int(has_key)

    def wrapped(*vals):
        main = vals[:n_main]
        i = n_main
        b = vals[i] if has_bias else None
        i += int(has_bias)
        f = vals[i] if has_fold else None
        i += int(has_fold)
        key = vals[i] if has_key else None
        if key is not None and typed_key:
            key = jax.random.wrap_key_data(key)
        return body(*main, bias=b, fold=f, prng_key=key)

    fn = compat.shard_map(
        wrapped,
        mesh=mesh,
        in_specs=tuple(specs),
        out_specs=out_spec,
        check_vma=False,
    )
    return fn(*args)


# ---------------------------------------------------------------------------
# STE float wrappers (module level: stable identity across jit traces)
# ---------------------------------------------------------------------------
def _float_fwd_impl(meta, x, w, bias, fold, prng_key):
    eng, site, axis, mesh, spec = meta
    bits = eng.dpu.operand_bits
    lead = x.shape[:-1]
    k, c = w.shape
    xr = x.reshape(-1, k)
    if mesh is None:
        # Manual mode: operands are replicated inside the enclosing
        # shard_map.  Quantize at the (locally visible) global abs-max,
        # then slice this device's K block — bitwise the scales the
        # unsharded path derives.
        size = int(compat.axis_size(axis))
        k_local = k // size
        xq, sx = quantize_symmetric(xr, bits)
        wq, sw = quantize_symmetric(w, bits, axis=0)
        idx = jax.lax.axis_index(axis)
        xl = jax.lax.dynamic_slice_in_dim(xq, idx * k_local, k_local, axis=1)
        wl = jax.lax.dynamic_slice_in_dim(wq, idx * k_local, k_local, axis=0)
        out = psum_int_gemm(
            eng, xl, wl, axis=axis, site=site, fold=fold, prng_key=prng_key
        )
        y = apply_epilogue(out, sx, sw.astype(jnp.float32), bias, spec)
    else:
        rows = _row_sharding(mesh, axis, xr.shape[0])
        x_axes = (axis,) if rows is None else rows + (axis,)

        def body(xl, wl, *, bias, fold, prng_key):
            # pmax-reduced global abs-max => shard-local quantization is
            # bitwise identical to the unsharded quantization (max is
            # exact under any reduction order).
            ax = jax.lax.pmax(jnp.max(jnp.abs(xl)), x_axes)
            xq, sx = quantize_symmetric(xl, bits, amax=ax)
            aw = jax.lax.pmax(jnp.max(jnp.abs(wl), axis=0, keepdims=True), axis)
            wq, sw = quantize_symmetric(wl, bits, axis=0, amax=aw)
            out = psum_int_gemm(
                eng, xq, wq, axis=axis, site=site, fold=fold,
                prng_key=prng_key,
            )
            # Full fused epilogue inside the collective body: partials meet
            # in the psum, then the replicated bias/activation tail runs on
            # the replicated output — the same op sequence as the
            # single-device epilogue.
            return apply_epilogue(out, sx, sw.astype(jnp.float32), bias, spec)

        y = _run_shard_map(
            mesh,
            axis,
            body,
            (xr, w),
            (P(rows, axis), P(axis, None)),
            fold,
            prng_key,
            out_spec=P(rows),
            bias=bias,
        )
    return y.reshape(*lead, c).astype(x.dtype)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _tp_float_matmul(meta, x, w, bias, fold, prng_key):
    return _float_fwd_impl(meta, x, w, bias, fold, prng_key)


def _tp_float_fwd(meta, x, w, bias, fold, prng_key):
    y = _float_fwd_impl(meta, x, w, bias, fold, prng_key)
    return y, (x, w, bias, fold, prng_key)


def _tp_float_bwd(meta, res, g):
    spec = meta[4]
    x, w, bias, fold, prng_key = res
    g2 = g.reshape(-1, g.shape[-1]).astype(jnp.float32)
    x2 = x.reshape(-1, x.shape[-1]).astype(jnp.float32)
    wf = w.astype(jnp.float32)
    g2, db = _epilogue_bwd(spec, g2, x2, wf, bias)
    dx = (g2 @ wf.T).reshape(x.shape).astype(x.dtype)
    dw = (x2.T @ g2).astype(w.dtype)
    return dx, dw, db, key_zero_cotangent(fold), key_zero_cotangent(prng_key)


_tp_float_matmul.defvjp(_tp_float_fwd, _tp_float_bwd)


def _packed_fwd_impl(meta, x, wq, w_scale, bias, fold, prng_key):
    eng, site, axis, mesh, k, c, tiling, shards, spec = meta
    bits = eng.dpu.operand_bits
    lead = x.shape[:-1]
    xr = x.reshape(-1, x.shape[-1])
    if mesh is None:
        # Manual mode: raw (K, C) int8 layout only (guarded by
        # maybe_tp_matmul) — slice this device's rows.
        size = int(compat.axis_size(axis))
        k_local = k // size
        xq, sx = quantize_symmetric(xr, bits)
        idx = jax.lax.axis_index(axis)
        xl = jax.lax.dynamic_slice_in_dim(xq, idx * k_local, k_local, axis=1)
        wl = jax.lax.dynamic_slice_in_dim(wq, idx * k_local, k_local, axis=0)
        out = psum_int_gemm(
            eng,
            xl,
            wl,
            axis=axis,
            site=site,
            fold=fold,
            prng_key=prng_key,
            logical_kc=(k_local, c),
        )
        y = apply_epilogue(out, sx, w_scale.astype(jnp.float32), bias, spec)
    else:
        size = int(mesh.shape[axis])
        k_local = k // size
        rows = _row_sharding(mesh, axis, xr.shape[0])
        x_axes = (axis,) if rows is None else rows + (axis,)

        def body(xl, wl, scale, *, bias, fold, prng_key):
            ax = jax.lax.pmax(jnp.max(jnp.abs(xl)), x_axes)
            xq, sx = quantize_symmetric(xl, bits, amax=ax)
            out = psum_int_gemm(
                eng,
                xq,
                wl,
                axis=axis,
                site=site,
                fold=fold,
                prng_key=prng_key,
                logical_kc=(k_local, c),
                tiling=tiling,
            )
            return apply_epilogue(out, sx, scale.astype(jnp.float32), bias, spec)

        # Activations shard rows over the DP axes and K over the TP axis,
        # int8 banks shard on their fan-in rows (the sharded pack stores
        # per-shard padded banks contiguously), the global per-column
        # scales replicate.
        y = _run_shard_map(
            mesh,
            axis,
            body,
            (xr, wq, w_scale),
            (P(rows, axis), P(axis, None), P()),
            fold,
            prng_key,
            out_spec=P(rows),
            bias=bias,
        )
    return y.reshape(*lead, c).astype(x.dtype)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _tp_packed_matmul(meta, x, wq, w_scale, bias, fold, prng_key):
    return _packed_fwd_impl(meta, x, wq, w_scale, bias, fold, prng_key)


def _tp_packed_fwd(meta, x, wq, w_scale, bias, fold, prng_key):
    y = _packed_fwd_impl(meta, x, wq, w_scale, bias, fold, prng_key)
    return y, (x, wq, w_scale, bias, fold, prng_key)


def _tp_packed_bwd(meta, res, g):
    _, _, _, _, k, c, tiling, shards, spec = meta
    x, wq, w_scale, bias, fold, prng_key = res
    wf = PackedDense(wq, w_scale, k, c, tiling, shards).dequant()
    g2 = g.reshape(-1, g.shape[-1]).astype(jnp.float32)
    x2 = x.reshape(-1, x.shape[-1]).astype(jnp.float32)
    g2, db = _epilogue_bwd(spec, g2, x2, wf, bias)
    dx = (g2 @ wf.T).reshape(x.shape).astype(x.dtype)
    # Prepacked weights are frozen serving state: int8 banks get the
    # mandatory float0 cotangent, the scale a plain zero.
    return (
        dx,
        key_zero_cotangent(wq),
        jnp.zeros_like(w_scale),
        db,
        key_zero_cotangent(fold),
        key_zero_cotangent(prng_key),
    )


_tp_packed_matmul.defvjp(_tp_packed_fwd, _tp_packed_bwd)


# ---------------------------------------------------------------------------
# dense() dispatch
# ---------------------------------------------------------------------------
def maybe_tp_matmul(
    engine: Optional[PhotonicEngine],
    params: dict,
    x: jax.Array,
    cfg,
    *,
    site: Optional[str] = None,
    fold=None,
    prng_key: Optional[jax.Array] = None,
    epilogue=None,
    slicing=None,
    bias: Optional[jax.Array] = None,
    activation: Optional[str] = None,
) -> Optional[jax.Array]:
    """The tensor-parallel product for ``models.common.dense``.

    Returns ``None`` when TP does not apply — no active context, TP
    degree 1, a site the policy keeps digital, a contraction K the axis
    does not divide, or a pack layout the active mode cannot shard —
    and the caller falls through to the single-device path.
    ``epilogue=`` rides the fused epilogue inside the collective body
    (replicated operands, applied after the psum); the legacy ``bias=``/
    ``activation=`` keywords are bitwise-identical shims.  ``slicing``
    overrides the engine's bit-slicing mode — it rides into every
    shard-local pass through :func:`shard_local_engine`.
    """
    ctx = current_tp()
    if ctx is None or engine is None or not engine.routes(site):
        return None
    size = ctx.size()
    if size <= 1:
        return None
    spec, bias = as_epilogue(epilogue, bias=bias, activation=activation)
    if slicing is not None:
        engine = engine.with_slicing(slicing)
    fold = None if fold is None else jnp.asarray(fold, jnp.int32)
    w = params["w"]
    if isinstance(w, PackedDense):
        packed = w
    elif "w_scale" in params:
        packed = PackedDense(w, params["w_scale"], w.shape[-2], w.shape[-1])
    elif getattr(cfg, "photonic_scope", "weights") == "weights":
        k, c = w.shape
        if k % size:
            return None
        meta = (engine, site, ctx.axis, ctx.mesh, spec)
        return _tp_float_matmul(meta, x, w, bias, fold, prng_key)
    else:
        return None
    if packed.k % size:
        return None
    if packed.tiling is not None:
        # Tile-padded banks are only shardable in the layout they were
        # packed for: GSPMD mode, pack shards == TP degree.
        if ctx.mesh is None or packed.shards != size:
            return None
    elif packed.shards not in (1, size):
        return None
    meta = (
        engine,
        site,
        ctx.axis,
        ctx.mesh,
        packed.k,
        packed.c,
        packed.tiling,
        packed.shards,
        spec,
    )
    return _tp_packed_matmul(
        meta, x, packed.wq, packed.w_scale, bias, fold, prng_key
    )
