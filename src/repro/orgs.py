"""First-class DPU organization specs (paper §III, Tables I–IV).

The paper's core classification variable is the *order* in which the four
optical signal manipulations appear along a channel's path:

* **S** — Splitting (1:M power fan-out to the DPE columns)
* **A** — Aggregation (WDM fan-in of the N channels onto a shared bus)
* **M** — Modulation (MRM bank imprinting the input symbols)
* **W** — Weighting (MRR bank applying the weight column)

followed by the terminal **Σ** (Summation at the balanced photodetector).
The paper studies three orders — ASMW, MASW, SMWA — and hand-tabulates
their crosstalk (Table II), loss structure (Table III), and lumped network
penalty (Table IV).  :class:`OrgSpec` makes the order itself the API and
*derives* those circuit-level properties structurally, so any valid
ordering — including the nine the paper never studied — gets a physically
consistent profile (see DESIGN.md §11 for the rule-by-rule derivation and
``benchmarks/org_design_space.py`` for the full-design-space sweep).

Derivation rules (all pure functions of the block order):

1. **Inter-modulation crosstalk** iff Aggregation precedes Modulation:
   the N WDM channels co-propagate through the MRM bank, so a modulator
   ring partially modulates its spectral neighbors (Table II row 1).
2. **Cross-weight crosstalk** iff Aggregation precedes Weighting: the
   aggregated channels traverse a shared weight bank, so a weight ring
   partially weights the adjacent wavelengths (Table II row 2).
3. **Filter truncation** iff Modulation precedes Aggregation: aggregating
   *already-modulated* channels needs a per-channel resonant add/drop mux
   whose passband truncates the modulated sidebands (Table II row 3; an
   unmodulated-carrier combine, as in ASMW, is broadband and filter-free).
4. **Through-device count**: each ring bank a channel shares with the
   other N-1 channels (a bank placed after Aggregation) contributes
   ``N-1`` out-of-resonance traversals; a ring add/drop mux (rule 3)
   contributes ``2`` when Aggregation is terminal (the hitless per-DPE
   add+drop pair at the detector) and ``1`` otherwise (a single add ring
   onto the bus).  Reproduces the paper's §IV-B1 counts: ASMW
   ``2(N-1)``, MASW ``N``, SMWA ``2``.
5. **Waveguide-length factor**: ``1.5`` for hitless layouts (both M and W
   before A — per-channel modulator+weight paths replicate N×M), ``0.75``
   when the modulator bank precedes Splitting (one input array shared by
   all M DPEs), ``1.0`` otherwise.  Reproduces Table III's propagation
   ordering (SMWA high, ASMW moderate, MASW low).
6. **Lumped penalty**: the §IV-C effect budgets (1 / 3 / 0.5 dB) summed
   over the active crosstalk mechanisms, plus two anchors calibrated
   against Table IV — a 1.3 dB base network penalty and a 0.5 dB
   surcharge when both ring banks sit on the shared bus.  Reproduces the
   Table IV values 5.8 / 4.8 / 1.8 dB exactly.

Everything downstream funnels through :func:`resolve` — the single
``str | OrgSpec`` resolution point used by ``DPUConfig``,
``AcceleratorConfig``, ``build_channel_model``, and the scalability
solver.  Strings are case-insensitive; unknown names raise ``ValueError``
naming the valid choices.
"""

from __future__ import annotations

import dataclasses
import functools
import itertools
from typing import Dict, Tuple, Union

SPLIT, AGG, MOD, WEIGHT, SUM = "S", "A", "M", "W", "Sigma"
_MANIPULATIONS = (SPLIT, AGG, MOD, WEIGHT)

# Optimistic per-effect power budgets assumed by the paper (§IV-C) when
# composing P_penalty: inter-modulation <= 1 dB, cross-weight <= 3 dB,
# filter truncation < 0.5 dB.
EFFECT_BUDGET_DB: Dict[str, float] = {
    "inter_modulation": 1.0,
    "cross_weight": 3.0,
    "filter_truncation": 0.5,
}

# Penalty anchors calibrated against Table IV (rule 6 above): with the
# §IV-C budgets they reproduce the paper's lumped penalties exactly
# (ASMW 1+3+1.3+0.5 = 5.8, MASW 3+0.5+1.3 = 4.8, SMWA 0.5+1.3 = 1.8).
PENALTY_BASE_DB = 1.3
PENALTY_DUAL_BANK_DB = 0.5


@dataclasses.dataclass(frozen=True)
class CrosstalkProfile:
    """Which crosstalk effects are present (paper Table II)."""

    inter_modulation: bool
    cross_weight: bool
    filter_truncation: bool


@dataclasses.dataclass(frozen=True)
class LossProfile:
    """Qualitative loss levels (paper Table III) + structural device counts."""

    through_loss_level: str  # "high" | "moderate" | "low"
    propagation_loss_level: str  # "high" | "moderate" | "low"
    # Number of out-of-resonance devices traversed by a channel before the
    # BPD, as a function of DPE size N (paper §IV-B1).
    #   ASMW: 2(N-1)   MASW: N   SMWA: 2
    through_devices: str  # formula id, e.g. "2(N-1)" | "N" | "2"
    # Relative waveguide-length factor for propagation loss (SMWA uses more,
    # longer waveguides because of its hitless N*M layout; MASW shares one
    # input array).  Multiplies N * d_mrr in the structural model.
    waveguide_length_factor: float


def _through_formula(scale: int, offset: int) -> str:
    """Canonical formula id for ``scale*(N-1) + offset`` through devices."""
    if scale == 0:
        return str(offset)
    coeff = "" if scale == 1 else str(scale)
    if offset == 0:
        return f"{coeff}(N-1)"
    if offset == scale:  # a(N-1) + a = aN
        return f"{coeff}N" if coeff else "N"
    delta = offset - scale
    return f"{coeff}N{delta:+d}" if coeff else f"N{delta:+d}"


@dataclasses.dataclass(frozen=True)
class OrgSpec:
    """A DPU organization, identified by its block order.

    Frozen and hashable (rides through ``jit`` closures, ``lru_cache``
    keys, and frozen configs).  Identity *is* the order: two specs are
    equal iff their blocks are equal, and ``name`` is the canonical
    four-letter order string ("ASMW").  Every circuit-level property is
    derived from the order by the module-docstring rules.
    """

    blocks: Tuple[str, ...]  # permutation of (S, A, M, W) + terminal Sigma

    def __post_init__(self):
        blocks = tuple(self.blocks)
        object.__setattr__(self, "blocks", blocks)
        if len(blocks) != 5 or blocks[-1] != SUM or (
            sorted(blocks[:-1]) != sorted(_MANIPULATIONS)
        ):
            raise ValueError(
                f"invalid block order {blocks!r}: expected a permutation of "
                f"{_MANIPULATIONS} followed by the terminal {SUM!r}"
            )
        if blocks.index(MOD) > blocks.index(WEIGHT):
            raise ValueError(
                f"invalid block order {''.join(blocks[:-1])!r}: Modulation "
                "must precede Weighting (paper §III-A — weights apply to "
                "modulated symbols)"
            )

    # -- identity ------------------------------------------------------------
    @property
    def name(self) -> str:
        """Canonical order string, e.g. ``"ASMW"``."""
        return "".join(self.blocks[:-1])

    @classmethod
    def from_order(cls, order: str) -> "OrgSpec":
        """Spec from a four-letter order string (case-insensitive)."""
        return _from_order_cached(_normalize_order(order))

    def before(self, a: str, b: str) -> bool:
        """True when block ``a`` precedes block ``b`` in this order."""
        return self.blocks.index(a) < self.blocks.index(b)

    @property
    def terminal_aggregation(self) -> bool:
        """Aggregation immediately feeds Summation (hitless detector mux)."""
        return self.blocks[-2] == AGG

    # -- Table II: crosstalk (rules 1-3) -------------------------------------
    @property
    def inter_modulation(self) -> bool:
        return self.before(AGG, MOD)

    @property
    def cross_weight(self) -> bool:
        return self.before(AGG, WEIGHT)

    @property
    def filter_truncation(self) -> bool:
        return self.before(MOD, AGG)

    @property
    def crosstalk(self) -> CrosstalkProfile:
        return CrosstalkProfile(
            inter_modulation=self.inter_modulation,
            cross_weight=self.cross_weight,
            filter_truncation=self.filter_truncation,
        )

    # -- Table III: loss structure (rules 4-5) -------------------------------
    @property
    def shared_bus_banks(self) -> int:
        """Ring banks (M, W) placed on the aggregated multi-channel bus."""
        return int(self.inter_modulation) + int(self.cross_weight)

    @property
    def mux_through_devices(self) -> int:
        """Out-of-resonance mux-ring traversals (rule 4): the hitless
        terminal add+drop pair counts 2, a mid-path add ring counts 1."""
        if not self.filter_truncation:
            return 0
        return 2 if self.terminal_aggregation else 1

    def through_device_count(self, n: int) -> int:
        """Out-of-resonance devices traversed by one channel (§IV-B1)."""
        return self.shared_bus_banks * (n - 1) + self.mux_through_devices

    @property
    def through_devices(self) -> str:
        """Formula id of :meth:`through_device_count` ("2(N-1)" | "N" | ...)."""
        return _through_formula(self.shared_bus_banks, self.mux_through_devices)

    @property
    def waveguide_length_factor(self) -> float:
        if self.before(MOD, AGG) and self.before(WEIGHT, AGG):
            return 1.5  # hitless: per-channel M+W paths replicate N x M
        if self.before(MOD, SPLIT):
            return 0.75  # one modulator array shared by all M DPEs
        return 1.0

    @property
    def through_loss_level(self) -> str:
        if self.shared_bus_banks == 2:
            return "high"
        if self.shared_bus_banks == 1:
            return "moderate"
        # Constant through count: the hitless terminal mux is an
        # in-resonance add+drop per channel (lossy per pass) -> "high";
        # anything else barely touches out-of-resonance rings.
        return "high" if self.terminal_aggregation else "low"

    @property
    def propagation_loss_level(self) -> str:
        f = self.waveguide_length_factor
        return "high" if f >= 1.25 else ("moderate" if f >= 1.0 else "low")

    @property
    def losses(self) -> LossProfile:
        return LossProfile(
            through_loss_level=self.through_loss_level,
            propagation_loss_level=self.propagation_loss_level,
            through_devices=self.through_devices,
            waveguide_length_factor=self.waveguide_length_factor,
        )

    # -- Table IV: lumped network penalty (rule 6) ---------------------------
    @property
    def derived_penalty_db(self) -> float:
        """Structural P_penalty: §IV-C budgets over the active crosstalk
        mechanisms + the Table IV-calibrated anchors.  Exactly reproduces
        the paper's 5.8 / 4.8 / 1.8 dB for ASMW / MASW / SMWA."""
        p = PENALTY_BASE_DB
        if self.inter_modulation:
            p += EFFECT_BUDGET_DB["inter_modulation"]
        if self.cross_weight:
            p += EFFECT_BUDGET_DB["cross_weight"]
        if self.filter_truncation:
            p += EFFECT_BUDGET_DB["filter_truncation"]
        if self.shared_bus_banks == 2:
            p += PENALTY_DUAL_BANK_DB
        return round(p, 6)

    # -- Fig. 2: ring counts (perf model) ------------------------------------
    def rings_per_dpu(self, n: int, m: int) -> int:
        """Active rings per DPU at DPE size ``n``, fan-out ``m`` (Fig. 2).

        A bank placed before Splitting is shared by all M DPEs (``n``
        rings); after Splitting it replicates per DPE (``n*m``).  A
        terminal ring mux adds the per-DPE wavelength demux ahead of each
        BPD (``n*m``); a mid-path add mux is the shared input combiner
        and is not counted (it replaces a broadband combiner 1:1).
        Reproduces the legacy counts: ASMW ``2NM``, MASW ``N + NM``,
        SMWA ``3NM``.
        """
        mrm = n if self.before(MOD, SPLIT) else n * m
        weight = n if self.before(WEIGHT, SPLIT) else n * m
        mux = n * m if (self.filter_truncation and self.terminal_aggregation) else 0
        return mrm + weight + mux

    def __str__(self) -> str:
        return self.name


def _normalize_order(order: str) -> str:
    """Canonicalize an order/organization string (strip + casefold to upper).

    THE single blessed normalization site for org-typed strings: both
    ``OrgSpec.from_order`` and ``resolve`` route through it, so case
    handling cannot drift between the two entry points (RPR002 forbids
    ad-hoc ``.upper()`` on org strings anywhere else).
    """
    return order.strip().upper()


@functools.lru_cache(maxsize=None)
def _from_order_cached(order: str) -> OrgSpec:
    if len(order) != 4:
        raise ValueError(
            f"invalid organization order {order!r}: expected 4 letters from "
            f"{_MANIPULATIONS} (e.g. 'SMWA')"
        )
    return OrgSpec(blocks=tuple(order) + (SUM,))


# ---------------------------------------------------------------------------
# Registry: the named organizations (paper Table I entries + user additions)
# ---------------------------------------------------------------------------
_REGISTRY: Dict[str, OrgSpec] = {}
_PRIOR_WORK: Dict[str, Tuple[str, ...]] = {}


def register(spec: OrgSpec, *, prior_work: Tuple[str, ...] = ()) -> OrgSpec:
    """Register ``spec`` under its canonical name; returns the spec.

    Re-registering the same order is a no-op; registering a *different*
    spec under an existing name is impossible (the name is derived from
    the order), so collisions cannot occur.
    """
    _REGISTRY[spec.name] = spec
    if prior_work:
        _PRIOR_WORK[spec.name] = tuple(prior_work)
    return spec


def registered() -> Dict[str, OrgSpec]:
    """Snapshot of the registered organizations (name -> spec)."""
    return dict(_REGISTRY)


def prior_work(org: Union[str, OrgSpec]) -> Tuple[str, ...]:
    """Prior-work accelerators classified under this order (paper Table I)."""
    return _PRIOR_WORK.get(resolve(org).name, ())


def resolve(org: Union[str, OrgSpec]) -> OrgSpec:
    """THE ``str | OrgSpec`` resolution point (case-insensitive).

    Accepts a spec (returned as-is), a registered name, or any valid
    four-letter order string; anything else raises ``ValueError`` naming
    the valid choices.  Every organization-typed entry point
    (``DPUConfig``, ``AcceleratorConfig``, ``build_channel_model``, the
    scalability solver) funnels through here, so validation is eager and
    the error message is uniform.
    """
    if isinstance(org, OrgSpec):
        return org
    if not isinstance(org, str):
        raise ValueError(
            f"organization must be a str or OrgSpec, got {type(org).__name__}"
        )
    name = _normalize_order(org)
    spec = _REGISTRY.get(name)
    if spec is not None:
        return spec
    try:
        return _from_order_cached(name)
    except ValueError:
        raise ValueError(
            f"unknown organization {org!r}: valid choices are "
            f"{tuple(sorted(_REGISTRY))} or any permutation of S/A/M/W with "
            "M before W (e.g. 'MWAS')"
        ) from None


def valid_orderings() -> Tuple[OrgSpec, ...]:
    """The full S/A/M/W design space: every order with M before W (12),
    paper-studied orders first, then the unstudied ones alphabetically."""
    specs = []
    for perm in itertools.permutations(_MANIPULATIONS):
        if perm.index(MOD) < perm.index(WEIGHT):
            specs.append(_from_order_cached("".join(perm)))
    paper = [s for s in specs if s.name in ORGANIZATIONS]
    novel = sorted(
        (s for s in specs if s.name not in ORGANIZATIONS), key=lambda s: s.name
    )
    paper.sort(key=lambda s: ORGANIZATIONS.index(s.name))
    return tuple(paper + novel)


# The three paper-studied organizations (Table I classification).
ASMW = register(
    OrgSpec.from_order("ASMW"),
    prior_work=("Crosslight", "DEAP-CNN", "Robin", "RAMM"),
)
MASW = register(
    OrgSpec.from_order("MASW"),
    prior_work=("Holylight", "Yang", "Al-Qadasi", "PCNNA", "RMAM"),
)
SMWA = register(
    OrgSpec.from_order("SMWA"),
    prior_work=("Hitless", "ADEPT", "Albireo"),
)

# Paper-studied organization names, in Table I order.
ORGANIZATIONS: Tuple[str, ...] = ("ASMW", "MASW", "SMWA")
