"""Deterministic, shard-aware synthetic token pipeline.

Stateless generation keyed on (seed, step, shard) makes the stream
*resumable by construction*: restarting from checkpoint step k reproduces
exactly the batches a failure-free run would have seen — the property the
fault-tolerance test asserts.  Each data-parallel shard draws its disjoint
slice of the global batch, so no cross-host coordination is needed.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int = 50304
    seq_len: int = 1024
    global_batch: int = 64
    seed: int = 0
    # synthetic distribution: mixture of zipf-ish unigrams + copy runs, so
    # models have learnable structure (loss decreases in the train example)
    copy_prob: float = 0.3


class SyntheticTokens:
    """Infinite deterministic token stream."""

    def __init__(self, cfg: DataConfig, shard_index: int = 0, num_shards: int = 1):
        assert cfg.global_batch % num_shards == 0
        self.cfg = cfg
        self.shard_index = shard_index
        self.num_shards = num_shards
        self.local_batch = cfg.global_batch // num_shards
        probs = 1.0 / np.arange(1, cfg.vocab_size + 1) ** 1.1
        self._probs = probs / probs.sum()

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        """The batch for a given global step (stateless — resumable)."""
        cfg = self.cfg
        rng = np.random.default_rng(
            np.random.SeedSequence([cfg.seed, step, self.shard_index])
        )
        toks = rng.choice(
            cfg.vocab_size, size=(self.local_batch, cfg.seq_len + 1), p=self._probs
        ).astype(np.int32)
        # inject copy structure: second half of some rows repeats the first
        copy_rows = rng.random(self.local_batch) < cfg.copy_prob
        half = (cfg.seq_len + 1) // 2
        toks[copy_rows, half : 2 * half] = toks[copy_rows, :half]
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


def make_global_batch(
    stream: SyntheticTokens, step: int, sharding: Optional[jax.sharding.Sharding] = None
) -> Dict[str, jax.Array]:
    """Device-put a step's batch (single-process: full global batch)."""
    host = stream.batch_at(step)
    if sharding is None:
        return {k: jnp.asarray(v) for k, v in host.items()}
    return {k: jax.device_put(v, sharding) for k, v in host.items()}
