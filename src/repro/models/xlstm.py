"""xLSTM blocks (arXiv:2405.04517): mLSTM (matrix memory, chunkwise-parallel
with log-domain stabilization) and sLSTM (scalar memory, sequential scan with
recurrent gating).  Layer pattern 7:1 mLSTM:sLSTM (`slstm_every = 8`).

The mLSTM recurrences (stabilizer m_t):

    m_t = max(log f_t + m_{t-1}, i~_t)
    C_t = e^{log f_t + m_{t-1} - m_t} C_{t-1} + e^{i~_t - m_t} k_t v_t^T
    n_t = e^{log f_t + m_{t-1} - m_t} n_{t-1} + e^{i~_t - m_t} k_t
    h_t = (q_t C_t) / max(|q_t n_t|, e^{-m_t})

Training uses the chunkwise form (within-chunk quadratic masked attention +
`lax.scan` over chunks) — O(T) memory; decode is the O(1) recurrence.
The sLSTM recurrence is sequential by construction (recurrent weights R act
on h_{t-1}); it appears in 1/8 of layers so the scan cost stays contained.
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models import common as cm
from repro.models.common import P, ModelConfig, dense, dense_def, qdense_def


def _inner(cfg: ModelConfig) -> int:
    return cfg.ssm_expand * cfg.d_model  # projection factor 2 by default


def _dh(cfg: ModelConfig) -> int:
    return _inner(cfg) // cfg.num_heads


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------
def mlstm_def(cfg: ModelConfig) -> Dict[str, Any]:
    d, inner, h = cfg.d_model, _inner(cfg), cfg.num_heads
    return {
        "ln": cm.rmsnorm_def(d),
        "up": qdense_def(cfg, d, 2 * inner, (None, "inner")),
        "wq": qdense_def(cfg, inner, inner, (None, "inner")),
        "wk": qdense_def(cfg, inner, inner, (None, "inner")),
        "wv": qdense_def(cfg, inner, inner, (None, "inner")),
        "wi": dense_def(inner, h, (None, None), init="zeros"),
        "wf": dense_def(inner, h, (None, None), init="zeros"),
        "out_norm": cm.rmsnorm_def(inner),
        "down": qdense_def(cfg, inner, d, ("inner", None)),
    }


def _mlstm_chunked(
    q, k, v,          # (B, T, H, dh)
    li, lf,           # (B, T, H)  input-gate preact, log-forget
    chunk: int,
    state: Tuple[jax.Array, jax.Array, jax.Array] | None = None,
    unroll: bool = False,
):
    b, t, h, dh = q.shape
    chunk = min(chunk, t)
    pad = (-t) % chunk
    if pad:
        q, k, v = (jnp.pad(a, ((0, 0), (0, pad), (0, 0), (0, 0))) for a in (q, k, v))
        li = jnp.pad(li, ((0, 0), (0, pad), (0, 0)), constant_values=-1e30)
        lf = jnp.pad(lf, ((0, 0), (0, pad), (0, 0)))
    tp = t + pad
    nc = tp // chunk

    def rs(x):
        return x.reshape(b, nc, chunk, *x.shape[2:]).swapaxes(0, 1)

    qs, ks, vs, lis, lfs = map(rs, (q, k, v, li, lf))
    scale = dh ** -0.5

    if state is None:
        state = (
            jnp.zeros((b, h, dh, dh), jnp.float32),  # C
            jnp.zeros((b, h, dh), jnp.float32),      # n
            jnp.full((b, h), -1e30, jnp.float32),    # m
        )

    def step(carry, inp):
        c_prev, n_prev, m_prev = carry
        qc, kc, vc, lic, lfc = inp
        qc = qc.astype(jnp.float32) * scale
        kc = kc.astype(jnp.float32)
        vc = vc.astype(jnp.float32)
        bcum = jnp.cumsum(lfc, axis=1)                      # (B,L,H)
        # intra log-weights: D[t,s] = b_t - b_s + li_s  (s <= t)
        dmat = bcum[:, :, None, :] - bcum[:, None, :, :] + lic[:, None, :, :]
        tri = jnp.tril(jnp.ones((chunk, chunk), bool))
        dmat = jnp.where(tri[None, :, :, None], dmat, -1e30)
        inter_w = bcum + m_prev[:, None, :]                 # (B,L,H)
        m_t = jnp.maximum(dmat.max(axis=2), inter_w)        # (B,L,H)
        dexp = jnp.exp(dmat - m_t[:, :, None, :])
        s = jnp.einsum("blhd,bshd->blsh", qc, kc) * dexp    # (B,L,S,H)
        num = jnp.einsum("blsh,bshd->blhd", s, vc)
        den = s.sum(axis=2)                                 # (B,L,H)
        wi = jnp.exp(inter_w - m_t)                         # (B,L,H)
        num = num + wi[..., None] * jnp.einsum("blhd,bhde->blhe", qc, c_prev)
        den = den + wi * jnp.einsum("blhd,bhd->blh", qc, n_prev)
        hout = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_t))[..., None]
        # chunk-end state
        btot = bcum[:, -1, :]                               # (B,H)
        up_w = btot[:, None, :] - bcum + lic                # (B,L,H)
        m_next = jnp.maximum(btot + m_prev, up_w.max(axis=1))
        wexp = jnp.exp(up_w - m_next[:, None, :])
        c_new = jnp.einsum("blh,blhd,blhe->bhde", wexp, kc, vc)
        n_new = jnp.einsum("blh,blhd->bhd", wexp, kc)
        decay = jnp.exp(btot + m_prev - m_next)
        c_next = decay[:, :, None, None] * c_prev + c_new
        n_next = decay[:, :, None] * n_prev + n_new
        return (c_next, n_next, m_next), hout

    state, hs = jax.lax.scan(
        step, state, (qs, ks, vs, lis, lfs), unroll=True if unroll else 1
    )
    hs = hs.swapaxes(0, 1).reshape(b, tp, h, dh)[:, :t]
    return hs, state


def _mlstm_qkv_gates(params, xin, cfg: ModelConfig):
    b, t, _ = xin.shape
    h, dh = cfg.num_heads, _dh(cfg)
    q = dense(params["wq"], xin, cfg, site="wq").reshape(b, t, h, dh)
    k = dense(params["wk"], xin, cfg, site="wk").reshape(b, t, h, dh)
    v = dense(params["wv"], xin, cfg, site="wv").reshape(b, t, h, dh)
    li = dense(params["wi"], xin, cfg, site="wi").astype(jnp.float32)         # (B,T,H)
    lf = jax.nn.log_sigmoid(
        dense(params["wf"], xin, cfg, site="wf").astype(jnp.float32)
    )
    return q, k, v, li, lf


def mlstm_block(params, x, cfg: ModelConfig) -> jax.Array:
    res = x
    xn = cm.rmsnorm(params["ln"], x, cfg.norm_eps)
    u = dense(params["up"], xn, cfg, site="up")
    xin, gate = jnp.split(u, 2, axis=-1)
    q, k, v, li, lf = _mlstm_qkv_gates(params, xin, cfg)
    hs, _ = _mlstm_chunked(q, k, v, li, lf, cfg.ssm_chunk, unroll=cfg.unroll_scans)
    hs = hs.reshape(*x.shape[:2], -1).astype(x.dtype)
    y = cm.rmsnorm(params["out_norm"], hs, cfg.norm_eps) * jax.nn.silu(gate)
    return res + dense(params["down"], y, cfg, site="down")


def mlstm_prefill(params, x, cfg: ModelConfig):
    res = x
    xn = cm.rmsnorm(params["ln"], x, cfg.norm_eps)
    u = dense(params["up"], xn, cfg, site="up")
    xin, gate = jnp.split(u, 2, axis=-1)
    q, k, v, li, lf = _mlstm_qkv_gates(params, xin, cfg)
    hs, (c, n, m) = _mlstm_chunked(
        q, k, v, li, lf, cfg.ssm_chunk, unroll=cfg.unroll_scans
    )
    hs = hs.reshape(*x.shape[:2], -1).astype(x.dtype)
    y = cm.rmsnorm(params["out_norm"], hs, cfg.norm_eps) * jax.nn.silu(gate)
    return res + dense(params["down"], y, cfg, site="down"), {"C": c, "n": n, "m": m}


def mlstm_decode(params, x, state, cfg: ModelConfig):
    """x: (B,1,D); O(1) recurrent step."""
    res = x
    h, dh = cfg.num_heads, _dh(cfg)
    xn = cm.rmsnorm(params["ln"], x, cfg.norm_eps)
    u = dense(params["up"], xn, cfg, site="up")
    xin, gate = jnp.split(u, 2, axis=-1)
    q, k, v, li, lf = _mlstm_qkv_gates(params, xin, cfg)
    q1 = q[:, 0].astype(jnp.float32) * (dh ** -0.5)  # (B,H,dh)
    k1, v1 = k[:, 0].astype(jnp.float32), v[:, 0].astype(jnp.float32)
    li1, lf1 = li[:, 0], lf[:, 0]                    # (B,H)
    c_prev, n_prev, m_prev = state["C"], state["n"], state["m"]
    m_t = jnp.maximum(lf1 + m_prev, li1)
    fw = jnp.exp(lf1 + m_prev - m_t)
    iw = jnp.exp(li1 - m_t)
    c_t = fw[:, :, None, None] * c_prev + iw[:, :, None, None] * jnp.einsum(
        "bhd,bhe->bhde", k1, v1
    )
    n_t = fw[:, :, None] * n_prev + iw[:, :, None] * k1
    num = jnp.einsum("bhd,bhde->bhe", q1, c_t)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", q1, n_t)), jnp.exp(-m_t))
    hout = (num / den[..., None]).reshape(x.shape[0], 1, -1).astype(x.dtype)
    y = cm.rmsnorm(params["out_norm"], hout, cfg.norm_eps) * jax.nn.silu(gate)
    return res + dense(params["down"], y, cfg, site="down"), {
        "C": c_t, "n": n_t, "m": m_t
    }


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------
def slstm_def(cfg: ModelConfig) -> Dict[str, Any]:
    d, h = cfg.d_model, cfg.num_heads
    dh = d // h
    return {
        "ln": cm.rmsnorm_def(d),
        "wx": qdense_def(cfg, d, 4 * d, (None, "inner")),
        "r": P((h, dh, 4 * dh), (None, None, None)),  # block-diag recurrent
        "out_norm": cm.rmsnorm_def(d),
        "down": qdense_def(cfg, d, d, ("inner", None)),
    }


def _slstm_scan(params, gx, cfg: ModelConfig, state):
    """gx: (B, T, 4D) input-side gate preacts. Sequential over T."""
    b, t, _ = gx.shape
    d = cfg.d_model
    h = cfg.num_heads
    dh = d // h
    r = params["r"].astype(jnp.float32)

    def step(carry, g_t):
        c, n, m, hprev = carry  # (B,D),(B,D),(B,D),(B,D)
        rec = jnp.einsum("bhd,hde->bhe", hprev.reshape(b, h, dh), r).reshape(b, 4 * d)
        g = g_t.astype(jnp.float32) + rec
        gi, gf, gz, go = jnp.split(g, 4, axis=-1)
        m_t = jnp.maximum(jax.nn.log_sigmoid(gf) + m, gi)
        fw = jnp.exp(jax.nn.log_sigmoid(gf) + m - m_t)
        iw = jnp.exp(gi - m_t)
        c_t = fw * c + iw * jnp.tanh(gz)
        n_t = fw * n + iw
        h_t = jax.nn.sigmoid(go) * c_t / jnp.maximum(n_t, 1e-6)
        return (c_t, n_t, m_t, h_t), h_t

    state, hs = jax.lax.scan(step, state, gx.swapaxes(0, 1))
    return hs.swapaxes(0, 1), state


def _slstm_init_state(b, d):
    z = jnp.zeros((b, d), jnp.float32)
    return (z, z, jnp.full((b, d), -1e30, jnp.float32), z)


def slstm_block(params, x, cfg: ModelConfig) -> jax.Array:
    res = x
    xn = cm.rmsnorm(params["ln"], x, cfg.norm_eps)
    gx = dense(params["wx"], xn, cfg, site="wx")
    hs, _ = _slstm_scan(params, gx, cfg, _slstm_init_state(x.shape[0], cfg.d_model))
    hs = hs.astype(x.dtype)
    y = cm.rmsnorm(params["out_norm"], hs, cfg.norm_eps)
    return res + dense(params["down"], y, cfg, site="down")


def slstm_prefill(params, x, cfg: ModelConfig):
    res = x
    xn = cm.rmsnorm(params["ln"], x, cfg.norm_eps)
    gx = dense(params["wx"], xn, cfg, site="wx")
    hs, (c, n, m, h) = _slstm_scan(
        params, gx, cfg, _slstm_init_state(x.shape[0], cfg.d_model)
    )
    hs = hs.astype(x.dtype)
    y = cm.rmsnorm(params["out_norm"], hs, cfg.norm_eps)
    return res + dense(params["down"], y, cfg, site="down"), {
        "c": c, "n": n, "m": m, "h": h
    }


def slstm_decode(params, x, state, cfg: ModelConfig):
    res = x
    xn = cm.rmsnorm(params["ln"], x, cfg.norm_eps)
    gx = dense(params["wx"], xn, cfg, site="wx")
    st = (state["c"], state["n"], state["m"], state["h"])
    hs, (c, n, m, h) = _slstm_scan(params, gx, cfg, st)
    hs = hs.astype(x.dtype)
    y = cm.rmsnorm(params["out_norm"], hs, cfg.norm_eps)
    return res + dense(params["down"], y, cfg, site="down"), {
        "c": c, "n": n, "m": m, "h": h
    }


# ---------------------------------------------------------------------------
# Full xLSTM LM (groups of slstm_every-1 mLSTM + 1 sLSTM)
# ---------------------------------------------------------------------------
def xlstm_def(cfg: ModelConfig) -> Dict[str, Any]:
    from repro.models.lm import stack_defs

    n_groups = cfg.num_layers // cfg.slstm_every
    per = cfg.slstm_every - 1
    return {
        "embed": cm.embed_def(cfg.n_vocab, cfg.d_model),
        "mlstm": stack_defs(stack_defs(mlstm_def(cfg), per), n_groups),
        "slstm": stack_defs(slstm_def(cfg), n_groups),
        "final_norm": cm.rmsnorm_def(cfg.d_model),
        "lm_head": cm.qdense_def(cfg, cfg.d_model, cfg.n_vocab, (None, "vocab")),
    }


def _xlstm_body(params, x, cfg: ModelConfig, mode: str, states=None):
    """Shared scan over groups for train ('full'), prefill, decode."""

    def group(carry, inp):
        x = carry
        if mode == "full":
            mparams, sparams = inp
            blk = cm.apply_remat(lambda p, x: mlstm_block(p, x, cfg), cfg)

            def inner_step(x, p):
                x = blk(p, x)
                return cm.with_logical(x, ("batch", None, None)), None

            x, _ = jax.lax.scan(inner_step, x, mparams)
            x = slstm_block(sparams, x, cfg)
            return x, None
        elif mode == "prefill":
            mparams, sparams = inp

            def inner_step(x, p):
                x, st = mlstm_prefill(p, x, cfg)
                return x, st

            x, msts = jax.lax.scan(inner_step, x, mparams)
            x, sst = slstm_prefill(sparams, x, cfg)
            return x, (msts, sst)
        else:  # decode
            mparams, sparams, mst, sst = inp

            def inner_step(x, pst):
                p, st = pst
                x, st = mlstm_decode(p, x, st, cfg)
                return x, st

            x, msts = jax.lax.scan(inner_step, x, (mparams, mst))
            x, sst = slstm_decode(sparams, x, sst, cfg)
            return x, (msts, sst)

    if mode == "decode":
        xs = (params["mlstm"], params["slstm"], states["mlstm"], states["slstm"])
    else:
        xs = (params["mlstm"], params["slstm"])
    x, sts = jax.lax.scan(group, x, xs)
    return x, sts


def xlstm_logits(params, tokens, cfg: ModelConfig):
    x = cm.embed(params["embed"], tokens, cfg)
    x = cm.with_logical(x, ("batch", None, None))
    x, _ = _xlstm_body(params, x, cfg, "full")
    x = cm.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return cm.dense(params["lm_head"], x, cfg, site="lm_head"), jnp.zeros(
        (), jnp.float32
    )


def xlstm_loss(params, batch, cfg: ModelConfig):
    logits, _ = xlstm_logits(params, batch["tokens"], cfg)
    return cm.softmax_cross_entropy(logits, batch["labels"], cfg.vocab_size)


def xlstm_prefill(params, tokens, cfg: ModelConfig, max_seq: int = 0):
    x = cm.embed(params["embed"], tokens, cfg)
    x, sts = _xlstm_body(params, x, cfg, "prefill")
    msts, ssts = sts
    x = cm.rmsnorm(params["final_norm"], x[:, -1:], cfg.norm_eps)
    logits = cm.dense(params["lm_head"], x, cfg, site="lm_head")
    cache = {"mlstm": msts, "slstm": ssts, "pos": jnp.array(tokens.shape[1], jnp.int32)}
    return logits, cache


def xlstm_decode(params, token, cache, cfg: ModelConfig):
    x = cm.embed(params["embed"], token, cfg)
    x, sts = _xlstm_body(params, x, cfg, "decode", states=cache)
    msts, ssts = sts
    x = cm.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = cm.dense(params["lm_head"], x, cfg, site="lm_head")
    return logits, {"mlstm": msts, "slstm": ssts, "pos": cache["pos"] + 1}


def xlstm_cache_def(cfg: ModelConfig, batch: int, max_seq: int, dtype):
    n_groups = cfg.num_layers // cfg.slstm_every
    per = cfg.slstm_every - 1
    h, dh, d = cfg.num_heads, _dh(cfg), cfg.d_model
    return {
        "mlstm": {
            "C": (
                (n_groups, per, batch, h, dh, dh),
                (None, None, "batch", None, "inner", None),
                jnp.float32,
            ),
            "n": (
                (n_groups, per, batch, h, dh),
                (None, None, "batch", None, "inner"),
                jnp.float32,
            ),
            "m": ((n_groups, per, batch, h), (None, None, "batch", None), jnp.float32),
        },
        "slstm": {
            k: ((n_groups, batch, d), (None, "batch", None), jnp.float32)
            for k in ("c", "n", "m", "h")
        },
        "pos": ((), (), jnp.int32),
    }
