"""Zamba2 hybrid (arXiv:2411.15242): a Mamba2 backbone with a single
weight-SHARED attention+MLP block applied every `attn_every` layers.

Simplifications vs the released model (documented in DESIGN.md §7): the
shared block consumes the hidden state only (no concat with the original
embedding) and per-invocation LoRA adapters are omitted; the shared block's
KV caches are per-invocation (stacked), since each invocation attends over
its own inputs.
"""

from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import common as cm
from repro.models import ffn, ssm
from repro.models.common import ModelConfig
from repro.models.lm import stack_defs


def _n_groups(cfg: ModelConfig) -> int:
    return cfg.num_layers // cfg.attn_every


def zamba2_def(cfg: ModelConfig) -> Dict[str, Any]:
    return {
        "embed": cm.embed_def(cfg.n_vocab, cfg.d_model),
        "mamba": stack_defs(
            stack_defs(ssm.mamba_def(cfg), cfg.attn_every), _n_groups(cfg)
        ),
        "shared": {  # ONE set of weights, applied at every group boundary
            "ln1": cm.rmsnorm_def(cfg.d_model),
            "attn": attn.gqa_def(cfg),
            "ln2": cm.rmsnorm_def(cfg.d_model),
            "ffn": ffn.mlp_def(cfg),
        },
        "final_norm": cm.rmsnorm_def(cfg.d_model),
        "lm_head": cm.qdense_def(cfg, cfg.d_model, cfg.n_vocab, (None, "vocab")),
    }


def _shared_block(params, x, cfg: ModelConfig, positions):
    h = cm.rmsnorm(params["ln1"], x, cfg.norm_eps)
    x = x + attn.gqa_attention(params["attn"], h, cfg, positions=positions)
    h = cm.rmsnorm(params["ln2"], x, cfg.norm_eps)
    return x + ffn.mlp(params["ffn"], h, cfg)


def zamba2_logits(params, tokens, cfg: ModelConfig):
    b, t = tokens.shape
    x = cm.embed(params["embed"], tokens, cfg)
    x = cm.with_logical(x, ("batch", "seq_sp", None))
    positions = jnp.arange(t)
    shared = params["shared"]  # closed over: same weights every group

    mblk = cm.apply_remat(lambda p, x: ssm.mamba_block(p, x, cfg), cfg)

    def group(x, mparams):
        def inner(x, p):
            x = mblk(p, x)
            return cm.with_logical(x, ("batch", "seq_sp", None)), None

        x, _ = jax.lax.scan(inner, x, mparams)
        x = _shared_block(shared, x, cfg, positions)
        return cm.with_logical(x, ("batch", "seq_sp", None)), None

    x, _ = jax.lax.scan(group, x, params["mamba"])
    x = cm.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return cm.dense(params["lm_head"], x, cfg, site="lm_head"), jnp.zeros(
        (), jnp.float32
    )


def zamba2_loss(params, batch, cfg: ModelConfig):
    logits, _ = zamba2_logits(params, batch["tokens"], cfg)
    return cm.softmax_cross_entropy(logits, batch["labels"], cfg.vocab_size)


def zamba2_prefill(params, tokens, cfg: ModelConfig, max_seq: int):
    b, t = tokens.shape
    x = cm.embed(params["embed"], tokens, cfg)
    positions = jnp.arange(t)
    shared = params["shared"]

    def group(x, mparams):
        def inner(x, p):
            return ssm.mamba_prefill(p, x, cfg)

        x, msts = jax.lax.scan(inner, x, mparams)
        h = cm.rmsnorm(shared["ln1"], x, cfg.norm_eps)
        a, kv = attn.gqa_prefill(
            shared["attn"], h, cfg, positions=positions, max_seq=max_seq
        )
        x = x + a
        h = cm.rmsnorm(shared["ln2"], x, cfg.norm_eps)
        x = x + ffn.mlp(shared["ffn"], h, cfg)
        return x, (msts, kv)

    x, (mamba_states, attn_caches) = jax.lax.scan(group, x, params["mamba"])
    x = cm.rmsnorm(params["final_norm"], x[:, -1:], cfg.norm_eps)
    logits = cm.dense(params["lm_head"], x, cfg, site="lm_head")
    cache = {
        "mamba": mamba_states,
        "attn": attn_caches,
        "pos": jnp.array(t, jnp.int32),
    }
    return logits, cache


def zamba2_decode(params, token, cache, cfg: ModelConfig):
    x = cm.embed(params["embed"], token, cfg)
    pos = cache["pos"]
    shared = params["shared"]

    def group(x, inp):
        mparams, msts, kv = inp

        def inner(x, pst):
            p, st = pst
            return ssm.mamba_decode(p, x, st, cfg)

        x, msts = jax.lax.scan(inner, x, (mparams, msts))
        h = cm.rmsnorm(shared["ln1"], x, cfg.norm_eps)
        a, kv = attn.gqa_decode(shared["attn"], h, kv, pos, cfg)
        x = x + a
        h = cm.rmsnorm(shared["ln2"], x, cfg.norm_eps)
        x = x + ffn.mlp(shared["ffn"], h, cfg)
        return x, (msts, kv)

    x, (mamba_states, attn_caches) = jax.lax.scan(
        group, x, (params["mamba"], cache["mamba"], cache["attn"])
    )
    x = cm.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = cm.dense(params["lm_head"], x, cfg, site="lm_head")
    return logits, {
        "mamba": mamba_states,
        "attn": attn_caches,
        "pos": pos + 1,
    }


def zamba2_cache_def(cfg: ModelConfig, batch: int, max_seq: int, dtype):
    g, per = _n_groups(cfg), cfg.attn_every
    mstate = ssm.mamba_state_def(cfg, batch, dtype)
    acache = attn.gqa_cache_def(cfg, batch, max_seq, dtype)
    return {
        "mamba": {
            k: ((g, per) + shape, (None, None) + axes, dt)
            for k, (shape, axes, dt) in mstate.items()
        },
        "attn": {
            k: ((g,) + shape, (None,) + axes, dt)
            for k, (shape, axes, dt) in acache.items()
        },
        "pos": ((), (), jnp.int32),
    }
