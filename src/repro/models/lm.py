"""Decoder-only LM covering the dense / MoE / MLA / vision-cross-attn
families (granite, qwen2 x2, deepseek-67b, phi3.5-moe, deepseek-v2-lite,
llama-3.2-vision).

Layers are stacked and scanned (`jax.lax.scan`) with optional remat — the
HLO stays one-layer-sized, which is what makes 512-way SPMD dry-runs
compile fast.  Heterogeneous stacks (vision cross-attn every Nth layer,
DeepSeek's dense first layer) become separate scanned groups (DESIGN.md §5).
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import common as cm
from repro.models import ffn
from repro.models.common import P, ModelConfig


# ---------------------------------------------------------------------------
# Param-def helpers
# ---------------------------------------------------------------------------
def stack_defs(defs: Any, n: int) -> Any:
    """Prepend a layer dimension to every P in a def tree."""
    if isinstance(defs, P):
        return P((n,) + defs.shape, (None,) + defs.axes, defs.init, defs.fan_axis + 1)
    return {k: stack_defs(v, n) for k, v in defs.items()}


def block_def(cfg: ModelConfig, kind: str = "self") -> Dict[str, Any]:
    d: Dict[str, Any] = {
        "ln1": cm.rmsnorm_def(cfg.d_model), "ln2": cm.rmsnorm_def(cfg.d_model)
    }
    if kind in ("self", "dense_ffn"):
        d["attn"] = attn.mla_def(cfg) if cfg.mla else attn.gqa_def(cfg)
    elif kind == "cross":
        d["attn"] = attn.cross_attn_def(cfg)
        d["gate_ffn"] = P((1,), (None,), init="zeros")
    if kind == "dense_ffn" or (cfg.num_experts == 0) or kind == "cross":
        d["ffn"] = ffn.mlp_def(cfg)
    else:
        d["ffn"] = ffn.moe_def(cfg)
    return d


def _n_cross(cfg: ModelConfig) -> int:
    return cfg.num_layers // cfg.cross_attn_every if cfg.cross_attn_every else 0


def _n_self(cfg: ModelConfig) -> int:
    n = cfg.num_layers - _n_cross(cfg)
    if cfg.mla and cfg.num_experts:  # deepseek: first layer has dense FFN
        n -= 1
    return n


def lm_def(cfg: ModelConfig) -> Dict[str, Any]:
    defs: Dict[str, Any] = {
        "embed": cm.embed_def(cfg.n_vocab, cfg.d_model),
        "layers": stack_defs(block_def(cfg, "self"), _n_self(cfg)),
        "final_norm": cm.rmsnorm_def(cfg.d_model),
    }
    if cfg.mla and cfg.num_experts:
        defs["first_block"] = block_def(cfg, "dense_ffn")
    if cfg.cross_attn_every:
        defs["cross"] = stack_defs(block_def(cfg, "cross"), _n_cross(cfg))
    if not cfg.tie_embeddings:
        defs["lm_head"] = cm.qdense_def(cfg, cfg.d_model, cfg.n_vocab, (None, "vocab"))
    return defs


# ---------------------------------------------------------------------------
# Blocks (training / full-sequence forward)
# ---------------------------------------------------------------------------
def self_block(params, x, cfg: ModelConfig, positions, layer=None) -> Tuple[
    jax.Array, jax.Array
]:
    h = cm.rmsnorm(params["ln1"], x, cfg.norm_eps)
    if cfg.mla:
        a = attn.mla_attention(params["attn"], h, cfg, positions=positions, layer=layer)
    else:
        a = attn.gqa_attention(params["attn"], h, cfg, positions=positions, layer=layer)
    x = x + a
    x = cm.with_logical(x, ("batch", "seq_sp", None))
    h = cm.rmsnorm(params["ln2"], x, cfg.norm_eps)
    aux = jnp.zeros((), jnp.float32)
    if "router" in params["ffn"]:
        f, aux = ffn.moe(params["ffn"], h, cfg, layer=layer)
    else:
        f = ffn.mlp(params["ffn"], h, cfg, layer=layer)
    x = x + f
    x = cm.with_logical(x, ("batch", "seq_sp", None))
    return x, aux


def cross_block(params, x, memory_kv, cfg: ModelConfig, layer=None) -> jax.Array:
    h = cm.rmsnorm(params["ln1"], x, cfg.norm_eps)
    x = x + attn.cross_attention(
        params["attn"], h, memory_kv, cfg, gated=True, layer=layer
    )
    h = cm.rmsnorm(params["ln2"], x, cfg.norm_eps)
    x = x + jnp.tanh(params["gate_ffn"].astype(x.dtype)) * ffn.mlp(
        params["ffn"], h, cfg, layer=layer
    )
    return cm.with_logical(x, ("batch", "seq_sp", None))


def _stack_len(stacked) -> int:
    return jax.tree.leaves(stacked)[0].shape[0]


def _scan_blocks(body, x, stacked, cfg: ModelConfig, *extra, base=0):
    """Scan ``body(layer_params, layer_idx, x, *extra)`` over a stacked
    layer tree.  The layer index rides the scan xs (``base`` offsets it
    past unscanned blocks) and feeds the photonic engine's site-folded
    noise streams, so same-shaped layers decorrelate (DESIGN.md §9)."""
    body = cm.apply_remat(body, cfg)

    def step(carry, inp):
        layer_params, idx = inp
        x, aux = carry
        x, a = body(layer_params, idx, x, *extra)
        return (x, aux + a), None

    idxs = base + jnp.arange(_stack_len(stacked))
    (x, aux), _ = jax.lax.scan(step, (x, jnp.zeros((), jnp.float32)), (stacked, idxs))
    return x, aux


# ---------------------------------------------------------------------------
# Training forward / loss
# ---------------------------------------------------------------------------
def lm_logits(params, tokens, cfg: ModelConfig, vision: Optional[jax.Array] = None):
    b, t = tokens.shape
    x = cm.embed(params["embed"], tokens, cfg)
    x = cm.with_logical(x, ("batch", "seq_sp", None))
    positions = jnp.arange(t)
    aux = jnp.zeros((), jnp.float32)

    base = 0
    if cfg.mla and cfg.num_experts:
        x, a = self_block(params["first_block"], x, cfg, positions, layer=0)
        aux += a
        base = 1

    if cfg.cross_attn_every:
        # groups of (cross_attn_every - 1) self layers + 1 cross layer
        per = cfg.cross_attn_every - 1
        n_groups = _n_cross(cfg)
        self_stack = jax.tree.map(
            lambda p: p.reshape((n_groups, per) + p.shape[1:]), params["layers"]
        )
        # Per-group cross params differ -> compute kv inside the group body.
        def group(carry, inp):
            x, aux = carry
            selfs, crossp, g = inp
            def body(p, idx, x, pos):
                return self_block(p, x, cfg, pos, layer=idx)
            x, a = _scan_blocks(body, x, selfs, cfg, positions, base=base + g * per)
            # Cross blocks fold in a range disjoint from the self-layer
            # indices, so same-site GEMMs never share a noise stream.
            cg = cfg.num_layers + g
            kv = attn.cross_kv(crossp["attn"], vision, cfg, layer=cg)
            cb = cm.apply_remat(
                lambda p, x, k, g: cross_block(p, x, k, cfg, layer=g), cfg
            )
            x = cb(crossp, x, kv, cg)
            return (x, aux + a), None

        (x, aux2), _ = jax.lax.scan(
            group, (x, aux), (self_stack, params["cross"], jnp.arange(n_groups))
        )
        aux = aux2
    else:
        def body(p, idx, x, pos):
            return self_block(p, x, cfg, pos, layer=idx)

        x, a = _scan_blocks(body, x, params["layers"], cfg, positions, base=base)
        aux += a

    x = cm.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = cm.unembed(params["embed"], x, cfg)
    else:
        logits = cm.dense(params["lm_head"], x, cfg, site="lm_head")
    return logits, aux


def lm_loss(params, batch: Dict[str, jax.Array], cfg: ModelConfig) -> jax.Array:
    logits, aux = lm_logits(params, batch["tokens"], cfg, vision=batch.get("vision"))
    ce = cm.softmax_cross_entropy(logits, batch["labels"], cfg.vocab_size)
    return ce + 0.01 * aux


# ---------------------------------------------------------------------------
# Serving: prefill + decode with per-layer caches
# ---------------------------------------------------------------------------
def _layer_prefill(p, x, cfg, positions, max_seq, layer=None):
    h = cm.rmsnorm(p["ln1"], x, cfg.norm_eps)
    if cfg.mla:
        a, cache = attn.mla_prefill(
            p["attn"], h, cfg, positions=positions, max_seq=max_seq, layer=layer
        )
    else:
        a, cache = attn.gqa_prefill(
            p["attn"], h, cfg, positions=positions, max_seq=max_seq, layer=layer
        )
    x = x + a
    h = cm.rmsnorm(p["ln2"], x, cfg.norm_eps)
    if "router" in p["ffn"]:
        f, _ = ffn.moe(p["ffn"], h, cfg, layer=layer)
    else:
        f = ffn.mlp(p["ffn"], h, cfg, layer=layer)
    x = x + f
    return cm.with_logical(x, ("batch", "seq_sp", None)), cache


def _layer_decode(p, x, cache, pos, cfg, layer=None):
    h = cm.rmsnorm(p["ln1"], x, cfg.norm_eps)
    if cfg.mla:
        a, cache = attn.mla_decode(p["attn"], h, cache, pos, cfg, layer=layer)
    else:
        a, cache = attn.gqa_decode(p["attn"], h, cache, pos, cfg, layer=layer)
    x = x + a
    h = cm.rmsnorm(p["ln2"], x, cfg.norm_eps)
    if "router" in p["ffn"]:
        f, _ = ffn.moe(p["ffn"], h, cfg, layer=layer)
    else:
        f = ffn.mlp(p["ffn"], h, cfg, layer=layer)
    return x + f, cache


def lm_prefill(
    params,
    tokens: jax.Array,  # (B, T)
    cfg: ModelConfig,
    max_seq: int,
    vision: Optional[jax.Array] = None,
):
    """Run the prompt; returns (last-token logits, cache)."""
    b, t = tokens.shape
    x = cm.embed(params["embed"], tokens, cfg)
    positions = jnp.arange(t)
    caches = {}

    base = 0
    if cfg.mla and cfg.num_experts:
        x, c0 = _layer_prefill(
            params["first_block"], x, cfg, positions, max_seq, layer=0
        )
        caches["first"] = c0
        base = 1

    if cfg.cross_attn_every:
        per = cfg.cross_attn_every - 1
        n_groups = _n_cross(cfg)
        self_stack = jax.tree.map(
            lambda p: p.reshape((n_groups, per) + p.shape[1:]), params["layers"]
        )

        def group(x, inp):
            selfs, crossp, g = inp

            def body(x, pi):
                p, idx = pi
                x, c = _layer_prefill(p, x, cfg, positions, max_seq, layer=idx)
                return x, c

            x, cs = jax.lax.scan(body, x, (selfs, base + g * per + jnp.arange(per)))
            cg = cfg.num_layers + g
            kv = attn.cross_kv(crossp["attn"], vision, cfg, layer=cg)
            x = cross_block(crossp, x, kv, cfg, layer=cg)
            return x, (cs, kv)

        x, (self_caches, cross_kvs) = jax.lax.scan(
            group, x, (self_stack, params["cross"], jnp.arange(n_groups))
        )
        # (groups, per, ...) -> flat (layers, ...)
        caches["layers"] = jax.tree.map(
            lambda c: c.reshape((-1,) + c.shape[2:]), self_caches
        )
        caches["cross_kv"] = cross_kvs
    else:
        def body(x, pi):
            p, idx = pi
            x, c = _layer_prefill(p, x, cfg, positions, max_seq, layer=idx)
            return x, c

        n = jax.tree.leaves(params["layers"])[0].shape[0]
        x, layer_caches = jax.lax.scan(
            body, x, (params["layers"], base + jnp.arange(n))
        )
        caches["layers"] = layer_caches

    x = cm.rmsnorm(params["final_norm"], x[:, -1:, :], cfg.norm_eps)
    logits = (
        cm.unembed(params["embed"], x, cfg)
        if cfg.tie_embeddings
        else cm.dense(params["lm_head"], x, cfg, site="lm_head")
    )
    caches["pos"] = jnp.array(t, jnp.int32)
    return logits, caches


def lm_decode(params, token: jax.Array, caches, cfg: ModelConfig):
    """One decode step. token: (B, 1) int32. Returns (logits, caches)."""
    pos = caches["pos"]
    x = cm.embed(params["embed"], token, cfg)

    base = 0
    if cfg.mla and cfg.num_experts:
        x, c0 = _layer_decode(
            params["first_block"], x, caches["first"], pos, cfg, layer=0
        )
        caches = {**caches, "first": c0}
        base = 1

    if cfg.cross_attn_every:
        per = cfg.cross_attn_every - 1
        n_groups = _n_cross(cfg)
        self_stack = jax.tree.map(
            lambda p: p.reshape((n_groups, per) + p.shape[1:]), params["layers"]
        )
        cache_stack = jax.tree.map(
            lambda c: c.reshape((n_groups, per) + c.shape[1:]), caches["layers"]
        )

        def group(x, inp):
            selfs, cs, crossp, kv, g = inp

            def body(x, pci):
                p, c, idx = pci
                x, c = _layer_decode(p, x, c, pos, cfg, layer=idx)
                return x, c

            x, cs = jax.lax.scan(
                body, x, (selfs, cs, base + g * per + jnp.arange(per))
            )
            x = cross_block(crossp, x, kv, cfg, layer=cfg.num_layers + g)
            return x, cs

        x, new_caches = jax.lax.scan(
            group,
            x,
            (
                self_stack,
                cache_stack,
                params["cross"],
                caches["cross_kv"],
                jnp.arange(n_groups),
            ),
        )
        caches = {
            **caches,
            "layers": jax.tree.map(
                lambda c: c.reshape((-1,) + c.shape[2:]), new_caches
            ),
        }
    else:
        def body(x, pci):
            p, c, idx = pci
            x, c = _layer_decode(p, x, c, pos, cfg, layer=idx)
            return x, c

        n = jax.tree.leaves(params["layers"])[0].shape[0]
        x, new_caches = jax.lax.scan(
            body, x, (params["layers"], caches["layers"], base + jnp.arange(n))
        )
        caches = {**caches, "layers": new_caches}

    x = cm.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = (
        cm.unembed(params["embed"], x, cfg)
        if cfg.tie_embeddings
        else cm.dense(params["lm_head"], x, cfg, site="lm_head")
    )
    caches = {**caches, "pos": pos + 1}
    return logits, caches


# ---------------------------------------------------------------------------
# Serving over the paged pool (repro.serving): chunked prefill, paged decode
# ---------------------------------------------------------------------------
def _check_paged_support(cfg: ModelConfig) -> None:
    if cfg.mla or cfg.cross_attn_every:
        raise ValueError(
            "paged serving covers the GQA self-attention stack only "
            "(no MLA latent caches / vision cross-attention); serve these "
            "families through the legacy fixed-slot engine"
        )


def _paged_head(params, x, cfg: ModelConfig):
    x = cm.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    if cfg.tie_embeddings:
        return cm.unembed(params["embed"], x, cfg)
    return cm.dense(params["lm_head"], x, cfg, site="lm_head")


def lm_prefill_chunk(
    params,
    tokens: jax.Array,  # (1, tc) — one request's chunk
    kv_pool,  # stacked pool: leaves (layers, num_blocks, bs, ...)
    block_table: jax.Array,  # (W,) int32
    t0: jax.Array,  # scalar int32 — chunk start
    cfg: ModelConfig,
    *,
    t_full: int,  # static total prompt length
    block_size: int,
    with_logits: bool,
):
    """One chunked-prefill step: run chunk tokens ``[t0, t0 + tc)`` of a
    single prompt, scattering each layer's K/V into the paged pool.  Only
    the prompt-final chunk pays for the LM head (``with_logits``); earlier
    chunks return ``None`` logits.  Returns ``(logits, kv_pool)``."""
    _check_paged_support(cfg)
    tc = tokens.shape[1]
    x = cm.embed(params["embed"], tokens, cfg)
    positions = t0 + jnp.arange(tc, dtype=jnp.int32)

    def body(x, inp):
        p, pc, idx = inp
        h = cm.rmsnorm(p["ln1"], x, cfg.norm_eps)
        a, pc = attn.gqa_prefill_chunk(
            p["attn"], h, pc, block_table, t0, cfg,
            t_full=t_full, block_size=block_size, positions=positions, layer=idx,
        )
        x = x + a
        h = cm.rmsnorm(p["ln2"], x, cfg.norm_eps)
        if "router" in p["ffn"]:
            f, _ = ffn.moe(p["ffn"], h, cfg, layer=idx)
        else:
            f = ffn.mlp(p["ffn"], h, cfg, layer=idx)
        return cm.with_logical(x + f, ("batch", "seq_sp", None)), pc

    n = _stack_len(params["layers"])
    x, kv_pool = jax.lax.scan(
        body, x, (params["layers"], kv_pool, jnp.arange(n))
    )
    logits = _paged_head(params, x[:, -1:, :], cfg) if with_logits else None
    return logits, kv_pool


def lm_decode_paged(
    params,
    token: jax.Array,  # (B, 1) int32
    kv_pool,  # stacked pool: leaves (layers, num_blocks, bs, ...)
    block_table: jax.Array,  # (B, W) int32
    pos: jax.Array,  # (B,) int32 — per-request cache length
    active: jax.Array,  # (B,) bool
    trash_blocks: jax.Array,  # (B,) int32
    cfg: ModelConfig,
    *,
    gather_len: int,
    block_size: int,
):
    """One decode step over the paged pool with *per-request* positions —
    the continuous-batching decode: rows mid-prefill or without a live
    request redirect their K/V write to a private trash block and their
    (discarded) output attends only to the zero null block.  Returns
    ``(logits (B, 1, V), kv_pool)``."""
    _check_paged_support(cfg)
    from repro.serving import kv_cache as kvc

    blocks, offsets = kvc.token_dest(block_table, pos, active, trash_blocks, block_size)
    x = cm.embed(params["embed"], token, cfg)

    def body(x, inp):
        p, pc, idx = inp
        h = cm.rmsnorm(p["ln1"], x, cfg.norm_eps)
        a, pc = attn.gqa_decode_paged(
            p["attn"], h, pc, block_table, pos, blocks, offsets, cfg,
            gather_len=gather_len, layer=idx,
        )
        x = x + a
        h = cm.rmsnorm(p["ln2"], x, cfg.norm_eps)
        if "router" in p["ffn"]:
            f, _ = ffn.moe(p["ffn"], h, cfg, layer=idx)
        else:
            f = ffn.mlp(p["ffn"], h, cfg, layer=idx)
        return x + f, pc

    n = _stack_len(params["layers"])
    x, kv_pool = jax.lax.scan(
        body, x, (params["layers"], kv_pool, jnp.arange(n))
    )
    return _paged_head(params, x, cfg), kv_pool


# ---------------------------------------------------------------------------
# Cache shape/axes definitions (for dry-run input_specs)
# ---------------------------------------------------------------------------
def lm_cache_def(cfg: ModelConfig, batch: int, max_seq: int, dtype) -> Dict[str, Any]:
    layer_cache = (
        attn.mla_cache_def(cfg, batch, max_seq, dtype)
        if cfg.mla
        else attn.gqa_cache_def(cfg, batch, max_seq, dtype)
    )
    n_self = _n_self(cfg)
    out: Dict[str, Any] = {
        "layers": {
            k: ((n_self,) + shape, (None,) + axes, dt)
            for k, (shape, axes, dt) in layer_cache.items()
        },
        "pos": ((), (), jnp.int32),
    }
    if cfg.mla and cfg.num_experts:
        out["first"] = layer_cache
    if cfg.cross_attn_every:
        n_cross = _n_cross(cfg)
        kv_shape = (n_cross, batch, cfg.vision_seq, cfg.num_kv_heads, cfg.hd)
        axes = (None, "batch", None, "kv_heads", None)
        out["cross_kv"] = ((kv_shape, axes, dtype), (kv_shape, axes, dtype))
    return out
