"""Mamba2 (SSD) blocks with chunkwise-parallel training scan and O(1)
recurrent decode.  Used standalone and inside the zamba2 hybrid.

SSD recurrence per head (scalar decay a_t = exp(-exp(A_log) * dt_t)):

    S_t = a_t * S_{t-1} + dt_t * x_t (outer) B_t        # (head_dim, state)
    y_t = S_t @ C_t + D * x_t

Chunkwise: within a chunk the quadratic masked form
``L[t,s] = (C_t . B_s) * exp(b_t - b_s) * dt_s`` (s <= t) computes intra-chunk
contributions; a `lax.scan` over chunks carries the inter-chunk state — O(T)
memory, parallel within chunks (the TPU-friendly SSD layout).
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models import common as cm
from repro.models.common import P, ModelConfig, dense, qdense_def


def _inner(cfg: ModelConfig) -> int:
    return cfg.ssm_expand * cfg.d_model


def _nheads(cfg: ModelConfig) -> int:
    return _inner(cfg) // cfg.ssm_head_dim


def mamba_def(cfg: ModelConfig) -> Dict[str, Any]:
    d, inner, st, h = cfg.d_model, _inner(cfg), cfg.ssm_state_size, _nheads(cfg)
    conv_ch = inner + 2 * st
    return {
        "ln": cm.rmsnorm_def(d),
        "in_proj": qdense_def(cfg, d, 2 * inner + 2 * st + h, (None, "inner")),
        "conv_w": P((cfg.ssm_conv_width, conv_ch), (None, "inner")),
        "conv_b": P((conv_ch,), ("inner",), init="zeros"),
        "a_log": P((h,), ("mamba_heads",), init="zeros"),
        "dt_bias": P((h,), ("mamba_heads",), init="zeros"),
        "d_skip": P((h,), ("mamba_heads",), init="ones"),
        "out_norm": cm.rmsnorm_def(inner),
        "out_proj": qdense_def(cfg, inner, d, ("inner", None)),
    }


def _split_in(params, x, cfg: ModelConfig):
    inner, st, h = _inner(cfg), cfg.ssm_state_size, _nheads(cfg)
    u = dense(params["in_proj"], x, cfg, site="in_proj")
    z = u[..., :inner]
    xbc = u[..., inner : 2 * inner + 2 * st]
    dt = u[..., 2 * inner + 2 * st :]
    return z, xbc, dt


def _causal_conv(params, xbc: jax.Array) -> jax.Array:
    """Depthwise causal conv over time. xbc: (B, T, C)."""
    w = params["conv_w"].astype(xbc.dtype)  # (W, C)
    width = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (width - 1, 0), (0, 0)))
    out = sum(
        pad[:, i : i + xbc.shape[1], :] * w[i][None, None, :] for i in range(width)
    )
    return jax.nn.silu(out + params["conv_b"].astype(xbc.dtype))


def _ssd_chunked(
    xh: jax.Array,   # (B, T, H, dh)
    b_mat: jax.Array,  # (B, T, st)
    c_mat: jax.Array,  # (B, T, st)
    dt: jax.Array,   # (B, T, H)  (softplus'd)
    a_log: jax.Array,  # (H,)
    chunk: int,
    s0: jax.Array | None = None,  # (B, H, dh, st) initial state
    unroll: bool = False,
) -> Tuple[jax.Array, jax.Array]:
    """Chunkwise SSD. Returns (y (B,T,H,dh), final state (B,H,dh,st))."""
    bsz, t, h, dh = xh.shape
    st = b_mat.shape[-1]
    chunk = min(chunk, t)
    pad = (-t) % chunk
    if pad:
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        b_mat = jnp.pad(b_mat, ((0, 0), (0, pad), (0, 0)))
        c_mat = jnp.pad(c_mat, ((0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
    tp = t + pad
    n_chunks = tp // chunk

    decay = -jnp.exp(a_log.astype(jnp.float32))  # (H,) negative rates

    def reshape_c(x):
        return x.reshape(bsz, n_chunks, chunk, *x.shape[2:]).swapaxes(0, 1)

    xs, bs, cs, dts = map(reshape_c, (xh, b_mat, c_mat, dt))

    if s0 is None:
        s0 = jnp.zeros((bsz, h, dh, st), jnp.float32)

    def step(state, inp):
        xc, bc, cc, dtc = inp  # (B, L, H, dh), (B, L, st), (B, L, st), (B, L, H)
        xc = xc.astype(jnp.float32)
        bc = bc.astype(jnp.float32)
        cc = cc.astype(jnp.float32)
        dtc = dtc.astype(jnp.float32)
        loga = dtc * decay[None, None, :]              # (B, L, H) log a_t
        b_cum = jnp.cumsum(loga, axis=1)               # (B, L, H)
        # intra-chunk: L[t,s] = (C_t.B_s) exp(b_t - b_s) dt_s  (s <= t)
        cb = jnp.einsum("bts,bls->btl", cc, bc)
        gap = b_cum[:, :, None, :] - b_cum[:, None, :, :]  # (B, L_t, L_s, H)
        tri = jnp.tril(jnp.ones((chunk, chunk), bool))
        lmat = jnp.where(
            tri[None, :, :, None], cb[..., None] * jnp.exp(gap), 0.0
        ) * dtc[:, None, :, :]
        y_intra = jnp.einsum("btsh,bshd->bthd", lmat, xc)
        # inter-chunk: y_t += C_t @ (exp(b_t) * S_prev)
        y_inter = jnp.einsum("bts,bhds,bth->bthd", cc, state, jnp.exp(b_cum))
        # state update
        b_tot = b_cum[:, -1, :]                        # (B, H)
        w = jnp.exp(b_tot[:, None, :] - b_cum) * dtc   # (B, L, H)
        s_new = jnp.einsum("blh,bls,blhd->bhds", w, bc, xc)
        state = state * jnp.exp(b_tot)[:, :, None, None] + s_new
        return state, y_intra + y_inter

    state, ys = jax.lax.scan(step, s0, (xs, bs, cs, dts), unroll=True if unroll else 1)
    ys = ys.swapaxes(0, 1).reshape(bsz, tp, h, dh)[:, :t]
    return ys, state


def mamba_block(params, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Full-sequence (train/prefill) Mamba2 block with residual."""
    inner, st, h, dh = _inner(cfg), cfg.ssm_state_size, _nheads(cfg), cfg.ssm_head_dim
    res = x
    xn = cm.rmsnorm(params["ln"], x, cfg.norm_eps)
    z, xbc, dt = _split_in(params, xn, cfg)
    xbc = _causal_conv(params, xbc)
    xi = xbc[..., :inner].reshape(*x.shape[:2], h, dh)
    b_mat = xbc[..., inner : inner + st]
    c_mat = xbc[..., inner + st :]
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"].astype(jnp.float32))
    y, _ = _ssd_chunked(
        xi, b_mat, c_mat, dt, params["a_log"], cfg.ssm_chunk, unroll=cfg.unroll_scans
    )
    y = y + params["d_skip"].astype(jnp.float32)[None, None, :, None] * xi.astype(
        jnp.float32
    )
    y = y.reshape(*x.shape[:2], inner).astype(x.dtype)
    y = cm.rmsnorm(params["out_norm"], y * jax.nn.silu(z), cfg.norm_eps)
    out = dense(params["out_proj"], y, cfg, site="out_proj")
    return res + out


def mamba_state_def(cfg: ModelConfig, batch: int, dtype) -> Dict[str, Any]:
    inner, st, h, dh = _inner(cfg), cfg.ssm_state_size, _nheads(cfg), cfg.ssm_head_dim
    conv_ch = inner + 2 * st
    return {
        "ssm": ((batch, h, dh, st), ("batch", "mamba_heads", None, None), jnp.float32),
        "conv": (
            (batch, cfg.ssm_conv_width - 1, conv_ch),
            ("batch", None, "inner"),
            dtype,
        ),
    }


def mamba_prefill(
    params, x: jax.Array, cfg: ModelConfig
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Like mamba_block but also returns the decode state."""
    inner, st, h, dh = _inner(cfg), cfg.ssm_state_size, _nheads(cfg), cfg.ssm_head_dim
    res = x
    xn = cm.rmsnorm(params["ln"], x, cfg.norm_eps)
    z, xbc, dt = _split_in(params, xn, cfg)
    conv_state = xbc[:, -(cfg.ssm_conv_width - 1) :, :]
    xbc = _causal_conv(params, xbc)
    xi = xbc[..., :inner].reshape(*x.shape[:2], h, dh)
    b_mat = xbc[..., inner : inner + st]
    c_mat = xbc[..., inner + st :]
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"].astype(jnp.float32))
    y, s = _ssd_chunked(
        xi, b_mat, c_mat, dt, params["a_log"], cfg.ssm_chunk, unroll=cfg.unroll_scans
    )
    y = y + params["d_skip"].astype(jnp.float32)[None, None, :, None] * xi.astype(
        jnp.float32
    )
    y = y.reshape(*x.shape[:2], inner).astype(x.dtype)
    y = cm.rmsnorm(params["out_norm"], y * jax.nn.silu(z), cfg.norm_eps)
    out = dense(params["out_proj"], y, cfg, site="out_proj")
    return res + out, {"ssm": s, "conv": conv_state}


def mamba_decode(
    params, x: jax.Array, state: Dict[str, jax.Array], cfg: ModelConfig
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """One-token step. x: (B, 1, D)."""
    inner, st, h, dh = _inner(cfg), cfg.ssm_state_size, _nheads(cfg), cfg.ssm_head_dim
    res = x
    xn = cm.rmsnorm(params["ln"], x, cfg.norm_eps)
    z, xbc, dt = _split_in(params, xn, cfg)  # (B,1,...)
    conv_in = jnp.concatenate([state["conv"].astype(xbc.dtype), xbc], axis=1)
    new_conv = conv_in[:, 1:, :]
    w = params["conv_w"].astype(xbc.dtype)
    width = w.shape[0]
    conv_out = jnp.einsum("bwc,wc->bc", conv_in[:, -width:, :], w)
    xbc1 = jax.nn.silu(conv_out + params["conv_b"].astype(xbc.dtype))  # (B, C)
    xi = xbc1[:, :inner].reshape(-1, h, dh).astype(jnp.float32)
    b_v = xbc1[:, inner : inner + st].astype(jnp.float32)
    c_v = xbc1[:, inner + st :].astype(jnp.float32)
    dt1 = jax.nn.softplus(
        dt[:, 0, :].astype(jnp.float32) + params["dt_bias"].astype(jnp.float32)
    )  # (B,H)
    a = jnp.exp(dt1 * -jnp.exp(params["a_log"].astype(jnp.float32)))  # (B,H)
    s = state["ssm"] * a[:, :, None, None] + jnp.einsum("bh,bhd,bs->bhds", dt1, xi, b_v)
    y = jnp.einsum("bhds,bs->bhd", s, c_v)
    y = y + params["d_skip"].astype(jnp.float32)[None, :, None] * xi
    y = y.reshape(-1, 1, inner).astype(x.dtype)
    y = cm.rmsnorm(params["out_norm"], y * jax.nn.silu(z), cfg.norm_eps)
    out = dense(params["out_proj"], y, cfg, site="out_proj")
    return res + out, {"ssm": s, "conv": new_conv}


# ---------------------------------------------------------------------------
# Naive recurrent reference (for tests)
# ---------------------------------------------------------------------------
def ssd_reference(xh, b_mat, c_mat, dt, a_log):
    """Step-by-step recurrence — oracle for _ssd_chunked."""
    bsz, t, h, dh = xh.shape
    st = b_mat.shape[-1]
    decay = -jnp.exp(a_log.astype(jnp.float32))
    s = jnp.zeros((bsz, h, dh, st), jnp.float32)
    ys = []
    for i in range(t):
        a = jnp.exp(dt[:, i, :] * decay[None, :])  # (B,H)
        s = s * a[:, :, None, None] + jnp.einsum(
            "bh,bhd,bs->bhds", dt[:, i, :], xh[:, i].astype(jnp.float32),
            b_mat[:, i].astype(jnp.float32),
        )
        ys.append(jnp.einsum("bhds,bs->bhd", s, c_mat[:, i].astype(jnp.float32)))
    return jnp.stack(ys, axis=1), s
