"""Attention variants: GQA (+RoPE, qkv-bias), MLA (DeepSeek-V2), cross-attn.

Train/prefill use a chunked (flash-style) online-softmax scan over KV blocks
— O(T) memory, the TPU-friendly pattern.  Decode consumes a KV cache updated
in place; cache layouts carry logical sharding axes so long-context caches
sequence-shard over the `data` mesh axis (DESIGN.md §5).
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import common as cm
from repro.models.common import P, ModelConfig, apply_rope, dense, qdense_def


# ---------------------------------------------------------------------------
# Param definitions
# ---------------------------------------------------------------------------
def gqa_def(cfg: ModelConfig) -> Dict[str, Any]:
    d, h, kv, hd = cfg.d_model, cfg.n_q_heads, cfg.num_kv_heads, cfg.hd
    return {
        "wq": qdense_def(cfg, d, h * hd, (None, "heads"), bias=cfg.qkv_bias),
        "wk": qdense_def(cfg, d, kv * hd, (None, "kv_heads"), bias=cfg.qkv_bias),
        "wv": qdense_def(cfg, d, kv * hd, (None, "kv_heads"), bias=cfg.qkv_bias),
        "wo": qdense_def(cfg, h * hd, d, ("heads", None)),
    }


def cross_attn_def(cfg: ModelConfig) -> Dict[str, Any]:
    d = gqa_def(cfg)
    d["gate"] = P((1,), (None,), init="zeros")  # gated cross-attn (llama-vision)
    return d


def mla_def(cfg: ModelConfig) -> Dict[str, Any]:
    d, h = cfg.d_model, cfg.n_q_heads
    nope, rope, vd = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    r = cfg.kv_lora_rank
    return {
        "wq": qdense_def(cfg, d, h * (nope + rope), (None, "heads")),
        "wdkv": qdense_def(cfg, d, r + rope, (None, None)),
        "wuk": qdense_def(cfg, r, h * nope, (None, "heads")),
        "wuv": qdense_def(cfg, r, h * vd, (None, "heads")),
        "wo": qdense_def(cfg, h * vd, d, ("heads", None)),
    }


# ---------------------------------------------------------------------------
# Chunked online-softmax attention (train / prefill)
# ---------------------------------------------------------------------------
def _repeat_kv(k: jax.Array, n_rep: int) -> jax.Array:
    if n_rep == 1:
        return k
    return jnp.repeat(k, n_rep, axis=2)


def chunked_attention(
    q: jax.Array,  # (B, Tq, H, hd)
    k: jax.Array,  # (B, Tk, KV, hd)
    v: jax.Array,  # (B, Tk, KV, hd_v)
    *,
    causal: bool,
    q_offset: int = 0,
    chunk: int = 512,
    scale: Optional[float] = None,
    unroll: bool = False,
    acc_dtype=jnp.float32,
) -> jax.Array:
    b, tq, h, hd = q.shape
    _, tk, kvh, hdv = v.shape
    n_rep = h // kvh
    scale = scale if scale is not None else hd ** -0.5
    chunk = min(chunk, tk)
    if tk % chunk:
        pad = (-tk) % chunk
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kv_valid = tk
        tk = tk + pad
    else:
        kv_valid = tk
    n_chunks = tk // chunk

    qf = (q.astype(acc_dtype) * scale).transpose(0, 2, 1, 3)  # (B,H,Tq,hd)
    kc = k.reshape(b, n_chunks, chunk, kvh, hd)
    vc = v.reshape(b, n_chunks, chunk, kvh, hdv)
    q_pos = q_offset + jnp.arange(tq)

    def step(carry, inputs):
        m, l, acc = carry
        kb, vb, c_idx = inputs
        kb = _repeat_kv(kb, n_rep).transpose(0, 2, 3, 1)  # (B,H,hd,chunk)
        vb = _repeat_kv(vb, n_rep).transpose(0, 2, 1, 3)  # (B,H,chunk,hdv)
        s = jnp.einsum(
            "bhqd,bhdc->bhqc", qf, kb.astype(acc_dtype),
            preferred_element_type=acc_dtype,
        )  # (B,H,Tq,chunk)
        kv_pos = c_idx * chunk + jnp.arange(chunk)
        valid = kv_pos[None, :] < kv_valid
        if causal:
            valid = valid & (kv_pos[None, :] <= q_pos[:, None])
        s = jnp.where(valid[None, None], s, -1e30)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bhqc,bhcd->bhqd", p, vb.astype(acc_dtype),
            preferred_element_type=acc_dtype,
        )
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, h, tq), -1e30, acc_dtype)
    l0 = jnp.zeros((b, h, tq), acc_dtype)
    acc0 = jnp.zeros((b, h, tq, hdv), acc_dtype)
    (m, l, acc), _ = jax.lax.scan(
        step,
        (m0, l0, acc0),
        (
            kc.transpose(1, 0, 2, 3, 4),
            vc.transpose(1, 0, 2, 3, 4),
            jnp.arange(n_chunks),
        ),
        unroll=True if unroll else 1,
    )
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out.transpose(0, 2, 1, 3).astype(q.dtype)  # (B,Tq,H,hdv)


# ---------------------------------------------------------------------------
# GQA self-attention
# ---------------------------------------------------------------------------
def _split_heads(x: jax.Array, n: int) -> jax.Array:
    b, t, _ = x.shape
    return x.reshape(b, t, n, -1)


def _qkv_proj(params, x, cfg: ModelConfig, *, layer=None):
    """Q/K/V projections, head-split, RoPE not yet applied.

    When the param dict carries a fused ``wqkv`` entry
    (:func:`repro.photonic.fuse_qkv_params`) the three projections run as
    ONE engine dispatch — one activation quantization, one fused-epilogue
    GEMM — and the output columns are split back here.  Per-column
    quantization and the K-chunked accumulation are column-independent,
    so under a deterministic channel this is bitwise the three separate
    calls (the noisy channel draws a different, equally valid stream for
    the "attn.wqkv" site).
    """
    h, kv, hd = cfg.n_q_heads, cfg.num_kv_heads, cfg.hd
    if "wqkv" in params:
        y = dense(params["wqkv"], x, cfg, site="attn.wqkv", layer=layer)
        yq, yk, yv = jnp.split(y, (h * hd, (h + kv) * hd), axis=-1)
    else:
        yq = dense(params["wq"], x, cfg, site="attn.wq", layer=layer)
        yk = dense(params["wk"], x, cfg, site="attn.wk", layer=layer)
        yv = dense(params["wv"], x, cfg, site="attn.wv", layer=layer)
    return _split_heads(yq, h), _split_heads(yk, kv), _split_heads(yv, kv)


def _attend(q, k, v, cfg: ModelConfig, *, causal: bool, q_offset: int = 0):
    """The prefill/train attention core behind ``cfg.attn_impl``.

    "chunked" is the jnp online-softmax scan; "flash" dispatches the
    Pallas flash-attention kernel via the ``repro.photonic`` surface
    (RPR003) — same math, different block partition, so the two agree to
    float tolerance rather than bitwise.
    """
    if cfg.attn_impl == "flash":
        from repro.photonic.flash import flash_attention

        return flash_attention(q, k, v, causal=causal, q_offset=q_offset)
    return chunked_attention(
        q, k, v, causal=causal, q_offset=q_offset,
        chunk=cfg.attn_chunk, unroll=cfg.unroll_scans,
        acc_dtype=jnp.float32 if cfg.attn_f32 else jnp.bfloat16,
    )


def gqa_attention(
    params: Dict[str, Any],
    x: jax.Array,  # (B, T, D)
    cfg: ModelConfig,
    *,
    positions: jax.Array,  # (T,)
    causal: bool = True,
    q_offset: int = 0,
    layer: Optional[jax.Array] = None,
) -> jax.Array:
    q, k, v = _qkv_proj(params, x, cfg, layer=layer)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    q = cm.with_logical(q, ("batch", None, "heads", None))
    k = cm.with_logical(k, ("batch", None, "kv_heads", None))
    v = cm.with_logical(v, ("batch", None, "kv_heads", None))
    out = _attend(q, k, v, cfg, causal=causal, q_offset=q_offset)
    out = out.reshape(*x.shape[:2], -1)
    return dense(params["wo"], out, cfg, site="attn.wo", layer=layer)


def gqa_prefill(
    params, x, cfg: ModelConfig, *, positions, max_seq: int, layer=None
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Self-attention over the prompt + returns a padded KV cache."""
    b, t, _ = x.shape
    q, k, v = _qkv_proj(params, x, cfg, layer=layer)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    out = _attend(q, k, v, cfg, causal=True)
    out = dense(params["wo"], out.reshape(b, t, -1), cfg, site="attn.wo", layer=layer)
    pad4 = ((0, 0), (0, max_seq - t), (0, 0), (0, 0))
    pad3 = ((0, 0), (0, max_seq - t), (0, 0))
    if cfg.kv_cache_int8:
        qk, sk = _quantize_kv(k)
        qv, sv = _quantize_kv(v)
        cache = {
            "k": jnp.pad(qk, pad4),
            "v": jnp.pad(qv, pad4),
            "k_scale": jnp.pad(sk, pad3),
            "v_scale": jnp.pad(sv, pad3),
        }
    else:
        cache = {"k": jnp.pad(k, pad4), "v": jnp.pad(v, pad4)}
    return out, cache


def _quantize_kv(x):
    """Per-(token, kv-head) symmetric int8 quantization of K/V rows.

    The paper's DPUs consume int8 operands; storing the KV cache at int8
    (+ one f32 scale per token-head) halves serving's dominant HBM stream —
    DESIGN.md §3 beyond-paper extension, exercised as §Perf HC-C."""
    xf = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf), axis=-1)
    scale = jnp.maximum(amax, 1e-12) * (1.0 / 127.0)
    q = jnp.clip(jnp.round(xf / scale[..., None]), -127, 127).astype(jnp.int8)
    return q, scale


def gqa_decode(
    params,
    x: jax.Array,  # (B, 1, D)
    cache: Dict[str, jax.Array],
    pos: jax.Array,  # scalar int32 — current length
    cfg: ModelConfig,
    *,
    layer: Optional[jax.Array] = None,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    h, kv = cfg.n_q_heads, cfg.num_kv_heads
    b = x.shape[0]
    q, k1, v1 = _qkv_proj(params, x, cfg, layer=layer)
    posv = pos[None] if pos.ndim == 0 else pos
    q = apply_rope(q, posv, cfg.rope_theta)
    k1 = apply_rope(k1, posv, cfg.rope_theta)
    new_cache = {}
    if cfg.kv_cache_int8:
        qk1, sk1 = _quantize_kv(k1)
        qv1, sv1 = _quantize_kv(v1)
        ck = jax.lax.dynamic_update_slice_in_dim(cache["k"], qk1, pos, 1)
        cv = jax.lax.dynamic_update_slice_in_dim(cache["v"], qv1, pos, 1)
        sk = jax.lax.dynamic_update_slice_in_dim(cache["k_scale"], sk1, pos, 1)
        sv = jax.lax.dynamic_update_slice_in_dim(cache["v_scale"], sv1, pos, 1)
        new_cache = {"k_scale": sk, "v_scale": sv}
        kf = ck.astype(jnp.float32) * sk[..., None]
        vf = cv.astype(jnp.float32) * sv[..., None]
    else:
        ck = jax.lax.dynamic_update_slice_in_dim(
            cache["k"], k1.astype(cache["k"].dtype), pos, 1
        )
        cv = jax.lax.dynamic_update_slice_in_dim(
            cache["v"], v1.astype(cache["v"].dtype), pos, 1
        )
        kf = ck.astype(jnp.float32)
        vf = cv.astype(jnp.float32)
    ck = cm.with_logical(ck, ("batch", "kv_seq", "kv_heads", None))
    cv = cm.with_logical(cv, ("batch", "kv_seq", "kv_heads", None))

    s_max = ck.shape[1]
    kf = _repeat_kv(kf, h // kv)
    vf = _repeat_kv(vf, h // kv)
    qf = q.astype(jnp.float32) * (cfg.hd ** -0.5)
    s = jnp.einsum("bqhd,bshd->bhqs", qf, kf, preferred_element_type=jnp.float32)
    kv_pos = jnp.arange(s_max)
    s = jnp.where((kv_pos <= pos)[None, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqs,bshd->bqhd", p, vf, preferred_element_type=jnp.float32)
    out = dense(
        params["wo"], out.reshape(b, 1, -1).astype(x.dtype), cfg,
        site="attn.wo", layer=layer,
    )
    return out, {"k": ck, "v": cv, **new_cache}


def gqa_cache_def(cfg: ModelConfig, batch: int, max_seq: int, dtype) -> Dict[str, Any]:
    kv, hd = cfg.num_kv_heads, cfg.hd
    shape = (batch, max_seq, kv, hd)
    axes = ("batch", "kv_seq", "kv_heads", None)
    if cfg.kv_cache_int8:
        sshape = (batch, max_seq, kv)
        saxes = ("batch", "kv_seq", "kv_heads")
        return {
            "k": (shape, axes, jnp.int8),
            "v": (shape, axes, jnp.int8),
            "k_scale": (sshape, saxes, jnp.float32),
            "v_scale": (sshape, saxes, jnp.float32),
        }
    return {
        "k": (shape, axes, dtype),
        "v": (shape, axes, dtype),
    }


# ---------------------------------------------------------------------------
# Paged GQA (repro.serving): block-table KV access, per-request positions
# ---------------------------------------------------------------------------
def _kv_rows(k, v, cfg: ModelConfig, batch_axis: int):
    """Cache rows (+ int8 scales) for computed K/V, batch axis dropped."""
    if cfg.kv_cache_int8:
        qk, sk = _quantize_kv(k)
        qv, sv = _quantize_kv(v)
        rows = {"k": qk, "v": qv, "k_scale": sk, "v_scale": sv}
    else:
        rows = {"k": k, "v": v}
    return jax.tree.map(lambda r: jnp.squeeze(r, batch_axis), rows)


def _kv_view(g, cfg: ModelConfig):
    """Float K/V view of a gathered cache slab (dequantizing int8 KV)."""
    if cfg.kv_cache_int8:
        kf = g["k"].astype(jnp.float32) * g["k_scale"][..., None]
        vf = g["v"].astype(jnp.float32) * g["v_scale"][..., None]
        return kf, vf
    return g["k"].astype(jnp.float32), g["v"].astype(jnp.float32)


def gqa_prefill_chunk(
    params,
    x: jax.Array,  # (1, tc, D) — one request's chunk
    kv_pool,  # per-layer pool leaves (num_blocks, bs, kv, hd)
    block_table: jax.Array,  # (W,) int32 — the request's table row
    t0: jax.Array,  # scalar int32 — chunk start (flat position)
    cfg: ModelConfig,
    *,
    t_full: int,  # static total prompt length (gather width)
    block_size: int,
    positions,  # (tc,) int32 — t0 + arange(tc)
    layer=None,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """One chunk of a chunked prefill: compute this chunk's K/V, scatter
    them into the paged pool, and attend over cache rows ``[0, t_full)``.

    Feeding ``chunked_attention`` exactly ``t_full`` KV rows reproduces the
    one-shot prefill's block partition (``chunk = min(attn_chunk, tk)``), so
    the float path is bitwise-identical to ``gqa_prefill`` per query; rows
    past the written prefix read as zeros off the null block and sit under
    the causal mask (``exp(-1e30 - m)`` underflows to exactly 0).  When one
    chunk covers the whole prompt the in-chunk K/V are used directly — the
    literal ``gqa_prefill`` computation, bitwise even for int8 KV (which
    otherwise round-trips prior chunks through the quantized pool).
    """
    from repro.serving import kv_cache as kvc

    tc = x.shape[1]
    q, k, v = _qkv_proj(params, x, cfg, layer=layer)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)

    blocks, offsets = kvc.chunk_dest(block_table, t0, tc, block_size)
    kv_pool = kvc.scatter_kv(kv_pool, blocks, offsets, _kv_rows(k, v, cfg, 0))

    if t_full == tc:
        kf, vf = k, v  # single chunk covers the prompt: legacy math exactly
        q_offset = 0
    else:
        kf, vf = _kv_view(kvc.gather_kv(kv_pool, block_table[None], t_full), cfg)
        q_offset = t0
    out = chunked_attention(
        q, kf, vf, causal=True, q_offset=q_offset,
        chunk=cfg.attn_chunk, unroll=cfg.unroll_scans,
        acc_dtype=jnp.float32 if cfg.attn_f32 else jnp.bfloat16,
    )
    out = dense(
        params["wo"], out.reshape(1, tc, -1), cfg, site="attn.wo", layer=layer
    )
    return out, kv_pool


def gqa_decode_paged(
    params,
    x: jax.Array,  # (B, 1, D)
    kv_pool,  # per-layer pool leaves (num_blocks, bs, kv, hd)
    block_table: jax.Array,  # (B, W) int32
    pos: jax.Array,  # (B,) int32 — per-request cache length
    blocks: jax.Array,  # (B,) int32 — precomputed write destinations
    offsets: jax.Array,  # (B,) int32
    cfg: ModelConfig,
    *,
    gather_len: int,
    layer=None,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """``gqa_decode`` generalized to per-request positions over the paged
    pool: scatter the new token's K/V through the block table, gather a
    contiguous ``(B, gather_len)`` view, and attend under a per-row causal
    mask ``kv_pos <= pos[b]``.  With uniform ``pos`` this is bitwise the
    legacy decode (same shapes, same masked softmax, same int8 round-trip).
    """
    from repro.serving import kv_cache as kvc

    h, kv = cfg.n_q_heads, cfg.num_kv_heads
    b = x.shape[0]
    q, k1, v1 = _qkv_proj(params, x, cfg, layer=layer)
    q = apply_rope(q, pos[:, None], cfg.rope_theta)
    k1 = apply_rope(k1, pos[:, None], cfg.rope_theta)

    kv_pool = kvc.scatter_kv(kv_pool, blocks, offsets, _kv_rows(k1, v1, cfg, 1))
    kf, vf = _kv_view(kvc.gather_kv(kv_pool, block_table, gather_len), cfg)

    kf = _repeat_kv(kf, h // kv)
    vf = _repeat_kv(vf, h // kv)
    qf = q.astype(jnp.float32) * (cfg.hd ** -0.5)
    s = jnp.einsum("bqhd,bshd->bhqs", qf, kf, preferred_element_type=jnp.float32)
    kv_pos = jnp.arange(gather_len)
    s = jnp.where((kv_pos[None, :] <= pos[:, None])[:, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqs,bshd->bqhd", p, vf, preferred_element_type=jnp.float32)
    out = dense(
        params["wo"], out.reshape(b, 1, -1).astype(x.dtype), cfg,
        site="attn.wo", layer=layer,
    )
    return out, kv_pool


# ---------------------------------------------------------------------------
# Cross-attention (vision / whisper decoder): static memory, no RoPE on kv
# ---------------------------------------------------------------------------
def cross_attention(
    params,
    x: jax.Array,       # (B, T, D)
    memory_kv: Tuple[jax.Array, jax.Array],  # precomputed (B,S,KV,hd) pair
    cfg: ModelConfig,
    *,
    gated: bool = False,
    layer: Optional[jax.Array] = None,
) -> jax.Array:
    h = cfg.n_q_heads
    b, t, _ = x.shape
    q = _split_heads(dense(params["wq"], x, cfg, site="attn.wq", layer=layer), h)
    k, v = memory_kv
    out = chunked_attention(
        q, k, v, causal=False, chunk=cfg.attn_chunk, unroll=cfg.unroll_scans,
        acc_dtype=jnp.float32 if cfg.attn_f32 else jnp.bfloat16,
    )
    out = dense(params["wo"], out.reshape(b, t, -1), cfg, site="attn.wo", layer=layer)
    if gated:
        out = jnp.tanh(params["gate"].astype(out.dtype)) * out
    return out


def cross_kv(params, memory: jax.Array, cfg: ModelConfig, layer=None):
    """Precompute cross-attention K/V from encoder/vision states."""
    kv = cfg.num_kv_heads
    k = _split_heads(dense(params["wk"], memory, cfg, site="attn.wk", layer=layer), kv)
    v = _split_heads(dense(params["wv"], memory, cfg, site="attn.wv", layer=layer), kv)
    return k, v


# ---------------------------------------------------------------------------
# MLA (multi-head latent attention, DeepSeek-V2)
# ---------------------------------------------------------------------------
def _mla_up_weight(p: Dict[str, Any]) -> jax.Array:
    """Float view of an MLA up-projection weight for the absorbed-decode
    einsums (dequantizing a prepacked / int8-stored layout if needed)."""
    from repro.photonic.packing import PackedDense

    w = p["w"]
    if isinstance(w, PackedDense):
        return w.dequant()
    if "w_scale" in p:
        return w.astype(jnp.float32) * p["w_scale"].astype(jnp.float32)[None, :]
    return w


def _mla_qkv(params, x, cfg: ModelConfig, positions, layer=None):
    h = cfg.n_q_heads
    nope, rope = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim
    b, t, _ = x.shape
    q = dense(params["wq"], x, cfg, site="attn.wq", layer=layer)
    q = q.reshape(b, t, h, nope + rope)
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)

    dkv = dense(params["wdkv"], x, cfg, site="attn.wdkv", layer=layer)  # (B,T,r+rope)
    c_kv, k_rope = dkv[..., : cfg.kv_lora_rank], dkv[..., cfg.kv_lora_rank:]
    k_rope = apply_rope(
        k_rope[:, :, None, :], positions, cfg.rope_theta
    )  # (B,T,1,rope)
    return q_nope, q_rope, c_kv, k_rope


def _mla_expand_kv(params, c_kv, k_rope, cfg: ModelConfig, layer=None):
    h = cfg.n_q_heads
    nope, vd = cfg.qk_nope_head_dim, cfg.v_head_dim
    b, t, _ = c_kv.shape
    k_nope = dense(params["wuk"], c_kv, cfg, site="attn.wuk", layer=layer)
    k_nope = k_nope.reshape(b, t, h, nope)
    v = dense(params["wuv"], c_kv, cfg, site="attn.wuv", layer=layer)
    v = v.reshape(b, t, h, vd)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope, (b, t, h, k_rope.shape[-1]))], -1
    )
    return k, v


def mla_attention(
    params, x, cfg: ModelConfig, *, positions, causal: bool = True, layer=None
) -> jax.Array:
    b, t, _ = x.shape
    q_nope, q_rope, c_kv, k_rope = _mla_qkv(params, x, cfg, positions, layer)
    k, v = _mla_expand_kv(params, c_kv, k_rope, cfg, layer)
    q = jnp.concatenate([q_nope, q_rope], -1)
    scale = (cfg.qk_nope_head_dim + cfg.qk_rope_head_dim) ** -0.5
    out = chunked_attention(
        q, k, v, causal=causal, scale=scale,
        chunk=cfg.attn_chunk, unroll=cfg.unroll_scans,
        acc_dtype=jnp.float32 if cfg.attn_f32 else jnp.bfloat16,
    )
    return dense(params["wo"], out.reshape(b, t, -1), cfg, site="attn.wo", layer=layer)


def mla_prefill(params, x, cfg: ModelConfig, *, positions, max_seq: int, layer=None):
    b, t, _ = x.shape
    q_nope, q_rope, c_kv, k_rope = _mla_qkv(params, x, cfg, positions, layer)
    k, v = _mla_expand_kv(params, c_kv, k_rope, cfg, layer)
    q = jnp.concatenate([q_nope, q_rope], -1)
    scale = (cfg.qk_nope_head_dim + cfg.qk_rope_head_dim) ** -0.5
    out = chunked_attention(
        q, k, v, causal=True, scale=scale,
        chunk=cfg.attn_chunk, unroll=cfg.unroll_scans,
        acc_dtype=jnp.float32 if cfg.attn_f32 else jnp.bfloat16,
    )
    out = dense(params["wo"], out.reshape(b, t, -1), cfg, site="attn.wo", layer=layer)
    pad = max_seq - t
    cache = {
        "c_kv": jnp.pad(c_kv, ((0, 0), (0, pad), (0, 0))),
        "k_rope": jnp.pad(k_rope[:, :, 0, :], ((0, 0), (0, pad), (0, 0))),
    }
    return out, cache


def mla_decode_absorbed(params, x, cache, pos, cfg: ModelConfig, layer=None):
    """MLA decode with the up-projections ABSORBED into the query/output
    paths (DeepSeek-V2 serving trick): attention runs directly against the
    compressed c_kv cache — no (B, S, H, head_dim) K/V expansion, cutting
    per-step traffic by ~H*head_dim/kv_lora_rank (4x for these configs).
    Exactly equals mla_decode (linear identity; tested)."""
    b = x.shape[0]
    h = cfg.n_q_heads
    nope, rope, vd, r = (
        cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim, cfg.kv_lora_rank
    )
    posv = pos[None] if pos.ndim == 0 else pos
    q_nope, q_rope, c_kv1, k_rope1 = _mla_qkv(params, x, cfg, posv, layer)
    c = jax.lax.dynamic_update_slice_in_dim(
        cache["c_kv"], c_kv1.astype(cache["c_kv"].dtype), pos, 1
    )
    kr = jax.lax.dynamic_update_slice_in_dim(
        cache["k_rope"], k_rope1[:, :, 0, :].astype(cache["k_rope"].dtype), pos, 1
    )
    c = cm.with_logical(c, ("batch", "kv_seq", None))
    kr = cm.with_logical(kr, ("batch", "kv_seq", None))

    w_uk = _mla_up_weight(params["wuk"]).astype(jnp.float32).reshape(r, h, nope)
    w_uv = _mla_up_weight(params["wuv"]).astype(jnp.float32).reshape(r, h, vd)
    # absorb W_uk into q:  q_abs[b,h,r] = sum_n q_nope[b,1,h,n] W_uk[r,h,n]
    q_abs = jnp.einsum("bqhn,rhn->bqhr", q_nope.astype(jnp.float32), w_uk)
    cf = c.astype(jnp.float32)
    s_nope = jnp.einsum("bqhr,bsr->bhqs", q_abs, cf)
    s_rope = jnp.einsum(
        "bqhe,bse->bhqs", q_rope.astype(jnp.float32), kr.astype(jnp.float32)
    )
    scale = (nope + rope) ** -0.5
    s = (s_nope + s_rope) * scale
    s_max = c.shape[1]
    s = jnp.where((jnp.arange(s_max) <= pos)[None, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    ctx = jnp.einsum("bhqs,bsr->bqhr", p, cf)          # attention over c_kv
    out = jnp.einsum("bqhr,rhv->bqhv", ctx, w_uv)      # absorb W_uv
    out = dense(
        params["wo"], out.reshape(b, 1, -1).astype(x.dtype), cfg,
        site="attn.wo", layer=layer,
    )
    return out, {"c_kv": c, "k_rope": kr}


def mla_decode(params, x, cache, pos, cfg: ModelConfig, layer=None):
    """MLA decode against the *compressed* cache (c_kv + k_rope only)."""
    if cfg.mla_absorb:
        return mla_decode_absorbed(params, x, cache, pos, cfg, layer)
    b = x.shape[0]
    posv = pos[None] if pos.ndim == 0 else pos
    q_nope, q_rope, c_kv1, k_rope1 = _mla_qkv(params, x, cfg, posv, layer)
    c = jax.lax.dynamic_update_slice_in_dim(
        cache["c_kv"], c_kv1.astype(cache["c_kv"].dtype), pos, 1
    )
    kr = jax.lax.dynamic_update_slice_in_dim(
        cache["k_rope"], k_rope1[:, :, 0, :].astype(cache["k_rope"].dtype), pos, 1
    )
    c = cm.with_logical(c, ("batch", "kv_seq", None))
    kr = cm.with_logical(kr, ("batch", "kv_seq", None))
    k, v = _mla_expand_kv(params, c, kr[:, :, None, :], cfg, layer)
    q = jnp.concatenate([q_nope, q_rope], -1)
    scale = (cfg.qk_nope_head_dim + cfg.qk_rope_head_dim) ** -0.5
    qf = q.astype(jnp.float32) * scale
    s = jnp.einsum("bqhd,bshd->bhqs", qf, k.astype(jnp.float32))
    s_max = k.shape[1]
    s = jnp.where((jnp.arange(s_max) <= pos)[None, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqs,bshd->bqhd", p, v.astype(jnp.float32))
    out = dense(
        params["wo"], out.reshape(b, 1, -1).astype(x.dtype), cfg,
        site="attn.wo", layer=layer,
    )
    return out, {"c_kv": c, "k_rope": kr}


def mla_cache_def(cfg: ModelConfig, batch: int, max_seq: int, dtype):
    return {
        "c_kv": ((batch, max_seq, cfg.kv_lora_rank), ("batch", "kv_seq", None), dtype),
        "k_rope": (
            (batch, max_seq, cfg.qk_rope_head_dim),
            ("batch", "kv_seq", None),
            dtype,
        ),
    }
