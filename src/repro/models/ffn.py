"""Feed-forward layers: dense MLP (SwiGLU / GELU) and top-k MoE.

The MoE uses capacity-based dense dispatch (Switch/MaxText style): one-hot
dispatch/combine einsums so the whole layer is GEMMs + all-to-all-able
reshards under GSPMD.  Experts are sharded over the `model` mesh axis
(expert parallelism); shared experts (DeepSeek-V2) are a plain dense MLP.
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models import common as cm
from repro.models.common import P, ModelConfig, dense, qdense_def
from repro.photonic import EpilogueSpec


# ---------------------------------------------------------------------------
# Dense MLP
# ---------------------------------------------------------------------------
def mlp_def(cfg: ModelConfig, d_ff: int | None = None) -> Dict[str, Any]:
    d, f = cfg.d_model, d_ff or cfg.d_ff
    if cfg.ffn_act == "swiglu":
        return {
            "wi": qdense_def(cfg, d, 2 * f, (None, "d_ff")),
            "wo": qdense_def(cfg, f, d, ("d_ff", None)),
        }
    return {
        "wi": qdense_def(cfg, d, f, (None, "d_ff")),
        "wo": qdense_def(cfg, f, d, ("d_ff", None)),
    }


def mlp(params, x: jax.Array, cfg: ModelConfig, layer=None, site="ffn") -> jax.Array:
    if cfg.ffn_act == "swiglu":
        # swiglu splits the GEMM output before gating, so the activation
        # cannot ride the fused epilogue (it is not per-column).
        h = dense(params["wi"], x, cfg, site=f"{site}.wi", layer=layer)
        u, g = jnp.split(h, 2, axis=-1)
        h = u * jax.nn.silu(g)
    else:
        # gelu is elementwise on the GEMM output — fused into the engine
        # epilogue (DESIGN.md §14); digital fallback applies the same op.
        h = dense(
            params["wi"], x, cfg, site=f"{site}.wi", layer=layer,
            epilogue=EpilogueSpec(activation="gelu"),
        )
    return dense(params["wo"], h, cfg, site=f"{site}.wo", layer=layer)


# ---------------------------------------------------------------------------
# Mixture of Experts
# ---------------------------------------------------------------------------
def moe_def(cfg: ModelConfig) -> Dict[str, Any]:
    d, f, e = cfg.d_model, cfg.moe_hidden, cfg.num_experts
    defs: Dict[str, Any] = {
        "router": qdense_def(cfg, d, e, (None, None), init="normal"),
        "wi": P((e, d, 2 * f), ("experts", None, None)),
        "wo": P((e, f, d), ("experts", None, None), fan_axis=1),
    }
    if cfg.num_shared_experts:
        defs["shared"] = mlp_def(cfg, cfg.num_shared_experts * f)
    return defs


def _capacity(cfg: ModelConfig, tokens: int) -> int:
    c = int(tokens * cfg.num_experts_per_tok * cfg.capacity_factor / cfg.num_experts)
    return max(c, 1)


def moe(params, x: jax.Array, cfg: ModelConfig, layer=None) -> Tuple[
    jax.Array, jax.Array
]:
    """Returns (output, aux load-balancing loss).

    The router projection carries the site name ``"ffn.router"``: under
    the default :class:`repro.photonic.SitePolicy` it executes *digitally*
    even when every other weight GEMM is photonic — expert selection is
    control flow, and analog noise on near-uniform router logits flips
    top-k membership.  Opt it in with ``ModelConfig.photonic_exclude=()``.
    """
    b, t, d = x.shape
    e, k = cfg.num_experts, cfg.num_experts_per_tok
    cap = _capacity(cfg, t)

    logits = dense(
        params["router"], x.astype(jnp.float32), cfg,
        site="ffn.router", layer=layer,
    )  # (B,T,E)
    gates = jax.nn.softmax(logits, axis=-1)
    topv, topi = jax.lax.top_k(gates, k)
    topv = topv / jnp.maximum(topv.sum(-1, keepdims=True), 1e-9)

    # Capacity-ranked dispatch, slot by slot (k is small and static).
    dispatch = jnp.zeros((b, t, e, cap), x.dtype)
    combine = jnp.zeros((b, t, e, cap), jnp.float32)
    used = jnp.zeros((b, e), jnp.int32)  # tokens already placed per expert
    for slot in range(k):
        mask = jax.nn.one_hot(topi[..., slot], e, dtype=jnp.int32)  # (B,T,E)
        pos = jnp.cumsum(mask, axis=1) - 1 + used[:, None, :]
        ok = (pos < cap) & (mask > 0)
        oh = jax.nn.one_hot(pos, cap, dtype=x.dtype) * ok[..., None]  # (B,T,E,C)
        dispatch = dispatch + oh * mask[..., None]
        combine = combine + oh.astype(jnp.float32) * (
            mask[..., None] * topv[..., slot, None, None]
        )
        used = used + mask.sum(axis=1)

    xin = jnp.einsum("btec,btd->becd", dispatch, x)  # (B,E,C,D)
    xin = cm.with_logical(xin, ("batch", "experts", None, None))
    h = jnp.einsum("becd,edf->becf", xin, params["wi"].astype(x.dtype))
    u, g = jnp.split(h, 2, axis=-1)
    h = u * jax.nn.silu(g)
    out_e = jnp.einsum("becf,efd->becd", h, params["wo"].astype(x.dtype))
    out_e = cm.with_logical(out_e, ("batch", "experts", None, None))
    out = jnp.einsum("btec,becd->btd", combine.astype(x.dtype), out_e)

    if cfg.num_shared_experts:
        out = out + mlp(params["shared"], x, cfg, layer=layer, site="ffn.shared")

    # Load-balancing aux loss (Switch-style): E * sum_e f_e * p_e.
    frac = jnp.mean(jax.nn.one_hot(topi[..., 0], e, dtype=jnp.float32), axis=(0, 1))
    prob = jnp.mean(gates, axis=(0, 1))
    aux = e * jnp.sum(frac * prob)
    return out, aux
