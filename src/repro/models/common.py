"""Shared model substrate: config, parameter system, core layers.

Models are pure functions over pytrees.  Each module contributes a *param
definition tree* (nested dicts of :class:`P`) carrying shape + logical
sharding axes + init rule; ``init_tree`` materializes arrays and
``axes_tree`` yields the matching logical-axis tree consumed by
``repro.runtime.sharding``.  One source of truth — params and shardings can
never drift apart.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.dpu import DPUConfig

# ---------------------------------------------------------------------------
# Model configuration — one dataclass covers all 10 assigned architectures
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class ModelConfig:
    arch_id: str = "custom"
    family: str = "dense"  # dense | vlm | moe | ssm | hybrid | audio

    num_layers: int = 2
    d_model: int = 128
    num_heads: int = 2
    num_kv_heads: int = 2
    d_ff: int = 256
    vocab_size: int = 256
    head_dim: Optional[int] = None
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    ffn_act: str = "swiglu"  # swiglu | gelu

    # MoE ------------------------------------------------------------------
    num_experts: int = 0           # routed experts (0 = dense FFN)
    num_experts_per_tok: int = 2
    num_shared_experts: int = 0
    moe_d_ff: Optional[int] = None  # per-expert hidden (defaults to d_ff)
    capacity_factor: float = 1.25

    # MLA (deepseek-v2) ------------------------------------------------------
    mla: bool = False
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128

    # Vision cross-attention (llama-3.2-vision) ------------------------------
    cross_attn_every: int = 0      # 0 = no cross-attn layers
    vision_seq: int = 1024         # stub patch-embedding sequence length

    # SSM / hybrid -----------------------------------------------------------
    ssm_state_size: int = 64
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_conv_width: int = 4
    ssm_chunk: int = 256
    attn_every: int = 0            # zamba2: shared attn block every k layers
    slstm_every: int = 0           # xlstm: sLSTM block every k layers

    # Encoder-decoder (whisper) ----------------------------------------------
    encoder_decoder: bool = False
    encoder_layers: int = 0
    decoder_ratio: int = 4         # decoder_len = seq_len // ratio

    # Numerics / execution ---------------------------------------------------
    param_dtype: Any = jnp.float32
    compute_dtype: Any = jnp.float32
    remat: bool = True
    remat_policy: str = "full"   # full | dots (save dot outputs) — §Perf knob
    attn_f32: bool = True        # f32 flash-attn accumulators (False: bf16 MXU)
    attn_chunk: int = 512        # KV-block size of chunked attention
    attn_impl: str = "chunked"   # chunked | flash (Pallas kernel, §14 hot path)
    unroll_scans: bool = False   # unroll inner chunk scans (cost-analysis mode)
    logical_rules: Any = None    # per-arch sharding-rule overrides (dict)
    kv_cache_int8: bool = False  # int8 KV cache w/ per-token-head scales
    mla_absorb: bool = False     # MLA decode with absorbed up-projections
    seq_shard_residual: bool = True  # sequence-parallel residual stream
    photonic: Optional[DPUConfig] = None
    photonic_backend: str = "ref"    # ref | pallas | exact
    # Which weights execute photonically (when `photonic` is set):
    #   "none"         — photonic config carried but no GEMM routed;
    #   "weights"      — float-stored weights, quantized per call (QAT/train);
    #   "weights_int8" — int8-stored weights (photonic serving layout).
    photonic_scope: str = "weights"  # none | weights | weights_int8
    # Per-site routing policy (repro.photonic.SitePolicy patterns, matched
    # against dotted site names like "ffn.router" and their last component).
    # The MoE router stays digital by default — expert-routing decisions are
    # control flow; opt it in with photonic_exclude=().
    photonic_include: Tuple[str, ...] = ("*",)
    photonic_exclude: Tuple[str, ...] = ("router",)
    # Bit-sliced execution mode (repro.photonic.slicing): None runs the
    # hardware datapath unchanged; plane bits (int/str/SlicingSpec) run
    # every routed GEMM as plane-pair passes re-referred to the plane
    # full-scale (DESIGN.md §15 — the fidelity lever past ENOB saturation).
    photonic_slicing: Any = None

    # Structural padding applied for mesh divisibility (see pad_for_mesh) ----
    padded_heads: Optional[int] = None
    padded_vocab: Optional[int] = None

    def __post_init__(self):
        scopes = ("none", "weights", "weights_int8")
        if self.photonic_scope not in scopes:
            raise ValueError(
                f"photonic_scope={self.photonic_scope!r} is not one of {scopes}"
            )
        backends = ("ref", "pallas", "exact")
        if self.photonic_backend not in backends:
            raise ValueError(
                f"photonic_backend={self.photonic_backend!r} is not one of "
                f"{backends}"
            )
        impls = ("chunked", "flash")
        if self.attn_impl not in impls:
            raise ValueError(
                f"attn_impl={self.attn_impl!r} is not one of {impls}"
            )
        # Eager normalization through THE slicing resolution point
        # (unknown plane widths raise here, not at first GEMM).
        from repro.photonic.slicing import resolve_slicing

        object.__setattr__(
            self, "photonic_slicing", resolve_slicing(self.photonic_slicing)
        )

    @property
    def hd(self) -> int:
        return (
            self.head_dim
            if self.head_dim is not None
            else self.d_model // self.num_heads
        )

    @property
    def n_q_heads(self) -> int:
        return self.padded_heads if self.padded_heads is not None else self.num_heads

    @property
    def n_vocab(self) -> int:
        return self.padded_vocab if self.padded_vocab is not None else self.vocab_size

    @property
    def moe_hidden(self) -> int:
        return self.moe_d_ff if self.moe_d_ff is not None else self.d_ff

    def pad_for_mesh(self, model_axis: int) -> "ModelConfig":
        """Return a config with head/kv/vocab sizes divisible by the TP degree.

        * q-heads padded up (zero-init extras — structural only),
        * kv-heads replicated up to the TP degree when smaller,
        * vocab padded up (masked out of the loss).
        Overheads are counted in EXPERIMENTS.md §Roofline "useful ratio".
        """
        changes: Dict[str, Any] = {}
        if self.num_heads % model_axis:
            changes["padded_heads"] = _round_up(self.num_heads, model_axis)
        kv = self.num_kv_heads
        if kv and kv < model_axis:
            if model_axis % kv:
                raise ValueError(f"cannot replicate kv={kv} onto tp={model_axis}")
            changes["num_kv_heads"] = model_axis
        elif kv % model_axis:
            changes["num_kv_heads"] = _round_up(kv, model_axis)
        if self.vocab_size % model_axis:
            changes["padded_vocab"] = _round_up(self.vocab_size, model_axis)
        return dataclasses.replace(self, **changes) if changes else self


def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m


# ---------------------------------------------------------------------------
# Parameter definition system
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class P:
    """A parameter definition: shape + logical axes + init rule."""

    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]
    init: str = "fan_in"  # fan_in | zeros | ones | embed | normal
    fan_axis: int = 0      # which axis is fan-in for scaling
    dtype: Optional[str] = None  # override model param_dtype ("int8", "float32")

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def init_tree(defs: Any, key: jax.Array, dtype: Any) -> Any:
    """Materialize a nested dict of P into arrays (deterministic per-path)."""
    leaves = []

    def walk(node, path):
        if isinstance(node, P):
            leaves.append((path, node))
        elif isinstance(node, dict):
            for k in sorted(node):
                walk(node[k], path + (k,))
        else:
            raise TypeError(f"bad param def at {path}: {type(node)}")

    walk(defs, ())

    out: Dict[str, Any] = {}
    for path, p in leaves:
        sub = key
        for name in path:
            sub = jax.random.fold_in(sub, _stable_hash(name))
        dt = jnp.dtype(p.dtype) if p.dtype is not None else dtype
        if dt == jnp.int8:
            arr = jax.random.randint(sub, p.shape, -127, 128, jnp.int32).astype(
                jnp.int8
            )
        elif p.init == "zeros":
            arr = jnp.zeros(p.shape, dt)
        elif p.init == "ones":
            arr = jnp.ones(p.shape, dt)
        elif p.init in ("embed", "normal"):
            arr = (jax.random.normal(sub, p.shape) * 0.02).astype(dt)
        else:  # fan_in variance scaling
            fan = max(p.shape[p.fan_axis], 1)
            arr = (jax.random.normal(sub, p.shape) / math.sqrt(fan)).astype(dt)
        node = out
        for name in path[:-1]:
            node = node.setdefault(name, {})
        node[path[-1]] = arr
    return out


def axes_tree(defs: Any) -> Any:
    """The logical-axis tree matching init_tree's output."""
    if isinstance(defs, P):
        return defs.axes
    return {k: axes_tree(v) for k, v in defs.items()}


def _stable_hash(s: str) -> int:
    h = 2166136261
    for ch in s.encode():
        h = (h ^ ch) * 16777619 & 0xFFFFFFFF
    return h


# ---------------------------------------------------------------------------
# Core layers (functional)
# ---------------------------------------------------------------------------
def dense_def(
    d_in: int,
    d_out: int,
    axes: Tuple[Optional[str], Optional[str]],
    bias: bool = False,
    init: str = "fan_in",
    quantized: bool = False,
) -> Dict[str, P]:
    if quantized:
        # int8-stored weights + per-column dequant scale (photonic serving:
        # the DPU weight banks hold B-bit slices of int8 weights — weights
        # live in HBM at 1 byte, halving weight streaming traffic vs bf16).
        d: Dict[str, P] = {
            "w": P((d_in, d_out), axes, init=init, dtype="int8"),
            "w_scale": P((d_out,), (axes[1],), init="ones", dtype="float32"),
        }
    else:
        d = {"w": P((d_in, d_out), axes, init=init)}
    if bias:
        d["b"] = P((d_out,), (axes[1],), init="zeros")
    return d


def qdense_def(
    cfg: ModelConfig,
    d_in: int,
    d_out: int,
    axes: Tuple[Optional[str], Optional[str]],
    bias: bool = False,
    init: str = "fan_in",
) -> Dict[str, P]:
    """dense_def that stores int8 weights when ``photonic_scope`` is
    ``"weights_int8"`` (accepted scopes: ``none | weights | weights_int8``,
    validated by :class:`ModelConfig`)."""
    quantized = cfg.photonic is not None and cfg.photonic_scope == "weights_int8"
    return dense_def(d_in, d_out, axes, bias=bias, init=init, quantized=quantized)


def engine_from_model_config(cfg: ModelConfig):
    """The :class:`repro.photonic.PhotonicEngine` a model config implies,
    or ``None`` when no GEMM is photonic (``photonic=None`` or scope
    ``"none"``)."""
    from repro.photonic.engine import engine_for

    if cfg.photonic is None or cfg.photonic_scope == "none":
        return None
    return engine_for(
        cfg.photonic,
        cfg.photonic_backend,
        tuple(cfg.photonic_include),
        tuple(cfg.photonic_exclude),
        slicing=cfg.photonic_slicing,
    )


def dense(
    params: Dict[str, Any],
    x: jax.Array,
    cfg: ModelConfig,
    *,
    site: Optional[str] = None,
    layer: Optional[jax.Array] = None,
    prng_key: Optional[jax.Array] = None,
    epilogue: Any = None,
    slicing: Any = None,
    activation: Optional[str] = None,
) -> jax.Array:
    """Linear layer; routes through the photonic engine when enabled.

    ``site`` names this GEMM for the engine's routing policy and seed
    derivation ("attn.wq", "ffn.router", "lm_head", ...); ``layer`` is an
    optional (traceable) stack index folded into the noise stream so
    same-shaped layers inside a ``lax.scan`` decorrelate; ``prng_key``
    threads an explicit randomness source end-to-end (a noisy channel
    with neither a key nor ``DPUConfig.noise_seed`` raises the documented
    ``ValueError``).

    ``epilogue=`` takes a bias-free :class:`EpilogueSpec` — the bias
    operand always comes from the param def (``params["b"]``), so the
    spec only selects the activation; the legacy ``activation=`` keyword
    remains as a bitwise-identical shim.  Either way the bias and
    activation are *not* applied here as separate ops: they ride the
    engine's fused epilogue (DESIGN.md §14) so routed GEMMs never
    materialize the unrescaled or pre-activation intermediate (RPR008
    enforces this).  Digital fallbacks keep the historical op order
    bit-for-bit.  ``slicing=`` overrides ``cfg.photonic_slicing`` for
    this GEMM (bit-sliced execution, DESIGN.md §15).

    Under an active tensor-parallel scope
    (``repro.photonic.sharded.tensor_parallel`` / ``manual_tp``) routed
    GEMMs K-shard over the mesh axis: shard-local channel at ``N_local``,
    (site, layer, shard)-folded noise, digital-domain ``psum`` — bitwise
    equal to the single-device path under an ideal channel.
    """
    from repro.photonic import Epilogue, EpilogueSpec
    from repro.photonic import sharded as tp

    if epilogue is not None:
        if activation is not None:
            raise TypeError(
                "pass either epilogue= or the legacy activation= keyword, "
                "not both"
            )
        if not isinstance(epilogue, EpilogueSpec):
            raise TypeError(
                f"dense() takes a bias-free EpilogueSpec (the bias operand "
                f"comes from the param def), got {type(epilogue).__name__}"
            )
        if epilogue.bias:
            raise TypeError(
                "dense() sources its bias from the param def; pass "
                "EpilogueSpec(bias=False, ...)"
            )
        activation = epilogue.activation

    w = params["w"]
    bias = params.get("b")
    ep = Epilogue(EpilogueSpec(bias=bias is not None, activation=activation), bias)
    eng = engine_from_model_config(cfg)
    y = tp.maybe_tp_matmul(
        eng,
        params,
        x,
        cfg,
        site=site,
        fold=layer,
        prng_key=prng_key,
        epilogue=ep,
        slicing=slicing,
    )
    if y is None:
        y = _single_device_matmul(
            eng,
            params,
            w,
            x,
            cfg,
            site=site,
            layer=layer,
            prng_key=prng_key,
            epilogue=ep,
            slicing=slicing,
        )
    return y


def _digital_epilogue(y, ep):
    """Bias/activation for fully digital matmuls — the historical op order
    (bias added in the output dtype, activation from the engine's shared
    table) so non-photonic paths are bitwise-unchanged by fusion."""
    if ep.bias is not None:
        y = y + ep.bias.astype(y.dtype)
    if ep.spec.activation is not None:
        from repro.photonic import ACTIVATIONS

        y = ACTIVATIONS[ep.spec.activation](y)
    return y


def _single_device_matmul(
    eng, params, w, x, cfg, *, site, layer, prng_key, epilogue, slicing=None
):
    """The non-sharded product of :func:`dense` (every weight layout)."""
    from repro.photonic.packing import PackedDense

    if isinstance(w, PackedDense):
        if eng is None:
            return _digital_epilogue(x @ w.dequant().astype(x.dtype), epilogue)
        return eng.matmul(
            x, w, site=site, fold=layer, prng_key=prng_key,
            epilogue=epilogue, slicing=slicing,
        )
    if "w_scale" in params:
        # int8-stored weights through the DPU integer datapath (legacy
        # layout; the engine wraps them as an unpadded pack on the fly).
        if eng is None:
            from repro.core.dpu import DPUConfig
            from repro.photonic.engine import engine_for

            eng = engine_for(DPUConfig(), cfg.photonic_backend)
        packed = PackedDense(
            w, params["w_scale"], w.shape[-2], w.shape[-1], tiling=None
        )
        return eng.matmul(
            x, packed, site=site, fold=layer, prng_key=prng_key,
            epilogue=epilogue, slicing=slicing,
        )
    if eng is not None and cfg.photonic_scope == "weights":
        return eng.matmul_float(
            x, w, site=site, fold=layer, prng_key=prng_key,
            epilogue=epilogue, slicing=slicing,
        )
    return _digital_epilogue(x @ w.astype(x.dtype), epilogue)


def quantize_params(params: Any, defs: Any) -> Any:
    """Convert a float checkpoint to the int8-stored layout (per-column
    symmetric quantization) for photonic serving."""
    if isinstance(defs, dict) and "w_scale" in defs:
        # w: (..., d_in, d_out) — per-(leading dims, column) symmetric scale,
        # reducing the contraction axis only.
        w = params["w"].astype(jnp.float32)
        amax = jnp.max(jnp.abs(w), axis=-2)
        # Reciprocal multiply: bitwise-stable across eager/compiled contexts
        # (see quantize_symmetric).
        scale = jnp.maximum(amax, 1e-12) * (1.0 / 127.0)
        q = jnp.clip(
            jnp.round(w / jnp.expand_dims(scale, -2)), -127, 127
        ).astype(jnp.int8)
        out = dict(params)
        out["w"] = q
        out["w_scale"] = scale
        return out
    if isinstance(defs, dict):
        return {
            k: quantize_params(params[k], v) if isinstance(v, dict) else params[k]
            for k, v in defs.items()
        }
    return params


def rmsnorm_def(d: int) -> Dict[str, P]:
    return {"scale": P((d,), (None,), init="ones")}


def rmsnorm(params, x: jax.Array, eps: float) -> jax.Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(dt)


def embed_def(vocab: int, d: int) -> Dict[str, P]:
    return {"table": P((vocab, d), ("vocab", None), init="embed")}


def embed(params, ids: jax.Array, cfg: ModelConfig) -> jax.Array:
    return params["table"].astype(cfg.compute_dtype)[ids]


def unembed(params, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Logits head (optionally tied to the embedding table)."""
    w = params["table"] if "table" in params else params["w"]
    if "table" in params:
        return x @ w.astype(x.dtype).T
    return x @ w.astype(x.dtype)


# ---------------------------------------------------------------------------
# Rotary position embedding
# ---------------------------------------------------------------------------
def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., seq, heads, head_dim); positions: (..., seq)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..., seq, hd/2)
    cos = jnp.cos(angles)[..., None, :]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Loss
# ---------------------------------------------------------------------------
def softmax_cross_entropy(
    logits: jax.Array,  # (B, T, V_padded)
    labels: jax.Array,  # (B, T) int32
    true_vocab: int,
) -> jax.Array:
    """Mean CE in f32; padded vocab columns masked to -inf."""
    v = logits.shape[-1]
    logits = logits.astype(jnp.float32)
    if v > true_vocab:
        col = jax.lax.broadcasted_iota(jnp.int32, (v,), 0)
        logits = jnp.where(col < true_vocab, logits, -1e30)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - gold)


def apply_remat(fn, cfg: ModelConfig):
    """jax.checkpoint with the configured policy (§Perf knob).

    * "full": save nothing — recompute the whole block in backward.
    * "dots": save dot/matmul outputs — no GEMM recompute (more memory,
      ~25% fewer training FLOPs).
    """
    if not cfg.remat:
        return fn
    policy = None
    if cfg.remat_policy == "dots":
        policy = jax.checkpoint_policies.dots_with_no_batch_dims_saveable
    return jax.checkpoint(fn, prevent_cse=False, policy=policy)


# ---------------------------------------------------------------------------
# Sharding-constraint helper (no-op outside a mesh)
# ---------------------------------------------------------------------------
def with_logical(x: jax.Array, axes: Tuple[Optional[str], ...]) -> jax.Array:
    from repro.runtime.sharding import logical_constraint

    return logical_constraint(x, axes)
