"""Architecture registry — uniform interface over the 10 assigned archs.

Every arch exposes:
  * ``param_defs(cfg)``      — parameter definition tree (P leaves)
  * ``loss(params, batch, cfg)``                    — training loss
  * ``prefill(params, batch, cfg, max_seq)``        — (logits, cache)
  * ``decode(params, token, cache, cfg)``           — (logits, cache)
  * ``cache_def(cfg, batch, max_seq, meta, dtype)`` — cache shapes/axes
  * ``batch_spec(cfg, shape)`` / ``decode_spec``    — input ShapeDtypeStructs

`--arch <id>` in the launchers resolves through ``get(name)``.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models import lm, whisper, xlstm, zamba2
from repro.models.common import ModelConfig

# ---------------------------------------------------------------------------
# Input-shape table (assignment: LM-family shapes)
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class Arch:
    name: str
    family: str
    config: ModelConfig
    smoke_config: ModelConfig
    param_defs: Callable[[ModelConfig], Any]
    loss: Callable[..., Any]
    prefill: Callable[..., Any]
    decode: Callable[..., Any]
    cache_def: Callable[..., Any]
    skip_shapes: Tuple[str, ...] = ()
    notes: str = ""

    # ---- cost-analysis layer ladder ----------------------------------------
    def ladder(self, cfg: ModelConfig):
        """[(step_name, cfg_overrides, coeff)] such that an additive compile
        metric (FLOPs, bytes) of the full model = sum_i coeff_i * metric_i.

        Needed because XLA's cost_analysis counts `while` (scan) bodies once;
        lowering 0- and 1-group variants with inner scans unrolled recovers
        the exact per-layer cost (see EXPERIMENTS.md §Roofline method).
        """
        u = {"unroll_scans": True}
        L = cfg.num_layers
        if self.family == "audio":
            le, ld = cfg.encoder_layers, cfg.num_layers
            return [
                ("zero", {**u, "encoder_layers": 0, "num_layers": 0}, 1.0 - le - ld),
                ("enc1", {**u, "encoder_layers": 1, "num_layers": 0}, float(le)),
                ("dec1", {**u, "encoder_layers": 0, "num_layers": 1}, float(ld)),
            ]
        if self.family == "vlm":
            g = L // cfg.cross_attn_every
            per = cfg.cross_attn_every
            return [
                ("zero", {**u, "num_layers": 0}, 1.0 - g),
                ("grp1", {**u, "num_layers": per}, float(g)),
            ]
        if self.family == "ssm":
            g = L // cfg.slstm_every
            return [
                ("zero", {**u, "num_layers": 0}, 1.0 - g),
                ("grp1", {**u, "num_layers": cfg.slstm_every}, float(g)),
            ]
        if self.family == "hybrid":
            g = L // cfg.attn_every
            return [
                ("zero", {**u, "num_layers": 0}, 1.0 - g),
                ("grp1", {**u, "num_layers": cfg.attn_every}, float(g)),
            ]
        if cfg.mla and cfg.num_experts:  # deepseek-v2: unscanned first block
            return [
                ("l1", {**u, "num_layers": 1}, 2.0 - L),
                ("l2", {**u, "num_layers": 2}, L - 1.0),
            ]
        return [
            ("zero", {**u, "num_layers": 0}, 1.0 - L),
            ("l1", {**u, "num_layers": 1}, float(L)),
        ]

    # ---- input specs -------------------------------------------------------
    def train_batch_spec(self, cfg: ModelConfig, shape: ShapeSpec):
        b, t = shape.global_batch, shape.seq_len
        tok = jax.ShapeDtypeStruct((b, t), jnp.int32)
        spec = {"tokens": tok, "labels": tok}
        axes = {"tokens": ("batch", None), "labels": ("batch", None)}
        if self.family == "vlm":
            spec["vision"] = jax.ShapeDtypeStruct(
                (b, cfg.vision_seq, cfg.d_model), cfg.compute_dtype
            )
            axes["vision"] = ("batch", None, None)
        if self.family == "audio":
            dec = t // cfg.decoder_ratio
            spec = {
                "audio_embed": jax.ShapeDtypeStruct(
                    (b, t, cfg.d_model), cfg.compute_dtype
                ),
                "tokens": jax.ShapeDtypeStruct((b, dec), jnp.int32),
                "labels": jax.ShapeDtypeStruct((b, dec), jnp.int32),
            }
            axes = {
                "audio_embed": ("batch", "seq_sp", None),
                "tokens": ("batch", None),
                "labels": ("batch", None),
            }
        return spec, axes

    def prefill_batch_spec(self, cfg: ModelConfig, shape: ShapeSpec):
        spec, axes = self.train_batch_spec(cfg, shape)
        spec.pop("labels", None)
        axes.pop("labels", None)
        return spec, axes

    def decode_specs(self, cfg: ModelConfig, shape: ShapeSpec):
        """(token spec/axes, cache spec/axes) for one decode step."""
        b = shape.global_batch
        max_seq = (
            shape.seq_len
            if self.family != "audio"
            else shape.seq_len // cfg.decoder_ratio
        )
        meta = {"enc_seq": shape.seq_len}
        cache = self.cache_def(cfg, b, max_seq, meta, cfg.compute_dtype)
        tok = jax.ShapeDtypeStruct((b, 1), jnp.int32)
        cache_spec = jax.tree.map(
            lambda leaf: jax.ShapeDtypeStruct(leaf[0], leaf[2]),
            cache,
            is_leaf=_is_cache_leaf,
        )
        cache_axes = jax.tree.map(lambda leaf: leaf[1], cache, is_leaf=_is_cache_leaf)
        return (tok, ("batch", None)), (cache_spec, cache_axes)


def _is_cache_leaf(x):
    return (
        isinstance(x, tuple)
        and len(x) == 3
        and isinstance(x[0], tuple)
        and isinstance(x[1], tuple)
    )


# ---------------------------------------------------------------------------
# Family adapters (uniform call signatures)
# ---------------------------------------------------------------------------
def _lm_loss(params, batch, cfg):
    return lm.lm_loss(params, batch, cfg)


def _lm_prefill(params, batch, cfg, max_seq):
    return lm.lm_prefill(
        params, batch["tokens"], cfg, max_seq, vision=batch.get("vision")
    )


def _lm_decode(params, token, cache, cfg):
    return lm.lm_decode(params, token, cache, cfg)


def _lm_cache_def(cfg, batch, max_seq, meta, dtype):
    return lm.lm_cache_def(cfg, batch, max_seq, dtype)


def _xlstm_prefill(params, batch, cfg, max_seq):
    return xlstm.xlstm_prefill(params, batch["tokens"], cfg)


def _xlstm_cache_def(cfg, batch, max_seq, meta, dtype):
    return xlstm.xlstm_cache_def(cfg, batch, max_seq, dtype)


def _zamba_prefill(params, batch, cfg, max_seq):
    return zamba2.zamba2_prefill(params, batch["tokens"], cfg, max_seq)


def _zamba_cache_def(cfg, batch, max_seq, meta, dtype):
    return zamba2.zamba2_cache_def(cfg, batch, max_seq, dtype)


def _whisper_prefill(params, batch, cfg, max_seq):
    return whisper.whisper_prefill(params, batch, cfg, max_seq)


def _whisper_cache_def(cfg, batch, max_seq, meta, dtype):
    return whisper.whisper_cache_def(cfg, batch, max_seq, meta["enc_seq"], dtype)


_REGISTRY: Dict[str, Arch] = {}


def register(arch: Arch) -> Arch:
    _REGISTRY[arch.name] = arch
    return arch


def get(name: str) -> Arch:
    _ensure_loaded()
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def names() -> list:
    _ensure_loaded()
    return sorted(_REGISTRY)


def _ensure_loaded():
    if _REGISTRY:
        return
    import repro.configs.catalog  # noqa: F401  (registers all archs)


# Family -> adapter bundle, used by the config files.
FAMILY_FNS = {
    "dense": (lm.lm_def, _lm_loss, _lm_prefill, _lm_decode, _lm_cache_def),
    "vlm": (lm.lm_def, _lm_loss, _lm_prefill, _lm_decode, _lm_cache_def),
    "moe": (lm.lm_def, _lm_loss, _lm_prefill, _lm_decode, _lm_cache_def),
    "ssm": (
        xlstm.xlstm_def,
        xlstm.xlstm_loss,
        _xlstm_prefill,
        xlstm.xlstm_decode,
        _xlstm_cache_def,
    ),
    "hybrid": (
        zamba2.zamba2_def,
        zamba2.zamba2_loss,
        _zamba_prefill,
        zamba2.zamba2_decode,
        _zamba_cache_def,
    ),
    "audio": (
        whisper.whisper_def,
        whisper.whisper_loss,
        _whisper_prefill,
        whisper.whisper_decode,
        _whisper_cache_def,
    ),
}


def make_arch(
    name: str,
    family: str,
    config: ModelConfig,
    smoke_config: ModelConfig,
    skip_shapes: Tuple[str, ...] = (),
    notes: str = "",
) -> Arch:
    defs, loss, prefill, decode, cache_def = FAMILY_FNS[family]
    return register(
        Arch(
            name=name,
            family=family,
            config=config,
            smoke_config=smoke_config,
            param_defs=defs,
            loss=loss,
            prefill=prefill,
            decode=decode,
            cache_def=cache_def,
            skip_shapes=skip_shapes,
            notes=notes,
        )
    )
