"""Whisper-medium backbone (arXiv:2212.04356): transformer encoder-decoder.

Per the assignment, the conv/mel frontend is a STUB — ``input_specs()``
provides precomputed frame embeddings (B, T_enc, D).  The decoder sequence
length is ``seq_len // decoder_ratio`` (DESIGN.md §6).  RoPE replaces the
original learned/sinusoidal positions (deviation noted in DESIGN.md §7).
"""

from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import common as cm
from repro.models import ffn
from repro.models.common import ModelConfig
from repro.models.lm import stack_defs


def _enc_block_def(cfg: ModelConfig) -> Dict[str, Any]:
    return {
        "ln1": cm.rmsnorm_def(cfg.d_model),
        "attn": attn.gqa_def(cfg),
        "ln2": cm.rmsnorm_def(cfg.d_model),
        "ffn": ffn.mlp_def(cfg),
    }


def _dec_block_def(cfg: ModelConfig) -> Dict[str, Any]:
    d = _enc_block_def(cfg)
    d["ln_cross"] = cm.rmsnorm_def(cfg.d_model)
    d["cross"] = attn.gqa_def(cfg)
    return d


def whisper_def(cfg: ModelConfig) -> Dict[str, Any]:
    return {
        "enc_layers": stack_defs(_enc_block_def(cfg), cfg.encoder_layers),
        "enc_norm": cm.rmsnorm_def(cfg.d_model),
        "embed": cm.embed_def(cfg.n_vocab, cfg.d_model),
        "dec_layers": stack_defs(_dec_block_def(cfg), cfg.num_layers),
        "final_norm": cm.rmsnorm_def(cfg.d_model),
        "lm_head": cm.qdense_def(cfg, cfg.d_model, cfg.n_vocab, (None, "vocab")),
    }


def encode(params, audio_embed: jax.Array, cfg: ModelConfig) -> jax.Array:
    """audio_embed: (B, T_enc, D) — stubbed conv-frontend output."""
    x = cm.with_logical(audio_embed, ("batch", "seq_sp", None))
    positions = jnp.arange(x.shape[1])

    def body(p, x):
        h = cm.rmsnorm(p["ln1"], x, cfg.norm_eps)
        x = x + attn.gqa_attention(p["attn"], h, cfg, positions=positions, causal=False)
        h = cm.rmsnorm(p["ln2"], x, cfg.norm_eps)
        x = x + ffn.mlp(p["ffn"], h, cfg)
        return cm.with_logical(x, ("batch", "seq_sp", None))

    body = cm.apply_remat(body, cfg)

    def step(x, p):
        return body(p, x), None

    x, _ = jax.lax.scan(step, x, params["enc_layers"])
    x = cm.rmsnorm(params["enc_norm"], x, cfg.norm_eps)
    # Replicate the encoder output across `model` ONCE: every decoder layer's
    # cross-KV projection consumes it inside the decoder scan, and a
    # seq_sp-sharded enc would be re-gathered per layer (24x) — found via the
    # §Perf HC-E probe (whisper prefill was the only collective-bound
    # attention cell).
    return cm.with_logical(x, ("batch", None, None))


def _dec_block(p, x, enc_kv, cfg: ModelConfig, positions):
    h = cm.rmsnorm(p["ln1"], x, cfg.norm_eps)
    x = x + attn.gqa_attention(p["attn"], h, cfg, positions=positions, causal=True)
    h = cm.rmsnorm(p["ln_cross"], x, cfg.norm_eps)
    x = x + attn.cross_attention(p["cross"], h, enc_kv, cfg)
    h = cm.rmsnorm(p["ln2"], x, cfg.norm_eps)
    x = x + ffn.mlp(p["ffn"], h, cfg)
    return cm.with_logical(x, ("batch", "seq_sp", None))


def whisper_logits(params, batch, cfg: ModelConfig):
    enc = encode(params, batch["audio_embed"], cfg)
    tokens = batch["tokens"]
    x = cm.embed(params["embed"], tokens, cfg)
    positions = jnp.arange(tokens.shape[1])

    body = cm.apply_remat(lambda p, x, kv: _dec_block(p, x, kv, cfg, positions), cfg)

    def step(x, p):
        kv = attn.cross_kv(p["cross"], enc, cfg)
        return body(p, x, kv), None

    x, _ = jax.lax.scan(step, x, params["dec_layers"])
    x = cm.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return cm.dense(params["lm_head"], x, cfg, site="lm_head"), jnp.zeros(
        (), jnp.float32
    )


def whisper_loss(params, batch, cfg: ModelConfig):
    logits, _ = whisper_logits(params, batch, cfg)
    return cm.softmax_cross_entropy(logits, batch["labels"], cfg.vocab_size)


def whisper_prefill(params, batch, cfg: ModelConfig, max_seq: int):
    """Encode audio + run decoder prompt. batch: {audio_embed, tokens}."""
    enc = encode(params, batch["audio_embed"], cfg)
    tokens = batch["tokens"]
    b, t = tokens.shape
    x = cm.embed(params["embed"], tokens, cfg)
    positions = jnp.arange(t)

    def step(x, p):
        h = cm.rmsnorm(p["ln1"], x, cfg.norm_eps)
        a, kv_self = attn.gqa_prefill(
            p["attn"], h, cfg, positions=positions, max_seq=max_seq
        )
        x = x + a
        h = cm.rmsnorm(p["ln_cross"], x, cfg.norm_eps)
        kv_cross = attn.cross_kv(p["cross"], enc, cfg)
        x = x + attn.cross_attention(p["cross"], h, kv_cross, cfg)
        h = cm.rmsnorm(p["ln2"], x, cfg.norm_eps)
        x = x + ffn.mlp(p["ffn"], h, cfg)
        return x, (kv_self, kv_cross)

    x, (self_caches, cross_kvs) = jax.lax.scan(step, x, params["dec_layers"])
    x = cm.rmsnorm(params["final_norm"], x[:, -1:], cfg.norm_eps)
    logits = cm.dense(params["lm_head"], x, cfg, site="lm_head")
    cache = {
        "self": self_caches,
        "cross": cross_kvs,
        "pos": jnp.array(t, jnp.int32),
    }
    return logits, cache


def whisper_decode(params, token, cache, cfg: ModelConfig):
    x = cm.embed(params["embed"], token, cfg)
    pos = cache["pos"]

    def step(x, inp):
        p, kv_self, kv_cross = inp
        h = cm.rmsnorm(p["ln1"], x, cfg.norm_eps)
        a, kv_self = attn.gqa_decode(p["attn"], h, kv_self, pos, cfg)
        x = x + a
        h = cm.rmsnorm(p["ln_cross"], x, cfg.norm_eps)
        x = x + attn.cross_attention(p["cross"], h, kv_cross, cfg)
        h = cm.rmsnorm(p["ln2"], x, cfg.norm_eps)
        x = x + ffn.mlp(p["ffn"], h, cfg)
        return x, kv_self

    x, new_self = jax.lax.scan(
        step, x, (params["dec_layers"], cache["self"], cache["cross"])
    )
    x = cm.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = cm.dense(params["lm_head"], x, cfg, site="lm_head")
    return logits, {**cache, "self": new_self, "pos": pos + 1}


def whisper_cache_def(cfg: ModelConfig, batch: int, max_seq: int, enc_seq: int, dtype):
    n = cfg.num_layers
    kv, hd = cfg.num_kv_heads, cfg.hd
    self_c = attn.gqa_cache_def(cfg, batch, max_seq, dtype)
    cross_shape = (n, batch, enc_seq, kv, hd)
    cross_axes = (None, "batch", "kv_seq", "kv_heads", None)
    return {
        "self": {
            k: ((n,) + shape, (None,) + axes, dt)
            for k, (shape, axes, dt) in self_c.items()
        },
        "cross": ((cross_shape, cross_axes, dtype), (cross_shape, cross_axes, dtype)),
        "pos": ((), (), jnp.int32),
    }
