"""zamba2-2.7b [hybrid]: 54L d=2560 32H (kv=32) d_ff=10240 vocab=32000,
ssm_state=64 — Mamba2 backbone + weight-shared attention block every 6th
layer. [arXiv:2411.15242; hf]

long_500k RUNS for this arch (hybrid): Mamba2 state is O(1); the shared
attention block's KV cache sequence-shards over the `data` mesh axis.
"""

import jax.numpy as jnp

from repro.models.common import ModelConfig
from repro.models.registry import make_arch

FULL = ModelConfig(
    arch_id="zamba2-2.7b",
    family="hybrid",
    num_layers=54,
    d_model=2560,
    num_heads=32,
    num_kv_heads=32,
    d_ff=10240,
    vocab_size=32000,
    ssm_state_size=64,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_chunk=256,
    attn_every=6,
    param_dtype=jnp.bfloat16,
    compute_dtype=jnp.bfloat16,
)

SMOKE = ModelConfig(
    arch_id="zamba2-2.7b-smoke",
    family="hybrid",
    num_layers=4,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    d_ff=128,
    vocab_size=256,
    ssm_state_size=8,
    ssm_expand=2,
    ssm_head_dim=16,
    ssm_chunk=16,
    attn_every=2,
)

ARCH = make_arch(
    "zamba2-2.7b", "hybrid", FULL, SMOKE,
    notes="shared attn block: one weight set, 9 invocations, per-invocation "
    "KV caches; LoRA adapters + embedding-concat omitted (DESIGN.md §7).",
)
