"""granite-3-8b [dense]: 40L d=4096 32H (GQA kv=8) d_ff=12800 vocab=49155.
[hf:ibm-granite/granite-3.0-8b-base; hf]"""

import jax.numpy as jnp

from repro.models.common import ModelConfig
from repro.models.registry import make_arch

FULL = ModelConfig(
    arch_id="granite-3-8b",
    family="dense",
    num_layers=40,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=12800,
    vocab_size=49155,
    tie_embeddings=True,
    param_dtype=jnp.bfloat16,
    compute_dtype=jnp.bfloat16,
)

SMOKE = ModelConfig(
    arch_id="granite-3-8b-smoke",
    family="dense",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    d_ff=128,
    vocab_size=503,
    tie_embeddings=True,
)

ARCH = make_arch(
    "granite-3-8b", "dense", FULL, SMOKE,
    skip_shapes=("long_500k",),
    notes="long_500k skipped: pure full-attention arch (DESIGN.md §6).",
)
