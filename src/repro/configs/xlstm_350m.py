"""xlstm-350m [ssm]: 24L d=1024 4H vocab=50304 — sLSTM + mLSTM blocks,
7:1 ratio (sLSTM every 8th layer). [arXiv:2405.04517; unverified]

long_500k RUNS for this arch: decode state is O(1) (matrix memory), no KV
cache.  Projection factor 2 per the official mLSTM block (param count lands
above the "350m" family label; DESIGN.md §7).
"""

import jax.numpy as jnp

from repro.models.common import ModelConfig
from repro.models.registry import make_arch

FULL = ModelConfig(
    arch_id="xlstm-350m",
    family="ssm",
    num_layers=24,
    d_model=1024,
    num_heads=4,
    num_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    slstm_every=8,
    ssm_expand=2,
    ssm_chunk=256,
    param_dtype=jnp.bfloat16,
    compute_dtype=jnp.bfloat16,
)

SMOKE = ModelConfig(
    arch_id="xlstm-350m-smoke",
    family="ssm",
    num_layers=4,
    d_model=64,
    num_heads=2,
    num_kv_heads=2,
    d_ff=0,
    vocab_size=256,
    slstm_every=2,
    ssm_expand=2,
    ssm_chunk=16,
)

ARCH = make_arch(
    "xlstm-350m", "ssm", FULL, SMOKE,
    notes="photonic GEMM applies to projections only; the sLSTM/mLSTM "
    "recurrences are elementwise/outer-product updates (DESIGN.md §6).",
)
