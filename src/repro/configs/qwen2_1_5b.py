"""qwen2-1.5b [dense]: 28L d=1536 12H (GQA kv=2) d_ff=8960 vocab=151936,
QKV bias. [arXiv:2407.10671; hf]"""

import jax.numpy as jnp

from repro.models.common import ModelConfig
from repro.models.registry import make_arch

FULL = ModelConfig(
    arch_id="qwen2-1.5b",
    family="dense",
    num_layers=28,
    d_model=1536,
    num_heads=12,
    num_kv_heads=2,
    d_ff=8960,
    vocab_size=151936,
    qkv_bias=True,
    tie_embeddings=True,
    rope_theta=1e6,
    param_dtype=jnp.bfloat16,
    compute_dtype=jnp.bfloat16,
)

SMOKE = ModelConfig(
    arch_id="qwen2-1.5b-smoke",
    family="dense",
    num_layers=2,
    d_model=48,
    num_heads=3,
    num_kv_heads=1,
    d_ff=96,
    vocab_size=256,
    qkv_bias=True,
    tie_embeddings=True,
)

ARCH = make_arch(
    "qwen2-1.5b", "dense", FULL, SMOKE,
    skip_shapes=("long_500k",),
    notes="q-heads 12 padded to 16 for TP=16 (zero-init, DESIGN.md §7); "
    "long_500k skipped: full attention.",
)
