"""llama-3.2-vision-90b [vlm]: 100L d=8192 64H (GQA kv=8) d_ff=28672
vocab=128256 — cross-attn image layers every 5th layer; patch frontend is a
stub (input_specs provides precomputed patch embeddings).
[hf:meta-llama/Llama-3.2-90B-Vision; unverified]"""

import jax.numpy as jnp

from repro.models.common import ModelConfig
from repro.models.registry import make_arch

FULL = ModelConfig(
    arch_id="llama-3.2-vision-90b",
    family="vlm",
    num_layers=100,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=28672,
    vocab_size=128256,
    rope_theta=5e5,
    cross_attn_every=5,
    vision_seq=1600,
    param_dtype=jnp.bfloat16,
    compute_dtype=jnp.bfloat16,
)

SMOKE = ModelConfig(
    arch_id="llama-3.2-vision-90b-smoke",
    family="vlm",
    num_layers=6,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    d_ff=128,
    vocab_size=320,
    cross_attn_every=3,
    vision_seq=16,
)

ARCH = make_arch(
    "llama-3.2-vision-90b", "vlm", FULL, SMOKE,
    skip_shapes=("long_500k",),
    notes="100 layers = 80 self + 20 gated cross-attn (every 5th); "
    "long_500k skipped: full attention.",
)
