"""qwen2-0.5b [dense]: 24L d=896 14H (GQA kv=2) d_ff=4864 vocab=151936,
QKV bias. [arXiv:2407.10671; hf]"""

import jax.numpy as jnp

from repro.models.common import ModelConfig
from repro.models.registry import make_arch

FULL = ModelConfig(
    arch_id="qwen2-0.5b",
    family="dense",
    num_layers=24,
    d_model=896,
    num_heads=14,
    num_kv_heads=2,
    d_ff=4864,
    vocab_size=151936,
    qkv_bias=True,
    tie_embeddings=True,
    rope_theta=1e6,
    param_dtype=jnp.bfloat16,
    compute_dtype=jnp.bfloat16,
)

SMOKE = ModelConfig(
    arch_id="qwen2-0.5b-smoke",
    family="dense",
    num_layers=2,
    d_model=56,
    num_heads=7,
    num_kv_heads=1,
    d_ff=112,
    vocab_size=256,
    qkv_bias=True,
    tie_embeddings=True,
)

ARCH = make_arch(
    "qwen2-0.5b", "dense", FULL, SMOKE,
    skip_shapes=("long_500k",),
    notes="q-heads 14 padded to 16 for TP=16; long_500k skipped: full attention.",
)
