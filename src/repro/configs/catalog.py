"""Imports every assigned architecture config, registering them all.

Also defines the paper's own CNN workloads (photonic accelerator targets) —
see repro.core.cnn_workloads for the layer tables.
"""

from repro.configs import (  # noqa: F401
    deepseek_67b,
    deepseek_v2_lite_16b,
    granite_3_8b,
    llama_3_2_vision_90b,
    phi3_5_moe_42b,
    qwen2_0_5b,
    qwen2_1_5b,
    whisper_medium,
    xlstm_350m,
    zamba2_2_7b,
)

ASSIGNED = [
    "granite-3-8b",
    "qwen2-1.5b",
    "deepseek-67b",
    "qwen2-0.5b",
    "llama-3.2-vision-90b",
    "phi3.5-moe-42b-a6.6b",
    "deepseek-v2-lite-16b",
    "xlstm-350m",
    "zamba2-2.7b",
    "whisper-medium",
]
