"""phi3.5-moe-42b-a6.6b [moe]: 32L d=4096 32H (GQA kv=8) d_ff=6400
vocab=32064, MoE 16 experts top-2. [hf:microsoft/Phi-3.5-MoE-instruct; hf]"""

import jax.numpy as jnp

from repro.models.common import ModelConfig
from repro.models.registry import make_arch

FULL = ModelConfig(
    arch_id="phi3.5-moe-42b-a6.6b",
    family="moe",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=6400,
    vocab_size=32064,
    num_experts=16,
    num_experts_per_tok=2,
    param_dtype=jnp.bfloat16,
    compute_dtype=jnp.bfloat16,
)

SMOKE = ModelConfig(
    arch_id="phi3.5-moe-smoke",
    family="moe",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    d_ff=96,
    vocab_size=320,
    num_experts=4,
    num_experts_per_tok=2,
)

ARCH = make_arch(
    "phi3.5-moe-42b-a6.6b", "moe", FULL, SMOKE,
    skip_shapes=("long_500k",),
    notes="16 experts / TP=16 -> 1 expert per model shard (EP); "
    "long_500k skipped: full attention.",
)
