"""whisper-medium [audio]: enc-dec 24L+24L d=1024 16H d_ff=4096 vocab=51865,
conv frontend stubbed (precomputed frame embeddings). [arXiv:2212.04356;
unverified]

Decoder length = seq_len // 4 (DESIGN.md §6); GELU FFN per the original.
long_500k skipped: full attention enc-dec.
"""

import jax.numpy as jnp

from repro.models.common import ModelConfig
from repro.models.registry import make_arch

FULL = ModelConfig(
    arch_id="whisper-medium",
    family="audio",
    num_layers=24,          # decoder layers
    encoder_layers=24,
    encoder_decoder=True,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    d_ff=4096,
    vocab_size=51865,
    ffn_act="gelu",
    decoder_ratio=4,
    param_dtype=jnp.bfloat16,
    compute_dtype=jnp.bfloat16,
)

SMOKE = ModelConfig(
    arch_id="whisper-medium-smoke",
    family="audio",
    num_layers=2,
    encoder_layers=2,
    encoder_decoder=True,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    d_ff=128,
    vocab_size=320,
    ffn_act="gelu",
    decoder_ratio=4,
)

ARCH = make_arch(
    "whisper-medium", "audio", FULL, SMOKE,
    skip_shapes=("long_500k",),
    notes="decode caches: decoder self-KV (seq/4) + cross-KV over encoder "
    "frames (seq); RoPE replaces learned/sinusoidal positions (DESIGN.md §7).",
)
