"""deepseek-67b [dense]: 95L d=8192 64H (GQA kv=8) d_ff=22016 vocab=102400,
llama-arch. [arXiv:2401.02954; hf]"""

import jax.numpy as jnp

from repro.models.common import ModelConfig
from repro.models.registry import make_arch

FULL = ModelConfig(
    arch_id="deepseek-67b",
    family="dense",
    num_layers=95,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=22016,
    vocab_size=102400,
    param_dtype=jnp.bfloat16,
    compute_dtype=jnp.bfloat16,
)

SMOKE = ModelConfig(
    arch_id="deepseek-67b-smoke",
    family="dense",
    num_layers=3,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    d_ff=160,
    vocab_size=320,
)

ARCH = make_arch(
    "deepseek-67b", "dense", FULL, SMOKE,
    skip_shapes=("long_500k",),
    notes="long_500k skipped: pure full-attention arch.",
)
