"""deepseek-v2-lite-16b [moe]: 27L d=2048 MLA (kv_lora=512) 16H, MoE 64
routed experts top-6 + 2 shared, expert d_ff=1408, vocab=102400.
[arXiv:2405.04434; hf]

Assignment note: the pool entry says "2 shared+160 routed"; 64 routed
(+2 shared) matches the published DeepSeek-V2-Lite — "160" is a pool typo
(DESIGN.md §7).  Layer 0 uses a dense FFN (d_ff=10944) per the paper.
"""

import jax.numpy as jnp

from repro.models.common import ModelConfig
from repro.models.registry import make_arch

FULL = ModelConfig(
    arch_id="deepseek-v2-lite-16b",
    family="moe",
    num_layers=27,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=10944,           # dense first layer
    vocab_size=102400,
    mla=True,
    kv_lora_rank=512,
    qk_nope_head_dim=128,
    qk_rope_head_dim=64,
    v_head_dim=128,
    num_experts=64,
    num_experts_per_tok=6,
    num_shared_experts=2,
    moe_d_ff=1408,        # per routed expert
    param_dtype=jnp.bfloat16,
    compute_dtype=jnp.bfloat16,
)

SMOKE = ModelConfig(
    arch_id="deepseek-v2-lite-smoke",
    family="moe",
    num_layers=3,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    d_ff=128,
    vocab_size=320,
    mla=True,
    kv_lora_rank=32,
    qk_nope_head_dim=16,
    qk_rope_head_dim=8,
    v_head_dim=16,
    num_experts=8,
    num_experts_per_tok=2,
    num_shared_experts=1,
    moe_d_ff=48,
)

ARCH = make_arch(
    "deepseek-v2-lite-16b", "moe", FULL, SMOKE,
    skip_shapes=("long_500k",),
    notes="MLA compressed KV cache (c_kv 512 + rope 64 per token); "
    "long_500k skipped: full attention.",
)
