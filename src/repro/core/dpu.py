"""Functional model of the photonic DPU datapath (paper §III-A / §V).

A DPU executes a GEMM by:

1. **Quantizing** operands to the digital precision (``operand_bits``, int8
   for the paper's CNNs).
2. **Bit-slicing** each operand into ``ceil(operand_bits / B)`` slices of the
   analog precision ``B`` (paper §III: "If the supported value of B is less
   than the precision requirement ... bit-slicing is applied").  Incoherent
   photonics carries magnitudes; signs ride on the balanced-photodetector
   differential rails — numerically we carry a signed magnitude slice.
3. **Chunking** the dot-product (contraction) dimension into chunks of the
   achievable DPE size ``N`` (from the scalability solver).  Each chunk's
   analog summation produces a *psum* that is digitized by the ADC and
   accumulated by the electronic reduction network.
4. **Shift-adding** slice-pair passes (2^{B(s+t)} weights) and
   **dequantizing**.

With no noise/saturation enabled the model is *numerically exact*: it equals
the integer GEMM of the quantized operands (tested).  Analog non-idealities
are modeled by an organization-aware :class:`repro.noise.ChannelModel`
(crosstalk per Table II, loss-chain-derived detector noise per Tables
III–IV, filter truncation, ADC quantization/saturation — see DESIGN.md §8);
the legacy scalar ``noise_sigma_lsb`` is kept as a shorthand for a
detector-noise-only channel.

Noise determinism: every noisy call needs an explicit randomness source —
either ``prng_key`` (same key => bitwise-identical result) or the
``DPUConfig.noise_seed`` field (the documented deterministic path used when
no key can be threaded, e.g. the model serving stack).  A noisy call with
neither raises ``ValueError`` rather than silently drawing fresh noise.

This module is the pure-jnp oracle; ``repro.kernels.photonic_gemm`` provides
the TPU Pallas kernel with identical semantics (fused slicing + chunked
accumulation in VMEM).
"""

from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro import platforms as _platforms
from repro.core import scalability
from repro.core.params import PhotonicParams
from repro.noise.channel import (
    ChannelModel,
    analog_pass_psums,
    shard_local_channel,
)
from repro.noise.stages import (
    data_tweak,
    fold_seed,
    key_zero_cotangent,
    seed_from_key,
)
from repro.orgs import OrgSpec, resolve


@dataclasses.dataclass(frozen=True)
class DPUConfig:
    """Operating point of a photonic DPU (organization + precision + rate).

    ``organization`` accepts a name ("SMWA", case-insensitive), a
    four-letter block-order string ("MWAS"), or a typed
    :class:`repro.orgs.OrgSpec`; it is validated eagerly and stored as
    the canonical order name (unknown orders raise ``ValueError`` naming
    the valid choices instead of a late ``KeyError``).  ``platform``
    follows the same pattern through :func:`repro.platforms.resolve`
    (canonical name stored, eager ``ValueError`` on unknown names) and
    selects the material platform the calibrated DPE size — and any
    channel built from this config — is derived on.
    """

    organization: "str | OrgSpec" = "SMWA"
    bits: int = 4              # analog precision B per pass
    operand_bits: int = 8      # digital operand precision (paper: int8 CNNs)
    datarate_gs: float = 5.0   # symbol rate [GS/s]
    dpe_size: Optional[int] = None   # N; None -> calibrated scalability solver
    dpu_fanout: Optional[int] = None  # M; None -> = N (paper assumption)
    noise_sigma_lsb: float = 0.0     # legacy: detector-noise-only channel
    adc_bits: Optional[int] = None   # ADC saturation range; None = ideal
    # Structural analog channel (repro.noise); overrides noise_sigma_lsb.
    channel: Optional[ChannelModel] = None
    # Deterministic noise seed used when no prng_key is threaded to a call
    # (the documented deterministic path; see module docstring).
    noise_seed: Optional[int] = None
    # Material platform (repro.platforms): canonical name after resolve.
    platform: "str | _platforms.PlatformSpec" = "SOI"

    def __post_init__(self):
        # One resolution point (repro.orgs.resolve): eager validation, one
        # normalization.  Storing the canonical name keeps the config's
        # repr/equality/hash identical to the historical string form.
        object.__setattr__(self, "organization", resolve(self.organization).name)
        # Same pattern for the platform (repro.platforms.resolve).
        object.__setattr__(self, "platform", _platforms.resolve(self.platform).name)

    @property
    def org_spec(self) -> OrgSpec:
        """The typed organization spec this config runs (repro.orgs)."""
        return resolve(self.organization)

    @property
    def platform_spec(self) -> _platforms.PlatformSpec:
        """The typed platform spec this config runs on (repro.platforms)."""
        return _platforms.resolve(self.platform)

    @property
    def n(self) -> int:
        if self.dpe_size is not None:
            return self.dpe_size
        n = scalability.calibrated_max_n(
            self.organization, self.bits, self.datarate_gs, platform=self.platform
        )
        if n <= 0:
            raise ValueError(
                f"infeasible operating point: {self.organization} B={self.bits} "
                f"DR={self.datarate_gs} GS/s"
            )
        return n

    @property
    def m(self) -> int:
        return self.dpu_fanout if self.dpu_fanout is not None else self.n

    @property
    def num_slices(self) -> int:
        return -(-self.operand_bits // self.bits)  # ceil

    @property
    def passes(self) -> int:
        """Slice-pair passes per GEMM element (inputs x weights)."""
        return self.num_slices * self.num_slices

    def num_chunks(self, k: int) -> int:
        """psum chunks for a contraction of length k."""
        return -(-k // self.n)

    def effective_channel(self) -> Optional[ChannelModel]:
        """The channel model this config implies (None = ideal datapath).

        ``channel`` wins when set (inheriting ``adc_bits`` from the config
        if the channel leaves it unset); a bare ``noise_sigma_lsb`` maps to
        a detector-noise-only channel; ADC-only configs return None and keep
        the exact-integer path with saturation (bit-compatible with the
        pre-channel behavior).
        """
        if self.channel is not None:
            ch = self.channel
            if ch.adc_bits is None and self.adc_bits is not None:
                ch = dataclasses.replace(ch, adc_bits=self.adc_bits)
            return ch
        if self.noise_sigma_lsb > 0.0:
            return ChannelModel(
                organization=self.organization,
                bits=self.bits,
                datarate_gs=self.datarate_gs,
                detector_sigma_lsb=self.noise_sigma_lsb,
                adc_bits=self.adc_bits,
                platform=self.platform,
            )
        return None

    def shard_local(self, k_local: int) -> "DPUConfig":
        """The per-shard operating point of a K-sharded GEMM.

        The paper's Summation manipulation accumulates per-DPE partials in
        the digital domain; sharding the contraction axis over a device
        mesh is the same semantics at system scale, and it changes the
        physics: each shard's DPE fan-in is ``N_local = min(N, K_local)``,
        and the Table II/III channel must be evaluated there rather than
        at the global ``N`` (:func:`repro.noise.shard_local_channel`).
        Ideal configs only clamp the chunk size, which is numerically
        inert — sharded and unsharded ideal GEMMs stay bitwise equal.
        """
        n_local = min(self.n, max(int(k_local), 1))
        updates: dict = {}
        if self.dpe_size != n_local:
            updates["dpe_size"] = n_local
        if self.channel is not None:
            ch = shard_local_channel(self.channel, n_local)
            if ch is not self.channel:
                updates["channel"] = ch
        return dataclasses.replace(self, **updates) if updates else self

    def noise_seed_array(
        self, prng_key: Optional[jax.Array], *, what: str = "noise"
    ) -> jax.Array:
        """uint32 stream seed from ``prng_key`` or ``noise_seed`` (in that
        order), raising the documented error when neither is given."""
        if prng_key is not None:
            return seed_from_key(prng_key)
        if self.noise_seed is not None:
            return jnp.uint32(self.noise_seed & 0xFFFFFFFF)
        raise ValueError(
            f"{what} requires a randomness source: pass prng_key or set "
            "DPUConfig.noise_seed (deterministic; same seed => bitwise-equal "
            "results)"
        )


# ---------------------------------------------------------------------------
# Quantization
# ---------------------------------------------------------------------------
def quant_scale(
    x: jax.Array,
    bits: int,
    axis: Optional[int] = None,
    *,
    amax: Optional[jax.Array] = None,
) -> jax.Array:
    """The symmetric quantization scale alone (f32), no rounding.

    Exactly the scale half of :func:`quantize_symmetric` — the fused
    Pallas hot path computes it outside the kernel (XLA fuses the abs-max
    reduction into the producer) and ships it into the kernel as an SMEM
    scalar for the in-kernel rounding prologue.
    """
    qmax = float(2 ** (bits - 1) - 1)
    if amax is None:
        amax = jnp.max(jnp.abs(x)) if axis is None else jnp.max(
            jnp.abs(x), axis=axis, keepdims=True
        )
    # Explicit reciprocal multiply: XLA's algebraic simplifier rewrites
    # divide-by-constant to exactly this inside compiled contexts (jit /
    # scan bodies), so spelling it out keeps the scale BITWISE identical
    # between eager calls and compiled ones — the invariant the prepacked
    # weight path (repro.photonic.packing) relies on.
    scale = jnp.maximum(amax, 1e-12) * (1.0 / qmax)
    return scale.astype(jnp.float32)


def quantize_with_scale(x: jax.Array, scale: jax.Array, bits: int) -> jax.Array:
    """Round/clip ``x`` against a precomputed symmetric ``scale``.

    The rounding half of :func:`quantize_symmetric` (``scale`` is traced,
    so the division is the blessed reciprocal-multiply idiom's second
    half); for f32 inputs, composing it with :func:`quant_scale` is the
    bitwise-identical op sequence of the one-shot call — which is why the
    fused hot path only fuses f32 activations (the one-shot call divides
    by the *raw-dtype* scale, so lower-precision inputs would round
    differently against the f32 SMEM scalar).
    """
    qmax = float(2 ** (bits - 1) - 1)
    q = jnp.clip(jnp.round(x / scale), -qmax, qmax)
    dtype = jnp.int8 if bits <= 8 else jnp.int32
    return q.astype(dtype)


def quantize_symmetric(
    x: jax.Array,
    bits: int,
    axis: Optional[int] = None,
    *,
    amax: Optional[jax.Array] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Symmetric linear quantization to signed ``bits`` integers.

    Returns ``(q, scale)`` with ``x ~= q * scale``; ``q`` in
    ``[-(2^{bits-1}-1), 2^{bits-1}-1]`` (int8 storage for bits<=8, int32
    otherwise).  ``amax`` overrides the local abs-max reduction — the
    K-sharded engine passes the ``pmax``-reduced global abs-max so every
    shard quantizes with the bitwise-identical scale the unsharded path
    would use (max is exact under any reduction order).
    """
    qmax = float(2 ** (bits - 1) - 1)
    if amax is None:
        amax = jnp.max(jnp.abs(x)) if axis is None else jnp.max(
            jnp.abs(x), axis=axis, keepdims=True
        )
    # Same reciprocal-multiply scale as quant_scale (see the comment
    # there); kept inline so the historical raw-dtype division below is
    # byte-for-byte unchanged for non-f32 inputs.
    scale = jnp.maximum(amax, 1e-12) * (1.0 / qmax)
    q = jnp.clip(jnp.round(x / scale), -qmax, qmax)
    dtype = jnp.int8 if bits <= 8 else jnp.int32
    return q.astype(dtype), scale.astype(jnp.float32)


def bit_slices(q: jax.Array, slice_bits: int, num_slices: int) -> jax.Array:
    """Signed-magnitude bit-slice decomposition.

    ``q == sum_s slices[s] * 2**(slice_bits * s)`` exactly, with
    ``slices[s]`` in ``[-(2^slice_bits - 1), 2^slice_bits - 1]``.
    Stacked on a new leading axis.
    """
    sgn = jnp.sign(q).astype(jnp.int32)
    mag = jnp.abs(q.astype(jnp.int32))
    mask = (1 << slice_bits) - 1
    slices = [
        (sgn * ((mag >> (slice_bits * s)) & mask)).astype(jnp.int8)
        for s in range(num_slices)
    ]
    return jnp.stack(slices, axis=0)


# ---------------------------------------------------------------------------
# The DPU integer GEMM (slice passes x psum chunks)
# ---------------------------------------------------------------------------
def _pad_to(x: jax.Array, axis: int, multiple: int) -> jax.Array:
    size = x.shape[axis]
    pad = (-size) % multiple
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def dpu_int_gemm(
    xq: jax.Array,  # (R, K) int8 — quantized inputs
    wq: jax.Array,  # (K, C) int8 — quantized weights
    cfg: DPUConfig,
    *,
    prng_key: Optional[jax.Array] = None,
) -> jax.Array:
    """Integer GEMM through the DPU datapath. Returns int32 (R, C).

    Exactly equals ``xq.astype(i32) @ wq.astype(i32)`` when the effective
    channel is ideal (no analog stages, no ADC saturation).  With an analog
    channel, each slice-pair pass routes its per-chunk psums through the
    full signal chain (:func:`repro.noise.analog_pass_psums`); the noise
    stream derives from ``prng_key`` or ``cfg.noise_seed`` (same source =>
    bitwise-identical output).
    """
    r, k = xq.shape
    k2, c = wq.shape
    assert k == k2, (xq.shape, wq.shape)
    n = cfg.n
    s = cfg.num_slices
    channel = cfg.effective_channel()
    analog = channel is not None and channel.analog
    adc_bits = channel.adc_bits if channel is not None else cfg.adc_bits
    seed = None
    if analog and channel.detector_sigma_lsb > 0.0:
        # Operand-content tweak decorrelates same-seed, same-shape calls
        # (layers of one model / QAT steps) without losing determinism.
        seed = data_tweak(cfg.noise_seed_array(prng_key, what="detector noise"), xq, wq)

    # psum chunking of the contraction dimension (electronic reduction).
    xq = _pad_to(xq, 1, n)
    wq = _pad_to(wq, 0, n)
    kp = xq.shape[1]
    chunks = kp // n
    x_c = xq.reshape(r, chunks, n)
    w_c = wq.reshape(chunks, n, c)

    x_sl = bit_slices(x_c, cfg.bits, s)      # (S, R, chunks, N)
    w_sl = bit_slices(w_c, cfg.bits, s)      # (S, chunks, N, C)

    out = jnp.zeros((r, c), jnp.int32)
    for si in range(s):
        for ti in range(s):
            shift = cfg.bits * (si + ti)
            if analog:
                # Full signal chain: crosstalk -> filter -> detector noise
                # -> ADC, one optical pass per slice pair.
                pass_seed = fold_seed(
                    seed if seed is not None else jnp.uint32(0), si * s + ti
                )
                psum = analog_pass_psums(x_sl[si], w_sl[ti], channel, pass_seed)
            else:
                # Exact integer route (ideal or ADC-saturation-only).
                psum = jnp.einsum(
                    "rgn,gnc->rgc",
                    x_sl[si].astype(jnp.int32),
                    w_sl[ti].astype(jnp.int32),
                    preferred_element_type=jnp.int32,
                )  # (R, chunks, C) — per-chunk psums, pre-ADC
                if adc_bits is not None:
                    lim = 2 ** (adc_bits - 1) - 1
                    psum = jnp.clip(psum, -lim, lim)
            out = out + (psum.sum(axis=1) << shift)
    return out


def photonic_matmul(
    x: jax.Array,  # (..., K) float
    w: jax.Array,  # (K, C) float
    cfg: DPUConfig = DPUConfig(),
    *,
    prng_key: Optional[jax.Array] = None,
    w_scale_axis: Optional[int] = 0,
    channel: Optional[ChannelModel] = None,
) -> jax.Array:
    """Float-in / float-out GEMM executed through the photonic DPU model.

    ``channel`` overrides ``cfg.channel`` for one call (convenient for
    sweeping organizations / stage ablations over a fixed config).
    """
    if channel is not None:
        cfg = dataclasses.replace(cfg, channel=channel)
    lead = x.shape[:-1]
    k = x.shape[-1]
    xr = x.reshape(-1, k)
    xq, sx = quantize_symmetric(xr, cfg.operand_bits)
    wq, sw = quantize_symmetric(w, cfg.operand_bits, axis=w_scale_axis)
    out = dpu_int_gemm(xq, wq, cfg, prng_key=prng_key)
    y = out.astype(jnp.float32) * sx * sw  # sw broadcasts (1, C) per-channel
    return y.reshape(*lead, w.shape[1]).astype(x.dtype)


# ---------------------------------------------------------------------------
# Straight-through estimator for training through the photonic path
# ---------------------------------------------------------------------------
@partial(jax.custom_vjp, nondiff_argnums=(2,))
def _photonic_matmul_ste(
    x: jax.Array, w: jax.Array, cfg: DPUConfig, prng_key
) -> jax.Array:
    return photonic_matmul(x, w, cfg, prng_key=prng_key)


def _ste_fwd(x, w, cfg, prng_key):
    return photonic_matmul(x, w, cfg, prng_key=prng_key), (x, w, prng_key)


def _ste_bwd(cfg, res, g):
    x, w, prng_key = res
    g2 = g.reshape(-1, g.shape[-1])
    x2 = x.reshape(-1, x.shape[-1])
    dx = (g2 @ w.T.astype(g2.dtype)).reshape(x.shape).astype(x.dtype)
    dw = (x2.T.astype(g2.dtype) @ g2).astype(w.dtype)
    return dx, dw, key_zero_cotangent(prng_key)


_photonic_matmul_ste.defvjp(_ste_fwd, _ste_bwd)


def photonic_matmul_ste(
    x: jax.Array,
    w: jax.Array,
    cfg: DPUConfig,
    prng_key: Optional[jax.Array] = None,
) -> jax.Array:
    """QAT-style forward through the (optionally noisy) photonic datapath;
    backward is the straight-through dense-matmul gradient.

    With ``cfg.channel`` set (or ``noise_sigma_lsb``), the forward pass sees
    the organization's analog perturbations — pass ``prng_key`` (or set
    ``cfg.noise_seed``) so the noise draw is explicit and reproducible.
    """
    return _photonic_matmul_ste(x, w, cfg, prng_key)


# ---------------------------------------------------------------------------
# Noise sigma derived from the scalability analysis (for accuracy studies)
# ---------------------------------------------------------------------------
def noise_sigma_from_snr(
    cfg: DPUConfig, params: Optional[PhotonicParams] = None
) -> float:
    """Analog noise std (in psum LSBs) implied by operating at ENOB = B.

    The DPU is sized so the *per-symbol* SNR supports B bits; the psum of a
    chunk spans ~N * (2^B-1)^2 levels, so half-LSB noise at B bits maps to a
    psum-level sigma of ``sqrt(N) / 2`` quantization-equivalent steps spread
    across the chunk (independent symbol noise accumulates in quadrature).
    """
    n = cfg.n
    return math.sqrt(n) * 0.5
