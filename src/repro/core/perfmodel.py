"""Analytical cost model of the photonic GEMM accelerator (paper §V).

Latency/energy/area per component follow Table VI; organization-dependent
ring counts follow the Fig. 2 structures.  The system-level configuration
(DPU size N and area-proportionate DPU count per organization x datarate)
comes from Table V — the paper's own area matching; our independent area
model is reported alongside as a cross-check (benchmarks/table5_dpu.py).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Sequence

from repro import platforms as _platforms
from repro.core import scalability
from repro.core.params import DEFAULT_PERIPHERALS, PeripheralParams, dbm_to_watts
from repro.orgs import OrgSpec, resolve


@dataclasses.dataclass(frozen=True)
class AcceleratorConfig:
    organization: str = "SMWA"
    datarate_gs: float = 1.0
    bits: int = 4             # analog precision B
    operand_bits: int = 8     # CNN quantization
    n: int = 83               # DPE size (fan-in)
    m: int = 83               # DPEs per DPU (fan-out)
    dpu_count: int = 50
    dpus_per_tile: int = 4
    peripherals: PeripheralParams = DEFAULT_PERIPHERALS
    # Material platform (repro.platforms): owns the laser wall-plug
    # efficiency and the ring tuning powers of the power model.
    platform: "str | _platforms.PlatformSpec" = "SOI"

    def __post_init__(self):
        # Eager organization validation + case normalization: accept
        # str | OrgSpec, store the canonical name (unknown orders raise
        # ValueError naming the valid choices — repro.orgs.resolve).
        object.__setattr__(self, "organization", resolve(self.organization).name)
        # Same pattern for the platform (repro.platforms.resolve).
        object.__setattr__(self, "platform", _platforms.resolve(self.platform).name)

    @property
    def org_spec(self) -> OrgSpec:
        """The typed organization spec this config runs (repro.orgs)."""
        return resolve(self.organization)

    @property
    def platform_spec(self) -> _platforms.PlatformSpec:
        """The typed platform spec this config runs on (repro.platforms)."""
        return _platforms.resolve(self.platform)

    @property
    def symbol_s(self) -> float:
        return 1e-9 / self.datarate_gs

    @property
    def passes(self) -> int:
        s = -(-self.operand_bits // self.bits)
        return s * s

    @property
    def tiles(self) -> int:
        return -(-self.dpu_count // self.dpus_per_tile)

    # ---- weight-update tuning ----------------------------------------------
    # Weight updates use EO tuning (Table VI: 20 ns, 80 uW/FSR) for ALL
    # organizations; TO tuning is the slow thermal bias path.  (We tested an
    # org-dependent "hitless SMWA = EO, others = TO" model — it overshoots
    # the paper's ratios by more than an order of magnitude; recorded as a
    # refuted hypothesis in EXPERIMENTS.md §Paper-validation.)
    @property
    def tune_latency_s(self) -> float:
        return self.peripherals.eo_tuning_latency_s

    @property
    def tune_power_w_per_ring(self) -> float:
        # The per-FSR tuning power is platform-owned (Table VI tabulates
        # the SOI value; repro.platforms.SOI carries it verbatim, so the
        # default is unchanged — SiN's weaker EO effect costs more drive).
        return self.platform_spec.eo_tuning_w_per_fsr * 0.5

    # ---- organization-dependent ring counts per DPU (Fig. 2) --------------
    @property
    def rings_per_dpu(self) -> int:
        # Derived from the block order (repro.orgs rule set; reproduces the
        # legacy Fig. 2 counts — ASMW: M waveguides x (N MRM + N MRR) = 2NM,
        # MASW: shared N-MRM input array + M x N weight MRRs = N + NM,
        # SMWA: N*M MRM + N*M MRR + M x (N-ring mux) = 3NM).
        return self.org_spec.rings_per_dpu(self.n, self.m)

    @property
    def dacs_per_dpu(self) -> int:
        # Input drivers are shared across the M fan-out copies.
        return self.n

    @property
    def adcs_per_dpu(self) -> int:
        return self.m  # one per DPE/BPD

    # ---- area --------------------------------------------------------------
    def dpu_area_mm2(self) -> float:
        p = self.peripherals
        adc = p.adc(self.datarate_gs).area_mm2
        return (
            self.rings_per_dpu * p.mrr_area_mm2
            + self.adcs_per_dpu * (adc + p.pd_area_mm2)
            + self.dacs_per_dpu * p.dac.area_mm2
        )

    def tile_overhead_mm2(self) -> float:
        p = self.peripherals
        return (
            p.reduction_network.area_mm2
            + p.activation_unit.area_mm2
            + p.pooling_unit.area_mm2
            + p.edram.area_mm2
            + p.bus.area_mm2
            + p.router.area_mm2
        )

    def total_area_mm2(self) -> float:
        return (
            self.dpu_count * self.dpu_area_mm2()
            + self.tiles * self.tile_overhead_mm2()
            + self.peripherals.io_interface.area_mm2
        )

    # ---- power -------------------------------------------------------------
    def laser_power_w(self) -> float:
        """Laser wall power: N wavelengths per DPU (10 dBm each, shared
        across the M DPEs by the splitting block), at the platform's
        wall-plug efficiency (Sec. V-B assumes 20%; SOI carries that)."""
        eff = self.platform_spec.laser_wallplug_eff
        return self.dpu_count * self.n * dbm_to_watts(10.0) / eff

    def static_power_w(self) -> float:
        p = self.peripherals
        per_tile = (
            p.reduction_network.power_w
            + p.activation_unit.power_w
            + p.pooling_unit.power_w
            + p.edram.power_w
            + p.bus.power_w
            + p.router.power_w
        )
        return (self.tiles * per_tile + p.io_interface.power_w + self.laser_power_w())

    def streaming_power_w(self) -> float:
        """DAC+ADC power while a DPU streams symbols."""
        p = self.peripherals
        adc = p.adc(self.datarate_gs).power_w
        return self.dacs_per_dpu * p.dac.power_w + self.adcs_per_dpu * adc

    def weight_reprogram_cost(self, groups: int = 1):
        """Latency/energy to (re)program one weight tile's rings — the
        weight-stationary cost the prepacking layer models
        (:func:`repro.photonic.packing.reprogram_cost`).  Dense tiles
        program all ``N x M`` weight rings; depthwise tiles hold one
        k-dot per DPE, so only the ``M`` active rings are driven."""
        from repro.photonic.packing import reprogram_cost

        rings = self.n * self.m if groups == 1 else self.m
        return reprogram_cost(
            rings,
            tune_latency_s=self.tune_latency_s,
            tune_power_w_per_ring=self.tune_power_w_per_ring,
        )

    # ---- convenience -------------------------------------------------------
    @staticmethod
    def from_paper(
        organization: "str | OrgSpec", datarate_gs: float
    ) -> "AcceleratorConfig":
        """Operating point from Table V (B=4; paper-studied orders only)."""
        spec = resolve(organization)
        key = (spec.name, int(datarate_gs))
        if key not in scalability.TABLE_V_N:
            raise ValueError(
                f"no Table V operating point for {spec.name!r} at "
                f"{datarate_gs} GS/s — the paper tabulates "
                f"{sorted({k[0] for k in scalability.TABLE_V_N})} at DR in "
                f"{sorted({k[1] for k in scalability.TABLE_V_N})}; use "
                "from_scalability() for unstudied orderings"
            )
        n = scalability.TABLE_V_N[key]
        count = scalability.TABLE_V_COUNT[key]
        return AcceleratorConfig(
            organization=spec.name,
            datarate_gs=datarate_gs,
            n=n,
            m=n,
            dpu_count=count,
        )

    @staticmethod
    def from_scalability(
        organization: "str | OrgSpec",
        datarate_gs: float,
        bits: int = 4,
        dpu_count: int = 50,
        *,
        platform: "str | _platforms.PlatformSpec" = "SOI",
    ) -> "AcceleratorConfig":
        """Operating point from OUR calibrated solver (works for any valid
        ordering, studied or not — the design-space benchmark's path).
        ``platform`` sizes N on that platform's loss chain and rides into
        the config's power model; ``from_paper`` stays SOI-only (Table V
        *is* the SOI calibration target)."""
        spec = resolve(organization)
        platform_spec = _platforms.resolve(platform)
        n = scalability.calibrated_max_n(
            spec, bits, datarate_gs, platform=platform_spec
        )
        return AcceleratorConfig(
            organization=spec.name,
            datarate_gs=datarate_gs,
            bits=bits,
            n=n,
            m=n,
            dpu_count=dpu_count,
            platform=platform_spec.name,
        )


def area_matched_count(cfg: AcceleratorConfig, target_area_mm2: float) -> int:
    """Largest ``dpu_count`` keeping ``cfg`` within ``target_area_mm2``
    (the paper's area-proportionate matching, generalized to any ordering
    for the design-space sweep)."""
    lo, hi = 1, 100000
    while lo < hi:
        mid = (lo + hi + 1) // 2
        if dataclasses.replace(cfg, dpu_count=mid).total_area_mm2() <= target_area_mm2:
            lo = mid
        else:
            hi = mid - 1
    return lo


def area_matched_counts(
    datarate_gs: float,
    base: AcceleratorConfig | None = None,
    *,
    organizations: "Sequence[str | OrgSpec] | None" = None,
    bits: int = 4,
    platform: "str | _platforms.PlatformSpec" = "SOI",
) -> Dict[str, int]:
    """Our area model's DPU counts matching SMWA's area (cross-check of the
    paper's area-proportionate analysis, Table V bottom rows).

    Default (``organizations=None``): the paper's three studied orders at
    their Table V operating points — unchanged legacy behavior.  With an
    explicit ``organizations`` list, each order is sized by the calibrated
    solver (``from_scalability``, any valid ordering, either platform) and
    area-matched to ``base``'s silicon — the mapper's equal-area pool
    construction (``DpuPool.area_matched``)."""
    base = base or AcceleratorConfig.from_paper("SMWA", datarate_gs)
    target = base.total_area_mm2()
    if organizations is None:
        out = {"SMWA": base.dpu_count}
        for org in ("ASMW", "MASW"):
            cfg = AcceleratorConfig.from_paper(org, datarate_gs)
            out[org] = area_matched_count(cfg, target)
        return out
    out: Dict[str, int] = {}
    for org in organizations:
        cfg = AcceleratorConfig.from_scalability(
            org, datarate_gs, bits=bits, platform=platform
        )
        out[cfg.organization] = area_matched_count(cfg, target)
    return out
