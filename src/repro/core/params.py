"""Hardware constants for the photonic DPU analysis (paper Tables IV & VI)
and for the TPU v5e roofline target.

All photonic parameters come from Table IV of the paper (values credited to
[27] Al-Qadasi et al. / [12] Vatsavai et al.).  Peripheral cost parameters
come from Table VI.  Parameters the paper uses but does not tabulate
(``P_SMF_att``, ``d_mrr_mm``, the noise-bandwidth convention) are exposed as
fields of :class:`PhotonicParams` and frozen by a one-time calibration against
Table V (see ``repro.core.scalability.calibrate``).
"""

from __future__ import annotations

import dataclasses
import math

# ---------------------------------------------------------------------------
# Physical constants
# ---------------------------------------------------------------------------
Q_ELECTRON = 1.602176634e-19  # C
K_BOLTZMANN = 1.380649e-23    # J/K


def dbm_to_watts(dbm: float) -> float:
    return 1e-3 * 10.0 ** (dbm / 10.0)


def watts_to_dbm(watts: float) -> float:
    return 10.0 * math.log10(max(watts, 1e-30) / 1e-3)


def db_to_linear(db: float) -> float:
    return 10.0 ** (db / 10.0)


# ---------------------------------------------------------------------------
# Table IV — photonic link / scalability parameters
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class PhotonicParams:
    """Parameters of Eq. 1–3 (paper Table IV)."""

    # Tabulated in Table IV -------------------------------------------------
    p_laser_dbm: float = 10.0          # laser power intensity per channel
    responsivity: float = 1.2          # PD responsivity R_s [A/W]
    r_load: float = 50.0               # load resistance R_L [ohm]
    i_dark: float = 35e-9              # dark current I_d [A]
    temperature: float = 300.0         # absolute temperature T [K]
    rin_db_per_hz: float = -140.0      # relative intensity noise [dB/Hz]
    p_ec_il_db: float = 1.44           # fiber->chip coupling insertion loss [dB]
    p_si_att_db_per_mm: float = 0.3    # Si waveguide propagation loss [dB/mm]
    p_splitter_il_db: float = 0.01     # splitter insertion loss [dB] (per 1x2 stage)
    p_mrm_il_db: float = 4.0           # microring modulator insertion loss [dB]
    p_mrr_w_il_db: float = 0.01        # weight MRR insertion loss [dB]
    p_mrm_obl_db: float = 0.01         # MRM out-of-band (through) loss [dB]
    p_mrr_w_obl_db: float = 0.01       # weight-MRR out-of-band (through) loss [dB]

    # Platform-owned (repro.platforms): laser electrical->optical wall-plug
    # efficiency used by the accelerator power model (Sec. V-B assumes 20%).
    laser_wallplug_eff: float = 0.2

    # Organization-dependent network penalties (Table IV, P_Penalty) --------
    penalty_asmw_db: float = 5.8
    penalty_masw_db: float = 4.8
    penalty_smwa_db: float = 1.8

    # Spectral parameters (Sec. IV-C) ---------------------------------------
    fsr_nm: float = 50.0               # free spectral range
    fwhm_nm: float = 0.7               # filter full-width half-maximum
    channel_spacing_factor: float = 0.4  # spacing = 0.4 x FWHM

    # Under-specified in the paper; frozen by calibration --------------------
    p_smf_att_db: float = 0.0          # single-mode fiber attenuation [dB]
    d_mrr_mm: float = 0.02             # MRR diameter (waveguide length per ring) [mm]
    # noise bandwidth = DR / bw_divisor  (paper writes sqrt(DR/sqrt(2)))
    bw_divisor: float = math.sqrt(2.0)

    @property
    def rin_linear_per_hz(self) -> float:
        return db_to_linear(self.rin_db_per_hz)

    # Paper states spacing "0.25nm (= 0.4 x 0.7)" (arithmetic says 0.28; the
    # paper rounds to 0.25 to get the FSR-limited N = 200). We honour the
    # paper's stated 0.25 nm / N=200.
    channel_spacing_nm: float = 0.25

    @property
    def fsr_limited_n(self) -> int:
        """Max WDM channel count allowed by the FSR (paper: 200)."""
        return int(round(self.fsr_nm / self.channel_spacing_nm))

    def penalty_db(self, organization) -> float:
        """Lumped network penalty P_penalty for an organization (Table IV).

        Accepts ``str | OrgSpec`` (resolved via :func:`repro.orgs.resolve`).
        The three paper-studied orders read the explicit Table IV fields
        above (so ``dataclasses.replace`` ablations keep working); any
        other valid ordering falls back to the structurally derived
        penalty — which, at the default anchors, reproduces the same
        values for ASMW / MASW / SMWA (see DESIGN.md §11).
        """
        from repro.orgs import resolve

        spec = resolve(organization)
        overrides = {
            "ASMW": self.penalty_asmw_db,
            "MASW": self.penalty_masw_db,
            "SMWA": self.penalty_smwa_db,
        }
        if spec.name in overrides:
            return overrides[spec.name]
        return spec.derived_penalty_db


# ---------------------------------------------------------------------------
# Table VI — accelerator peripheral cost model
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class PeripheralCost:
    power_w: float      # static/active power [W]
    latency_s: float    # per-use latency [s]
    area_mm2: float     # area [mm^2]


@dataclasses.dataclass(frozen=True)
class PeripheralParams:
    """Table VI — peripherals and DPU parameters (from [12])."""

    reduction_network: PeripheralCost = PeripheralCost(0.050e-3, 3.125e-9, 3.00e-5)
    activation_unit: PeripheralCost = PeripheralCost(0.52e-3, 0.78e-9, 6.00e-5)
    io_interface: PeripheralCost = PeripheralCost(140.18e-3, 0.78e-9, 2.44e-2)
    pooling_unit: PeripheralCost = PeripheralCost(0.4e-3, 3.125e-9, 2.40e-4)
    edram: PeripheralCost = PeripheralCost(41.1e-3, 1.56e-9, 1.66e-1)
    bus: PeripheralCost = PeripheralCost(7e-3, 5 * 0.78e-9, 9.00e-3)       # 5 cycles
    router: PeripheralCost = PeripheralCost(42e-3, 2 * 0.78e-9, 1.50e-2)   # 2 cycles
    dac: PeripheralCost = PeripheralCost(12.5e-3, 0.78e-9, 2.50e-3)
    adc_1gs: PeripheralCost = PeripheralCost(2.55e-3, 0.78e-9, 2e-3)
    adc_5gs: PeripheralCost = PeripheralCost(11e-3, 0.78e-9, 21e-3)
    adc_10gs: PeripheralCost = PeripheralCost(30e-3, 0.78e-9, 103e-3)
    # Tuning: power per FSR of shift, latency per actuation.
    eo_tuning_w_per_fsr: float = 80e-6
    eo_tuning_latency_s: float = 20e-9
    to_tuning_w_per_fsr: float = 275e-3
    to_tuning_latency_s: float = 4e-6
    # Laser: 10 dBm per wavelength channel (Table IV / Sec. V-B).
    laser_w_per_channel: float = dbm_to_watts(10.0)
    # MRR active area (typical 20um ring + driver pitch) for area model.
    mrr_area_mm2: float = 4.0e-4
    pd_area_mm2: float = 1.0e-4

    def adc(self, datarate_gs: float) -> PeripheralCost:
        if datarate_gs <= 1:
            return self.adc_1gs
        if datarate_gs <= 5:
            return self.adc_5gs
        return self.adc_10gs


# ---------------------------------------------------------------------------
# TPU v5e roofline constants (per system prompt)
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class TPUv5eParams:
    peak_flops_bf16: float = 197e12    # FLOP/s per chip
    hbm_bandwidth: float = 819e9       # B/s per chip
    ici_bandwidth: float = 50e9        # B/s per link
    hbm_bytes: float = 16e9            # HBM capacity per chip
    vmem_bytes: float = 128 * 2 ** 20  # ~128 MiB VMEM
    mxu_dim: int = 128                 # systolic array tile


DEFAULT_PHOTONIC = PhotonicParams()
DEFAULT_PERIPHERALS = PeripheralParams()
TPU_V5E = TPUv5eParams()
