"""Scalability analysis — paper Eq. 1, Eq. 2, Eq. 3 (§IV-C, Fig. 5, Table V).

Given a bit precision ``B`` and datarate ``DR``, Eq. 1–2 yield the minimum
optical power ``P_PD-opt`` that the balanced photodetector must receive to
resolve ``B`` bits (ENOB relation with shot + thermal + RIN noise).  Eq. 3
computes the optical power ``P_O/p`` that actually reaches the photodetector
after all losses/penalties for a DPU of size ``N`` (fan-in) and fan-out ``M``.
The achievable DPU size is the largest ``N`` (= ``M``, following the paper)
with ``P_O/p >= P_PD-opt``, capped by the FSR-limited WDM channel count.

Three parameters of Eq. 3 are not tabulated in the paper (``P_SMF-att``,
``d_MRR`` and the exact noise-bandwidth convention); :func:`calibrate` freezes
them with a one-time grid search against the nine Table V entries.  The
calibrated defaults below reproduce Table V closely (see
``benchmarks/table5_dpu.py`` and EXPERIMENTS.md §Paper-validation).
"""

from __future__ import annotations

import dataclasses
import itertools
import math
from typing import Dict, Iterable, Tuple

from repro import platforms as _platforms
from repro.core.params import K_BOLTZMANN, Q_ELECTRON, PhotonicParams, watts_to_dbm
from repro.orgs import ORGANIZATIONS, OrgSpec, resolve

# Paper Table V — DPU size N at 4-bit precision (targets for calibration /
# validation).  Keys: (organization, datarate in GS/s) -> N.
TABLE_V_N: Dict[Tuple[str, int], int] = {
    ("ASMW", 1): 36, ("ASMW", 5): 17, ("ASMW", 10): 12,
    ("MASW", 1): 43, ("MASW", 5): 21, ("MASW", 10): 15,
    ("SMWA", 1): 83, ("SMWA", 5): 42, ("SMWA", 10): 30,
}

# Paper Table V — area-proportionate DPU counts (validated in perfmodel).
TABLE_V_COUNT: Dict[Tuple[str, int], int] = {
    ("ASMW", 1): 160, ("ASMW", 5): 265, ("ASMW", 10): 291,
    ("MASW", 1): 186, ("MASW", 5): 275, ("MASW", 10): 295,
    ("SMWA", 1): 50, ("SMWA", 5): 147, ("SMWA", 10): 198,
}


# ---------------------------------------------------------------------------
# Eq. 2 — input-referred noise amplitude beta(P_PD) [A / sqrt(Hz)]
# ---------------------------------------------------------------------------
def noise_beta(p_pd_watts: float, params: PhotonicParams) -> float:
    r = params.responsivity
    shot_signal = 2.0 * Q_ELECTRON * (r * p_pd_watts + params.i_dark)
    thermal = 4.0 * K_BOLTZMANN * params.temperature / params.r_load
    rin = (r * p_pd_watts) ** 2 * params.rin_linear_per_hz
    dark_branch = 2.0 * Q_ELECTRON * params.i_dark + thermal
    return math.sqrt(shot_signal + thermal + rin) + math.sqrt(dark_branch)


# ---------------------------------------------------------------------------
# Eq. 1 — minimum PD power for B bits at datarate DR (fixed point on Eq. 2)
# ---------------------------------------------------------------------------
def pd_sensitivity_watts(
    bits: float,
    datarate_hz: float,
    params: PhotonicParams,
    *,
    snr_margin_db: float = 0.0,
    tol: float = 1e-12,
) -> float:
    """Solve Eq. 1 for P_PD-opt: B = (20 log10(R P / (beta sqrt(BW))) - 1.76)/6.02.

    The achievable SNR saturates at 1/sqrt(RIN*BW) as P grows (the RIN term of
    Eq. 2 scales with P^2), so high (B, DR) corners can be *infeasible* — we
    return ``math.inf`` there (and :func:`max_dpu_size` returns N=0, matching
    the empty corners of Fig. 5).
    """
    snr_db = 6.02 * bits + 1.76 + snr_margin_db
    snr_amp = 10.0 ** (snr_db / 20.0)
    bw = datarate_hz / params.bw_divisor
    sqrt_bw = math.sqrt(bw)

    def snr(p: float) -> float:
        return params.responsivity * p / (noise_beta(p, params) * sqrt_bw)

    # RIN-imposed SNR ceiling (amplitude).
    snr_ceiling = 1.0 / math.sqrt(params.rin_linear_per_hz * bw)
    if snr_amp >= snr_ceiling:
        return math.inf
    lo, hi = 1e-15, 1e-9
    while snr(hi) < snr_amp:
        hi *= 2.0
        if hi > 10.0:  # > 10 W at the PD: treat as infeasible
            return math.inf
    # snr(p) is monotonically increasing -> bisection.
    for _ in range(200):
        mid = 0.5 * (lo + hi)
        if snr(mid) < snr_amp:
            lo = mid
        else:
            hi = mid
        if hi - lo <= tol * hi:
            break
    return hi


def bits_supported(
    p_pd_watts: float, datarate_hz: float, params: PhotonicParams
) -> float:
    """Forward Eq. 1: ENOB supported by a received power (for property tests)."""
    bw = datarate_hz / params.bw_divisor
    snr = (
        params.responsivity
        * p_pd_watts
        / (noise_beta(p_pd_watts, params) * math.sqrt(bw))
    )
    if snr <= 0:
        return 0.0
    return (20.0 * math.log10(snr) - 1.76) / 6.02


# ---------------------------------------------------------------------------
# Eq. 3 — optical power reaching the photodetector, in dBm
# ---------------------------------------------------------------------------
def output_power_dbm(
    n: int,
    m: int,
    organization: "str | OrgSpec",
    params: PhotonicParams,
    *,
    org_aware_through: bool = True,
) -> float:
    spec = resolve(organization)
    p = params.p_laser_dbm
    p -= params.p_smf_att_db
    p -= params.p_ec_il_db
    p -= params.p_si_att_db_per_mm * n * params.d_mrr_mm
    p -= params.p_mrm_il_db
    p -= params.p_splitter_il_db * math.log2(max(m, 2))
    p -= params.p_mrr_w_il_db
    if org_aware_through:
        # Structural through loss (paper §IV-B1 / Table III, derived from
        # the block order): a channel passes 2(N-1) out-of-resonance rings
        # in ASMW, N in MASW, only 2 in SMWA.
        p -= spec.through_device_count(n) * params.p_mrm_obl_db
    else:
        # Eq. 3 exactly as printed (organization differences lumped in
        # P_penalty only).
        p -= (n - 1) * params.p_mrm_obl_db
        p -= (n - 1) * params.p_mrr_w_obl_db
    p -= params.penalty_db(spec)
    p -= 10.0 * math.log10(n)  # 1:M fan-out power split (M = N)
    return p


# ---------------------------------------------------------------------------
# Achievable DPU size N (Fig. 5 / Table V)
# ---------------------------------------------------------------------------
def max_dpu_size(
    organization: "str | OrgSpec",
    bits: float,
    datarate_gs: float,
    params: PhotonicParams,
    *,
    snr_margin_db: float = 0.0,
    org_aware_through: bool = True,
) -> int:
    """Largest N (= M) whose delivered power meets the PD sensitivity."""
    organization = resolve(organization)
    p_pd = pd_sensitivity_watts(
        bits, datarate_gs * 1e9, params, snr_margin_db=snr_margin_db
    )
    if math.isinf(p_pd):
        return 0
    p_pd_dbm = watts_to_dbm(p_pd)
    best = 0
    for n in range(1, params.fsr_limited_n + 1):
        if (
            output_power_dbm(
                n, n, organization, params, org_aware_through=org_aware_through
            )
            >= p_pd_dbm
        ):
            best = n
        else:
            # P_O/p is monotonically decreasing in N -> can stop early.
            break
    return best


def scalability_table(
    params: PhotonicParams,
    *,
    bits: Iterable[int] = range(1, 9),
    datarates_gs: Iterable[float] = (1, 5, 10),
    organizations: "Iterable[str | OrgSpec]" = ORGANIZATIONS,
    snr_margin_db: float = 0.0,
) -> Dict[Tuple[str, float, int], int]:
    """Fig. 5 — N for every (organization, DR, B); keyed by canonical name."""
    out = {}
    for org, dr, b in itertools.product(organizations, datarates_gs, bits):
        out[(resolve(org).name, dr, b)] = max_dpu_size(
            org, b, dr, params, snr_margin_db=snr_margin_db
        )
    return out


# ---------------------------------------------------------------------------
# Calibration of under-specified parameters against Table V
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class CalibrationResult:
    params: PhotonicParams
    snr_margin_db: float
    mean_abs_rel_err: float
    per_cell: Dict[Tuple[str, int], Tuple[int, int]]  # (ours, paper)
    org_aware_through: bool = True


def calibrate(
    base: PhotonicParams | None = None,
    *,
    d_mrr_grid: Tuple[float, ...] = (0.005, 0.01, 0.02, 0.05),
    bw_divisor_grid: Tuple[float, ...] = (1.0, math.sqrt(2.0), 2.0),
    smf_att_grid: Tuple[float, ...] = (0.0, 0.5, 1.0),
    margin_grid: Tuple[float, ...] = (0.0, 1.0, 2.0, 3.0),
    through_grid: Tuple[bool, ...] = (True, False),
) -> CalibrationResult:
    """Grid-search the untabulated parameters to match Table V."""
    base = base or PhotonicParams()
    best: CalibrationResult | None = None
    for d_mrr, bw_div, smf, margin, th in itertools.product(
        d_mrr_grid, bw_divisor_grid, smf_att_grid, margin_grid, through_grid
    ):
        params = dataclasses.replace(
            base, d_mrr_mm=d_mrr, bw_divisor=bw_div, p_smf_att_db=smf
        )
        per_cell = {}
        err = 0.0
        for (org, dr), n_paper in TABLE_V_N.items():
            n_ours = max_dpu_size(
                org, 4, dr, params, snr_margin_db=margin, org_aware_through=th
            )
            per_cell[(org, dr)] = (n_ours, n_paper)
            err += abs(n_ours - n_paper) / n_paper
        err /= len(TABLE_V_N)
        if best is None or err < best.mean_abs_rel_err:
            best = CalibrationResult(params, margin, err, per_cell, th)
    assert best is not None
    return best


# Calibrated operating point, frozen at import (cheap: ~300 grid points of a
# closed-form solve).  tests/test_scalability.py re-derives it and checks the
# Table V match stays within tolerance.
_CALIBRATION = calibrate()
CALIBRATED = _CALIBRATION.params


def calibration() -> CalibrationResult:
    return _CALIBRATION


def calibrated_max_n(
    organization: "str | OrgSpec",
    bits: float,
    datarate_gs: float,
    *,
    platform: "str | _platforms.PlatformSpec" = "SOI",
) -> int:
    """Achievable DPU size N at the calibrated operating point.

    ``platform`` applies a :class:`repro.platforms.PlatformSpec` over the
    calibrated parameters (loss fields only — the Table-V-calibrated
    margins and under-specified fields are platform-independent), so the
    SOI default reproduces the paper's Table V exactly and SiN answers
    "how far does the same calibration scale on a lower-loss platform".
    """
    params = _platforms.resolve(platform).apply(CALIBRATED)
    return max_dpu_size(
        organization,
        bits,
        datarate_gs,
        params,
        snr_margin_db=_CALIBRATION.snr_margin_db,
        org_aware_through=_CALIBRATION.org_aware_through,
    )


def table_v() -> Dict[Tuple[str, int], int]:
    """Our reproduction of Table V's N row (B=4)."""
    return {(org, dr): calibrated_max_n(org, 4, dr) for (org, dr) in TABLE_V_N}
