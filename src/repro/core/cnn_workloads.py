"""CNN workloads evaluated by the paper (§V-B): GoogleNet, ResNet50,
MobileNetV2, ShuffleNetV2 — batch 1, 224x224 inputs, 8-bit quantized.

Each conv layer is expressed as its im2col GEMM (paper Fig. 1):
rows = output spatial positions, k = C_in*kh*kw (dot-product length),
cols = C_out.  Depthwise convs set groups=C (each output channel is an
independent k=kh*kw dot product).  FC layers are rows=1 GEMMs.
"""

from __future__ import annotations

import dataclasses
from typing import List


@dataclasses.dataclass(frozen=True)
class GemmLayer:
    name: str
    rows: int      # output spatial positions (im2col rows) x batch
    k: int         # dot-product length per group
    cols: int      # output channels per group
    groups: int = 1

    @property
    def dots(self) -> int:
        """Total dot products (each of length k)."""
        return self.rows * self.cols * self.groups

    @property
    def macs(self) -> int:
        return self.dots * self.k


def _conv(name, hw, cin, cout, kernel=1, stride=1, groups=1) -> GemmLayer:
    out = hw // stride
    if groups == 1:
        return GemmLayer(name, out * out, cin * kernel * kernel, cout)
    # depthwise: per-channel k*k dot
    assert groups == cin == cout
    return GemmLayer(name, out * out, kernel * kernel, 1, groups=cin)


# ---------------------------------------------------------------------------
# ResNet50 (He et al., CVPR 2016) — exact
# ---------------------------------------------------------------------------
def resnet50() -> List[GemmLayer]:
    layers = [_conv("conv1", 224, 3, 64, 7, 2)]
    cfg = [  # (blocks, c_mid, c_out, hw_in, first_stride)
        (3, 64, 256, 56, 1),
        (4, 128, 512, 56, 2),
        (6, 256, 1024, 28, 2),
        (3, 512, 2048, 14, 2),
    ]
    c_in = 64
    for si, (blocks, cm, co, hw, s0) in enumerate(cfg):
        for b in range(blocks):
            s = s0 if b == 0 else 1
            hw_b = hw if b == 0 else hw // s0
            pre = f"res{si+2}{chr(97+b)}"
            layers.append(_conv(f"{pre}_1x1a", hw_b, c_in, cm, 1, s))
            layers.append(_conv(f"{pre}_3x3", hw_b // s, cm, cm, 3, 1))
            layers.append(_conv(f"{pre}_1x1b", hw_b // s, cm, co, 1, 1))
            if b == 0:
                layers.append(_conv(f"{pre}_down", hw_b, c_in, co, 1, s))
            c_in = co
    layers.append(GemmLayer("fc", 1, 2048, 1000))
    return layers


# ---------------------------------------------------------------------------
# GoogleNet / Inception-v1 (Szegedy et al., CVPR 2015)
# ---------------------------------------------------------------------------
_INCEPTION = [  # (name, hw, cin, 1x1, 3x3red, 3x3, 5x5red, 5x5, pool_proj)
    ("3a", 28, 192, 64, 96, 128, 16, 32, 32),
    ("3b", 28, 256, 128, 128, 192, 32, 96, 64),
    ("4a", 14, 480, 192, 96, 208, 16, 48, 64),
    ("4b", 14, 512, 160, 112, 224, 24, 64, 64),
    ("4c", 14, 512, 128, 128, 256, 24, 64, 64),
    ("4d", 14, 512, 112, 144, 288, 32, 64, 64),
    ("4e", 14, 528, 256, 160, 320, 32, 128, 128),
    ("5a", 7, 832, 256, 160, 320, 32, 128, 128),
    ("5b", 7, 832, 384, 192, 384, 48, 128, 128),
]


def googlenet() -> List[GemmLayer]:
    layers = [
        _conv("conv1", 224, 3, 64, 7, 2),
        _conv("conv2_red", 56, 64, 64, 1, 1),
        _conv("conv2", 56, 64, 192, 3, 1),
    ]
    for name, hw, cin, c1, c3r, c3, c5r, c5, cp in _INCEPTION:
        layers += [
            _conv(f"inc{name}_1x1", hw, cin, c1),
            _conv(f"inc{name}_3x3r", hw, cin, c3r),
            _conv(f"inc{name}_3x3", hw, c3r, c3, 3),
            _conv(f"inc{name}_5x5r", hw, cin, c5r),
            _conv(f"inc{name}_5x5", hw, c5r, c5, 5),
            _conv(f"inc{name}_pool", hw, cin, cp),
        ]
    layers.append(GemmLayer("fc", 1, 1024, 1000))
    return layers


# ---------------------------------------------------------------------------
# MobileNetV2 (Sandler et al., CVPR 2018)
# ---------------------------------------------------------------------------
_MBV2 = [  # (expansion t, c_out, n_blocks, stride)
    (1, 16, 1, 1), (6, 24, 2, 2), (6, 32, 3, 2), (6, 64, 4, 2),
    (6, 96, 3, 1), (6, 160, 3, 2), (6, 320, 1, 1),
]


def mobilenet_v2() -> List[GemmLayer]:
    layers = [_conv("conv1", 224, 3, 32, 3, 2)]
    c_in, hw = 32, 112
    for bi, (t, c, n, s) in enumerate(_MBV2):
        for i in range(n):
            stride = s if i == 0 else 1
            mid = c_in * t
            pre = f"ir{bi}_{i}"
            if t != 1:
                layers.append(_conv(f"{pre}_exp", hw, c_in, mid, 1, 1))
            layers.append(_conv(f"{pre}_dw", hw, mid, mid, 3, stride, groups=mid))
            hw = hw // stride
            layers.append(_conv(f"{pre}_proj", hw, mid, c, 1, 1))
            c_in = c
    layers.append(_conv("conv_last", hw, c_in, 1280, 1, 1))
    layers.append(GemmLayer("fc", 1, 1280, 1000))
    return layers


# ---------------------------------------------------------------------------
# ShuffleNetV2 1x (Ma et al., ECCV 2018)
# ---------------------------------------------------------------------------
_SHUFFLE = [(116, 4, 28), (232, 8, 14), (464, 4, 7)]  # (c_out, units, hw_out)


def shufflenet_v2() -> List[GemmLayer]:
    layers = [_conv("conv1", 224, 3, 24, 3, 2)]
    c_in = 24
    for si, (c, n, hw_out) in enumerate(_SHUFFLE):
        hw_in = hw_out * 2
        half = c // 2
        # downsample unit: two branches
        layers += [
            _conv(f"st{si}_d_b1dw", hw_in, c_in, c_in, 3, 2, groups=c_in),
            _conv(f"st{si}_d_b1pw", hw_out, c_in, half, 1, 1),
            _conv(f"st{si}_d_b2pw1", hw_in, c_in, half, 1, 1),
            _conv(f"st{si}_d_b2dw", hw_in, half, half, 3, 2, groups=half),
            _conv(f"st{si}_d_b2pw2", hw_out, half, half, 1, 1),
        ]
        for u in range(1, n):
            layers += [
                _conv(f"st{si}_u{u}_pw1", hw_out, half, half, 1, 1),
                _conv(f"st{si}_u{u}_dw", hw_out, half, half, 3, 1, groups=half),
                _conv(f"st{si}_u{u}_pw2", hw_out, half, half, 1, 1),
            ]
        c_in = c
    layers.append(_conv("conv5", 7, 464, 1024, 1, 1))
    layers.append(GemmLayer("fc", 1, 1024, 1000))
    return layers


WORKLOADS = {
    "googlenet": googlenet,
    "resnet50": resnet50,
    "mobilenet_v2": mobilenet_v2,
    "shufflenet_v2": shufflenet_v2,
}


def total_macs(name: str) -> int:
    return sum(l.macs for l in WORKLOADS[name]())
