"""DPU organizations (paper §III) and their circuit-level properties.

The paper classifies incoherent MRR-based DPUs by the order in which the four
optical-channel manipulation blocks appear:

* **ASMW** — Aggregation, Splitting, Modulation, Weighting
  (Crosslight, DEAP-CNN, Robin, RAMM)
* **MASW** — Modulation, Aggregation, Splitting, Weighting
  (Holylight, Yang, Al-Qadasi, PCNNA, RMAM)
* **SMWA** — Splitting, Modulation, Weighting, Aggregation ("hitless")
  (Hitless, ADEPT, Albireo)

Since PR 5 the block order itself is the API: :mod:`repro.orgs` defines the
typed :class:`~repro.orgs.OrgSpec` whose crosstalk (Table II), loss
structure (Table III), and lumped penalty (Table IV) are *derived* from the
order by structural rules (DESIGN.md §11) instead of looked up.  This
module keeps the historical table-shaped views — ``CROSSTALK`` / ``LOSSES``
/ ``BLOCK_ORDERS`` / ``through_device_count`` — as thin projections of the
derived profiles (tested equal to the paper tables in
``tests/test_orgs.py``), plus the structural penalty decomposition used by
the circuit-level analysis benchmark.
"""

from __future__ import annotations

from typing import Callable, Dict, Iterator, Mapping, Union

from repro import orgs
from repro.core.params import PhotonicParams
from repro.orgs import (  # noqa: F401  (re-exported compatibility surface)
    AGG,
    EFFECT_BUDGET_DB,
    MOD,
    ORGANIZATIONS,
    SPLIT,
    SUM,
    WEIGHT,
    CrosstalkProfile,
    LossProfile,
    OrgSpec,
)

class _RegistryView(Mapping):
    """Live name-keyed view over the org registry (one projected field per
    spec).  A mapping rather than a dict snapshot so organizations added
    via :func:`repro.orgs.register` after import appear here too."""

    def __init__(self, project: Callable[[OrgSpec], object]):
        self._project = project

    def __getitem__(self, name: str):
        return self._project(orgs.registered()[name])

    def __iter__(self) -> Iterator[str]:
        return iter(orgs.registered())

    def __len__(self) -> int:
        return len(orgs.registered())

    def __repr__(self) -> str:
        return repr(dict(self))


BLOCK_ORDERS: Mapping[str, tuple] = _RegistryView(lambda s: s.blocks)

# Prior-work classification (paper Table I).
PRIOR_WORK: Mapping[str, tuple] = _RegistryView(orgs.prior_work)

# Table II / Table III, derived from the block orders (asserted equal to the
# paper's hand-tabulated values in tests/test_orgs.py).
CROSSTALK: Mapping[str, CrosstalkProfile] = _RegistryView(lambda s: s.crosstalk)

LOSSES: Mapping[str, LossProfile] = _RegistryView(lambda s: s.losses)


def through_device_count(organization: Union[str, OrgSpec], n: int) -> int:
    """Out-of-resonance devices traversed by one channel (paper §IV-B1)."""
    return orgs.resolve(organization).through_device_count(n)


def structural_penalty_db(
    organization: Union[str, OrgSpec],
    n: int,
    params: PhotonicParams,
) -> Dict[str, float]:
    """Per-effect penalty decomposition (beyond-paper structural model).

    The paper lumps crosstalk + filter + propagation into ``P_penalty``
    (Table IV).  This reconstructs the composition from the per-effect
    budgets of §IV-C and the structural loss model of §IV-B, so the
    circuit-level analysis benchmark can show *where* each organization's
    penalty comes from.  ``sum(values)`` approximates Table IV's lumped value
    at the paper's operating point.
    """
    spec = orgs.resolve(organization)
    parts = {
        "inter_modulation": (
            EFFECT_BUDGET_DB["inter_modulation"] if spec.inter_modulation else 0.0
        ),
        "cross_weight": (
            EFFECT_BUDGET_DB["cross_weight"] if spec.cross_weight else 0.0
        ),
        "filter_truncation": (
            EFFECT_BUDGET_DB["filter_truncation"] if spec.filter_truncation else 0.0
        ),
        # Propagation beyond the per-ring term already in Eq. 3: scaled by the
        # organization's extra waveguide length.
        "propagation": params.p_si_att_db_per_mm
        * spec.waveguide_length_factor
        * n
        * params.d_mrr_mm,
        # Through-loss differential vs the generic (N-1)+(N-1) terms of Eq.3.
        "through_delta": (spec.through_device_count(n) - 2 * (n - 1))
        * params.p_mrm_obl_db,
    }
    return parts


def lumped_penalty_db(
    organization: Union[str, OrgSpec], params: PhotonicParams
) -> float:
    """The paper's Table IV P_penalty — what Eq. 3 / Table V actually use."""
    return params.penalty_db(organization)
