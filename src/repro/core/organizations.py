"""DPU organizations (paper §III) and their circuit-level properties.

The paper classifies incoherent MRR-based DPUs by the order in which the four
optical-channel manipulation blocks appear:

* **ASMW** — Aggregation, Splitting, Modulation, Weighting
  (Crosslight, DEAP-CNN, Robin, RAMM)
* **MASW** — Modulation, Aggregation, Splitting, Weighting
  (Holylight, Yang, Al-Qadasi, PCNNA, RMAM)
* **SMWA** — Splitting, Modulation, Weighting, Aggregation ("hitless")
  (Hitless, ADEPT, Albireo)

Each organization incurs a different set of crosstalk effects (Table II) and
optical losses (Table III), composing into the per-organization network
penalty ``P_penalty`` of Table IV.  This module encodes those tables
declaratively and provides both the paper's *lumped* penalty (used by Eq. 3 /
Table V) and a *structural* per-effect decomposition used by the circuit-level
analysis benchmark.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

from repro.core.params import PhotonicParams

# Block symbols
SPLIT, AGG, MOD, WEIGHT, SUM = "S", "A", "M", "W", "Sigma"

BLOCK_ORDERS: Dict[str, Tuple[str, ...]] = {
    "ASMW": (AGG, SPLIT, MOD, WEIGHT, SUM),
    "MASW": (MOD, AGG, SPLIT, WEIGHT, SUM),
    "SMWA": (SPLIT, MOD, WEIGHT, AGG, SUM),
}

# Prior-work classification (paper Table I).
PRIOR_WORK: Dict[str, Tuple[str, ...]] = {
    "ASMW": ("Crosslight", "DEAP-CNN", "Robin", "RAMM"),
    "MASW": ("Holylight", "Yang", "Al-Qadasi", "PCNNA", "RMAM"),
    "SMWA": ("Hitless", "ADEPT", "Albireo"),
}


@dataclasses.dataclass(frozen=True)
class CrosstalkProfile:
    """Which crosstalk effects are present (paper Table II)."""

    inter_modulation: bool
    cross_weight: bool
    filter_truncation: bool


@dataclasses.dataclass(frozen=True)
class LossProfile:
    """Qualitative loss levels (paper Table III) + structural device counts."""

    through_loss_level: str      # "high" | "moderate" | "low"
    propagation_loss_level: str  # "high" | "moderate" | "low"
    # Number of out-of-resonance devices traversed by a channel before the
    # BPD, as a function of DPE size N (paper §IV-B1).
    #   ASMW: 2(N-1)   MASW: N   SMWA: 2
    through_devices: str         # formula id: "2(N-1)" | "N" | "2"
    # Relative waveguide-length factor for propagation loss (SMWA uses more,
    # longer waveguides because of its hitless N*M layout; MASW shares one
    # input array).  Multiplies N * d_mrr in the structural model.
    waveguide_length_factor: float


CROSSTALK: Dict[str, CrosstalkProfile] = {
    "ASMW": CrosstalkProfile(True, True, False),
    "MASW": CrosstalkProfile(False, True, True),
    "SMWA": CrosstalkProfile(False, False, True),
}

LOSSES: Dict[str, LossProfile] = {
    "ASMW": LossProfile("high", "moderate", "2(N-1)", 1.0),
    "MASW": LossProfile("moderate", "low", "N", 0.75),
    "SMWA": LossProfile("high", "high", "2", 1.5),
}

# Optimistic per-effect budgets assumed by the paper (§IV-C) when composing
# P_penalty: inter-modulation <= 1 dB, cross-weight <= 3 dB, filter < 0.5 dB.
EFFECT_BUDGET_DB = {
    "inter_modulation": 1.0,
    "cross_weight": 3.0,
    "filter_truncation": 0.5,
}


def through_device_count(organization: str, n: int) -> int:
    """Out-of-resonance devices traversed by one channel (paper §IV-B1)."""
    org = organization.upper()
    if org == "ASMW":
        return 2 * (n - 1)
    if org == "MASW":
        return n
    if org == "SMWA":
        return 2
    raise ValueError(f"unknown organization {organization!r}")


def structural_penalty_db(
    organization: str,
    n: int,
    params: PhotonicParams,
) -> Dict[str, float]:
    """Per-effect penalty decomposition (beyond-paper structural model).

    The paper lumps crosstalk + filter + propagation into ``P_penalty``
    (Table IV).  This reconstructs the composition from the per-effect
    budgets of §IV-C and the structural loss model of §IV-B, so the
    circuit-level analysis benchmark can show *where* each organization's
    penalty comes from.  ``sum(values)`` approximates Table IV's lumped value
    at the paper's operating point.
    """
    org = organization.upper()
    xt = CROSSTALK[org]
    loss = LOSSES[org]
    parts = {
        "inter_modulation": EFFECT_BUDGET_DB["inter_modulation"] if xt.inter_modulation else 0.0,
        "cross_weight": EFFECT_BUDGET_DB["cross_weight"] if xt.cross_weight else 0.0,
        "filter_truncation": EFFECT_BUDGET_DB["filter_truncation"] if xt.filter_truncation else 0.0,
        # Propagation beyond the per-ring term already in Eq. 3: scaled by the
        # organization's extra waveguide length.
        "propagation": params.p_si_att_db_per_mm
        * loss.waveguide_length_factor
        * n
        * params.d_mrr_mm,
        # Through-loss differential vs the generic (N-1)+(N-1) terms of Eq.3.
        "through_delta": (through_device_count(org, n) - 2 * (n - 1))
        * params.p_mrm_obl_db,
    }
    return parts


def lumped_penalty_db(organization: str, params: PhotonicParams) -> float:
    """The paper's Table IV P_penalty — what Eq. 3 / Table V actually use."""
    return params.penalty_db(organization)


ORGANIZATIONS = ("ASMW", "MASW", "SMWA")
