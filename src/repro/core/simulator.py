"""Transaction-level, event-driven simulator of the photonic GEMM
accelerator (paper §V-B: "custom, transaction-level, event-driven
Python-based simulator").

Execution model (output-stationary, batch=1 CNN inference):

* each layer's im2col GEMM is tiled into *weight tiles* — (psum-chunk of the
  k dimension) x (M output columns) — per bit-slice pass;
* a weight tile is programmed onto a DPU's weight MRRs (EO tuning latency),
  then the layer's `rows` input vectors stream through at the symbol rate,
  producing one psum per row per DPE;
* tiles are dispatched to the earliest-free DPU (greedy list scheduling via
  a heap — the transaction/event queue);
* psums funnel through each tile's electronic reduction network
  (Table VI latency/energy); reduction time overlaps streaming and the layer
  completes at max(stream, reduce) + drain;
* layers execute in dependency order (batch=1), energy integrates DAC/ADC
  streaming power, laser + peripheral static power, tuning and reduction
  energy, and eDRAM/NoC transfers for psums.

Depthwise convs map one k=9 dot per DPE (an analog DPE cannot share its
summation across independent dots), so large-N DPUs waste N-9 rings there —
the model charges full-DPE occupancy, matching the paper's observation that
psum/utilization effects, not raw N, drive the final FPS ordering.
"""

from __future__ import annotations

import dataclasses
import heapq
from typing import Dict, List

from repro.core.cnn_workloads import WORKLOADS, GemmLayer
from repro.core.perfmodel import AcceleratorConfig
from repro.orgs import ORGANIZATIONS, resolve


@dataclasses.dataclass
class LayerStats:
    name: str
    time_s: float
    stream_s: float
    reduce_s: float
    tune_s: float
    energy_j: float
    psums: int
    tiles_dispatched: int


@dataclasses.dataclass
class SimResult:
    model: str
    config: AcceleratorConfig
    total_time_s: float
    dynamic_energy_j: float
    static_power_w: float
    layers: List[LayerStats]

    @property
    def fps(self) -> float:
        return 1.0 / self.total_time_s

    @property
    def avg_power_w(self) -> float:
        return self.static_power_w + self.dynamic_energy_j / self.total_time_s

    @property
    def fps_per_w(self) -> float:
        return self.fps / self.avg_power_w

    def fps_per_w_per_mm2(self) -> float:
        return self.fps_per_w / self.config.total_area_mm2()


def _simulate_layer(layer: GemmLayer, cfg: AcceleratorConfig) -> LayerStats:
    p = cfg.peripherals
    sym = cfg.symbol_s
    tune = cfg.tune_latency_s  # org-dependent: hitless SMWA = EO, else TO

    if layer.groups == 1:
        chunks = -(-layer.k // cfg.n)
        col_tiles = -(-layer.cols // cfg.m)
        rows = layer.rows
        psums_per_output = chunks * cfg.passes
        outputs = layer.rows * layer.cols
    else:
        # depthwise: each output channel is an independent k-dot; a DPE holds
        # one dot -> M channels per DPU tile-slot (N-9 rings idle).
        chunks = 1
        col_tiles = -(-layer.groups // cfg.m)
        rows = layer.rows
        psums_per_output = cfg.passes
        outputs = layer.rows * layer.groups
    n_tiles = chunks * col_tiles * cfg.passes

    # --- event loop: output-stationary dispatch (paper §V-B) ---------------
    # Each output-column tile is OWNED by one DPU: its psums accumulate
    # locally across the chunks x passes weight tiles, which therefore run
    # *sequentially* on that DPU (an analog DPE cannot merge psums from a
    # sibling DPU without a cross-DPU reduction round-trip).  The serial
    # chain per output tile is ceil(k/N) * passes weight tiles long.
    #
    # Chunked dots additionally pace at the psum-reduction clock: every
    # symbol's psum must round-trip the 320 MHz accumulation FIFO (Table VI
    # reduction network) before the next chunk's contribution can merge, so
    # the effective symbol time is max(1/DR, 3.125 ns) when chunks > 1.
    # Dots that fit one DPE (k <= N) skip the FIFO and stream at full DR —
    # this is what the paper means by "larger N generates less psums which
    # reduces the use of the psum reduction network": at high datarates the
    # fixed reduction clock throttles small-N organizations on every
    # chunked layer, and N shrinks with datarate (Table V), which is why
    # absolute FPS *decreases* with DR for all organizations (Fig. 7a).
    sym_eff = max(sym, p.reduction_network.latency_s) if chunks > 1 else sym
    serial_dur = chunks * cfg.passes * (tune + rows * sym_eff)
    heap = [(0.0, d) for d in range(cfg.dpu_count)]
    heapq.heapify(heap)
    end = 0.0
    busy_s = 0.0
    for _ in range(col_tiles):
        free, d = heapq.heappop(heap)
        fin = free + serial_dur
        busy_s += serial_dur
        end = max(end, fin)
        heapq.heappush(heap, (fin, d))
    stream_s = end

    # --- psum accounting ----------------------------------------------------
    total_psums = outputs * psums_per_output
    reductions = outputs * (psums_per_output - 1) if psums_per_output > 1 else 0
    red_s = (
        (sym_eff - sym) * rows * chunks * cfg.passes if chunks > 1 else 0.0
    )  # throttle attributable to the reduction clock (reported per layer)
    time_s = stream_s + p.reduction_network.latency_s

    # --- energy -------------------------------------------------------------
    stream_energy = busy_s * cfg.streaming_power_w()
    tune_energy = n_tiles * (
        cfg.tune_power_w_per_ring * tune * (
            cfg.n * cfg.m if layer.groups == 1 else cfg.m
        )
    )
    red_energy = (
        reductions * p.reduction_network.power_w * p.reduction_network.latency_s
    )
    # psum + activation movement: eDRAM write/read + bus per psum word
    mem_energy = total_psums * (
        p.edram.power_w * p.edram.latency_s + p.bus.power_w * p.bus.latency_s / cfg.m
    )
    act_energy = outputs * p.activation_unit.power_w * p.activation_unit.latency_s
    energy = stream_energy + tune_energy + red_energy + mem_energy + act_energy

    return LayerStats(
        name=layer.name,
        time_s=time_s,
        stream_s=stream_s,
        reduce_s=red_s,
        tune_s=n_tiles * tune / cfg.dpu_count,
        energy_j=energy,
        psums=total_psums,
        tiles_dispatched=n_tiles,
    )


def simulate(model: str, cfg: AcceleratorConfig) -> SimResult:
    layers = [_simulate_layer(l, cfg) for l in WORKLOADS[model]()]
    total = sum(l.time_s for l in layers)
    energy = sum(l.energy_j for l in layers)
    return SimResult(
        model=model,
        config=cfg,
        total_time_s=total,
        dynamic_energy_j=energy,
        static_power_w=cfg.static_power_w(),
        layers=layers,
    )


def evaluate_all(
    organizations=ORGANIZATIONS,
    datarates=(1, 5, 10),
    models=tuple(WORKLOADS),
    use_paper_operating_points: bool = True,
    platform="SOI",
) -> Dict:
    """Fig. 7 sweep: (org x DR x CNN) -> SimResult.

    ``organizations`` accepts ``str | OrgSpec`` entries; results are keyed
    by the canonical order name.  Unstudied orderings — and any platform
    other than the SOI baseline (Table V *is* an SOI table) — require
    ``use_paper_operating_points=False`` so the operating point comes
    from the calibrated solver on that platform's loss chain.
    """
    from repro import platforms as _platforms

    platform_name = _platforms.resolve(platform).name
    if use_paper_operating_points and platform_name != "SOI":
        raise ValueError(
            f"paper operating points are SOI-only (Table V); pass "
            f"use_paper_operating_points=False to sweep {platform_name!r}"
        )
    out = {}
    for org in organizations:
        name = resolve(org).name
        for dr in datarates:
            cfg = (
                AcceleratorConfig.from_paper(org, dr)
                if use_paper_operating_points
                else AcceleratorConfig.from_scalability(
                    org, dr, platform=platform_name
                )
            )
            for m in models:
                out[(name, dr, m)] = simulate(m, cfg)
    return out
