"""Transaction-level, event-driven simulator of the photonic GEMM
accelerator (paper §V-B: "custom, transaction-level, event-driven
Python-based simulator").

Execution model (output-stationary, batch=1 CNN inference):

* each layer's im2col GEMM is tiled into *weight tiles* — (psum-chunk of the
  k dimension) x (M output columns) — per bit-slice pass;
* a weight tile is programmed onto a DPU's weight MRRs (EO tuning latency),
  then the layer's `rows` input vectors stream through at the symbol rate,
  producing one psum per row per DPE;
* tiles are dispatched to the earliest-free DPU (greedy list scheduling via
  a heap — the transaction/event queue);
* psums funnel through each tile's electronic reduction network
  (Table VI latency/energy); reduction time overlaps streaming and the layer
  completes at max(stream, reduce) + drain;
* layers execute in dependency order (batch=1), energy integrates DAC/ADC
  streaming power, laser + peripheral static power, tuning and reduction
  energy, and eDRAM/NoC transfers for psums.

Depthwise convs map one k=9 dot per DPE (an analog DPE cannot share its
summation across independent dots), so large-N DPUs waste N-9 rings there —
the model charges full-DPE occupancy, matching the paper's observation that
psum/utilization effects, not raw N, drive the final FPS ordering.

Since PR 10 the event loop itself lives in :mod:`repro.mapper`:
``simulate`` *is* the mapper's degenerate schedule
(``MapperOptions.degenerate()`` — batch=1, no replication, no overlap,
layer-at-a-time barriers on one pool) and reproduces the pre-mapper
numbers bit-for-bit (DESIGN.md §16 contract; pinned by
``tests/test_mapper.py``).  Chunked dots pace at the psum-reduction
clock: every symbol's psum must round-trip the 320 MHz accumulation
FIFO (Table VI reduction network) before the next chunk's contribution
can merge, so the effective symbol time is max(1/DR, 3.125 ns) when
chunks > 1 — at high datarates the fixed reduction clock throttles
small-N organizations on every chunked layer, and N shrinks with
datarate (Table V), which is why absolute FPS *decreases* with DR for
all organizations (Fig. 7a).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List

from repro.core.cnn_workloads import WORKLOADS
from repro.core.perfmodel import AcceleratorConfig
from repro.mapper import DpuPool, MapperOptions, WorkloadGraph, map_workload
from repro.orgs import ORGANIZATIONS, resolve


@dataclasses.dataclass
class LayerStats:
    name: str
    time_s: float
    stream_s: float
    reduce_s: float
    tune_s: float
    energy_j: float
    psums: int
    tiles_dispatched: int


@dataclasses.dataclass
class SimResult:
    model: str
    config: AcceleratorConfig
    total_time_s: float
    dynamic_energy_j: float
    static_power_w: float
    layers: List[LayerStats]

    @property
    def fps(self) -> float:
        return 1.0 / self.total_time_s

    @property
    def avg_power_w(self) -> float:
        return self.static_power_w + self.dynamic_energy_j / self.total_time_s

    @property
    def fps_per_w(self) -> float:
        return self.fps / self.avg_power_w

    def fps_per_w_per_mm2(self) -> float:
        return self.fps_per_w / self.config.total_area_mm2()


def simulate(model: str, cfg: AcceleratorConfig) -> SimResult:
    """Batch-1 CNN inference = the mapper's degenerate schedule.

    The layer chain lowers to a :class:`~repro.mapper.WorkloadGraph`, the
    pool is ``cfg``'s own ``dpu_count`` DPUs, and the schedule is
    ``MapperOptions.degenerate()`` — which is contractually bit-for-bit
    the pre-PR-10 event loop (output-stationary greedy dispatch, FIFO-
    paced chunked dots, per-layer barriers).
    """
    graph = WorkloadGraph.from_layers(WORKLOADS[model](), name=model)
    timeline = map_workload(
        graph, DpuPool.from_config(cfg), MapperOptions.degenerate()
    )
    layers = [
        LayerStats(
            name=ns.name,
            time_s=ns.time_s,
            stream_s=ns.stream_s,
            reduce_s=ns.reduce_s,
            tune_s=ns.tune_s,
            energy_j=ns.energy_j,
            psums=ns.psums,
            tiles_dispatched=ns.tiles,
        )
        for ns in timeline.nodes
    ]
    return SimResult(
        model=model,
        config=cfg,
        total_time_s=timeline.makespan_s,
        dynamic_energy_j=timeline.dynamic_energy_j,
        static_power_w=timeline.static_power_w,
        layers=layers,
    )


def evaluate_all(
    organizations=ORGANIZATIONS,
    datarates=(1, 5, 10),
    models=tuple(WORKLOADS),
    use_paper_operating_points: bool = True,
    platform="SOI",
) -> Dict:
    """Fig. 7 sweep: (org x DR x CNN) -> SimResult.

    ``organizations`` accepts ``str | OrgSpec`` entries; results are keyed
    by the canonical order name.  Unstudied orderings — and any platform
    other than the SOI baseline (Table V *is* an SOI table) — require
    ``use_paper_operating_points=False`` so the operating point comes
    from the calibrated solver on that platform's loss chain.
    """
    from repro import platforms as _platforms

    platform_name = _platforms.resolve(platform).name
    if use_paper_operating_points and platform_name != "SOI":
        raise ValueError(
            f"paper operating points are SOI-only (Table V); pass "
            f"use_paper_operating_points=False to sweep {platform_name!r}"
        )
    out = {}
    for org in organizations:
        name = resolve(org).name
        for dr in datarates:
            cfg = (
                AcceleratorConfig.from_paper(org, dr)
                if use_paper_operating_points
                else AcceleratorConfig.from_scalability(
                    org, dr, platform=platform_name
                )
            )
            for m in models:
                out[(name, dr, m)] = simulate(m, cfg)
    return out
