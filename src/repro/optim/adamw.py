"""AdamW with decoupled weight decay, global-norm clipping and LR schedules.

Pure-JAX (no optax).  Optimizer state mirrors the param tree (f32 moments
regardless of param dtype — mixed-precision training with bf16 params keeps
master statistics in f32).
"""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: Optional[float] = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    schedule: str = "cosine"  # cosine | linear | constant


class OptState(NamedTuple):
    step: jax.Array
    mu: Any
    nu: Any


def init(params: Any) -> OptState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return OptState(jnp.zeros((), jnp.int32), zeros, jax.tree.map(jnp.copy, zeros))


def schedule_lr(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    if cfg.schedule == "constant":
        decay = 1.0
    elif cfg.schedule == "linear":
        frac = jnp.clip(
            (step - cfg.warmup_steps) / max(cfg.total_steps - cfg.warmup_steps, 1),
            0.0, 1.0,
        )
        decay = 1.0 - 0.9 * frac
    else:  # cosine
        frac = jnp.clip(
            (step - cfg.warmup_steps) / max(cfg.total_steps - cfg.warmup_steps, 1),
            0.0, 1.0,
        )
        decay = 0.1 + 0.45 * (1.0 + jnp.cos(jnp.pi * frac))
    return cfg.lr * warm * decay


def global_norm(tree: Any) -> jax.Array:
    return jnp.sqrt(
        sum(jnp.sum(x.astype(jnp.float32) ** 2) for x in jax.tree.leaves(tree))
    )


def update(
    cfg: AdamWConfig,
    params: Any,
    grads: Any,
    state: OptState,
) -> Tuple[Any, OptState, dict]:
    """One AdamW step. Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    if cfg.clip_norm is not None:
        scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
        grads = jax.tree.map(lambda g: g * scale.astype(g.dtype), grads)

    step = state.step + 1
    lr = schedule_lr(cfg, step)
    b1, b2 = cfg.beta1, cfg.beta2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, mu, nu):
        g32 = g.astype(jnp.float32)
        mu = b1 * mu + (1 - b1) * g32
        nu = b2 * nu + (1 - b2) * g32 * g32
        mhat = mu / bc1
        vhat = nu / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(
            jnp.float32
        )
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), mu, nu

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_mu = treedef.flatten_up_to(state.mu)
    flat_nu = treedef.flatten_up_to(state.nu)
    out = [upd(p, g, m, n) for p, g, m, n in zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_mu = treedef.unflatten([o[1] for o in out])
    new_nu = treedef.unflatten([o[2] for o in out])
    return new_p, OptState(step, new_mu, new_nu), {"lr": lr, "grad_norm": gnorm}


def opt_state_axes(param_axes: Any) -> "OptState":
    """Logical axes for the optimizer state (moments shard like params)."""
    return OptState((), param_axes, param_axes)
