"""Gradient compression for cross-pod data parallelism.

``compressed_psum(tree, axis_name)`` — int8-quantized all-reduce for use
inside ``shard_map``: each leaf is symmetric-quantized to int8 with an f32
per-leaf scale, summed in int32 across the axis (exact given int8 inputs),
and dequantized with the psum of scales' max.  Halves (vs bf16) / quarters
(vs f32) the wire bytes of the slow inter-pod gradient reduction at a
bounded quantization error (<= 1/254 of each leaf's max-abs per shard).

``with_error_feedback`` keeps the per-step quantization residual and adds it
to the next step's gradients (1-bit-Adam style error feedback), making the
compression unbiased over time.
"""

from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

from repro import compat


def _quantize(g: jax.Array) -> Tuple[jax.Array, jax.Array]:
    if g.size == 0:  # zero-layer ladder variants produce (0, ...) leaves
        return g.astype(jnp.int8), jnp.ones((), jnp.float32)
    amax = jnp.max(jnp.abs(g)).astype(jnp.float32)
    scale = jnp.maximum(amax, 1e-12) * (1.0 / 127.0)
    q = jnp.clip(jnp.round(g.astype(jnp.float32) / scale), -127, 127).astype(jnp.int8)
    return q, scale


def compressed_psum(tree: Any, axis_name: str) -> Any:
    """int8 all-reduce of a pytree over a shard_map axis."""

    def leaf(g):
        q, scale = _quantize(g)
        # max-scale across the axis so all shards dequantize consistently;
        # requantize local values at the shared scale, then int32-sum.
        scale_max = jax.lax.pmax(scale, axis_name)
        q2 = jnp.clip(
            jnp.round(g.astype(jnp.float32) / scale_max), -127, 127
        ).astype(jnp.int8)
        total = jax.lax.psum(q2.astype(jnp.int32), axis_name)
        return (total.astype(jnp.float32) * scale_max).astype(g.dtype)

    return compat.tree_map(leaf, tree)


def ring_int8_allreduce(tree: Any, axis_name) -> Any:
    """All-reduce with int8 WIRE bytes: a reduce-scatter ring of quantized
    chunks (ppermute int8 payloads, f32 local accumulation) followed by an
    int8 all-gather ring.  2(n-1) steps; wire = 2x int8 vs 2x bf16/f32 for a
    plain psum — the half-traffic variant XLA cannot express with psum
    (int8 summands overflow; accumulation must stay local).

    Requantization error per hop is bounded by the per-chunk scale; for
    gradient averaging this is the standard int8-ring trade (error feedback
    available via with_error_feedback)."""
    n = compat.axis_size(axis_name)
    if n == 1:
        return tree
    idx = jax.lax.axis_index(axis_name)
    fwd = [(i, (i + 1) % n) for i in range(n)]

    def leaf(g):
        if g.size == 0:
            return g
        shape = g.shape
        flat = g.astype(jnp.float32).reshape(-1)
        pad = (-flat.size) % n
        flat = jnp.pad(flat, (0, pad))
        chunks = flat.reshape(n, -1)  # chunk c owned by device c

        # reduce-scatter ring: at step s, device d sends chunk (d - s) and
        # accumulates into chunk (d - s - 1).
        def rs_step(s, carry):
            acc = carry  # (n, chunk) f32 local view
            send_idx = (idx - s) % n
            q, scale = _quantize(acc[send_idx])
            q_recv = jax.lax.ppermute(q, axis_name, fwd)
            s_recv = jax.lax.ppermute(scale, axis_name, fwd)
            recv_idx = (idx - s - 1) % n
            acc = acc.at[recv_idx].add(q_recv.astype(jnp.float32) * s_recv)
            return acc

        acc = jax.lax.fori_loop(0, n - 1, rs_step, chunks)
        # device d now owns the fully reduced chunk (d + 1) % n

        # all-gather ring: at step t, device d sends chunk (d+1-t) (complete
        # by induction) and overwrites chunk (d-t) with its neighbour's.
        def ag_step(t, carry):
            acc = carry
            send_idx = (idx + 1 - t) % n
            q, scale = _quantize(acc[send_idx])
            q_recv = jax.lax.ppermute(q, axis_name, fwd)
            s_recv = jax.lax.ppermute(scale, axis_name, fwd)
            recv_idx = (idx - t) % n
            acc = acc.at[recv_idx].set(q_recv.astype(jnp.float32) * s_recv)
            return acc

        acc = jax.lax.fori_loop(0, n - 1, ag_step, acc)
        out = acc.reshape(-1)
        if pad:
            out = out[: g.size]
        return out.reshape(shape).astype(g.dtype)

    return compat.tree_map(leaf, tree)


def quantize_dequantize(tree: Any) -> Tuple[Any, Any]:
    """(compressed value, residual) per leaf — error-feedback building block."""

    def leaf(g):
        q, scale = _quantize(g)
        deq = (q.astype(jnp.float32) * scale).astype(g.dtype)
        return deq, (g - deq)

    pairs = compat.tree_map(leaf, tree)
    comp = compat.tree_map(
        lambda p: p[0], pairs, is_leaf=lambda x: isinstance(x, tuple)
    )
    resid = compat.tree_map(
        lambda p: p[1], pairs, is_leaf=lambda x: isinstance(x, tuple)
    )
    return comp, resid


def with_error_feedback(grads: Any, residual: Any) -> Tuple[Any, Any]:
    """Add carried residual, compress, return (compressed, new residual)."""
    fed = compat.tree_map(lambda g, r: g + r.astype(g.dtype), grads, residual)
    return quantize_dequantize(fed)
