"""Workload-graph front-end of the mapper (DESIGN.md §16).

Every schedulable workload — the paper's CNN layer tables
(:mod:`repro.core.cnn_workloads`) *and* the LM configs' per-layer GEMM
sites — lowers to one uniform representation: a DAG of
:class:`GemmNode`\\ s.  A node is an im2col-style integer GEMM (``rows x
k x cols``, ``groups`` for depthwise); a dependency edge means the
producer's outputs feed the consumer's activations, so the consumer
cannot start streaming before the producer drains.

The graph is *batch-free*: ``rows`` counts the output positions of ONE
inference (one image, one sequence).  Input batching is a scheduling
decision — :class:`repro.mapper.mapping.MapperOptions.batch` multiplies
the streamed rows at tiling time, which is exactly how the hardware
amortizes a programmed weight tile over many inputs.

Lowering rules:

* ``from_layers`` — a CNN layer list becomes a dependency *chain* (the
  paper's batch-1 inference order; branch-level parallelism inside
  inception-style modules is not reconstructed from the flat table).
* ``from_model_config`` — an LM :class:`~repro.models.common.ModelConfig`
  becomes per-layer GEMM sites with the real intra-layer parallelism:
  ``attn.wq``/``wk``/``wv`` (or the MLA ``wq``/``wdkv`` → ``wuk``/``wuv``
  chain) fan out from the layer input, join at ``attn.wo``, feed the FFN
  (fused SwiGLU ``ffn.wi`` → ``ffn.wo``; MoE prices the *active* experts
  per token and keeps the router digital, matching the engine's default
  site policy), and the last layer feeds ``lm_head``.  Node names carry
  the dotted site (``L3.attn.wq``) so timelines read like engine traces.
"""

from __future__ import annotations

import dataclasses
from typing import TYPE_CHECKING, Dict, Iterable, List, Optional, Sequence, Tuple

from repro.core.cnn_workloads import GemmLayer

if TYPE_CHECKING:  # annotation only — keeps core/mapper import-light
    from repro.models.common import ModelConfig


@dataclasses.dataclass(frozen=True)
class GemmNode:
    """One tiled-GEMM site of a workload DAG (batch-free, see module doc)."""

    name: str
    rows: int      # output positions per inference (im2col rows / tokens)
    k: int         # dot-product length per group
    cols: int      # output channels per group
    groups: int = 1
    deps: Tuple[str, ...] = ()
    site: Optional[str] = None  # dotted engine site name, when lowered from an LM

    def __post_init__(self):
        if min(self.rows, self.k, self.cols, self.groups) < 1:
            raise ValueError(f"non-positive GEMM dims in node {self.name!r}: {self}")

    @property
    def dots(self) -> int:
        return self.rows * self.cols * self.groups

    @property
    def macs(self) -> int:
        return self.dots * self.k


class WorkloadGraph:
    """A validated DAG of :class:`GemmNode`\\ s, iterated in topological order."""

    def __init__(self, name: str, nodes: Sequence[GemmNode]):
        self.name = name
        self._nodes: Dict[str, GemmNode] = {}
        for node in nodes:
            if node.name in self._nodes:
                raise ValueError(f"duplicate node name {node.name!r} in {name!r}")
            self._nodes[node.name] = node
        for node in nodes:
            for dep in node.deps:
                if dep not in self._nodes:
                    raise ValueError(
                        f"node {node.name!r} depends on unknown node {dep!r}"
                    )
        self._topo = self._toposort()

    # -- construction --------------------------------------------------------
    @classmethod
    def from_layers(
        cls, layers: Iterable[GemmLayer], name: str = "cnn"
    ) -> "WorkloadGraph":
        """A CNN layer list as a dependency chain (paper §V-B batch-1 order)."""
        nodes: List[GemmNode] = []
        prev: Tuple[str, ...] = ()
        for layer in layers:
            nodes.append(
                GemmNode(
                    name=layer.name,
                    rows=layer.rows,
                    k=layer.k,
                    cols=layer.cols,
                    groups=layer.groups,
                    deps=prev,
                )
            )
            prev = (layer.name,)
        return cls(name, nodes)

    @classmethod
    def from_model_config(
        cls,
        cfg: "ModelConfig",
        *,
        seq_len: int,
        name: Optional[str] = None,
    ) -> "WorkloadGraph":
        """Lower an LM config's per-layer weight-GEMM sites to a DAG.

        Covers the dense/GQA, MoE (active experts only; the router stays
        digital, mirroring the engine's default ``photonic_exclude``) and
        MLA attention families.  Encoder-decoder, SSM and hybrid configs
        have recurrent/scan GEMM structure the tile mapper does not model
        yet and are rejected eagerly.
        """
        if (
            cfg.encoder_decoder
            or cfg.attn_every
            or cfg.slstm_every
            or cfg.cross_attn_every
        ):
            raise NotImplementedError(
                f"cannot lower family {cfg.family!r} ({cfg.arch_id}): "
                "encoder-decoder / hybrid / cross-attention GEMM structure "
                "is not mapper-schedulable yet"
            )
        if cfg.family in ("ssm", "audio"):
            raise NotImplementedError(
                f"cannot lower family {cfg.family!r} ({cfg.arch_id})"
            )
        head_dim = cfg.head_dim or cfg.d_model // cfg.num_heads
        d = cfg.d_model
        t = seq_len
        nodes: List[GemmNode] = []
        prev: Tuple[str, ...] = ()

        def add(nm: str, rows: int, k: int, cols: int, deps: Tuple[str, ...]):
            site = nm.split(".", 1)[1] if "." in nm else nm
            nodes.append(
                GemmNode(name=nm, rows=rows, k=k, cols=cols, deps=deps, site=site)
            )

        for i in range(cfg.num_layers):
            p = f"L{i}"
            if cfg.mla:
                q_cols = cfg.num_heads * (cfg.qk_nope_head_dim + cfg.qk_rope_head_dim)
                add(f"{p}.attn.wq", t, d, q_cols, prev)
                kv_cols = cfg.kv_lora_rank + cfg.qk_rope_head_dim
                add(f"{p}.attn.wdkv", t, d, kv_cols, prev)
                add(
                    f"{p}.attn.wuk", t, cfg.kv_lora_rank,
                    cfg.num_heads * cfg.qk_nope_head_dim, (f"{p}.attn.wdkv",),
                )
                add(
                    f"{p}.attn.wuv", t, cfg.kv_lora_rank,
                    cfg.num_heads * cfg.v_head_dim, (f"{p}.attn.wdkv",),
                )
                add(
                    f"{p}.attn.wo", t, cfg.num_heads * cfg.v_head_dim, d,
                    (f"{p}.attn.wq", f"{p}.attn.wuk", f"{p}.attn.wuv"),
                )
            else:
                add(f"{p}.attn.wq", t, d, cfg.num_heads * head_dim, prev)
                add(f"{p}.attn.wk", t, d, cfg.num_kv_heads * head_dim, prev)
                add(f"{p}.attn.wv", t, d, cfg.num_kv_heads * head_dim, prev)
                add(
                    f"{p}.attn.wo", t, cfg.num_heads * head_dim, d,
                    (f"{p}.attn.wq", f"{p}.attn.wk", f"{p}.attn.wv"),
                )
            attn_out = (f"{p}.attn.wo",)

            wi_mult = 2 if cfg.ffn_act == "swiglu" else 1  # fused SwiGLU bank
            if cfg.num_experts > 0:
                # Active experts only: each token streams through its top-k
                # routed experts, so the streamed rows are t * top_k per
                # expert bank (capacity effects ignored — the mapper prices
                # the GEMM work, not the dispatch).  Router: digital.
                f = cfg.moe_d_ff or cfg.d_ff
                rows = t * cfg.num_experts_per_tok
                add(f"{p}.ffn.wi", rows, d, wi_mult * f, attn_out)
                add(f"{p}.ffn.wo", rows, f, d, (f"{p}.ffn.wi",))
                layer_out = [f"{p}.ffn.wo"]
                if cfg.num_shared_experts:
                    fs = cfg.num_shared_experts * (cfg.moe_d_ff or cfg.d_ff)
                    add(f"{p}.ffn.shared.wi", t, d, wi_mult * fs, attn_out)
                    add(f"{p}.ffn.shared.wo", t, fs, d, (f"{p}.ffn.shared.wi",))
                    layer_out.append(f"{p}.ffn.shared.wo")
                prev = tuple(layer_out)
            else:
                add(f"{p}.ffn.wi", t, d, wi_mult * cfg.d_ff, attn_out)
                add(f"{p}.ffn.wo", t, cfg.d_ff, d, (f"{p}.ffn.wi",))
                prev = (f"{p}.ffn.wo",)

        add("lm_head", t, d, cfg.vocab_size, prev)
        return cls(name or cfg.arch_id, nodes)

    # -- access --------------------------------------------------------------
    @property
    def nodes(self) -> Tuple[GemmNode, ...]:
        return tuple(self._nodes.values())

    def __len__(self) -> int:
        return len(self._nodes)

    def __getitem__(self, name: str) -> GemmNode:
        return self._nodes[name]

    def topological(self) -> Tuple[GemmNode, ...]:
        """Nodes in a dependency-respecting order (stable: insertion order
        breaks ties), validated acyclic at construction."""
        return self._topo

    @property
    def total_macs(self) -> int:
        return sum(n.macs for n in self._nodes.values())

    def _toposort(self) -> Tuple[GemmNode, ...]:
        indeg = {n: len(self._nodes[n].deps) for n in self._nodes}
        consumers: Dict[str, List[str]] = {n: [] for n in self._nodes}
        for node in self._nodes.values():
            for dep in node.deps:
                consumers[dep].append(node.name)
        order: List[GemmNode] = []
        ready = [n for n in self._nodes if indeg[n] == 0]  # insertion-ordered
        while ready:
            nm = ready.pop(0)
            order.append(self._nodes[nm])
            for c in consumers[nm]:
                indeg[c] -= 1
                if indeg[c] == 0:
                    ready.append(c)
        if len(order) != len(self._nodes):
            cyclic = sorted(n for n in self._nodes if indeg[n] > 0)
            raise ValueError(f"dependency cycle through {cyclic}")
        return tuple(order)

    def __repr__(self):
        return (
            f"WorkloadGraph({self.name!r}, nodes={len(self)}, "
            f"macs={self.total_macs / 1e9:.2f}G)"
        )
