"""Tile mapper: place a workload graph's GEMM tiles onto a DPU pool.

The mapper owns every *decision* of the schedule; the event engine
(:mod:`repro.mapper.timeline`) merely executes it.  Per node it fixes:

* the weight tiling — ``ceil(k/N)`` psum chunks x ``ceil(cols/M)`` output
  column tiles x ``passes`` bit-slice passes (depthwise: one k-dot per
  DPE, ``M`` channels per tile), identical to the paper's §V-B
  decomposition;
* the *effective symbol time* — chunked dots pace at the 320 MHz psum
  FIFO unless :attr:`MapperOptions.overlap_reduce` double-buffers the
  digital accumulation behind the analog stream;
* the *replication factor* — how many DPUs co-serve one output-column
  tile by splitting the streamed rows.  Each replica re-programs the
  full weight-tile chain, so replication is priced with the
  weight-stationary reprogram cost the engine's prepacking already
  models (:func:`repro.photonic.packing.reprogram_cost`, surfaced as
  :meth:`AcceleratorConfig.weight_reprogram_cost`): a replica is only
  admitted while its streamed time covers
  ``reprogram_amortization x`` its tuning time.

Degenerate contract (DESIGN.md §16): ``MapperOptions.degenerate()`` —
batch=1, no replication, no overlap, per-node barriers on a single
:meth:`DpuPool.from_config` pool — reproduces
:func:`repro.core.simulator.simulate` bit-for-bit; the expressions below
are spelled exactly like the legacy event loop's so every float rounds
identically.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

from repro.core.perfmodel import AcceleratorConfig, area_matched_count
from repro.mapper.workload import GemmNode


@dataclasses.dataclass(frozen=True)
class DpuPool:
    """A pool of identical DPUs executing one accelerator configuration.

    The stored config is normalized so ``cfg.dpu_count == size`` — the
    power/area model and the scheduler always describe the same silicon.
    """

    cfg: AcceleratorConfig

    def __post_init__(self):
        if self.cfg.dpu_count < 1:
            raise ValueError(f"empty DPU pool: dpu_count={self.cfg.dpu_count}")

    @property
    def size(self) -> int:
        return self.cfg.dpu_count

    @classmethod
    def from_config(
        cls, cfg: AcceleratorConfig, size: Optional[int] = None
    ) -> "DpuPool":
        """Pool over ``cfg``'s DPUs (``size`` overrides ``dpu_count``)."""
        if size is not None and size != cfg.dpu_count:
            cfg = dataclasses.replace(cfg, dpu_count=size)
        return cls(cfg)

    @classmethod
    def area_matched(
        cls,
        organization,
        datarate_gs: float,
        *,
        bits: int = 4,
        platform="SOI",
        target_area_mm2: Optional[float] = None,
    ) -> "DpuPool":
        """Pool sized to the paper's silicon budget: the calibrated
        operating point for ``organization`` on ``platform``, with the DPU
        count area-matched to ``target_area_mm2`` (default: the paper's
        SOI SMWA configuration at this datarate — the same equal-area
        construction as Fig. 7 / ``benchmarks/org_design_space.py``)."""
        if target_area_mm2 is None:
            target_area_mm2 = AcceleratorConfig.from_paper(
                "SMWA", datarate_gs
            ).total_area_mm2()
        cfg = AcceleratorConfig.from_scalability(
            organization, datarate_gs, bits=bits, platform=platform
        )
        return cls.from_config(cfg, size=area_matched_count(cfg, target_area_mm2))


@dataclasses.dataclass(frozen=True)
class MapperOptions:
    """Scheduling policy knobs (defaults = the full scheduler).

    ``batch``                  — inferences streamed per programmed tile
                                 (input batching; rows multiply).
    ``replicate``              — split a tile's rows over idle DPUs
                                 (priced by reprogram amortization).
    ``overlap_reduce``         — double-buffer the digital psum
                                 accumulation behind the analog stream
                                 (chunked dots stop pacing at the FIFO).
    ``cross_layer``            — schedule the DAG with dependency edges
                                 instead of per-node barriers.
    ``reprogram_amortization`` — minimum streamed-time : reprogram-time
                                 ratio a replica must sustain (>= 1 keeps
                                 every admitted DPU streaming at least as
                                 long as it tunes).
    """

    batch: int = 1
    replicate: bool = True
    overlap_reduce: bool = True
    cross_layer: bool = True
    reprogram_amortization: float = 1.0

    def __post_init__(self):
        if self.batch < 1:
            raise ValueError(f"batch must be >= 1, got {self.batch}")
        if self.reprogram_amortization <= 0.0:
            raise ValueError(
                f"reprogram_amortization must be > 0, "
                f"got {self.reprogram_amortization}"
            )

    @classmethod
    def degenerate(cls) -> "MapperOptions":
        """The legacy schedule: batch-1, one tile chain per column tile,
        FIFO-paced chunked dots, layer-at-a-time barriers.  Contract:
        bit-for-bit equal to ``repro.core.simulator.simulate``."""
        return cls(
            batch=1, replicate=False, overlap_reduce=False, cross_layer=False
        )


@dataclasses.dataclass(frozen=True)
class NodeTiling:
    """The mapper's placement decision for one GEMM node."""

    node: GemmNode
    chunks: int                  # psum chunks: ceil(k / N)
    col_tiles: int               # output column tiles: ceil(cols / M)
    passes: int                  # bit-slice pass pairs
    replicas: int                # DPUs co-serving one column tile
    row_blocks: Tuple[int, ...]  # streamed rows per replica (sums to rows)
    sym_eff: float               # effective symbol time (FIFO pacing)
    tune_s: float                # reprogram latency per weight tile
    tile_energy_j: float         # reprogram energy per weight tile
    outputs: int                 # output words (incl. batch)
    psums_per_output: int

    @property
    def tiles(self) -> int:
        """Weight tiles programmed (chunks x col_tiles x passes x replicas)."""
        return self.chunks * self.col_tiles * self.passes * self.replicas

    @property
    def chains(self) -> int:
        """Independent serial tile chains dispatched to the pool."""
        return self.col_tiles * self.replicas

    def chain_duration_s(self, rows_block: int) -> float:
        """Serial duration of one column tile's chain on one DPU: program +
        stream, for every chunk of every pass (spelled exactly like the
        legacy simulator's ``serial_dur`` — bitwise contract)."""
        return self.chunks * self.passes * (self.tune_s + rows_block * self.sym_eff)


def _split_rows(rows: int, replicas: int) -> Tuple[int, ...]:
    base, rem = divmod(rows, replicas)
    return tuple(base + 1 if i < rem else base for i in range(replicas))


def _choose_replicas(
    rows_total: int,
    col_tiles: int,
    pool_size: int,
    tune_s: float,
    sym_eff: float,
    options: MapperOptions,
) -> int:
    """Row-split replication factor, priced by reprogram amortization.

    Replicas beyond ``pool_size // col_tiles`` would queue behind the
    first wave (no throughput win); replicas whose row block streams for
    less than ``reprogram_amortization x tune_s`` spend more time
    re-programming weights than computing — the weight-stationary cost
    model says they are not worth their laser power.
    """
    if not options.replicate or rows_total <= 1:
        return 1
    cap = max(1, pool_size // max(col_tiles, 1))
    cap = min(cap, rows_total)
    if tune_s > 0.0 and cap > 1:
        amort = int(
            rows_total * sym_eff / (options.reprogram_amortization * tune_s)
        )
        cap = min(cap, max(1, amort))
    return cap


def tile_node(
    node: GemmNode, cfg: AcceleratorConfig, pool_size: int, options: MapperOptions
) -> NodeTiling:
    """Tile one GEMM node for ``cfg`` and fix its placement decision."""
    p = cfg.peripherals
    sym = cfg.symbol_s
    rows_total = node.rows * options.batch

    if node.groups == 1:
        chunks = -(-node.k // cfg.n)
        col_tiles = -(-node.cols // cfg.m)
        psums_per_output = chunks * cfg.passes
        outputs = rows_total * node.cols
    else:
        # Depthwise: each output channel is an independent k-dot; a DPE
        # holds one dot -> M channels per DPU tile-slot (N-9 rings idle).
        chunks = 1
        col_tiles = -(-node.groups // cfg.m)
        psums_per_output = cfg.passes
        outputs = rows_total * node.groups

    # Chunked dots pace at the psum-reduction FIFO clock unless the
    # digital accumulation is double-buffered behind the analog stream.
    if chunks > 1 and not options.overlap_reduce:
        sym_eff = max(sym, p.reduction_network.latency_s)
    else:
        sym_eff = sym

    cost = cfg.weight_reprogram_cost(groups=node.groups)
    replicas = _choose_replicas(
        rows_total, col_tiles, pool_size, cost.latency_s, sym_eff, options
    )
    return NodeTiling(
        node=node,
        chunks=chunks,
        col_tiles=col_tiles,
        passes=cfg.passes,
        replicas=replicas,
        row_blocks=_split_rows(rows_total, replicas),
        sym_eff=sym_eff,
        tune_s=cost.latency_s,
        tile_energy_j=cost.energy_j,
        outputs=outputs,
        psums_per_output=psums_per_output,
    )
