"""Event-timeline simulator: execute a mapped workload on a DPU pool.

Two scheduling regimes share the per-node accounting:

* **barrier** (``options.cross_layer=False``) — nodes run one at a time
  in topological order; each node is scheduled in its own local clock
  (greedy earliest-free-DPU dispatch over the pool, exactly the paper's
  §V-B event loop) and the makespan is the sum of node times.  With
  ``MapperOptions.degenerate()`` this path re-derives
  ``repro.core.simulator.simulate`` bit-for-bit — every expression below
  is spelled like the legacy ``_simulate_layer`` so the floats round
  identically (DESIGN.md §16 contract).
* **dag** (``options.cross_layer=True``) — one global event clock; a
  node's chains become dispatchable when every producer has drained, so
  parallel branches (inception-style columns, attention QKV fan-out,
  shared-expert banks) and successive batches genuinely overlap and the
  extra DPUs of cheap organizations can be fed.

Energy accounting is identical in both regimes (same component formulas,
applied to the same tile counts); only *when* tiles run differs.  Static
power integrates over the makespan, which is how idle silicon — the
batch-1 killer of area-matched many-DPU organizations — prices itself.
"""

from __future__ import annotations

import dataclasses
import heapq
from typing import Dict, List, Optional, Tuple

from repro.core.perfmodel import AcceleratorConfig
from repro.mapper.mapping import DpuPool, MapperOptions, NodeTiling, tile_node
from repro.mapper.workload import WorkloadGraph


@dataclasses.dataclass(frozen=True)
class NodeSchedule:
    """Realized schedule of one GEMM node."""

    name: str
    site: Optional[str]
    start_s: float      # earliest chain dispatch (cumulative offset in barrier mode)
    time_s: float       # stream + drain latency attributed to this node
    stream_s: float     # last chain drain (node-local clock in barrier mode)
    reduce_s: float     # stream throttle attributable to the psum FIFO clock
    tune_s: float       # pool-amortized reprogram latency
    energy_j: float
    psums: int
    tiles: int          # weight tiles programmed
    chains: int         # serial tile chains dispatched
    replicas: int       # row-split DPUs per column tile


@dataclasses.dataclass(frozen=True)
class Timeline:
    """Per-DPU, per-node realized schedule of one workload on one pool."""

    workload: str
    pool: DpuPool
    options: MapperOptions
    nodes: Tuple[NodeSchedule, ...]
    makespan_s: float
    dynamic_energy_j: float
    static_power_w: float
    busy_per_dpu: Tuple[float, ...]

    # -- derived metrics (the ONLY blessed FPS/energy aggregation surface;
    # rule RPR010 keeps ad-hoc re-derivations out of the tree) ------------
    @property
    def batch(self) -> int:
        return self.options.batch

    @property
    def fps(self) -> float:
        """Inferences per second (batch inferences per makespan)."""
        return self.options.batch / self.makespan_s

    @property
    def avg_power_w(self) -> float:
        return self.static_power_w + self.dynamic_energy_j / self.makespan_s

    @property
    def fps_per_w(self) -> float:
        return self.fps / self.avg_power_w

    @property
    def energy_per_inference_j(self) -> float:
        return self.dynamic_energy_j / self.options.batch

    @property
    def utilization(self) -> Tuple[float, ...]:
        return tuple(b / self.makespan_s for b in self.busy_per_dpu)

    @property
    def mean_utilization(self) -> float:
        return sum(self.busy_per_dpu) / (self.makespan_s * len(self.busy_per_dpu))

    def to_dict(self) -> dict:
        """JSON-serializable record (the benchmark/CI timeline artifact)."""
        util = self.utilization
        return {
            "workload": self.workload,
            "organization": self.pool.cfg.organization,
            "platform": self.pool.cfg.platform,
            "datarate_gs": self.pool.cfg.datarate_gs,
            "n": self.pool.cfg.n,
            "pool_size": self.pool.size,
            "options": dataclasses.asdict(self.options),
            "makespan_s": self.makespan_s,
            "fps": self.fps,
            "fps_per_w": self.fps_per_w,
            "avg_power_w": self.avg_power_w,
            "dynamic_energy_j": self.dynamic_energy_j,
            "static_power_w": self.static_power_w,
            "mean_utilization": self.mean_utilization,
            "utilization": [round(u, 6) for u in util],
            "nodes": [
                {
                    "name": ns.name,
                    "site": ns.site,
                    "start_s": ns.start_s,
                    "time_s": ns.time_s,
                    "energy_j": ns.energy_j,
                    "tiles": ns.tiles,
                    "chains": ns.chains,
                    "replicas": ns.replicas,
                }
                for ns in self.nodes
            ],
        }

    def utilization_table(self, max_rows: int = 16, width: int = 40) -> str:
        """Human-readable per-DPU utilization table (example/driver output)."""
        util = self.utilization
        lines = [
            f"pool: {self.pool.size} x {self.pool.cfg.organization} "
            f"N={self.pool.cfg.n} ({self.pool.cfg.platform}, "
            f"{self.pool.cfg.datarate_gs:g} GS/s)   batch={self.batch}",
            f"makespan {self.makespan_s * 1e3:.3f} ms   fps {self.fps:.1f}   "
            f"fps/W {self.fps_per_w:.3f}   mean util {self.mean_utilization:.1%}",
        ]
        step = max(1, len(util) // max_rows)
        for d0 in range(0, len(util), step):
            group = util[d0 : d0 + step]
            u = sum(group) / len(group)
            bar = "#" * int(u * width)
            d1 = min(d0 + step, len(util)) - 1
            label = f"dpu {d0}" if step == 1 else f"dpu {d0}-{d1}"
            lines.append(f"  {label:>12}  {u:7.1%}  |{bar:<{width}}|")
        return "\n".join(lines)


def map_workload(
    graph: WorkloadGraph,
    pool: DpuPool,
    options: MapperOptions = MapperOptions(),
) -> Timeline:
    """Map ``graph`` onto ``pool`` and simulate the event timeline."""
    cfg = pool.cfg
    order = graph.topological()
    tilings = {node.name: tile_node(node, cfg, pool.size, options) for node in order}
    if options.cross_layer:
        return _run_dag(graph, pool, options, tilings)
    return _run_barrier(graph, pool, options, tilings)


# ---------------------------------------------------------------------------
# Shared per-node accounting (bitwise-pinned against the legacy simulator)
# ---------------------------------------------------------------------------
def _node_energy_j(tl: NodeTiling, cfg: AcceleratorConfig, busy_s: float) -> float:
    """Dynamic energy of one node, spelled exactly like the legacy layer
    accounting (association order matters: bitwise contract)."""
    p = cfg.peripherals
    stream_energy = busy_s * cfg.streaming_power_w()
    tune_energy = tl.tiles * tl.tile_energy_j
    reductions = (
        tl.outputs * (tl.psums_per_output - 1) if tl.psums_per_output > 1 else 0
    )
    red_energy = (
        reductions * p.reduction_network.power_w * p.reduction_network.latency_s
    )
    total_psums = tl.outputs * tl.psums_per_output
    mem_energy = total_psums * (
        p.edram.power_w * p.edram.latency_s + p.bus.power_w * p.bus.latency_s / cfg.m
    )
    act_energy = tl.outputs * p.activation_unit.power_w * p.activation_unit.latency_s
    return stream_energy + tune_energy + red_energy + mem_energy + act_energy


def _node_reduce_s(tl: NodeTiling, cfg: AcceleratorConfig) -> float:
    """Stream throttle attributable to the psum FIFO clock (report-only)."""
    if tl.chunks <= 1:
        return 0.0
    rows_total = sum(tl.row_blocks)
    return (tl.sym_eff - cfg.symbol_s) * rows_total * tl.chunks * tl.passes


# ---------------------------------------------------------------------------
# Barrier regime — node-local clocks, makespan = sum of node times
# ---------------------------------------------------------------------------
def _run_barrier(
    graph: WorkloadGraph,
    pool: DpuPool,
    options: MapperOptions,
    tilings: Dict[str, NodeTiling],
) -> Timeline:
    cfg = pool.cfg
    p = cfg.peripherals
    busy_per_dpu = [0.0] * pool.size
    cursor = 0.0  # sum of node times so far (legacy: sum(l.time_s))
    energy_total = 0.0
    scheds: List[NodeSchedule] = []

    for node in graph.topological():
        tl = tilings[node.name]
        heap = [(0.0, d) for d in range(pool.size)]
        heapq.heapify(heap)
        end = 0.0
        busy_s = 0.0
        for rows_block in tl.row_blocks:
            dur = tl.chain_duration_s(rows_block)
            for _ in range(tl.col_tiles):
                free, d = heapq.heappop(heap)
                fin = free + dur
                busy_s += dur
                busy_per_dpu[d] += dur
                end = max(end, fin)
                heapq.heappush(heap, (fin, d))
        stream_s = end
        time_s = stream_s + p.reduction_network.latency_s
        energy = _node_energy_j(tl, cfg, busy_s)
        scheds.append(
            NodeSchedule(
                name=node.name,
                site=node.site,
                start_s=cursor,
                time_s=time_s,
                stream_s=stream_s,
                reduce_s=_node_reduce_s(tl, cfg),
                tune_s=tl.tiles * tl.tune_s / pool.size,
                energy_j=energy,
                psums=tl.outputs * tl.psums_per_output,
                tiles=tl.tiles,
                chains=tl.chains,
                replicas=tl.replicas,
            )
        )
        cursor += time_s
        energy_total += energy

    return Timeline(
        workload=graph.name,
        pool=pool,
        options=options,
        nodes=tuple(scheds),
        makespan_s=cursor,
        dynamic_energy_j=energy_total,
        static_power_w=cfg.static_power_w(),
        busy_per_dpu=tuple(busy_per_dpu),
    )


# ---------------------------------------------------------------------------
# DAG regime — one global event clock, dependency-gated dispatch
# ---------------------------------------------------------------------------
def _run_dag(
    graph: WorkloadGraph,
    pool: DpuPool,
    options: MapperOptions,
    tilings: Dict[str, NodeTiling],
) -> Timeline:
    cfg = pool.cfg
    p = cfg.peripherals
    busy_per_dpu = [0.0] * pool.size
    heap = [(0.0, d) for d in range(pool.size)]
    heapq.heapify(heap)
    finish: Dict[str, float] = {}
    energy_total = 0.0
    makespan = 0.0
    scheds: List[NodeSchedule] = []

    for node in graph.topological():
        tl = tilings[node.name]
        ready = max((finish[dep] for dep in node.deps), default=0.0)
        node_start = None
        node_end = 0.0
        busy_s = 0.0
        for rows_block in tl.row_blocks:
            dur = tl.chain_duration_s(rows_block)
            for _ in range(tl.col_tiles):
                free, d = heapq.heappop(heap)
                start = max(free, ready)
                fin = start + dur
                busy_s += dur
                busy_per_dpu[d] += dur
                node_start = start if node_start is None else min(node_start, start)
                node_end = max(node_end, fin)
                heapq.heappush(heap, (fin, d))
        node_finish = node_end + p.reduction_network.latency_s
        finish[node.name] = node_finish
        makespan = max(makespan, node_finish)
        energy = _node_energy_j(tl, cfg, busy_s)
        energy_total += energy
        scheds.append(
            NodeSchedule(
                name=node.name,
                site=node.site,
                start_s=node_start if node_start is not None else ready,
                time_s=node_finish - (node_start if node_start is not None else ready),
                stream_s=node_end,
                reduce_s=_node_reduce_s(tl, cfg),
                tune_s=tl.tiles * tl.tune_s / pool.size,
                energy_j=energy,
                psums=tl.outputs * tl.psums_per_output,
                tiles=tl.tiles,
                chains=tl.chains,
                replicas=tl.replicas,
            )
        )

    return Timeline(
        workload=graph.name,
        pool=pool,
        options=options,
        nodes=tuple(scheds),
        makespan_s=makespan,
        dynamic_energy_j=energy_total,
        static_power_w=cfg.static_power_w(),
        busy_per_dpu=tuple(busy_per_dpu),
    )
