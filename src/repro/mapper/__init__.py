"""repro.mapper — multi-DPU tile-level workload scheduler (DESIGN.md §16).

Lowers CNN layer lists and LM configs to a tiled-GEMM DAG
(:mod:`repro.mapper.workload`), places tiles onto an area-matched DPU
pool with batching / replication / overlap decisions
(:mod:`repro.mapper.mapping`), and executes the event timeline
(:mod:`repro.mapper.timeline`).  ``MapperOptions.degenerate()``
reproduces ``repro.core.simulator.simulate`` bit-for-bit.
"""

from repro.mapper.mapping import DpuPool, MapperOptions, NodeTiling, tile_node
from repro.mapper.timeline import NodeSchedule, Timeline, map_workload
from repro.mapper.workload import GemmNode, WorkloadGraph

__all__ = [
    "DpuPool",
    "GemmNode",
    "MapperOptions",
    "NodeSchedule",
    "NodeTiling",
    "Timeline",
    "WorkloadGraph",
    "map_workload",
    "tile_node",
]
