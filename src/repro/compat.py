"""JAX version-compatibility layer.

The runtime targets JAX 0.4.x through 0.6.x, which moved or reshaped several
symbols this repo depends on:

* ``shard_map`` — ``jax.experimental.shard_map.shard_map`` (0.4.x, with a
  ``check_rep`` flag) became ``jax.shard_map`` (0.5+, flag renamed
  ``check_vma``).
* ``AxisType`` — ``jax.sharding.AxisType`` and the ``axis_types=`` parameter
  of ``jax.make_mesh`` only exist on 0.5+.
* ``AbstractMesh`` — 0.4.x takes a pair tuple ``((name, size), ...)``;
  0.5+ takes ``(axis_sizes, axis_names)``.
* tree utils — ``jax.tree.map`` & co. replaced ``jax.tree_util.tree_map``
  (old alias kept, new namespace absent on very old releases).

POLICY: no version-sensitive JAX symbol may be referenced outside this
module (enforced by ISSUE-1's acceptance grep and tests/test_compat.py).
Call sites import ``shard_map``, ``make_mesh``, ``abstract_mesh``,
``AxisType`` and the ``tree_*`` aliases from here.
"""

from __future__ import annotations

import enum
import inspect
from typing import Any, Optional, Sequence

import jax

# Stable re-exports, so call sites can take everything mesh/sharding-related
# from one place.
from jax.sharding import Mesh, NamedSharding, PartitionSpec  # noqa: F401


# ---------------------------------------------------------------------------
# The compat-sensitivity registry. ONE list of the JAX symbols whose name,
# location, or signature changed across the supported 0.4.x–0.6.x range.
# repro.analysis rule RPR001 reads these to forbid any reference outside this
# module (replacing the old ROADMAP `rg` spot-check); keeping the data here
# means adding a shim and banning direct use are the same edit.
# ---------------------------------------------------------------------------

# Dotted attribute paths that must never be spelled at call sites.
COMPAT_SENSITIVE_ATTRS = frozenset(
    {
        "jax.shard_map",  # 0.5+ only (0.4.x: jax.experimental.shard_map)
        "jax.experimental.shard_map.shard_map",
        "jax.sharding.AxisType",  # 0.5+ only
        "jax.sharding.AbstractMesh",  # ctor signature flipped at 0.5
        "jax.make_mesh",  # axis_types= param is 0.5+ only
        "jax.lax.axis_size",  # 0.5+ only
    }
)

# Modules that must not be imported (their contents moved).
COMPAT_SENSITIVE_MODULES = frozenset({"jax.experimental.shard_map"})

# Names that must not be from-imported out of any jax.* module.
COMPAT_SENSITIVE_NAMES = frozenset(
    {
        "shard_map",
        "AxisType",
        "AbstractMesh",
        "make_mesh",
        "axis_size",
        "TPUCompilerParams",  # renamed CompilerParams at 0.5
        "CompilerParams",
    }
)

# Keyword arguments whose spelling is version-dependent (check_rep became
# check_vma; compat.shard_map accepts only the new spelling).
COMPAT_SENSITIVE_KWARGS = frozenset({"check_rep"})

# Methods whose return shape is version-dependent; call the wrapper instead.
COMPAT_SENSITIVE_METHODS = frozenset({"cost_analysis"})


def _version_tuple(v: str) -> tuple:
    parts = []
    for piece in v.split(".")[:3]:
        digits = "".join(ch for ch in piece if ch.isdigit())
        parts.append(int(digits) if digits else 0)
    return tuple(parts)


JAX_VERSION: tuple = _version_tuple(jax.__version__)


# ---------------------------------------------------------------------------
# shard_map: jax.shard_map (0.5+) vs jax.experimental.shard_map (0.4.x)
# ---------------------------------------------------------------------------
if hasattr(jax, "shard_map"):
    _shard_map_impl = jax.shard_map
else:  # 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map_impl

_SHARD_MAP_PARAMS = frozenset(inspect.signature(_shard_map_impl).parameters)


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: Optional[bool] = None):
    """Version-agnostic shard_map.

    ``check_vma`` follows the 0.5+ spelling; on 0.4.x it is forwarded as
    ``check_rep`` (same semantics: per-output replication/varying-manual-axes
    checking). ``None`` keeps the installed JAX's default.
    """
    kwargs: dict = {}
    if check_vma is not None:
        if "check_vma" in _SHARD_MAP_PARAMS:
            kwargs["check_vma"] = check_vma
        elif "check_rep" in _SHARD_MAP_PARAMS:
            kwargs["check_rep"] = check_vma
    return _shard_map_impl(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kwargs
    )


# ---------------------------------------------------------------------------
# AxisType / mesh construction
# ---------------------------------------------------------------------------
HAS_AXIS_TYPES: bool = hasattr(jax.sharding, "AxisType")

if HAS_AXIS_TYPES:
    AxisType = jax.sharding.AxisType
else:

    class AxisType(enum.Enum):
        """Stand-in for jax.sharding.AxisType on JAX < 0.5.

        0.4.x meshes have no axis-type concept; every axis behaves like the
        later ``Auto`` (GSPMD decides). The enum exists so call sites can
        spell intent uniformly; it is dropped at mesh construction.
        """

        Auto = "auto"
        Explicit = "explicit"
        Manual = "manual"


# jax.make_mesh only exists from 0.4.35; below that, fall back to arranging
# jax.devices() by hand. Introspection must stay guarded so merely importing
# compat never crashes on an older JAX.
_HAS_MAKE_MESH: bool = hasattr(jax, "make_mesh")
_MAKE_MESH_PARAMS = (
    frozenset(inspect.signature(jax.make_mesh).parameters)
    if _HAS_MAKE_MESH
    else frozenset()
)


def make_mesh(
    axis_shapes: Sequence[int],
    axis_names: Sequence[str],
    *,
    axis_types: Optional[Sequence[Any]] = None,
    devices=None,
) -> Mesh:
    """jax.make_mesh that tolerates JAX versions without ``axis_types``.

    When the installed JAX supports axis types and ``axis_types`` is None,
    every axis defaults to Auto (the 0.4.x behavior), so meshes built here
    lower identically across versions.
    """
    axis_shapes = tuple(axis_shapes)
    axis_names = tuple(axis_names)
    if not _HAS_MAKE_MESH:
        import numpy as np

        n_dev = 1
        for s in axis_shapes:
            n_dev *= s
        devs = list(devices) if devices is not None else jax.devices()[:n_dev]
        return Mesh(np.asarray(devs).reshape(axis_shapes), axis_names)
    kwargs: dict = {}
    if devices is not None:
        kwargs["devices"] = devices
    if HAS_AXIS_TYPES and "axis_types" in _MAKE_MESH_PARAMS:
        if axis_types is None:
            axis_types = (AxisType.Auto,) * len(axis_names)
        kwargs["axis_types"] = tuple(axis_types)
    return jax.make_mesh(axis_shapes, axis_names, **kwargs)


# AbstractMesh: 0.4.x __init__(shape_tuple=((name, size), ...));
# 0.5+ __init__(axis_sizes, axis_names, *, axis_types=...). Absent on very
# old JAX, so introspect lazily via getattr.
_ABSTRACT_MESH_CLS = getattr(jax.sharding, "AbstractMesh", None)
_ABSTRACT_MESH_PAIR_STYLE: bool = _ABSTRACT_MESH_CLS is not None and (
    "shape_tuple" in inspect.signature(_ABSTRACT_MESH_CLS.__init__).parameters
)


def abstract_mesh(axis_shapes: Sequence[int], axis_names: Sequence[str]):
    """Device-free mesh for sharding resolution, on any supported JAX."""
    axis_shapes = tuple(axis_shapes)
    axis_names = tuple(axis_names)
    if len(axis_shapes) != len(axis_names):
        raise ValueError(
            f"axis_shapes {axis_shapes} and axis_names {axis_names} disagree"
        )
    if _ABSTRACT_MESH_CLS is None:
        raise NotImplementedError(
            f"jax {jax.__version__} has no jax.sharding.AbstractMesh; "
            "device-free sharding resolution needs jax >= 0.4.31"
        )
    if _ABSTRACT_MESH_PAIR_STYLE:
        return _ABSTRACT_MESH_CLS(tuple(zip(axis_names, axis_shapes)))
    return _ABSTRACT_MESH_CLS(axis_shapes, axis_names)


# ---------------------------------------------------------------------------
# Pallas TPU compiler params: pltpu.CompilerParams (0.5+) was named
# pltpu.TPUCompilerParams on 0.4.x (same fields). Lazy import so compat
# stays light for the many call sites that never touch Pallas.
# ---------------------------------------------------------------------------
def pallas_tpu_compiler_params(**kwargs):
    from jax.experimental.pallas import tpu as pltpu

    cls = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams
    return cls(**kwargs)


# ---------------------------------------------------------------------------
# Compiled.cost_analysis(): 0.4.x returns a one-element list of dicts,
# 0.5+ returns the dict directly (or None when unavailable).
# ---------------------------------------------------------------------------
def cost_analysis(compiled) -> dict:
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return ca or {}


# ---------------------------------------------------------------------------
# axis_size: jax.lax.axis_size is 0.5+; older JAX gets it via psum(1, axis),
# which constant-folds to a concrete int inside shard_map/pmap traces.
# ---------------------------------------------------------------------------
if hasattr(jax.lax, "axis_size"):
    axis_size = jax.lax.axis_size
else:

    def axis_size(axis_name):
        return jax.lax.psum(1, axis_name)


# ---------------------------------------------------------------------------
# tree utils: jax.tree.* (0.4.25+) vs jax.tree_util.tree_*
# ---------------------------------------------------------------------------
if hasattr(jax, "tree") and hasattr(jax.tree, "map"):
    tree_map = jax.tree.map
    tree_leaves = jax.tree.leaves
    tree_flatten = jax.tree.flatten
    tree_unflatten = jax.tree.unflatten
    tree_structure = jax.tree.structure
else:  # pragma: no cover - pre-0.4.25 fallback
    from jax import tree_util as _tree_util

    tree_map = _tree_util.tree_map
    tree_leaves = _tree_util.tree_leaves
    tree_flatten = _tree_util.tree_flatten
    tree_unflatten = _tree_util.tree_unflatten
    tree_structure = _tree_util.tree_structure
