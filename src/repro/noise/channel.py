"""Organization-aware analog channel model (paper Tables II–IV, DESIGN.md §8).

:func:`build_channel_model` maps an organization (a name like ASMW / MASW /
SMWA, any valid S/A/M/W order string, or a typed
:class:`repro.orgs.OrgSpec`), the photonic link parameters of Table IV, and
a DPE geometry (fan-in ``N``, fan-out ``M``, analog precision ``B``,
``N_lambda`` WDM channels) to a :class:`ChannelModel` — a frozen, hashable
description of every signal manipulation the DPU applies to a psum:

* **loss chain** (Table III): through loss over the out-of-resonance rings a
  channel traverses (``2(N-1)`` for ASMW, ``N`` for MASW, ``2`` for SMWA),
  propagation loss over the organization's waveguide length, splitter /
  insertion losses, the 1:M fan-out split, and the lumped network penalty —
  composing into the delivered power of Eq. 3;
* **detector noise** (Eq. 1–2): the shot/thermal/RIN-limited SNR at the
  delivered power, converted to a gaussian psum sigma in integer LSBs;
* **crosstalk** (Table II): inter-modulation and cross-weight leakage as
  adjacent-channel amplitude couplings, filter truncation as an amplitude
  compression — present/absent per organization exactly as Table II states;
* **ADC**: round-to-LSB plus optional saturation at ``adc_bits``.

Every stage is individually toggleable (set its magnitude to zero / pass the
corresponding ``enable_*`` flag to the builder) and the applied chain
(:func:`analog_pass_psums`, :func:`apply_channel_psum`) is jit/vmap
compatible and differentiable (``round_ste`` where non-smooth).  With all
stages disabled the datapath takes the exact integer route and is
bit-identical to the ideal DPU GEMM.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro import platforms as _platforms
from repro.core import scalability
from repro.core.params import PhotonicParams, dbm_to_watts
from repro.noise import stages
from repro.orgs import EFFECT_BUDGET_DB, OrgSpec, resolve


# ---------------------------------------------------------------------------
# The structural channel model
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class ChannelModel:
    """Signal-chain model of one DPU channel (frozen => static under jit)."""

    organization: str = "SMWA"
    n: int = 1                     # DPE fan-in (psum chunk length)
    m: int = 1                     # fan-out
    bits: int = 4                  # analog slice precision B
    num_wavelengths: int = 1       # N_lambda WDM channels (= n for the DPU)
    datarate_gs: float = 5.0

    # Stage magnitudes; 0.0 / None = stage disabled.
    intermod_eps: float = 0.0      # inter-modulation coupling per neighbor
    crossweight_eps: float = 0.0   # cross-weight coupling per neighbor
    filter_alpha: float = 0.0      # filter-truncation amplitude compression
    detector_sigma_lsb: float = 0.0  # gaussian psum noise std [psum LSBs]
    adc_bits: Optional[int] = None   # ADC saturation range; None = ideal

    # Loss-chain bookkeeping [dB] (reports / structure tests; delivered
    # power already folds these in via Eq. 3).
    through_loss_db: float = 0.0
    propagation_loss_db: float = 0.0
    splitter_loss_db: float = 0.0
    insertion_loss_db: float = 0.0
    fanout_split_db: float = 0.0
    penalty_db: float = 0.0
    delivered_dbm: float = 0.0
    snr_db: float = math.inf

    # Material platform the loss chain was derived on (repro.platforms);
    # provenance only — the quantitative effect already rides the loss /
    # sigma fields above.
    platform: str = "SOI"

    # Builder provenance (set by :func:`build_channel_model`): the as-given
    # arguments, minus ``n``, that produced this model.  Lets
    # :func:`shard_local_channel` re-derive the n-dependent stages at a
    # smaller fan-in (K-sharded GEMMs, repro.photonic.sharded) instead of
    # carrying the global-N loss chain into every shard.  ``None`` for
    # hand-constructed models (which then keep their magnitudes as given).
    builder: Optional[tuple] = None

    @property
    def analog(self) -> bool:
        """True when any float-valued analog stage is active (the datapath
        must then leave the exact integer route)."""
        return (
            self.intermod_eps > 0.0
            or self.crossweight_eps > 0.0
            or self.filter_alpha > 0.0
            or self.detector_sigma_lsb > 0.0
        )

    @property
    def is_ideal(self) -> bool:
        return not self.analog and self.adc_bits is None

    def total_loss_db(self) -> float:
        return (
            self.through_loss_db
            + self.propagation_loss_db
            + self.splitter_loss_db
            + self.insertion_loss_db
            + self.fanout_split_db
            + self.penalty_db
        )

    def disable(self, *stage_names: str) -> "ChannelModel":
        """Return a copy with the named stages off.

        Names: ``intermod``, ``crossweight``, ``filter``, ``detector``,
        ``adc``; ``crosstalk`` = intermod + crossweight + filter (the three
        Table II mechanisms); ``all`` = everything.
        """
        off = {
            "intermod": {"intermod_eps": 0.0},
            "crossweight": {"crossweight_eps": 0.0},
            "filter": {"filter_alpha": 0.0},
            "detector": {"detector_sigma_lsb": 0.0},
            "adc": {"adc_bits": None},
        }
        groups = {
            "crosstalk": ("intermod", "crossweight", "filter"),
            "all": tuple(off),
        }
        updates: Dict[str, object] = {}
        for s in stage_names:
            for name in groups.get(s, (s,)):
                if name not in off:
                    raise ValueError(f"unknown stage {s!r}")
                updates.update(off[name])
        return dataclasses.replace(self, **updates)


# ---------------------------------------------------------------------------
# Builder: organization + PhotonicParams + geometry -> ChannelModel
# ---------------------------------------------------------------------------
def _budget_to_coupling(budget_db: float) -> float:
    """Map a per-effect power budget (paper §IV-C) to a per-neighbor
    amplitude coupling: the budget bounds the worst-case amplitude error
    contributed by the two adjacent channels, so each neighbor couples with
    ``(1 - 10^(-budget/20)) / 2``."""
    return (1.0 - 10.0 ** (-budget_db / 20.0)) / 2.0


def build_channel_model(
    organization: "str | OrgSpec",
    params: Optional[PhotonicParams] = None,
    *,
    n: Optional[int] = None,
    m: Optional[int] = None,
    bits: int = 4,
    datarate_gs: float = 5.0,
    adc_bits: Optional[int] = None,
    enable_loss: bool = True,
    enable_crosstalk: bool = True,
    enable_detector_noise: bool = True,
    enable_adc: bool = True,
    platform: "str | _platforms.PlatformSpec" = "SOI",
) -> ChannelModel:
    """Derive the quantitative channel model for one organization.

    ``organization`` accepts a name, a four-letter block-order string, or
    a typed :class:`repro.orgs.OrgSpec` (one resolution point — unknown or
    wrong-case orders raise ``ValueError`` naming the valid choices); the
    Table II/III structure is derived from the block order, so unstudied
    orderings get a physically consistent channel.  ``platform`` accepts
    a name or a :class:`repro.platforms.PlatformSpec` (resolved through
    ``repro.platforms.resolve``, the same eager single-point validation)
    and replaces the platform-owned loss fields of ``params`` before the
    chain is derived — the SOI default is the identity.  ``n`` defaults
    to the calibrated achievable DPE size at (B, DR) *on that platform*;
    ``m`` defaults to ``n`` (paper assumption).  ``enable_loss=False``
    zeroes the loss chain *for the SNR computation* (the detector then
    sees the full laser power), which isolates the crosstalk stages in
    ablations.
    """
    spec = resolve(organization)
    org = spec.name
    m_given = m  # provenance: record m as-given (None = paper's m=n rule)
    platform_spec = _platforms.resolve(platform)
    params_given = params  # provenance: pre-platform (None = calibrated)
    params = platform_spec.apply(params or scalability.CALIBRATED)
    if n is None:
        n = scalability.calibrated_max_n(
            spec, bits, datarate_gs, platform=platform_spec
        )
        if n <= 0:
            raise ValueError(
                f"infeasible operating point {org} B={bits} DR={datarate_gs}"
            )
    if m is None:
        m = n

    through_db = spec.through_device_count(n) * params.p_mrm_obl_db
    prop_db = (
        params.p_si_att_db_per_mm
        * spec.waveguide_length_factor
        * n
        * params.d_mrr_mm
        + params.p_smf_att_db
    )
    split_db = params.p_splitter_il_db * math.log2(max(m, 2))
    il_db = params.p_ec_il_db + params.p_mrm_il_db + params.p_mrr_w_il_db
    fanout_db = 10.0 * math.log10(max(m, 1))
    penalty_db = params.penalty_db(spec)

    # Delivered power (Eq. 3, org-aware through loss) and the SNR it buys.
    if enable_loss:
        delivered_dbm = scalability.output_power_dbm(n, m, spec, params)
    else:
        delivered_dbm = params.p_laser_dbm
    p_ch = dbm_to_watts(delivered_dbm)
    bw = datarate_gs * 1e9 / params.bw_divisor
    # Eq. 1 link SNR (solver convention: noise beta at per-channel power) —
    # equals the B-bit ENOB requirement at the calibrated achievable N.
    snr_amp = params.responsivity * p_ch / (
        scalability.noise_beta(p_ch, params) * math.sqrt(bw)
    )
    snr_db = 20.0 * math.log10(snr_amp) if snr_amp > 0 else -math.inf

    sigma = 0.0
    if enable_detector_noise:
        # The BPD sees the *aggregate* of the chunk's N channels and adds
        # ONE noise draw per psum sample (the paper's Eq. 1 sizes the link
        # per channel; the aggregate draw is the beyond-paper refinement).
        # Composition mirrors Eq. 2's two-branch balanced-PD convention:
        # shot scales with the total received power, thermal (4kT/R_L —
        # dominant at these powers) is fixed, and RIN adds in quadrature
        # over the N *independent* WDM lasers (N * (R P)^2, not (N R P)^2).
        # Referred to the per-symbol product full-scale of (2^B - 1)^2
        # psum LSBs.
        from repro.core.params import K_BOLTZMANN, Q_ELECTRON

        r_s = params.responsivity
        shot = 2.0 * Q_ELECTRON * (r_s * n * p_ch + params.i_dark)
        thermal = 4.0 * K_BOLTZMANN * params.temperature / params.r_load
        rin = n * (r_s * p_ch) ** 2 * params.rin_linear_per_hz
        dark_branch = 2.0 * Q_ELECTRON * params.i_dark + thermal
        noise_amp = (
            math.sqrt(shot + thermal + rin) + math.sqrt(dark_branch)
        ) * math.sqrt(bw)
        fullscale = float((2**bits - 1) ** 2)
        sigma = fullscale * noise_amp / max(r_s * p_ch, 1e-30)

    eps_im = eps_cw = alpha = 0.0
    if enable_crosstalk:
        # Table II presence/absence, derived from the block order.
        if spec.inter_modulation:
            eps_im = _budget_to_coupling(EFFECT_BUDGET_DB["inter_modulation"])
        if spec.cross_weight:
            eps_cw = _budget_to_coupling(EFFECT_BUDGET_DB["cross_weight"])
        if spec.filter_truncation:
            alpha = 1.0 - 10.0 ** (-EFFECT_BUDGET_DB["filter_truncation"] / 20.0)

    builder = (
        org,
        params_given,
        m_given,
        bits,
        datarate_gs,
        adc_bits,
        enable_loss,
        enable_crosstalk,
        enable_detector_noise,
        enable_adc,
        platform_spec.name,
    )
    return ChannelModel(
        organization=org,
        platform=platform_spec.name,
        n=n,
        m=m,
        bits=bits,
        num_wavelengths=n,
        datarate_gs=datarate_gs,
        intermod_eps=eps_im,
        crossweight_eps=eps_cw,
        filter_alpha=alpha,
        detector_sigma_lsb=sigma,
        adc_bits=adc_bits if enable_adc else None,
        through_loss_db=through_db,
        propagation_loss_db=prop_db,
        splitter_loss_db=split_db,
        insertion_loss_db=il_db,
        fanout_split_db=fanout_db,
        penalty_db=penalty_db,
        delivered_dbm=delivered_dbm,
        snr_db=snr_db,
        builder=builder,
    )


def shard_local_channel(channel: ChannelModel, n_local: int) -> ChannelModel:
    """The channel model one shard of a K-sharded GEMM sees.

    Sharding the contraction (fan-in) axis over ``shards`` devices gives
    each shard a local fan-in ``N_local = min(N, K/shards)``; the through
    loss (Table III: ``2(N-1)`` / ``N`` / ``2`` rings), the propagation
    length, the delivered power, and therefore the detector sigma all
    shrink with it, while the crosstalk couplings and the ADC are
    per-neighbor/per-sample quantities and carry over unchanged.  Stages
    the caller disabled stay disabled (``disable``/``replace`` masks are
    re-applied on top of the rebuilt model).

    Models built by :func:`build_channel_model` are re-derived from their
    recorded builder arguments at ``n_local``; hand-constructed models
    (no provenance) keep their magnitudes and only shrink the geometry.
    """
    n_local = max(int(n_local), 1)
    if n_local >= channel.n:
        return channel
    if channel.builder is None:
        return dataclasses.replace(
            channel,
            n=n_local,
            num_wavelengths=min(channel.num_wavelengths, n_local),
        )
    rebuild = _rebuilder(channel.builder)
    rebuilt = rebuild(n_local)
    # Re-apply the caller's per-stage state: the n-independent magnitudes
    # (crosstalk couplings, filter alpha, ADC range) are taken from the
    # *current* channel so disable()/replace() masks survive the rebuild.
    # The detector sigma is n-dependent and is re-derived — unless the
    # caller replaced it with a custom value (it no longer matches what
    # the builder produced at the original N, e.g. a noise-margin
    # ablation), in which case the override is preserved as-is.
    sigma = rebuilt.detector_sigma_lsb
    if channel.detector_sigma_lsb != rebuild(channel.n).detector_sigma_lsb:
        sigma = channel.detector_sigma_lsb
    return dataclasses.replace(
        rebuilt,
        intermod_eps=channel.intermod_eps,
        crossweight_eps=channel.crossweight_eps,
        filter_alpha=channel.filter_alpha,
        adc_bits=channel.adc_bits,
        detector_sigma_lsb=sigma,
    )


def _rebuilder(builder: tuple):
    """Re-derivation closure over a ChannelModel's recorded builder args.

    Returns ``rebuild(n, bits=None)`` — the model the builder would have
    produced at fan-in ``n`` (and, optionally, a different analog
    precision), with every other as-given argument replayed verbatim.
    """
    (
        org,
        params,
        m_given,
        bits_given,
        datarate_gs,
        adc_bits,
        enable_loss,
        enable_crosstalk,
        enable_detector_noise,
        enable_adc,
        platform,
    ) = builder

    def rebuild(n: int, bits: Optional[int] = None) -> ChannelModel:
        return build_channel_model(
            org,
            params,
            n=n,
            m=m_given,
            bits=bits_given if bits is None else bits,
            datarate_gs=datarate_gs,
            adc_bits=adc_bits,
            enable_loss=enable_loss,
            enable_crosstalk=enable_crosstalk,
            enable_detector_noise=enable_detector_noise,
            enable_adc=enable_adc,
            platform=platform,
        )

    return rebuild


def sliced_channel(channel: ChannelModel, plane_bits: int) -> ChannelModel:
    """The channel one bit-plane pass of the sliced execution mode sees.

    Bit-slicing (DESIGN.md §15) runs the *same* hardware — fan-in N,
    delivered power, loss chain all unchanged — but each analog pass
    carries a ``plane_bits``-bit operand plane instead of a full B-bit
    slice.  The per-pass product full-scale shrinks from ``(2^B - 1)^2``
    to ``(2^p - 1)^2`` psum LSBs, and the detector sigma (which is
    referred to that full-scale) shrinks with it; the crosstalk couplings
    are relative amplitudes and carry over unchanged.

    Models built by :func:`build_channel_model` are re-derived from their
    recorded builder arguments at ``bits=plane_bits`` (same N); hand-
    constructed models re-refer their sigma by the full-scale ratio.
    Caller-disabled stages and sigma overrides survive exactly as in
    :func:`shard_local_channel`.
    """
    plane_bits = int(plane_bits)
    if plane_bits == channel.bits:
        return channel
    scale = float((2**plane_bits - 1) ** 2) / float((2**channel.bits - 1) ** 2)
    if channel.builder is None:
        return dataclasses.replace(
            channel,
            bits=plane_bits,
            detector_sigma_lsb=channel.detector_sigma_lsb * scale,
        )
    rebuild = _rebuilder(channel.builder)
    rebuilt = rebuild(channel.n, plane_bits)
    sigma = rebuilt.detector_sigma_lsb
    if channel.detector_sigma_lsb != rebuild(channel.n).detector_sigma_lsb:
        sigma = channel.detector_sigma_lsb * scale
    return dataclasses.replace(
        rebuilt,
        intermod_eps=channel.intermod_eps,
        crossweight_eps=channel.crossweight_eps,
        filter_alpha=channel.filter_alpha,
        adc_bits=channel.adc_bits,
        detector_sigma_lsb=sigma,
    )


# ---------------------------------------------------------------------------
# Channel application (the oracle-side analog pass)
# ---------------------------------------------------------------------------
def analog_pass_psums(
    x_chunks: jax.Array,  # (R, G, N) int — one operand slice, chunked
    w_chunks: jax.Array,  # (G, N, C) int — one weight slice, chunked
    channel: ChannelModel,
    seed: jax.Array,      # uint32 stream seed (stages.fold_seed output)
) -> jax.Array:
    """One slice-pair optical pass through the full signal chain.

    Returns int32 per-chunk psums ``(R, G, C)`` after crosstalk, filter
    truncation, detector noise, and the ADC.  The wavelength axis is the
    chunk-local ``N`` axis; leakage never crosses chunk (DPE) boundaries.
    """
    xs = x_chunks.astype(jnp.int32)
    ws = w_chunks.astype(jnp.int32)
    psum = jnp.einsum("rgn,gnc->rgc", xs, ws, preferred_element_type=jnp.int32)
    a = psum.astype(jnp.float32)
    if channel.intermod_eps > 0.0:
        # Modulated symbols leak into spectrally-adjacent channels *before*
        # weighting (Table II: inter-modulation crosstalk).
        x_nb = stages.neighbor_sum(xs, axis=-1).astype(jnp.float32)
        a = a + channel.intermod_eps * jnp.einsum(
            "rgn,gnc->rgc", x_nb, ws.astype(jnp.float32)
        )
    if channel.crossweight_eps > 0.0:
        # A weight ring partially drops/weights the adjacent wavelengths
        # (Table II: cross-weight crosstalk).
        w_nb = stages.neighbor_sum(ws, axis=1).astype(jnp.float32)
        a = a + channel.crossweight_eps * jnp.einsum(
            "rgn,gnc->rgc", xs.astype(jnp.float32), w_nb
        )
    if channel.filter_alpha > 0.0:
        a = stages.filter_truncation(a, channel.filter_alpha)
    if channel.detector_sigma_lsb > 0.0:
        a = stages.detector_noise(a, channel.detector_sigma_lsb, seed)
    return stages.adc_quantize(a, channel.adc_bits)


def apply_channel_psum(
    a: jax.Array,
    channel: ChannelModel,
    seed: jax.Array,
    *,
    differentiable: bool = True,
) -> jax.Array:
    """Post-accumulation stages only (filter -> noise -> ADC) on a float
    psum array — the differentiable entry point for training-time noise
    models that keep operands in float."""
    if channel.filter_alpha > 0.0:
        a = stages.filter_truncation(a, channel.filter_alpha)
    if channel.detector_sigma_lsb > 0.0:
        a = stages.detector_noise(a, channel.detector_sigma_lsb, seed)
    return stages.adc_quantize(a, channel.adc_bits, differentiable=differentiable)
