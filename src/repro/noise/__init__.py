"""`repro.noise` — differentiable per-organization analog channel model.

Maps an organization + :class:`~repro.core.params.PhotonicParams` + DPE
geometry to a structural :class:`ChannelModel` (Tables II–IV quantified),
and provides the composable signal-chain stages the numeric datapath
(`repro.core.dpu`, `repro.kernels.photonic_gemm`) applies per optical pass.
See DESIGN.md §8.
"""

from repro.noise.channel import (
    ChannelModel,
    analog_pass_psums,
    apply_channel_psum,
    build_channel_model,
    shard_local_channel,
    sliced_channel,
)
from repro.noise.stages import (
    adc_quantize,
    data_tweak,
    detector_noise,
    filter_truncation,
    fold_seed,
    gaussian_from_counter,
    hash_mix32,
    key_zero_cotangent,
    neighbor_sum,
    round_ste,
    seed_from_key,
)

__all__ = [
    "ChannelModel",
    "analog_pass_psums",
    "apply_channel_psum",
    "build_channel_model",
    "shard_local_channel",
    "sliced_channel",
    "adc_quantize",
    "data_tweak",
    "detector_noise",
    "key_zero_cotangent",
    "filter_truncation",
    "fold_seed",
    "gaussian_from_counter",
    "hash_mix32",
    "neighbor_sum",
    "round_ste",
    "seed_from_key",
]
