"""Differentiable signal-chain stages for the analog channel model.

Each stage is a small, jit/vmap-compatible array transform; the composition
order (paper §IV, DESIGN.md §8) is

    crosstalk (operand level) -> analog accumulate -> filter truncation
    -> detector noise -> ADC (round + saturate)

Non-smooth stages use straight-through estimators (:func:`round_ste`) so the
whole chain is differentiable; smooth stages are plain jnp and get exact
gradients.  The gaussian generator is *counter-based* (murmur3-style integer
mixing + Box-Muller) rather than ``jax.random`` so the exact same code runs
inside the Pallas TPU kernel (where ``jax.random`` / ``pltpu.prng_*`` are
unavailable or backend-specific) and in interpret mode on CPU — bitwise
deterministic for a fixed seed and layout.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# Straight-through rounding (the ADC quantizer)
# ---------------------------------------------------------------------------
@jax.custom_vjp
def round_ste(x: jax.Array) -> jax.Array:
    """``jnp.round`` with an identity (straight-through) gradient."""
    return jnp.round(x)


def _round_ste_fwd(x):
    return jnp.round(x), None


def _round_ste_bwd(_, g):
    return (g,)


round_ste.defvjp(_round_ste_fwd, _round_ste_bwd)


def adc_quantize(
    a: jax.Array, adc_bits: Optional[int], *, differentiable: bool = False
) -> jax.Array:
    """ADC stage: round to integer psum LSBs, saturate to ``adc_bits``.

    ``differentiable=True`` keeps the output float and routes rounding
    through :func:`round_ste` (clipping already has the usual subgradient);
    the default integer path is used by the int-level DPU datapath.
    """
    q = round_ste(a) if differentiable else jnp.round(a)
    if adc_bits is not None:
        lim = 2 ** (adc_bits - 1) - 1
        q = jnp.clip(q, -lim, lim)
    return q if differentiable else q.astype(jnp.int32)


# ---------------------------------------------------------------------------
# Crosstalk perturbations (operand level, within one DPE chunk)
# ---------------------------------------------------------------------------
def neighbor_sum(x: jax.Array, axis: int) -> jax.Array:
    """Sum of the two spectrally-adjacent channels, zero at chunk edges.

    ``axis`` indexes the wavelength (fan-in) dimension of one DPE chunk; a
    chunk boundary is a physical DPE boundary, so leakage never crosses it.
    Implemented with concatenate+slice (not ``roll``) so edges see zeros and
    the same code lowers inside Pallas kernels.
    """
    axis = axis % x.ndim
    zshape = list(x.shape)
    zshape[axis] = 1
    zero = jnp.zeros(zshape, x.dtype)
    idx_lo = [slice(None)] * x.ndim
    idx_hi = [slice(None)] * x.ndim
    idx_lo[axis] = slice(1, None)     # left-shift: neighbor at +1
    idx_hi[axis] = slice(None, -1)    # right-shift: neighbor at -1
    up = jnp.concatenate([x[tuple(idx_lo)], zero], axis=axis)
    dn = jnp.concatenate([zero, x[tuple(idx_hi)]], axis=axis)
    return up + dn


def filter_truncation(a: jax.Array, alpha: float) -> jax.Array:
    """Aggregation-filter truncation: amplitude compression ``(1 - alpha)``.

    The partial-drop filter truncates the modulated symbol's spectrum
    (paper Table II, "filter truncation"); the surviving fraction of the
    amplitude is ``1 - alpha``.  Linear, hence exactly differentiable.
    """
    return a * (1.0 - alpha)


# ---------------------------------------------------------------------------
# Counter-based gaussian noise (shared between oracle and Pallas kernel)
# ---------------------------------------------------------------------------
_M1 = 0x85EBCA6B
_M2 = 0xC2B2AE35
_GOLDEN = 0x9E3779B9
_S1 = 0x27D4EB2F
_S2 = 0x165667B1
_S3 = 0x5BF03635


def hash_mix32(x: jax.Array) -> jax.Array:
    """murmur3 fmix32 finalizer — a full-avalanche 32-bit mixer."""
    x = x ^ (x >> jnp.uint32(16))
    x = x * jnp.uint32(_M1)
    x = x ^ (x >> jnp.uint32(13))
    x = x * jnp.uint32(_M2)
    x = x ^ (x >> jnp.uint32(16))
    return x


def fold_seed(seed: jax.Array, *ids) -> jax.Array:
    """Fold integer stream ids (pass / chunk / grid indices) into a seed."""
    s = seed.astype(jnp.uint32)
    for i, v in enumerate(ids):
        v = jnp.asarray(v).astype(jnp.uint32)
        s = hash_mix32(s ^ (v + jnp.uint32(1)) * jnp.uint32(_GOLDEN) ^ jnp.uint32(i))
    return s


def gaussian_from_counter(base: jax.Array, shape) -> jax.Array:
    """Standard-normal draws of ``shape`` from a mixed ``base`` stream seed.

    Element counters are hashed into two independent uniform streams and
    combined with Box-Muller.  Pure jnp (iota / integer ops / transcendental
    VPU ops), so it lowers identically inside Pallas TPU kernels and in
    interpret mode.
    """
    if len(shape) == 1:
        ctr = jax.lax.iota(jnp.uint32, shape[0])
    else:
        ctr = jnp.zeros(shape, jnp.uint32)
        stride = jnp.uint32(1)
        for ax in range(len(shape) - 1, -1, -1):
            ctr = ctr + jax.lax.broadcasted_iota(jnp.uint32, shape, ax) * stride
            stride = stride * jnp.uint32(shape[ax])
    u1 = hash_mix32(base ^ (ctr * jnp.uint32(_S1)))
    u2 = hash_mix32(base ^ (ctr * jnp.uint32(_S2)) ^ jnp.uint32(_S3))
    # 24-bit mantissa uniforms; u1 offset keeps log() finite.
    f1 = (u1 >> jnp.uint32(8)).astype(jnp.float32) * (1.0 / (1 << 24)) + (
        0.5 / (1 << 24)
    )
    f2 = (u2 >> jnp.uint32(8)).astype(jnp.float32) * (1.0 / (1 << 24))
    return jnp.sqrt(-2.0 * jnp.log(f1)) * jnp.cos((2.0 * jnp.pi) * f2)


def data_tweak(seed: jax.Array, *arrays: jax.Array) -> jax.Array:
    """Fold a cheap content hash of the operands into a stream seed.

    Two GEMMs that share a ``noise_seed`` and a psum shape (e.g. the
    same-shaped projections of every transformer layer, or successive QAT
    steps) would otherwise draw bitwise-identical noise arrays and their
    analog errors would add coherently instead of averaging out.  Folding
    an operand-dependent word keeps full determinism (same seed + same
    inputs => same noise) while decorrelating distinct layers/steps.
    Zero-padding is hash-neutral (zeros contribute nothing to the sum), so
    callers may tweak before or after padding.
    """
    s = seed.astype(jnp.uint32)
    for a in arrays:
        word = (a.astype(jnp.uint32) * jnp.uint32(_S1)).sum(dtype=jnp.uint32)
        s = hash_mix32(s ^ word)
    return s


def key_zero_cotangent(prng_key: Optional[jax.Array]):
    """The zero cotangent custom-VJP rules must return for a PRNG-key
    argument: ``None`` for an absent key, a symbolic-zero ``float0`` array
    for an integer-typed one."""
    if prng_key is None:
        return None
    import numpy as np

    return np.zeros(prng_key.shape, dtype=jax.dtypes.float0)


def seed_from_key(prng_key: Optional[jax.Array]) -> Optional[jax.Array]:
    """Collapse a JAX PRNG key (typed or raw uint32) to a uint32 seed.

    Lets the counter-based generator honour the ``jax.random`` key
    discipline of the callers: same key -> bitwise-identical noise,
    ``fold_in``-style independence comes from :func:`fold_seed`.
    """
    if prng_key is None:
        return None
    key = prng_key
    if jnp.issubdtype(key.dtype, jax.dtypes.prng_key):
        key = jax.random.key_data(key)
    data = key.astype(jnp.uint32).reshape(-1)
    seed = jnp.uint32(0)
    for i in range(data.shape[0]):
        seed = hash_mix32(seed ^ data[i] ^ jnp.uint32(i * _GOLDEN & 0xFFFFFFFF))
    return seed


def detector_noise(a: jax.Array, sigma: float, base: jax.Array) -> jax.Array:
    """Additive shot/thermal/RIN noise at the balanced photodetector.

    ``sigma`` is the per-psum standard deviation in psum LSBs (set by the
    delivered-power SNR, see ``channel.build_channel_model``); ``base`` is a
    uint32 stream seed from :func:`fold_seed`.  The draw does not depend on
    ``a`` so gradients pass through exactly.
    """
    if sigma <= 0.0:
        return a
    return a + sigma * jax.lax.stop_gradient(gaussian_from_counter(base, a.shape))
