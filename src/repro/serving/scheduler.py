"""Token-budgeted continuous-batching scheduler over the paged KV pool.

Supersedes the fixed-slot loop in ``repro.runtime.serve``: requests admit
into ``batch_size`` decode slots backed by the block pool
(``repro.serving.kv_cache``), prompts prefill in chunks of at most
``chunk_tokens`` tokens per engine step, and every step interleaves that
prefill budget with one batched decode over all live slots — a long prompt
can no longer head-of-line-block the tokens streaming out of the decode
batch, and admission reserves request-sized block counts instead of a
worst-case ``max_seq`` row per slot.

Correctness contracts (tested in ``tests/test_serving.py``):

* **bitwise vs one-shot** — on the float path, chunked prefill +
  interleaved paged decode reproduce the legacy one-shot engine's logits
  bit-for-bit per request (the chunk attention feeds exactly the one-shot
  KV block partition); under a photonic engine the same holds whenever a
  wave admits in lockstep with single-chunk prefills (per-tensor activation
  scales are the one chunk-extensive quantity);
* **weight-stationary** — decode steps over the prepacked params trace
  with zero weight-sized round ops (``ContractChecker``, PR-3 invariant);
* **per-request sampling streams** — the sampling key folds in the request
  ``uid`` and its token index, never the slot id, so a recycled slot cannot
  replay (or be influenced by) a previous occupant's sample stream;
* **no stale KV** — blocks zero at (re)allocation; see ``kv_cache``.

Tensor parallel: pass the PR-4 ``mesh``/``tp_axis`` and every model call
runs under ``repro.photonic.sharded.tensor_parallel`` with shard-local
prepacked banks, exactly like the legacy engine.
"""

from __future__ import annotations

import collections
import contextlib
import dataclasses
import time
from typing import Callable, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.serving import kv_cache as kvc


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray  # (T,) int32
    max_new_tokens: int = 16
    eos_id: Optional[int] = None
    # filled by the engine
    output: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    # latency bookkeeping, in units of the scheduler's clock
    t_submit: Optional[float] = None
    t_first: Optional[float] = None
    t_done: Optional[float] = None
    # per-emitted-token logits rows, only with ServingConfig.record_logits
    logits: List[np.ndarray] = dataclasses.field(default_factory=list)


@dataclasses.dataclass
class ServingConfig:
    batch_size: int = 4
    max_seq: int = 256
    block_size: int = 16
    num_blocks: Optional[int] = None  # None: worst case (null + trash + B*max_seq)
    chunk_tokens: int = 64  # prefill token budget per engine step
    greedy: bool = True
    temperature: float = 1.0
    seed: int = 0
    record_logits: bool = False


def prepack_serving_params(arch, model_cfg, params, *, mesh=None, tp_axis="model"):
    """Weight-stationary prepack (DESIGN.md §9) shared by the paged
    scheduler and the legacy engine: with a photonic engine configured,
    quantize + pack every routed weight ONCE, so serving steps stream
    activations against packed int8 banks and never re-quantize.  Returns
    ``(engine_or_None, params)``."""
    from repro.models.common import engine_from_model_config
    from repro.photonic.packing import prepack_params

    engine = engine_from_model_config(model_cfg)
    if engine is None:
        return None, params
    pack_engine = engine
    if getattr(model_cfg, "mla_absorb", False):
        # Absorbed MLA decode consumes wuk/wuv as raw floats in its einsums
        # (never through the quantizing dense path); keep them float.
        pol = dataclasses.replace(
            pack_engine.policy, exclude=pack_engine.policy.exclude + ("wuk", "wuv")
        )
        pack_engine = dataclasses.replace(pack_engine, policy=pol)
    tp_size = (
        int(mesh.shape[tp_axis]) if mesh is not None and tp_axis in mesh.shape else 1
    )
    params = prepack_params(
        params,
        arch.param_defs(model_cfg),
        pack_engine,
        mesh=mesh if tp_size > 1 else None,
        axis=tp_axis,
    )
    return engine, params


class _Slot:
    """Host-side per-slot state; the device sees only (table, pos, active)."""

    def __init__(self, req: Request, blocks: List[int]):
        self.req = req
        self.blocks = blocks
        self.prefill_done = 0
        self.decoding = False


class Scheduler:
    def __init__(
        self,
        arch,
        model_cfg,
        params,
        cfg: ServingConfig,
        *,
        mesh=None,
        tp_axis: str = "model",
        clock: Callable[[], float] = time.monotonic,
    ):
        from repro.models import lm

        if model_cfg.mla or model_cfg.cross_attn_every:
            raise ValueError(
                "paged serving covers the GQA self-attention LM stack; use "
                "runtime.serve.LegacyEngine for MLA / cross-attention families"
            )
        if cfg.max_seq % cfg.block_size:
            raise ValueError(
                f"block_size={cfg.block_size} must divide max_seq={cfg.max_seq}"
            )
        b = cfg.batch_size
        self.arch = arch
        self.model_cfg = model_cfg
        self.cfg = cfg
        self.mesh = mesh
        self.tp_axis = tp_axis
        self._tp_size = (
            int(mesh.shape[tp_axis])
            if mesh is not None and tp_axis in mesh.shape
            else 1
        )
        self._clock = clock

        self.photonic, self.params = prepack_serving_params(
            arch, model_cfg, params, mesh=mesh, tp_axis=tp_axis
        )

        table_width = cfg.max_seq // cfg.block_size
        reserved = 1 + b  # null block + one trash block per slot
        num_blocks = cfg.num_blocks
        if num_blocks is None:
            num_blocks = reserved + b * table_width
        self.num_blocks = num_blocks
        self.allocator = kvc.BlockAllocator(
            num_blocks, cfg.block_size, reserved=reserved
        )
        pool_def = arch.cache_def(
            model_cfg, num_blocks, cfg.block_size,
            {"enc_seq": cfg.block_size}, model_cfg.compute_dtype,
        )
        self.kv_pool = kvc.init_pool(pool_def["layers"])
        self._trash = jnp.arange(1, b + 1, dtype=jnp.int32)

        self._table = np.full((b, table_width), kvc.NULL_BLOCK, np.int32)
        self._pos = np.zeros((b,), np.int32)
        self._tokens = np.zeros((b, 1), np.int32)
        self.slots: List[Optional[_Slot]] = [None] * b
        self._prefill_fifo: List[int] = []  # slot ids, admission order
        self.queue: collections.deque = collections.deque()
        self.stats = {
            "prefills": 0, "prefill_chunks": 0, "decode_steps": 0, "completed": 0
        }

        self._base_key = jax.random.PRNGKey(cfg.seed)

        def decode_fn(p, tok, pool, table, pos, active):
            return lm.lm_decode_paged(
                p, tok, pool, table, pos, active, self._trash, model_cfg,
                gather_len=cfg.max_seq, block_size=cfg.block_size,
            )

        def prefill_fn(p, toks, pool, table_row, t0, t_full, with_logits):
            return lm.lm_prefill_chunk(
                p, toks, pool, table_row, t0, model_cfg,
                t_full=t_full, block_size=cfg.block_size, with_logits=with_logits,
            )

        self._decode_fn = decode_fn
        self._decode = jax.jit(decode_fn)
        self._prefill = jax.jit(prefill_fn, static_argnums=(5, 6))
        self._argmax = jax.jit(lambda rows: jnp.argmax(rows, axis=-1))

        def sample_fn(rows, uids, ns):
            def key(u, n):
                return jax.random.fold_in(jax.random.fold_in(self._base_key, u), n)

            keys = jax.vmap(key)(uids, ns)
            draw = lambda k, row: jax.random.categorical(k, row / cfg.temperature)
            return jax.vmap(draw)(keys, rows)

        self._sample = jax.jit(sample_fn)

    # -- helpers -------------------------------------------------------------
    def _tp_scope(self):
        """The tensor-parallel scope every model call runs under (a no-op
        without a TP mesh); consulted at trace time by ``dense``."""
        if self.photonic is not None and self._tp_size > 1:
            from repro.photonic import sharded

            return sharded.tensor_parallel(self.mesh, self.tp_axis)
        return contextlib.nullcontext()

    def _pick(self, rows: jax.Array, uids, ns) -> jax.Array:
        """Next-token choice per row.  The sampling key is derived from
        (seed, request uid, token index) — never the slot — so a request's
        stream is reproducible and slot recycling cannot replay streams."""
        if self.cfg.greedy:
            return self._argmax(rows)
        return self._sample(
            rows,
            jnp.asarray(np.asarray(uids, np.int32)),
            jnp.asarray(np.asarray(ns, np.int32)),
        )

    def _emit(self, slot: int, tok: int, logits_row=None) -> None:
        s = self.slots[slot]
        req = s.req
        req.output.append(tok)
        if req.t_first is None:
            req.t_first = self._clock()
        if self.cfg.record_logits and logits_row is not None:
            req.logits.append(np.asarray(logits_row))
        done = len(req.output) >= req.max_new_tokens or (
            req.eos_id is not None and tok == req.eos_id
        )
        if done:
            self.allocator.free(s.blocks)
            self._table[slot, :] = kvc.NULL_BLOCK
            self.slots[slot] = None
            if slot in self._prefill_fifo:
                self._prefill_fifo.remove(slot)
            req.done = True
            req.t_done = self._clock()
            self.stats["completed"] += 1

    # -- request lifecycle ---------------------------------------------------
    def submit(self, req: Request, *, t_submit: Optional[float] = None) -> None:
        total = len(req.prompt) + req.max_new_tokens
        if len(req.prompt) < 1:
            raise ValueError("empty prompt")
        if total > self.cfg.max_seq:
            raise ValueError(
                f"prompt + max_new_tokens = {total} exceeds max_seq="
                f"{self.cfg.max_seq}"
            )
        cap = self.allocator.num_blocks - self.allocator.reserved
        if self.allocator.blocks_needed(total) > cap:
            raise ValueError(
                f"request needs {self.allocator.blocks_needed(total)} blocks "
                f"but the pool only has {cap} allocatable"
            )
        req.t_submit = self._clock() if t_submit is None else t_submit
        self.queue.append(req)

    def _admit(self) -> None:
        """Fill free slots FCFS.  All-or-nothing block reservation for
        prompt + max_new_tokens: if the pool cannot cover the queue head's
        worst case, admission waits (no preemption path exists)."""
        for slot in range(self.cfg.batch_size):
            if not self.queue:
                return
            if self.slots[slot] is not None:
                continue
            req = self.queue[0]
            need = self.allocator.blocks_needed(len(req.prompt) + req.max_new_tokens)
            blocks = self.allocator.alloc(need)
            if blocks is None:
                return
            self.queue.popleft()
            # Stale-KV admission contract: recycled blocks zero here, before
            # any table entry can reach them.
            self.kv_pool = kvc.zero_blocks(self.kv_pool, blocks)
            self._table[slot, :] = kvc.NULL_BLOCK
            self._table[slot, : len(blocks)] = blocks
            self._pos[slot] = 0
            self.slots[slot] = _Slot(req, blocks)
            self._prefill_fifo.append(slot)
            self.stats["prefills"] += 1

    def _prefill_phase(self) -> None:
        budget = self.cfg.chunk_tokens
        while budget > 0 and self._prefill_fifo:
            slot = self._prefill_fifo[0]
            s = self.slots[slot]
            prompt = np.asarray(s.req.prompt, np.int32)
            t_full = len(prompt)
            tc = min(budget, t_full - s.prefill_done)
            toks = jnp.asarray(prompt[s.prefill_done : s.prefill_done + tc][None, :])
            final = s.prefill_done + tc == t_full
            with self._tp_scope():
                logits, self.kv_pool = self._prefill(
                    self.params, toks, self.kv_pool,
                    jnp.asarray(self._table[slot]),
                    jnp.int32(s.prefill_done), t_full, final,
                )
            budget -= tc
            s.prefill_done += tc
            self.stats["prefill_chunks"] += 1
            if final:
                self._prefill_fifo.pop(0)
                row = logits[:, -1, : self.model_cfg.vocab_size]
                tok = int(
                    np.asarray(self._pick(row, [s.req.uid], [len(s.req.output)]))[0]
                )
                self._pos[slot] = t_full
                self._tokens[slot, 0] = tok
                s.decoding = True
                self._emit(slot, tok, logits_row=row[0])

    def _decode_phase(self) -> None:
        decoding = [
            i for i, s in enumerate(self.slots) if s is not None and s.decoding
        ]
        if not decoding:
            return
        b = self.cfg.batch_size
        active = np.zeros((b,), bool)
        active[decoding] = True
        with self._tp_scope():
            logits, self.kv_pool = self._decode(
                self.params, jnp.asarray(self._tokens), self.kv_pool,
                jnp.asarray(self._table), jnp.asarray(self._pos),
                jnp.asarray(active),
            )
        self.stats["decode_steps"] += 1
        rows = logits[:, -1, : self.model_cfg.vocab_size]
        uids = [self.slots[i].req.uid if active[i] else 0 for i in range(b)]
        ns = [len(self.slots[i].req.output) if active[i] else 0 for i in range(b)]
        toks = np.asarray(self._pick(rows, uids, ns))
        for i in decoding:
            tok = int(toks[i])
            self._pos[i] += 1
            self._tokens[i, 0] = tok
            self._emit(i, tok, logits_row=rows[i])

    # -- one engine iteration ------------------------------------------------
    def step(self) -> None:
        self._admit()
        self._prefill_phase()
        self._decode_phase()

    @property
    def pending(self) -> bool:
        return bool(self.queue) or any(s is not None for s in self.slots)

    def run(
        self, requests: Optional[List[Request]] = None, max_steps: int = 100_000
    ) -> Optional[List[Request]]:
        if requests:
            for r in requests:
                self.submit(r)
        steps = 0
        while self.pending and steps < max_steps:
            self.step()
            steps += 1
        return requests

    # -- contract access (tests / analysis) ----------------------------------
    def decode_checker(self, label: str = "paged_decode"):
        """ContractChecker over one traced decode step with the live state —
        the PR-3 weight-stationary assertion runs against the exact stepped
        program (``assert_zero_weight_rounds``)."""
        from repro.analysis.contracts import ContractChecker

        b = self.cfg.batch_size
        return ContractChecker.trace(
            self._decode_fn,
            self.params,
            jnp.asarray(self._tokens),
            self.kv_pool,
            jnp.asarray(self._table),
            jnp.asarray(self._pos),
            jnp.ones((b,), bool),
            label=label,
        )
