"""Paged-KV continuous-batching serving (DESIGN.md §13).

``kv_cache`` owns the block pool (allocator, tables, gather/scatter);
``scheduler`` owns request lifecycle: token-budgeted chunked prefill
interleaved with batched paged decode over the PR-3 weight-stationary
photonic path, TP-compatible via the PR-4 mesh scope.
"""

from repro.serving.kv_cache import NULL_BLOCK, BlockAllocator
from repro.serving.scheduler import (
    Request,
    Scheduler,
    ServingConfig,
    prepack_serving_params,
)

__all__ = [
    "NULL_BLOCK",
    "BlockAllocator",
    "Request",
    "Scheduler",
    "ServingConfig",
    "prepack_serving_params",
]
