"""Paged KV cache: fixed-size blocks, a free-list allocator, block tables.

The serving pool stores every layer's KV rows in ``num_blocks`` fixed-size
blocks instead of a dense ``(batch, max_seq)`` reservation per decode slot.
A request owns an ordered list of block ids (its *block table* row); flat
token position ``p`` lives at ``(table[p // block_size], p % block_size)``.
Attention reads through the table with :func:`gather_kv` and writes through
:func:`scatter_kv` — models and runtime never index the pool directly
(rule RPR007 in ``repro.analysis``).

Pool leaf layout (dense-LM family): ``(layers, num_blocks, block_size,
...)`` — exactly ``arch.cache_def(cfg, batch=num_blocks,
max_seq=block_size, ...)`` with the ``(batch, kv_seq)`` axes reinterpreted
as ``(block, in-block offset)``, so int8 KV scale leaves page for free.

Reserved blocks (never allocated to a request):

* block ``NULL_BLOCK`` (0) — permanently zero; unallocated table entries
  point here so a full-width gather reads exact zeros, and it is never a
  scatter destination;
* blocks ``1 .. batch_size`` — one private *trash* block per decode slot;
  inactive slots in a batched decode step redirect their writes there
  (per-slot, so no two rows ever scatter to the same destination and the
  step stays bitwise deterministic).

Admission contract (DESIGN.md §13): a request is admitted only once
``blocks_needed(prompt + max_new_tokens)`` blocks can be reserved, and the
allocator zeroes every block at (re)allocation time — a recycled block can
never leak the previous occupant's KV into a new request (the stale-KV
regression in ``tests/test_serving.py`` plants sentinels to prove it).
"""

from __future__ import annotations

import collections
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

NULL_BLOCK = 0


class BlockAllocator:
    """Host-side free-list allocator over the pool's block ids.

    Blocks ``[0, reserved)`` (the null block + per-slot trash blocks) are
    never handed out.  ``alloc`` is all-or-nothing: admission either gets
    the request's full worst-case reservation or stays queued, so a running
    request can never hit pool exhaustion mid-decode (no preemption path).
    """

    def __init__(self, num_blocks: int, block_size: int, *, reserved: int = 1):
        if reserved < 1:
            raise ValueError("need at least the null block reserved")
        if num_blocks <= reserved:
            raise ValueError(
                f"num_blocks={num_blocks} leaves no allocatable blocks "
                f"(reserved={reserved})"
            )
        self.num_blocks = num_blocks
        self.block_size = block_size
        self.reserved = reserved
        self._free: collections.deque = collections.deque(range(reserved, num_blocks))

    def blocks_needed(self, n_tokens: int) -> int:
        return -(-n_tokens // self.block_size)

    @property
    def free_blocks(self) -> int:
        return len(self._free)

    def alloc(self, n: int) -> Optional[List[int]]:
        """Reserve ``n`` blocks, or None (and no change) if unavailable."""
        if n > len(self._free):
            return None
        return [self._free.popleft() for _ in range(n)]

    def free(self, blocks: Sequence[int]) -> None:
        for b in blocks:
            if not (self.reserved <= b < self.num_blocks):
                raise ValueError(f"freed block {b} was never allocatable")
        self._free.extend(blocks)


# ---------------------------------------------------------------------------
# Pool construction
# ---------------------------------------------------------------------------
def _is_def_leaf(x) -> bool:
    return (
        isinstance(x, tuple)
        and len(x) == 3
        and isinstance(x[0], tuple)
        and isinstance(x[1], tuple)
    )


def init_pool(pool_def: Any) -> Any:
    """Zero-initialized pool from a ``cache_def``-style ``(shape, axes,
    dtype)`` leaf tree (evaluated with ``batch=num_blocks,
    max_seq=block_size``).  Zeros everywhere make the null block exact and
    every block "pre-zeroed" for its first allocation."""

    def leaf(d):
        shape, axes, dtype = d
        if "batch" not in axes or "kv_seq" not in axes:
            raise ValueError(f"cache leaf axes {axes} have no (batch, kv_seq) pair")
        if axes.index("kv_seq") != axes.index("batch") + 1:
            raise ValueError(
                f"paged pool needs kv_seq right after batch, got axes {axes}"
            )
        return jnp.zeros(shape, dtype)

    return jax.tree.map(leaf, pool_def, is_leaf=_is_def_leaf)


# ---------------------------------------------------------------------------
# Destination computation (the only code that touches block tables)
# ---------------------------------------------------------------------------
def token_dest(
    block_table: jax.Array,  # (B, W) int32
    pos: jax.Array,  # (B,) int32 — flat write position per row
    active: jax.Array,  # (B,) bool — rows with a live decode slot
    trash_blocks: jax.Array,  # (B,) int32 — per-slot trash block ids
    block_size: int,
) -> Tuple[jax.Array, jax.Array]:
    """(block ids, in-block offsets) for one decode token per batch row;
    inactive rows redirect to their private trash block."""
    owned = jnp.take_along_axis(block_table, (pos // block_size)[:, None], axis=1)
    blocks = jnp.where(active, owned[:, 0], trash_blocks)
    offsets = jnp.where(active, pos % block_size, 0)
    return blocks, offsets


def chunk_dest(
    block_table: jax.Array,  # (W,) int32 — one request's table row
    t0: jax.Array,  # scalar int32 — chunk start (flat position)
    tc: int,
    block_size: int,
) -> Tuple[jax.Array, jax.Array]:
    """(block ids, offsets), each (tc,), for a prefill chunk covering flat
    positions ``[t0, t0 + tc)`` of a single request."""
    flat = t0 + jnp.arange(tc, dtype=jnp.int32)
    return block_table[flat // block_size], flat % block_size


# ---------------------------------------------------------------------------
# Gather / scatter (the only code that indexes pool leaves)
# ---------------------------------------------------------------------------
def gather_kv(
    kv_pool: Dict[str, jax.Array],  # per-layer: leaves (num_blocks, bs, ...)
    block_table: jax.Array,  # (B, W) int32
    length: int,
) -> Dict[str, jax.Array]:
    """Contiguous ``(B, length, ...)`` view of the first ``length`` cache
    rows of each request, read through the block table.  Unallocated table
    entries point at the null block, so rows past a request's allocation
    read as exact zeros."""

    def leaf(p):
        bs = p.shape[1]
        n = -(-length // bs)
        t = block_table[:, :n]
        out = p[t]  # (B, n, bs, ...)
        out = out.reshape((t.shape[0], n * bs) + p.shape[2:])
        return out[:, :length]

    return jax.tree.map(leaf, kv_pool)


def scatter_kv(
    kv_pool: Dict[str, jax.Array],
    blocks: jax.Array,  # (N,) int32
    offsets: jax.Array,  # (N,) int32
    rows: Dict[str, jax.Array],  # per-layer: leaves (N, ...)
) -> Dict[str, jax.Array]:
    """Write N rows at ``(blocks[i], offsets[i])`` in every leaf.  Callers
    guarantee destinations are distinct (distinct block tables per request;
    per-slot trash blocks), keeping the scatter order-free."""
    return jax.tree.map(
        lambda p, r: p.at[blocks, offsets].set(r.astype(p.dtype)), kv_pool, rows
    )


def zero_blocks(kv_pool: Any, blocks: Sequence[int]) -> Any:
    """Zero the given blocks across every leaf of the full stacked pool
    (leaves ``(layers, num_blocks, block_size, ...)``) — the allocation-time
    half of the stale-KV admission contract."""
    idx = jnp.asarray(list(blocks), jnp.int32)
    return jax.tree.map(lambda p: p.at[:, idx].set(0), kv_pool)
