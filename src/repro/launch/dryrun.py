import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

from repro.launch import profile as _profile  # noqa: E402

# Tuned launch profile (log hygiene, persistent compilation cache; the
# device-count flag above is already set, so the merge leaves it alone).
_profile.apply()

"""Multi-pod dry-run (deliverable e).

For every (architecture x input-shape x mesh) cell: build the step function
(train_step for train shapes, prefill/serve_step otherwise), lower with
ShapeDtypeStruct inputs (no allocation), ``.compile()`` on the production
mesh, and record ``memory_analysis()`` / ``cost_analysis()`` / the HLO
collective summary to a JSON cache consumed by the roofline report.

The XLA_FLAGS line above MUST stay the first statement — jax locks the
device count at first init.  Smoke tests / benches never import this module.

Usage:
  python -m repro.launch.dryrun --arch granite-3-8b --shape train_4k --mesh single
  python -m repro.launch.dryrun --sweep            # all cells, subprocess each
"""

import argparse
import dataclasses
import gzip
import json
import subprocess
import sys
import time
import traceback
from pathlib import Path

RESULTS_DIR = Path(__file__).resolve().parents[3] / "results" / "dryrun"
HLO_DIR = Path(__file__).resolve().parents[3] / "results" / "hlo"


def _cell_path(arch: str, shape: str, mesh: str, variant: str = "base") -> Path:
    safe = arch.replace("/", "_").replace(".", "_")
    return RESULTS_DIR / f"{safe}__{shape}__{mesh}__{variant}.json"


def run_cell(
    arch_name: str,
    shape_name: str,
    mesh_kind: str,
    *,
    photonic: bool = False,
    photonic_scope: str = "weights",
    photonic_org: str = "SMWA",  # str | OrgSpec; validated by DPUConfig
    save_hlo: bool = False,
    overrides: dict | None = None,
    variant: str = "base",
    zero1: bool = True,
    skip_main: bool = False,  # annotate mode: only re-run the (cheap) ladder
    dp_shardmap: bool = False,  # shard_map-pinned DP step (runtime/dp_step)
    dp_compress: bool = False,  # int8-compressed gradient all-reduce
) -> dict:
    import jax
    import jax.numpy as jnp

    from repro import compat
    from repro.core.dpu import DPUConfig
    from repro.launch import hlo_analysis
    from repro.launch.mesh import make_production_mesh, require_devices
    from repro.models import registry
    from repro.models.common import axes_tree, init_tree
    from repro.optim import adamw
    from repro.runtime import sharding as shd

    arch = registry.get(arch_name)
    shape = registry.SHAPES[shape_name]
    multi = mesh_kind == "multi"
    require_devices(512 if multi else 256)
    mesh = make_production_mesh(multi_pod=multi)
    model_axis = mesh.shape["model"]

    cfg = arch.config.pad_for_mesh(model_axis)
    if photonic:
        cfg = dataclasses.replace(
            cfg,
            photonic=DPUConfig(
                organization=photonic_org, bits=4, datarate_gs=5.0
            ),
            photonic_backend="ref",
            photonic_scope=photonic_scope,
        )
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)

    # Engine-routed photonic GEMMs: constructing the engine here validates
    # the operating point + site policy before any lowering work.
    from repro.models.common import engine_from_model_config

    eng = engine_from_model_config(cfg)

    defs = arch.param_defs(cfg)
    param_sds = jax.eval_shape(
        lambda k: init_tree(defs, k, cfg.param_dtype),
        jax.ShapeDtypeStruct((2,), jnp.uint32),
    )

    out: dict = {
        "arch": arch_name,
        "shape": shape_name,
        "mesh": mesh_kind,
        "variant": variant,
        "kind": shape.kind,
        "padded_heads": cfg.padded_heads,
        "padded_vocab": cfg.padded_vocab,
        "num_kv_heads_effective": cfg.num_kv_heads,
        "param_count": sum(
            int(jnp.prod(jnp.array(l.shape))) for l in compat.tree_leaves(param_sds)
        ),
        "photonic_engine": None if eng is None else eng.describe().to_dict(),
    }

    def build(bcfg):
        """(jitted step fn, SDS args) for this cell at config `bcfg`."""
        bdefs = arch.param_defs(bcfg)
        baxes = axes_tree(bdefs)
        bsds = jax.eval_shape(
            lambda k: init_tree(bdefs, k, bcfg.param_dtype),
            jax.ShapeDtypeStruct((2,), jnp.uint32),
        )
        p_sh = shd.tree_shardings(mesh, bsds, baxes)
        if shape.kind == "train" and dp_shardmap:
            from repro.compat import NamedSharding, PartitionSpec
            from repro.runtime.dp_step import make_dp_train_step

            opt_cfg = adamw.AdamWConfig()
            opt_sds = jax.eval_shape(adamw.init, bsds)
            batch_sds, _ = arch.train_batch_spec(bcfg, shape)
            step = make_dp_train_step(
                lambda p, b: arch.loss(p, b, bcfg), opt_cfg, mesh,
                compress_grads=dp_compress,
            )
            repl = NamedSharding(mesh, PartitionSpec())
            bsh = NamedSharding(mesh, PartitionSpec(tuple(mesh.axis_names)))
            jitted = jax.jit(
                step,
                in_shardings=(
                    compat.tree_map(lambda _: repl, bsds),
                    compat.tree_map(lambda _: repl, opt_sds),
                    compat.tree_map(lambda _: bsh, batch_sds),
                ),
                donate_argnums=(0, 1),
            )
            return jitted, (bsds, opt_sds, batch_sds)
        if shape.kind == "train":
            opt_cfg = adamw.AdamWConfig()
            opt_sds = jax.eval_shape(adamw.init, bsds)
            # ZeRO-1 by default: moments shard over (pod, data) in addition
            # to the param's own TP axes — see EXPERIMENTS.md §Perf.
            dp_degree = 1
            for ax in ("pod", "data"):
                dp_degree *= mesh.shape.get(ax, 1)
            moment_axes = shd.zero1_axes(baxes, bsds, dp_degree) if zero1 else baxes
            opt_sh = shd.tree_shardings(
                mesh, opt_sds, adamw.opt_state_axes(moment_axes)
            )
            batch_sds, batch_axes = arch.train_batch_spec(bcfg, shape)
            batch_sh = shd.tree_shardings(mesh, batch_sds, batch_axes)

            moment_sh_p = shd.tree_shardings(mesh, bsds, moment_axes)

            def train_step(params, opt_state, batch):
                loss, grads = jax.value_and_grad(arch.loss)(params, batch, bcfg)
                if zero1:
                    # ZeRO-1: slice grads+params to the moment sharding so the
                    # f32 update math runs at 1/dp size; params re-gather via
                    # the jit out_sharding.
                    grads = jax.lax.with_sharding_constraint(grads, moment_sh_p)
                    params = jax.lax.with_sharding_constraint(params, moment_sh_p)
                params, opt_state, metrics = adamw.update(
                    opt_cfg, params, grads, opt_state
                )
                return params, opt_state, loss, metrics["grad_norm"]

            jitted = jax.jit(
                train_step,
                in_shardings=(p_sh, opt_sh, batch_sh),
                out_shardings=(p_sh, opt_sh, None, None),
                donate_argnums=(0, 1),
            )
            args = (bsds, opt_sds, batch_sds)
        elif shape.kind == "prefill":
            batch_sds, batch_axes = arch.prefill_batch_spec(bcfg, shape)
            batch_sh = shd.tree_shardings(mesh, batch_sds, batch_axes)

            def prefill_step(params, batch):
                return arch.prefill(params, batch, bcfg, shape.seq_len)

            jitted = jax.jit(prefill_step, in_shardings=(p_sh, batch_sh))
            args = (bsds, batch_sds)
        else:  # decode
            (tok_sds, tok_axes), (cache_sds, cache_axes) = arch.decode_specs(
                bcfg, shape
            )
            tok_sh = shd.tree_shardings(mesh, tok_sds, tok_axes)
            cache_sh = shd.tree_shardings(mesh, cache_sds, cache_axes)

            def serve_step(params, token, cache):
                return arch.decode(params, token, cache, bcfg)

            jitted = jax.jit(
                serve_step,
                in_shardings=(p_sh, tok_sh, cache_sh),
                out_shardings=(None, cache_sh),
                donate_argnums=(2,),
            )
            args = (bsds, tok_sds, cache_sds)
        return jitted, args

    if not skip_main:
        with shd.use_rules(mesh, cfg.logical_rules):
            jitted, args = build(cfg)
            t0 = time.time()
            lowered = jitted.lower(*args)
            out["lower_s"] = round(time.time() - t0, 2)
            t0 = time.time()
            compiled = lowered.compile()
            out["compile_s"] = round(time.time() - t0, 2)

        out["sharding_fallbacks"] = [
            {
                "shape": list(s),
                "logical": n,
                "mesh_axis": str(a),
                "dim": d,
                "axis_size": z,
            }
            for (s, n, a, d, z) in shd.fallback_log()
        ]

        ma = compiled.memory_analysis()
        for field in (
            "argument_size_in_bytes",
            "output_size_in_bytes",
            "temp_size_in_bytes",
            "generated_code_size_in_bytes",
            "alias_size_in_bytes",
        ):
            out[field] = getattr(ma, field, None)

        ca = compat.cost_analysis(compiled)
        out["hlo_flops_per_device"] = ca.get("flops")
        out["hlo_bytes_per_device"] = ca.get("bytes accessed")

        hlo = compiled.as_text()
        out["hlo_chars"] = len(hlo)
        out.update(hlo_analysis.collective_summary(hlo))

    # ---- layer-ladder cost analysis (exact FLOPs/bytes; see Arch.ladder) ----
    try:
        ladder_steps = {}
        flops_total = 0.0
        bytes_total = 0.0
        dot_total = 0.0
        for step_name, ov, coeff in arch.ladder(cfg):
            lcfg = dataclasses.replace(cfg, **ov)
            with shd.use_rules(mesh, lcfg.logical_rules):
                lj, largs = build(lcfg)
                lcomp = lj.lower(*largs).compile()
            lca = compat.cost_analysis(lcomp)
            dot_b = hlo_analysis.matmul_traffic_bytes(lcomp.as_text())
            ladder_steps[step_name] = {
                "coeff": coeff,
                "flops": lca.get("flops"),
                "bytes": lca.get("bytes accessed"),
                "dot_bytes": dot_b,
            }
            flops_total += coeff * (lca.get("flops") or 0.0)
            bytes_total += coeff * (
                lca.get("bytes") or lca.get("bytes accessed") or 0.0
            )
            dot_total += coeff * dot_b
        out["ladder"] = ladder_steps
        out["flops_per_device_exact"] = flops_total
        out["bytes_per_device_exact"] = bytes_total
        # fusion-optimal HBM traffic: dot operands/outputs + step args once
        out["dot_bytes_ladder_only"] = dot_total
        out["dot_bytes_per_device_exact"] = dot_total + (
            out.get("argument_size_in_bytes") or 0.0
        )
    except Exception:
        out["ladder_error"] = traceback.format_exc()[-3000:]
    if save_hlo and not skip_main:
        HLO_DIR.mkdir(parents=True, exist_ok=True)
        p = HLO_DIR / (
            _cell_path(arch_name, shape_name, mesh_kind, variant).stem + ".hlo.gz"
        )
        with gzip.open(p, "wt") as f:
            f.write(hlo)
        out["hlo_path"] = str(p)
    out["ok"] = True
    return out


# ---------------------------------------------------------------------------
# Sweep driver — one subprocess per cell (isolation + JSON cache)
# ---------------------------------------------------------------------------
def all_cells():
    from repro.models import registry

    cells = []
    for arch_name in registry.names():
        arch = registry.get(arch_name)
        for shape_name in registry.SHAPES:
            skipped = shape_name in arch.skip_shapes
            for mesh_kind in ("single", "multi"):
                cells.append((arch_name, shape_name, mesh_kind, skipped))
    return cells


def sweep(save_hlo: bool, timeout_s: int = 3600, force: bool = False):
    RESULTS_DIR.mkdir(parents=True, exist_ok=True)
    cells = all_cells()
    todo = []
    for arch, shp, mesh, skipped in cells:
        path = _cell_path(arch, shp, mesh)
        if skipped:
            path.write_text(
                json.dumps(
                    {
                        "arch": arch, "shape": shp, "mesh": mesh, "ok": True,
                        "skipped": True,
                        "reason": "shape inapplicable to arch (DESIGN.md §6)",
                    },
                    indent=1,
                )
            )
            continue
        if path.exists() and not force:
            try:
                if json.loads(path.read_text()).get("ok"):
                    continue
            except Exception:
                pass
        todo.append((arch, shp, mesh))

    print(f"[sweep] {len(todo)} cells to run ({len(cells)} total)", flush=True)
    for i, (arch, shp, mesh) in enumerate(todo):
        cmd = [
            sys.executable, "-m", "repro.launch.dryrun",
            "--arch", arch, "--shape", shp, "--mesh", mesh,
        ]
        if save_hlo:
            cmd.append("--save-hlo")
        t0 = time.time()
        print(f"[sweep {i+1}/{len(todo)}] {arch} x {shp} x {mesh} ...", flush=True)
        try:
            r = subprocess.run(
                cmd, capture_output=True, text=True, timeout=timeout_s,
                env=_profile.child_env(),
            )
            if r.returncode != 0:
                _cell_path(arch, shp, mesh).write_text(
                    json.dumps(
                        {
                            "arch": arch, "shape": shp, "mesh": mesh, "ok": False,
                            "error": (r.stderr or "")[-4000:],
                        },
                        indent=1,
                    )
                )
                print(f"  FAILED ({time.time()-t0:.0f}s)", flush=True)
            else:
                print(f"  ok ({time.time()-t0:.0f}s)", flush=True)
        except subprocess.TimeoutExpired:
            _cell_path(arch, shp, mesh).write_text(
                json.dumps(
                    {"arch": arch, "shape": shp, "mesh": mesh, "ok": False,
                     "error": f"timeout after {timeout_s}s"}, indent=1,
                )
            )
            print("  TIMEOUT", flush=True)


def annotate_sweep(timeout_s: int = 3600):
    """Merge newly added ladder metrics into finished cells (subprocess per
    cell via --annotate-cell; skips cells that already have them)."""
    todo = []
    for p in sorted(RESULTS_DIR.glob("*__base.json")):
        d = json.loads(p.read_text())
        if (
            d.get("ok")
            and not d.get("skipped")
            and "dot_bytes_per_device_exact" not in d
        ):
            todo.append((d["arch"], d["shape"], d["mesh"]))
    print(f"[annotate] {len(todo)} cells", flush=True)
    for i, (arch, shp, mesh) in enumerate(todo):
        print(f"[annotate {i+1}/{len(todo)}] {arch} x {shp} x {mesh}", flush=True)
        cmd = [
            sys.executable,
            "-m",
            "repro.launch.dryrun",
            "--arch",
            arch,
            "--shape",
            shp,
            "--mesh",
            mesh,
            "--annotate-cell",
        ]
        try:
            r = subprocess.run(
                cmd, capture_output=True, text=True, timeout=timeout_s,
                env=_profile.child_env(),
            )
            print(
                "  ok" if r.returncode == 0 else f"  FAILED: {(r.stderr or '')[-300:]}",
                flush=True,
            )
        except subprocess.TimeoutExpired:
            print("  TIMEOUT", flush=True)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument(
        "--shape", choices=["train_4k", "prefill_32k", "decode_32k", "long_500k"]
    )
    ap.add_argument("--mesh", choices=["single", "multi"], default="single")
    ap.add_argument("--sweep", action="store_true")
    ap.add_argument("--annotate", action="store_true")
    ap.add_argument("--annotate-cell", action="store_true")
    ap.add_argument("--photonic", action="store_true")
    ap.add_argument(
        "--photonic-scope",
        default="weights",
        choices=["none", "weights", "weights_int8"],
        help="which weight GEMMs the engine routes (with --photonic)",
    )
    ap.add_argument(
        "--photonic-org",
        default="SMWA",
        help="DPU organization: a registered name or any valid "
        "S/A/M/W order string (with --photonic)",
    )
    ap.add_argument(
        "--dp-shardmap",
        action="store_true",
        help="shard_map-pinned DP train step (replicated params)",
    )
    ap.add_argument(
        "--dp-compress",
        action="store_true",
        help="int8-compressed gradient all-reduce (with --dp-shardmap)",
    )
    ap.add_argument(
        "--no-zero1",
        action="store_true",
        help="replicate optimizer moments across data (ablation)",
    )
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--variant", default=None)
    ap.add_argument(
        "--override",
        action="append",
        default=[],
        help="cfg overrides, e.g. --override remat=False",
    )
    args = ap.parse_args()

    if args.sweep:
        sweep(args.save_hlo, force=args.force)
        return
    if args.annotate:
        annotate_sweep()
        return
    if args.annotate_cell:
        path = _cell_path(args.arch, args.shape, args.mesh, "base")
        existing = json.loads(path.read_text())
        out = run_cell(args.arch, args.shape, args.mesh, skip_main=True)
        out["dot_bytes_per_device_exact"] = out.get("dot_bytes_ladder_only", 0.0) + (
            existing.get("argument_size_in_bytes") or 0.0
        )
        existing.update(out)
        path.write_text(json.dumps(existing, indent=1))
        return

    assert args.arch and args.shape, "--arch and --shape required (or --sweep)"
    overrides = {}
    for ov in args.override:
        k, v = ov.split("=", 1)
        try:
            overrides[k] = json.loads(v)
        except json.JSONDecodeError:
            overrides[k] = v
    variant = args.variant or ("photonic" if args.photonic else "base")
    RESULTS_DIR.mkdir(parents=True, exist_ok=True)
    path = _cell_path(args.arch, args.shape, args.mesh, variant)
    try:
        out = run_cell(
            args.arch, args.shape, args.mesh,
            photonic=args.photonic, photonic_scope=args.photonic_scope,
            photonic_org=args.photonic_org,
            save_hlo=args.save_hlo,
            overrides=overrides or None, variant=variant,
            zero1=not args.no_zero1,
            dp_shardmap=args.dp_shardmap, dp_compress=args.dp_compress,
        )
    except Exception:
        out = {
            "arch": args.arch, "shape": args.shape, "mesh": args.mesh,
            "variant": variant, "ok": False, "error": traceback.format_exc()[-6000:],
        }
    path.write_text(json.dumps(out, indent=1))
    print(json.dumps(out, indent=1))
    if not out.get("ok"):
        sys.exit(1)


if __name__ == "__main__":
    main()
