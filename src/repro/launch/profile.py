"""Tuned process-launch profile: allocator, logging, XLA and compilation
cache environment for benchmarks, examples and the dry-run sweep.

The upstream JAX training harnesses this repo draws idiom from launch
through a shell profile (``export LD_PRELOAD=...libtcmalloc.so.4``,
``TF_CPP_MIN_LOG_LEVEL``, curated ``XLA_FLAGS``) before ever touching
Python.  We keep the same knobs but make them a library so every entry
point — ``benchmarks/run.py``, ``examples/*``, ``repro.launch.dryrun``
— applies one *identical, recorded* profile instead of whatever the
invoking shell happened to export:

* **tcmalloc** — detected, never injected in-process: ``LD_PRELOAD`` is
  read by the dynamic linker at ``exec`` time, so :func:`apply` can only
  report whether it is active; :func:`child_env` builds the environment
  for subprocess launches (the dry-run sweep) where it *can* take
  effect.
* **env hygiene** — ``TF_CPP_MIN_LOG_LEVEL`` and the tcmalloc
  large-alloc report threshold are defaulted (never overridden) so
  benchmark stdout is the measurement, not the log stream.
* **XLA_FLAGS** — curated flags are *merged*: anything the user already
  set wins, flags are only appended if the option is absent.  Nothing in
  the curated set changes numerics — the bitwise contracts
  (DESIGN.md §7/§14) hold with or without the profile.
* **persistent compilation cache** — ``jax_compilation_cache_dir``
  pointed at a keyed directory so repeat benchmark runs (and CI, which
  restores the directory from its cache action) skip recompilation; the
  first trace of a decode step dominates cold benchmark wall-clock.

:func:`describe` snapshots the resolved profile; ``benchmarks/run.py``
embeds it in ``results/BENCH_photonic.json`` so every committed number
names the environment that produced it.
"""

from __future__ import annotations

import os
from typing import Dict, List, Optional

# Known tcmalloc install paths (Debian/Ubuntu multiarch, RH, conda).
TCMALLOC_CANDIDATES = (
    "/usr/lib/x86_64-linux-gnu/libtcmalloc.so.4",
    "/usr/lib/x86_64-linux-gnu/libtcmalloc_minimal.so.4",
    "/usr/lib/libtcmalloc.so.4",
    "/usr/lib64/libtcmalloc.so.4",
    "/opt/conda/lib/libtcmalloc.so",
)

# Suppress absl/TF chatter and tcmalloc's large-allocation reports (60 GB
# threshold — big weight buffers are expected, not leaks).
ENV_DEFAULTS = {
    "TF_CPP_MIN_LOG_LEVEL": "3",
    "TCMALLOC_LARGE_ALLOC_REPORT_THRESHOLD": "60000000000",
}

# Merged into XLA_FLAGS only when the option is not already present.
# Numerics-neutral by construction: no fast-math, no contraction changes.
XLA_FLAG_DEFAULTS: List[str] = [
    # CPU hosts: keep the compilation parallelism bounded so benchmark
    # processes don't oversubscribe the cores the benchmark is timing.
    "--xla_cpu_parallel_codegen_split_count=8",
]

_DEFAULT_CACHE_DIR = os.path.join(
    os.path.expanduser("~"), ".cache", "repro_jax_cache"
)


def find_tcmalloc() -> Optional[str]:
    """Path of an installed tcmalloc shared object, or ``None``."""
    for path in TCMALLOC_CANDIDATES:
        if os.path.exists(path):
            return path
    return None


def tcmalloc_active() -> bool:
    """Whether this process was launched with tcmalloc preloaded."""
    return "tcmalloc" in os.environ.get("LD_PRELOAD", "")


def _merge_xla_flags(extra: List[str]) -> str:
    """Append ``extra`` to ``XLA_FLAGS``, user-set options winning."""
    current = os.environ.get("XLA_FLAGS", "")
    present = {
        tok.split("=", 1)[0] for tok in current.split() if tok.startswith("--")
    }
    added = [f for f in extra if f.split("=", 1)[0] not in present]
    merged = " ".join(filter(None, [current, *added]))
    if merged:
        os.environ["XLA_FLAGS"] = merged
    return merged


def apply(
    *,
    cache_dir: Optional[str] = None,
    xla_flags: Optional[List[str]] = None,
    compilation_cache: bool = True,
) -> Dict[str, object]:
    """Apply the launch profile to the current process and return
    :func:`describe`'s snapshot of what was resolved.

    Idempotent, and safe to call after ``jax`` is imported (the
    compilation-cache config is applied through ``jax.config``; the env
    defaults only matter pre-import but are harmless after).  Call sites
    that must pin ``--xla_force_host_platform_device_count`` first
    (``repro.launch.dryrun``) keep their flag: merging never overrides
    an option that is already set.
    """
    for key, val in ENV_DEFAULTS.items():
        os.environ.setdefault(key, val)
    _merge_xla_flags(XLA_FLAG_DEFAULTS if xla_flags is None else xla_flags)

    resolved_cache = None
    if compilation_cache:
        resolved_cache = (
            cache_dir
            or os.environ.get("JAX_COMPILATION_CACHE_DIR")
            or _DEFAULT_CACHE_DIR
        )
        try:
            import jax

            jax.config.update("jax_compilation_cache_dir", resolved_cache)
            # Cache every compile — benchmark steps are small; the default
            # 1 s floor would skip exactly the dispatch-bound kernels the
            # fused-hot-path benchmark measures.
            jax.config.update("jax_persistent_cache_min_compile_time_secs", 0.0)
            os.makedirs(resolved_cache, exist_ok=True)
        except Exception:
            resolved_cache = None  # old jax / read-only FS: run uncached
    return describe()


def describe() -> Dict[str, object]:
    """Snapshot of the effective launch profile (recorded into benchmark
    JSON so committed numbers name their environment)."""
    try:
        import jax

        cache = jax.config.jax_compilation_cache_dir
    except Exception:
        cache = None
    return {
        "tcmalloc_found": find_tcmalloc(),
        "tcmalloc_active": tcmalloc_active(),
        "ld_preload": os.environ.get("LD_PRELOAD") or None,
        "tf_cpp_min_log_level": os.environ.get("TF_CPP_MIN_LOG_LEVEL"),
        "xla_flags": os.environ.get("XLA_FLAGS") or None,
        "jax_compilation_cache_dir": cache,
    }


def child_env(base: Optional[Dict[str, str]] = None) -> Dict[str, str]:
    """Environment for launching a child process under the full profile —
    including ``LD_PRELOAD=tcmalloc``, which only the *next* ``exec`` can
    honour.  Used by the dry-run sweep's per-cell subprocesses."""
    env = dict(os.environ if base is None else base)
    for key, val in ENV_DEFAULTS.items():
        env.setdefault(key, val)
    tc = find_tcmalloc()
    if tc and "tcmalloc" not in env.get("LD_PRELOAD", ""):
        env["LD_PRELOAD"] = ":".join(filter(None, [env.get("LD_PRELOAD"), tc]))
    env.setdefault("JAX_COMPILATION_CACHE_DIR", _DEFAULT_CACHE_DIR)
    return env
