"""Static analysis of compiled HLO text: collective-communication bytes.

``compiled.cost_analysis()`` has no collective term, so the roofline's
collective component is derived here by parsing ``compiled.as_text()``:

* every ``all-gather`` / ``all-reduce`` / ``reduce-scatter`` / ``all-to-all``
  / ``collective-permute`` op contributes *wire bytes* (ring-algorithm
  estimates based on its printed shape and replica-group size);
* ops inside ``while`` bodies (from ``lax.scan`` over layers/chunks) are
  multiplied by the loop trip count, recovered from the loop-condition
  computation — nested loops multiply.
"""

from __future__ import annotations

import dataclasses
import re
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\([^)]*\)|\S+)\s+"
    r"(all-gather-start|all-gather|all-reduce-start|all-reduce|"
    r"reduce-scatter|all-to-all|collective-permute-start|collective-permute)\("
)
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->.*\{\s*$")
_WHILE_RE = re.compile(
    r"while\(.*?\)(?:.*?)condition=%?([\w.\-]+).*?body=%?([\w.\-]+)", re.S
)
_IOTA_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=\[\d+\]")
_EXPLICIT_GROUPS_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")


def _shape_bytes(shape_str: str) -> float:
    """Total bytes of a shape string like 'bf16[2,512,4096]' or a tuple."""
    total = 0.0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _group_size(line: str) -> Optional[int]:
    m = _IOTA_GROUPS_RE.search(line)
    if m:
        return int(m.group(2))
    m = _EXPLICIT_GROUPS_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return None


@dataclasses.dataclass
class CollectiveOp:
    kind: str
    computation: str
    out_bytes: float
    wire_bytes: float
    group_size: Optional[int]
    multiplier: float = 1.0


def _wire_bytes(kind: str, out_bytes: float, group: Optional[int]) -> float:
    """Ring-algorithm wire-byte estimate per device."""
    g = group or 2
    frac = (g - 1) / g
    if kind.startswith("all-gather"):
        return out_bytes * frac                  # receive full output minus own shard
    if kind.startswith("all-reduce"):
        return 2.0 * out_bytes * frac            # reduce-scatter + all-gather
    if kind == "reduce-scatter":
        return out_bytes * (g - 1)  # operand (= out * g) times (g-1)/g
    if kind == "all-to-all":
        return out_bytes * frac
    if kind.startswith("collective-permute"):
        return out_bytes
    return out_bytes


def parse_computations(hlo: str) -> Dict[str, List[str]]:
    """computation name -> its lines."""
    comps: Dict[str, List[str]] = {}
    cur: Optional[str] = None
    for line in hlo.splitlines():
        m = _COMP_RE.match(line)
        if m:
            cur = m.group(1)
            comps[cur] = []
            continue
        if cur is not None:
            if line.strip() == "}":
                cur = None
                continue
            comps[cur].append(line)
    return comps


def _trip_count(cond_lines: List[str]) -> int:
    """Largest s32 constant in the loop condition ~= trip count."""
    best = 1
    for line in cond_lines:
        for m in re.finditer(r"constant\((\d+)\)", line):
            best = max(best, int(m.group(1)))
    return best


def computation_multipliers(hlo: str) -> Dict[str, float]:
    """Loop-nest multiplier for every computation (entry = 1)."""
    comps = parse_computations(hlo)
    # while edges: parent computation -> (body, trip)
    edges: Dict[str, List[Tuple[str, int]]] = defaultdict(list)
    for name, lines in comps.items():
        for line in lines:
            if " while(" in line:
                m = _WHILE_RE.search(line)
                if m:
                    cond, body = m.group(1), m.group(2)
                    trip = _trip_count(comps.get(cond, []))
                    edges[name].append((body, trip))
                    edges[name].append((cond, trip))

    mult: Dict[str, float] = {name: 1.0 for name in comps}
    # propagate: iterate to fixpoint (loop nests are shallow)
    for _ in range(10):
        changed = False
        for parent, children in edges.items():
            for child, trip in children:
                want = mult.get(parent, 1.0) * trip
                if mult.get(child, 1.0) < want:
                    mult[child] = want
                    changed = True
        if not changed:
            break
    return mult


def collect_collectives(hlo: str) -> List[CollectiveOp]:
    comps = parse_computations(hlo)
    mult = computation_multipliers(hlo)
    ops: List[CollectiveOp] = []
    for comp, lines in comps.items():
        m_c = mult.get(comp, 1.0)
        for line in lines:
            m = _OP_RE.match(line)
            if not m:
                continue
            shape_str, kind = m.group(1), m.group(2)
            if kind.endswith("-start"):
                kind = kind[: -len("-start")]
            out_b = _shape_bytes(shape_str)
            grp = _group_size(line)
            ops.append(
                CollectiveOp(
                    kind=kind,
                    computation=comp,
                    out_bytes=out_b,
                    wire_bytes=_wire_bytes(kind, out_b, grp),
                    group_size=grp,
                    multiplier=m_c,
                )
            )
    return ops


_DEF_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(\([^=]*?\)|\S+)\s+([\w\-]+)[\(.]"
)
_PARAM_SIG_RE = re.compile(
    r"%?([\w.\-]+):\s*((?:\([^()]*\)|[a-z0-9]+\[[\d,]*\])(?:\{[^}]*\})?)"
)
_DOT_ARGS_RE = re.compile(r"\bdot\(\s*%?([\w.\-]+)\s*,\s*%?([\w.\-]+)")


def matmul_traffic_bytes(hlo: str) -> float:
    """Fusion-optimal HBM-traffic estimate: every `dot`'s operands + output
    cross HBM once (elementwise chains assumed fused away), times the
    enclosing loop multiplier.  An optimistic-but-TPU-realistic memory bound
    to complement XLA's unfused 'bytes accessed'."""
    comps = parse_computations(hlo)
    mult = computation_multipliers(hlo)
    # symbol table: op name -> shape string (defs + computation params)
    shapes: Dict[str, str] = {}
    for lines in comps.values():
        for line in lines:
            m = _DEF_RE.match(line)
            if m:
                shapes[m.group(1)] = m.group(2)
            if "parameter(" in line:
                pm = re.match(
                    r"\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(\([^=]*?\)|\S+)\s+parameter",
                    line,
                )
                if pm:
                    shapes[pm.group(1)] = pm.group(2)
    total = 0.0
    for comp, lines in comps.items():
        m_c = mult.get(comp, 1.0)
        for line in lines:
            dm = _DEF_RE.match(line)
            if not dm or dm.group(3) != "dot":
                continue
            out_b = _shape_bytes(dm.group(2))
            am = _DOT_ARGS_RE.search(line)
            op_b = 0.0
            if am:
                for name in am.groups():
                    op_b += _shape_bytes(shapes.get(name, ""))
            total += (out_b + op_b) * m_c
    return total


_ENTRY_RE = re.compile(r"^ENTRY\s+%?([\w.\-]+)\s*\(")

# Definitions that are bookkeeping, not work: they never become a thunk /
# kernel launch of their own in the compiled module.
_BOOKKEEPING_OPS = frozenset(
    {"parameter", "constant", "tuple", "get-tuple-element", "bitcast"}
)


def entry_computation(hlo: str) -> Optional[str]:
    """Name of the ENTRY computation of an HLO module dump."""
    for line in hlo.splitlines():
        m = _ENTRY_RE.match(line)
        if m:
            return m.group(1)
    return None


def dispatch_summary(hlo: str) -> Dict[str, object]:
    """Structural dispatch-count summary of a compiled module.

    ``dispatch_count`` is the number of non-bookkeeping op definitions in
    the ENTRY computation — the module's top-level op sequence, a proxy
    for per-call dispatch/launch overhead (parameters, constants, tuple
    plumbing and bitcasts excluded: they emit no work).  ``entry_fusions``
    counts fusion regions among them (post-fusion, fewer regions ==
    more work riding in each launch).  ``total_ops_loop_adjusted``
    additionally walks every sub-computation times its ``while``-loop
    trip count, the op-count analogue of :func:`collective_summary`.

    This is what the fused-hot-path benchmark asserts on: fusing the
    quant prologue + rescale/bias/activation epilogue into the GEMM
    kernel must *structurally* shrink the entry op sequence, not just
    happen to run faster on one machine.
    """
    comps = parse_computations(hlo)
    mult = computation_multipliers(hlo)
    entry = entry_computation(hlo)
    by_kind: Dict[str, int] = defaultdict(int)
    for line in comps.get(entry, []):
        dm = _DEF_RE.match(line)
        if dm and dm.group(3) not in _BOOKKEEPING_OPS:
            by_kind[dm.group(3)] += 1
    total = 0.0
    for comp, lines in comps.items():
        m_c = mult.get(comp, 1.0)
        for line in lines:
            dm = _DEF_RE.match(line)
            if dm and dm.group(3) not in _BOOKKEEPING_OPS:
                total += m_c
    return {
        "entry_computation": entry,
        "dispatch_count": int(sum(by_kind.values())),
        "entry_fusions": int(by_kind.get("fusion", 0)),
        "entry_ops_by_kind": dict(sorted(by_kind.items())),
        "total_ops_loop_adjusted": total,
    }


def collective_summary(hlo: str) -> Dict[str, float]:
    """Total wire bytes per device, by kind and overall (loop-adjusted)."""
    ops = collect_collectives(hlo)
    by_kind: Dict[str, float] = defaultdict(float)
    count: Dict[str, int] = defaultdict(int)
    for op in ops:
        by_kind[op.kind] += op.wire_bytes * op.multiplier
        count[op.kind] += 1
    out = {f"bytes_{k}": v for k, v in by_kind.items()}
    out.update({f"count_{k}": float(v) for k, v in count.items()})
    out["total_wire_bytes"] = sum(by_kind.values())
    return out
