"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so
importing this module never touches jax device state.  The single-pod mesh is
16x16 = 256 chips (one v5e pod's worth for this exercise); multi-pod adds a
leading "pod" axis (2 pods = 512 chips).  The `pod` axis carries outer data
parallelism (gradient all-reduce crosses the inter-pod DCN once per step);
`model` is tensor/expert parallel and stays ICI-local.

Mesh construction goes through ``repro.compat`` (never raw jax) so it works
on JAX 0.4.x through 0.6.x regardless of axis-type API availability.
"""

from __future__ import annotations

from typing import Sequence

import jax

from repro import compat


def build_mesh(shape: Sequence[int], axes: Sequence[str]) -> compat.Mesh:
    """Device mesh over the first prod(shape) devices, all axes auto-typed
    (GSPMD decides placement — the 0.4.x behavior on every JAX version)."""
    return compat.make_mesh(tuple(shape), tuple(axes))


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return build_mesh(shape, axes)


def make_smoke_mesh():
    """1-device mesh with the production axis names (for CPU smoke tests)."""
    return build_mesh((1, 1), ("data", "model"))


def max_tp_degree(limit: int = 8) -> int:
    """Largest power-of-two tensor-parallel degree the available devices
    support (1 on a bare CPU; 8 in the multi-device CI tier, which forces
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8``)."""
    n = min(len(jax.devices()), limit)
    tp = 1
    while tp * 2 <= n:
        tp *= 2
    return tp


def make_tp_smoke_mesh(tp: int | None = None):
    """("data", "model") mesh with a real tensor-parallel axis over host
    devices — the mesh the sharded photonic engine tests/benchmarks run
    on.  ``tp`` defaults to :func:`max_tp_degree`; the data axis stays 1
    (TP is the axis under test)."""
    if tp is None:
        tp = max_tp_degree()
    require_devices(tp)
    return build_mesh((1, tp), ("data", "model"))


def require_devices(n: int) -> None:
    have = len(jax.devices())
    if have < n:
        raise RuntimeError(
            f"need {n} devices but have {have}. The dry-run launcher must set "
            "XLA_FLAGS=--xla_force_host_platform_device_count=512 BEFORE "
            "importing jax (repro.launch.dryrun does this)."
        )
