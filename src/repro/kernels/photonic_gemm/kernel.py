"""Pallas TPU kernel for the photonic (bit-sliced, psum-chunked) GEMM.

TPU-native adaptation of the paper's DPU datapath (DESIGN.md §3):

* the DPE size ``N`` becomes the psum chunk along the contraction dim — each
  chunk's int32 partial sum models one analog summation + ADC event and can
  be saturated to ``adc_bits`` like the real converter;
* the fan-out ``M`` becomes the output-column tile — ``M`` parallel DPEs map
  onto MXU output columns;
* operand bit-slices (``ceil(operand_bits/B)`` per operand) are extracted
  *inside* the kernel from int8 residents of VMEM, so HBM traffic stays int8
  (one read per operand) while the MXU consumes one slice-pair per pass —
  mirroring the temporal passes of the photonic DPU.

Analog channel stages (DESIGN.md §8) run *inside* the kernel so the noisy
path needs no extra HBM traffic: inter-modulation / cross-weight crosstalk
as extra chunk-local MXU passes against neighbor-shifted operands, filter
truncation as a psum scale, detector noise from a counter-based gaussian
generator (`repro.noise.stages`) seeded by a scalar SMEM input — bitwise
deterministic for a fixed seed + tiling, statistically matching the jnp
oracle (which draws from flat, untiled streams).

Blocking: grid ``(R/TR, C/TC, K/TK)`` with the K axis innermost so the output
tile stays resident in VMEM and accumulates across K-tiles (standard Pallas
matmul accumulation).  ``TK`` must be a multiple of ``n_chunk``; MXU-aligned
tiles (multiples of 128) are used when ADC/analog fidelity is off (chunking
is then numerically irrelevant), and exact-N chunks when it is on.

Two entry points share the datapath:

* :func:`photonic_gemm_pallas` — the integer core: int8 in, int32 out.
* :func:`photonic_gemm_fused_pallas` — the fused hot path (DESIGN.md §14):
  optional in-kernel activation-quantization prologue (f32 tile + SMEM
  scale -> int, :func:`repro.kernels.photonic_gemm.epilogue.quantize_tile`)
  and the fused epilogue (int32 VMEM scratch accumulator -> ``sx *
  w_scale`` rescale -> optional bias -> optional activation) applied at
  the last K step, so neither the int32 accumulator nor the quantized
  activation ever round-trips through HBM.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import compat
from repro.kernels.photonic_gemm.epilogue import (
    EpilogueSpec,
    apply_epilogue,
    quantize_tile,
)
from repro.noise.stages import fold_seed, gaussian_from_counter, neighbor_sum


def _f32_dot(a: jax.Array, b: jax.Array) -> jax.Array:
    return jax.lax.dot_general(
        a.astype(jnp.float32),
        b.astype(jnp.float32),
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )


def _accumulate(
    x: jax.Array,  # (TR, TK) int32
    w: jax.Array,  # (TK, TC) int32
    tile_seed: Optional[jax.Array],
    *,
    slice_bits: int,
    num_slices: int,
    n_chunk: int,
    adc_bits: Optional[int],
    noise_sigma: float,
    filter_alpha: float,
    intermod_eps: float,
    crossweight_eps: float,
    valid_chunks: Optional[int],
) -> jax.Array:
    """One K-tile's int32 contribution through the DPU datapath.

    The single definition of the bit-sliced / psum-chunked / analog-stage
    accumulation, shared by the integer and fused kernels so the two can
    never drift.
    """
    analog = (
        noise_sigma > 0.0
        or filter_alpha > 0.0
        or intermod_eps > 0.0
        or crossweight_eps > 0.0
    )
    tr, tk = x.shape
    _, tc = w.shape
    chunks = tk // n_chunk

    sgn_x, mag_x = jnp.sign(x), jnp.abs(x)
    sgn_w, mag_w = jnp.sign(w), jnp.abs(w)
    mask = (1 << slice_bits) - 1

    acc = jnp.zeros((tr, tc), jnp.int32)
    for si in range(num_slices):
        xs = sgn_x * ((mag_x >> (slice_bits * si)) & mask)
        for ti in range(num_slices):
            ws = sgn_w * ((mag_w >> (slice_bits * ti)) & mask)
            shift = slice_bits * (si + ti)
            if not analog and adc_bits is None and chunks >= 1:
                # Ideal ADC: chunk boundaries are numerically irrelevant —
                # one MXU pass over the whole K-tile.
                psum = jax.lax.dot_general(
                    xs,
                    ws,
                    (((1,), (0,)), ((), ())),
                    preferred_element_type=jnp.int32,
                )
                acc = acc + (psum << shift)
            else:
                # DPU-faithful: run each N-size chunk through the analog
                # signal chain (crosstalk -> filter -> noise -> ADC).
                lim = 2 ** (adc_bits - 1) - 1 if adc_bits is not None else None
                for g in range(chunks):
                    sl = slice(g * n_chunk, (g + 1) * n_chunk)
                    x_c, w_c = xs[:, sl], ws[sl, :]
                    psum = jax.lax.dot_general(
                        x_c,
                        w_c,
                        (((1,), (0,)), ((), ())),
                        preferred_element_type=jnp.int32,
                    )
                    if analog:
                        a = psum.astype(jnp.float32)
                        if intermod_eps > 0.0:
                            a = a + intermod_eps * _f32_dot(
                                neighbor_sum(x_c, axis=1), w_c
                            )
                        if crossweight_eps > 0.0:
                            a = a + crossweight_eps * _f32_dot(
                                x_c, neighbor_sum(w_c, axis=0)
                            )
                        if filter_alpha > 0.0:
                            a = a * (1.0 - filter_alpha)
                        if noise_sigma > 0.0:
                            z = gaussian_from_counter(
                                fold_seed(tile_seed, si * num_slices + ti, g),
                                a.shape,
                            )
                            if valid_chunks is not None:
                                # Chunks entirely inside K-padding carry no
                                # data and fire no optical pass — mask their
                                # noise so variance matches the oracle.
                                gchunk = pl.program_id(2) * chunks + g
                                z = z * (gchunk < valid_chunks).astype(jnp.float32)
                            a = a + noise_sigma * z
                        psum = jnp.round(a).astype(jnp.int32)
                    if lim is not None:
                        psum = jnp.clip(psum, -lim, lim)
                    acc = acc + (psum << shift)
    return acc


def _kernel(
    *refs,
    slice_bits: int,
    num_slices: int,
    n_chunk: int,
    adc_bits: Optional[int],
    noise_sigma: float,
    filter_alpha: float,
    intermod_eps: float,
    crossweight_eps: float,
    valid_chunks: Optional[int],
):
    analog = (
        noise_sigma > 0.0
        or filter_alpha > 0.0
        or intermod_eps > 0.0
        or crossweight_eps > 0.0
    )
    if analog:
        seed_ref, x_ref, w_ref, out_ref = refs
    else:
        x_ref, w_ref, out_ref = refs

    @pl.when(pl.program_id(2) == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    tile_seed = None
    if analog:
        # Per-tile noise stream: seed x grid position (bitwise deterministic
        # for fixed seed and tiling; independent across tiles).
        tile_seed = fold_seed(
            seed_ref[0].astype(jnp.uint32),
            pl.program_id(0),
            pl.program_id(1),
            pl.program_id(2),
        )

    out_ref[...] += _accumulate(
        x_ref[...].astype(jnp.int32),
        w_ref[...].astype(jnp.int32),
        tile_seed,
        slice_bits=slice_bits,
        num_slices=num_slices,
        n_chunk=n_chunk,
        adc_bits=adc_bits,
        noise_sigma=noise_sigma,
        filter_alpha=filter_alpha,
        intermod_eps=intermod_eps,
        crossweight_eps=crossweight_eps,
        valid_chunks=valid_chunks,
    )


def _fused_kernel(
    *refs,
    operand_bits: int,
    fuse_quant: bool,
    has_bias: bool,
    activation: Optional[str],
    out_dtype,
    slice_bits: int,
    num_slices: int,
    n_chunk: int,
    adc_bits: Optional[int],
    noise_sigma: float,
    filter_alpha: float,
    intermod_eps: float,
    crossweight_eps: float,
    valid_chunks: Optional[int],
):
    analog = (
        noise_sigma > 0.0
        or filter_alpha > 0.0
        or intermod_eps > 0.0
        or crossweight_eps > 0.0
    )
    refs = list(refs)
    seed_ref = refs.pop(0) if analog else None
    xs_ref = refs.pop(0)  # SMEM (1,) f32 activation scale (always present)
    x_ref, w_ref, wscale_ref = refs[0], refs[1], refs[2]
    bias_ref = refs[3] if has_bias else None
    out_ref = refs[4] if has_bias else refs[3]
    acc_ref = refs[-1]  # VMEM (TR, TC) int32 scratch accumulator

    kk = pl.program_id(2)

    @pl.when(kk == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    if fuse_quant:
        # In-kernel prologue: the rounding half of quantize_symmetric
        # against the SMEM scale (elementwise, so per-tile == whole-array;
        # zero padding quantizes to zero).
        qmax = float(2 ** (operand_bits - 1) - 1)
        x = quantize_tile(x_ref[...], xs_ref[0], qmax)
    else:
        x = x_ref[...].astype(jnp.int32)

    tile_seed = None
    if analog:
        tile_seed = fold_seed(
            seed_ref[0].astype(jnp.uint32),
            pl.program_id(0),
            pl.program_id(1),
            pl.program_id(2),
        )

    acc_ref[...] += _accumulate(
        x,
        w_ref[...].astype(jnp.int32),
        tile_seed,
        slice_bits=slice_bits,
        num_slices=num_slices,
        n_chunk=n_chunk,
        adc_bits=adc_bits,
        noise_sigma=noise_sigma,
        filter_alpha=filter_alpha,
        intermod_eps=intermod_eps,
        crossweight_eps=crossweight_eps,
        valid_chunks=valid_chunks,
    )

    @pl.when(kk == pl.num_programs(2) - 1)
    def _epilogue():
        spec = EpilogueSpec(bias=has_bias, activation=activation)
        y = apply_epilogue(
            acc_ref[...],
            xs_ref[0],
            wscale_ref[...],  # (1, TC), broadcasts over rows
            bias_ref[...] if has_bias else None,
            spec,
        )
        out_ref[...] = y.astype(out_dtype)


@functools.partial(
    jax.jit,
    static_argnames=(
        "slice_bits",
        "num_slices",
        "n_chunk",
        "adc_bits",
        "noise_sigma",
        "filter_alpha",
        "intermod_eps",
        "crossweight_eps",
        "valid_chunks",
        "tile_r",
        "tile_c",
        "tile_k",
        "interpret",
    ),
)
def photonic_gemm_pallas(
    xq: jax.Array,  # (R, K) int8, R % tile_r == 0, K % tile_k == 0
    wq: jax.Array,  # (K, C) int8, C % tile_c == 0
    seed: Optional[jax.Array] = None,  # int32 scalar (1,), required if noisy
    *,
    slice_bits: int = 4,
    num_slices: int = 2,
    n_chunk: int = 128,
    adc_bits: Optional[int] = None,
    noise_sigma: float = 0.0,
    filter_alpha: float = 0.0,
    intermod_eps: float = 0.0,
    crossweight_eps: float = 0.0,
    valid_chunks: Optional[int] = None,
    tile_r: int = 128,
    tile_c: int = 128,
    tile_k: int = 512,
    interpret: bool = False,
) -> jax.Array:
    r, k = xq.shape
    _, c = wq.shape
    assert r % tile_r == 0 and c % tile_c == 0 and k % tile_k == 0, (
        xq.shape,
        wq.shape,
        (tile_r, tile_c, tile_k),
    )
    assert tile_k % n_chunk == 0, (tile_k, n_chunk)
    analog = (
        noise_sigma > 0.0
        or filter_alpha > 0.0
        or intermod_eps > 0.0
        or crossweight_eps > 0.0
    )
    if noise_sigma > 0.0 and seed is None:
        raise ValueError("noise_sigma > 0 requires a seed")

    grid = (r // tile_r, c // tile_c, k // tile_k)
    kernel = functools.partial(
        _kernel,
        slice_bits=slice_bits,
        num_slices=num_slices,
        n_chunk=n_chunk,
        adc_bits=adc_bits,
        noise_sigma=noise_sigma,
        filter_alpha=filter_alpha,
        intermod_eps=intermod_eps,
        crossweight_eps=crossweight_eps,
        valid_chunks=valid_chunks,
    )
    in_specs = [
        pl.BlockSpec((tile_r, tile_k), lambda i, j, kk: (i, kk)),
        pl.BlockSpec((tile_k, tile_c), lambda i, j, kk: (kk, j)),
    ]
    args = [xq, wq]
    if analog:
        if seed is None:
            seed = jnp.zeros((1,), jnp.int32)
        in_specs.insert(0, pl.BlockSpec(memory_space=pltpu.SMEM))
        args.insert(0, jnp.asarray(seed, jnp.int32).reshape(1))
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((tile_r, tile_c), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((r, c), jnp.int32),
        compiler_params=compat.pallas_tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        ),
        interpret=interpret,
    )(*args)


@functools.partial(
    jax.jit,
    static_argnames=(
        "operand_bits",
        "activation",
        "out_dtype",
        "slice_bits",
        "num_slices",
        "n_chunk",
        "adc_bits",
        "noise_sigma",
        "filter_alpha",
        "intermod_eps",
        "crossweight_eps",
        "valid_chunks",
        "tile_r",
        "tile_c",
        "tile_k",
        "interpret",
    ),
)
def photonic_gemm_fused_pallas(
    x: jax.Array,  # (R, K) f32 activations, or pre-quantized int8
    wq: jax.Array,  # (K, C) int8, C % tile_c == 0
    x_scale: jax.Array,  # () or (1,) f32 — activation quantization scale
    w_scale: jax.Array,  # (C,) f32 per-column dequant scale
    bias: Optional[jax.Array] = None,  # (C,) f32
    seed: Optional[jax.Array] = None,  # int32 scalar (1,), required if noisy
    *,
    operand_bits: int = 8,
    activation: Optional[str] = None,
    out_dtype=jnp.float32,
    slice_bits: int = 4,
    num_slices: int = 2,
    n_chunk: int = 128,
    adc_bits: Optional[int] = None,
    noise_sigma: float = 0.0,
    filter_alpha: float = 0.0,
    intermod_eps: float = 0.0,
    crossweight_eps: float = 0.0,
    valid_chunks: Optional[int] = None,
    tile_r: int = 128,
    tile_c: int = 128,
    tile_k: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """The fused hot path: [quantize] -> integer GEMM -> epilogue, one kernel.

    When ``x`` is floating point it is quantized in-kernel against the
    SMEM-resident ``x_scale`` (the prologue); pre-quantized int operands
    skip the prologue (the noisy channel pre-quantizes digitally because
    its seed derivation hashes the integer operand).  The int32
    accumulator lives in a VMEM scratch tile across K steps; at the last
    K step the epilogue (rescale / bias / activation) writes the f32
    output — the int32 intermediate never reaches HBM.
    """
    r, k = x.shape
    _, c = wq.shape
    assert r % tile_r == 0 and c % tile_c == 0 and k % tile_k == 0, (
        x.shape,
        wq.shape,
        (tile_r, tile_c, tile_k),
    )
    assert tile_k % n_chunk == 0, (tile_k, n_chunk)
    analog = (
        noise_sigma > 0.0
        or filter_alpha > 0.0
        or intermod_eps > 0.0
        or crossweight_eps > 0.0
    )
    if noise_sigma > 0.0 and seed is None:
        raise ValueError("noise_sigma > 0 requires a seed")
    fuse_quant = jnp.issubdtype(x.dtype, jnp.floating)
    has_bias = bias is not None

    grid = (r // tile_r, c // tile_c, k // tile_k)
    kernel = functools.partial(
        _fused_kernel,
        operand_bits=operand_bits,
        fuse_quant=fuse_quant,
        has_bias=has_bias,
        activation=activation,
        out_dtype=out_dtype,
        slice_bits=slice_bits,
        num_slices=num_slices,
        n_chunk=n_chunk,
        adc_bits=adc_bits,
        noise_sigma=noise_sigma,
        filter_alpha=filter_alpha,
        intermod_eps=intermod_eps,
        crossweight_eps=crossweight_eps,
        valid_chunks=valid_chunks,
    )
    in_specs = [
        pl.BlockSpec(memory_space=pltpu.SMEM),  # x_scale
        pl.BlockSpec((tile_r, tile_k), lambda i, j, kk: (i, kk)),
        pl.BlockSpec((tile_k, tile_c), lambda i, j, kk: (kk, j)),
        pl.BlockSpec((1, tile_c), lambda i, j, kk: (0, j)),  # w_scale
    ]
    args = [
        jnp.asarray(x_scale, jnp.float32).reshape(1),
        x,
        wq,
        w_scale.astype(jnp.float32).reshape(1, c),
    ]
    if has_bias:
        in_specs.append(pl.BlockSpec((1, tile_c), lambda i, j, kk: (0, j)))
        args.append(bias.astype(jnp.float32).reshape(1, c))
    if analog:
        if seed is None:
            seed = jnp.zeros((1,), jnp.int32)
        in_specs.insert(0, pl.BlockSpec(memory_space=pltpu.SMEM))
        args.insert(0, jnp.asarray(seed, jnp.int32).reshape(1))
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((tile_r, tile_c), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((r, c), out_dtype),
        scratch_shapes=[pltpu.VMEM((tile_r, tile_c), jnp.int32)],
        compiler_params=compat.pallas_tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        ),
        interpret=interpret,
    )(*args)
