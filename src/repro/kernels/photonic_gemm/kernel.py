"""Pallas TPU kernel for the photonic (bit-sliced, psum-chunked) GEMM.

TPU-native adaptation of the paper's DPU datapath (DESIGN.md §3):

* the DPE size ``N`` becomes the psum chunk along the contraction dim — each
  chunk's int32 partial sum models one analog summation + ADC event and can
  be saturated to ``adc_bits`` like the real converter;
* the fan-out ``M`` becomes the output-column tile — ``M`` parallel DPEs map
  onto MXU output columns;
* operand bit-slices (``ceil(operand_bits/B)`` per operand) are extracted
  *inside* the kernel from int8 residents of VMEM, so HBM traffic stays int8
  (one read per operand) while the MXU consumes one slice-pair per pass —
  mirroring the temporal passes of the photonic DPU.

Blocking: grid ``(R/TR, C/TC, K/TK)`` with the K axis innermost so the output
tile stays resident in VMEM and accumulates across K-tiles (standard Pallas
matmul accumulation).  ``TK`` must be a multiple of ``n_chunk``; MXU-aligned
tiles (multiples of 128) are used when ADC fidelity is off (chunking is then
numerically irrelevant), and exact-N chunks when it is on.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import compat


def _kernel(
    x_ref,
    w_ref,
    out_ref,
    *,
    slice_bits: int,
    num_slices: int,
    n_chunk: int,
    adc_bits: Optional[int],
):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    x = x_ref[...].astype(jnp.int32)  # (TR, TK)
    w = w_ref[...].astype(jnp.int32)  # (TK, TC)
    tr, tk = x.shape
    _, tc = w.shape
    chunks = tk // n_chunk

    sgn_x, mag_x = jnp.sign(x), jnp.abs(x)
    sgn_w, mag_w = jnp.sign(w), jnp.abs(w)
    mask = (1 << slice_bits) - 1

    acc = jnp.zeros((tr, tc), jnp.int32)
    for si in range(num_slices):
        xs = sgn_x * ((mag_x >> (slice_bits * si)) & mask)
        for ti in range(num_slices):
            ws = sgn_w * ((mag_w >> (slice_bits * ti)) & mask)
            shift = slice_bits * (si + ti)
            if adc_bits is None and chunks >= 1:
                # Ideal ADC: chunk boundaries are numerically irrelevant —
                # one MXU pass over the whole K-tile.
                psum = jax.lax.dot_general(
                    xs,
                    ws,
                    (((1,), (0,)), ((), ())),
                    preferred_element_type=jnp.int32,
                )
                acc = acc + (psum << shift)
            else:
                # DPU-faithful: saturate each N-size chunk psum at the ADC.
                lim = 2 ** (adc_bits - 1) - 1
                for g in range(chunks):
                    sl = slice(g * n_chunk, (g + 1) * n_chunk)
                    psum = jax.lax.dot_general(
                        xs[:, sl],
                        ws[sl, :],
                        (((1,), (0,)), ((), ())),
                        preferred_element_type=jnp.int32,
                    )
                    psum = jnp.clip(psum, -lim, lim)
                    acc = acc + (psum << shift)
    out_ref[...] += acc


@functools.partial(
    jax.jit,
    static_argnames=(
        "slice_bits",
        "num_slices",
        "n_chunk",
        "adc_bits",
        "tile_r",
        "tile_c",
        "tile_k",
        "interpret",
    ),
)
def photonic_gemm_pallas(
    xq: jax.Array,  # (R, K) int8, R % tile_r == 0, K % tile_k == 0
    wq: jax.Array,  # (K, C) int8, C % tile_c == 0
    *,
    slice_bits: int = 4,
    num_slices: int = 2,
    n_chunk: int = 128,
    adc_bits: Optional[int] = None,
    tile_r: int = 128,
    tile_c: int = 128,
    tile_k: int = 512,
    interpret: bool = False,
) -> jax.Array:
    r, k = xq.shape
    _, c = wq.shape
    assert r % tile_r == 0 and c % tile_c == 0 and k % tile_k == 0, (
        xq.shape,
        wq.shape,
        (tile_r, tile_c, tile_k),
    )
    assert tile_k % n_chunk == 0, (tile_k, n_chunk)

    grid = (r // tile_r, c // tile_c, k // tile_k)
    kernel = functools.partial(
        _kernel,
        slice_bits=slice_bits,
        num_slices=num_slices,
        n_chunk=n_chunk,
        adc_bits=adc_bits,
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_r, tile_k), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((tile_k, tile_c), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((tile_r, tile_c), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((r, c), jnp.int32),
        compiler_params=compat.pallas_tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        ),
        interpret=interpret,
    )(xq, wq)
