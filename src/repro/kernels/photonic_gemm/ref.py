"""Pure-jnp oracle for the photonic GEMM kernel.

Implements exactly the kernel's semantics — signed-magnitude bit-slicing,
DPE-size (N) psum chunking with optional ADC saturation, shift-add recombine —
with no Pallas, no tiling.  Used by tests as the gold reference and by the
models as the portable fallback backend.

With a :class:`repro.noise.ChannelModel` the oracle applies the full analog
signal chain per slice-pair pass, using the same seed/stream derivation as
``repro.core.dpu.dpu_int_gemm`` (the two are bitwise equal under noise); the
Pallas kernel draws its noise from tile-local streams and agrees with the
oracle *statistically* (mean/variance), not bitwise.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.noise.channel import ChannelModel, analog_pass_psums
from repro.noise.stages import fold_seed


def slice_decompose(q: jax.Array, slice_bits: int, num_slices: int) -> list:
    """Signed-magnitude slices: sum_s out[s] * 2**(slice_bits*s) == q."""
    qi = q.astype(jnp.int32)
    sgn = jnp.sign(qi)
    mag = jnp.abs(qi)
    mask = (1 << slice_bits) - 1
    return [sgn * ((mag >> (slice_bits * s)) & mask) for s in range(num_slices)]


def photonic_gemm_ref(
    xq: jax.Array,  # (R, K) int8
    wq: jax.Array,  # (K, C) int8
    *,
    slice_bits: int = 4,
    num_slices: int = 2,
    n_chunk: int = 128,
    adc_bits: Optional[int] = None,
    channel: Optional[ChannelModel] = None,
    seed: Optional[jax.Array] = None,  # uint32; required if channel has noise
) -> jax.Array:
    """Reference int32 GEMM through the DPU datapath."""
    r, k = xq.shape
    _, c = wq.shape
    analog = channel is not None and channel.analog
    if analog and channel.detector_sigma_lsb > 0.0 and seed is None:
        raise ValueError("channel with detector noise requires a seed")
    if channel is not None and channel.adc_bits is not None:
        adc_bits = channel.adc_bits
    pad = (-k) % n_chunk
    if pad:
        xq = jnp.pad(xq, ((0, 0), (0, pad)))
        wq = jnp.pad(wq, ((0, pad), (0, 0)))
    kp = k + pad
    chunks = kp // n_chunk

    x_sl = slice_decompose(xq, slice_bits, num_slices)
    w_sl = slice_decompose(wq, slice_bits, num_slices)

    out = jnp.zeros((r, c), jnp.int32)
    for si in range(num_slices):
        xs = x_sl[si].reshape(r, chunks, n_chunk)
        for ti in range(num_slices):
            ws = w_sl[ti].reshape(chunks, n_chunk, c)
            shift = slice_bits * (si + ti)
            if analog:
                pass_seed = fold_seed(
                    seed if seed is not None else jnp.uint32(0),
                    si * num_slices + ti,
                )
                psum = analog_pass_psums(xs, ws, channel, pass_seed)
            else:
                psum = jnp.einsum(
                    "rgn,gnc->rgc", xs, ws, preferred_element_type=jnp.int32
                )
                if adc_bits is not None:
                    lim = 2 ** (adc_bits - 1) - 1
                    psum = jnp.clip(psum, -lim, lim)
            out = out + (psum.sum(axis=1) << shift)
    return out


def exact_int_gemm(xq: jax.Array, wq: jax.Array) -> jax.Array:
    """The ideal integer GEMM (what the DPU must equal when ideal)."""
    return jnp.matmul(
        xq.astype(jnp.int32), wq.astype(jnp.int32), preferred_element_type=jnp.int32
    )
