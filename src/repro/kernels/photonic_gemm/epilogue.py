"""Fused GEMM shoulder work: the prologue/epilogue math shared by every
photonic backend.

The photonic GEMM proper is integer-in / int32-out, but every call site
wraps it in the same digital shoulder: quantize the streaming activation
on the way in, rescale the int32 accumulator by ``sx * w_scale`` (plus
optional bias and activation) on the way out.  Left as separate XLA ops
those shoulders dominate the dispatch count of a decode step (the
roofline gap ``benchmarks/roofline_report.py`` measures); fused into the
Pallas kernel they ride in the same VMEM residency as the GEMM.

This module is the *single definition* of that shoulder math.  The Pallas
kernel applies :func:`quantize_tile` / :func:`apply_epilogue` per tile,
the jnp oracle and the engine apply them to whole arrays — elementwise
identical ops, which is what makes the fused path bitwise-equal to the
unfused one under an ideal channel (DESIGN.md §14).

Bitwise fine print: the rescale stage is a pure multiply chain, so it is
contraction-free and bitwise-stable across eager/jit/backends — the full
historical engine contract carries over unchanged.  The *bias add* and
*activation* stages contain float add-of-multiply patterns that LLVM
contracts into FMAs inside compiled fusion regions (invisible at HLO
level, immune to ``optimization_barrier``), so their last ulp can differ
between compilation regimes — exactly as the pre-fusion digital
``y + b`` in ``models/common.py::dense`` already did.  The guarantee for
those stages is therefore *one shared op sequence* (this module) and
exact equality within a matching regime; the engine jit-aligns the ref
backend's epilogue with the Pallas kernel so the backends agree bitwise
in every calling context.

Deliberately a leaf: imports ``jax`` only, so it is importable from
``repro.kernels`` (below the engine) and re-exportable from
``repro.photonic`` (above it) without a cycle.
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

# Activation table — the *same callables* everywhere (including what the
# digital models applied post-GEMM before fusion existed), so the fused
# epilogue and a digital application are the same op sequence.
ACTIVATIONS = {
    "gelu": jax.nn.gelu,
    "silu": jax.nn.silu,
}


@dataclasses.dataclass(frozen=True)
class EpilogueSpec:
    """What the fused GEMM epilogue applies to the int32 accumulator.

    The order is fixed (and bitwise-load-bearing): accumulator ``->`` f32
    ``-> * sx -> * w_scale[col] -> + bias -> activation``, exactly the op
    sequence the historical unfused path ran (``out.astype(f32) * sx *
    w_scale[None, :]`` then the digital bias add).  Frozen + hashable so
    it rides through ``jit`` closures and ``custom_vjp`` static metadata.
    """

    bias: bool = False
    activation: Optional[str] = None  # None | "gelu" | "silu"

    def __post_init__(self):
        if self.activation is not None and self.activation not in ACTIVATIONS:
            raise ValueError(
                f"activation={self.activation!r} is not one of "
                f"{(None, *sorted(ACTIVATIONS))}"
            )


class EpilogueArgs(NamedTuple):
    """Runtime operands of one fused-epilogue GEMM call.

    ``x_scale`` is the activation quantization scale (scalar, f32) — when
    the paired activation operand is still *float*, the Pallas backend
    quantizes it in-kernel with this scale (:func:`quantize_tile`); the
    other backends apply :func:`repro.core.dpu.quantize_with_scale`
    digitally, which is the same op sequence.  ``w_scale`` is the
    per-column dequant scale ``(C,)``; ``bias`` is ``(C,)`` or ``None``
    (must agree with ``spec.bias``).
    """

    spec: EpilogueSpec
    x_scale: jax.Array
    w_scale: jax.Array
    bias: Optional[jax.Array] = None


class Epilogue(NamedTuple):
    """The user-facing epilogue request of the unified GEMM surface.

    A static :class:`EpilogueSpec` plus its runtime bias operand — what
    ``engine.matmul`` / ``matmul_float`` / ``models.common.dense`` accept
    as ``epilogue=`` (PR-9 API redesign).  ``spec.bias`` must agree with
    ``bias is not None``; :func:`as_epilogue` enforces that eagerly.
    """

    spec: EpilogueSpec
    bias: Optional[jax.Array] = None


def as_epilogue(
    epilogue=None,
    *,
    bias: Optional[jax.Array] = None,
    activation: Optional[str] = None,
) -> "tuple[EpilogueSpec, Optional[jax.Array]]":
    """Normalize the unified ``epilogue=`` surface to ``(spec, bias)``.

    The one resolution point for the GEMM surface's epilogue request:

    * ``epilogue=EpilogueSpec(...)`` — bias-free spec (``spec.bias`` must
      be False: the spec alone carries no bias operand);
    * ``epilogue=Epilogue(spec, bias)`` — spec + bias operand;
    * legacy ``bias=`` / ``activation=`` keywords (deprecation shims on
      the engine surface) — folded into a spec exactly as the historical
      call sites did, so shimmed calls stay bitwise-identical;
    * nothing — the no-epilogue spec.

    Mixing ``epilogue=`` with the legacy keywords raises ``TypeError``
    eagerly (one spelling per call site; RPR008's blessed form is
    ``epilogue=``).
    """
    if epilogue is None:
        return EpilogueSpec(bias=bias is not None, activation=activation), bias
    if bias is not None or activation is not None:
        raise TypeError(
            "pass either epilogue= or the legacy bias=/activation= "
            "keywords, not both"
        )
    if isinstance(epilogue, Epilogue):
        spec, b = epilogue
        if not isinstance(spec, EpilogueSpec):
            raise TypeError(
                f"Epilogue.spec must be an EpilogueSpec, got "
                f"{type(spec).__name__}"
            )
        if spec.bias != (b is not None):
            raise TypeError(
                f"Epilogue spec.bias={spec.bias} disagrees with its bias "
                f"operand ({'present' if b is not None else 'absent'})"
            )
        return spec, b
    if isinstance(epilogue, EpilogueSpec):
        if epilogue.bias:
            raise TypeError(
                "EpilogueSpec(bias=True) carries no bias operand; pass "
                "Epilogue(spec, bias) instead"
            )
        return epilogue, None
    raise TypeError(
        f"epilogue must be an EpilogueSpec or Epilogue, got "
        f"{type(epilogue).__name__}"
    )


def quantize_tile(x: jax.Array, scale: jax.Array, qmax: float) -> jax.Array:
    """The in-kernel image of ``quantize_symmetric``'s rounding step.

    ``scale`` is traced (never a constant), so the division is the blessed
    second half of the reciprocal-multiply idiom (RPR005) and rounds
    identically eager vs compiled.  Elementwise => applying it per Pallas
    tile equals applying it to the whole array; zero padding quantizes to
    zero, so padded tiles stay hash- and value-neutral.
    """
    return jnp.clip(jnp.round(x / scale), -qmax, qmax).astype(jnp.int32)


def apply_epilogue(
    acc: jax.Array,  # (..., C) int32 accumulator (or a (TR, TC) tile of it)
    x_scale: jax.Array,  # scalar f32
    w_scale: jax.Array,  # (C,) or (1, TC) f32 — broadcasts over rows
    bias: Optional[jax.Array],  # (C,) / (1, TC) f32, or None
    spec: EpilogueSpec,
) -> jax.Array:
    """int32 accumulator -> rescale -> optional bias -> optional activation.

    Left-associated multiply order matches the historical unfused dequant
    (``acc.astype(f32) * sx * w_scale``) bit-for-bit; bias and activation
    run in f32 before the caller's output cast.  The rescale stage is
    contraction-free (multiplies only); the bias/activation stages are
    subject to FMA contraction, so their bitwise guarantee is per
    compilation regime (see the module docstring).
    """
    y = acc.astype(jnp.float32) * x_scale * w_scale
    return apply_bias_activation(y, bias, spec.activation)


def apply_bias_activation(
    y: jax.Array, bias: Optional[jax.Array], activation: Optional[str]
) -> jax.Array:
    """The bias/activation tail of the epilogue alone, for callers that
    already hold the rescaled float output (the shard-map bodies rescale
    inside the collective; same ops as the fused kernel's tail)."""
    if bias is not None:
        y = y + bias.astype(jnp.float32)
    if activation is not None:
        y = ACTIVATIONS[activation](y)
    return y
