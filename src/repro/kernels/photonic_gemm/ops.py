"""Public jit'd entry points for the photonic GEMM kernel.

Since the ``repro.photonic`` engine refactor these are *thin
compatibility wrappers*: both functions delegate to
:class:`repro.photonic.engine.PhotonicEngine` with ``site=None`` (no
site folding), which reproduces the pre-engine behavior bit-for-bit —
same backend dispatch, same tiling, same seed derivation.  New code
should use the engine directly (per-site routing, prepacked weights,
threaded PRNG keys).

``photonic_gemm(x, w, cfg)`` — float in/out, quantize → kernel → dequantize.
Backend selection:

* ``"pallas"``   — the Pallas TPU kernel (interpret mode on CPU hosts);
* ``"ref"``      — the pure-jnp oracle (portable, differentiably wrapped);
* ``"exact"``    — plain int GEMM of the quantized operands (the ideal the
                   DPU converges to; useful as an upper bound in tests).

Analog channel semantics (DESIGN.md §8): the backends honour
``cfg.effective_channel()``.  ``"ref"`` is bitwise-equal to
``repro.core.dpu.dpu_int_gemm`` under noise (same stream derivation);
``"pallas"`` injects noise in-kernel from tile-local streams and agrees
with the oracle statistically.  ``"exact"`` ignores the channel by design.
Noisy calls need ``prng_key`` or ``cfg.noise_seed`` (deterministic: same
source => same result for a fixed backend and tiling).
"""

from __future__ import annotations

from typing import Optional

import jax

from repro.core.dpu import DPUConfig
from repro.photonic.engine import engine_for


def photonic_gemm_int(
    xq: jax.Array,
    wq: jax.Array,
    cfg: DPUConfig,
    *,
    backend: str = "pallas",
    interpret: Optional[bool] = None,
    tile_r: int = 128,
    tile_c: int = 128,
    prng_key: Optional[jax.Array] = None,
) -> jax.Array:
    """Integer-level DPU GEMM with automatic padding to kernel tiles."""
    eng = engine_for(cfg, backend)
    return eng.int_gemm(
        xq,
        wq,
        prng_key=prng_key,
        interpret=interpret,
        tile_r=tile_r,
        tile_c=tile_c,
    )


def photonic_gemm(
    x: jax.Array,
    w: jax.Array,
    cfg: DPUConfig = DPUConfig(),
    backend: str = "pallas",
    prng_key: Optional[jax.Array] = None,
) -> jax.Array:
    """Float GEMM through the photonic DPU. Differentiable via STE."""
    return engine_for(cfg, backend).matmul_float(x, w, prng_key=prng_key)
