"""Public jit'd entry points for the photonic GEMM kernel.

``photonic_gemm(x, w, cfg)`` — float in/out, quantize → kernel → dequantize.
Backend selection:

* ``"pallas"``   — the Pallas TPU kernel (interpret mode on CPU hosts);
* ``"ref"``      — the pure-jnp oracle (portable, differentiably wrapped);
* ``"exact"``    — plain int GEMM of the quantized operands (the ideal the
                   DPU converges to; useful as an upper bound in tests).

Analog channel semantics (DESIGN.md §8): the backends honour
``cfg.effective_channel()``.  ``"ref"`` is bitwise-equal to
``repro.core.dpu.dpu_int_gemm`` under noise (same stream derivation);
``"pallas"`` injects noise in-kernel from tile-local streams and agrees
with the oracle statistically.  ``"exact"`` ignores the channel by design.
Noisy calls need ``prng_key`` or ``cfg.noise_seed`` (deterministic: same
source => same result for a fixed backend and tiling).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.dpu import DPUConfig, quantize_symmetric
from repro.kernels.photonic_gemm.kernel import photonic_gemm_pallas
from repro.kernels.photonic_gemm.ref import exact_int_gemm, photonic_gemm_ref
from repro.noise.stages import data_tweak, key_zero_cotangent


def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


def photonic_gemm_int(
    xq: jax.Array,
    wq: jax.Array,
    cfg: DPUConfig,
    *,
    backend: str = "pallas",
    interpret: Optional[bool] = None,
    tile_r: int = 128,
    tile_c: int = 128,
    prng_key: Optional[jax.Array] = None,
) -> jax.Array:
    """Integer-level DPU GEMM with automatic padding to kernel tiles."""
    if backend == "exact":
        return exact_int_gemm(xq, wq)

    n = cfg.n
    channel = cfg.effective_channel()
    analog = channel is not None and channel.analog
    adc_bits = channel.adc_bits if channel is not None else cfg.adc_bits
    noisy = analog and channel.detector_sigma_lsb > 0.0
    # Same seed derivation as dpu_int_gemm (content tweak included) so the
    # "ref" backend stays bitwise-equal to the oracle.
    seed = (
        data_tweak(cfg.noise_seed_array(prng_key), xq, wq) if noisy else None
    )

    if backend == "ref":
        return photonic_gemm_ref(
            xq,
            wq,
            slice_bits=cfg.bits,
            num_slices=cfg.num_slices,
            n_chunk=n,
            adc_bits=adc_bits,
            channel=channel,
            seed=seed,
        )

    assert backend == "pallas", backend
    if interpret is None:
        interpret = _on_cpu()
    r, k = xq.shape
    _, c = wq.shape
    if adc_bits is None and not analog:
        # Chunking numerically irrelevant -> MXU-aligned tiles.
        n_chunk = 128
        tile_k = 512 if k >= 512 else _round_up(max(k, 128), 128)
        n_chunk = min(n_chunk, tile_k)
    else:
        # DPU-faithful chunking at the achievable DPE size N.
        n_chunk = n
        per_tile = max(1, 512 // n)
        tile_k = n * per_tile
    tile_r = min(tile_r, _round_up(r, 8))
    tile_c = min(tile_c, _round_up(c, 128))

    rp, kp, cp = _round_up(r, tile_r), _round_up(k, tile_k), _round_up(c, tile_c)
    xp = jnp.pad(xq, ((0, rp - r), (0, kp - k)))
    wp = jnp.pad(wq, ((0, kp - k), (0, cp - c)))
    ch = channel
    out = photonic_gemm_pallas(
        xp,
        wp,
        None if seed is None else seed.astype(jnp.int32).reshape(1),
        slice_bits=cfg.bits,
        num_slices=cfg.num_slices,
        n_chunk=n_chunk,
        adc_bits=adc_bits,
        noise_sigma=ch.detector_sigma_lsb if analog else 0.0,
        filter_alpha=ch.filter_alpha if analog else 0.0,
        intermod_eps=ch.intermod_eps if analog else 0.0,
        crossweight_eps=ch.crossweight_eps if analog else 0.0,
        valid_chunks=-(-k // n_chunk) if noisy else None,
        tile_r=tile_r,
        tile_c=tile_c,
        tile_k=tile_k,
        interpret=interpret,
    )
    return out[:r, :c]


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3))
def _photonic_gemm(
    x: jax.Array,
    w: jax.Array,
    cfg: DPUConfig,
    backend: str,
    prng_key,
) -> jax.Array:
    return _photonic_gemm_fwd_impl(x, w, cfg, backend, prng_key)


def _photonic_gemm_fwd_impl(x, w, cfg, backend, prng_key):
    lead = x.shape[:-1]
    xr = x.reshape(-1, x.shape[-1])
    xq, sx = quantize_symmetric(xr, cfg.operand_bits)
    wq, sw = quantize_symmetric(w, cfg.operand_bits, axis=0)
    out = photonic_gemm_int(xq, wq, cfg, backend=backend, prng_key=prng_key)
    y = out.astype(jnp.float32) * sx * sw
    return y.reshape(*lead, w.shape[1]).astype(x.dtype)


def _fwd(x, w, cfg, backend, prng_key):
    return _photonic_gemm_fwd_impl(x, w, cfg, backend, prng_key), (x, w, prng_key)


def _bwd(cfg, backend, res, g):
    x, w, prng_key = res
    g2 = g.reshape(-1, g.shape[-1]).astype(jnp.float32)
    x2 = x.reshape(-1, x.shape[-1]).astype(jnp.float32)
    dx = (g2 @ w.astype(jnp.float32).T).reshape(x.shape).astype(x.dtype)
    dw = (x2.T @ g2).astype(w.dtype)
    return dx, dw, key_zero_cotangent(prng_key)


_photonic_gemm.defvjp(_fwd, _bwd)


def photonic_gemm(
    x: jax.Array,
    w: jax.Array,
    cfg: DPUConfig = DPUConfig(),
    backend: str = "pallas",
    prng_key: Optional[jax.Array] = None,
) -> jax.Array:
    """Float GEMM through the photonic DPU. Differentiable via STE."""
    return _photonic_gemm(x, w, cfg, backend, prng_key)
