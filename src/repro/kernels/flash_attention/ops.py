"""jit'd wrapper: layout handling + padding for the flash-attention kernel."""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_pallas


def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m


def flash_attention(
    q: jax.Array,  # (B, Tq, H, hd)
    k: jax.Array,  # (B, Tk, KV, hd)
    v: jax.Array,  # (B, Tk, KV, hd)
    *,
    causal: bool = True,
    q_offset: int = 0,
    kv_valid: Optional[int] = None,
    scale: Optional[float] = None,
    bq: int = 128,
    bk: int = 128,
    interpret: Optional[bool] = None,
) -> jax.Array:
    b, tq, h, hd = q.shape
    _, tk, kvh, _ = k.shape
    scale = scale if scale is not None else hd ** -0.5
    kv_valid = tk if kv_valid is None else kv_valid
    if interpret is None:
        interpret = jax.default_backend() == "cpu"

    bq = min(bq, _round_up(tq, 8))
    bk = min(bk, _round_up(tk, 8))
    tq_p, tk_p = _round_up(tq, bq), _round_up(tk, bk)
    qt = jnp.pad(q, ((0, 0), (0, tq_p - tq), (0, 0), (0, 0))).transpose(0, 2, 1, 3)
    kt = jnp.pad(k, ((0, 0), (0, tk_p - tk), (0, 0), (0, 0))).transpose(0, 2, 1, 3)
    vt = jnp.pad(v, ((0, 0), (0, tk_p - tk), (0, 0), (0, 0))).transpose(0, 2, 1, 3)

    out = flash_attention_pallas(
        qt, kt, vt,
        scale=scale,
        causal=causal,
        q_offset=q_offset,
        kv_valid=min(kv_valid, tk),
        n_rep=h // kvh,
        bq=bq,
        bk=bk,
        interpret=interpret,
    )
    return out.transpose(0, 2, 1, 3)[:, :tq]
