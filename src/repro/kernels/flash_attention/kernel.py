"""Flash-attention forward Pallas kernel (TPU target, interpret-validated).

Grid ``(B, H, Tq/bq, Tk/bk)`` with the KV axis innermost; the output tile
and the online-softmax state (m, l, acc) live in VMEM scratch across KV
steps, so the ``Tq x Tk`` score/probability matrices NEVER reach HBM — the
structural basis for the §Perf claim that attention-score HBM traffic is
removable (compare ``repro.models.attention.chunked_attention``, whose
scanned accumulators round-trip HBM every KV chunk).

GQA in the index map: query head h reads kv head ``h // n_rep``.  Causal and
kv-validity masks are computed on block coordinates.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import compat


def _kernel(
    q_ref,    # (1, 1, bq, hd)
    k_ref,    # (1, 1, bk, hd)
    v_ref,    # (1, 1, bk, hd)
    o_ref,    # (1, 1, bq, hd)
    m_ref,    # (bq,)     scratch f32
    l_ref,    # (bq,)     scratch f32
    acc_ref,  # (bq, hd)  scratch f32
    *,
    scale: float,
    causal: bool,
    q_offset: int,
    kv_valid: int,
    bq: int,
    bk: int,
    n_k: int,
):
    iq = pl.program_id(2)
    kk = pl.program_id(3)

    @pl.when(kk == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, -1e30)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32) * scale       # (bq, hd)
    k = k_ref[0, 0].astype(jnp.float32)               # (bk, hd)
    v = v_ref[0, 0].astype(jnp.float32)
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )                                                  # (bq, bk)

    kv_pos = kk * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    valid = kv_pos < kv_valid
    if causal:
        q_pos = q_offset + iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        valid &= kv_pos <= q_pos
    s = jnp.where(valid, s, -1e30)

    m_prev, l_prev, acc_prev = m_ref[...], l_ref[...], acc_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_new = l_prev * corr + p.sum(axis=1)
    acc_new = acc_prev * corr[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    m_ref[...] = m_new
    l_ref[...] = l_new
    acc_ref[...] = acc_new

    @pl.when(kk == n_k - 1)
    def _finalize():
        o_ref[0, 0] = (
            acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)[:, None]
        ).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=(
        "scale", "causal", "q_offset", "kv_valid", "n_rep", "bq", "bk", "interpret"
    ),
)
def flash_attention_pallas(
    q: jax.Array,  # (B, H, Tq, hd)
    k: jax.Array,  # (B, KV, Tk, hd)
    v: jax.Array,  # (B, KV, Tk, hd)
    *,
    scale: float,
    causal: bool,
    q_offset: int,
    kv_valid: int,
    n_rep: int,
    bq: int = 128,
    bk: int = 128,
    interpret: bool = False,
) -> jax.Array:
    b, h, tq, hd = q.shape
    _, kvh, tk, _ = k.shape
    assert h == kvh * n_rep, (h, kvh, n_rep)
    assert tq % bq == 0 and tk % bk == 0, (tq, tk, bq, bk)
    n_k = tk // bk

    kernel = functools.partial(
        _kernel,
        scale=scale,
        causal=causal,
        q_offset=q_offset,
        kv_valid=kv_valid,
        bq=bq,
        bk=bk,
        n_k=n_k,
    )
    return pl.pallas_call(
        kernel,
        grid=(b, h, tq // bq, n_k),
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda bb, hh, iq, kk: (bb, hh, iq, 0)),
            pl.BlockSpec(
                (1, 1, bk, hd), lambda bb, hh, iq, kk: (bb, hh // n_rep, kk, 0)
            ),
            pl.BlockSpec(
                (1, 1, bk, hd), lambda bb, hh, iq, kk: (bb, hh // n_rep, kk, 0)
            ),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, hd), lambda bb, hh, iq, kk: (bb, hh, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, tq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, hd), jnp.float32),
        ],
        compiler_params=compat.pallas_tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary")
        ),
        interpret=interpret,
    )(q, k, v)
