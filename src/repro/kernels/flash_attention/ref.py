"""Pure-jnp oracle for the flash-attention kernel: exact softmax attention
with GQA head grouping, causal masking and kv-length masking."""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def attention_ref(
    q: jax.Array,  # (B, Tq, H, hd)
    k: jax.Array,  # (B, Tk, KV, hd)
    v: jax.Array,  # (B, Tk, KV, hd)
    *,
    causal: bool = True,
    q_offset: int = 0,
    kv_valid: Optional[int] = None,
    scale: Optional[float] = None,
) -> jax.Array:
    b, tq, h, hd = q.shape
    _, tk, kvh, _ = k.shape
    rep = h // kvh
    scale = scale if scale is not None else hd ** -0.5
    kf = jnp.repeat(k, rep, axis=2).astype(jnp.float32)
    vf = jnp.repeat(v, rep, axis=2).astype(jnp.float32)
    s = jnp.einsum("bqhd,bshd->bhqs", q.astype(jnp.float32) * scale, kf)
    kv_pos = jnp.arange(tk)
    mask = jnp.ones((tq, tk), bool)
    if causal:
        q_pos = q_offset + jnp.arange(tq)
        mask &= kv_pos[None, :] <= q_pos[:, None]
    if kv_valid is not None:
        mask &= (kv_pos < kv_valid)[None, :]
    s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqs,bshd->bqhd", p, vf)
    return out.astype(q.dtype)
