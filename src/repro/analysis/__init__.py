"""repro.analysis — machine-enforced repo contracts (DESIGN.md §12).

Two levels:

* **Level 1 — AST lint** (:mod:`repro.analysis.core`, rules in
  :mod:`repro.analysis.rules`): six RPR rules codifying the ROADMAP
  conventions — compat isolation (RPR001), single-point org resolution
  (RPR002), engine-only GEMM routing (RPR003), engine-derived randomness
  (RPR004), reciprocal-multiply quantization (RPR005), and the
  tensor_parallel/shard_map nesting ban (RPR006).
* **Level 2 — jaxpr contract passes** (:mod:`repro.analysis.contracts`):
  :class:`ContractChecker` traces a model/engine fn and statically asserts
  the execution contracts — zero weight-sized rounds in decode, exactly
  one psum per routed GEMM on sharded paths, noisy channels untraceable
  without a key source.

CLI: ``python -m repro.analysis`` (the blocking CI lint entry point).
"""

from repro.analysis.contracts import (
    ContractChecker,
    count_primitives,
    count_weight_round_ops,
    iter_eqns,
)
from repro.analysis.core import (
    Finding,
    Rule,
    all_rules,
    check_source,
    register_rule,
    run_all,
)

__all__ = [
    "ContractChecker",
    "Finding",
    "Rule",
    "all_rules",
    "check_source",
    "count_primitives",
    "count_weight_round_ops",
    "iter_eqns",
    "register_rule",
    "run_all",
]
