"""``python -m repro.analysis`` — the blocking CI entry point.

Exit status 0 iff every rule passes on the scanned tree. Formats:

* ``text`` (default) — ``path:line:col: RPRxxx message`` per finding;
* ``github`` — workflow-command annotations rendered inline on PR diffs;
* ``json`` — the full machine-readable report on stdout.

``--report PATH`` additionally writes the JSON report (uploaded as a CI
artifact), independent of the chosen display format.
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path
from typing import List, Optional

from repro.analysis.core import all_rules, default_paths, find_repo_root, run_all


def _build_report(findings, rule_ids) -> dict:
    return {
        "tool": "repro.analysis",
        "rules": [
            {"id": cls.id, "summary": cls.summary, "rationale": cls.rationale}
            for cls in all_rules()
            if rule_ids is None or cls.id in rule_ids
        ],
        "findings": [f.to_dict() for f in findings],
        "count": len(findings),
        "ok": not findings,
    }


def main(argv: Optional[List[str]] = None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="repro invariant checker (AST lint, rules RPR001-RPR006)",
    )
    parser.add_argument(
        "paths",
        nargs="*",
        help="files/directories to scan (default: src tests benchmarks examples)",
    )
    parser.add_argument(
        "--format",
        choices=("text", "github", "json"),
        default="text",
        help="finding output format (default: text)",
    )
    parser.add_argument(
        "--report",
        type=Path,
        default=None,
        metavar="PATH",
        help="also write the JSON report to PATH (CI artifact)",
    )
    parser.add_argument(
        "--select",
        default=None,
        metavar="IDS",
        help="comma-separated rule IDs to run (default: all)",
    )
    parser.add_argument(
        "--root",
        type=Path,
        default=None,
        help="repo root for relative paths (default: nearest pyproject.toml)",
    )
    parser.add_argument(
        "--list-rules", action="store_true", help="print the rule table and exit"
    )
    args = parser.parse_args(argv)

    if args.list_rules:
        for cls in all_rules():
            print(f"{cls.id}  {cls.summary}")
        return 0

    rule_ids = (
        tuple(s.strip().upper() for s in args.select.split(",") if s.strip())
        if args.select
        else None
    )
    root = (args.root or find_repo_root()).resolve()
    findings = run_all(args.paths or None, root=root, rule_ids=rule_ids)
    report = _build_report(findings, rule_ids)

    if args.report is not None:
        args.report.write_text(json.dumps(report, indent=2) + "\n")

    if args.format == "json":
        print(json.dumps(report, indent=2))
    else:
        for f in findings:
            print(f.format_github() if args.format == "github" else f.format_text())
        scanned = args.paths or [
            p.relative_to(root).as_posix() for p in default_paths(root)
        ]
        status = "ok" if not findings else f"{len(findings)} finding(s)"
        print(
            f"repro.analysis: {status} over {', '.join(map(str, scanned))}",
            file=sys.stderr,
        )
    return 1 if findings else 0


if __name__ == "__main__":
    sys.exit(main())
