"""Level-2 contract passes: static assertions over traced jaxprs.

Where Level 1 lints *source*, Level 2 checks the *program JAX actually
traced*: a :class:`ContractChecker` wraps a jaxpr and asserts the
execution contracts the paper results depend on —

* **weight-stationary decode** — a decode step over prepacked params
  contains zero weight-sized ``round`` ops (the quantization work provably
  left the hot path, PR-3);
* **single psum per routed GEMM** — the sharded integer path reduces each
  GEMM's int32 partials exactly once in the digital domain (PR-4);
* **noisy needs a source** — a noisy channel cannot even be traced without
  a key source (``prng_key`` or ``DPUConfig.noise_seed``), so silent
  seed-less noise is unrepresentable (PR-2/PR-3).

The traversal (:func:`iter_eqns`) recurses uniformly through every
sub-jaxpr container — ``pjit``/``scan``/``while``/``cond`` bodies,
``shard_map`` jaxprs, and the closed call jaxprs of ``custom_jvp`` /
``custom_vjp`` — on both the 0.4.30 floor and 0.6.x spellings. The old
``repro.photonic.engine.count_weight_round_ops`` walker missed closed-call
sub-jaxprs on the floor; it now lives here (re-exported there). Checkers
built with :meth:`ContractChecker.trace` also expose the HLO-level passes
of ``repro.launch.hlo_analysis`` (collective wire bytes, GEMM traffic)
over the *same compiled call*, so jaxpr- and HLO-level assertions agree
on what program they describe.

Only ``jax`` + ``numpy`` are imported, so this module is usable from the
engine without an import cycle.
"""

from __future__ import annotations

from typing import Any, Callable, Dict, Iterator, Optional

import jax
import numpy as np


class ContractViolation(AssertionError):
    """A traced program broke one of the repo's execution contracts."""


def _as_jaxpr(jaxpr: Any):
    """Accept a Jaxpr, a ClosedJaxpr, or anything exposing one of them."""
    if hasattr(jaxpr, "eqns"):
        return jaxpr
    if hasattr(jaxpr, "jaxpr"):
        return jaxpr.jaxpr
    raise TypeError(f"expected a Jaxpr or ClosedJaxpr, got {type(jaxpr).__name__}")


def _iter_param(value: Any) -> Iterator[Any]:
    """Yield every (sub-)jaxpr reachable from one eqn param value.

    Handles ClosedJaxpr (pjit's ``jaxpr``, custom_jvp/vjp's ``call_jaxpr``,
    scan/while bodies), raw Jaxpr (shard_map), and list/tuple containers
    (cond's ``branches``). Callables (vjp thunks) are opaque and skipped.
    """
    if hasattr(value, "jaxpr") and hasattr(value.jaxpr, "eqns"):
        yield value.jaxpr
    elif hasattr(value, "eqns"):
        yield value
    elif isinstance(value, (list, tuple)):
        for item in value:
            yield from _iter_param(item)


def iter_eqns(jaxpr: Any) -> Iterator[Any]:
    """Every equation in ``jaxpr`` and, recursively, in all sub-jaxprs."""
    jaxpr = _as_jaxpr(jaxpr)
    for eqn in jaxpr.eqns:
        yield eqn
        for value in eqn.params.values():
            for sub in _iter_param(value):
                yield from iter_eqns(sub)


def count_primitives(jaxpr: Any, name: str, *, substring: bool = False) -> int:
    """Occurrences of primitive ``name`` across the whole (sub-)jaxpr tree."""
    n = 0
    for eqn in iter_eqns(jaxpr):
        pname = eqn.primitive.name
        if pname == name or (substring and name in pname):
            n += 1
    return n


def count_weight_round_ops(jaxpr: Any, min_size: int) -> int:
    """Rounding ops over arrays of >= ``min_size`` elements, recursing into
    every sub-jaxpr (pjit, scan/while/cond, shard_map, custom_jvp/vjp).

    The weight-stationary acceptance check: a decode step over prepacked
    params must contain ZERO weight-sized rounds — the quantization work
    provably left the hot path rather than merely getting cheaper.
    """
    n = 0
    for eqn in iter_eqns(jaxpr):
        if "round" not in eqn.primitive.name:
            continue
        if any(
            hasattr(v, "aval") and int(np.prod(v.aval.shape or (1,))) >= min_size
            for v in eqn.invars
        ):
            n += 1
    return n


class ContractChecker:
    """Static contract assertions over one traced function.

    Build with :meth:`trace` (or directly from a jaxpr); every assertion
    raises :class:`ContractViolation` with the offending counts, so a
    failing CI run names the broken contract rather than a numeric diff.
    """

    def __init__(self, jaxpr: Any, label: str = "<traced fn>"):
        self.jaxpr = _as_jaxpr(jaxpr)
        self.label = label
        self._compile: Optional[Callable[[], Any]] = None

    @classmethod
    def trace(cls, fn: Callable, *args, label: Optional[str] = None, **kwargs):
        closed = jax.make_jaxpr(fn)(*args, **kwargs)
        self = cls(closed, label=label or getattr(fn, "__name__", "<traced fn>"))
        # Keep a way to lower/compile the same call so the HLO-level passes
        # (launch.hlo_analysis) run over the identical program.
        self._compile = lambda: jax.jit(fn).lower(*args, **kwargs).compile()
        return self

    # -- generic counting ---------------------------------------------------
    def count(self, primitive: str, *, substring: bool = False) -> int:
        return count_primitives(self.jaxpr, primitive, substring=substring)

    def primitive_counts(self) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for eqn in iter_eqns(self.jaxpr):
            out[eqn.primitive.name] = out.get(eqn.primitive.name, 0) + 1
        return out

    # -- contract: weight-stationary decode ---------------------------------
    def weight_round_ops(self, min_size: int) -> int:
        return count_weight_round_ops(self.jaxpr, min_size)

    def assert_zero_weight_rounds(self, min_size: int) -> "ContractChecker":
        n = self.weight_round_ops(min_size)
        if n != 0:
            raise ContractViolation(
                f"{self.label}: weight-stationary contract broken — "
                f"{n} round op(s) over arrays >= {min_size} elements "
                "(prepacked decode must quantize activations only)"
            )
        return self

    # -- contract: one digital psum per routed GEMM --------------------------
    def assert_psum_per_gemm(self, gemms: int) -> "ContractChecker":
        n = self.count("psum")
        if n != gemms:
            raise ContractViolation(
                f"{self.label}: sharded-GEMM contract broken — expected "
                f"exactly {gemms} psum (one per routed GEMM), traced {n}"
            )
        return self

    # -- HLO-level passes (delegated to launch.hlo_analysis) ------------------
    def hlo_text(self) -> str:
        """Compiled HLO of the traced call (``trace()``-built checkers only)."""
        if self._compile is None:
            raise ValueError(
                f"{self.label}: HLO passes need the original callable — "
                "build this checker with ContractChecker.trace(fn, *args)"
            )
        return self._compile().as_text()

    def collective_summary(self) -> Dict[str, float]:
        """Loop-adjusted wire bytes per collective kind, from the HLO."""
        from repro.launch import hlo_analysis

        return hlo_analysis.collective_summary(self.hlo_text())

    def matmul_traffic_bytes(self) -> float:
        """Fusion-optimal HBM-traffic bound for the GEMMs, from the HLO."""
        from repro.launch import hlo_analysis

        return hlo_analysis.matmul_traffic_bytes(self.hlo_text())

    # -- contract: noisy channels need a key source --------------------------
    @staticmethod
    def assert_untraceable_without_source(
        fn: Callable, *args, match: str = "randomness source", **kwargs
    ) -> None:
        """Assert tracing ``fn`` fails with the documented seed-source error.

        A noisy channel with neither ``prng_key`` nor ``noise_seed`` must
        raise at *trace time* — noise with an unpinned seed would silently
        decohere the bitwise-reproducibility story.
        """
        try:
            jax.make_jaxpr(fn)(*args, **kwargs)
        except ValueError as e:
            if match in str(e):
                return
            raise ContractViolation(
                f"tracing raised ValueError, but not the documented "
                f"seed-source error ({match!r}): {e}"
            ) from e
        raise ContractViolation(
            "noisy channel traced without a key source; expected ValueError "
            f"matching {match!r}"
        )
