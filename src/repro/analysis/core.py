"""Level-1 machinery of the invariant checker: rules, findings, the runner.

The repo's conventions (ROADMAP "Conventions", DESIGN.md §12) were
historically enforced by ``rg`` one-liners and reviewer memory.  This
module turns them into *rules*: small AST visitors, each with a stable ID
(``RPR001``...), a one-line summary, and a docs string explaining which
PR-era contract it guards.  Rules are plugins — a module under
``repro.analysis.rules`` defines a :class:`Rule` subclass and registers it
with :func:`register_rule`; the runner, the CLI, and the tests all consume
the same registry.

Escape hatch: a ``# repro: noqa[RPR001]`` (or bare ``# repro: noqa``)
comment on the flagged line suppresses the finding.  The acceptance bar
for the tree itself is *zero* suppressions under ``src/`` — the hatch
exists for vendored snippets and deliberate fixtures, not for code.
"""

from __future__ import annotations

import ast
import dataclasses
import re
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Sequence, Tuple, Type

_NOQA_RE = re.compile(r"#\s*repro:\s*noqa(?:\[([A-Za-z0-9_, ]+)\])?")

# Directories never scanned (caches, VCS internals, build output).
_SKIP_DIRS = {"__pycache__", ".git", ".ruff_cache", "build", "dist", ".eggs"}


@dataclasses.dataclass(frozen=True, order=True)
class Finding:
    """One rule violation, anchored to a repo-relative location."""

    path: str  # repo-relative, posix separators
    line: int
    col: int
    rule: str
    message: str

    def format_text(self) -> str:
        return f"{self.path}:{self.line}:{self.col}: {self.rule} {self.message}"

    def format_github(self) -> str:
        # GitHub workflow-command annotation (rendered inline on the PR diff).
        return (
            f"::error file={self.path},line={self.line},col={self.col},"
            f"title={self.rule}::{self.message}"
        )

    def to_dict(self) -> Dict[str, object]:
        return dataclasses.asdict(self)


class Rule:
    """Base class for a lint rule.

    Subclasses set ``id`` / ``summary`` / ``rationale`` and implement
    :meth:`check`; ``applies_to`` pre-filters by repo-relative path so a
    rule scoped to e.g. ``src/repro/models/`` never walks other files.
    """

    id: str = "RPR000"
    summary: str = ""
    rationale: str = ""

    def applies_to(self, relpath: str) -> bool:
        return True

    def check(self, tree: ast.Module, text: str, relpath: str) -> Iterable[Finding]:
        raise NotImplementedError

    def finding(self, relpath: str, node: ast.AST, message: str) -> Finding:
        return Finding(
            path=relpath,
            line=getattr(node, "lineno", 1),
            col=getattr(node, "col_offset", 0) + 1,
            rule=self.id,
            message=message,
        )


_REGISTRY: Dict[str, Type[Rule]] = {}


def register_rule(cls: Type[Rule]) -> Type[Rule]:
    """Class decorator adding a rule to the shared registry (keyed by ID)."""
    if cls.id in _REGISTRY and _REGISTRY[cls.id] is not cls:
        raise ValueError(f"duplicate rule id {cls.id}")
    _REGISTRY[cls.id] = cls
    return cls


def all_rules() -> Tuple[Type[Rule], ...]:
    """Registered rule classes, sorted by ID (plugins imported on demand)."""
    # Importing the rules package populates the registry exactly once.
    from repro.analysis import rules  # noqa: F401

    return tuple(_REGISTRY[k] for k in sorted(_REGISTRY))


def _noqa_lines(text: str) -> Dict[int, Optional[Tuple[str, ...]]]:
    """line -> suppressed rule IDs (None = all rules) for ``repro: noqa``."""
    out: Dict[int, Optional[Tuple[str, ...]]] = {}
    for i, line in enumerate(text.splitlines(), start=1):
        m = _NOQA_RE.search(line)
        if not m:
            continue
        ids = m.group(1)
        if ids:
            out[i] = tuple(s.strip().upper() for s in ids.split(",") if s.strip())
        else:
            out[i] = None
    return out


def check_source(
    text: str,
    relpath: str,
    rule_ids: Optional[Sequence[str]] = None,
) -> List[Finding]:
    """Run the (selected) rules over one source string.

    The entry point tests use for violation fixtures; ``relpath`` decides
    which path-scoped rules apply, exactly as in a tree run.
    """
    relpath = relpath.replace("\\", "/")
    try:
        tree = ast.parse(text)
    except SyntaxError as e:
        return [
            Finding(
                path=relpath,
                line=e.lineno or 1,
                col=(e.offset or 0) + 1,
                rule="RPR999",
                message=f"syntax error: {e.msg}",
            )
        ]
    suppressed = _noqa_lines(text)
    findings: List[Finding] = []
    for cls in all_rules():
        if rule_ids is not None and cls.id not in rule_ids:
            continue
        rule = cls()
        if not rule.applies_to(relpath):
            continue
        for f in rule.check(tree, text, relpath):
            ids = suppressed.get(f.line, ())
            if ids is None or (ids and f.rule in ids):
                continue
            findings.append(f)
    return sorted(findings)


def iter_python_files(paths: Sequence[Path]) -> Iterable[Path]:
    for p in paths:
        if p.is_file() and p.suffix == ".py":
            yield p
        elif p.is_dir():
            for f in sorted(p.rglob("*.py")):
                if not _SKIP_DIRS.intersection(f.parts):
                    yield f


def default_paths(root: Path) -> List[Path]:
    """The tree the CI job lints: src + tests + benchmarks + examples."""
    return [
        root / d
        for d in ("src", "tests", "benchmarks", "examples")
        if (root / d).is_dir()
    ]


def find_repo_root(start: Optional[Path] = None) -> Path:
    """Nearest ancestor carrying pyproject.toml (fallback: the start dir)."""
    cur = (start or Path.cwd()).resolve()
    for candidate in (cur, *cur.parents):
        if (candidate / "pyproject.toml").is_file():
            return candidate
    return cur


def run_all(
    paths: Optional[Sequence] = None,
    root: Optional[Path] = None,
    rule_ids: Optional[Sequence[str]] = None,
) -> List[Finding]:
    """Lint a file set; returns all findings sorted by (path, line, rule).

    ``paths`` defaults to the repo's ``src``/``tests``/``benchmarks``/
    ``examples`` directories under ``root`` (which defaults to the nearest
    ancestor of cwd holding a pyproject.toml).  An empty return value is
    the machine-checked statement that every convention holds.
    """
    root = Path(root).resolve() if root is not None else find_repo_root()
    targets = [Path(p) for p in paths] if paths else default_paths(root)
    findings: List[Finding] = []
    for f in iter_python_files(targets):
        try:
            rel = f.resolve().relative_to(root).as_posix()
        except ValueError:
            rel = f.as_posix()
        findings.extend(check_source(f.read_text(), rel, rule_ids=rule_ids))
    return sorted(findings)


# ---------------------------------------------------------------------------
# Shared AST helpers used by several rules
# ---------------------------------------------------------------------------
def dotted_name(node: ast.AST) -> Optional[str]:
    """'a.b.c' for a Name/Attribute chain rooted at a Name, else None."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def enclosing_functions(tree: ast.Module) -> Dict[ast.AST, List[ast.AST]]:
    """node -> chain of enclosing FunctionDef/AsyncFunctionDef (outer→inner)."""
    out: Dict[ast.AST, List[ast.AST]] = {}

    def walk(node: ast.AST, stack: List[ast.AST]) -> None:
        out[node] = list(stack)
        is_fn = isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef))
        if is_fn:
            stack = stack + [node]
        for child in ast.iter_child_nodes(node):
            walk(child, stack)

    walk(tree, [])
    return out
