"""RPR006 — no ``tensor_parallel`` context inside a ``shard_map`` body.

``tensor_parallel(mesh, axis)`` installs the *device-level* sharded-GEMM
scope (it enters a mesh and shards via collectives issued by shard_map
wrappers it builds itself); entering it inside an already-manual
``shard_map`` body nests manual collectives and deadlocks or double-reduces.
Inside a shard_map body the blessed scope is ``manual_tp(axis)``, which
only tags the axis for the engine's shard-local channel model.
"""

from __future__ import annotations

import ast
from typing import Dict, Iterable, List

from repro.analysis.core import Finding, Rule, register_rule

_BANNED = "tensor_parallel"


def _callee_name(func: ast.AST) -> str:
    if isinstance(func, ast.Attribute):
        return func.attr
    if isinstance(func, ast.Name):
        return func.id
    return ""


def _uses_banned(node: ast.AST) -> List[ast.AST]:
    hits = []
    for sub in ast.walk(node):
        if isinstance(sub, (ast.Name, ast.Attribute)):
            name = sub.id if isinstance(sub, ast.Name) else sub.attr
            if name == _BANNED:
                hits.append(sub)
    return hits


@register_rule
class ShardMapNestingRule(Rule):
    id = "RPR006"
    summary = "tensor_parallel entered inside a shard_map body"
    rationale = (
        "tensor_parallel is a device-level scope (it builds its own "
        "shard_map wrappers); nesting it under an explicit shard_map body "
        "double-issues collectives. Use manual_tp(axis) inside shard_map "
        "bodies."
    )

    def check(self, tree: ast.Module, text: str, relpath: str) -> Iterable[Finding]:
        # Map function name -> def node, per enclosing scope is overkill for
        # this codebase; module-wide name resolution is sufficient and errs
        # toward flagging.
        defs: Dict[str, ast.AST] = {}
        for node in ast.walk(tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                defs.setdefault(node.name, node)

        for node in ast.walk(tree):
            if not isinstance(node, ast.Call):
                continue
            if _callee_name(node.func) != "shard_map":
                continue
            if not node.args:
                continue
            body = node.args[0]
            target: ast.AST | None = None
            if isinstance(body, ast.Lambda):
                target = body.body
            elif isinstance(body, ast.Name) and body.id in defs:
                target = defs[body.id]
            if target is None:
                continue
            for hit in _uses_banned(target):
                yield self.finding(
                    relpath,
                    hit,
                    "tensor_parallel inside a shard_map body; use "
                    "manual_tp(axis) for in-shard scopes",
                )
