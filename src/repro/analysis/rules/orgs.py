"""RPR002 — org-typed strings resolve through ``repro.orgs.resolve`` only.

Ad-hoc case normalization of an organization/order string is how two call
sites drift apart (the rule's first catch was ``orgs.resolve`` itself
duplicating ``from_order``'s ``.strip().upper()``). The single blessed
normalization site is ``orgs._normalize_order``.
"""

from __future__ import annotations

import ast
import re
from typing import Iterable

from repro.analysis.core import Finding, Rule, dotted_name, register_rule

_CASE_METHODS = frozenset({"upper", "lower", "casefold", "title", "capitalize"})

# Identifier tokens that mark a value as organization-typed. "order" is
# included because in this codebase the four-letter block order *is* the
# organization identity (OrgSpec.from_order / resolve accept it).
_ORG_TOKENS = frozenset(
    {"org", "orgs", "organization", "organizations", "order", "orders", "ordering"}
)
_TOKEN_SPLIT = re.compile(r"[^a-zA-Z0-9]+")


def _is_orgish(node: ast.AST) -> bool:
    dotted = dotted_name(node)
    if dotted is None:
        return False
    tokens = {t.lower() for t in _TOKEN_SPLIT.split(dotted) if t}
    return bool(tokens & _ORG_TOKENS)


@register_rule
class OrgResolutionRule(Rule):
    id = "RPR002"
    summary = "ad-hoc case normalization of an org string outside repro.orgs"
    rationale = (
        "Organization-typed values (order strings like 'ASMW') must flow "
        "through repro.orgs.resolve; hand-rolled .upper()/.lower() "
        "normalization forks the canonicalization logic and silently "
        "diverges from the registry's case/whitespace handling."
    )

    def applies_to(self, relpath: str) -> bool:
        return relpath != "src/repro/orgs.py"

    def check(self, tree: ast.Module, text: str, relpath: str) -> Iterable[Finding]:
        for node in ast.walk(tree):
            if not (
                isinstance(node, ast.Call)
                and isinstance(node.func, ast.Attribute)
                and node.func.attr in _CASE_METHODS
                and not node.args
                and not node.keywords
            ):
                continue
            receiver = node.func.value
            # `org.strip().upper()` — look through chained str methods.
            while (
                isinstance(receiver, ast.Call)
                and isinstance(receiver.func, ast.Attribute)
            ):
                receiver = receiver.func.value
            if _is_orgish(receiver):
                yield self.finding(
                    relpath,
                    node,
                    f"case-normalizing an org-typed value via "
                    f".{node.func.attr}(); route through repro.orgs.resolve",
                )
