"""RPR007 — paged KV memory is touched only through the kv_cache API.

The serving pool's invariants (null block stays zero, blocks zero at
allocation, scatter destinations distinct, ``(block, offset)`` addressing)
all live in ``repro.serving.kv_cache``.  Model and runtime code therefore
consumes the pool opaquely: it may thread ``kv_pool`` / ``block_table``
values through calls and scans, but raw indexing (``kv_pool[...]``,
``block_table[i]``, ``kv_pool.at[...]``) re-implements paged addressing at
the call site and silently breaks those invariants — e.g. writing into the
null block corrupts every request's zero-padding at once.

Axis manipulation (``block_table[None]`` — adding a broadcast axis before a
batched gather) carries no block arithmetic and stays allowed.
"""

from __future__ import annotations

import ast
import re
from typing import Iterable, Optional

from repro.analysis.core import Finding, Rule, register_rule

# Variable names that carry paged-serving memory by convention.
_PAGED_NAME = re.compile(r"(^|_)(kv_pools?|block_tables?)$")

_SCOPED_PREFIXES = ("src/repro/models/", "src/repro/runtime/")


def _paged_base(node: ast.AST) -> Optional[str]:
    """The paged-memory variable name behind an expression, if any —
    handles ``kv_pool``, ``self.kv_pool``, and chained attributes."""
    if isinstance(node, ast.Name) and _PAGED_NAME.search(node.id):
        return node.id
    if isinstance(node, ast.Attribute) and _PAGED_NAME.search(node.attr):
        return node.attr
    return None


def _is_axis_only_index(idx: ast.AST) -> bool:
    """True for pure broadcast-axis indices: ``x[None]``, ``x[None, None]``
    — no block arithmetic, just layout."""
    if isinstance(idx, ast.Constant):
        return idx.value is None
    if isinstance(idx, ast.Tuple):
        return all(_is_axis_only_index(e) for e in idx.elts)
    return False


@register_rule
class PagedKVAccessRule(Rule):
    id = "RPR007"
    summary = "raw paged-KV indexing outside repro.serving.kv_cache"
    rationale = (
        "Models and runtime must go through the kv_cache API "
        "(gather_kv/scatter_kv/zero_blocks/chunk_dest/token_dest); "
        "subscripting kv_pool or block_table re-implements block "
        "addressing and can break the pool invariants (zero null "
        "block, allocation-time zeroing, distinct scatter rows)."
    )

    def applies_to(self, relpath: str) -> bool:
        return relpath.startswith(_SCOPED_PREFIXES)

    def check(self, tree: ast.Module, text: str, relpath: str) -> Iterable[Finding]:
        for node in ast.walk(tree):
            if isinstance(node, ast.Subscript):
                base = _paged_base(node.value)
                if base is not None and not _is_axis_only_index(node.slice):
                    yield self.finding(
                        relpath,
                        node,
                        f"raw indexing of paged memory {base!r}; use the "
                        "repro.serving.kv_cache gather/scatter API",
                    )
            elif isinstance(node, ast.Attribute) and node.attr == "at":
                base = _paged_base(node.value)
                if base is not None:
                    yield self.finding(
                        relpath,
                        node,
                        f"in-place update of paged memory {base!r} via .at[]; "
                        "use repro.serving.kv_cache.scatter_kv/zero_blocks",
                    )
