"""RPR001 — JAX compat-sensitive symbols only inside ``repro/compat.py``.

The symbol inventory is imported from :mod:`repro.compat` itself (the
``COMPAT_SENSITIVE_*`` registry), so adding a shim and banning direct use
of the raw symbol are one edit. Replaces the ROADMAP ``rg`` spot-check.
"""

from __future__ import annotations

import ast
from typing import Iterable

from repro.analysis.core import Finding, Rule, dotted_name, register_rule
from repro.compat import (
    COMPAT_SENSITIVE_ATTRS,
    COMPAT_SENSITIVE_KWARGS,
    COMPAT_SENSITIVE_METHODS,
    COMPAT_SENSITIVE_MODULES,
    COMPAT_SENSITIVE_NAMES,
)

# compat.py holds the shims; test_compat.py exercises the version-sensitive
# surface on purpose.
_EXEMPT = ("src/repro/compat.py", "tests/test_compat.py")


@register_rule
class CompatIsolationRule(Rule):
    id = "RPR001"
    summary = "version-sensitive JAX symbol referenced outside repro.compat"
    rationale = (
        "The runtime supports JAX 0.4.30-0.6.x; symbols that moved or "
        "changed signature across that range (shard_map, AxisType, "
        "AbstractMesh, make_mesh, axis_size, TPUCompilerParams, check_rep, "
        "Compiled.cost_analysis) must be reached through repro.compat so "
        "every call site works on every supported version."
    )

    def applies_to(self, relpath: str) -> bool:
        return relpath not in _EXEMPT

    def check(self, tree: ast.Module, text: str, relpath: str) -> Iterable[Finding]:
        for node in ast.walk(tree):
            if isinstance(node, ast.Import):
                for alias in node.names:
                    if alias.name in COMPAT_SENSITIVE_MODULES:
                        yield self.finding(
                            relpath,
                            node,
                            f"import of version-sensitive module "
                            f"{alias.name!r}; use repro.compat",
                        )
            elif isinstance(node, ast.ImportFrom):
                mod = node.module or ""
                if mod in COMPAT_SENSITIVE_MODULES:
                    yield self.finding(
                        relpath,
                        node,
                        f"import from version-sensitive module {mod!r}; "
                        "use repro.compat",
                    )
                elif mod == "jax" or mod.startswith("jax."):
                    for alias in node.names:
                        if alias.name in COMPAT_SENSITIVE_NAMES:
                            yield self.finding(
                                relpath,
                                node,
                                f"from-import of version-sensitive "
                                f"{alias.name!r} from {mod!r}; import it "
                                "from repro.compat",
                            )
            elif isinstance(node, ast.Attribute):
                dotted = dotted_name(node)
                if dotted in COMPAT_SENSITIVE_ATTRS:
                    yield self.finding(
                        relpath,
                        node,
                        f"{dotted} is version-sensitive; use the "
                        "repro.compat equivalent",
                    )
            elif isinstance(node, ast.Call):
                for kw in node.keywords:
                    if kw.arg in COMPAT_SENSITIVE_KWARGS:
                        yield self.finding(
                            relpath,
                            kw.value,
                            f"keyword {kw.arg!r} is the pre-0.5 spelling; "
                            "compat.shard_map takes check_vma",
                        )
                func = node.func
                if (
                    isinstance(func, ast.Attribute)
                    and func.attr in COMPAT_SENSITIVE_METHODS
                    and not self._is_compat_receiver(func.value)
                ):
                    yield self.finding(
                        relpath,
                        node,
                        f".{func.attr}() return shape is version-dependent; "
                        f"call compat.{func.attr}(...) instead",
                    )

    @staticmethod
    def _is_compat_receiver(value: ast.AST) -> bool:
        dotted = dotted_name(value)
        return dotted is not None and (dotted == "compat" or dotted.endswith(".compat"))
