"""RPR010 — FPS/makespan/energy aggregation routes through the mapper.

PR 10 moved the event loop into ``repro.mapper``: the timeline owns every
derived performance number (``Timeline.fps`` / ``fps_per_w`` /
``avg_power_w`` / ``mean_utilization``), and ``core/simulator.py`` is its
degenerate batch-1 re-expression.  Ad-hoc arithmetic over the timing
attributes of layer stats / node schedules / timelines (summing
``time_s`` into a makespan, dividing by ``energy_j``, scaling a
``makespan_s``) re-derives those numbers at the call site — which is
exactly the class of silent utilization assumption the mapper exists to
centralize (and that arXiv 2511.00186 shows decides photonic throughput
claims).  Reading a timing attribute, storing it, or serializing it is
fine; *arithmetic* on one belongs in ``repro/mapper/`` or
``core/simulator.py``.
"""

from __future__ import annotations

import ast
from typing import Dict, Iterable, Optional

from repro.analysis.core import Finding, Rule, register_rule

#: Timing/energy attributes owned by the mapper timeline contract.
_TIMING_ATTRS = frozenset(
    {
        "time_s",
        "stream_s",
        "reduce_s",
        "tune_s",
        "energy_j",
        "total_time_s",
        "dynamic_energy_j",
        "makespan_s",
        "busy_s",
    }
)

#: Aggregation builtins that re-derive a schedule-level number.
_AGGREGATORS = frozenset({"sum", "min", "max"})

_SCOPED_PREFIXES = ("src/", "benchmarks/", "examples/")
_EXEMPT_PREFIXES = ("src/repro/mapper/", "src/repro/core/simulator.py")


def _parents(tree: ast.Module) -> Dict[ast.AST, Optional[ast.AST]]:
    out: Dict[ast.AST, Optional[ast.AST]] = {tree: None}
    for node in ast.walk(tree):
        for child in ast.iter_child_nodes(node):
            out[child] = node
    return out


@register_rule
class MapperTimingArithmeticRule(Rule):
    id = "RPR010"
    summary = "ad-hoc timing/FPS arithmetic outside repro.mapper"
    rationale = (
        "Makespan/FPS/energy aggregation must route through the mapper "
        "timeline (Timeline.fps / fps_per_w / avg_power_w) or "
        "core/simulator.py's degenerate schedule; arithmetic over "
        "time_s/energy_j/makespan_s at the call site re-implements the "
        "schedule's utilization assumptions."
    )

    def applies_to(self, relpath: str) -> bool:
        return relpath.startswith(_SCOPED_PREFIXES) and not relpath.startswith(
            _EXEMPT_PREFIXES
        )

    def check(self, tree: ast.Module, text: str, relpath: str) -> Iterable[Finding]:
        parents = _parents(tree)
        for node in ast.walk(tree):
            if not (
                isinstance(node, ast.Attribute)
                and node.attr in _TIMING_ATTRS
                and isinstance(node.ctx, ast.Load)
            ):
                continue
            cur: Optional[ast.AST] = node
            while cur is not None:
                parent = parents.get(cur)
                if isinstance(parent, (ast.BinOp, ast.AugAssign)):
                    yield self.finding(
                        relpath,
                        node,
                        f"arithmetic over timing attribute .{node.attr}; "
                        "use the repro.mapper Timeline metrics "
                        "(fps/fps_per_w/avg_power_w) instead",
                    )
                    break
                if (
                    isinstance(parent, ast.Call)
                    and isinstance(parent.func, ast.Name)
                    and parent.func.id in _AGGREGATORS
                    and cur is not parent.func
                ):
                    yield self.finding(
                        relpath,
                        node,
                        f"aggregating timing attribute .{node.attr} with "
                        f"{parent.func.id}(); makespans/energies come from "
                        "the repro.mapper Timeline, not call-site reductions",
                    )
                    break
                cur = parent
