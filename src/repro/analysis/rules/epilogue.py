"""RPR008 — engine GEMM outputs take no post-GEMM scale/bias shoulders.

PR-8 moved the int32→float rescale, bias add, and elementwise activation
into the engine's fused epilogue (``EpilogueSpec``, DESIGN.md §14): a
routed GEMM's result leaves ``engine.matmul*`` already rescaled, biased
and activated.  Model code that multiplies or adds onto an engine output
afterwards re-introduces the materialized intermediate the fusion
removed — and silently double-applies the shoulder if the epilogue was
also requested.  The blessed spelling is
``dense(..., epilogue=EpilogueSpec(...))`` /
``engine.matmul(..., epilogue=Epilogue(spec, bias))`` (PR-9 unified
surface; the legacy ``bias=``/``activation=`` keywords survive only as
deprecation shims).

Only *engine* matmul results are tracked, by the receiver spelling:
``jnp.matmul`` / ``np.matmul`` and arithmetic on :func:`dense` outputs
(residual adds, SwiGLU gating) are out of scope — those run in the
digital domain where XLA fuses freely.
"""

from __future__ import annotations

import ast
from typing import Iterable, List, Set

from repro.analysis.core import Finding, Rule, register_rule

# Engine GEMM entry points whose results are epilogue-complete.
_ENGINE_MATMULS = frozenset({"matmul", "matmul_float", "maybe_tp_matmul"})

# Receiver modules whose .matmul is the digital op, not the engine's.
_DIGITAL_BASES = frozenset({"jnp", "np", "jax", "numpy", "lax", "torch"})

_ARITH_OPS = (ast.Mult, ast.Add, ast.Sub, ast.Div)


def _is_engine_matmul(node: ast.AST) -> bool:
    if not isinstance(node, ast.Call):
        return False
    func = node.func
    if isinstance(func, ast.Attribute) and func.attr in _ENGINE_MATMULS:
        base = func.value
        while isinstance(base, ast.Attribute):
            base = base.value
        if isinstance(base, ast.Name) and base.id in _DIGITAL_BASES:
            return False
        return True
    if isinstance(func, ast.Name) and func.id in _ENGINE_MATMULS:
        return True
    return False


@register_rule
class FusedEpilogueRule(Rule):
    id = "RPR008"
    summary = "post-GEMM arithmetic on an engine matmul output"
    rationale = (
        "Engine GEMM results are epilogue-complete (rescale, bias, "
        "activation ride the fused EpilogueSpec); scaling or bias-adding "
        "them afterwards re-materializes the intermediate the fusion "
        "removed — pass epilogue= (EpilogueSpec/Epilogue) to "
        "dense()/engine.matmul* instead."
    )

    def applies_to(self, relpath: str) -> bool:
        return relpath.startswith("src/repro/models/")

    def check(self, tree: ast.Module, text: str, relpath: str) -> Iterable[Finding]:
        for fn in ast.walk(tree):
            if isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda)):
                yield from self._check_scope(fn, relpath)

    def _check_scope(self, fn: ast.AST, relpath: str) -> Iterable[Finding]:
        tracked: Set[str] = set()
        findings: List[Finding] = []

        def operand_hits(node: ast.AST) -> bool:
            if _is_engine_matmul(node):
                return True
            return isinstance(node, ast.Name) and node.id in tracked

        def visit_expr(node: ast.AST) -> None:
            if isinstance(node, ast.BinOp) and isinstance(node.op, _ARITH_OPS):
                if operand_hits(node.left) or operand_hits(node.right):
                    findings.append(
                        self.finding(
                            relpath,
                            node,
                            "arithmetic on an engine matmul output; pass "
                            "epilogue= so it rides the fused "
                            "epilogue",
                        )
                    )
            for child in ast.iter_child_nodes(node):
                # Nested scopes get their own tracker pass.
                if not isinstance(
                    child, (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda)
                ):
                    visit_expr(child)

        def visit_stmts(stmts: List[ast.stmt]) -> None:
            for stmt in stmts:
                if isinstance(stmt, ast.AugAssign):
                    if (
                        isinstance(stmt.target, ast.Name)
                        and stmt.target.id in tracked
                        and isinstance(stmt.op, _ARITH_OPS)
                    ):
                        findings.append(
                            self.finding(
                                relpath,
                                stmt,
                                "in-place arithmetic on an engine matmul "
                                "output; pass epilogue= so it rides "
                                "the fused epilogue",
                            )
                        )
                    visit_expr(stmt.value)
                    continue
                if isinstance(stmt, ast.Assign):
                    visit_expr(stmt.value)
                    for tgt in stmt.targets:
                        if isinstance(tgt, ast.Name):
                            if _is_engine_matmul(stmt.value):
                                tracked.add(tgt.id)
                            else:
                                tracked.discard(tgt.id)
                    continue
                # Recurse through compound statements in source order so
                # tracking follows control flow (approximately: branches
                # share one tracker, which only over-approximates).
                for field in ("test", "value", "iter", "exc"):
                    sub = getattr(stmt, field, None)
                    if sub is not None and isinstance(sub, ast.AST):
                        visit_expr(sub)
                for field in ("body", "orelse", "finalbody"):
                    sub = getattr(stmt, field, None)
                    if isinstance(sub, list) and sub and isinstance(sub[0], ast.stmt):
                        visit_stmts(sub)
                for handler in getattr(stmt, "handlers", []) or []:
                    visit_stmts(handler.body)

        if isinstance(fn, ast.Lambda):
            visit_expr(fn.body)
        else:
            visit_stmts(fn.body)
        yield from findings
