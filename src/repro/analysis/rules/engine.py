"""RPR003 — models/runtime route every GEMM through the engine surface.

The sanctioned entry points are ``repro.models.common.dense(site=...)``,
``engine.matmul``/``matmul_float``/``matmul_int`` and the
``repro.photonic.sharded`` contexts. Direct calls into the kernel backends
(Pallas kernel, reference int GEMM, the raw ops wrappers) from model or
runtime code bypass routing policy, seed derivation, and prepacking — the
exact machinery the PR-3/PR-4 results depend on.
"""

from __future__ import annotations

import ast
from typing import Iterable

from repro.analysis.core import Finding, Rule, dotted_name, register_rule

# Backend entry points that only repro.photonic / repro.kernels may touch.
_BACKEND_NAMES = frozenset(
    {
        "photonic_gemm_pallas",
        "photonic_gemm_ref",
        "exact_int_gemm",
        "photonic_gemm_int",
        "photonic_gemm",
        "int_gemm",
        "psum_int_gemm",
        "_packed_matmul",
    }
)

_SCOPED_PREFIXES = ("src/repro/models/", "src/repro/runtime/")


@register_rule
class EngineRoutingRule(Rule):
    id = "RPR003"
    summary = "direct kernel-backend call outside repro.photonic"
    rationale = (
        "Models and runtime must route GEMMs via dense(site=...) or "
        "engine.matmul*; calling kernel backends directly skips the "
        "engine's routing policy, per-site seed derivation, and the "
        "weight-stationary prepacked path."
    )

    def applies_to(self, relpath: str) -> bool:
        return relpath.startswith(_SCOPED_PREFIXES)

    def check(self, tree: ast.Module, text: str, relpath: str) -> Iterable[Finding]:
        for node in ast.walk(tree):
            if isinstance(node, ast.ImportFrom):
                mod = node.module or ""
                if mod == "repro.kernels" or mod.startswith("repro.kernels."):
                    yield self.finding(
                        relpath,
                        node,
                        f"import from kernel backend {mod!r}; route via "
                        "dense(site=...) / engine.matmul*",
                    )
                    continue
                for alias in node.names:
                    if alias.name in _BACKEND_NAMES:
                        yield self.finding(
                            relpath,
                            node,
                            f"import of backend entry point {alias.name!r}; "
                            "route via dense(site=...) / engine.matmul*",
                        )
            elif isinstance(node, ast.Import):
                for alias in node.names:
                    if alias.name.startswith("repro.kernels"):
                        yield self.finding(
                            relpath,
                            node,
                            f"import of kernel backend {alias.name!r}; "
                            "route via dense(site=...) / engine.matmul*",
                        )
            elif isinstance(node, ast.Call):
                func = node.func
                name = None
                if isinstance(func, ast.Attribute):
                    name = func.attr
                elif isinstance(func, ast.Name):
                    name = func.id
                if name in _BACKEND_NAMES:
                    dotted = dotted_name(func) or name
                    yield self.finding(
                        relpath,
                        node,
                        f"direct kernel-backend call {dotted}(); route via "
                        "dense(site=...) / engine.matmul*",
                    )
