"""RPR004 — models/kernels draw no randomness outside the engine's streams.

Noise in the photonic channel is keyed per (site, layer, shard) by the
engine's seed derivation (``stream_seed`` / ``DPUConfig.noise_seed_array``)
so runs are reproducible and shards decorrelate deterministically. A model
or kernel sampling from ``jax.random`` on the side forks the stream and
breaks the bitwise-stability story. Parameter initialization (``init*``
functions, host-side setup) is exempt, as is pure key plumbing.
"""

from __future__ import annotations

import ast
from typing import Iterable

from repro.analysis.core import (
    Finding,
    Rule,
    dotted_name,
    enclosing_functions,
    register_rule,
)

# Key plumbing — allowed everywhere (moving keys around samples nothing).
_KEY_PLUMBING = frozenset(
    {"PRNGKey", "key", "split", "fold_in", "key_data", "wrap_key_data", "clone"}
)

_SCOPED_PREFIXES = ("src/repro/models/", "src/repro/kernels/")


@register_rule
class ModelRandomnessRule(Rule):
    id = "RPR004"
    summary = "jax.random sampling in models/kernels outside init paths"
    rationale = (
        "All model/kernel randomness must come from the engine's seed "
        "derivation (stream_seed / noise_seed_array) so noise streams are "
        "(site, layer, shard)-keyed and reproducible; ad-hoc jax.random "
        "sampling forks the stream."
    )

    def applies_to(self, relpath: str) -> bool:
        return relpath.startswith(_SCOPED_PREFIXES)

    def check(self, tree: ast.Module, text: str, relpath: str) -> Iterable[Finding]:
        enclosing = enclosing_functions(tree)
        for node in ast.walk(tree):
            if not isinstance(node, ast.Attribute):
                continue
            dotted = dotted_name(node)
            if dotted is None or not dotted.startswith("jax.random."):
                continue
            leaf = dotted.rsplit(".", 1)[1]
            if leaf in _KEY_PLUMBING:
                continue
            fns = enclosing.get(node, [])
            if any(f.name.lstrip("_").startswith("init") for f in fns):
                continue  # parameter initialization is host-side setup
            yield self.finding(
                relpath,
                node,
                f"{dotted} sampled outside an init path; derive randomness "
                "from the engine stream (stream_seed / noise_seed_array)",
            )
