"""RPR009 — platform-typed strings resolve through ``repro.platforms.resolve``
only.

The mirror of RPR002 for the material-platform axis (PR-9): platform
names ("SOI", "SiN") are registry keys, and the single blessed
normalization site is ``platforms._normalize_platform`` — ad-hoc
``.upper()``/``.lower()`` on a platform-typed value forks the
canonicalization and silently diverges from the registry's
case/whitespace handling.
"""

from __future__ import annotations

import ast
import re
from typing import Iterable

from repro.analysis.core import Finding, Rule, dotted_name, register_rule

_CASE_METHODS = frozenset({"upper", "lower", "casefold", "title", "capitalize"})

# Identifier tokens that mark a value as platform-typed. "material" is
# included because the platform axis *is* the waveguide material choice
# (SOI vs SiN) throughout the paper's Sec. V discussion.
_PLATFORM_TOKENS = frozenset(
    {"platform", "platforms", "material", "materials"}
)
_TOKEN_SPLIT = re.compile(r"[^a-zA-Z0-9]+")


def _is_platformish(node: ast.AST) -> bool:
    dotted = dotted_name(node)
    if dotted is None:
        return False
    tokens = {t.lower() for t in _TOKEN_SPLIT.split(dotted) if t}
    return bool(tokens & _PLATFORM_TOKENS)


@register_rule
class PlatformResolutionRule(Rule):
    id = "RPR009"
    summary = "ad-hoc case normalization of a platform string outside repro.platforms"
    rationale = (
        "Platform-typed values (material names like 'SOI'/'SiN') must flow "
        "through repro.platforms.resolve; hand-rolled .upper()/.lower() "
        "normalization forks the canonicalization logic and silently "
        "diverges from the registry's case/whitespace handling."
    )

    def applies_to(self, relpath: str) -> bool:
        return relpath != "src/repro/platforms.py"

    def check(self, tree: ast.Module, text: str, relpath: str) -> Iterable[Finding]:
        for node in ast.walk(tree):
            if not (
                isinstance(node, ast.Call)
                and isinstance(node.func, ast.Attribute)
                and node.func.attr in _CASE_METHODS
                and not node.args
                and not node.keywords
            ):
                continue
            receiver = node.func.value
            # `platform.strip().upper()` — look through chained str methods.
            while (
                isinstance(receiver, ast.Call)
                and isinstance(receiver.func, ast.Attribute)
            ):
                receiver = receiver.func.value
            if _is_platformish(receiver):
                yield self.finding(
                    relpath,
                    node,
                    f"case-normalizing a platform-typed value via "
                    f".{node.func.attr}(); route through "
                    f"repro.platforms.resolve",
                )
