"""Rule plugins. Importing this package registers every RPR rule.

Each module defines one themed rule (or a small family) and registers it
via :func:`repro.analysis.core.register_rule`; adding a rule is: create a
module here, import it below, document the ID in DESIGN.md §12.
"""

from repro.analysis.rules import (  # noqa: F401
    compat,
    engine,
    epilogue,
    mapper,
    orgs,
    platforms,
    quant,
    randomness,
    serving,
    sharding,
)
