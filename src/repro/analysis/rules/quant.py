"""RPR005 — quantization paths multiply by a reciprocal, never divide by a
constant scale.

``x / 127.0`` and ``x * (1.0 / 127.0)`` round differently in the last ulp,
and XLA rewrites constant-divisor division into reciprocal multiplication
when compiling — so the divide spelling produces results that differ
between eager and jitted execution. The repo's bitwise-stability contract
(eager == compiled, PR-2) requires the reciprocal-multiply spelling
everywhere a quantization scale is built from constants.
"""

from __future__ import annotations

import ast
from typing import Iterable

from repro.analysis.core import Finding, Rule, register_rule

_CONST_CALLS = frozenset({"float", "int", "min", "max", "abs"})


def _is_const_expr(node: ast.AST) -> bool:
    """Syntactically constant numeric expression (no names, no attributes).

    Names are deliberately NOT constant: ``x / scale`` with a traced scale
    is the correct second half of the blessed pattern and must never flag.
    """
    if isinstance(node, ast.Constant):
        is_num = isinstance(node.value, (int, float))
        return is_num and not isinstance(node.value, bool)
    if isinstance(node, ast.UnaryOp):
        return _is_const_expr(node.operand)
    if isinstance(node, ast.BinOp):
        return _is_const_expr(node.left) and _is_const_expr(node.right)
    if isinstance(node, ast.Call) and isinstance(node.func, ast.Name):
        return node.func.id in _CONST_CALLS and all(
            _is_const_expr(a) for a in node.args
        )
    return False


def _is_literal_one(node: ast.AST) -> bool:
    return isinstance(node, ast.Constant) and node.value in (1, 1.0)


@register_rule
class ReciprocalQuantRule(Rule):
    id = "RPR005"
    summary = "constant-divisor division in a quantization path"
    rationale = (
        "XLA rewrites x / const into x * (1/const) when compiling, so the "
        "division spelling diverges bitwise between eager and jitted "
        "execution; quantization scales must be built as reciprocal "
        "multiplies (amax * (1.0 / qmax)) for eager/compiled bit-identity."
    )

    def applies_to(self, relpath: str) -> bool:
        # Quantization paths live in src/; test tolerance arithmetic is out
        # of scope.
        return relpath.startswith("src/")

    def check(self, tree: ast.Module, text: str, relpath: str) -> Iterable[Finding]:
        for fn in ast.walk(tree):
            if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            if "quant" not in fn.name.lower():
                continue
            for node in ast.walk(fn):
                if not (isinstance(node, ast.BinOp) and isinstance(node.op, ast.Div)):
                    continue
                if _is_literal_one(node.left):
                    continue  # 1.0 / qmax IS the reciprocal idiom
                if _is_const_expr(node.right):
                    yield self.finding(
                        relpath,
                        node,
                        f"division by constant scale in {fn.name}(); use "
                        "reciprocal multiply: x * (1.0 / const)",
                    )
