"""First-class photonic platform specs (SOI + SiN presets).

The source paper anchors every loss number to a silicon-on-insulator
(SOI) process (Table IV, credited to [27]/[12]); the 4-bit ENOB wall that
saturates LM serving in ``benchmarks/org_accuracy.py`` is an SOI wall,
not a law of incoherent photonics.  Sibling work (arXiv 2402.11047)
builds the same microring GEMM fabric on silicon nitride, whose ~10x
lower propagation loss and far gentler ring insertion loss deliver more
optical power to the detector — a larger achievable N and a lower
detector sigma at the same geometry.

:class:`PlatformSpec` makes the material platform the API, exactly as
:class:`repro.orgs.OrgSpec` does for the block order: a frozen, hashable
spec holding the platform-owned fields of Eq. 1-3 (propagation /
through / coupling / ring insertion losses), the laser wall-plug
efficiency used by the accelerator power model, and the ring tuning
powers.  Everything platform-typed funnels through :func:`resolve` — the
single ``str | PlatformSpec`` resolution point used by
``build_channel_model``, ``DPUConfig``, ``AcceleratorConfig``, and the
scalability solver (RPR009 forbids ad-hoc case normalization of platform
strings anywhere else, mirroring RPR002 for organizations).

A spec is *applied* to a :class:`repro.core.params.PhotonicParams` via
:meth:`PlatformSpec.apply`, which replaces only the platform-owned loss
fields and leaves the Table-V-calibrated fields (``p_smf_att_db``,
``d_mrr_mm``, ``bw_divisor``) untouched.  The SOI preset is field-for-
field identical to the Table IV defaults, so ``SOI.apply(params) ==
params`` and every pre-platform call site is bitwise unchanged.
"""

from __future__ import annotations

import dataclasses
from typing import TYPE_CHECKING, Dict, Union

if TYPE_CHECKING:  # pragma: no cover - import cycle guard (params is a leaf)
    from repro.core.params import PhotonicParams


@dataclasses.dataclass(frozen=True)
class PlatformSpec:
    """A photonic material platform (frozen, hashable).

    Fields mirror the platform-owned subset of ``PhotonicParams`` (same
    units), plus the wall-plug efficiency and ring tuning powers consumed
    by ``repro.core.perfmodel``.  ``name`` is the canonical upper-case
    identity; two specs with the same name must be equal (enforced by
    :func:`register`).
    """

    name: str
    description: str = ""
    citation: str = ""
    # Eq. 1-3 loss fields (platform-owned subset of PhotonicParams) ---------
    propagation_loss_db_per_mm: float = 0.3   # waveguide loss [dB/mm]
    coupling_loss_db: float = 1.44            # fiber->chip coupling IL [dB]
    splitter_loss_db: float = 0.01            # per 1x2 splitter stage [dB]
    mrm_il_db: float = 4.0                    # modulator ring IL [dB]
    mrr_w_il_db: float = 0.01                 # weight ring IL [dB]
    mrm_through_db: float = 0.01              # MRM out-of-band (through) [dB]
    mrr_w_through_db: float = 0.01            # weight-MRR through [dB]
    # Accelerator power model (repro.core.perfmodel) ------------------------
    laser_wallplug_eff: float = 0.2           # electrical->optical efficiency
    eo_tuning_w_per_fsr: float = 80e-6        # EO ring tuning power [W/FSR]
    to_tuning_w_per_fsr: float = 275e-3       # thermal ring tuning [W/FSR]

    def __post_init__(self):
        if self.name != _normalize_platform(self.name):
            raise ValueError(
                f"platform name {self.name!r} is not canonical; use "
                f"{_normalize_platform(self.name)!r}"
            )

    def apply(self, params: "PhotonicParams") -> "PhotonicParams":
        """``params`` with the platform-owned fields replaced.

        Only the loss fields and the wall-plug efficiency change; the
        Table-V-calibrated under-specified fields and every
        non-platform field (detector, RIN, spectral grid, penalties)
        pass through untouched.  Idempotent, and the identity for the
        platform a ``PhotonicParams`` already describes.
        """
        return dataclasses.replace(
            params,
            p_ec_il_db=self.coupling_loss_db,
            p_si_att_db_per_mm=self.propagation_loss_db_per_mm,
            p_splitter_il_db=self.splitter_loss_db,
            p_mrm_il_db=self.mrm_il_db,
            p_mrr_w_il_db=self.mrr_w_il_db,
            p_mrm_obl_db=self.mrm_through_db,
            p_mrr_w_obl_db=self.mrr_w_through_db,
            laser_wallplug_eff=self.laser_wallplug_eff,
        )

    def __str__(self) -> str:
        return self.name


def _normalize_platform(name: str) -> str:
    """Canonicalize a platform string (strip + casefold to upper).

    THE single blessed normalization site for platform-typed strings:
    :func:`resolve` and :class:`PlatformSpec` validation both route
    through it, so case handling cannot drift between entry points
    (RPR009 forbids ad-hoc ``.upper()`` on platform strings anywhere
    else, mirroring RPR002 for organization strings).
    """
    return name.strip().upper()


# ---------------------------------------------------------------------------
# Registry: the named platforms (paper SOI baseline + sibling-work SiN)
# ---------------------------------------------------------------------------
_REGISTRY: Dict[str, PlatformSpec] = {}


def register(spec: PlatformSpec) -> PlatformSpec:
    """Register ``spec`` under its canonical name; returns the spec.

    Re-registering an equal spec is a no-op; registering a *different*
    spec under an existing name raises (platform identity is the name,
    so a silent overwrite would fork the physics behind it).
    """
    existing = _REGISTRY.get(spec.name)
    if existing is not None and existing != spec:
        raise ValueError(
            f"platform {spec.name!r} is already registered with different "
            "fields; pick a new name instead of overwriting"
        )
    _REGISTRY[spec.name] = spec
    return spec


def registered() -> Dict[str, PlatformSpec]:
    """Snapshot of the registered platforms (name -> spec)."""
    return dict(_REGISTRY)


def resolve(platform: Union[str, "PlatformSpec"]) -> PlatformSpec:
    """THE ``str | PlatformSpec`` resolution point (case-insensitive).

    Accepts a spec (returned as-is) or a registered name; anything else
    raises ``ValueError`` naming the valid choices.  Every
    platform-typed entry point (``build_channel_model``, ``DPUConfig``,
    ``AcceleratorConfig``, ``calibrated_max_n``) funnels through here,
    so validation is eager and the error message is uniform.
    """
    if isinstance(platform, PlatformSpec):
        return platform
    if not isinstance(platform, str):
        raise ValueError(
            f"platform must be a str or PlatformSpec, got "
            f"{type(platform).__name__}"
        )
    spec = _REGISTRY.get(_normalize_platform(platform))
    if spec is None:
        raise ValueError(
            f"unknown platform {platform!r}: valid choices are "
            f"{tuple(sorted(_REGISTRY))}"
        )
    return spec


# The paper's SOI baseline: field-for-field identical to the Table IV
# defaults in PhotonicParams, so resolving/applying "SOI" is a no-op and
# the pre-platform behavior of every call site is preserved bitwise.
SOI = register(
    PlatformSpec(
        name="SOI",
        description="Silicon-on-insulator (paper Table IV baseline)",
        citation="arXiv 2402.03149 Table IV ([27] Al-Qadasi, [12] Vatsavai)",
    )
)

# Silicon nitride: the low-loss escape hatch from the SOI ENOB wall.
# Propagation ~0.03 dB/mm (an order below SOI's 0.3), gentler edge
# coupling, and a much lower modulator insertion loss; the cost is the
# weak thermo-optic coefficient — ring tuning takes ~4x the power and
# the EO effect is weaker still.
SIN = register(
    PlatformSpec(
        name="SIN",
        description="Silicon nitride (low-loss microring GEMM platform)",
        citation="arXiv 2402.11047",
        propagation_loss_db_per_mm=0.03,
        coupling_loss_db=1.0,
        splitter_loss_db=0.01,
        mrm_il_db=1.0,
        mrr_w_il_db=0.01,
        mrm_through_db=0.005,
        mrr_w_through_db=0.005,
        laser_wallplug_eff=0.2,
        eo_tuning_w_per_fsr=320e-6,
        to_tuning_w_per_fsr=1.1,
    )
)

# Registered platform names, baseline first.
PLATFORMS = ("SOI", "SIN")
