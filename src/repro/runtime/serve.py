"""Batched serving engine: continuous batching over fixed decode slots.

The engine keeps ``batch_size`` decode slots.  Requests queue up; free slots
are filled by prefilling the prompt (one prefill per admission — left-padded
into the shared KV cache), then all active slots advance together through
``decode`` steps (one token per step for the whole batch).  Finished slots
(EOS or max tokens) are immediately recycled — the vLLM-style continuous
batching pattern, reduced to its JAX-functional core.

For per-slot admission the cache must be *batch-indexable*: we prefill a
single-row cache and scatter it into the batch cache at the slot index.

Photonic serving is *weight-stationary*: at engine construction every
policy-routed weight is prepacked (int8 + per-column scale, tile-padded
for the Pallas backend) via ``repro.photonic.packing.prepack_params``, so
steady-state decode performs zero weight-quantization work — the software
analogue of programming the DPU weight MRR banks once per tile.
"""

from __future__ import annotations

import contextlib
import dataclasses
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray          # (T,) int32
    max_new_tokens: int = 16
    eos_id: Optional[int] = None
    # filled by the engine
    output: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


@dataclasses.dataclass
class ServeConfig:
    batch_size: int = 4
    max_seq: int = 256
    greedy: bool = True
    temperature: float = 1.0
    seed: int = 0


class Engine:
    def __init__(
        self,
        arch,
        model_cfg,
        params,
        cfg: ServeConfig,
        *,
        mesh=None,
        tp_axis: str = "model",
    ):
        from repro.models.common import engine_from_model_config
        from repro.photonic.packing import prepack_params

        self.arch = arch
        self.model_cfg = model_cfg
        # Tensor-parallel photonic serving: with a mesh whose `tp_axis` is
        # sized > 1, the int8 banks prepack in the K-sharded layout
        # (shard-local tile padding, fan-in rows on the TP axis, scales
        # replicated) and every prefill/decode step runs its routed GEMMs
        # inside shard_map with shard-local channel models (DESIGN.md §10).
        self.mesh = mesh
        self.tp_axis = tp_axis
        self._tp_size = (
            int(mesh.shape[tp_axis])
            if mesh is not None and tp_axis in mesh.shape
            else 1
        )
        # Weight-stationary serving (DESIGN.md §9): when a photonic engine
        # is configured, quantize + pack every routed weight ONCE here —
        # prefill and decode steps then stream activations against the
        # packed int8 banks and never touch (or re-quantize) float weights.
        self.photonic = engine_from_model_config(model_cfg)
        if self.photonic is not None:
            pack_engine = self.photonic
            if getattr(model_cfg, "mla_absorb", False):
                # Absorbed MLA decode consumes wuk/wuv as raw floats in its
                # einsums (never through the quantizing dense path); packing
                # them would change decode numerics vs the per-call path and
                # add a per-step weight-sized dequant.  Keep them float.
                pol = dataclasses.replace(
                    pack_engine.policy,
                    exclude=pack_engine.policy.exclude + ("wuk", "wuv"),
                )
                pack_engine = dataclasses.replace(pack_engine, policy=pol)
            params = prepack_params(
                params,
                arch.param_defs(model_cfg),
                pack_engine,
                mesh=mesh if self._tp_size > 1 else None,
                axis=tp_axis,
            )
        self.params = params
        self.cfg = cfg
        self._decode = jax.jit(lambda p, t, c: arch.decode(p, t, c, model_cfg))
        self.slots: List[Optional[Request]] = [None] * cfg.batch_size
        self.cache = None
        self.tokens = jnp.zeros((cfg.batch_size, 1), jnp.int32)
        self._rng = jax.random.PRNGKey(cfg.seed)
        self.stats = {"prefills": 0, "decode_steps": 0, "completed": 0}
        # Batch-axis index per cache leaf, from the cache_def's logical axes
        # (guessing by size collides with e.g. n_layers == batch_size).
        cache_def = arch.cache_def(
            model_cfg, cfg.batch_size, cfg.max_seq,
            {"enc_seq": cfg.max_seq}, model_cfg.compute_dtype,
        )

        def _axis(leaf):
            _, axes, _ = leaf
            return axes.index("batch") if "batch" in axes else None

        self._batch_axis = jax.tree.map(
            _axis, cache_def,
            is_leaf=lambda x: isinstance(x, tuple) and len(x) == 3
            and isinstance(x[0], tuple) and isinstance(x[1], tuple),
        )

    def _tp_scope(self):
        """The tensor-parallel scope every model call runs under (a no-op
        without a TP mesh); consulted at trace time by ``dense``."""
        if self.photonic is not None and self._tp_size > 1:
            from repro.photonic import sharded

            return sharded.tensor_parallel(self.mesh, self.tp_axis)
        return contextlib.nullcontext()

    # -- admission -----------------------------------------------------------
    def _admit(self, req: Request, slot: int):
        """Prefill the prompt for one slot and merge into the batch cache."""
        b = self.cfg.batch_size
        prompt = jnp.asarray(req.prompt)[None, :]  # (1, T)
        batch = {"tokens": jnp.tile(prompt, (b, 1))}
        with self._tp_scope():
            logits, cache = self.arch.prefill(
                self.params, batch, self.model_cfg, self.cfg.max_seq
            )
        self.stats["prefills"] += 1
        if self.cache is None:
            self.cache = cache
        else:
            # scatter this request's row into the live cache at `slot`,
            # along the true batch axis of each leaf
            def merge(live, new, ax):
                if ax is None or live.ndim == 0:
                    return live  # batchless leaves (pos scalar) stay live
                idx = [slice(None)] * live.ndim
                idx[ax] = slice(slot, slot + 1)
                return live.at[tuple(idx)].set(new[tuple(idx)])

            self.cache = jax.tree.map(merge, self.cache, cache, self._batch_axis)
        tok = jnp.argmax(logits[:, -1, : self.model_cfg.vocab_size], axis=-1)
        self.tokens = self.tokens.at[slot, 0].set(tok[slot].astype(jnp.int32))
        req.output.append(int(tok[slot]))
        self.slots[slot] = req

    # -- one engine iteration --------------------------------------------------
    def step(self, queue: List[Request]):
        # fill free slots
        for slot in range(self.cfg.batch_size):
            if self.slots[slot] is None and queue:
                self._admit(queue.pop(0), slot)
        if all(s is None for s in self.slots):
            return
        with self._tp_scope():
            logits, self.cache = self._decode(self.params, self.tokens, self.cache)
        self.stats["decode_steps"] += 1
        logits = logits[:, -1, : self.model_cfg.vocab_size]
        if self.cfg.greedy:
            nxt = jnp.argmax(logits, axis=-1)
        else:
            self._rng, k = jax.random.split(self._rng)
            nxt = jax.random.categorical(k, logits / self.cfg.temperature, axis=-1)
        self.tokens = nxt[:, None].astype(jnp.int32)
        for slot, req in enumerate(self.slots):
            if req is None:
                continue
            tok = int(nxt[slot])
            req.output.append(tok)
            if (
                len(req.output) >= req.max_new_tokens
                or (req.eos_id is not None and tok == req.eos_id)
            ):
                req.done = True
                self.stats["completed"] += 1
                self.slots[slot] = None

    def run(self, requests: List[Request], max_steps: int = 10_000) -> List[Request]:
        queue = list(requests)
        steps = 0
        while (queue or any(s is not None for s in self.slots)) and steps < max_steps:
            self.step(queue)
            steps += 1
        return requests
