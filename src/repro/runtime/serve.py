"""Serving entry point (compatibility wrapper over ``repro.serving``).

``Engine`` keeps the original constructor/run surface but routes the dense
GQA LM families onto the paged-KV continuous-batching scheduler
(``repro.serving.Scheduler``, DESIGN.md §13): block-granular KV memory,
chunked prefill interleaved with batched decode, and per-request sampling
streams.  Families the paged path does not cover (MLA latent caches,
vision cross-attention, SSM/hybrid/audio) fall back to ``LegacyEngine`` —
the original fixed-slot loop, kept verbatim as the baseline the serving
tests and the ``serve_latency`` benchmark compare against.

Both paths share the weight-stationary prepack
(``repro.serving.prepack_serving_params``): with a photonic engine
configured, every policy-routed weight packs ONCE at construction, so
steady-state decode performs zero weight-quantization work — the software
analogue of programming the DPU weight MRR banks once per tile.
"""

from __future__ import annotations

import contextlib
import dataclasses
import time
from typing import Callable, List, Optional

import jax
import jax.numpy as jnp

from repro.serving.scheduler import (
    Request,
    Scheduler,
    ServingConfig,
    prepack_serving_params,
)

__all__ = ["Request", "ServeConfig", "Engine", "LegacyEngine"]


@dataclasses.dataclass
class ServeConfig:
    batch_size: int = 4
    max_seq: int = 256
    greedy: bool = True
    temperature: float = 1.0
    seed: int = 0


def _paged_block_size(max_seq: int) -> int:
    for b in (16, 8, 4, 2, 1):
        if max_seq % b == 0:
            return b
    raise AssertionError  # unreachable: 1 always divides


class Engine:
    def __init__(
        self,
        arch,
        model_cfg,
        params,
        cfg: ServeConfig,
        *,
        mesh=None,
        tp_axis: str = "model",
    ):
        self.arch = arch
        self.model_cfg = model_cfg
        self.cfg = cfg
        paged = (
            getattr(arch, "family", None) == "dense"
            and not model_cfg.mla
            and not model_cfg.cross_attn_every
        )
        if paged:
            # Legacy-compatible scheduler setup: a chunk budget of a full
            # wave (batch_size * max_seq tokens) admits and fully prefills
            # every free slot before the step's decode, preserving the old
            # engine's admission order.  Callers that want chunked-prefill
            # interleaving construct repro.serving.Scheduler directly.
            scfg = ServingConfig(
                batch_size=cfg.batch_size,
                max_seq=cfg.max_seq,
                block_size=_paged_block_size(cfg.max_seq),
                chunk_tokens=cfg.batch_size * cfg.max_seq,
                greedy=cfg.greedy,
                temperature=cfg.temperature,
                seed=cfg.seed,
            )
            self.impl = Scheduler(
                arch, model_cfg, params, scfg, mesh=mesh, tp_axis=tp_axis
            )
        else:
            self.impl = LegacyEngine(
                arch, model_cfg, params, cfg, mesh=mesh, tp_axis=tp_axis
            )

    @property
    def photonic(self):
        return self.impl.photonic

    @property
    def params(self):
        return self.impl.params

    @params.setter
    def params(self, value):
        self.impl.params = value

    @property
    def stats(self):
        return self.impl.stats

    def _tp_scope(self):
        return self.impl._tp_scope()

    def run(self, requests: List[Request], max_steps: int = 10_000) -> List[Request]:
        return self.impl.run(requests, max_steps)


class LegacyEngine:
    """The original fixed-slot continuous-batching loop.

    Keeps ``batch_size`` decode slots backed by one dense ``(batch,
    max_seq)`` KV cache.  Free slots fill by prefilling the prompt (one
    prefill per admission, scattered into the batch cache at the slot
    index), then all active slots advance together through ``decode`` steps.
    Known limitations the paged scheduler exists to fix: worst-case cache
    memory per slot, head-of-line blocking on long prompts, batchless cache
    leaves (e.g. the scalar ``pos``) staying live across admissions, and a
    shared sampling stream across slots.
    """

    def __init__(
        self,
        arch,
        model_cfg,
        params,
        cfg: ServeConfig,
        *,
        mesh=None,
        tp_axis: str = "model",
        clock: Callable[[], float] = time.monotonic,
    ):
        self.arch = arch
        self.model_cfg = model_cfg
        # Tensor-parallel photonic serving: with a mesh whose `tp_axis` is
        # sized > 1, the int8 banks prepack in the K-sharded layout
        # (shard-local tile padding, fan-in rows on the TP axis, scales
        # replicated) and every prefill/decode step runs its routed GEMMs
        # inside shard_map with shard-local channel models (DESIGN.md §10).
        self.mesh = mesh
        self.tp_axis = tp_axis
        self._tp_size = (
            int(mesh.shape[tp_axis])
            if mesh is not None and tp_axis in mesh.shape
            else 1
        )
        self._clock = clock
        self.photonic, self.params = prepack_serving_params(
            arch, model_cfg, params, mesh=mesh, tp_axis=tp_axis
        )
        self.cfg = cfg
        self._decode = jax.jit(lambda p, t, c: arch.decode(p, t, c, model_cfg))
        self.slots: List[Optional[Request]] = [None] * cfg.batch_size
        self.cache = None
        self.tokens = jnp.zeros((cfg.batch_size, 1), jnp.int32)
        self._rng = jax.random.PRNGKey(cfg.seed)
        self.stats = {"prefills": 0, "decode_steps": 0, "completed": 0}
        # Batch-axis index per cache leaf, from the cache_def's logical axes
        # (guessing by size collides with e.g. n_layers == batch_size).
        cache_def = arch.cache_def(
            model_cfg, cfg.batch_size, cfg.max_seq,
            {"enc_seq": cfg.max_seq}, model_cfg.compute_dtype,
        )

        def _axis(leaf):
            _, axes, _ = leaf
            return axes.index("batch") if "batch" in axes else None

        self._batch_axis = jax.tree.map(
            _axis, cache_def,
            is_leaf=lambda x: isinstance(x, tuple) and len(x) == 3
            and isinstance(x[0], tuple) and isinstance(x[1], tuple),
        )

    def _tp_scope(self):
        """The tensor-parallel scope every model call runs under (a no-op
        without a TP mesh); consulted at trace time by ``dense``."""
        if self.photonic is not None and self._tp_size > 1:
            from repro.photonic import sharded

            return sharded.tensor_parallel(self.mesh, self.tp_axis)
        return contextlib.nullcontext()

    # -- admission -----------------------------------------------------------
    def _admit(self, req: Request, slot: int):
        """Prefill the prompt for one slot and merge into the batch cache."""
        b = self.cfg.batch_size
        prompt = jnp.asarray(req.prompt)[None, :]  # (1, T)
        batch = {"tokens": jnp.tile(prompt, (b, 1))}
        with self._tp_scope():
            logits, cache = self.arch.prefill(
                self.params, batch, self.model_cfg, self.cfg.max_seq
            )
        self.stats["prefills"] += 1
        if self.cache is None:
            self.cache = cache
        else:
            # scatter this request's row into the live cache at `slot`,
            # along the true batch axis of each leaf
            def merge(live, new, ax):
                if ax is None or live.ndim == 0:
                    return live  # batchless leaves (pos scalar) stay live
                idx = [slice(None)] * live.ndim
                idx[ax] = slice(slot, slot + 1)
                return live.at[tuple(idx)].set(new[tuple(idx)])

            self.cache = jax.tree.map(merge, self.cache, cache, self._batch_axis)
        tok = jnp.argmax(logits[:, -1, : self.model_cfg.vocab_size], axis=-1)
        self.tokens = self.tokens.at[slot, 0].set(tok[slot].astype(jnp.int32))
        req.output.append(int(tok[slot]))
        if req.t_first is None:
            req.t_first = self._clock()
        self.slots[slot] = req

    # -- one engine iteration ------------------------------------------------
    def step(self, queue: List[Request]):
        # fill free slots
        for slot in range(self.cfg.batch_size):
            if self.slots[slot] is None and queue:
                self._admit(queue.pop(0), slot)
        if all(s is None for s in self.slots):
            return
        with self._tp_scope():
            logits, self.cache = self._decode(self.params, self.tokens, self.cache)
        self.stats["decode_steps"] += 1
        logits = logits[:, -1, : self.model_cfg.vocab_size]
        if self.cfg.greedy:
            nxt = jnp.argmax(logits, axis=-1)
        else:
            self._rng, k = jax.random.split(self._rng)
            nxt = jax.random.categorical(k, logits / self.cfg.temperature, axis=-1)
        self.tokens = nxt[:, None].astype(jnp.int32)
        for slot, req in enumerate(self.slots):
            if req is None:
                continue
            tok = int(nxt[slot])
            req.output.append(tok)
            if (
                len(req.output) >= req.max_new_tokens
                or (req.eos_id is not None and tok == req.eos_id)
            ):
                req.done = True
                req.t_done = self._clock()
                self.stats["completed"] += 1
                self.slots[slot] = None

    def run(self, requests: List[Request], max_steps: int = 10_000) -> List[Request]:
        queue = list(requests)
        for req in queue:
            if req.t_submit is None:
                req.t_submit = self._clock()
        steps = 0
        while (queue or any(s is not None for s in self.slots)) and steps < max_steps:
            self.step(queue)
            steps += 1
        return requests
