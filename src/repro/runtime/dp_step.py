"""shard_map-pinned data-parallel train step.

GSPMD occasionally picks pathological reshard points inside `lax.scan`
bodies ("[SPMD] Involuntary full rematerialization" — observed on the
xlstm/zamba2 train cells, EXPERIMENTS.md §Perf HC-B).  For replicated-param
(DP) training the communication pattern is fully known: per-device gradients,
ONE all-reduce, replicated update.  This module pins exactly that with
`shard_map`, bypassing the partitioner's choices:

* params + optimizer state replicated (P());
* batch sharded over every mesh axis (pod x data x model ways of DP);
* gradients all-reduced once — optionally int8-compressed
  (`repro.optim.compress`, max-scale-consistent quantized psum), which
  halves the wire bytes of the only collective in the step.

Fits models whose replicated params+moments fit HBM (<= ~1.5B params bf16 +
f32 moments per v5e chip) — exactly the small-dense/SSM regime where the
GSPMD pathology bites.
"""

from __future__ import annotations

from typing import Callable, Optional, Tuple

import jax

from repro import compat
from repro.compat import PartitionSpec as P
from repro.optim import adamw
from repro.optim.compress import ring_int8_allreduce
from repro.runtime import sharding as shd


def make_dp_train_step(
    loss_fn: Callable,            # (params, batch) -> scalar loss
    opt_cfg: adamw.AdamWConfig,
    mesh: jax.sharding.Mesh,
    *,
    compress_grads: bool = False,
    tp_axis: Optional[str] = None,
) -> Callable:
    """Returns jit-able (params, opt_state, batch) -> (params, opt, loss, gnorm).

    ``tp_axis`` reserves one mesh axis for photonic tensor parallelism:
    the batch shards over the remaining axes only, and inside the body
    every routed dense GEMM K-shards over ``tp_axis`` with shard-local
    channel models (``repro.photonic.sharded.manual_tp`` — collectives
    only, since a nested shard_map is illegal here).  Params stay
    replicated, so TP-axis peers hold identical grads and the single
    all-reduce below stays correct unchanged.
    """
    axes: Tuple[str, ...] = tuple(mesh.axis_names)
    if tp_axis is not None and tp_axis not in axes:
        raise ValueError(f"tp_axis {tp_axis!r} not in mesh axes {axes}")
    n_dev = 1
    for a in axes:
        n_dev *= mesh.shape[a]

    dp_axes = tuple(a for a in axes if a != tp_axis)
    batch_spec = P(dp_axes)  # leading (batch) dim sharded over the DP axes

    def step(params, opt_state, batch):
        # constraints are GSPMD-only; inside shard_map all axes are manual
        with shd.no_constraints():
            if tp_axis is not None:
                from repro.photonic import sharded as tp_sharded

                with tp_sharded.manual_tp(tp_axis):
                    loss, grads = jax.value_and_grad(loss_fn)(params, batch)
            else:
                loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        if compress_grads:
            # int8-wire ring all-reduce: halves the only collective's bytes
            grads = ring_int8_allreduce(grads, axes)
            grads = compat.tree_map(lambda g: (g / n_dev).astype(g.dtype), grads)
        else:
            grads = jax.lax.pmean(grads, axes)
        loss = jax.lax.pmean(loss, axes)
        params, opt_state, metrics = adamw.update(opt_cfg, params, grads, opt_state)
        return params, opt_state, loss, metrics["grad_norm"]

    return compat.shard_map(
        step,
        mesh=mesh,
        in_specs=(P(), P(), batch_spec),
        out_specs=(P(), P(), P(), P()),
        check_vma=False,
    )
