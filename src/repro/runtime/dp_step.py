"""shard_map-pinned data-parallel train step.

GSPMD occasionally picks pathological reshard points inside `lax.scan`
bodies ("[SPMD] Involuntary full rematerialization" — observed on the
xlstm/zamba2 train cells, EXPERIMENTS.md §Perf HC-B).  For replicated-param
(DP) training the communication pattern is fully known: per-device gradients,
ONE all-reduce, replicated update.  This module pins exactly that with
`shard_map`, bypassing the partitioner's choices:

* params + optimizer state replicated (P());
* batch sharded over every mesh axis (pod x data x model ways of DP);
* gradients all-reduced once — optionally int8-compressed
  (`repro.optim.compress`, max-scale-consistent quantized psum), which
  halves the wire bytes of the only collective in the step.

Fits models whose replicated params+moments fit HBM (<= ~1.5B params bf16 +
f32 moments per v5e chip) — exactly the small-dense/SSM regime where the
GSPMD pathology bites.
"""

from __future__ import annotations

from functools import partial
from typing import Any, Callable, Tuple

import jax
import jax.numpy as jnp

from repro import compat
from repro.compat import PartitionSpec as P
from repro.optim import adamw
from repro.optim.compress import ring_int8_allreduce
from repro.runtime import sharding as shd


def make_dp_train_step(
    loss_fn: Callable,            # (params, batch) -> scalar loss
    opt_cfg: adamw.AdamWConfig,
    mesh: jax.sharding.Mesh,
    *,
    compress_grads: bool = False,
) -> Callable:
    """Returns jit-able (params, opt_state, batch) -> (params, opt, loss, gnorm)."""
    axes: Tuple[str, ...] = tuple(mesh.axis_names)
    n_dev = 1
    for a in axes:
        n_dev *= mesh.shape[a]

    batch_spec = P(axes)  # leading (batch) dim sharded over every axis

    def step(params, opt_state, batch):
        # constraints are GSPMD-only; inside shard_map all axes are manual
        with shd.no_constraints():
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        if compress_grads:
            # int8-wire ring all-reduce: halves the only collective's bytes
            grads = ring_int8_allreduce(grads, axes)
            grads = compat.tree_map(lambda g: (g / n_dev).astype(g.dtype), grads)
        else:
            grads = jax.lax.pmean(grads, axes)
        loss = jax.lax.pmean(loss, axes)
        params, opt_state, metrics = adamw.update(opt_cfg, params, grads, opt_state)
        return params, opt_state, loss, metrics["grad_norm"]

    return compat.shard_map(
        step,
        mesh=mesh,
        in_specs=(P(), P(), batch_spec),
        out_specs=(P(), P(), P(), P()),
        check_vma=False,
    )
