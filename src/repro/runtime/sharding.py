"""Logical-axis sharding: rules, constraint helper, param shardings.

Tensors (params and activations) carry *logical* axis names
("batch", "heads", "d_ff", ...).  A rule table maps logical names to mesh
axes ("pod", "data", "model").  Resolution is shape-aware: if a dimension is
not divisible by the mapped mesh-axis size, the mapping falls back to
replication for that dimension (recorded, surfaced in the dry-run report) —
this is what makes awkward head counts / batch=1 long-context shapes lower
cleanly instead of erroring.
"""

from __future__ import annotations

import contextlib
import threading
from typing import Any, Dict, Optional, Sequence, Tuple, Union

import jax
import numpy as np

from repro import compat
from repro.compat import Mesh, NamedSharding, PartitionSpec

AxisName = Union[str, Tuple[str, ...]]

# Logical axis -> mesh axis (or tuple of mesh axes).
DEFAULT_RULES: Dict[str, AxisName] = {
    "batch": ("pod", "data"),
    "seq_sp": "model",    # sequence-parallel residual stream
    "kv_seq": "data",     # long-context KV-cache sequence sharding
    "heads": "model",
    "kv_heads": "model",
    "d_ff": "model",
    "vocab": "model",
    "experts": "model",
    "inner": "model",     # xlstm / mamba inner projection dim
    "mamba_heads": "model",
    "state": None,
    # ZeRO-1: optimizer moments additionally shard a replicated dim over data.
    "zero1": ("pod", "data"),
}


def zero1_axes(param_axes: Any, param_shapes: Any, divisor: int) -> Any:
    """Optimizer-moment axes: like the param, plus one unsharded dim sharded
    over the data axes (ZeRO-1).  Shape-aware: picks the first dim divisible
    by the data-parallel degree (skips e.g. 95-layer stack dims)."""

    def is_axes_leaf(x):
        return isinstance(x, tuple) and all(isinstance(e, (str, type(None))) for e in x)

    flat_shapes, treedef = compat.tree_flatten(param_shapes)
    flat_axes = treedef.flatten_up_to(param_axes)

    out = []
    for sds, axes in zip(flat_shapes, flat_axes):
        best = None
        for i, (dim, a) in enumerate(zip(sds.shape, axes)):
            if a is None and dim % divisor == 0:
                best = i
                break
        if best is None:
            out.append(axes)
        else:
            new = list(axes)
            new[best] = "zero1"
            out.append(tuple(new))
    return treedef.unflatten(out)


class _Ctx(threading.local):
    mesh: Optional[Mesh] = None
    rules: Optional[Dict[str, AxisName]] = None
    fallbacks: list = []
    suspended: bool = False


_CTX = _Ctx()


@contextlib.contextmanager
def no_constraints():
    """Suspend logical_constraint (e.g. inside shard_map bodies, where mesh
    axes are manual and with_sharding_constraint is disallowed)."""
    prev = _CTX.suspended
    _CTX.suspended = True
    try:
        yield
    finally:
        _CTX.suspended = prev


@contextlib.contextmanager
def use_rules(mesh: Mesh, rules: Optional[Dict[str, AxisName]] = None):
    """Activate a mesh + rule table for logical_constraint resolution."""
    prev = (_CTX.mesh, _CTX.rules)
    _CTX.mesh = mesh
    merged = dict(DEFAULT_RULES, **(rules or {}))
    # JSON-sourced overrides arrive as lists; normalize to tuples.
    _CTX.rules = {k: tuple(v) if isinstance(v, list) else v for k, v in merged.items()}
    _CTX.fallbacks = []
    try:
        yield
    finally:
        _CTX.mesh, _CTX.rules = prev


def fallback_log() -> list:
    """Divisibility fallbacks recorded during the last use_rules scope."""
    return list(_CTX.fallbacks)


def _mesh_axis_size(mesh: Mesh, axis: AxisName) -> int:
    if isinstance(axis, tuple):
        return int(np.prod([_mesh_axis_size(mesh, a) for a in axis]))
    return mesh.shape[axis]


def _filter_axis(mesh: Mesh, axis: AxisName) -> Optional[AxisName]:
    """Drop mesh axes that don't exist in this mesh (e.g. no 'pod')."""
    if isinstance(axis, tuple):
        kept = tuple(a for a in axis if a in mesh.shape)
        return kept if kept else None
    return axis if axis in mesh.shape else None


def resolve_spec(
    shape: Sequence[int],
    axes: Sequence[Optional[str]],
    mesh: Optional[Mesh] = None,
    rules: Optional[Dict[str, AxisName]] = None,
) -> PartitionSpec:
    """Logical axes -> PartitionSpec with shape-aware divisibility fallback."""
    mesh = mesh or _CTX.mesh
    rules = rules or _CTX.rules or DEFAULT_RULES
    assert mesh is not None, "resolve_spec needs a mesh (use use_rules)"
    parts = []
    used: set = set()
    for dim, name in zip(shape, axes):
        if name is None:
            parts.append(None)
            continue
        mapped = rules.get(name)
        if mapped is None:
            parts.append(None)
            continue
        mapped = _filter_axis(mesh, mapped)
        if mapped is None:
            parts.append(None)
            continue
        # A mesh axis may appear at most once in a spec.
        flat = mapped if isinstance(mapped, tuple) else (mapped,)
        if any(a in used for a in flat):
            parts.append(None)
            continue
        size = _mesh_axis_size(mesh, mapped)
        if dim % size != 0:
            # Try a prefix of the axis tuple (e.g. ("pod","data") -> ("pod",)).
            ok = None
            if isinstance(mapped, tuple):
                for cut in range(len(mapped) - 1, 0, -1):
                    sub = mapped[:cut]
                    if dim % _mesh_axis_size(mesh, sub) == 0:
                        ok = sub
                        break
            if ok is None:
                _CTX.fallbacks.append((tuple(shape), name, mapped, dim, size))
                parts.append(None)
                continue
            mapped = ok
            flat = mapped if isinstance(mapped, tuple) else (mapped,)
        used.update(flat)
        parts.append(mapped)
    while parts and parts[-1] is None:
        parts.pop()
    return PartitionSpec(*parts)


def logical_constraint(x: jax.Array, axes: Sequence[Optional[str]]) -> jax.Array:
    """with_sharding_constraint by logical names; identity with no mesh."""
    if _CTX.mesh is None or _CTX.suspended:
        return x
    spec = resolve_spec(x.shape, axes)
    return jax.lax.with_sharding_constraint(x, NamedSharding(_CTX.mesh, spec))


def named_sharding(
    mesh: Mesh,
    shape: Sequence[int],
    axes: Sequence[Optional[str]],
    rules: Optional[Dict[str, AxisName]] = None,
) -> NamedSharding:
    return NamedSharding(mesh, resolve_spec(shape, axes, mesh, rules))


def tree_shardings(
    mesh: Mesh,
    shapes: Any,     # pytree of arrays or ShapeDtypeStruct
    axes: Any,       # matching pytree whose leaves are tuples of logical names
    rules: Optional[Dict[str, AxisName]] = None,
) -> Any:
    """Build a NamedSharding pytree for pjit in/out_shardings."""

    def leaf(s, a):
        return named_sharding(mesh, s.shape, a, rules)

    # tree_map flattens up to `shapes`' leaves, so the tuple-of-names leaves
    # of `axes` pass through intact.
    return compat.tree_map(leaf, shapes, axes)
