"""Fault-tolerant training loop.

Production behaviors, all exercised by tests/test_runtime.py:

* **checkpoint/restart** — periodic async checkpoints (params + optimizer +
  step); on start, resumes from the latest complete checkpoint; the
  stateless data pipeline replays the exact batch sequence.
* **preemption handling** — SIGTERM/SIGINT trigger a final checkpoint and a
  clean exit (the SLURM/Borg eviction pattern).
* **straggler watchdog** — per-step wall times feed an EWMA; steps slower
  than ``straggler_factor`` x the EWMA are logged with a mitigation hook
  (on real fleets: re-shard / hot-spare swap; here: recorded + surfaced).
* **elastic resume** — checkpoints are mesh-independent (host arrays), so a
  job may resume on a different mesh shape; shardings are re-derived.
* **microbatching** — gradient accumulation splits the global batch into
  ``microbatches`` sequential chunks (jax.lax.scan), trading step time for
  activation memory.
"""

from __future__ import annotations

import dataclasses
import signal
import time
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro import compat
from repro.checkpoint import ckpt
from repro.data.pipeline import DataConfig, SyntheticTokens
from repro.models.common import axes_tree, init_tree
from repro.optim import adamw
from repro.runtime import sharding as shd


@dataclasses.dataclass
class TrainConfig:
    steps: int = 100
    ckpt_every: int = 20
    ckpt_dir: str = "/tmp/repro_ckpt"
    log_every: int = 10
    microbatches: int = 1
    straggler_factor: float = 3.0
    async_checkpoint: bool = True
    keep_checkpoints: int = 3
    seed: int = 0


@dataclasses.dataclass
class TrainState:
    params: Any
    opt_state: adamw.OptState
    step: int


def build_train_step(
    loss_fn: Callable, opt_cfg: adamw.AdamWConfig, microbatches: int = 1
):
    """jit-able (params, opt_state, batch) -> (params, opt_state, metrics)."""

    def step(params, opt_state, batch):
        if microbatches == 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        else:
            def micro(carry, mb):
                acc, _ = carry
                l, g = jax.value_and_grad(loss_fn)(params, mb)
                return (compat.tree_map(jnp.add, acc, g), l), None

            mbs = compat.tree_map(
                lambda x: x.reshape(microbatches, -1, *x.shape[1:]), batch
            )
            zero = compat.tree_map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (gsum, loss), _ = jax.lax.scan(micro, (zero, jnp.zeros(())), mbs)
            grads = compat.tree_map(lambda g: g / microbatches, gsum)
        params, opt_state, metrics = adamw.update(opt_cfg, params, grads, opt_state)
        metrics["loss"] = loss
        return params, opt_state, metrics

    return step


class StragglerWatchdog:
    def __init__(self, factor: float):
        self.factor = factor
        self.ewma: Optional[float] = None
        self.events: list = []

    def observe(self, step: int, dt: float) -> bool:
        straggled = self.ewma is not None and dt > self.factor * self.ewma
        if straggled:
            self.events.append((step, dt, self.ewma))
        # EWMA excludes straggler samples so one hiccup doesn't mask the next
        if not straggled:
            self.ewma = dt if self.ewma is None else 0.9 * self.ewma + 0.1 * dt
        return straggled


def train(
    *,
    arch,
    model_cfg,
    data_cfg: DataConfig,
    train_cfg: TrainConfig,
    opt_cfg: Optional[adamw.AdamWConfig] = None,
    mesh: Optional[jax.sharding.Mesh] = None,
    tp_axis: Optional[str] = None,  # K-shard photonic GEMMs over this axis
    fail_at_step: Optional[int] = None,  # test hook: simulated crash
    log: Callable[[str], None] = print,
) -> Dict[str, Any]:
    """Run (or resume) a training job. Returns final state + metrics."""
    opt_cfg = opt_cfg or adamw.AdamWConfig(total_steps=train_cfg.steps)
    stream = SyntheticTokens(data_cfg)
    # Photonic QAT: construct the engine up front so an invalid operating
    # point / scope / backend fails here with a readable error instead of
    # mid-trace inside the first jitted step, and the operator can see
    # which sites run photonically (STE backward keeps dense gradients).
    from repro.models.common import engine_from_model_config

    photonic_engine = engine_from_model_config(model_cfg)
    if photonic_engine is not None:
        log(f"[train] photonic engine: {photonic_engine.describe()}")
    loss_fn = lambda p, b: arch.loss(p, b, model_cfg)  # noqa: E731
    step_fn = build_train_step(loss_fn, opt_cfg, train_cfg.microbatches)

    defs = arch.param_defs(model_cfg)
    param_axes = axes_tree(defs)

    if mesh is not None:
        ctx = shd.use_rules(mesh)
        ctx.__enter__()
        params_sh = shd.tree_shardings(
            mesh,
            jax.eval_shape(
                lambda k: init_tree(defs, k, model_cfg.param_dtype),
                jax.ShapeDtypeStruct((2,), jnp.uint32),
            ),
            param_axes,
        )
    else:
        ctx = None
        params_sh = None

    # ---- init or resume -----------------------------------------------------
    start = ckpt.latest_step(train_cfg.ckpt_dir)
    params = init_tree(defs, jax.random.PRNGKey(train_cfg.seed), model_cfg.param_dtype)
    opt_state = adamw.init(params)
    step0 = 0
    if start is not None:
        state_like = {"params": params, "opt": opt_state}
        restored = ckpt.restore(train_cfg.ckpt_dir, start, state_like)
        params, opt_state = restored["params"], restored["opt"]
        step0 = start
        log(f"[train] resumed from checkpoint step {start}")

    jitted = jax.jit(step_fn, donate_argnums=(0, 1))

    # ---- preemption handling -------------------------------------------------
    preempted = {"flag": False}

    def _handler(signum, frame):
        preempted["flag"] = True

    old_handlers = {}
    for sig in (signal.SIGTERM, signal.SIGINT):
        try:
            old_handlers[sig] = signal.signal(sig, _handler)
        except ValueError:
            pass  # non-main thread (tests)

    watchdog = StragglerWatchdog(train_cfg.straggler_factor)
    losses = []
    pending_save = None
    # Tensor-parallel photonic QAT: the TP scope must be live whenever the
    # jitted step (re)traces, i.e. across the whole loop.  Entered as the
    # last statement before the try so the matching __exit__ in `finally`
    # cannot be skipped by a setup failure (a leaked thread-local scope
    # would silently re-route every later dense() in this process).
    tp_ctx = None
    if tp_axis is not None and mesh is not None and photonic_engine is not None:
        from repro.photonic import sharded as tp_sharded

        log(f"[train] photonic tensor-parallel over mesh axis {tp_axis!r}")
        tp_ctx = tp_sharded.tensor_parallel(mesh, tp_axis)
        tp_ctx.__enter__()
    try:
        for step in range(step0, train_cfg.steps):
            if fail_at_step is not None and step == fail_at_step:
                raise RuntimeError(f"simulated node failure at step {step}")
            t0 = time.time()
            batch = {k: jnp.asarray(v) for k, v in stream.batch_at(step).items()}
            params, opt_state, metrics = jitted(params, opt_state, batch)
            loss = float(metrics["loss"])
            dt = time.time() - t0
            losses.append(loss)
            if watchdog.observe(step, dt):
                log(
                    f"[train] straggler at step {step}: {dt:.3f}s "
                    f"(ewma {watchdog.ewma:.3f}s)"
                )
            if step % train_cfg.log_every == 0:
                log(f"[train] step {step} loss {loss:.4f} ({dt:.3f}s)")
            is_last = step == train_cfg.steps - 1
            if (step + 1) % train_cfg.ckpt_every == 0 or is_last or preempted["flag"]:
                if pending_save is not None:
                    pending_save.join()
                pending_save = ckpt.save(
                    train_cfg.ckpt_dir,
                    step + 1,
                    {"params": params, "opt": opt_state},
                    blocking=not train_cfg.async_checkpoint,
                )
                ckpt.cleanup(train_cfg.ckpt_dir, train_cfg.keep_checkpoints)
            if preempted["flag"]:
                log(f"[train] preempted at step {step}; checkpointed and exiting")
                break
    finally:
        if pending_save is not None:
            pending_save.join()
        for sig, h in old_handlers.items():
            signal.signal(sig, h)
        if tp_ctx is not None:
            tp_ctx.__exit__(None, None, None)
        if ctx is not None:
            ctx.__exit__(None, None, None)

    return {
        "params": params,
        "opt_state": opt_state,
        "losses": losses,
        "straggler_events": watchdog.events,
        "final_step": step0 + len(losses),
        "preempted": preempted["flag"],
    }
