"""The `repro.noise` channel model: Table II/III structure, backend
equivalence (bit-identical ideal path, statistical noise agreement),
determinism, and differentiability."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import scalability
from repro.core.dpu import DPUConfig, dpu_int_gemm
from repro.core.organizations import ORGANIZATIONS, through_device_count
from repro.kernels.photonic_gemm.ops import photonic_gemm_int
from repro.kernels.photonic_gemm.ref import exact_int_gemm
from repro.noise import (
    apply_channel_psum,
    build_channel_model,
    fold_seed,
    gaussian_from_counter,
    neighbor_sum,
    round_ste,
)


def _rand_int8(rng, shape):
    return jnp.asarray(rng.integers(-127, 128, shape, dtype=np.int8))


# ---------------------------------------------------------------------------
# Table II — crosstalk presence/absence per organization
# ---------------------------------------------------------------------------
def test_table2_crosstalk_structure():
    asmw = build_channel_model("ASMW", n=16)
    masw = build_channel_model("MASW", n=16)
    smwa = build_channel_model("SMWA", n=16)
    # ASMW: inter-modulation + cross-weight, no filter truncation.
    assert asmw.intermod_eps > 0 and asmw.crossweight_eps > 0
    assert asmw.filter_alpha == 0.0
    # MASW: cross-weight + filter truncation, no inter-modulation.
    assert masw.intermod_eps == 0.0
    assert masw.crossweight_eps > 0 and masw.filter_alpha > 0
    # SMWA ("hitless"): only filter truncation.
    assert smwa.intermod_eps == 0.0 and smwa.crossweight_eps == 0.0
    assert smwa.filter_alpha > 0
    # Budget ordering (paper §IV-C): cross-weight (3 dB) > inter-mod (1 dB)
    # > filter (0.5 dB).
    assert asmw.crossweight_eps > asmw.intermod_eps > smwa.filter_alpha / 2


# ---------------------------------------------------------------------------
# Table III — loss-chain structure
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("n", [4, 17, 42, 83])
def test_table3_through_loss_formulas(n):
    p = scalability.CALIBRATED
    for org, count in (("ASMW", 2 * (n - 1)), ("MASW", n), ("SMWA", 2)):
        ch = build_channel_model(org, n=n)
        assert count == through_device_count(org, n)
        np.testing.assert_allclose(
            ch.through_loss_db, count * p.p_mrm_obl_db, rtol=1e-12
        )


def test_through_loss_growth():
    """ASMW through loss grows ~2N; SMWA's is constant in N (Table III)."""
    a8, a64 = (build_channel_model("ASMW", n=n).through_loss_db for n in (8, 64))
    s8, s64 = (build_channel_model("SMWA", n=n).through_loss_db for n in (8, 64))
    assert a64 / a8 == pytest.approx((2 * 63) / (2 * 7))
    assert s64 == s8  # constant: 2 devices regardless of N
    # MASW sits between.
    m8, m64 = (build_channel_model("MASW", n=n).through_loss_db for n in (8, 64))
    assert a64 > m64 > s64
    assert m64 / m8 == pytest.approx(8.0)


def test_detector_sigma_ordering_and_monotonicity():
    """Penalty + loss ordering (SMWA best) shows up as noise sigma; sigma
    grows with N for every organization (less power per channel)."""
    for n in (8, 17, 42):
        sig = {o: build_channel_model(o, n=n).detector_sigma_lsb for o in ORGANIZATIONS}
        assert sig["ASMW"] > sig["MASW"] > sig["SMWA"], (n, sig)
    for org in ORGANIZATIONS:
        sigs = [
            build_channel_model(org, n=n).detector_sigma_lsb for n in (8, 16, 32, 64)
        ]
        assert sigs == sorted(sigs)


def test_snr_consistent_with_scalability_solver():
    """At the calibrated achievable N the delivered-power SNR meets the
    B-bit ENOB requirement; one step past it, it no longer does."""
    margin = scalability.calibration().snr_margin_db
    need_db = 6.02 * 4 + 1.76 + margin
    for org in ORGANIZATIONS:
        n_max = scalability.calibrated_max_n(org, 4, 5.0)
        ch = build_channel_model(org, n=n_max, bits=4, datarate_gs=5.0)
        assert ch.snr_db >= need_db - 1e-6, (org, ch.snr_db, need_db)
        beyond = build_channel_model(org, n=n_max + 1, bits=4, datarate_gs=5.0)
        assert beyond.snr_db < need_db
        assert beyond.detector_sigma_lsb > ch.detector_sigma_lsb


# ---------------------------------------------------------------------------
# Ideal channel == exact integer path, bit-identical, both backends
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("org", ORGANIZATIONS)
def test_disabled_channel_bit_identical(org):
    rng = np.random.default_rng(0)
    xq = _rand_int8(rng, (16, 200))
    wq = _rand_int8(rng, (200, 96))
    ch = build_channel_model(org, n=21).disable("all")
    assert ch.is_ideal
    cfg = DPUConfig(organization=org, dpe_size=21, channel=ch)
    gold = np.asarray(exact_int_gemm(xq, wq))
    for backend in ("ref", "pallas"):
        out = photonic_gemm_int(xq, wq, cfg, backend=backend)
        np.testing.assert_array_equal(np.asarray(out), gold)
    np.testing.assert_array_equal(np.asarray(dpu_int_gemm(xq, wq, cfg)), gold)


def test_builder_enable_flags_disable_stages():
    ch = build_channel_model(
        "MASW",
        n=16,
        enable_crosstalk=False,
        enable_detector_noise=False,
    )
    assert ch.is_ideal
    full = build_channel_model("MASW", n=16)
    assert not full.is_ideal
    assert full.disable("crosstalk").crossweight_eps == 0.0
    assert full.disable("detector").analog  # crosstalk still on


# ---------------------------------------------------------------------------
# Deterministic stages: oracle / ref / pallas agree bitwise
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("org,n", [("ASMW", 17), ("MASW", 21), ("SMWA", 42)])
def test_crosstalk_stages_bitwise_across_backends(org, n):
    rng = np.random.default_rng(2)
    xq = _rand_int8(rng, (32, 200))
    wq = _rand_int8(rng, (200, 64))
    ch = build_channel_model(org, n=n).disable("detector")
    cfg = DPUConfig(organization=org, dpe_size=n, channel=ch)
    ref = np.asarray(photonic_gemm_int(xq, wq, cfg, backend="ref"))
    pal = np.asarray(photonic_gemm_int(xq, wq, cfg, backend="pallas"))
    orc = np.asarray(dpu_int_gemm(xq, wq, cfg))
    np.testing.assert_array_equal(ref, pal)
    np.testing.assert_array_equal(ref, orc)


def test_crosstalk_perturbs_but_smwa_unbiased_by_neighbors():
    """Crosstalk-on changes results for ASMW/MASW; SMWA's only Table II
    effect is filter truncation (a pure amplitude compression)."""
    rng = np.random.default_rng(3)
    xq = _rand_int8(rng, (8, 84))
    wq = _rand_int8(rng, (84, 16))
    gold = np.asarray(exact_int_gemm(xq, wq))
    for org in ("ASMW", "MASW"):
        ch = build_channel_model(org, n=21).disable("detector", "filter")
        cfg = DPUConfig(organization=org, dpe_size=21, channel=ch)
        out = np.asarray(photonic_gemm_int(xq, wq, cfg, backend="ref"))
        assert (out != gold).any(), org
    ch = build_channel_model("SMWA", n=21).disable("detector", "filter")
    cfg = DPUConfig(organization="SMWA", dpe_size=21, channel=ch)
    out = np.asarray(photonic_gemm_int(xq, wq, cfg, backend="ref"))
    np.testing.assert_array_equal(out, gold)  # nothing left to perturb


# ---------------------------------------------------------------------------
# Noise: statistical pallas/oracle agreement, bitwise ref==dpu
# ---------------------------------------------------------------------------
def test_pallas_noise_statistics_match_oracle():
    rng = np.random.default_rng(4)
    xq = _rand_int8(rng, (128, 256))
    wq = _rand_int8(rng, (256, 128))
    ch = build_channel_model("SMWA", n=64).disable("crosstalk")
    cfg = DPUConfig(dpe_size=64, channel=ch, noise_seed=3)
    gold = np.asarray(exact_int_gemm(xq, wq), np.float64)
    e_pal = (
        np.asarray(photonic_gemm_int(xq, wq, cfg, backend="pallas"), np.float64)
        - gold
    )
    e_ref = np.asarray(photonic_gemm_int(xq, wq, cfg, backend="ref"), np.float64) - gold
    assert abs(e_pal.std() / e_ref.std() - 1.0) < 0.1, (e_pal.std(), e_ref.std())
    # Means consistent with zero (std over sqrt(n_samples) scale).
    tol = 4 * e_ref.std() / np.sqrt(e_ref.size)
    assert abs(e_pal.mean()) < tol and abs(e_ref.mean()) < tol


def test_pallas_noise_statistics_ragged_k():
    """K-padding chunks must not receive noise (variance would inflate)."""
    rng = np.random.default_rng(5)
    xq = _rand_int8(rng, (64, 200))   # 200 = 2 full + 1 partial chunk of 83
    wq = _rand_int8(rng, (200, 128))
    ch = build_channel_model("SMWA", n=83).disable("crosstalk")
    cfg = DPUConfig(dpe_size=83, channel=ch, noise_seed=9)
    gold = np.asarray(exact_int_gemm(xq, wq), np.float64)
    e_pal = (
        np.asarray(photonic_gemm_int(xq, wq, cfg, backend="pallas"), np.float64)
        - gold
    )
    e_ref = np.asarray(photonic_gemm_int(xq, wq, cfg, backend="ref"), np.float64) - gold
    assert abs(e_pal.std() / e_ref.std() - 1.0) < 0.1, (e_pal.std(), e_ref.std())


def test_noisy_ref_bitwise_equals_dpu_oracle():
    rng = np.random.default_rng(6)
    xq = _rand_int8(rng, (16, 100))
    wq = _rand_int8(rng, (100, 24))
    ch = build_channel_model("ASMW", n=17)
    cfg = DPUConfig(organization="ASMW", dpe_size=17, channel=ch)
    key = jax.random.PRNGKey(11)
    a = dpu_int_gemm(xq, wq, cfg, prng_key=key)
    b = photonic_gemm_int(xq, wq, cfg, backend="ref", prng_key=key)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_pallas_noise_seed_determinism():
    rng = np.random.default_rng(7)
    xq = _rand_int8(rng, (32, 128))
    wq = _rand_int8(rng, (128, 32))
    ch = build_channel_model("MASW", n=32)
    c1 = DPUConfig(organization="MASW", dpe_size=32, channel=ch, noise_seed=1)
    c2 = DPUConfig(organization="MASW", dpe_size=32, channel=ch, noise_seed=2)
    a = photonic_gemm_int(xq, wq, c1, backend="pallas")
    b = photonic_gemm_int(xq, wq, c1, backend="pallas")
    c = photonic_gemm_int(xq, wq, c2, backend="pallas")
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert (np.asarray(a) != np.asarray(c)).any()


# ---------------------------------------------------------------------------
# Stage primitives
# ---------------------------------------------------------------------------
def test_neighbor_sum_zero_edges():
    x = jnp.asarray([[1.0, 2.0, 3.0, 4.0]])
    out = np.asarray(neighbor_sum(x, axis=1))
    np.testing.assert_allclose(out, [[2.0, 4.0, 6.0, 3.0]])


def test_gaussian_from_counter_moments():
    z = np.asarray(gaussian_from_counter(fold_seed(jnp.uint32(42), 0), (256, 256)))
    assert abs(z.mean()) < 0.02
    assert abs(z.std() - 1.0) < 0.02
    # Distinct streams are decorrelated.
    z2 = np.asarray(gaussian_from_counter(fold_seed(jnp.uint32(42), 1), (256, 256)))
    assert abs(np.corrcoef(z.ravel(), z2.ravel())[0, 1]) < 0.02


def test_round_ste_identity_gradient():
    g = jax.grad(lambda x: round_ste(3.0 * x).sum())(jnp.ones(5))
    np.testing.assert_allclose(np.asarray(g), 3.0)


def test_apply_channel_psum_differentiable():
    """filter -> noise -> ADC chain passes gradients (STE through round,
    zero grad only where the ADC saturates)."""
    ch = build_channel_model("SMWA", n=16, adc_bits=8)
    a = jnp.asarray([0.4, 10.0, 1e6, -1e6])  # last two saturate
    seed = fold_seed(jnp.uint32(0), 0)

    def f(a):
        return apply_channel_psum(a, ch, seed).sum()

    g = np.asarray(jax.grad(f)(a))
    scale = 1.0 - ch.filter_alpha
    np.testing.assert_allclose(g[:2], scale, rtol=1e-6)
    np.testing.assert_allclose(g[2:], 0.0)


def test_channel_model_hashable_jit_static():
    ch = build_channel_model("SMWA", n=16)
    assert hash(ch) == hash(dataclasses.replace(ch))

    @jax.jit
    def f(a):
        return apply_channel_psum(a, ch, fold_seed(jnp.uint32(1), 0))

    out = f(jnp.ones((4, 4)) * 100.0)
    assert out.shape == (4, 4)
    # vmap over inputs with the channel closed over.
    outs = jax.vmap(lambda a: apply_channel_psum(a, ch, fold_seed(jnp.uint32(1), 0)))(
        jnp.ones((3, 5)) * 50.0
    )
    assert outs.shape == (3, 5)


def test_adc_saturation_under_channel():
    rng = np.random.default_rng(8)
    xq = _rand_int8(rng, (8, 128))
    wq = _rand_int8(rng, (128, 8))
    ch = build_channel_model("SMWA", n=32, adc_bits=8).disable("detector", "filter")
    cfg = DPUConfig(dpe_size=32, channel=ch)
    gold = np.asarray(exact_int_gemm(xq, wq))
    sat = np.asarray(photonic_gemm_int(xq, wq, cfg, backend="ref"))
    assert np.abs(sat).max() <= np.abs(gold).max()
    assert (sat != gold).any()
    # Same semantics on the Pallas path.
    np.testing.assert_array_equal(
        sat, np.asarray(photonic_gemm_int(xq, wq, cfg, backend="pallas"))
    )
