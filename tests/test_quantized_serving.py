"""int8 serving paths: int8 KV cache and int8-stored (photonic) weights."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.dpu import DPUConfig
from repro.models import registry
from repro.models.common import init_tree, quantize_params


def _roundtrip(arch, cfg, params, toks, T):
    logits, cache = arch.prefill(params, {"tokens": toks[:, : T - 4]}, cfg, T)
    outs = [logits]
    for i in range(T - 4, T):
        logits, cache = arch.decode(params, toks[:, i : i + 1], cache, cfg)
        outs.append(logits)
    return jnp.concatenate(outs, axis=1)


@pytest.mark.parametrize("name", ["granite-3-8b", "zamba2-2.7b", "whisper-medium"])
def test_int8_kv_cache_close_to_f32(name):
    arch = registry.get(name)
    cfg = dataclasses.replace(arch.smoke_config, remat=False)
    params = init_tree(arch.param_defs(cfg), jax.random.PRNGKey(0), cfg.param_dtype)
    rng = np.random.default_rng(0)
    B, T = 2, 16
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, T)), jnp.int32)
    audio = jnp.asarray(rng.normal(size=(B, T, cfg.d_model)), jnp.float32)

    def run(c):
        batch = {"tokens": toks[:, : T - 4]}
        if arch.family == "audio":
            batch["audio_embed"] = audio
        logits, cache = arch.prefill(params, batch, c, T)
        outs = [logits]
        for i in range(T - 4, T):
            logits, cache = arch.decode(params, toks[:, i : i + 1], cache, c)
            outs.append(logits)
        return jnp.concatenate(outs, axis=1)

    f32 = run(cfg)
    i8 = run(dataclasses.replace(cfg, kv_cache_int8=True))
    rel = float(jnp.linalg.norm(i8 - f32) / jnp.linalg.norm(f32))
    agree = float(jnp.mean(jnp.argmax(i8, -1) == jnp.argmax(f32, -1)))
    assert rel < 0.05, (name, rel)
    assert agree >= 0.9, (name, agree)


def test_int8_weight_storage_close_to_float():
    arch = registry.get("qwen2-0.5b")
    cfg = dataclasses.replace(arch.smoke_config, remat=False)
    cfg_q = dataclasses.replace(
        cfg,
        photonic=DPUConfig(organization="SMWA", bits=4, datarate_gs=5.0),
        photonic_backend="ref",
        photonic_scope="weights_int8",
    )
    params = init_tree(arch.param_defs(cfg), jax.random.PRNGKey(0), cfg.param_dtype)
    defs_q = arch.param_defs(cfg_q)
    params_q = quantize_params(params, defs_q)
    # int8 leaves exist with scales
    leaves = jax.tree_util.tree_flatten_with_path(params_q)[0]
    assert any(l.dtype == jnp.int8 for _, l in leaves)

    rng = np.random.default_rng(1)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 16)), jnp.int32)
    f = _roundtrip(arch, cfg, params, toks, 16)
    q = _roundtrip(arch, cfg_q, params_q, toks, 16)
    rel = float(jnp.linalg.norm(q - f) / jnp.linalg.norm(f))
    agree = float(jnp.mean(jnp.argmax(q, -1) == jnp.argmax(f, -1)))
    assert rel < 0.2, rel
    assert agree >= 0.75, agree


def test_mla_absorbed_decode_exact():
    """Weight-absorbed MLA decode == naive MLA decode (linear identity)."""
    arch = registry.get("deepseek-v2-lite-16b")
    cfg = dataclasses.replace(arch.smoke_config, remat=False)
    params = init_tree(arch.param_defs(cfg), jax.random.PRNGKey(0), cfg.param_dtype)
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 16)), jnp.int32)

    a = _roundtrip(arch, cfg, params, toks, 16)
    b = _roundtrip(arch, dataclasses.replace(cfg, mla_absorb=True), params, toks, 16)
    assert float(jnp.abs(a - b).max()) < 1e-4


def test_int8_cache_def_shapes():
    from repro.models import attention as attn

    arch = registry.get("granite-3-8b")
    cfg = dataclasses.replace(arch.smoke_config, kv_cache_int8=True)
    d = attn.gqa_cache_def(cfg, 4, 32, jnp.bfloat16)
    assert d["k"][2] == jnp.int8
    assert d["k_scale"][0] == (4, 32, cfg.num_kv_heads)
