"""Tests for `repro.platforms` — first-class material platform specs.

The PR-9 contract mirrors `repro.orgs`: one frozen spec per platform,
one blessed resolution point (`repro.platforms.resolve`), eager
validation at every platform-typed entry point, and an SOI preset that
is field-for-field the paper's Table IV — so every pre-platform call
site stays bitwise unchanged.
"""

import dataclasses

import pytest

from repro import platforms
from repro.core import scalability as sc
from repro.core.dpu import DPUConfig
from repro.core.params import PhotonicParams
from repro.core.perfmodel import AcceleratorConfig
from repro.noise import build_channel_model, shard_local_channel
from repro.platforms import SIN, SOI, PlatformSpec, resolve


class TestResolve:
    def test_round_trips(self):
        assert resolve("SOI") is SOI
        assert resolve("SIN") is SIN
        # Case / whitespace are normalized by the single blessed site.
        assert resolve("soi") is SOI
        assert resolve(" sin ") is SIN
        assert resolve("SiN") is SIN
        # Spec input is the identity; resolve is idempotent.
        assert resolve(SOI) is SOI
        assert resolve(resolve("SIN")) is resolve("SIN")

    def test_registry_snapshot(self):
        reg = platforms.registered()
        assert set(reg) >= {"SOI", "SIN"}
        assert tuple(platforms.PLATFORMS) == ("SOI", "SIN")
        for name, spec in reg.items():
            assert spec.name == name
            assert str(spec) == name

    def test_unknown_platform_raises_naming_choices(self):
        with pytest.raises(ValueError, match="SOI"):
            resolve("GAAS")
        with pytest.raises(ValueError, match="SIN"):
            resolve("InP")

    def test_non_string_rejected(self):
        with pytest.raises(ValueError, match="str or PlatformSpec"):
            resolve(3)
        with pytest.raises(ValueError, match="str or PlatformSpec"):
            resolve(None)

    def test_non_canonical_spec_name_rejected(self):
        with pytest.raises(ValueError, match="canonical"):
            PlatformSpec(name="soi")

    def test_register_conflict_rejected(self):
        # Re-registering the identical spec is a no-op...
        assert platforms.register(SOI) is SOI
        # ...but forking the physics behind an existing name raises.
        clash = dataclasses.replace(SOI, propagation_loss_db_per_mm=9.9)
        with pytest.raises(ValueError, match="already registered"):
            platforms.register(clash)


class TestEagerValidation:
    @pytest.mark.parametrize(
        "ctor",
        [
            lambda p: DPUConfig(platform=p),
            lambda p: AcceleratorConfig(platform=p),
            lambda p: build_channel_model("SMWA", n=8, platform=p),
            lambda p: sc.calibrated_max_n("SMWA", 4, 5.0, platform=p),
        ],
        ids=[
            "DPUConfig",
            "AcceleratorConfig",
            "build_channel_model",
            "calibrated_max_n",
        ],
    )
    def test_unknown_platform_raises_valueerror(self, ctor):
        with pytest.raises(ValueError, match="SOI"):
            ctor("not-a-platform")

    def test_configs_normalize_to_canonical_name(self):
        assert DPUConfig(platform="sin").platform == "SIN"
        assert DPUConfig(platform=SIN).platform == "SIN"
        assert AcceleratorConfig(platform=" soi ").platform == "SOI"
        assert DPUConfig(platform="sin") == DPUConfig(platform="SIN")
        assert hash(DPUConfig(platform="sin")) == hash(DPUConfig(platform="SIN"))
        assert DPUConfig(platform="SIN").platform_spec is SIN


class TestSOIIsThePaperBaseline:
    """SOI.apply is the identity on the Table IV calibration, so every
    pre-platform call site is bitwise unchanged (PR-9 compat contract)."""

    def test_soi_apply_is_identity_on_calibrated_params(self):
        assert SOI.apply(sc.CALIBRATED) == sc.CALIBRATED
        assert SOI.apply(PhotonicParams()) == PhotonicParams()

    def test_soi_preset_matches_table_iv_field_for_field(self):
        p = PhotonicParams()
        assert SOI.coupling_loss_db == p.p_ec_il_db == 1.44
        assert SOI.propagation_loss_db_per_mm == p.p_si_att_db_per_mm == 0.3
        assert SOI.splitter_loss_db == p.p_splitter_il_db == 0.01
        assert SOI.mrm_il_db == p.p_mrm_il_db == 4.0
        assert SOI.mrr_w_il_db == p.p_mrr_w_il_db == 0.01
        assert SOI.mrm_through_db == p.p_mrm_obl_db == 0.01
        assert SOI.mrr_w_through_db == p.p_mrr_w_obl_db == 0.01
        assert SOI.laser_wallplug_eff == p.laser_wallplug_eff == 0.2

    @pytest.mark.parametrize("org", ["ASMW", "MASW", "SMWA"])
    def test_default_channel_is_the_soi_channel(self, org):
        """build_channel_model without a platform == explicit SOI, every
        field equal (frozen-dataclass equality covers the builder tuple)."""
        default = build_channel_model(org, n=17, bits=4, datarate_gs=5.0)
        explicit = build_channel_model(
            org, n=17, bits=4, datarate_gs=5.0, platform="SOI"
        )
        assert default == explicit
        assert default.platform == "SOI"
        for f in dataclasses.fields(default):
            assert getattr(default, f.name) == getattr(explicit, f.name), f.name

    def test_sin_apply_changes_only_platform_owned_fields(self):
        applied = SIN.apply(sc.CALIBRATED)
        changed = {
            f.name
            for f in dataclasses.fields(applied)
            if getattr(applied, f.name) != getattr(sc.CALIBRATED, f.name)
        }
        platform_owned = {
            "p_ec_il_db",
            "p_si_att_db_per_mm",
            "p_splitter_il_db",
            "p_mrm_il_db",
            "p_mrr_w_il_db",
            "p_mrm_obl_db",
            "p_mrr_w_obl_db",
            "laser_wallplug_eff",
        }
        assert changed <= platform_owned, changed
        # Idempotent: applying twice is applying once.
        assert SIN.apply(applied) == applied


class TestPlatformProvenance:
    @pytest.mark.parametrize("org", ["ASMW", "MASW", "SMWA"])
    def test_shard_local_rebuild_preserves_platform(self, org):
        base = build_channel_model(org, n=32, bits=4, datarate_gs=5.0, platform="SIN")
        assert base.platform == "SIN"
        for n_local in (16, 8, 3):
            local = shard_local_channel(base, n_local)
            assert local.platform == "SIN"
            assert local == build_channel_model(
                org, n=n_local, bits=4, datarate_gs=5.0, platform="SIN"
            )

    def test_dpu_config_shard_local_preserves_platform(self):
        ch = build_channel_model("SMWA", n=32, platform="SIN")
        cfg = DPUConfig(organization="SMWA", dpe_size=32, platform="SIN", channel=ch)
        local = cfg.shard_local(8)
        assert local.platform == "SIN"
        assert local.channel.platform == "SIN"

    @pytest.mark.parametrize("org", ["ASMW", "MASW", "SMWA"])
    def test_sin_lower_loss_buys_fanin_and_snr(self, org):
        """The physics the preset encodes: SiN's lower loss chain yields a
        larger calibrated N and a better SNR at matched geometry."""
        n_soi = sc.calibrated_max_n(org, 4, 5.0, platform="SOI")
        n_sin = sc.calibrated_max_n(org, 4, 5.0, platform="SIN")
        assert n_sin > n_soi
        soi = build_channel_model(org, n=32, platform="SOI")
        sin = build_channel_model(org, n=32, platform="SIN")
        assert sin.snr_db > soi.snr_db
        assert sin.detector_sigma_lsb < soi.detector_sigma_lsb
        assert sin.total_loss_db() < soi.total_loss_db()
