"""Kernel sweep: Pallas photonic GEMM vs the pure-jnp oracle, plus DPU
datapath invariants (property-based)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.core.dpu import (
    DPUConfig,
    bit_slices,
    dpu_int_gemm,
    photonic_matmul,
    photonic_matmul_ste,
    quantize_symmetric,
)
from repro.kernels.photonic_gemm.ops import photonic_gemm, photonic_gemm_int
from repro.kernels.photonic_gemm.ref import (
    exact_int_gemm,
    slice_decompose,
)


def _rand_int8(rng, shape):
    return jnp.asarray(rng.integers(-127, 128, shape, dtype=np.int8))


# ---------------------------------------------------------------------------
# Shape / precision sweep of the Pallas kernel vs the oracle
# ---------------------------------------------------------------------------
SHAPES = [
    (8, 64, 32),
    (16, 200, 96),     # K not a multiple of the chunk
    (1, 128, 128),     # decode-like single row
    (64, 83, 83),      # K = exactly one SMWA DPE
    (128, 512, 256),
    (33, 1000, 17),    # ragged everything
]


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("bits,operand_bits", [(4, 8), (2, 8), (8, 8), (4, 4)])
def test_pallas_matches_oracle(shape, bits, operand_bits):
    r, k, c = shape
    rng = np.random.default_rng(hash((shape, bits)) % 2**32)
    xq = _rand_int8(rng, (r, k))
    wq = _rand_int8(rng, (k, c))
    if operand_bits < 8:
        lim = 2 ** (operand_bits - 1) - 1
        xq = jnp.clip(xq, -lim, lim)
        wq = jnp.clip(wq, -lim, lim)
    cfg = DPUConfig(bits=bits, operand_bits=operand_bits, dpe_size=83)
    gold = exact_int_gemm(xq, wq)
    ref = photonic_gemm_int(xq, wq, cfg, backend="ref")
    pal = photonic_gemm_int(xq, wq, cfg, backend="pallas")
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(gold))
    np.testing.assert_array_equal(np.asarray(pal), np.asarray(gold))


@pytest.mark.parametrize("adc_bits", [10, 12, 16])
def test_pallas_adc_saturation_matches_ref(adc_bits):
    rng = np.random.default_rng(7)
    xq = _rand_int8(rng, (32, 256))
    wq = _rand_int8(rng, (256, 64))
    cfg = DPUConfig(dpe_size=42, adc_bits=adc_bits)
    ref = photonic_gemm_int(xq, wq, cfg, backend="ref")
    pal = photonic_gemm_int(xq, wq, cfg, backend="pallas")
    np.testing.assert_array_equal(np.asarray(pal), np.asarray(ref))


def test_adc_saturation_bounds_error():
    """Saturated psums bias the result, but never past the clip bound."""
    rng = np.random.default_rng(11)
    xq = _rand_int8(rng, (16, 512))
    wq = _rand_int8(rng, (512, 32))
    gold = np.asarray(exact_int_gemm(xq, wq))
    sat = np.asarray(
        photonic_gemm_int(xq, wq, DPUConfig(dpe_size=64, adc_bits=8), backend="ref")
    )
    assert np.abs(sat).max() <= np.abs(gold).max()


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_float_roundtrip_error_small(dtype):
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.normal(size=(8, 96)), dtype)
    w = jnp.asarray(rng.normal(size=(96, 48)), dtype)
    y = photonic_gemm(x, w, DPUConfig(dpe_size=48), "pallas")
    ye = (x.astype(jnp.float32) @ w.astype(jnp.float32)).astype(dtype)
    rel = float(
        jnp.linalg.norm((y - ye).astype(jnp.float32))
        / jnp.linalg.norm(ye.astype(jnp.float32))
    )
    assert rel < 0.03, rel


# ---------------------------------------------------------------------------
# Property tests — DPU datapath invariants
# ---------------------------------------------------------------------------
@given(
    r=st.integers(1, 16),
    k=st.integers(1, 96),
    c=st.integers(1, 24),
    bits=st.sampled_from([2, 4, 8]),
    n=st.integers(1, 64),
    seed=st.integers(0, 2**31 - 1),
)
@settings(max_examples=40, deadline=None)
def test_dpu_gemm_exact_property(r, k, c, bits, n, seed):
    """Ideal DPU (no noise, no ADC clip) == exact integer GEMM, for any
    chunking N, slicing B, and shape."""
    rng = np.random.default_rng(seed)
    xq = _rand_int8(rng, (r, k))
    wq = _rand_int8(rng, (k, c))
    cfg = DPUConfig(bits=bits, dpe_size=n)
    out = dpu_int_gemm(xq, wq, cfg)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(exact_int_gemm(xq, wq)))


@given(
    bits=st.sampled_from([1, 2, 3, 4, 6, 8]),
    seed=st.integers(0, 2**31 - 1),
)
@settings(max_examples=30, deadline=None)
def test_bit_slice_recompose(bits, seed):
    """sum_s slice_s * 2^(B s) reconstructs the operand exactly."""
    rng = np.random.default_rng(seed)
    q = _rand_int8(rng, (5, 7))
    num = -(-8 // bits)
    sl = bit_slices(q, bits, num)
    recomposed = sum(sl[s].astype(jnp.int32) << (bits * s) for s in range(num))
    np.testing.assert_array_equal(np.asarray(recomposed), np.asarray(q, dtype=np.int32))
    # and the ref decomposition agrees
    sl2 = slice_decompose(q, bits, num)
    for a, b in zip(sl, sl2):
        np.testing.assert_array_equal(np.asarray(a, dtype=np.int32), np.asarray(b))


@given(seed=st.integers(0, 2**31 - 1))
@settings(max_examples=20, deadline=None)
def test_quantization_error_bound(seed):
    """Symmetric quantization error is bounded by scale/2 elementwise."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(32, 32)), jnp.float32)
    q, scale = quantize_symmetric(x, 8)
    err = jnp.abs(q.astype(jnp.float32) * scale - x)
    assert float(err.max()) <= float(scale) * 0.5 + 1e-7


@given(
    b_lo=st.sampled_from([2, 4]),
    seed=st.integers(0, 2**31 - 1),
)
@settings(max_examples=15, deadline=None)
def test_more_noise_worse_error_monotonicity(b_lo, seed):
    """Noisier analog path -> larger expected GEMM error (paper Fig. 3
    narrative: precision costs power; here: noise costs accuracy)."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(16, 128)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(128, 16)), jnp.float32)
    key = jax.random.PRNGKey(seed)
    exact = x @ w

    def err(sigma):
        cfg = DPUConfig(bits=b_lo, dpe_size=32, noise_sigma_lsb=sigma)
        y = photonic_matmul(x, w, cfg, prng_key=key)
        return float(jnp.linalg.norm(y - exact))

    e0, e1, e2 = err(0.0), err(2.0), err(16.0)
    assert e0 <= e1 + 1e-5
    assert e1 < e2


def test_ste_gradients_match_dense_path():
    """STE backward == gradients of the exact float matmul."""
    rng = np.random.default_rng(5)
    x = jnp.asarray(rng.normal(size=(4, 8, 32)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(32, 16)), jnp.float32)
    cfg = DPUConfig(dpe_size=16)

    gx, gw = jax.grad(
        lambda x, w: (photonic_matmul_ste(x, w, cfg) ** 2).sum(), argnums=(0, 1)
    )(x, w)
    # Compare direction against the dense-path gradient of the same loss
    # evaluated at the quantized output (STE: identity through quantizer).
    y = photonic_matmul(x, w, cfg)
    gx_e = jnp.einsum("bsc,kc->bsk", 2 * y, w)
    gw_e = jnp.einsum("bsk,bsc->kc", x, 2 * y)
    assert float(jnp.linalg.norm(gx - gx_e) / jnp.linalg.norm(gx_e)) < 1e-5
    assert float(jnp.linalg.norm(gw - gw_e) / jnp.linalg.norm(gw_e)) < 1e-5


def test_dpu_config_from_scalability():
    """DPUConfig with no explicit N pulls the calibrated Table V value."""
    cfg = DPUConfig(organization="SMWA", bits=4, datarate_gs=5.0)
    assert cfg.n == 42  # Table V
    assert cfg.m == 42
    cfg = DPUConfig(organization="ASMW", bits=4, datarate_gs=10.0)
    assert cfg.n == 12
    assert DPUConfig(bits=4).num_slices == 2
    assert DPUConfig(bits=4).passes == 4
    assert DPUConfig(bits=4, dpe_size=83).num_chunks(4096) == 50
