"""repro.mapper — workload graphs, tiling, timelines, and the degenerate
schedule contract (DESIGN.md §16).

The heart of this file is the regression pin: the mapper with
``MapperOptions.degenerate()`` must reproduce the pre-PR-10
``core/simulator.simulate`` numbers **bit-for-bit** for every (org, DR,
model) cell of the fig7_system grid.  ``PINS`` holds the float-hex FPS /
dynamic-energy values captured from the legacy event loop, and
``_legacy_layer`` is a frozen copy of its per-layer arithmetic — so the
contract is checked both against committed constants and against an
independent re-derivation.

Also pinned here (satellite): ``calibrated_max_n`` and
``area_matched_counts`` across all 12 S/A/M/W orderings x both
platforms — Table V-adjacent anchors for the design-space sweeps.
"""

import dataclasses
import heapq

import pytest

from repro.core import scalability as sc
from repro.core.cnn_workloads import WORKLOADS, GemmLayer
from repro.core.perfmodel import AcceleratorConfig, area_matched_counts
from repro.core.simulator import evaluate_all, simulate
from repro.mapper import (
    DpuPool,
    GemmNode,
    MapperOptions,
    Timeline,
    WorkloadGraph,
    map_workload,
    tile_node,
)
from repro.models import registry
from repro.orgs import ORGANIZATIONS, valid_orderings

# ---------------------------------------------------------------------------
# The degenerate-schedule contract: float-hex pins of the legacy simulator
# over the full fig7_system grid (org x DR x model) -> (fps, dynamic_energy_j)
# ---------------------------------------------------------------------------
PINS = {
    ("ASMW", 1, "googlenet"): ("0x1.b5f976a53cef3p+7", "0x1.630624f70c616p-6"),
    ("ASMW", 1, "mobilenet_v2"): ("0x1.dcc02edc5329cp+9", "0x1.39cbe6a35c676p-8"),
    ("ASMW", 1, "resnet50"): ("0x1.049ff9ae7e8a0p+7", "0x1.b3a4715ae033fp-5"),
    ("ASMW", 1, "shufflenet_v2"): ("0x1.f48e34b0277edp+10", "0x1.39fc81457a849p-9"),
    ("ASMW", 5, "googlenet"): ("0x1.ae5e5b6b57bb7p+6", "0x1.c3288e5a03337p-5"),
    ("ASMW", 5, "mobilenet_v2"): ("0x1.911ba0a935851p+8", "0x1.67206b543fd2ap-7"),
    ("ASMW", 5, "resnet50"): ("0x1.f96c2488b1480p+5", "0x1.15b1fe1f35d93p-3"),
    ("ASMW", 5, "shufflenet_v2"): ("0x1.a5b1ab8a5db38p+9", "0x1.6c597fdf6415cp-8"),
    ("ASMW", 10, "googlenet"): ("0x1.3238ae9c62a4ap+6", "0x1.c3bec8f70c7d9p-4"),
    ("ASMW", 10, "mobilenet_v2"): ("0x1.0cfa4bf40f1f4p+8", "0x1.696e3772b7b8bp-6"),
    ("ASMW", 10, "resnet50"): ("0x1.65e33d16eaa77p+5", "0x1.18f91ee9ce110p-2"),
    ("ASMW", 10, "shufflenet_v2"): ("0x1.34df4be7a1b77p+9", "0x1.60c5f451c8ba5p-7"),
    ("MASW", 1, "googlenet"): ("0x1.06c93e210d5bep+8", "0x1.2eadae34f32adp-6"),
    ("MASW", 1, "mobilenet_v2"): ("0x1.fc98e654f7823p+9", "0x1.30121926a25c2p-8"),
    ("MASW", 1, "resnet50"): ("0x1.370a64c1e1836p+7", "0x1.63f0258ff251ap-5"),
    ("MASW", 1, "shufflenet_v2"): ("0x1.1ca11dbb44bdcp+11", "0x1.0d06e8dd22efap-9"),
    ("MASW", 5, "googlenet"): ("0x1.08d517bb63c23p+7", "0x1.7acd1c690a39ep-5"),
    ("MASW", 5, "mobilenet_v2"): ("0x1.c6fd16c9f585bp+8", "0x1.47104a0977cc6p-7"),
    ("MASW", 5, "resnet50"): ("0x1.33fa176ae9b75p+6", "0x1.d434178abb2afp-4"),
    ("MASW", 5, "shufflenet_v2"): ("0x1.de41fd18399ebp+9", "0x1.3e34f4424c460p-8"),
    ("MASW", 10, "googlenet"): ("0x1.7d8878272b9a0p+6", "0x1.7247f1e00ea3fp-4"),
    ("MASW", 10, "mobilenet_v2"): ("0x1.3a54fb8faa494p+8", "0x1.4514e6aa967dap-6"),
    ("MASW", 10, "resnet50"): ("0x1.bbd9bf83ce50ep+5", "0x1.cbc2005453488p-3"),
    ("MASW", 10, "shufflenet_v2"): ("0x1.8f28359d37dc9p+9", "0x1.1c4b2ddaa5cc6p-7"),
    ("SMWA", 1, "googlenet"): ("0x1.f190003a907e5p+8", "0x1.55be24038e01dp-7"),
    ("SMWA", 1, "mobilenet_v2"): ("0x1.7818d5488193bp+10", "0x1.dd03ef176e3b8p-9"),
    ("SMWA", 1, "resnet50"): ("0x1.2e701b257d18bp+8", "0x1.98c6f9ace202ep-6"),
    ("SMWA", 1, "shufflenet_v2"): ("0x1.ffa554e257f66p+11", "0x1.6305af25d6ebap-10"),
    ("SMWA", 5, "googlenet"): ("0x1.ff1bef0cd69cdp+7", "0x1.8c5b5f6eae9eap-6"),
    ("SMWA", 5, "mobilenet_v2"): ("0x1.439c39e130a97p+10", "0x1.4fcf01f9ae9e7p-8"),
    ("SMWA", 5, "resnet50"): ("0x1.2ac431265298ep+7", "0x1.f079f002837e6p-5"),
    ("SMWA", 5, "shufflenet_v2"): ("0x1.5366dd5bc242ap+11", "0x1.427896e64402dp-9"),
    ("SMWA", 10, "googlenet"): ("0x1.773b4b4a26bebp+7", "0x1.8cb5b001286d0p-5"),
    ("SMWA", 10, "mobilenet_v2"): ("0x1.717488989c8a7p+9", "0x1.437103e62095ep-7"),
    ("SMWA", 10, "resnet50"): ("0x1.b12393ea3c769p+6", "0x1.e8f3b5c713a95p-4"),
    ("SMWA", 10, "shufflenet_v2"): ("0x1.23038b814bb73p+11", "0x1.0d65cc1b43fbcp-8"),
}


def _legacy_layer(layer: GemmLayer, cfg: AcceleratorConfig):
    """Frozen copy of the pre-PR-10 ``_simulate_layer`` arithmetic — the
    independent reference the mapper's degenerate path must match bitwise."""
    p = cfg.peripherals
    sym = cfg.symbol_s
    tune = cfg.tune_latency_s
    if layer.groups == 1:
        chunks = -(-layer.k // cfg.n)
        col_tiles = -(-layer.cols // cfg.m)
        rows = layer.rows
        psums_per_output = chunks * cfg.passes
        outputs = layer.rows * layer.cols
    else:
        chunks = 1
        col_tiles = -(-layer.groups // cfg.m)
        rows = layer.rows
        psums_per_output = cfg.passes
        outputs = layer.rows * layer.groups
    n_tiles = chunks * col_tiles * cfg.passes
    sym_eff = max(sym, p.reduction_network.latency_s) if chunks > 1 else sym
    serial_dur = chunks * cfg.passes * (tune + rows * sym_eff)
    heap = [(0.0, d) for d in range(cfg.dpu_count)]
    heapq.heapify(heap)
    end = 0.0
    busy_s = 0.0
    for _ in range(col_tiles):
        free, d = heapq.heappop(heap)
        fin = free + serial_dur
        busy_s += serial_dur
        end = max(end, fin)
        heapq.heappush(heap, (fin, d))
    stream_s = end
    total_psums = outputs * psums_per_output
    reductions = outputs * (psums_per_output - 1) if psums_per_output > 1 else 0
    red_s = (sym_eff - sym) * rows * chunks * cfg.passes if chunks > 1 else 0.0
    time_s = stream_s + p.reduction_network.latency_s
    stream_energy = busy_s * cfg.streaming_power_w()
    tune_energy = n_tiles * (
        cfg.tune_power_w_per_ring * tune * (
            cfg.n * cfg.m if layer.groups == 1 else cfg.m
        )
    )
    red_energy = (
        reductions * p.reduction_network.power_w * p.reduction_network.latency_s
    )
    mem_energy = total_psums * (
        p.edram.power_w * p.edram.latency_s + p.bus.power_w * p.bus.latency_s / cfg.m
    )
    act_energy = outputs * p.activation_unit.power_w * p.activation_unit.latency_s
    energy = stream_energy + tune_energy + red_energy + mem_energy + act_energy
    return {
        "time_s": time_s,
        "stream_s": stream_s,
        "reduce_s": red_s,
        "tune_s": n_tiles * tune / cfg.dpu_count,
        "energy_j": energy,
        "psums": total_psums,
        "tiles": n_tiles,
    }


class TestDegenerateContract:
    def test_fig7_grid_bit_for_bit_pinned(self):
        results = evaluate_all()
        assert set(results) == set(PINS)
        for key, (fps_hex, energy_hex) in PINS.items():
            res = results[key]
            assert res.fps.hex() == fps_hex, key
            assert res.dynamic_energy_j.hex() == energy_hex, key

    def test_simulate_equals_mapper_degenerate(self):
        for model in WORKLOADS:
            graph = WorkloadGraph.from_layers(WORKLOADS[model](), name=model)
            for org in ORGANIZATIONS:
                cfg = AcceleratorConfig.from_paper(org, 5)
                ref = simulate(model, cfg)
                tl = map_workload(
                    graph, DpuPool.from_config(cfg), MapperOptions.degenerate()
                )
                assert tl.fps == ref.fps
                assert tl.fps_per_w == ref.fps_per_w
                assert tl.avg_power_w == ref.avg_power_w
                assert tl.dynamic_energy_j == ref.dynamic_energy_j
                assert tl.makespan_s == ref.total_time_s

    @pytest.mark.parametrize("org", ORGANIZATIONS)
    @pytest.mark.parametrize("model", ["resnet50", "mobilenet_v2"])
    def test_per_layer_stats_match_frozen_legacy(self, org, model):
        # Independent re-derivation: every per-layer stat of the mapper's
        # degenerate schedule equals the frozen legacy loop, exactly
        # (covers depthwise via mobilenet_v2).
        cfg = AcceleratorConfig.from_paper(org, 10)
        res = simulate(model, cfg)
        layers = WORKLOADS[model]()
        assert [ls.name for ls in res.layers] == [l.name for l in layers]
        for ls, layer in zip(res.layers, layers):
            ref = _legacy_layer(layer, cfg)
            assert ls.time_s == ref["time_s"], layer.name
            assert ls.stream_s == ref["stream_s"], layer.name
            assert ls.reduce_s == ref["reduce_s"], layer.name
            assert ls.tune_s == ref["tune_s"], layer.name
            assert ls.energy_j == ref["energy_j"], layer.name
            assert ls.psums == ref["psums"], layer.name
            assert ls.tiles_dispatched == ref["tiles"], layer.name

    def test_degenerate_holds_off_paper_operating_points(self):
        # The contract is schedule-level, not Table V-level: it holds on
        # calibrated/SiN configs and resized pools too.
        graph = WorkloadGraph.from_layers(WORKLOADS["googlenet"](), "googlenet")
        for cfg in (
            AcceleratorConfig.from_scalability("MWAS", 5, platform="SIN"),
            dataclasses.replace(AcceleratorConfig.from_paper("SMWA", 1), dpu_count=7),
        ):
            ref = simulate("googlenet", cfg)
            tl = map_workload(
                graph, DpuPool.from_config(cfg), MapperOptions.degenerate()
            )
            assert tl.fps == ref.fps
            assert tl.dynamic_energy_j == ref.dynamic_energy_j


# ---------------------------------------------------------------------------
# Workload graphs
# ---------------------------------------------------------------------------
class TestWorkloadGraph:
    def test_from_layers_is_a_chain(self):
        layers = WORKLOADS["resnet50"]()
        g = WorkloadGraph.from_layers(layers, name="resnet50")
        assert len(g) == len(layers)
        order = g.topological()
        assert [n.name for n in order] == [l.name for l in layers]
        assert order[0].deps == ()
        for prev, node in zip(order, order[1:]):
            assert node.deps == (prev.name,)
        assert g.total_macs == sum(l.macs for l in layers)

    def test_duplicate_name_rejected(self):
        n = GemmNode(name="a", rows=1, k=1, cols=1)
        with pytest.raises(ValueError, match="duplicate"):
            WorkloadGraph("g", [n, n])

    def test_unknown_dep_rejected(self):
        with pytest.raises(ValueError, match="unknown"):
            WorkloadGraph("g", [GemmNode(name="a", rows=1, k=1, cols=1, deps=("b",))])

    def test_cycle_rejected(self):
        nodes = [
            GemmNode(name="a", rows=1, k=1, cols=1, deps=("b",)),
            GemmNode(name="b", rows=1, k=1, cols=1, deps=("a",)),
        ]
        with pytest.raises(ValueError, match="cycle"):
            WorkloadGraph("g", nodes)

    def test_non_positive_dims_rejected(self):
        with pytest.raises(ValueError, match="non-positive"):
            GemmNode(name="a", rows=0, k=1, cols=1)

    def test_dense_lm_lowering_structure(self):
        cfg = registry.get("qwen2-0.5b").config
        g = WorkloadGraph.from_model_config(cfg, seq_len=128)
        # 24 layers x (wq, wk, wv, wo, ffn.wi, ffn.wo) + lm_head
        assert len(g) == cfg.num_layers * 6 + 1
        wq, wk, wv = g["L0.attn.wq"], g["L0.attn.wk"], g["L0.attn.wv"]
        assert wq.deps == wk.deps == wv.deps == ()  # parallel fan-out
        assert set(g["L0.attn.wo"].deps) == {
            "L0.attn.wq", "L0.attn.wk", "L0.attn.wv",
        }
        # GQA: kv projections are num_kv_heads-sized
        head_dim = cfg.head_dim or cfg.d_model // cfg.num_heads
        assert wq.cols == cfg.num_heads * head_dim
        assert wk.cols == cfg.num_kv_heads * head_dim
        # fused SwiGLU bank: wi spans both halves
        assert g["L0.ffn.wi"].cols == 2 * cfg.d_ff
        # layer chaining + head
        assert g["L1.attn.wq"].deps == ("L0.ffn.wo",)
        assert g["lm_head"].deps == (f"L{cfg.num_layers - 1}.ffn.wo",)
        assert g["lm_head"].cols == cfg.vocab_size
        assert g["L0.attn.wq"].site == "attn.wq"

    def test_mla_moe_lowering(self):
        cfg = registry.get("deepseek-v2-lite-16b").config
        assert cfg.mla and cfg.num_experts > 0 and cfg.num_shared_experts > 0
        g = WorkloadGraph.from_model_config(cfg, seq_len=64)
        # MLA: wq + wdkv fan out; wuk/wuv hang off the latent projection.
        assert g["L0.attn.wuk"].deps == ("L0.attn.wdkv",)
        assert g["L0.attn.wuv"].deps == ("L0.attn.wdkv",)
        assert g["L0.attn.wdkv"].cols == cfg.kv_lora_rank + cfg.qk_rope_head_dim
        # MoE: active experts stream t * top_k rows; shared expert rides
        # in parallel and both feed the next layer.
        assert g["L1.ffn.wi"].rows == 64 * cfg.num_experts_per_tok
        assert g["L1.ffn.shared.wi"].rows == 64
        assert set(g["L2.attn.wq"].deps) == {
            "L1.ffn.wo", "L1.ffn.shared.wo",
        }

    @pytest.mark.parametrize(
        "name", ["whisper-medium", "xlstm-350m", "zamba2-2.7b",
                 "llama-3.2-vision-90b"]
    )
    def test_unschedulable_families_rejected(self, name):
        cfg = registry.get(name).config
        with pytest.raises(NotImplementedError):
            WorkloadGraph.from_model_config(cfg, seq_len=16)


# ---------------------------------------------------------------------------
# Tiling and pool construction
# ---------------------------------------------------------------------------
class TestTilingAndPools:
    def test_pool_normalizes_dpu_count(self):
        cfg = AcceleratorConfig.from_paper("SMWA", 5)
        pool = DpuPool.from_config(cfg, size=300)
        assert pool.size == 300 == pool.cfg.dpu_count

    def test_area_matched_pool_matches_benchmark_counts(self):
        for platform, expected in AREA_MATCHED_ALL12_DR5.items():
            for order, count in expected.items():
                pool = DpuPool.area_matched(order, 5, platform=platform)
                assert pool.size == count, (platform, order)
                assert pool.cfg.platform == platform

    def test_degenerate_tiling_matches_legacy_decomposition(self):
        cfg = AcceleratorConfig.from_paper("ASMW", 5)
        opts = MapperOptions.degenerate()
        for layer in WORKLOADS["mobilenet_v2"]():
            node = GemmNode(
                name=layer.name, rows=layer.rows, k=layer.k,
                cols=layer.cols, groups=layer.groups,
            )
            tl = tile_node(node, cfg, cfg.dpu_count, opts)
            ref = _legacy_layer(layer, cfg)
            assert tl.tiles == ref["tiles"], layer.name
            assert tl.replicas == 1 and tl.row_blocks == (layer.rows,)

    def test_batch_multiplies_streamed_rows(self):
        cfg = AcceleratorConfig.from_paper("SMWA", 5)
        node = GemmNode(name="g", rows=100, k=500, cols=200)
        t1 = tile_node(node, cfg, 64, MapperOptions(batch=1, replicate=False))
        t8 = tile_node(node, cfg, 64, MapperOptions(batch=8, replicate=False))
        assert sum(t8.row_blocks) == 8 * sum(t1.row_blocks)
        assert t8.tiles == t1.tiles  # weights programmed once, not per input

    def test_replication_caps(self):
        cfg = AcceleratorConfig.from_paper("SMWA", 5)
        node = GemmNode(name="g", rows=10000, k=40, cols=40)  # one col tile
        tl = tile_node(node, cfg, 16, MapperOptions())
        assert tl.replicas == 16  # pool-bound
        assert sum(tl.row_blocks) == 10000
        # amortization-bound: tiny streams admit no replicas
        small = GemmNode(name="s", rows=2, k=40, cols=40)
        assert tile_node(small, cfg, 16, MapperOptions()).replicas <= 2
        # replication off -> one chain per column tile
        assert tile_node(node, cfg, 16, MapperOptions(replicate=False)).replicas == 1

    def test_overlap_reduce_hides_fifo_pacing(self):
        cfg = AcceleratorConfig.from_paper("ASMW", 10)  # small N -> chunked
        node = GemmNode(name="g", rows=50, k=10 * cfg.n, cols=cfg.m)
        paced = tile_node(node, cfg, 1, MapperOptions(overlap_reduce=False))
        hidden = tile_node(node, cfg, 1, MapperOptions(overlap_reduce=True))
        assert paced.sym_eff > cfg.symbol_s
        assert hidden.sym_eff == cfg.symbol_s


# ---------------------------------------------------------------------------
# Timelines
# ---------------------------------------------------------------------------
class TestTimeline:
    GRAPH = None

    @classmethod
    def graph(cls):
        if cls.GRAPH is None:
            cls.GRAPH = WorkloadGraph.from_layers(
                WORKLOADS["resnet50"](), "resnet50"
            )
        return cls.GRAPH

    def test_batching_raises_throughput_and_utilization(self):
        pool = DpuPool.area_matched("MWAS", 5)
        t1 = map_workload(self.graph(), pool, MapperOptions(batch=1))
        t64 = map_workload(self.graph(), pool, MapperOptions(batch=64))
        assert t64.fps > 4 * t1.fps
        assert t64.fps_per_w > t1.fps_per_w
        assert t64.mean_utilization > t1.mean_utilization
        assert isinstance(t64, Timeline) and t64.batch == 64

    def test_cross_layer_never_slower_than_barrier(self):
        pool = DpuPool.area_matched("SMWA", 5)
        for batch in (1, 16):
            dag = map_workload(self.graph(), pool, MapperOptions(batch=batch))
            barrier = map_workload(
                self.graph(), pool, MapperOptions(batch=batch, cross_layer=False)
            )
            # <= up to float association noise: a chain graph makes the two
            # schedules mathematically equal, but the DAG path accumulates
            # one global clock instead of summing per-node local ends.
            assert dag.makespan_s <= barrier.makespan_s * (1 + 1e-9)
            assert dag.dynamic_energy_j == barrier.dynamic_energy_j

    def test_utilization_bounded_and_sized(self):
        pool = DpuPool.area_matched("MASW", 5)
        tl = map_workload(self.graph(), pool, MapperOptions(batch=16))
        util = tl.utilization
        assert len(util) == pool.size
        assert all(0.0 <= u <= 1.0 + 1e-12 for u in util)
        assert 0.0 < tl.mean_utilization <= 1.0

    def test_to_dict_round_trips_the_artifact(self):
        import json

        pool = DpuPool.area_matched("SMWA", 5)
        tl = map_workload(self.graph(), pool, MapperOptions(batch=4))
        d = json.loads(json.dumps(tl.to_dict()))
        assert d["organization"] == "SMWA"
        assert d["pool_size"] == pool.size
        assert d["options"]["batch"] == 4
        assert len(d["nodes"]) == len(self.graph())
        assert d["fps"] == tl.fps
        assert len(d["utilization"]) == pool.size

    def test_utilization_table_renders(self):
        pool = DpuPool.area_matched("SMWA", 5)
        tl = map_workload(self.graph(), pool, MapperOptions(batch=4))
        table = tl.utilization_table()
        assert "SMWA" in table and "batch=4" in table and "dpu" in table

    def test_lm_graph_maps_end_to_end(self):
        cfg = registry.get("qwen2-0.5b").config
        g = WorkloadGraph.from_model_config(cfg, seq_len=64)
        tl = map_workload(g, DpuPool.area_matched("SMWA", 5), MapperOptions(batch=4))
        assert tl.makespan_s > 0 and tl.fps_per_w > 0
        sites = {ns.site for ns in tl.nodes}
        assert {"attn.wq", "ffn.wi", "lm_head"} <= sites


# ---------------------------------------------------------------------------
# Satellite pins: calibrated_max_n / area_matched_counts across the space
# ---------------------------------------------------------------------------
CALIBRATED_N = {
    ("SOI", 1): {
        "ASMW": 33, "MASW": 43, "SMWA": 82, "AMSW": 33, "AMWS": 33,
        "MAWS": 43, "MSAW": 43, "MSWA": 82, "MWAS": 82, "MWSA": 82,
        "SAMW": 33, "SMAW": 43,
    },
    ("SOI", 5): {
        "ASMW": 17, "MASW": 21, "SMWA": 42, "AMSW": 17, "AMWS": 17,
        "MAWS": 21, "MSAW": 21, "MSWA": 42, "MWAS": 42, "MWSA": 42,
        "SAMW": 17, "SMAW": 21,
    },
    ("SOI", 10): {
        "ASMW": 12, "MASW": 15, "SMWA": 30, "AMSW": 12, "AMWS": 12,
        "MAWS": 15, "MSAW": 15, "MSWA": 30, "MWAS": 30, "MWSA": 30,
        "SAMW": 12, "SMAW": 15,
    },
    ("SIN", 1): {
        "ASMW": 78, "MASW": 104, "SMWA": 200, "AMSW": 78, "AMWS": 78,
        "MAWS": 104, "MSAW": 104, "MSWA": 200, "MWAS": 200, "MWSA": 200,
        "SAMW": 78, "SMAW": 104,
    },
    ("SIN", 5): {
        "ASMW": 38, "MASW": 50, "SMWA": 103, "AMSW": 38, "AMWS": 38,
        "MAWS": 50, "MSAW": 50, "MSWA": 103, "MWAS": 103, "MWSA": 103,
        "SAMW": 38, "SMAW": 50,
    },
    ("SIN", 10): {
        "ASMW": 27, "MASW": 35, "SMWA": 73, "AMSW": 27, "AMWS": 27,
        "MAWS": 35, "MSAW": 35, "MSWA": 73, "MWAS": 73, "MWSA": 73,
        "SAMW": 27, "SMAW": 35,
    },
}

AREA_MATCHED_PAPER = {
    1: {"SMWA": 50, "ASMW": 347, "MASW": 433},
    5: {"SMWA": 147, "ASMW": 682, "MASW": 637},
    10: {"SMWA": 198, "ASMW": 594, "MASW": 492},
}

AREA_MATCHED_ALL12_DR5 = {
    "SOI": {
        "ASMW": 682, "MASW": 637, "SMWA": 147, "AMSW": 812, "AMWS": 1003,
        "MAWS": 828, "MSAW": 637, "MSWA": 188, "MWAS": 432, "MWSA": 260,
        "SAMW": 682, "SMAW": 517,
    },
    "SIN": {
        "ASMW": 220, "MASW": 206, "SMWA": 30, "AMSW": 301, "AMWS": 475,
        "MAWS": 365, "MSAW": 206, "MSWA": 42, "MWAS": 181, "MWSA": 68,
        "SAMW": 220, "SMAW": 143,
    },
}

ALL_ORDERS = tuple(sorted(CALIBRATED_N[("SOI", 5)]))


class TestOperatingPointPins:
    @pytest.mark.parametrize("platform", ["SOI", "SIN"])
    @pytest.mark.parametrize("dr", [1, 5, 10])
    def test_calibrated_max_n_all_orderings(self, platform, dr):
        expected = CALIBRATED_N[(platform, dr)]
        got = {
            spec.name: sc.calibrated_max_n(spec, 4, dr, platform=platform)
            for spec in valid_orderings()
        }
        assert got == expected
        # Structural grouping: achievable N depends only on the crosstalk
        # profile, so the filter-only family jointly maximizes N.
        assert got["SMWA"] == got["MSWA"] == got["MWAS"] == got["MWSA"]
        assert max(got.values()) == got["SMWA"]

    def test_area_matched_counts_paper_defaults_unchanged(self):
        for dr, expected in AREA_MATCHED_PAPER.items():
            assert area_matched_counts(dr) == expected

    @pytest.mark.parametrize("platform", ["SOI", "SIN"])
    def test_area_matched_counts_generalized_all_orderings(self, platform):
        got = area_matched_counts(
            5, organizations=ALL_ORDERS, platform=platform
        )
        assert got == AREA_MATCHED_ALL12_DR5[platform]

    def test_reprogram_cost_surface(self):
        cfg = AcceleratorConfig.from_paper("SMWA", 5)
        dense = cfg.weight_reprogram_cost()
        depthwise = cfg.weight_reprogram_cost(groups=32)
        assert dense.latency_s == cfg.tune_latency_s == depthwise.latency_s
        assert dense.rings == cfg.n * cfg.m
        assert depthwise.rings == cfg.m
        assert dense.energy_j == (
            cfg.tune_power_w_per_ring * cfg.tune_latency_s * (cfg.n * cfg.m)
        )
