"""Hypothesis import shim for property-based tests.

``hypothesis`` is a dev-only dependency (see pyproject.toml ``[dev]`` extra).
When it is installed, this module re-exports the real ``given`` / ``settings``
/ ``strategies``.  When it is absent (minimal CI images, the bare runtime
install), a small deterministic fallback runs each property test against a
fixed, seeded sample of the strategy space instead of erroring at collection
time — weaker shrinking/coverage than real hypothesis, but the invariants
still get exercised.

Only the strategy surface this repo uses is implemented: ``st.integers``,
``st.floats``, ``st.sampled_from``.
"""

from __future__ import annotations

try:
    from hypothesis import given, settings, strategies  # noqa: F401

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    import random
    import zlib

    _DEFAULT_MAX_EXAMPLES = 20

    class _Strategy:
        def __init__(self, sample):
            self._sample = sample

        def sample(self, rng: random.Random):
            return self._sample(rng)

    class _Strategies:
        @staticmethod
        def integers(min_value=None, max_value=None):
            lo = -(2**63) if min_value is None else int(min_value)
            hi = 2**63 - 1 if max_value is None else int(max_value)
            return _Strategy(lambda rng: rng.randint(lo, hi))

        @staticmethod
        def floats(min_value=None, max_value=None, **_kwargs):
            # Unbounded floats default to [0, 1] — far narrower than real
            # hypothesis. Every in-repo usage passes explicit bounds.
            lo = 0.0 if min_value is None else float(min_value)
            hi = 1.0 if max_value is None else float(max_value)
            return _Strategy(lambda rng: rng.uniform(lo, hi))

        @staticmethod
        def sampled_from(elements):
            elements = list(elements)
            return _Strategy(lambda rng: rng.choice(elements))

    strategies = _Strategies()

    def settings(max_examples=_DEFAULT_MAX_EXAMPLES, **_ignored):
        def deco(fn):
            fn._compat_max_examples = max_examples
            return fn

        return deco

    def given(**strategy_kwargs):
        def deco(fn):
            # Seeded by the test name so runs are reproducible across
            # processes (hash() is salted; crc32 is not).
            base_seed = zlib.crc32(fn.__qualname__.encode())

            def runner(*args):
                # Read at call time so @settings works above OR below @given
                # (both orders are legal with real hypothesis).
                max_examples = getattr(
                    runner,
                    "_compat_max_examples",
                    getattr(fn, "_compat_max_examples", _DEFAULT_MAX_EXAMPLES),
                )
                for i in range(max_examples):
                    rng = random.Random(base_seed * 1_000_003 + i)
                    drawn = {
                        name: strat.sample(rng)
                        for name, strat in strategy_kwargs.items()
                    }
                    try:
                        fn(*args, **drawn)
                    except Exception as e:  # surface the failing example
                        raise AssertionError(
                            f"{fn.__qualname__} failed on example {i}: {drawn!r}"
                        ) from e

            # A plain zero/varargs signature, so pytest does not mistake the
            # strategy kwargs for fixtures. Deliberately no __wrapped__.
            runner.__name__ = fn.__name__
            runner.__qualname__ = fn.__qualname__
            runner.__doc__ = fn.__doc__
            return runner

        return deco
