"""PR-8 fused hot path (DESIGN.md §14): in-kernel quant + GEMM epilogue,
fused-QKV attention, the dispatch-count summary, and the launch profile.

The load-bearing contracts:

* a fused-epilogue GEMM is bitwise-equal to the unfused composition
  (explicit quantize → ``int_gemm`` → digital rescale) under an ideal
  channel, on both backends, eager and jitted — including the tiling
  edge cases (non-divisible K/C, ``tile_c > 128``, R=1 decode rows);
* one fused-QKV GEMM (``fuse_qkv_params``) is bitwise-equal to the three
  separate projections, for every weight layout the packer accepts;
* ``hlo_analysis.dispatch_summary`` proves the fusion *structurally*:
  the fused module's entry op sequence is strictly shorter.
"""

import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.dpu import DPUConfig, quantize_symmetric
from repro.launch import hlo_analysis, profile
from repro.models import attention as attn
from repro.models.common import ModelConfig, dense, init_tree
from repro.photonic import (
    ACTIVATIONS,
    EpilogueArgs,
    EpilogueSpec,
    engine_for,
    fuse_qkv_params,
    pack_dense,
)

DPU = DPUConfig(organization="SMWA", bits=4, datarate_gs=5.0)
RNG = np.random.default_rng(0)


def _arr(*shape, scale=1.0):
    return jnp.asarray(RNG.normal(size=shape) * scale, jnp.float32)


def _manual_unfused(eng, x, pk, bias=None, activation=None):
    """The pre-fusion composition, op for op — the bitwise oracle."""
    xq, sx = quantize_symmetric(x, eng.dpu.operand_bits)
    acc = eng.int_gemm(xq, pk.wq, logical_kc=(pk.k, pk.c), tiling=pk.tiling)
    y = acc.astype(jnp.float32) * sx * pk.w_scale.astype(jnp.float32)[None, :]
    if bias is not None:
        y = y + bias.astype(jnp.float32)
    if activation is not None:
        y = ACTIVATIONS[activation](y)
    return y


# ---------------------------------------------------------------------------
# Fused epilogue == unfused composition (tiling edge cases, both backends)
# ---------------------------------------------------------------------------
class TestFusedEpilogueBitwise:
    # r=1 is the decode row; 100/130/257 are deliberately non-divisible
    # by every tile size in play; c=384 forces multiple column tiles.
    @pytest.mark.parametrize("r,k,c", [(1, 64, 64), (3, 100, 257), (8, 130, 384)])
    @pytest.mark.parametrize("backend", ["ref", "pallas"])
    @pytest.mark.parametrize("jitted", [False, True])
    def test_fused_matmul_matches_manual(self, r, k, c, backend, jitted):
        eng = engine_for(DPU, backend)
        pk = pack_dense({"w": _arr(k, c, scale=k**-0.5)}, eng)["w"]
        x = _arr(r, k)
        fused = lambda x: eng.matmul(x, pk, site="s")  # noqa: E731
        manual = lambda x: _manual_unfused(eng, x, pk)  # noqa: E731
        if jitted:
            fused, manual = jax.jit(fused), jax.jit(manual)
        np.testing.assert_array_equal(np.asarray(fused(x)), np.asarray(manual(x)))

    @pytest.mark.parametrize("backend", ["ref", "pallas"])
    @pytest.mark.parametrize("activation", [None, "gelu", "silu"])
    def test_bias_activation_ride_epilogue(self, backend, activation):
        eng = engine_for(DPU, backend)
        pk = pack_dense({"w": _arr(100, 130, scale=0.1)}, eng)["w"]
        b, x = _arr(130, scale=0.02), _arr(3, 100)
        fused = jax.jit(
            lambda x: eng.matmul(x, pk, site="s", bias=b, activation=activation)
        )
        manual = jax.jit(lambda x: _manual_unfused(eng, x, pk, b, activation))
        np.testing.assert_allclose(
            np.asarray(fused(x)), np.asarray(manual(x)), rtol=1e-6, atol=1e-6
        )

    @pytest.mark.parametrize("r,k,c", [(1, 64, 64), (5, 100, 257)])
    def test_pallas_matches_ref_bitwise(self, r, k, c):
        x, w, b = _arr(r, k), _arr(k, c, scale=0.1), _arr(c, scale=0.02)
        outs = {}
        for backend in ("ref", "pallas"):
            eng = engine_for(DPU, backend)
            # same float weight packed per backend: layouts differ
            # (pallas pads to its tiling), values must not
            pk = pack_dense({"w": w}, eng)["w"]
            outs[backend] = np.asarray(eng.matmul(x, pk, site="s", bias=b))
        np.testing.assert_array_equal(outs["ref"], outs["pallas"])

    @pytest.mark.parametrize("with_epilogue", [False, True])
    def test_tile_c_above_128(self, with_epilogue):
        """int_gemm honours a caller tile_c above 128 (legal, layout-only)."""
        k, c = 96, 200
        w = _arr(k, c, scale=0.1)
        wq = jnp.round(jnp.clip(w * 10, -7, 7)).astype(jnp.int8)
        x = _arr(4, k)
        xq, sx = quantize_symmetric(x, DPU.operand_bits)
        args = None
        if with_epilogue:
            args = EpilogueArgs(
                spec=EpilogueSpec(), x_scale=sx, w_scale=jnp.full((c,), 0.1)
            )
        ref = engine_for(DPU, "ref").int_gemm(
            xq, wq, epilogue=args
        )
        for tile_c in (128, 256):
            out = engine_for(DPU, "pallas").int_gemm(
                xq, wq, tile_c=tile_c, epilogue=args
            )
            np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))

    def test_float_activations_need_epilogue(self):
        eng = engine_for(DPU, "ref")
        wq = jnp.ones((8, 8), jnp.int8)
        with pytest.raises(TypeError, match="EpilogueArgs"):
            eng.int_gemm(_arr(2, 8), wq)


# ---------------------------------------------------------------------------
# fuse_qkv_params — one QKV bank == three separate projections
# ---------------------------------------------------------------------------
def _qkv_params(d, eng=None, bias=False, scaled=False):
    params = {}
    for name in ("wq", "wk", "wv"):
        w = _arr(d, d, scale=d**-0.5)
        if eng is not None:
            p = pack_dense({"w": w}, eng)
        elif scaled:
            ws = jnp.max(jnp.abs(w), axis=0) * (1.0 / 127.0)
            p = {"w": jnp.round(w / ws).astype(jnp.int8), "w_scale": ws}
        else:
            p = {"w": w}
        if bias:
            p["b"] = _arr(d, scale=0.02)
        params[name] = p
    params["wo"] = {"w": _arr(d, d, scale=d**-0.5)}
    return params


class TestFuseQKV:
    @pytest.mark.parametrize("backend", ["ref", "pallas"])
    @pytest.mark.parametrize("bias", [False, True])
    def test_packed_layout_bitwise(self, backend, bias):
        d, eng = 48, engine_for(DPU, backend)
        params = _qkv_params(d, eng=eng, bias=bias)
        fused = fuse_qkv_params(params, eng)
        assert "wqkv" in fused and "wq" not in fused and "wo" in fused
        x = _arr(3, d)
        kw = {"bias": fused["wqkv"].get("b")} if bias else {}
        y = eng.matmul(x, fused["wqkv"]["w"], site="attn.wqkv", **kw)
        parts = []
        for name in ("wq", "wk", "wv"):
            kw1 = {"bias": params[name].get("b")} if bias else {}
            parts.append(
                eng.matmul(x, params[name]["w"], site=f"attn.{name}", **kw1)
            )
        np.testing.assert_array_equal(
            np.asarray(y), np.asarray(jnp.concatenate(parts, axis=-1))
        )

    def test_int8_stored_layout(self):
        d = 32
        params = _qkv_params(d, scaled=True)
        eng = engine_for(DPU, "ref")
        fused = fuse_qkv_params(params, eng)
        assert fused["wqkv"]["w"].dtype == jnp.int8
        assert fused["wqkv"]["w_scale"].shape == (3 * d,)

    def test_float_layout(self):
        d = 32
        params = _qkv_params(d)
        fused = fuse_qkv_params(params, engine_for(DPU, "ref"))
        assert fused["wqkv"]["w"].shape == (d, 3 * d)

    def test_mixed_layouts_rejected(self):
        eng = engine_for(DPU, "ref")
        params = _qkv_params(32, eng=eng)
        params["wk"] = {"w": _arr(32, 32)}  # float amid packed
        with pytest.raises(ValueError, match="mix"):
            fuse_qkv_params(params, eng)

    def test_partial_bias_rejected(self):
        eng = engine_for(DPU, "ref")
        params = _qkv_params(32, eng=eng, bias=True)
        del params["wk"]["b"]
        with pytest.raises(ValueError, match="bias"):
            fuse_qkv_params(params, eng)

    def test_missing_projection_rejected(self):
        eng = engine_for(DPU, "ref")
        params = _qkv_params(32, eng=eng)
        del params["wv"]
        with pytest.raises(KeyError, match="wv"):
            fuse_qkv_params(params, eng)

    def test_model_qkv_proj_uses_fused_bank(self):
        """gqa_attention with a fused bank == with separate projections."""
        cfg = ModelConfig(
            d_model=32, num_heads=4, num_kv_heads=4, num_layers=1,
            photonic=DPU, photonic_backend="ref",
        )
        params = init_tree(attn.gqa_def(cfg), jax.random.PRNGKey(0), jnp.float32)
        eng = engine_for(DPU, "ref")
        fused = fuse_qkv_params(params, eng)
        x = _arr(1, 4, 32)
        pos = jnp.arange(4)
        y_sep = attn.gqa_attention(params, x, cfg, positions=pos)
        y_fused = attn.gqa_attention(fused, x, cfg, positions=pos)
        np.testing.assert_array_equal(np.asarray(y_sep), np.asarray(y_fused))


# ---------------------------------------------------------------------------
# attn_impl routing (flash prototype behind the config switch)
# ---------------------------------------------------------------------------
class TestAttnImpl:
    def test_flash_agrees_with_chunked(self):
        cfg = ModelConfig(d_model=32, num_heads=2, num_kv_heads=2, num_layers=1)
        params = init_tree(attn.gqa_def(cfg), jax.random.PRNGKey(1), jnp.float32)
        x = _arr(1, 16, 32, scale=0.5)
        pos = jnp.arange(16)
        y_ch = attn.gqa_attention(params, x, cfg, positions=pos)
        cfg_fl = dataclasses.replace(cfg, attn_impl="flash")
        y_fl = attn.gqa_attention(params, x, cfg_fl, positions=pos)
        np.testing.assert_allclose(
            np.asarray(y_ch), np.asarray(y_fl), rtol=2e-5, atol=2e-5
        )

    def test_invalid_attn_impl_rejected(self):
        with pytest.raises(ValueError, match="attn_impl"):
            ModelConfig(attn_impl="paged-flash")

    def test_flash_reexport_surface(self):
        # models/ must reach flash via repro.photonic (RPR003); the
        # re-export is the same callable as the kernel op.
        from repro.kernels.flash_attention.ops import flash_attention as raw
        from repro.photonic.flash import flash_attention

        assert flash_attention is raw


# ---------------------------------------------------------------------------
# dispatch_summary — the structural fusion check (satellite b)
# ---------------------------------------------------------------------------
class TestDispatchSummary:
    def test_counts_entry_ops_not_bookkeeping(self):
        f = jax.jit(lambda x, w: jax.nn.gelu(x @ w))
        x, w = _arr(8, 16), _arr(16, 4)
        hlo = f.lower(x, w).compile().as_text()
        s = hlo_analysis.dispatch_summary(hlo)
        assert s["entry_computation"] is not None
        assert 1 <= s["dispatch_count"] <= 4
        assert s["entry_fusions"] >= 1
        assert "parameter" not in s["entry_ops_by_kind"]
        assert s["total_ops_loop_adjusted"] >= s["dispatch_count"]

    def test_fused_entry_sequence_strictly_shorter(self):
        """The benchmark's structural claim, as a contract test: the
        fused hot path compiles to fewer entry dispatches than the
        legacy shoulder-op composition."""
        eng = engine_for(DPU, "ref")
        pks = [pack_dense({"w": _arr(48, 48, scale=0.1)}, eng)["w"] for _ in range(3)]
        bs = [_arr(48, scale=0.02) for _ in range(3)]

        def legacy(x):
            outs = [
                _manual_unfused(eng, x, pk, b) for pk, b in zip(pks, bs)
            ]
            return jnp.concatenate(outs, axis=-1)

        pk_f = pack_dense(
            {"w": jnp.concatenate([pk.dequant() for pk in pks], axis=-1)}, eng
        )["w"]
        b_f = jnp.concatenate(bs)

        def fused(x):
            return eng.matmul(x, pk_f, site="s", bias=b_f)

        x = _arr(1, 48)
        counts = {}
        for name, fn in (("legacy", legacy), ("fused", fused)):
            hlo = jax.jit(fn).lower(x).compile().as_text()
            counts[name] = hlo_analysis.dispatch_summary(hlo)["dispatch_count"]
        assert counts["fused"] < counts["legacy"], counts


# ---------------------------------------------------------------------------
# launch profile
# ---------------------------------------------------------------------------
class TestLaunchProfile:
    def test_merge_user_flags_win(self, monkeypatch):
        monkeypatch.setenv(
            "XLA_FLAGS", "--xla_cpu_parallel_codegen_split_count=2 --xla_foo=1"
        )
        merged = profile._merge_xla_flags(
            ["--xla_cpu_parallel_codegen_split_count=8", "--xla_bar=0"]
        )
        opts = dict(o.split("=", 1) for o in merged.split())
        # the user's value survives; non-conflicting defaults are appended
        assert opts["--xla_cpu_parallel_codegen_split_count"] == "2"
        assert opts["--xla_foo"] == "1"
        assert opts["--xla_bar"] == "0"

    def test_apply_returns_describe(self, tmp_path, monkeypatch):
        monkeypatch.setenv("XLA_FLAGS", "--xla_force_host_platform_device_count=4")
        monkeypatch.delenv("TF_CPP_MIN_LOG_LEVEL", raising=False)
        desc = profile.apply(cache_dir=str(tmp_path / "cache"))
        # user-set option preserved, curated defaults appended
        assert "--xla_force_host_platform_device_count=4" in desc["xla_flags"]
        assert "--xla_cpu_parallel_codegen_split_count" in desc["xla_flags"]
        assert desc["jax_compilation_cache_dir"] == str(tmp_path / "cache")
        assert os.path.isdir(str(tmp_path / "cache"))
        assert desc["tf_cpp_min_log_level"] == "3"

    def test_child_env_injects_cache_and_tcmalloc(self, monkeypatch):
        monkeypatch.delenv("LD_PRELOAD", raising=False)
        env = profile.child_env({"PATH": "/usr/bin"})
        assert env["PATH"] == "/usr/bin"
        assert "JAX_COMPILATION_CACHE_DIR" in env
        lib = profile.find_tcmalloc()
        if lib is not None:
            assert lib in env.get("LD_PRELOAD", "")
        else:
            assert "LD_PRELOAD" not in env

    def test_benchmark_json_records_profile(self):
        # The smoke harness records the profile into the committed JSON;
        # keep the schema keys stable (CI greps them).
        desc = profile.describe()
        for key in (
            "tcmalloc_found", "tcmalloc_active", "xla_flags",
            "jax_compilation_cache_dir", "tf_cpp_min_log_level",
        ):
            assert key in desc
