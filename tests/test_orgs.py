"""Tests for `repro.orgs` — first-class organization specs.

The tentpole contract: the Table II/III/IV profiles are *derived* from the
block order, and for the three paper-studied orders the derivation equals
the legacy hand-copied tables exactly.  The legacy values are spelled out
here as literals (they no longer exist as hardcoded tables in the source)
so the assertion stays a real paper-anchored check.
"""

import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro import orgs
from repro.core import organizations as org_tables
from repro.core import scalability as sc
from repro.core.dpu import DPUConfig, dpu_int_gemm
from repro.core.params import PhotonicParams
from repro.core.perfmodel import AcceleratorConfig
from repro.kernels.photonic_gemm.ref import exact_int_gemm
from repro.noise import build_channel_model, shard_local_channel
from repro.orgs import OrgSpec, resolve, valid_orderings

# ---------------------------------------------------------------------------
# The paper's hand-tabulated values (Tables II, III, IV and §IV-B1), kept
# as literals: the derivation must reproduce them, not the other way round.
# ---------------------------------------------------------------------------
TABLE_II = {  # (inter_modulation, cross_weight, filter_truncation)
    "ASMW": (True, True, False),
    "MASW": (False, True, True),
    "SMWA": (False, False, True),
}
TABLE_III = {  # (through level, propagation level, through formula, wg factor)
    "ASMW": ("high", "moderate", "2(N-1)", 1.0),
    "MASW": ("moderate", "low", "N", 0.75),
    "SMWA": ("high", "high", "2", 1.5),
}
TABLE_IV_PENALTY = {"ASMW": 5.8, "MASW": 4.8, "SMWA": 1.8}
THROUGH_COUNT = {  # §IV-B1 at N
    "ASMW": lambda n: 2 * (n - 1),
    "MASW": lambda n: n,
    "SMWA": lambda n: 2,
}
RINGS_PER_DPU = {  # Fig. 2 at (N, M)
    "ASMW": lambda n, m: 2 * n * m,
    "MASW": lambda n, m: n + n * m,
    "SMWA": lambda n, m: 3 * n * m,
}
BLOCK_ORDERS = {
    "ASMW": ("A", "S", "M", "W", "Sigma"),
    "MASW": ("M", "A", "S", "W", "Sigma"),
    "SMWA": ("S", "M", "W", "A", "Sigma"),
}


class TestDerivedEqualsPaperTables:
    @pytest.mark.parametrize("org", orgs.ORGANIZATIONS)
    def test_block_orders(self, org):
        assert resolve(org).blocks == BLOCK_ORDERS[org]

    @pytest.mark.parametrize("org", orgs.ORGANIZATIONS)
    def test_table_ii_crosstalk_derived(self, org):
        s = resolve(org)
        assert (s.inter_modulation, s.cross_weight, s.filter_truncation) == (
            TABLE_II[org]
        )
        # ... and the legacy dict view agrees field-for-field.
        xt = org_tables.CROSSTALK[org]
        assert (xt.inter_modulation, xt.cross_weight, xt.filter_truncation) == (
            TABLE_II[org]
        )

    @pytest.mark.parametrize("org", orgs.ORGANIZATIONS)
    def test_table_iii_losses_derived(self, org):
        s = resolve(org)
        derived = (
            s.through_loss_level,
            s.propagation_loss_level,
            s.through_devices,
            s.waveguide_length_factor,
        )
        assert derived == TABLE_III[org]
        lp = org_tables.LOSSES[org]
        assert (
            lp.through_loss_level,
            lp.propagation_loss_level,
            lp.through_devices,
            lp.waveguide_length_factor,
        ) == TABLE_III[org]

    @pytest.mark.parametrize("org", orgs.ORGANIZATIONS)
    def test_table_iv_penalty_derived(self, org):
        s = resolve(org)
        assert s.derived_penalty_db == pytest.approx(TABLE_IV_PENALTY[org])
        # The PhotonicParams fields remain the calibrated anchors and win
        # for the paper orgs...
        assert PhotonicParams().penalty_db(org) == TABLE_IV_PENALTY[org]
        # ...including under ablation replaces.
        p = dataclasses.replace(PhotonicParams(), penalty_smwa_db=9.9)
        assert p.penalty_db("smwa") == 9.9

    @pytest.mark.parametrize("org", orgs.ORGANIZATIONS)
    @pytest.mark.parametrize("n", [2, 10, 17, 83])
    def test_through_device_count(self, org, n):
        assert resolve(org).through_device_count(n) == THROUGH_COUNT[org](n)
        assert org_tables.through_device_count(org, n) == THROUGH_COUNT[org](n)

    @pytest.mark.parametrize("org", orgs.ORGANIZATIONS)
    def test_rings_per_dpu_derived(self, org):
        s = resolve(org)
        for n, m in ((8, 8), (17, 17), (40, 24)):
            assert s.rings_per_dpu(n, m) == RINGS_PER_DPU[org](n, m)
        cfg = AcceleratorConfig(organization=org, n=40, m=40)
        assert cfg.rings_per_dpu == RINGS_PER_DPU[org](40, 40)


class TestChannelModelEquivalence:
    @pytest.mark.parametrize("org", orgs.ORGANIZATIONS)
    def test_spec_and_name_build_identical_models(self, org):
        by_name = build_channel_model(org, n=21, bits=4, datarate_gs=5.0)
        by_spec = build_channel_model(resolve(org), n=21, bits=4, datarate_gs=5.0)
        # Deliberately un-normalized input: the point is that resolve()
        # normalizes it.
        by_case = build_channel_model(
            org.lower(), n=21, bits=4, datarate_gs=5.0  # repro: noqa[RPR002]
        )
        # Frozen-dataclass equality covers every field INCLUDING the
        # builder provenance tuple.
        assert by_name == by_spec == by_case
        assert by_name.builder == by_spec.builder
        for f in dataclasses.fields(by_name):
            assert getattr(by_name, f.name) == getattr(by_spec, f.name), f.name

    @pytest.mark.parametrize("org", orgs.ORGANIZATIONS)
    def test_shard_local_round_trip(self, org):
        """Builder provenance survives spec-built models: the shard-local
        rebuild of a spec-built channel equals the name-built one."""
        by_name = build_channel_model(org, n=32, bits=4, datarate_gs=5.0)
        by_spec = build_channel_model(resolve(org), n=32, bits=4, datarate_gs=5.0)
        for n_local in (16, 8, 3):
            a = shard_local_channel(by_name, n_local)
            b = shard_local_channel(by_spec, n_local)
            assert a == b
            assert a == build_channel_model(org, n=n_local, bits=4, datarate_gs=5.0)

    def test_dpu_config_shard_local_accepts_spec(self):
        ch = build_channel_model(resolve("MASW"), n=32)
        cfg = DPUConfig(organization=resolve("MASW"), dpe_size=32, channel=ch)
        local = cfg.shard_local(8)
        assert local.organization == "MASW"
        assert local.channel == build_channel_model("MASW", n=8)

    def test_novel_ordering_channel_profile(self):
        """An ordering the paper never studied gets a structurally derived
        channel: MWAS is filter-only with ONE through device."""
        ch = build_channel_model("MWAS", n=16)
        assert ch.intermod_eps == 0.0
        assert ch.crossweight_eps == 0.0
        assert ch.filter_alpha > 0.0
        assert ch.through_loss_db == pytest.approx(1 * sc.CALIBRATED.p_mrm_obl_db)
        assert ch.penalty_db == pytest.approx(resolve("MWAS").derived_penalty_db)


class TestEagerValidation:
    @pytest.mark.parametrize(
        "ctor",
        [
            lambda org: DPUConfig(organization=org),
            lambda org: AcceleratorConfig(organization=org),
            lambda org: build_channel_model(org, n=8),
        ],
        ids=["DPUConfig", "AcceleratorConfig", "build_channel_model"],
    )
    def test_unknown_org_raises_valueerror_naming_choices(self, ctor):
        with pytest.raises(ValueError, match="ASMW"):
            ctor("not-an-org")
        with pytest.raises(ValueError, match="MASW"):
            ctor("WSMA")  # W before M: physically invalid order

    def test_case_normalization_unified(self):
        assert DPUConfig(organization="smwa") == DPUConfig(organization="SMWA")
        assert hash(DPUConfig(organization="smwa")) == hash(
            DPUConfig(organization="SMWA")
        )
        assert AcceleratorConfig(organization="masw").organization == "MASW"
        assert build_channel_model("aSmW", n=8).organization == "ASMW"

    def test_spec_input_normalizes_to_canonical_name(self):
        cfg = DPUConfig(organization=resolve("ASMW"))
        assert cfg.organization == "ASMW"
        assert cfg.org_spec is resolve("ASMW")

    def test_resolve_rejects_non_string(self):
        with pytest.raises(ValueError, match="str or OrgSpec"):
            resolve(3)


class TestDesignSpace:
    def test_twelve_valid_orderings(self):
        space = valid_orderings()
        names = [s.name for s in space]
        assert len(space) == 12
        assert len(set(names)) == 12
        assert names[:3] == list(orgs.ORGANIZATIONS)
        for s in space:
            assert s.blocks[-1] == "Sigma"
            assert s.blocks.index("M") < s.blocks.index("W")
            assert sorted(s.blocks[:-1]) == ["A", "M", "S", "W"]

    def test_specs_hashable_and_order_is_identity(self):
        assert len({s for s in valid_orderings()}) == 12
        assert OrgSpec.from_order("smwa") is resolve("SMWA")
        assert resolve(resolve("MASW")) is resolve("MASW")

    def test_invalid_orders_rejected(self):
        for bad in ("SSMW", "SAMWX", "SAM", "ABCD"):
            with pytest.raises(ValueError):
                OrgSpec.from_order(bad)
        with pytest.raises(ValueError, match="Modulation"):
            OrgSpec(blocks=("W", "M", "S", "A", "Sigma"))
        with pytest.raises(ValueError, match="terminal"):
            OrgSpec(blocks=("Sigma", "S", "M", "W", "A"))

    @given(idx=st.integers(min_value=0, max_value=11), n=st.integers(2, 200))
    @settings(max_examples=60, deadline=None)
    def test_through_count_matches_formula_property(self, idx, n):
        """Property: through_device_count agrees with the canonical formula
        string for every ordering in the space."""
        s = valid_orderings()[idx]
        expected = {
            "2(N-1)": 2 * (n - 1),
            "N-1": n - 1,
            "N": n,
            "N+1": n + 1,
            "2N": 2 * n,
            "2N-1": 2 * n - 1,
            "2N-2": 2 * n - 2,
            "0": 0,
            "1": 1,
            "2": 2,
        }[s.through_devices]
        assert s.through_device_count(n) == expected
        assert s.through_device_count(n) >= 0

    @given(idx=st.integers(min_value=0, max_value=11))
    @settings(max_examples=24, deadline=None)
    def test_crosstalk_rules_property(self, idx):
        """Property: the Table II mechanisms follow the structural rules
        for every ordering (not just the paper's three)."""
        s = valid_orderings()[idx]
        assert s.inter_modulation == s.before("A", "M")
        assert s.cross_weight == s.before("A", "W")
        assert s.filter_truncation == s.before("M", "A")
        # filter truncation and inter-modulation are mutually exclusive
        # (M<A vs A<M), a structural theorem of the rule set.
        assert not (s.inter_modulation and s.filter_truncation)

    def test_scalability_solver_covers_novel_orderings(self):
        """The Eq. 1-3 solver works on the whole space; filter-only
        orderings achieve the largest N (Fig. 5 logic, generalized)."""
        ns = {s.name: sc.calibrated_max_n(s, 4, 5) for s in valid_orderings()}
        best = max(ns.values())
        assert ns["SMWA"] == best
        assert ns["MWAS"] == best  # the unstudied challenger ties SMWA
        for s in valid_orderings():
            if s.cross_weight or s.inter_modulation:
                assert ns[s.name] < best, ns

    def test_novel_ordering_ideal_gemm_bitwise_exact(self):
        """A novel ordering runs the full DPU datapath; ideal channel is
        bit-identical to the exact integer GEMM (DESIGN.md §8 contract 1,
        extended to the whole design space)."""
        rng = np.random.default_rng(0)
        xq = jnp.asarray(rng.integers(-127, 128, (5, 40), dtype=np.int8))
        wq = jnp.asarray(rng.integers(-127, 128, (40, 7), dtype=np.int8))
        gold = exact_int_gemm(xq, wq)
        for order in ("MWAS", "SAMW", "MSAW"):
            cfg = DPUConfig(organization=order, bits=4, dpe_size=16)
            out = dpu_int_gemm(xq, wq, cfg)
            assert jnp.array_equal(out, gold), order
