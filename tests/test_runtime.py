"""Runtime substrate tests: checkpoint/restart, failure recovery, elastic
resharding, straggler watchdog, serving engine, data determinism, gradient
compression."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import ckpt
from repro.data.pipeline import DataConfig, SyntheticTokens
from repro.models import registry
from repro.models.common import init_tree
from repro.optim import adamw
from repro.optim.compress import compressed_psum, with_error_feedback
from repro.runtime import serve
from repro.runtime.train_loop import StragglerWatchdog, TrainConfig, train

ARCH = registry.get("qwen2-0.5b")
SMOKE = dataclasses.replace(ARCH.smoke_config, remat=False)
DATA = DataConfig(vocab_size=SMOKE.vocab_size, seq_len=32, global_batch=4, seed=1)


def _quiet(msg):
    pass


class TestDataPipeline:
    def test_deterministic_and_resumable(self):
        s1 = SyntheticTokens(DATA)
        s2 = SyntheticTokens(DATA)
        b5a, b5b = s1.batch_at(5), s2.batch_at(5)
        np.testing.assert_array_equal(b5a["tokens"], b5b["tokens"])
        assert not np.array_equal(s1.batch_at(5)["tokens"], s1.batch_at(6)["tokens"])

    def test_shards_are_disjoint_slices(self):
        sh0 = SyntheticTokens(DATA, 0, 2).batch_at(3)
        sh1 = SyntheticTokens(DATA, 1, 2).batch_at(3)
        assert sh0["tokens"].shape[0] == DATA.global_batch // 2
        assert not np.array_equal(sh0["tokens"], sh1["tokens"])

    def test_copy_structure_learnable(self):
        b = SyntheticTokens(DATA).batch_at(0)
        t = b["tokens"]
        half = t.shape[1] // 2
        copies = sum(
            np.array_equal(t[i, 1 : half], t[i, half + 1 : 2 * half])
            for i in range(t.shape[0])
        )
        assert copies >= 0  # structural smoke (prob. copy rows exist over steps)


class TestCheckpoint:
    def test_save_restore_roundtrip(self, tmp_path):
        params = init_tree(
            ARCH.param_defs(SMOKE), jax.random.PRNGKey(0), SMOKE.param_dtype
        )
        opt = adamw.init(params)
        ckpt.save(tmp_path, 7, {"params": params, "opt": opt})
        assert ckpt.latest_step(tmp_path) == 7
        restored = ckpt.restore(tmp_path, 7, {"params": params, "opt": opt})
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored["params"])):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_async_save_and_cleanup(self, tmp_path):
        tree = {"x": jnp.arange(10)}
        for s in (1, 2, 3, 4):
            t = ckpt.save(tmp_path, s, tree, blocking=False)
            if t:
                t.join()
        ckpt.cleanup(tmp_path, keep=2)
        assert ckpt.latest_step(tmp_path) == 4
        assert (tmp_path / "step_00000003").exists()
        assert not (tmp_path / "step_00000001").exists()

    def test_atomicity_tmp_never_visible(self, tmp_path):
        ckpt.save(tmp_path, 1, {"x": jnp.zeros(3)})
        assert not any(p.name.startswith(".tmp") for p in tmp_path.iterdir())


class TestFaultTolerance:
    def test_crash_and_resume_matches_uninterrupted(self, tmp_path):
        """A run killed at step 6 and resumed produces the same final loss
        trajectory as an uninterrupted run (checkpoint + stateless data)."""
        tc = lambda d: TrainConfig(  # noqa: E731
            steps=10, ckpt_every=3, ckpt_dir=str(d), log_every=100,
            async_checkpoint=False,
        )
        ref = train(
            arch=ARCH, model_cfg=SMOKE, data_cfg=DATA,
            train_cfg=tc(tmp_path / "ref"), log=_quiet,
        )

        with pytest.raises(RuntimeError, match="simulated node failure"):
            train(
                arch=ARCH, model_cfg=SMOKE, data_cfg=DATA,
                train_cfg=tc(tmp_path / "ft"), fail_at_step=6, log=_quiet,
            )
        assert ckpt.latest_step(tmp_path / "ft") == 6
        resumed = train(
            arch=ARCH, model_cfg=SMOKE, data_cfg=DATA,
            train_cfg=tc(tmp_path / "ft"), log=_quiet,
        )
        assert resumed["final_step"] == 10
        # same trailing losses as the uninterrupted run
        np.testing.assert_allclose(
            resumed["losses"][-3:], ref["losses"][-3:], rtol=1e-4
        )

    def test_loss_decreases(self, tmp_path):
        out = train(
            arch=ARCH, model_cfg=SMOKE, data_cfg=DATA,
            train_cfg=TrainConfig(steps=30, ckpt_every=1000, ckpt_dir=str(tmp_path),
                                  log_every=1000),
            opt_cfg=adamw.AdamWConfig(lr=3e-3, warmup_steps=5, total_steps=30),
            log=_quiet,
        )
        first = np.mean(out["losses"][:5])
        last = np.mean(out["losses"][-5:])
        assert last < first, (first, last)

    def test_straggler_watchdog(self):
        w = StragglerWatchdog(factor=2.0)
        for s in range(10):
            assert not w.observe(s, 0.1)
        assert w.observe(10, 0.5)
        assert len(w.events) == 1
        # EWMA not polluted by the straggler sample
        assert w.ewma == pytest.approx(0.1, rel=0.01)


class TestElastic:
    def test_restore_onto_different_mesh(self, tmp_path):
        """Checkpoint saved unsharded restores under a new mesh/sharding."""
        from repro.launch.mesh import make_smoke_mesh
        from repro.models.common import axes_tree
        from repro.runtime import sharding as shd

        params = init_tree(
            ARCH.param_defs(SMOKE), jax.random.PRNGKey(0), SMOKE.param_dtype
        )
        ckpt.save(tmp_path, 1, {"params": params})
        mesh = make_smoke_mesh()
        with shd.use_rules(mesh):
            sh = shd.tree_shardings(mesh, params, axes_tree(ARCH.param_defs(SMOKE)))
        restored = ckpt.restore(tmp_path, 1, {"params": params}, {"params": sh})
        leaf = jax.tree.leaves(restored["params"])[0]
        assert isinstance(leaf.sharding, jax.sharding.NamedSharding)


class TestServing:
    def test_batched_serving_completes_and_matches_decode(self):
        params = init_tree(
            ARCH.param_defs(SMOKE), jax.random.PRNGKey(0), SMOKE.param_dtype
        )
        eng = serve.Engine(
            ARCH, SMOKE, params, serve.ServeConfig(batch_size=2, max_seq=64)
        )
        rng = np.random.default_rng(0)
        reqs = [
            serve.Request(
                uid=i,
                prompt=rng.integers(0, SMOKE.vocab_size, 8).astype(np.int32),
                max_new_tokens=6,
            )
            for i in range(5)
        ]
        done = eng.run(reqs)
        assert all(r.done for r in done)
        assert all(len(r.output) == 6 for r in done)
        assert eng.stats["completed"] == 5
        # greedy decode of request 0 must match a standalone prefill+decode
        r0 = reqs[0]
        b = {"tokens": jnp.asarray(r0.prompt)[None, :]}
        logits, cache = ARCH.prefill(params, b, SMOKE, 64)
        toks = [int(jnp.argmax(logits[0, -1, : SMOKE.vocab_size]))]
        for _ in range(5):
            logits, cache = ARCH.decode(
                params, jnp.asarray([[toks[-1]]], jnp.int32), cache, SMOKE
            )
            toks.append(int(jnp.argmax(logits[0, -1, : SMOKE.vocab_size])))
        assert toks == r0.output


class TestCompression:
    def test_compressed_psum_axis1_identity_error_bound(self):
        """On a singleton axis, compressed_psum == quantize-dequantize; the
        error is bounded by scale/2 elementwise."""
        from repro.compat import Mesh, PartitionSpec as P, shard_map

        mesh = Mesh(np.array(jax.devices()[:1]), ("pod",))
        g = {
            "w": jnp.asarray(
                np.random.default_rng(0).normal(size=(16, 16)), jnp.float32
            )
        }
        out = shard_map(
            lambda t: compressed_psum(t, "pod"),
            mesh=mesh, in_specs=(P(),), out_specs=P(),
        )(g)
        err = jnp.abs(out["w"] - g["w"])
        bound = jnp.max(jnp.abs(g["w"])) / 127.0
        assert float(err.max()) <= float(bound) * 0.51 + 1e-7

    def test_error_feedback_reduces_bias(self):
        rng = np.random.default_rng(1)
        g = {"w": jnp.asarray(rng.normal(size=(64,)), jnp.float32)}
        resid = jax.tree.map(jnp.zeros_like, g)
        total_comp = jnp.zeros((64,))
        steps = 20
        for _ in range(steps):
            comp, resid = with_error_feedback(g, resid)
            total_comp = total_comp + comp["w"]
        # accumulated compressed grads converge to accumulated true grads
        rel = float(
            jnp.linalg.norm(total_comp - steps * g["w"]) / jnp.linalg.norm(
                steps * g["w"]
            )
        )
        assert rel < 0.01, rel


class TestDPShardMap:
    def test_dp_step_matches_plain_step(self):
        """shard_map-pinned DP step == plain jit step on a 1x1 mesh."""
        import numpy as np
        from repro.compat import Mesh
        from repro.runtime.dp_step import make_dp_train_step
        from repro.runtime.train_loop import build_train_step

        mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
        params = init_tree(
            ARCH.param_defs(SMOKE), jax.random.PRNGKey(0), SMOKE.param_dtype
        )
        opt = adamw.init(params)
        opt_cfg = adamw.AdamWConfig()
        batch = {
            k: jnp.asarray(v) for k, v in SyntheticTokens(DATA).batch_at(0).items()
        }
        loss_fn = lambda p, b: ARCH.loss(p, b, SMOKE)  # noqa: E731

        dp = make_dp_train_step(loss_fn, opt_cfg, mesh)
        p1, o1, l1, g1 = jax.jit(dp)(params, opt, batch)

        plain = build_train_step(loss_fn, opt_cfg)
        p2, o2, m2 = jax.jit(plain)(params, opt, batch)
        assert float(l1) == pytest.approx(float(m2["loss"]), rel=1e-5)
        for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
            np.testing.assert_allclose(
                np.asarray(a, np.float32), np.asarray(b, np.float32), atol=2e-2
            )

    def test_ring_int8_allreduce_singleton(self):
        from repro.optim.compress import ring_int8_allreduce
        from repro.compat import Mesh, PartitionSpec as P, shard_map
        import numpy as np

        mesh = Mesh(np.array(jax.devices()[:1]), ("pod",))
        g = {"w": jnp.arange(12.0).reshape(3, 4)}
        out = jax.jit(shard_map(
            lambda t: ring_int8_allreduce(t, ("pod",)),
            mesh=mesh, in_specs=(P(),), out_specs=P(), check_vma=False,
        ))(g)
        np.testing.assert_allclose(np.asarray(out["w"]), np.asarray(g["w"]))
