"""STE backward pass of the photonic matmul (noise-aware), and the noise
seed-determinism contract of `dpu_int_gemm`."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.dpu import (
    DPUConfig,
    dpu_int_gemm,
    photonic_matmul,
    photonic_matmul_ste,
)
from repro.kernels.photonic_gemm.ops import photonic_gemm
from repro.noise import build_channel_model


def _data(seed=0, b=4, s=8, k=32, c=16):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(b, s, k)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(k, c)), jnp.float32)
    g = jnp.asarray(rng.normal(size=(b, s, c)), jnp.float32)
    return x, w, g


# ---------------------------------------------------------------------------
# STE backward == dense-matmul gradients (exactly, for a linear loss)
# ---------------------------------------------------------------------------
def test_ste_backward_matches_dense_matmul_grad():
    x, w, g = _data()
    cfg = DPUConfig(dpe_size=16)

    def loss_ste(x, w):
        return (photonic_matmul_ste(x, w, cfg) * g).sum()

    def loss_dense(x, w):
        return ((x @ w) * g).sum()

    gx, gw = jax.grad(loss_ste, argnums=(0, 1))(x, w)
    ex, ew = jax.grad(loss_dense, argnums=(0, 1))(x, w)
    np.testing.assert_allclose(np.asarray(gx), np.asarray(ex), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(gw), np.asarray(ew), rtol=1e-6)


def test_ste_backward_unchanged_by_noise():
    """The straight-through gradient ignores forward perturbations: a noisy
    channel changes the forward value but not the backward pass."""
    x, w, g = _data(1)
    ch = build_channel_model("ASMW", n=16)
    cfg_ideal = DPUConfig(organization="ASMW", dpe_size=16)
    cfg_noisy = dataclasses.replace(cfg_ideal, channel=ch)
    key = jax.random.PRNGKey(7)

    y_ideal = photonic_matmul_ste(x, w, cfg_ideal)
    y_noisy = photonic_matmul_ste(x, w, cfg_noisy, key)
    assert (np.asarray(y_ideal) != np.asarray(y_noisy)).any()

    def gset(cfg, key=None):
        gx, gw = jax.grad(
            lambda x, w: (photonic_matmul_ste(x, w, cfg, key) * g).sum(),
            argnums=(0, 1),
        )(x, w)
        return np.asarray(gx), np.asarray(gw)

    gx_i, gw_i = gset(cfg_ideal)
    gx_n, gw_n = gset(cfg_noisy, key)
    np.testing.assert_array_equal(gx_i, gx_n)
    np.testing.assert_array_equal(gw_i, gw_n)
    assert np.isfinite(gx_n).all() and np.isfinite(gw_n).all()


@pytest.mark.parametrize("backend", ["ref", "pallas"])
def test_kernel_entrypoint_ste_noise_aware(backend):
    """`photonic_gemm` (kernel entry point) takes a prng_key and keeps its
    STE gradients exact while the forward carries channel noise."""
    x, w, g = _data(2)
    ch = build_channel_model("MASW", n=16)
    cfg = DPUConfig(organization="MASW", dpe_size=16, channel=ch)
    key = jax.random.PRNGKey(3)

    y = photonic_gemm(x, w, cfg, backend, key)
    assert np.isfinite(np.asarray(y)).all()
    gx = jax.grad(lambda x: (photonic_gemm(x, w, cfg, backend, key) * g).sum())(x)
    ex = jax.grad(lambda x: ((x @ w) * g).sum())(x)
    np.testing.assert_allclose(np.asarray(gx), np.asarray(ex), rtol=1e-6)


def test_ste_jit_and_value_and_grad():
    x, w, g = _data(3)
    ch = build_channel_model("SMWA", n=16)
    cfg = DPUConfig(dpe_size=16, channel=ch, noise_seed=5)

    @jax.jit
    def vg(x, w):
        return jax.value_and_grad(
            lambda x, w: (photonic_matmul_ste(x, w, cfg) * g).sum(),
            argnums=(0, 1),
        )(x, w)

    (v1, (gx, gw)) = vg(x, w)
    (v2, _) = vg(x, w)
    assert v1 == v2  # noise_seed path: bitwise-deterministic forward
    assert np.isfinite(np.asarray(gx)).all()


# ---------------------------------------------------------------------------
# Seed-determinism contract (regression for the prng_key=None path)
# ---------------------------------------------------------------------------
def test_same_key_bitwise_equal_under_noise():
    rng = np.random.default_rng(4)
    xq = jnp.asarray(rng.integers(-127, 128, (16, 96), dtype=np.int8))
    wq = jnp.asarray(rng.integers(-127, 128, (96, 24), dtype=np.int8))
    cfg = DPUConfig(dpe_size=24, noise_sigma_lsb=4.0)
    key = jax.random.PRNGKey(0)
    a = dpu_int_gemm(xq, wq, cfg, prng_key=key)
    b = dpu_int_gemm(xq, wq, cfg, prng_key=key)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    c = dpu_int_gemm(xq, wq, cfg, prng_key=jax.random.PRNGKey(1))
    assert (np.asarray(a) != np.asarray(c)).any()


def test_noise_without_seed_is_explicit_error():
    rng = np.random.default_rng(5)
    xq = jnp.asarray(rng.integers(-127, 128, (4, 32), dtype=np.int8))
    wq = jnp.asarray(rng.integers(-127, 128, (32, 8), dtype=np.int8))
    with pytest.raises(ValueError, match="randomness source"):
        dpu_int_gemm(xq, wq, DPUConfig(dpe_size=16, noise_sigma_lsb=2.0))
    ch = build_channel_model("ASMW", n=16)
    with pytest.raises(ValueError, match="randomness source"):
        dpu_int_gemm(xq, wq, DPUConfig(organization="ASMW", dpe_size=16, channel=ch))
    # Crosstalk-only channels are deterministic — no seed needed.
    out = dpu_int_gemm(
        xq,
        wq,
        DPUConfig(
            organization="ASMW", dpe_size=16, channel=ch.disable("detector")
        ),
    )
    assert out.shape == (4, 8)


def test_same_seed_distinct_operands_decorrelated():
    """Two same-shaped GEMMs sharing one noise_seed must not reuse the same
    noise array (operand-content tweak): otherwise every same-shaped layer
    of a model would see coherent, correlated analog errors."""
    rng = np.random.default_rng(7)
    xq = jnp.asarray(rng.integers(-127, 128, (8, 64), dtype=np.int8))
    w1 = jnp.asarray(rng.integers(-127, 128, (64, 16), dtype=np.int8))
    w2 = jnp.asarray(rng.integers(-127, 128, (64, 16), dtype=np.int8))
    ch = build_channel_model("SMWA", n=16).disable("crosstalk")
    cfg = DPUConfig(dpe_size=16, channel=ch, noise_seed=0)
    from repro.kernels.photonic_gemm.ref import exact_int_gemm

    n1 = np.asarray(dpu_int_gemm(xq, w1, cfg)) - np.asarray(exact_int_gemm(xq, w1))
    n2 = np.asarray(dpu_int_gemm(xq, w2, cfg)) - np.asarray(exact_int_gemm(xq, w2))
    assert (n1 != n2).any()
    corr = np.corrcoef(n1.ravel().astype(float), n2.ravel().astype(float))[0, 1]
    assert abs(corr) < 0.3, corr


def test_noise_seed_documented_deterministic_path():
    rng = np.random.default_rng(6)
    x = jnp.asarray(rng.normal(size=(8, 64)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(64, 16)), jnp.float32)
    ch = build_channel_model("MASW", n=21)
    cfg = DPUConfig(organization="MASW", dpe_size=21, channel=ch, noise_seed=42)
    a = photonic_matmul(x, w, cfg)
    b = photonic_matmul(x, w, cfg)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # An explicit key overrides the config seed.
    c = photonic_matmul(x, w, cfg, prng_key=jax.random.PRNGKey(9))
    assert (np.asarray(a) != np.asarray(c)).any()
