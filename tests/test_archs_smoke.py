"""Per-architecture smoke tests (assignment deliverable f).

Every assigned arch instantiates a REDUCED config of the same family and
runs: init -> train loss (finite) -> gradients (finite) -> prefill + decode
consistency against the teacher-forced full forward.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import lm, registry, whisper, xlstm, zamba2
from repro.models.common import init_tree

ARCHS = registry.names()
B, T = 2, 16


def _make_batch(arch, cfg, with_labels=True):
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, T)), jnp.int32)
    batch = {"tokens": toks}
    if with_labels:
        batch["labels"] = jnp.asarray(
            rng.integers(0, cfg.vocab_size, (B, T)), jnp.int32
        )
    if arch.family == "vlm":
        batch["vision"] = jnp.asarray(
            rng.normal(size=(B, cfg.vision_seq, cfg.d_model)), jnp.float32
        )
    if arch.family == "audio":
        batch["audio_embed"] = jnp.asarray(
            rng.normal(size=(B, 2 * T, cfg.d_model)), jnp.float32
        )
    return batch


def _full_logits(arch, params, batch, cfg):
    if arch.family in ("dense", "vlm", "moe"):
        out, _ = lm.lm_logits(params, batch["tokens"], cfg, vision=batch.get("vision"))
    elif arch.family == "ssm":
        out, _ = xlstm.xlstm_logits(params, batch["tokens"], cfg)
    elif arch.family == "hybrid":
        out, _ = zamba2.zamba2_logits(params, batch["tokens"], cfg)
    else:
        out, _ = whisper.whisper_logits(params, batch, cfg)
    return out


@pytest.mark.parametrize("name", ARCHS)
def test_train_step_shapes_and_finite(name):
    arch = registry.get(name)
    cfg = arch.smoke_config
    params = init_tree(arch.param_defs(cfg), jax.random.PRNGKey(0), cfg.param_dtype)
    batch = _make_batch(arch, cfg)
    loss, grads = jax.value_and_grad(lambda p: arch.loss(p, batch, cfg))(params)
    assert jnp.isfinite(loss), name
    # loss ~ ln(vocab) at init
    assert 0.5 * np.log(cfg.vocab_size) < float(loss) < 2.0 * np.log(cfg.vocab_size)
    for path, g in jax.tree_util.tree_flatten_with_path(grads)[0]:
        assert bool(jnp.all(jnp.isfinite(g))), (name, path)


@pytest.mark.parametrize("name", ARCHS)
def test_prefill_decode_matches_full_forward(name):
    arch = registry.get(name)
    cfg = dataclasses.replace(arch.smoke_config, remat=False)
    if cfg.num_experts:
        # Dropless capacity: capacity-based token dropping is T-dependent, so
        # exact prefill/decode vs full-forward equivalence needs cf >= E/k.
        cfg = dataclasses.replace(cfg, capacity_factor=float(cfg.num_experts))
    params = init_tree(arch.param_defs(cfg), jax.random.PRNGKey(0), cfg.param_dtype)
    batch = _make_batch(arch, cfg, with_labels=False)
    full = _full_logits(arch, params, batch, cfg)

    tp = T - 4
    pb = dict(batch)
    pb["tokens"] = batch["tokens"][:, :tp]
    logits, cache = arch.prefill(params, pb, cfg, T)
    assert logits.shape == (B, 1, cfg.n_vocab)
    errs = [float(jnp.abs(logits[:, 0] - full[:, tp - 1]).max())]
    for i in range(tp, T):
        logits, cache = arch.decode(params, batch["tokens"][:, i : i + 1], cache, cfg)
        errs.append(float(jnp.abs(logits[:, 0] - full[:, i]).max()))
    assert max(errs) < 1e-3, (name, errs)


@pytest.mark.parametrize("name", ARCHS)
def test_full_configs_construct(name):
    """FULL configs build param-def trees with the exact assigned sizes
    (no allocation — shapes only)."""
    arch = registry.get(name)
    cfg = arch.config
    defs = arch.param_defs(cfg)
    n_params = 0

    def walk(node):
        nonlocal n_params
        if isinstance(node, dict):
            for v in node.values():
                walk(v)
        else:
            size = 1
            for s in node.shape:
                size *= s
            n_params += size

    walk(defs)
    expected = {
        "granite-3-8b": 8.1e9,
        "qwen2-1.5b": 1.5e9,
        "deepseek-67b": 67e9,
        "qwen2-0.5b": 0.5e9,
        "llama-3.2-vision-90b": 90e9,
        "phi3.5-moe-42b-a6.6b": 42e9,
        "deepseek-v2-lite-16b": 16e9,
        "xlstm-350m": 0.35e9,
        "zamba2-2.7b": 2.7e9,
        "whisper-medium": 0.77e9,
    }[name]
    # within 2.2x of the nameplate (nameplates are approximate; xlstm uses
    # projection factor 2 — DESIGN.md §7)
    assert expected / 2.2 < n_params < expected * 2.2, (name, n_params, expected)


def test_registry_complete():
    assert len(ARCHS) == 10
    fams = {registry.get(n).family for n in ARCHS}
    assert fams == {"dense", "vlm", "moe", "ssm", "hybrid", "audio"}
