"""repro.photonic engine + packing: weight-stationary prepacked GEMM
routing (DESIGN.md §9 contracts)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.dpu import DPUConfig
from repro.kernels.photonic_gemm.ref import exact_int_gemm
from repro.models import registry
from repro.models.common import (
    ModelConfig,
    dense,
    engine_from_model_config,
    init_tree,
    quantize_params,
)
from repro.noise import build_channel_model
from repro.photonic import (
    PackedDense,
    SitePolicy,
    engine_for,
    pack_dense,
    prepack_params,
)


def _noisy_dpu(noise_seed=3, n=21):
    ch = build_channel_model("SMWA", n=n, bits=4, datarate_gs=5.0)
    return DPUConfig(
        organization="SMWA", bits=4, dpe_size=n, channel=ch, noise_seed=noise_seed
    )


def _det_dpu(n=21):
    """Deterministic analog stages only (crosstalk/filter/ADC, no detector
    noise) — bitwise across backends per DESIGN.md §8."""
    ch = build_channel_model("SMWA", n=n, bits=4, datarate_gs=5.0)
    ch = dataclasses.replace(ch, detector_sigma_lsb=0.0)
    return DPUConfig(organization="SMWA", bits=4, dpe_size=n, channel=ch)


RNG = np.random.default_rng(0)
X = jnp.asarray(RNG.normal(size=(4, 200)), jnp.float32)
W = jnp.asarray(RNG.normal(size=(200, 96)), jnp.float32)


# ---------------------------------------------------------------------------
# Prepacked == per-call quantization (both backends, all channel kinds)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("backend", ["ref", "pallas", "exact"])
@pytest.mark.parametrize("kind", ["ideal", "det", "noisy"])
def test_prepack_bitwise_equals_per_call(backend, kind):
    if kind == "noisy" and backend == "exact":
        pytest.skip("exact backend ignores the channel by design")
    dpu = {
        "ideal": DPUConfig(organization="SMWA", bits=4, dpe_size=21),
        "det": _det_dpu(),
        "noisy": _noisy_dpu(),
    }[kind]
    eng = engine_for(dpu, backend)
    packed = pack_dense({"w": W}, eng)["w"]
    a = eng.matmul_float(X, W, site="s", fold=2)
    b = eng.matmul(X, packed, site="s", fold=2)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_prepack_ideal_engine_equals_exact_int_gemm():
    """Ideal-channel engine output == exact integer GEMM of the quantized
    operands, through the packed path, on both backends."""
    from repro.core.dpu import quantize_symmetric

    dpu = DPUConfig(organization="SMWA", bits=4, dpe_size=21)
    xq, sx = quantize_symmetric(X, 8)
    wq, sw = quantize_symmetric(W, 8, axis=0)
    gold = np.asarray(exact_int_gemm(xq, wq), np.float32) * np.asarray(
        sx
    ) * np.asarray(sw)
    for backend in ("ref", "pallas"):
        eng = engine_for(dpu, backend)
        packed = pack_dense({"w": W}, eng)["w"]
        y = eng.matmul(X, packed, site="s")
        np.testing.assert_allclose(np.asarray(y), gold, rtol=0, atol=0)


def test_prepack_pallas_layout_is_tile_padded():
    eng = engine_for(DPUConfig(organization="SMWA", bits=4, dpe_size=21), "pallas")
    packed = pack_dense({"w": W}, eng)["w"]
    assert packed.tiling is not None
    n_chunk, tile_k, tile_c = packed.tiling
    kp, cp = packed.wq.shape
    assert kp % tile_k == 0 and cp % tile_c == 0
    assert (kp, cp) != (packed.k, packed.c)  # genuinely padded for this shape
    # raw layout for the oracle backend
    raw = pack_dense({"w": W}, engine_for(DPUConfig(dpe_size=21), "ref"))["w"]
    assert raw.tiling is None and raw.wq.shape == (200, 96)


def test_prepack_reuses_existing_int8_layout_bitwise():
    """Prepacking int8-stored params reuses their quantization bit-for-bit
    (only the layout changes)."""
    arch = registry.get("qwen2-0.5b")
    mcfg = dataclasses.replace(
        arch.smoke_config,
        remat=False,
        photonic=DPUConfig(dpe_size=21),
        photonic_backend="ref",
        photonic_scope="weights_int8",
    )
    fcfg = dataclasses.replace(mcfg, photonic_scope="weights")
    params = init_tree(arch.param_defs(fcfg), jax.random.PRNGKey(0), mcfg.param_dtype)
    defs_q = arch.param_defs(mcfg)
    params_q = quantize_params(params, defs_q)
    eng = engine_from_model_config(mcfg)
    packed = prepack_params(params_q, defs_q, eng)

    leaf_q = params_q["layers"]["attn"]["wq"]
    leaf_p = packed["layers"]["attn"]["wq"]["w"]
    assert isinstance(leaf_p, PackedDense) and leaf_p.wq.dtype == jnp.int8
    np.testing.assert_array_equal(np.asarray(leaf_q["w"]), np.asarray(leaf_p.wq))
    np.testing.assert_array_equal(
        np.asarray(leaf_q["w_scale"], np.float32), np.asarray(leaf_p.w_scale)
    )


# ---------------------------------------------------------------------------
# Site policy: routing + the MoE router bugfix
# ---------------------------------------------------------------------------
def test_site_policy_matching():
    pol = SitePolicy()
    assert pol.routes("attn.wq") and pol.routes("lm_head") and pol.routes(None)
    assert not pol.routes("ffn.router")
    assert not pol.routes("router")
    assert SitePolicy(exclude=()).routes("ffn.router")  # documented opt-in
    assert not SitePolicy(include=("attn.*",)).routes("ffn.wi")
    assert SitePolicy(include=("attn.*",)).routes("attn.wq")


def test_router_site_stays_digital_under_noise():
    """dense(site='ffn.router') must equal the exact digital matmul even
    with a ferociously noisy analog channel configured (satellite bugfix:
    expert routing decisions are control flow)."""
    ch = dataclasses.replace(
        build_channel_model("SMWA", n=21, bits=4, datarate_gs=5.0),
        detector_sigma_lsb=500.0,
    )
    cfg = ModelConfig(
        photonic=DPUConfig(dpe_size=21, channel=ch, noise_seed=0),
        photonic_backend="ref",
    )
    params = {"w": W}
    y = dense(params, X, cfg, site="ffn.router")
    np.testing.assert_array_equal(np.asarray(y), np.asarray(X @ W))
    # ...and a routed site under the same channel is genuinely perturbed
    y2 = dense(params, X, cfg, site="ffn.wi")
    assert not np.array_equal(np.asarray(y2), np.asarray(X @ W))
    # opt-in: clearing the exclusion routes the router photonically
    cfg_in = dataclasses.replace(cfg, photonic_exclude=())
    y3 = dense(params, X, cfg_in, site="ffn.router")
    assert not np.array_equal(np.asarray(y3), np.asarray(X @ W))


def test_moe_router_excluded_end_to_end():
    """A full MoE forward picks identical experts with and without an
    (ideal) photonic engine only because the router runs digitally."""
    from repro.models import ffn

    cfg = ModelConfig(
        d_model=32,
        d_ff=64,
        num_experts=4,
        num_experts_per_tok=2,
        photonic=DPUConfig(organization="SMWA", bits=4, datarate_gs=5.0),
        photonic_backend="ref",
    )
    defs = ffn.moe_def(cfg)
    params = init_tree(defs, jax.random.PRNGKey(1), jnp.float32)
    x = jnp.asarray(RNG.normal(size=(2, 8, 32)), jnp.float32)
    logits_digital = x.astype(jnp.float32) @ params["router"]["w"]
    logits_engine = dense(
        params["router"], x.astype(jnp.float32), cfg, site="ffn.router"
    )
    np.testing.assert_array_equal(np.asarray(logits_engine), np.asarray(logits_digital))
    # the full MoE layer still runs (photonic experts, digital router)
    out, aux = ffn.moe(params, x, cfg)
    assert bool(jnp.all(jnp.isfinite(out))) and bool(jnp.isfinite(aux))


# ---------------------------------------------------------------------------
# Scope validation (satellite bugfix)
# ---------------------------------------------------------------------------
def test_model_config_validates_scope_and_backend():
    with pytest.raises(ValueError, match="photonic_scope"):
        ModelConfig(photonic_scope="weights_int4")
    with pytest.raises(ValueError, match="photonic_backend"):
        ModelConfig(photonic_backend="cuda")
    for scope in ("none", "weights", "weights_int8"):
        ModelConfig(photonic_scope=scope)  # documented values accepted
    assert (
        engine_from_model_config(
            ModelConfig(photonic=DPUConfig(dpe_size=8), photonic_scope="none")
        )
        is None
    )


# ---------------------------------------------------------------------------
# PRNG-key threading (satellite bugfix)
# ---------------------------------------------------------------------------
def test_int8_branch_threads_prng_key_end_to_end():
    """The int8-stored dense branch accepts prng_key (same key => bitwise
    equal; different key => different) and raises the documented
    ValueError when a noisy channel has no randomness source at all."""
    ch = build_channel_model("SMWA", n=21, bits=4, datarate_gs=5.0)
    cfg = ModelConfig(
        photonic=DPUConfig(dpe_size=21, channel=ch),  # NO noise_seed
        photonic_backend="ref",
        photonic_scope="weights_int8",
    )
    wq, sw = (
        jnp.asarray(RNG.integers(-127, 128, (200, 96)), jnp.int8),
        jnp.asarray(RNG.uniform(0.005, 0.02, (96,)), jnp.float32),
    )
    params = {"w": wq, "w_scale": sw}
    k1, k2 = jax.random.PRNGKey(0), jax.random.PRNGKey(1)
    a = dense(params, X, cfg, site="ffn.wi", prng_key=k1)
    b = dense(params, X, cfg, site="ffn.wi", prng_key=k1)
    c = dense(params, X, cfg, site="ffn.wi", prng_key=k2)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert not np.array_equal(np.asarray(a), np.asarray(c))
    with pytest.raises(ValueError, match="randomness source"):
        dense(params, X, cfg, site="ffn.wi")


# ---------------------------------------------------------------------------
# Seed decorrelation: by site, and by layer inside a lax.scan stack
# ---------------------------------------------------------------------------
def test_sites_decorrelate_same_operands():
    """Identical operands + one noise_seed: different sites must draw
    different noise (content tweak alone cannot separate them)."""
    eng = engine_for(_noisy_dpu(), "ref")
    a = eng.matmul_float(X, W, site="attn.wk")
    b = eng.matmul_float(X, W, site="attn.wv")
    c = eng.matmul_float(X, W, site="attn.wk")
    assert not np.array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(np.asarray(a), np.asarray(c))


def test_scan_stack_layers_decorrelate_via_site_folded_seeds():
    """Regression: same-shaped layers inside a lax.scan stack with
    IDENTICAL weights and inputs (content hash collision by construction)
    still draw decorrelated noise, because the layer index is folded into
    the site seed by the model stack."""
    eng = engine_for(_noisy_dpu(), "ref")
    w3 = jnp.broadcast_to(W, (3,) + W.shape)  # identical weights per layer

    def body(c, inp):
        w, idx = inp
        y = eng.matmul_float(X, w, site="ffn.wi", fold=idx)
        return c, y

    _, ys = jax.lax.scan(body, 0, (w3, jnp.arange(3)))
    noise = np.asarray(ys) - np.asarray(X @ W)
    assert not np.array_equal(noise[0], noise[1])
    assert not np.array_equal(noise[1], noise[2])

    # without the fold the three layers would collide bitwise
    def body_nofold(c, w):
        return c, eng.matmul_float(X, w, site="ffn.wi")

    _, ys0 = jax.lax.scan(body_nofold, 0, w3)
    np.testing.assert_array_equal(np.asarray(ys0[0]), np.asarray(ys0[1]))


def test_model_scan_layers_get_layer_folded_noise():
    """End-to-end regression: an LM whose scanned layers have ZERO weights
    everywhere (residual stream frozen, every layer sees identical
    operands — the content tweak cannot separate them) still decorrelates
    per-layer analog noise, because lm.py folds the scan index into the
    engine seed.  Observed through the residual stream: with N identical
    noise draws the layer contributions would add coherently; decorrelated
    draws partially cancel.  We check bit-level: two runs are reproducible,
    and a 2-layer stack differs from 2x the 1-layer contribution."""
    from repro.models import lm

    arch = registry.get("qwen2-0.5b")

    def build(num_layers):
        cfg = dataclasses.replace(
            arch.smoke_config,
            remat=False,
            num_layers=num_layers,
            photonic=_noisy_dpu(noise_seed=11),
            photonic_backend="ref",
        )
        params = init_tree(arch.param_defs(cfg), jax.random.PRNGKey(0), cfg.param_dtype)
        # zero all layer weights: every layer computes pure noise on top of
        # an unchanged residual stream -> identical operands at every layer
        params["layers"] = jax.tree.map(jnp.zeros_like, params["layers"])
        return cfg, params

    toks = jnp.asarray(RNG.integers(0, 256, (1, 8)), jnp.int32)
    cfg2, params2 = build(2)
    l2a, _ = lm.lm_logits(params2, toks, cfg2)
    l2b, _ = lm.lm_logits(params2, toks, cfg2)
    np.testing.assert_array_equal(np.asarray(l2a), np.asarray(l2b))  # determinism

    # layer 0 vs layer 1 noise: recompute each layer's additive contribution
    # directly through the engine (zero weights => output is noise only)
    eng = engine_from_model_config(cfg2)
    d = cfg2.d_model
    h = jnp.zeros((1, 8, d), jnp.float32)
    w0 = jnp.zeros((d, 2 * cfg2.d_ff), jnp.float32)
    n0 = eng.matmul_float(h, w0, site="ffn.wi", fold=0)
    n1 = eng.matmul_float(h, w0, site="ffn.wi", fold=1)
    assert not np.array_equal(np.asarray(n0), np.asarray(n1))


# ---------------------------------------------------------------------------
# Serving: prepack-at-construction + zero weight-quantization decode
# ---------------------------------------------------------------------------
def _weight_round_count(fn, *args, min_size):
    from repro.photonic.engine import count_weight_round_ops

    return count_weight_round_ops(jax.make_jaxpr(fn)(*args).jaxpr, min_size)


def test_serve_engine_prepacks_and_decode_has_zero_weight_quant_ops():
    from repro.runtime import serve

    arch = registry.get("granite-3-8b")
    cfg = dataclasses.replace(
        arch.smoke_config,
        remat=False,
        photonic=DPUConfig(organization="SMWA", bits=4, datarate_gs=5.0),
        photonic_backend="ref",
    )
    params = init_tree(arch.param_defs(cfg), jax.random.PRNGKey(0), cfg.param_dtype)
    eng = serve.Engine(arch, cfg, params, serve.ServeConfig(batch_size=2, max_seq=32))
    assert eng.photonic is not None

    def has_packed(node):
        if isinstance(node, PackedDense):
            return True
        if isinstance(node, dict):
            return any(has_packed(v) for v in node.values())
        return False

    assert has_packed(eng.params), "serve.Engine did not prepack weights"

    # decode jaxpr: zero round ops over weight-sized arrays after prepack
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 8)), jnp.int32)
    _, cache = arch.prefill(eng.params, {"tokens": toks}, cfg, 32)
    tok = toks[:, :1]
    min_w = cfg.d_model * cfg.d_ff // 2
    n_packed = _weight_round_count(
        lambda p, t, c: arch.decode(p, t, c, cfg), eng.params, tok, cache,
        min_size=min_w,
    )
    n_percall = _weight_round_count(
        lambda p, t, c: arch.decode(p, t, c, cfg), params, tok, cache,
        min_size=min_w,
    )
    assert n_packed == 0, f"{n_packed} weight-sized rounds survived prepack"
    assert n_percall > 0

    # and the engine still serves correctly
    reqs = [serve.Request(uid=0, prompt=np.arange(6, dtype=np.int32), max_new_tokens=4)]
    eng.run(reqs)
    assert len(reqs[0].output) >= 4


def test_serve_prepacked_outputs_match_per_call():
    """serve.Engine with prepacking produces the same tokens as the same
    engine forced onto the per-call-quantization path."""
    from repro.runtime import serve

    arch = registry.get("qwen2-0.5b")
    cfg = dataclasses.replace(
        arch.smoke_config,
        remat=False,
        photonic=DPUConfig(organization="SMWA", bits=4, datarate_gs=5.0),
        photonic_backend="ref",
    )
    params = init_tree(arch.param_defs(cfg), jax.random.PRNGKey(0), cfg.param_dtype)
    prompts = [np.arange(5, dtype=np.int32) + i for i in range(3)]

    def run_serve(force_per_call):
        eng = serve.Engine(
            arch, cfg, params, serve.ServeConfig(batch_size=2, max_seq=32)
        )
        if force_per_call:
            eng.params = params  # bypass the prepack done at construction
        reqs = [
            serve.Request(uid=i, prompt=pr, max_new_tokens=4)
            for i, pr in enumerate(prompts)
        ]
        eng.run(reqs)
        return [r.output for r in reqs]

    assert run_serve(False) == run_serve(True)


# ---------------------------------------------------------------------------
# Legacy API stability
# ---------------------------------------------------------------------------
def test_legacy_photonic_gemm_matches_oracle_composition():
    """photonic_gemm (compat wrapper, site=None) == quantize ∘ dpu_int_gemm
    ∘ dequantize with the legacy seed derivation — the pre-engine pipeline."""
    from repro.core.dpu import dpu_int_gemm, quantize_symmetric
    from repro.kernels.photonic_gemm.ops import photonic_gemm

    dpu = _noisy_dpu(noise_seed=9)
    y = photonic_gemm(X, W, dpu, "ref")
    xq, sx = quantize_symmetric(X, 8)
    wq, sw = quantize_symmetric(W, 8, axis=0)
    gold = (
        np.asarray(dpu_int_gemm(xq, wq, dpu), np.float32)
        * np.asarray(sx)
        * np.asarray(sw)
    )
    np.testing.assert_array_equal(np.asarray(y), gold.astype(np.float32))


def test_all_archs_smoke_with_engine_routed_photonic():
    """All ten architectures run a photonic-routed forward + decode step
    (ideal channel: engine output must match the digital int8 pipeline
    closely; attention + FFN + lm_head sites all engine-routed)."""
    rng = np.random.default_rng(0)
    for name in registry.names():
        arch = registry.get(name)
        cfg = dataclasses.replace(
            arch.smoke_config,
            remat=False,
            photonic=DPUConfig(organization="SMWA", bits=4, datarate_gs=5.0),
            photonic_backend="ref",
        )
        params = init_tree(arch.param_defs(cfg), jax.random.PRNGKey(0), cfg.param_dtype)
        B, T = 2, 8
        toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, T)), jnp.int32)
        batch = {"tokens": toks}
        if arch.family == "vlm":
            batch["vision"] = jnp.asarray(
                rng.normal(size=(B, cfg.vision_seq, cfg.d_model)), jnp.float32
            )
        if arch.family == "audio":
            batch["audio_embed"] = jnp.asarray(
                rng.normal(size=(B, 2 * T, cfg.d_model)), jnp.float32
            )
        logits, cache = arch.prefill(params, batch, cfg, T + 4)
        assert bool(jnp.all(jnp.isfinite(logits))), name
        logits, cache = arch.decode(params, toks[:, :1], cache, cfg)
        assert bool(jnp.all(jnp.isfinite(logits))), name


# ---------------------------------------------------------------------------
# Review regressions: site-name agreement, absorbed MLA, legacy tile_c
# ---------------------------------------------------------------------------
def _derived_sites(defs, path=()):
    from repro.photonic.packing import _is_dense_def, site_name

    out = set()
    if _is_dense_def(defs):
        out.add(site_name(path))
    elif isinstance(defs, dict):
        for k, v in defs.items():
            out |= _derived_sites(v, path + (k,))
    return out


@pytest.mark.parametrize("name", registry.names())
def test_prepack_site_names_agree_with_call_time_sites(name):
    """Routing must agree between prepack time (names derived from the def
    tree) and call time (names passed to dense(site=...)) for ANY policy —
    so the two name sets must be identical per architecture."""
    from repro.photonic.engine import PhotonicEngine

    arch = registry.get(name)
    cfg = dataclasses.replace(
        arch.smoke_config,
        remat=False,
        photonic=DPUConfig(organization="SMWA", bits=4, datarate_gs=5.0),
        photonic_backend="ref",
    )
    derived = _derived_sites(arch.param_defs(cfg))

    recorded = set()
    orig_float, orig_packed = PhotonicEngine.matmul_float, PhotonicEngine.matmul

    def rec_float(self, x, w, *, site=None, **kw):
        recorded.add(site)
        return orig_float(self, x, w, site=site, **kw)

    def rec_packed(self, x, packed, *, site=None, **kw):
        recorded.add(site)
        return orig_packed(self, x, packed, site=site, **kw)

    PhotonicEngine.matmul_float = rec_float
    PhotonicEngine.matmul = rec_packed
    try:
        rng = np.random.default_rng(0)
        params = init_tree(arch.param_defs(cfg), jax.random.PRNGKey(0), cfg.param_dtype)
        B, T = 1, 8
        toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, T)), jnp.int32)
        batch = {"tokens": toks, "labels": toks}
        if arch.family == "vlm":
            batch["vision"] = jnp.asarray(
                rng.normal(size=(B, cfg.vision_seq, cfg.d_model)), jnp.float32
            )
        if arch.family == "audio":
            batch["audio_embed"] = jnp.asarray(
                rng.normal(size=(B, 2 * T, cfg.d_model)), jnp.float32
            )
        arch.loss(params, batch, cfg)
        pb = {k: v for k, v in batch.items() if k != "labels"}
        _, cache = arch.prefill(params, pb, cfg, T + 2)
        arch.decode(params, toks[:, :1], cache, cfg)
    finally:
        PhotonicEngine.matmul_float = orig_float
        PhotonicEngine.matmul = orig_packed

    recorded.discard(None)
    assert recorded == derived, (
        name,
        sorted(recorded - derived),
        sorted(derived - recorded),
    )


def test_serve_prepack_preserves_absorbed_mla_decode_bitwise():
    """mla_absorb decode consumes wuk/wuv as raw floats; serve.Engine must
    leave them unpacked so prepacked decode stays bitwise-equal."""
    from repro.runtime import serve

    arch = registry.get("deepseek-v2-lite-16b")
    cfg = dataclasses.replace(
        arch.smoke_config,
        remat=False,
        mla_absorb=True,
        photonic=DPUConfig(organization="SMWA", bits=4, datarate_gs=5.0),
        photonic_backend="ref",
    )
    params = init_tree(arch.param_defs(cfg), jax.random.PRNGKey(0), cfg.param_dtype)
    eng = serve.Engine(arch, cfg, params, serve.ServeConfig(batch_size=1, max_seq=16))
    assert not isinstance(eng.params["layers"]["attn"]["wuk"]["w"], PackedDense)
    assert isinstance(eng.params["layers"]["attn"]["wq"]["w"], PackedDense)

    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (1, 8)), jnp.int32)
    _, cache_a = arch.prefill(params, {"tokens": toks}, cfg, 16)
    _, cache_b = arch.prefill(eng.params, {"tokens": toks}, cfg, 16)
    la, _ = arch.decode(params, toks[:, :1], cache_a, cfg)
    lb, _ = arch.decode(eng.params, toks[:, :1], cache_b, cfg)
    np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


def test_legacy_tile_c_parameter_honored():
    """photonic_gemm_int(tile_c=256) keeps the legacy tiling (values above
    128 are legal for the per-call pallas path)."""
    from repro.kernels.photonic_gemm.ops import photonic_gemm_int

    rng = np.random.default_rng(2)
    xq = jnp.asarray(rng.integers(-127, 128, (8, 256)), jnp.int8)
    wq = jnp.asarray(rng.integers(-127, 128, (256, 256)), jnp.int8)
    cfg = DPUConfig(organization="SMWA", bits=4, dpe_size=64)
    gold = exact_int_gemm(xq, wq)
    out = photonic_gemm_int(xq, wq, cfg, backend="pallas", tile_c=256)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(gold))
