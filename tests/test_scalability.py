"""Tests for the Eq.1-3 scalability solver against the paper's own numbers."""

import math

import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.core import organizations as orgs
from repro.core import scalability as sc
from repro.core.params import PhotonicParams


class TestPaperValidation:
    def test_table_v_reproduction(self):
        """Our calibrated solver reproduces Table V (B=4) within +-10% per cell."""
        ours = sc.table_v()
        for key, n_paper in sc.TABLE_V_N.items():
            n_ours = ours[key]
            assert abs(n_ours - n_paper) / n_paper <= 0.10, (key, n_ours, n_paper)

    def test_table_v_mean_error_small(self):
        res = sc.calibration()
        assert res.mean_abs_rel_err < 0.02

    def test_table_v_exact_cells(self):
        """At least 7 of 9 Table V cells match exactly."""
        ours = sc.table_v()
        exact = sum(ours[k] == v for k, v in sc.TABLE_V_N.items())
        assert exact >= 7, ours

    def test_fig5_ordering_smwa_best(self):
        """Fig. 5: SMWA supports the largest N at every (B, DR)."""
        tab = sc.scalability_table(sc.CALIBRATED)
        for dr in (1, 5, 10):
            for b in range(1, 9):
                asmw = tab[("ASMW", dr, b)]
                masw = tab[("MASW", dr, b)]
                smwa = tab[("SMWA", dr, b)]
                assert smwa >= masw >= asmw, (dr, b, asmw, masw, smwa)

    def test_fsr_cap(self):
        """N never exceeds the FSR-limited channel count (200)."""
        assert sc.CALIBRATED.fsr_limited_n == 200
        tab = sc.scalability_table(sc.CALIBRATED)
        assert max(tab.values()) <= 200


class TestEquations:
    def test_enob_round_trip(self):
        p = PhotonicParams()
        for b in (1, 2, 4, 6, 8):
            for dr in (1e9, 5e9, 10e9):
                p_pd = sc.pd_sensitivity_watts(b, dr, p)
                if math.isinf(p_pd):
                    continue  # RIN-limited infeasible corner
                assert sc.bits_supported(p_pd, dr, p) == pytest.approx(b, abs=1e-5)

    def test_rin_ceiling_makes_high_b_dr_infeasible(self):
        """High B at high DR is RIN-limited (empty Fig. 5 corners)."""
        p = PhotonicParams()
        assert math.isinf(sc.pd_sensitivity_watts(10, 10e9, p))
        assert sc.max_dpu_size("SMWA", 10, 10, p) == 0

    def test_sensitivity_monotone_in_bits_and_rate(self):
        p = PhotonicParams()
        s = [sc.pd_sensitivity_watts(b, 1e9, p) for b in range(1, 9)]
        assert all(a < b for a, b in zip(s, s[1:]))
        s = [sc.pd_sensitivity_watts(4, dr, p) for dr in (1e9, 5e9, 10e9)]
        assert all(a < b for a, b in zip(s, s[1:]))

    def test_output_power_decreasing_in_n(self):
        p = sc.CALIBRATED
        for org in orgs.ORGANIZATIONS:
            vals = [sc.output_power_dbm(n, n, org, p) for n in range(2, 200)]
            assert all(a > b for a, b in zip(vals, vals[1:]))

    @given(
        b=st.integers(min_value=1, max_value=10),
        dr=st.floats(min_value=0.5, max_value=20.0),
    )
    @settings(max_examples=50, deadline=None)
    def test_n_monotone_property(self, b, dr):
        """Property: N never increases when B or DR increases."""
        p = sc.CALIBRATED
        for org in orgs.ORGANIZATIONS:
            n0 = sc.max_dpu_size(org, b, dr, p)
            n_b = sc.max_dpu_size(org, b + 1, dr, p)
            n_dr = sc.max_dpu_size(org, b, dr * 1.5, p)
            assert n_b <= n0
            assert n_dr <= n0

    @given(n=st.integers(min_value=2, max_value=200))
    @settings(max_examples=50, deadline=None)
    def test_org_power_ordering(self, n):
        """SMWA always delivers more power to the PD than MASW than ASMW."""
        p = sc.CALIBRATED
        asmw = sc.output_power_dbm(n, n, "ASMW", p)
        masw = sc.output_power_dbm(n, n, "MASW", p)
        smwa = sc.output_power_dbm(n, n, "SMWA", p)
        assert smwa > masw > asmw


class TestOrganizations:
    def test_block_orders(self):
        for org, order in orgs.BLOCK_ORDERS.items():
            assert set(order) == {"S", "A", "M", "W", "Sigma"}
            assert order[-1] == "Sigma"  # summation always last
            assert order.index("M") < order.index("W")  # M before W (paper §III-A)

    def test_crosstalk_table_ii(self):
        assert orgs.CROSSTALK["ASMW"].inter_modulation
        assert not orgs.CROSSTALK["MASW"].inter_modulation
        assert not orgs.CROSSTALK["SMWA"].inter_modulation
        assert orgs.CROSSTALK["ASMW"].cross_weight
        assert orgs.CROSSTALK["MASW"].cross_weight
        assert not orgs.CROSSTALK["SMWA"].cross_weight
        assert not orgs.CROSSTALK["ASMW"].filter_truncation
        assert orgs.CROSSTALK["MASW"].filter_truncation
        assert orgs.CROSSTALK["SMWA"].filter_truncation

    def test_through_device_counts(self):
        # Paper §IV-B1: 2(N-1), N, 2 for ASMW, MASW, SMWA at N.
        assert orgs.through_device_count("ASMW", 10) == 18
        assert orgs.through_device_count("MASW", 10) == 10
        assert orgs.through_device_count("SMWA", 10) == 2

    def test_penalty_ordering(self):
        p = PhotonicParams()
        assert p.penalty_db("SMWA") < p.penalty_db("MASW") < p.penalty_db("ASMW")

    def test_structural_penalty_composition(self):
        """Structural decomposition lands near Table IV's lumped penalties."""
        p = sc.CALIBRATED
        for org in orgs.ORGANIZATIONS:
            total = sum(
                v
                for k, v in orgs.structural_penalty_db(org, 50, p).items()
                if k != "through_delta"
            )
            lumped = p.penalty_db(org)
            assert abs(total - lumped) < 2.0, (org, total, lumped)
