"""K-sharded (tensor-parallel) photonic execution — DESIGN.md §10.

Contracts under test:

* each shard's :class:`~repro.noise.ChannelModel` is built at its local
  fan-in ``N_local`` (compared against a manually constructed
  shard-local model — the acceptance assertion);
* K-sharded ideal-channel ``int_gemm`` + ``psum`` is bitwise equal to
  the unsharded engine on both the ``ref`` and ``pallas`` backends
  (property-tested via the hypothesis shim);
* noisy sharded runs are deterministic given ``noise_seed``/``prng_key``
  and decorrelated across shards;
* the runtime threading (dense / serve / dp_step) routes through the
  sharded engine and preserves the weight-stationary decode contract.

The mesh-level tests size themselves to the devices present: 1 on a bare
CPU runner (the TP paths degenerate but stay green), 8 in the CI tier
that forces ``XLA_FLAGS=--xla_force_host_platform_device_count=8``.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.dpu import DPUConfig
from repro.kernels.photonic_gemm.ref import exact_int_gemm
from repro.launch import mesh as mesh_mod
from repro.models import registry
from repro.models.common import ModelConfig, dense, init_tree
from repro.noise import ChannelModel, build_channel_model, shard_local_channel
from repro.photonic import (
    PackedDense,
    engine_for,
    prepack_params,
    shard_local_engine,
    tensor_parallel,
)
from tests._hypothesis_compat import given, settings, strategies as st

TP = mesh_mod.max_tp_degree()  # 1 on bare CPU; 8 in the multi-device CI leg

RNG = np.random.default_rng(0)
X = jnp.asarray(RNG.normal(size=(4, 128)), jnp.float32)
W = jnp.asarray(RNG.normal(size=(128, 32)), jnp.float32)


def _ideal_dpu(n=16):
    return DPUConfig(organization="SMWA", bits=4, dpe_size=n)


def _noisy_dpu(org="ASMW", n=64, noise_seed=3):
    ch = build_channel_model(org, n=n, bits=4, datarate_gs=5.0)
    return DPUConfig(
        organization=org, bits=4, dpe_size=n, channel=ch, noise_seed=noise_seed
    )


def _small_lm_cfg(arch, **kw):
    return dataclasses.replace(
        arch.smoke_config,
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        d_ff=128,
        vocab_size=64,
        remat=False,
        **kw,
    )


# ---------------------------------------------------------------------------
# Shard-local channel: N_local semantics (the acceptance assertion)
# ---------------------------------------------------------------------------
class TestShardLocalChannel:
    @pytest.mark.parametrize("org", ["ASMW", "MASW", "SMWA"])
    @pytest.mark.parametrize("shards", [2, 4, 8])
    def test_equals_manually_constructed_shard_local_model(self, org, shards):
        k = 64
        base = build_channel_model(org, n=k, bits=4, datarate_gs=5.0)
        manual = build_channel_model(org, n=k // shards, bits=4, datarate_gs=5.0)
        assert shard_local_channel(base, k // shards) == manual

    @pytest.mark.parametrize("org", ["ASMW", "MASW", "SMWA"])
    def test_engine_inside_shard_uses_n_local(self, org):
        """The channel the shard-local engine carries IS the manual
        shard-local model, and the DPU chunks at N_local."""
        k, shards = 64, 8
        eng = engine_for(_noisy_dpu(org=org, n=k), "ref")
        local = shard_local_engine(eng, k // shards)
        assert local.dpu.n == k // shards
        assert local.dpu.channel == build_channel_model(
            org, n=k // shards, bits=4, datarate_gs=5.0
        )

    def test_sharding_recovers_snr(self):
        """Fewer rings per shard => more delivered power => higher SNR
        (the physical content of N_local; benchmarks/tp_scaling.py sweeps
        this per organization)."""
        base = build_channel_model("ASMW", n=64)
        local = shard_local_channel(base, 8)
        assert local.snr_db > base.snr_db
        assert local.through_loss_db < base.through_loss_db
        assert local.detector_sigma_lsb < base.detector_sigma_lsb

    def test_disabled_stages_stay_disabled(self):
        base = build_channel_model("ASMW", n=64).disable("detector", "filter")
        local = shard_local_channel(base, 8)
        assert local.detector_sigma_lsb == 0.0
        assert local.filter_alpha == 0.0
        # non-disabled, n-independent couplings carry over unchanged
        assert local.intermod_eps == base.intermod_eps

    def test_custom_sigma_override_survives_resharding(self):
        """A caller-replaced detector sigma (noise-margin ablation) is an
        override, not a derived value — resharding must not quietly swap
        it back to the paper number."""
        import dataclasses as dc

        base = build_channel_model("ASMW", n=64)
        tweaked = dc.replace(base, detector_sigma_lsb=123.5)
        local = shard_local_channel(tweaked, 8)
        assert local.n == 8
        assert local.detector_sigma_lsb == 123.5

    def test_hand_built_channel_keeps_magnitudes(self):
        base = ChannelModel(n=32, detector_sigma_lsb=0.5, filter_alpha=0.01)
        local = shard_local_channel(base, 4)
        assert local.n == 4
        assert local.detector_sigma_lsb == 0.5
        assert local.filter_alpha == 0.01

    def test_noop_when_local_fanin_not_smaller(self):
        base = build_channel_model("SMWA", n=16)
        assert shard_local_channel(base, 16) is base
        assert shard_local_channel(base, 64) is base

    def test_dpu_shard_local_clamps_chunking(self):
        dpu = _noisy_dpu(n=64)
        local = dpu.shard_local(8)
        assert local.n == 8
        assert local.channel.n == 8
        # ideal configs only clamp the (numerically inert) chunk size
        ideal = _ideal_dpu(n=64).shard_local(8)
        assert ideal.n == 8 and ideal.channel is None


# ---------------------------------------------------------------------------
# Property: K-sharded ideal int_gemm + psum == unsharded, both backends
# ---------------------------------------------------------------------------
@settings(max_examples=8, deadline=None)
@given(
    r=st.integers(min_value=1, max_value=5),
    k_base=st.integers(min_value=1, max_value=6),
    c=st.integers(min_value=1, max_value=33),
    shards=st.sampled_from([2, 4, 8]),
    seed=st.integers(min_value=0, max_value=2**16),
)
def test_property_sharded_ideal_bitwise_equals_unsharded(r, k_base, c, shards, seed):
    """sum_i shard_i(int_gemm) == unsharded int_gemm == exact, bitwise,
    on both backends: int32 psums are associative and the shard-local
    engine only re-chunks an ideal channel (numerically inert without
    ADC/noise)."""
    k = shards * k_base * 2
    rng = np.random.default_rng(seed)
    xq = jnp.asarray(rng.integers(-127, 128, (r, k)), jnp.int8)
    wq = jnp.asarray(rng.integers(-127, 128, (k, c)), jnp.int8)
    k_local = k // shards
    for backend in ("ref", "pallas"):
        eng = engine_for(_ideal_dpu(n=8), backend)
        full = np.asarray(eng.int_gemm(xq, wq))
        parts = np.zeros_like(full)
        for i in range(shards):
            local = shard_local_engine(eng, k_local)
            blk = local.int_gemm(
                xq[:, i * k_local : (i + 1) * k_local],
                wq[i * k_local : (i + 1) * k_local],
                shard=jnp.int32(i),
            )
            parts = parts + np.asarray(blk)
        np.testing.assert_array_equal(parts, full, err_msg=backend)
        np.testing.assert_array_equal(
            full, np.asarray(exact_int_gemm(xq, wq)), err_msg=backend
        )


# ---------------------------------------------------------------------------
# shard_map path through dense(): bitwise under ideal channels
# ---------------------------------------------------------------------------
class TestTensorParallelDense:
    @pytest.mark.parametrize("backend", ["ref", "pallas", "exact"])
    def test_float_path_bitwise_ideal(self, backend):
        mesh = mesh_mod.make_tp_smoke_mesh()
        cfg = ModelConfig(photonic=_ideal_dpu(), photonic_backend=backend)
        base = dense({"w": W}, X, cfg, site="attn.wq")
        with tensor_parallel(mesh, "model"):
            eager = dense({"w": W}, X, cfg, site="attn.wq")
            jitted = jax.jit(lambda x: dense({"w": W}, x, cfg, site="attn.wq"))(X)
        np.testing.assert_array_equal(np.asarray(base), np.asarray(eager))
        np.testing.assert_array_equal(np.asarray(base), np.asarray(jitted))

    @pytest.mark.parametrize("backend", ["ref", "pallas"])
    def test_packed_path_bitwise_ideal(self, backend):
        mesh = mesh_mod.make_tp_smoke_mesh()
        dpu = _ideal_dpu()
        cfg = ModelConfig(photonic=dpu, photonic_backend=backend)
        eng = engine_for(dpu, backend)
        defs = {"attn": {"wq": {"w": W}}}
        params = {"attn": {"wq": {"w": W}}}
        plain = prepack_params(params, defs, eng)["attn"]["wq"]
        shard = prepack_params(params, defs, eng, mesh=mesh)["attn"]["wq"]
        base = dense(plain, X, cfg, site="attn.wq")
        with tensor_parallel(mesh, "model"):
            y = dense(shard, X, cfg, site="attn.wq")
        np.testing.assert_array_equal(np.asarray(base), np.asarray(y))

    def test_sharded_prepack_reuses_global_scales(self):
        mesh = mesh_mod.make_tp_smoke_mesh()
        eng = engine_for(_ideal_dpu(), "pallas")
        defs = {"proj": {"w": W}}
        plain = prepack_params({"proj": {"w": W}}, defs, eng)["proj"]["w"]
        shard = prepack_params({"proj": {"w": W}}, defs, eng, mesh=mesh)["proj"]["w"]
        np.testing.assert_array_equal(
            np.asarray(plain.w_scale), np.asarray(shard.w_scale)
        )
        np.testing.assert_array_equal(
            np.asarray(plain.dequant()), np.asarray(shard.dequant())
        )
        assert shard.shards == TP and shard.k == W.shape[0]

    @pytest.mark.skipif(TP < 4, reason="needs a data x model host mesh")
    def test_dp_plus_tp_mesh_keeps_bit_identity_and_row_sharding(self):
        """On a (data=2, model=TP/2) mesh the GSPMD path shards rows over
        the data axis (no batch replication into TP groups) and stays
        bitwise equal to the unsharded engine under an ideal channel."""
        mesh = mesh_mod.build_mesh((2, TP // 2), ("data", "model"))
        cfg = ModelConfig(photonic=_ideal_dpu(), photonic_backend="ref")
        base = dense({"w": W}, X, cfg, site="attn.wq")
        with tensor_parallel(mesh, "model"):
            y = dense({"w": W}, X, cfg, site="attn.wq")
            yj = jax.jit(lambda x: dense({"w": W}, x, cfg, site="attn.wq"))(X)
        np.testing.assert_array_equal(np.asarray(base), np.asarray(y))
        np.testing.assert_array_equal(np.asarray(base), np.asarray(yj))

    def test_grad_is_dense_ste(self):
        mesh = mesh_mod.make_tp_smoke_mesh()
        cfg = ModelConfig(photonic=_ideal_dpu(), photonic_backend="ref")

        def loss(w):
            with tensor_parallel(mesh, "model"):
                return jnp.sum(dense({"w": w}, X, cfg, site="attn.wq") ** 2)

        def loss_base(w):
            return jnp.sum(dense({"w": w}, X, cfg, site="attn.wq") ** 2)

        g = jax.grad(loss)(W)
        g0 = jax.grad(loss_base)(W)
        np.testing.assert_array_equal(np.asarray(g), np.asarray(g0))

    def test_indivisible_k_falls_back_bitwise(self):
        mesh = mesh_mod.make_tp_smoke_mesh()
        w_odd = W[:77, :]
        x_odd = X[:, :77]
        cfg = ModelConfig(photonic=_ideal_dpu(), photonic_backend="ref")
        base = dense({"w": w_odd}, x_odd, cfg, site="attn.wq")
        with tensor_parallel(mesh, "model"):
            y = dense({"w": w_odd}, x_odd, cfg, site="attn.wq")
        np.testing.assert_array_equal(np.asarray(base), np.asarray(y))

    def test_non_routed_site_stays_digital(self):
        mesh = mesh_mod.make_tp_smoke_mesh()
        cfg = ModelConfig(photonic=_ideal_dpu(), photonic_backend="ref")
        with tensor_parallel(mesh, "model"):
            y = dense({"w": W}, X, cfg, site="ffn.router")
        np.testing.assert_array_equal(np.asarray(y), np.asarray(X @ W))

    def test_bad_axis_raises(self):
        mesh = mesh_mod.make_tp_smoke_mesh()
        with pytest.raises(ValueError, match="no axis"):
            with tensor_parallel(mesh, "nonexistent"):
                pass


# ---------------------------------------------------------------------------
# Noise: deterministic per source, decorrelated across shards
# ---------------------------------------------------------------------------
class TestShardedNoise:
    def test_noise_seed_deterministic(self):
        mesh = mesh_mod.make_tp_smoke_mesh()
        cfg = ModelConfig(photonic=_noisy_dpu(), photonic_backend="ref")
        with tensor_parallel(mesh, "model"):
            y1 = dense({"w": W}, X, cfg, site="attn.wq")
            y2 = dense({"w": W}, X, cfg, site="attn.wq")
        np.testing.assert_array_equal(np.asarray(y1), np.asarray(y2))

    def test_prng_key_deterministic_and_key_sensitive(self):
        mesh = mesh_mod.make_tp_smoke_mesh()
        cfg = ModelConfig(photonic=_noisy_dpu(), photonic_backend="ref")
        k1, k2 = jax.random.PRNGKey(1), jax.random.PRNGKey(2)
        with tensor_parallel(mesh, "model"):
            a = dense({"w": W}, X, cfg, site="attn.wq", prng_key=k1)
            b = dense({"w": W}, X, cfg, site="attn.wq", prng_key=k1)
            c = dense({"w": W}, X, cfg, site="attn.wq", prng_key=k2)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert not np.array_equal(np.asarray(a), np.asarray(c))

    def test_shards_draw_decorrelated_noise(self):
        """Two shards given identical operand blocks draw different noise
        (the shard index is folded into the stream), while one shard is
        bitwise reproducible — no devices needed."""
        eng = engine_for(_noisy_dpu(n=16), "ref")
        local = shard_local_engine(eng, 16)
        xq = jnp.asarray(RNG.integers(-127, 128, (4, 16)), jnp.int8)
        wq = jnp.asarray(RNG.integers(-127, 128, (16, 8)), jnp.int8)
        s0 = np.asarray(local.int_gemm(xq, wq, shard=jnp.int32(0)))
        s0b = np.asarray(local.int_gemm(xq, wq, shard=jnp.int32(0)))
        s1 = np.asarray(local.int_gemm(xq, wq, shard=jnp.int32(1)))
        np.testing.assert_array_equal(s0, s0b)
        assert not np.array_equal(s0, s1)

    def test_shard_stream_distinct_from_layer_fold(self):
        """(site, fold=i) and (site, shard=i) must be different streams."""
        eng = engine_for(_noisy_dpu(n=16), "ref")
        xq = jnp.asarray(RNG.integers(-127, 128, (4, 16)), jnp.int8)
        wq = jnp.asarray(RNG.integers(-127, 128, (16, 8)), jnp.int8)
        a = eng.stream_seed("s", jnp.int32(3), None, xq, wq)
        b = eng.stream_seed("s", None, None, xq, wq, shard=jnp.int32(3))
        assert int(a) != int(b)

    @pytest.mark.skipif(TP < 2, reason="needs a real multi-device mesh")
    def test_sharded_noise_differs_from_unsharded(self):
        """With real shards the (N_local channel, shard-folded seed) run
        must not reproduce the unsharded noise draw."""
        mesh = mesh_mod.make_tp_smoke_mesh()
        cfg = ModelConfig(photonic=_noisy_dpu(n=64), photonic_backend="ref")
        base = dense({"w": W}, X, cfg, site="attn.wq")
        with tensor_parallel(mesh, "model"):
            y = dense({"w": W}, X, cfg, site="attn.wq")
        assert not np.array_equal(np.asarray(base), np.asarray(y))


# ---------------------------------------------------------------------------
# Runtime threading: serve + dp_step
# ---------------------------------------------------------------------------
class TestRuntimeThreading:
    def test_serve_tp_prepacks_sharded_and_decode_stays_zero_quant(self):
        from repro.photonic.engine import count_weight_round_ops
        from repro.runtime import serve

        mesh = mesh_mod.make_tp_smoke_mesh()
        arch = registry.get("qwen2-0.5b")
        cfg = _small_lm_cfg(
            arch,
            photonic=_noisy_dpu(n=16, noise_seed=11),
            photonic_backend="ref",
        )
        params = init_tree(arch.param_defs(cfg), jax.random.PRNGKey(0), cfg.param_dtype)
        eng = serve.Engine(
            arch,
            cfg,
            params,
            serve.ServeConfig(batch_size=2, max_seq=32),
            mesh=mesh,
            tp_axis="model",
        )

        packs = [
            leaf
            for leaf in jax.tree.leaves(
                eng.params, is_leaf=lambda x: isinstance(x, PackedDense)
            )
            if isinstance(leaf, PackedDense)
        ]
        assert packs, "serve.Engine did not prepack weights"
        if TP > 1:
            assert {p.shards for p in packs} == {TP}

        # decode jaxpr (traced under the TP scope, shard_map included):
        # zero round ops over weight-sized arrays — the weight-stationary
        # contract survives sharding.
        rng = np.random.default_rng(0)
        toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 8)), jnp.int32)
        with eng._tp_scope():
            _, cache = arch.prefill(eng.params, {"tokens": toks}, cfg, 32)
            jaxpr = jax.make_jaxpr(
                lambda p, t, c: arch.decode(p, t, c, cfg)
            )(eng.params, toks[:, :1], cache)
        min_w = cfg.d_model * cfg.d_ff // 2
        assert count_weight_round_ops(jaxpr.jaxpr, min_w) == 0

        reqs = [
            serve.Request(
                uid=0, prompt=np.arange(6, dtype=np.int32), max_new_tokens=4
            )
        ]
        eng.run(reqs)
        assert len(reqs[0].output) >= 4

    def test_dp_step_with_tp_axis_matches_plain_loss(self):
        from repro.optim import adamw
        from repro.runtime.dp_step import make_dp_train_step

        mesh = mesh_mod.make_tp_smoke_mesh()
        arch = registry.get("qwen2-0.5b")
        cfg = _small_lm_cfg(arch, photonic=_ideal_dpu(), photonic_backend="ref")
        params = init_tree(arch.param_defs(cfg), jax.random.PRNGKey(0), cfg.param_dtype)
        loss_fn = lambda p, b: arch.loss(p, b, cfg)  # noqa: E731
        batch = {
            "tokens": jnp.arange(8 * 16, dtype=jnp.int32).reshape(8, 16)
            % cfg.vocab_size,
            "labels": jnp.arange(8 * 16, dtype=jnp.int32).reshape(8, 16)
            % cfg.vocab_size,
        }
        opt_cfg = adamw.AdamWConfig(total_steps=2)
        step = make_dp_train_step(loss_fn, opt_cfg, mesh, tp_axis="model")
        _, _, loss, gnorm = jax.jit(step)(params, adamw.init(params), batch)
        plain = jax.jit(loss_fn)(params, batch)
        # the TP GEMMs are bitwise; the surrounding softmax/norm reductions
        # compile into different fusions, so compare at float tolerance
        np.testing.assert_allclose(float(loss), float(plain), rtol=1e-5, atol=0)
        assert np.isfinite(float(gnorm))

    def test_dp_step_rejects_unknown_tp_axis(self):
        from repro.optim import adamw
        from repro.runtime.dp_step import make_dp_train_step

        mesh = mesh_mod.make_tp_smoke_mesh()
        with pytest.raises(ValueError, match="tp_axis"):
            make_dp_train_step(
                lambda p, b: 0.0,
                adamw.AdamWConfig(total_steps=1),
                mesh,
                tp_axis="nope",
            )
