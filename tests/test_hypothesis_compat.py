"""Tests for the hypothesis fallback shim itself.

The fallback branch of tests/_hypothesis_compat.py only runs where
hypothesis is absent, so CI (which installs the ``dev`` extra) would never
execute it. Here we force-load the module with hypothesis masked so the
fallback is exercised on every environment.
"""

import importlib.util
import sys
from pathlib import Path

import pytest

_SHIM_PATH = Path(__file__).with_name("_hypothesis_compat.py")


@pytest.fixture()
def shim():
    """The shim module imported with hypothesis guaranteed-absent."""
    saved = {
        k: sys.modules.get(k) for k in list(sys.modules) if k.startswith("hypothesis")
    }
    for k in saved:
        del sys.modules[k]
    # None in sys.modules makes `import hypothesis` raise ImportError.
    sys.modules["hypothesis"] = None
    try:
        spec = importlib.util.spec_from_file_location(
            "_hypothesis_compat_forced_fallback", _SHIM_PATH
        )
        mod = importlib.util.module_from_spec(spec)
        spec.loader.exec_module(mod)
        yield mod
    finally:
        del sys.modules["hypothesis"]
        sys.modules.update(saved)
        sys.modules.pop("_hypothesis_compat_forced_fallback", None)


def test_fallback_branch_selected(shim):
    assert shim.HAVE_HYPOTHESIS is False


@pytest.mark.parametrize("settings_on_top", [True, False])
def test_max_examples_honored_in_either_decorator_order(shim, settings_on_top):
    st = shim.strategies
    calls = []

    def prop(n):
        calls.append(n)

    if settings_on_top:
        wrapped = shim.settings(max_examples=7)(shim.given(n=st.integers(0, 9))(prop))
    else:
        wrapped = shim.given(n=st.integers(0, 9))(shim.settings(max_examples=7)(prop))
    wrapped()
    assert len(calls) == 7
    assert all(0 <= n <= 9 for n in calls)


def test_strategies_respect_bounds_and_kwarg_spelling(shim):
    st = shim.strategies
    seen = {"ints": [], "floats": [], "sampled": []}

    @shim.given(
        a=st.integers(min_value=3, max_value=5),
        b=st.floats(min_value=0.5, max_value=2.0),
        c=st.sampled_from([10, 20]),
    )
    @shim.settings(max_examples=25, deadline=None)
    def prop(a, b, c):
        seen["ints"].append(a)
        seen["floats"].append(b)
        seen["sampled"].append(c)

    prop()
    assert all(3 <= a <= 5 for a in seen["ints"])
    assert all(0.5 <= b <= 2.0 for b in seen["floats"])
    assert set(seen["sampled"]) <= {10, 20}


def test_failure_surfaces_the_drawn_example(shim):
    @shim.given(n=shim.strategies.integers(0, 100))
    @shim.settings(max_examples=5)
    def prop(n):
        assert n > 100  # impossible

    with pytest.raises(AssertionError, match="failed on example 0"):
        prop()


def test_draws_are_deterministic_across_runs(shim):
    runs = []
    for _ in range(2):
        drawn = []

        @shim.given(n=shim.strategies.integers(0, 10**9))
        @shim.settings(max_examples=10)
        def prop(n):
            drawn.append(n)

        prop()
        runs.append(drawn)
    assert runs[0] == runs[1]


def test_methods_receive_self(shim):
    class Holder:
        hits = 0

        @shim.given(n=shim.strategies.integers(0, 1))
        @shim.settings(max_examples=3)
        def prop(self, n):
            type(self).hits += 1

    Holder().prop()
    assert Holder.hits == 3
