"""repro.analysis — Level-1 rule fixtures, the clean-tree bar, and the
Level-2 contract passes (DESIGN.md §12).

Every RPR rule gets a violating fixture snippet proving it fires (ID +
location), plus a clean twin proving the blessed idiom passes. The
clean-tree test is the acceptance criterion itself: zero findings over
the repo with zero suppressions under ``src/``.
"""

import json
import os
import re
import subprocess
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import compat
from repro.analysis import (
    ContractChecker,
    all_rules,
    check_source,
    count_primitives,
    count_weight_round_ops,
    run_all,
)
from repro.analysis.contracts import ContractViolation, iter_eqns
from repro.compat import Mesh, PartitionSpec as P
from repro.core.dpu import DPUConfig
from repro.noise import build_channel_model
from repro.photonic import engine_for
from repro.photonic import sharded as tp_sharded

ROOT = Path(__file__).resolve().parents[1]

RNG = np.random.default_rng(0)


def rule_ids(findings):
    return [f.rule for f in findings]


# ---------------------------------------------------------------------------
# Registry sanity
# ---------------------------------------------------------------------------
def test_rule_registry_complete():
    rules = all_rules()
    ids = [r.id for r in rules]
    assert ids == [
        "RPR001", "RPR002", "RPR003", "RPR004", "RPR005", "RPR006", "RPR007",
        "RPR008", "RPR009", "RPR010",
    ]
    for r in rules:
        assert r.summary and r.rationale, f"{r.id} lacks docs"


# ---------------------------------------------------------------------------
# RPR001 — compat isolation
# ---------------------------------------------------------------------------
class TestRPR001:
    def test_attribute_path_fires(self):
        src = 'import jax\n\nmesh = jax.make_mesh((1,), ("d",))\n'
        f = check_source(src, "src/repro/foo.py")
        assert rule_ids(f) == ["RPR001"]
        assert f[0].line == 3

    def test_from_import_fires(self):
        src = "from jax.experimental.shard_map import shard_map\n"
        f = check_source(src, "src/repro/foo.py")
        assert rule_ids(f) == ["RPR001"]
        assert f[0].line == 1

    def test_name_from_jax_module_fires(self):
        src = "from jax.sharding import AxisType\n"
        assert rule_ids(check_source(src, "src/repro/foo.py")) == ["RPR001"]

    def test_check_rep_kwarg_fires(self):
        src = (
            "from repro import compat\n\n"
            "f = compat.shard_map(g, mesh=m, in_specs=s, out_specs=o, "
            "check_rep=False)\n"
        )
        f = check_source(src, "src/repro/foo.py")
        assert rule_ids(f) == ["RPR001"]

    def test_cost_analysis_method_fires_but_wrapper_is_clean(self):
        bad = "ca = compiled.cost_analysis()\n"
        assert rule_ids(check_source(bad, "src/repro/foo.py")) == ["RPR001"]
        good = "from repro import compat\n\nca = compat.cost_analysis(compiled)\n"
        assert check_source(good, "src/repro/foo.py") == []

    def test_compat_module_and_its_tests_exempt(self):
        src = "from jax.experimental.shard_map import shard_map\n"
        assert check_source(src, "src/repro/compat.py") == []
        assert check_source(src, "tests/test_compat.py") == []


# ---------------------------------------------------------------------------
# RPR002 — single-point org resolution
# ---------------------------------------------------------------------------
class TestRPR002:
    def test_upper_on_org_fires(self):
        src = "def f(org):\n    return org.strip().upper()\n"
        f = check_source(src, "src/repro/foo.py")
        assert rule_ids(f) == ["RPR002"]
        assert f[0].line == 2

    def test_lower_on_organization_attr_fires(self):
        src = "def f(cfg):\n    return cfg.organization.lower()\n"
        assert rule_ids(check_source(src, "src/repro/foo.py")) == ["RPR002"]

    def test_non_org_receiver_clean(self):
        src = "def f(s):\n    return s.upper()\n"
        assert check_source(src, "src/repro/foo.py") == []

    def test_orgs_module_exempt(self):
        src = "def f(order):\n    return order.strip().upper()\n"
        assert check_source(src, "src/repro/orgs.py") == []


# ---------------------------------------------------------------------------
# RPR003 — engine-only GEMM routing in models/runtime
# ---------------------------------------------------------------------------
class TestRPR003:
    def test_kernel_import_fires_in_models(self):
        src = "from repro.kernels.photonic_gemm.ops import photonic_gemm\n"
        f = check_source(src, "src/repro/models/foo.py")
        assert rule_ids(f) == ["RPR003"]
        assert f[0].line == 1

    def test_backend_call_fires_in_runtime(self):
        src = "def step(a, b):\n    return exact_int_gemm(a, b)\n"
        f = check_source(src, "src/repro/runtime/foo.py")
        assert rule_ids(f) == ["RPR003"]
        assert f[0].line == 2

    def test_photonic_and_kernels_zones_exempt(self):
        src = "def step(a, b):\n    return exact_int_gemm(a, b)\n"
        assert check_source(src, "src/repro/photonic/foo.py") == []
        assert check_source(src, "src/repro/kernels/foo.py") == []

    def test_engine_route_clean(self):
        src = (
            "def step(eng, x, packed):\n"
            '    return eng.matmul(x, packed, site="ffn.wi")\n'
        )
        assert check_source(src, "src/repro/models/foo.py") == []


# ---------------------------------------------------------------------------
# RPR004 — engine-derived randomness in models/kernels
# ---------------------------------------------------------------------------
class TestRPR004:
    def test_sampler_fires_in_models(self):
        src = (
            "import jax\n\n"
            "def forward(key, x):\n"
            "    return x + jax.random.normal(key, x.shape)\n"
        )
        f = check_source(src, "src/repro/models/foo.py")
        assert rule_ids(f) == ["RPR004"]
        assert f[0].line == 4

    def test_init_functions_exempt(self):
        src = (
            "import jax\n\n"
            "def init_weights(key):\n"
            "    return jax.random.normal(key, (4, 4))\n"
        )
        assert check_source(src, "src/repro/models/foo.py") == []

    def test_key_plumbing_clean(self):
        src = (
            "import jax\n\n"
            "def forward(key, i):\n"
            "    return jax.random.fold_in(key, i)\n"
        )
        assert check_source(src, "src/repro/models/foo.py") == []

    def test_out_of_scope_paths_clean(self):
        src = (
            "import jax\n\n"
            "def sample(key, logits):\n"
            "    return jax.random.categorical(key, logits)\n"
        )
        assert check_source(src, "src/repro/runtime/foo.py") == []


# ---------------------------------------------------------------------------
# RPR005 — reciprocal-multiply quantization
# ---------------------------------------------------------------------------
class TestRPR005:
    def test_constant_divisor_fires_once(self):
        src = (
            "import jax.numpy as jnp\n\n"
            "def _quantize(x, amax):\n"
            "    scale = jnp.maximum(amax, 1e-12) / 127.0\n"
            "    return jnp.round(x / scale)\n"
        )
        f = check_source(src, "src/repro/foo.py")
        assert rule_ids(f) == ["RPR005"]
        assert f[0].line == 4  # x / scale (traced divisor) must NOT flag

    def test_const_expression_divisor_fires(self):
        src = (
            "def quantize(amax):\n"
            "    return amax / float(2 ** 7 - 1)\n"
        )
        assert rule_ids(check_source(src, "src/repro/foo.py")) == ["RPR005"]

    def test_reciprocal_multiply_clean(self):
        src = (
            "import jax.numpy as jnp\n\n"
            "def _quantize(x, amax):\n"
            "    scale = jnp.maximum(amax, 1e-12) * (1.0 / 127.0)\n"
            "    return jnp.round(x / scale)\n"
        )
        assert check_source(src, "src/repro/foo.py") == []

    def test_non_quant_function_out_of_scope(self):
        src = "def halve(x):\n    return x / 2.0\n"
        assert check_source(src, "src/repro/foo.py") == []


# ---------------------------------------------------------------------------
# RPR006 — no tensor_parallel under shard_map
# ---------------------------------------------------------------------------
class TestRPR006:
    def test_named_body_fires(self):
        src = (
            "from repro import compat\n"
            "from repro.photonic.sharded import tensor_parallel\n\n"
            "def body(x):\n"
            '    with tensor_parallel(mesh, "tp"):\n'
            "        return x\n\n"
            "def run(mesh, x, spec):\n"
            "    return compat.shard_map(\n"
            "        body, mesh=mesh, in_specs=(spec,), out_specs=spec\n"
            "    )(x)\n"
        )
        f = check_source(src, "src/repro/foo.py")
        assert rule_ids(f) == ["RPR006"]
        assert f[0].line == 5

    def test_lambda_body_fires(self):
        src = (
            "import repro.photonic.sharded as tp\n\n"
            "out = compat.shard_map(\n"
            '    lambda x: tp.tensor_parallel(mesh, "tp"), mesh=mesh,\n'
            "    in_specs=(spec,), out_specs=spec,\n"
            ")(x)\n"
        )
        assert rule_ids(check_source(src, "src/repro/foo.py")) == ["RPR006"]

    def test_manual_tp_inside_body_clean(self):
        src = (
            "from repro import compat\n"
            "from repro.photonic.sharded import manual_tp\n\n"
            "def body(x):\n"
            '    with manual_tp("tp"):\n'
            "        return x\n\n"
            "def run(mesh, x, spec):\n"
            "    return compat.shard_map(\n"
            "        body, mesh=mesh, in_specs=(spec,), out_specs=spec\n"
            "    )(x)\n"
        )
        assert check_source(src, "src/repro/foo.py") == []

    def test_tensor_parallel_outside_body_clean(self):
        src = (
            "from repro.photonic.sharded import tensor_parallel\n\n"
            "def run(mesh, x):\n"
            '    with tensor_parallel(mesh, "tp"):\n'
            "        return go(x)\n"
        )
        assert check_source(src, "src/repro/foo.py") == []


# ---------------------------------------------------------------------------
# RPR007 — paged KV memory only through the kv_cache API
# ---------------------------------------------------------------------------
class TestRPR007:
    def test_pool_subscript_fires_in_models(self):
        src = "def read(kv_pool, blocks):\n    return kv_pool[blocks]\n"
        f = check_source(src, "src/repro/models/foo.py")
        assert rule_ids(f) == ["RPR007"]
        assert f[0].line == 2

    def test_block_table_indexing_fires_in_runtime(self):
        src = "def dest(block_table, p, bs):\n    return block_table[p // bs]\n"
        f = check_source(src, "src/repro/runtime/foo.py")
        assert rule_ids(f) == ["RPR007"]

    def test_at_update_fires(self):
        src = (
            "def write(kv_pool, b, o, rows):\n"
            "    return kv_pool.at[b, o].set(rows)\n"
        )
        assert rule_ids(check_source(src, "src/repro/models/foo.py")) == ["RPR007"]

    def test_api_calls_and_axis_insertion_clean(self):
        src = (
            "from repro.serving import kv_cache as kvc\n\n"
            "def read(kv_pool, block_table, n):\n"
            "    return kvc.gather_kv(kv_pool, block_table[None], n)\n"
        )
        assert check_source(src, "src/repro/models/foo.py") == []

    def test_kv_cache_and_serving_zone_exempt(self):
        src = "def read(kv_pool, blocks):\n    return kv_pool[blocks]\n"
        assert check_source(src, "src/repro/serving/kv_cache.py") == []
        assert check_source(src, "src/repro/serving/foo.py") == []


# ---------------------------------------------------------------------------
# RPR008 — engine GEMM outputs take no post-GEMM scale/bias shoulders
# ---------------------------------------------------------------------------
class TestRPR008:
    def test_scale_on_tracked_output_fires(self):
        src = (
            "def f(eng, x, pk, sx, ws):\n"
            "    y = eng.matmul(x, pk, site='attn.wq')\n"
            "    return y * sx * ws\n"
        )
        f = check_source(src, "src/repro/models/foo.py")
        assert rule_ids(f) == ["RPR008"]
        assert f[0].line == 3

    def test_bias_add_on_call_result_fires(self):
        src = (
            "def f(eng, x, w, b):\n"
            "    return eng.matmul_float(x, w, site='ffn.wi') + b\n"
        )
        f = check_source(src, "src/repro/models/foo.py")
        assert rule_ids(f) == ["RPR008"]
        assert f[0].line == 2

    def test_augassign_fires(self):
        src = (
            "def f(eng, x, pk, b):\n"
            "    y = eng.matmul(x, pk, site='s')\n"
            "    y += b\n"
            "    return y\n"
        )
        f = check_source(src, "src/repro/models/foo.py")
        assert rule_ids(f) == ["RPR008"]
        assert f[0].line == 3

    def test_epilogue_kwargs_clean(self):
        src = (
            "def f(eng, x, pk, b):\n"
            "    return eng.matmul(x, pk, site='s', bias=b, "
            "activation='gelu')\n"
        )
        assert check_source(src, "src/repro/models/foo.py") == []

    def test_dense_output_gating_clean(self):
        # SwiGLU gating and residual adds act on dense() results, which
        # are epilogue-complete already — the rule must not track them.
        src = (
            "def f(params, x, cfg):\n"
            "    u = dense(params['wi'], x, cfg, site='ffn.wi')\n"
            "    g = dense(params['wg'], x, cfg, site='ffn.wg')\n"
            "    return x + u * jax.nn.silu(g)\n"
        )
        assert check_source(src, "src/repro/models/foo.py") == []

    def test_reassignment_untracks(self):
        src = (
            "def f(eng, x, pk, b):\n"
            "    y = eng.matmul(x, pk, site='s')\n"
            "    y = jnp.reshape(y, (-1,))\n"
            "    return y + b\n"
        )
        assert check_source(src, "src/repro/models/foo.py") == []

    def test_digital_matmul_receivers_clean(self):
        src = (
            "def f(x, w, b):\n"
            "    y = jnp.matmul(x, w)\n"
            "    return y + b\n"
        )
        assert check_source(src, "src/repro/models/foo.py") == []

    def test_out_of_models_zone_clean(self):
        src = (
            "def f(eng, x, pk, sx):\n"
            "    return eng.matmul(x, pk, site='s') * sx\n"
        )
        assert check_source(src, "src/repro/photonic/foo.py") == []
        assert check_source(src, "benchmarks/foo.py") == []


# ---------------------------------------------------------------------------
# RPR009 — single-point platform resolution (mirror of RPR002)
# ---------------------------------------------------------------------------
class TestRPR009:
    def test_upper_on_platform_fires(self):
        src = "def f(platform):\n    return platform.strip().upper()\n"
        f = check_source(src, "src/repro/foo.py")
        assert rule_ids(f) == ["RPR009"]
        assert f[0].line == 2

    def test_lower_on_material_attr_fires(self):
        src = "def f(cfg):\n    return cfg.material.lower()\n"
        assert rule_ids(check_source(src, "src/repro/foo.py")) == ["RPR009"]

    def test_resolve_route_clean(self):
        # The clean twin of the violating fixture: same normalization
        # need, routed through THE resolution point.
        src = (
            "from repro import platforms\n\n"
            "def f(platform):\n"
            "    return platforms.resolve(platform).name\n"
        )
        assert check_source(src, "src/repro/foo.py") == []

    def test_non_platform_receiver_clean(self):
        src = "def f(s):\n    return s.upper()\n"
        assert check_source(src, "src/repro/foo.py") == []

    def test_platforms_module_exempt(self):
        src = "def f(platform):\n    return platform.strip().upper()\n"
        # _normalize_platform itself lives here — the one blessed site.
        assert check_source(src, "src/repro/platforms.py") == []


# ---------------------------------------------------------------------------
# RPR010 — timing/FPS aggregation routes through the mapper timeline
# ---------------------------------------------------------------------------
class TestRPR010:
    def test_sum_over_time_s_fires(self):
        src = (
            "def makespan(layers):\n"
            "    return sum(l.time_s for l in layers)\n"
        )
        f = check_source(src, "src/repro/foo.py")
        assert rule_ids(f) == ["RPR010"]
        assert f[0].line == 2

    def test_binop_on_makespan_fires(self):
        src = "def fps(t):\n    return 1.0 / t.makespan_s\n"
        assert rule_ids(check_source(src, "src/repro/foo.py")) == ["RPR010"]

    def test_augassign_energy_fires(self):
        src = (
            "def total(nodes):\n"
            "    e = 0.0\n"
            "    for n in nodes:\n"
            "        e += n.energy_j\n"
            "    return e\n"
        )
        assert rule_ids(check_source(src, "src/repro/foo.py")) == ["RPR010"]

    def test_benchmark_scope_fires(self):
        src = "def ms(t):\n    return t.makespan_s * 1e3\n"
        assert rule_ids(check_source(src, "benchmarks/foo.py")) == ["RPR010"]

    def test_timeline_metrics_clean(self):
        # The clean twin: same numbers, read from the blessed surface.
        src = (
            "def report(timeline):\n"
            "    d = timeline.to_dict()\n"
            "    return timeline.fps_per_w, d['makespan_s'] * 1e3\n"
        )
        assert check_source(src, "src/repro/foo.py") == []

    def test_plain_read_and_store_clean(self):
        src = (
            "def record(ns):\n"
            "    return {'time_s': ns.time_s, 'energy_j': ns.energy_j}\n"
        )
        assert check_source(src, "src/repro/foo.py") == []

    def test_mapper_and_simulator_exempt(self):
        src = "def makespan(ls):\n    return sum(l.time_s for l in ls)\n"
        assert check_source(src, "src/repro/mapper/timeline.py") == []
        assert check_source(src, "src/repro/core/simulator.py") == []

    def test_tests_out_of_scope(self):
        src = "def f(a, b):\n    return a.time_s - b.time_s\n"
        assert check_source(src, "tests/test_foo.py") == []


# ---------------------------------------------------------------------------
# The noqa escape hatch
# ---------------------------------------------------------------------------
class TestNoqa:
    BAD = (
        "def _quantize(x, amax):\n"
        "    return x / 127.0{comment}\n"
    )

    def test_matching_id_suppresses(self):
        src = self.BAD.format(comment="  # repro: noqa[RPR005]")
        assert check_source(src, "src/repro/foo.py") == []

    def test_bare_noqa_suppresses(self):
        src = self.BAD.format(comment="  # repro: noqa")
        assert check_source(src, "src/repro/foo.py") == []

    def test_other_id_does_not_suppress(self):
        src = self.BAD.format(comment="  # repro: noqa[RPR001]")
        assert rule_ids(check_source(src, "src/repro/foo.py")) == ["RPR005"]


# ---------------------------------------------------------------------------
# The acceptance bar: clean tree, zero suppressions in src/
# ---------------------------------------------------------------------------
class TestCleanTree:
    def test_run_all_default_paths_is_empty(self):
        assert run_all(root=ROOT) == []

    def test_src_has_zero_noqa_suppressions(self):
        # The hatch is for tests/fixtures; src/ must hold the bar with no
        # suppressions. repro/analysis itself documents the syntax in
        # docstrings, hence the carve-out.
        noqa = re.compile(r"#\s*repro:\s*noqa")
        hits = []
        for f in (ROOT / "src").rglob("*.py"):
            if "analysis" in f.parts:
                continue
            for i, line in enumerate(f.read_text().splitlines(), 1):
                if noqa.search(line):
                    hits.append(f"{f}:{i}")
        assert hits == []


# ---------------------------------------------------------------------------
# Level 2: traversal + contract passes
# ---------------------------------------------------------------------------
class TestJaxprTraversal:
    def test_recurses_custom_jvp_under_pjit(self):
        @jax.custom_jvp
        def rnd(x):
            return jnp.round(x)

        @rnd.defjvp
        def rnd_jvp(primals, tangents):
            (x,), (t,) = primals, tangents
            return rnd(x), t

        closed = jax.make_jaxpr(jax.jit(lambda x: rnd(x) * 2.0))(jnp.ones((8, 8)))
        # the round sits inside custom_jvp_call inside pjit — two levels of
        # closed sub-jaxpr the old engine walker missed on the 0.4.30 floor
        assert count_weight_round_ops(closed, 64) == 1
        assert count_weight_round_ops(closed.jaxpr, 64) == 1  # raw Jaxpr too

    def test_recurses_cond_branches(self):
        def fn(x):
            return jax.lax.cond(
                x.sum() > 0, lambda y: jnp.round(y), lambda y: y * 2.0, x
            )

        closed = jax.make_jaxpr(fn)(jnp.ones((8, 8)))
        assert count_weight_round_ops(closed, 64) == 1

    def test_min_size_filters_activation_rounds(self):
        closed = jax.make_jaxpr(lambda x: jnp.round(x))(jnp.ones((4,)))
        assert count_weight_round_ops(closed, 64) == 0
        assert count_weight_round_ops(closed, 1) == 1

    def test_count_primitives_and_iter_eqns(self):
        closed = jax.make_jaxpr(jax.jit(lambda x: jnp.sin(x) + jnp.sin(x * 2)))(
            jnp.ones((4,))
        )
        assert count_primitives(closed, "sin") == 2
        assert any(e.primitive.name == "sin" for e in iter_eqns(closed))

    def test_back_compat_reexport(self):
        from repro.photonic.engine import count_weight_round_ops as legacy

        assert legacy is count_weight_round_ops


class TestContractChecker:
    def _engine(self):
        return engine_for(
            DPUConfig(organization="SMWA", bits=4, datarate_gs=5.0), "ref"
        )

    def test_decode_zero_quant_on_packed_path(self):
        from repro.photonic.packing import pack_dense

        eng = self._engine()
        w = jnp.asarray(RNG.normal(size=(64, 48)), jnp.float32)
        x = jnp.asarray(RNG.normal(size=(4, 64)), jnp.float32)
        packed = pack_dense({"w": w}, eng)["w"]

        checker = ContractChecker.trace(
            lambda a, p: eng.matmul(a, p, site="ffn.wi"), x, packed
        )
        assert checker.weight_round_ops(64 * 48) == 0
        checker.assert_zero_weight_rounds(64 * 48)  # must not raise

    def test_per_call_path_violates_and_raises(self):
        eng = self._engine()
        w = jnp.asarray(RNG.normal(size=(64, 48)), jnp.float32)
        x = jnp.asarray(RNG.normal(size=(4, 64)), jnp.float32)
        checker = ContractChecker.trace(
            lambda a, b: eng.matmul_float(a, b, site="ffn.wi"), x, w
        )
        assert checker.weight_round_ops(64 * 48) > 0
        with pytest.raises(ContractViolation, match="weight-stationary"):
            checker.assert_zero_weight_rounds(64 * 48)

    def _psum_body_checker(self, n_gemms):
        eng = self._engine()
        mesh = Mesh(np.array(jax.devices()[:1]), ("tp",))
        xq = jnp.asarray(RNG.integers(-7, 8, (4, 16)), jnp.int32)
        wq = jnp.asarray(RNG.integers(-7, 8, (16, 16)), jnp.int32)

        def body(a, b):
            out = tp_sharded.psum_int_gemm(eng, a, b, axis="tp", site="ffn.wi")
            for _ in range(n_gemms - 1):
                out = tp_sharded.psum_int_gemm(
                    eng, out, b, axis="tp", site="ffn.wo"
                )
            return out

        fn = compat.shard_map(
            body, mesh=mesh, in_specs=(P(), P()), out_specs=P(), check_vma=False
        )
        return ContractChecker.trace(fn, xq, wq, label=f"{n_gemms}-gemm")

    def test_single_psum_per_routed_gemm(self):
        # psum sits inside the shard_map sub-jaxpr: the count proves both
        # the contract and the traversal into shard_map bodies.
        self._psum_body_checker(1).assert_psum_per_gemm(1)

    def test_psum_count_tracks_gemms_and_mismatch_raises(self):
        checker = self._psum_body_checker(2)
        checker.assert_psum_per_gemm(2)
        with pytest.raises(ContractViolation, match="psum"):
            checker.assert_psum_per_gemm(1)

    def test_noisy_channel_untraceable_without_source(self):
        ch = build_channel_model("SMWA", n=21, bits=4, datarate_gs=5.0)
        eng = engine_for(DPUConfig(dpe_size=21, channel=ch), "ref")  # no seed
        x = jnp.zeros((2, 21), jnp.float32)
        w = jnp.zeros((21, 8), jnp.float32)
        ContractChecker.assert_untraceable_without_source(
            lambda a, b: eng.matmul_float(a, b, site="ffn.wi"), x, w
        )

    def test_hlo_bridge_reuses_hlo_analysis_on_the_same_call(self):
        from repro.launch import hlo_analysis

        eng = self._engine()
        w = jnp.asarray(RNG.normal(size=(64, 48)), jnp.float32)
        x = jnp.asarray(RNG.normal(size=(4, 64)), jnp.float32)
        checker = ContractChecker.trace(
            lambda a, b: eng.matmul_float(a, b, site="ffn.wi"), x, w
        )
        hlo = checker.hlo_text()
        assert "HloModule" in hlo
        summary = checker.collective_summary()
        assert summary == hlo_analysis.collective_summary(hlo)
        assert "total_wire_bytes" in summary

    def test_hlo_bridge_requires_trace_built_checker(self):
        closed = jax.make_jaxpr(lambda a: a + 1)(jnp.zeros((2,)))
        with pytest.raises(ValueError, match="ContractChecker.trace"):
            ContractChecker(closed).hlo_text()

    def test_seeded_noisy_channel_traces_and_hatch_detects_it(self):
        ch = build_channel_model("SMWA", n=21, bits=4, datarate_gs=5.0)
        eng = engine_for(DPUConfig(dpe_size=21, channel=ch, noise_seed=7), "ref")
        x = jnp.zeros((2, 21), jnp.float32)
        w = jnp.zeros((21, 8), jnp.float32)
        with pytest.raises(ContractViolation, match="traced without"):
            ContractChecker.assert_untraceable_without_source(
                lambda a, b: eng.matmul_float(a, b, site="ffn.wi"), x, w
            )


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------
class TestCLI:
    def _run(self, *args, cwd=ROOT):
        env = dict(os.environ)
        env["PYTHONPATH"] = str(ROOT / "src")
        return subprocess.run(
            [sys.executable, "-m", "repro.analysis", *args],
            cwd=cwd, env=env, capture_output=True, text=True,
        )

    def test_clean_tree_exits_zero(self):
        r = self._run()
        assert r.returncode == 0, r.stdout + r.stderr
        assert r.stdout == ""

    def test_list_rules(self):
        r = self._run("--list-rules")
        assert r.returncode == 0
        for rid in ("RPR001", "RPR006"):
            assert rid in r.stdout

    def test_violation_github_format_and_report(self, tmp_path):
        proj = tmp_path / "proj"
        (proj / "src" / "repro").mkdir(parents=True)
        (proj / "pyproject.toml").write_text("[project]\nname='x'\n")
        bad = proj / "src" / "repro" / "bad.py"
        bad.write_text("def f(org):\n    return org.upper()\n")
        report = tmp_path / "report.json"
        r = self._run(
            "--root", str(proj), "--format", "github",
            "--report", str(report), str(proj / "src"),
        )
        assert r.returncode == 1
        assert "::error file=src/repro/bad.py,line=2" in r.stdout
        assert "RPR002" in r.stdout
        data = json.loads(report.read_text())
        assert data["count"] == 1 and not data["ok"]
        assert data["findings"][0]["rule"] == "RPR002"

    def test_select_filters_rules(self, tmp_path):
        proj = tmp_path / "proj"
        (proj / "src").mkdir(parents=True)
        (proj / "pyproject.toml").write_text("[project]\nname='x'\n")
        (proj / "src" / "bad.py").write_text(
            "def f(org):\n    return org.upper()\n"
        )
        r = self._run("--root", str(proj), "--select", "RPR001", str(proj / "src"))
        assert r.returncode == 0
