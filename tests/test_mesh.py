"""Direct tests for repro.launch.mesh (previously only exercised through the
dry-run subprocess test, which hid mesh-construction crashes behind a
returncode assert)."""

import jax
import pytest

from repro.compat import Mesh
from repro.launch import mesh as mesh_mod


class TestBuildMesh:
    def test_single_device_mesh(self):
        m = mesh_mod.build_mesh((1, 1), ("data", "model"))
        assert isinstance(m, Mesh)
        assert tuple(m.axis_names) == ("data", "model")
        assert m.shape["data"] == 1 and m.shape["model"] == 1
        assert m.devices.size == 1

    def test_one_axis(self):
        m = mesh_mod.build_mesh((1,), ("pod",))
        assert dict(m.shape) == {"pod": 1}

    def test_smoke_mesh_matches_production_axis_names(self):
        m = mesh_mod.make_smoke_mesh()
        assert tuple(m.axis_names) == ("data", "model")
        assert m.devices.size == 1

    def test_smoke_mesh_usable_for_sharding(self):
        from repro.compat import PartitionSpec as P
        from repro.runtime import sharding as shd

        m = mesh_mod.make_smoke_mesh()
        with shd.use_rules(m):
            spec = shd.resolve_spec((4, 8), ("batch", "heads"))
        assert spec == P(("data",), "model")

    def test_production_mesh_needs_many_devices(self):
        # CPU test env has 1 device; the production mesh (256 chips) must be
        # impossible to build silently wrong.
        if len(jax.devices()) >= 256:
            pytest.skip("enough devices for a real production mesh")
        with pytest.raises(ValueError):
            mesh_mod.make_production_mesh()


class TestRequireDevices:
    def test_passes_for_available(self):
        mesh_mod.require_devices(1)

    def test_raises_with_actionable_message(self):
        have = len(jax.devices())
        with pytest.raises(RuntimeError, match="XLA_FLAGS"):
            mesh_mod.require_devices(have + 1)
