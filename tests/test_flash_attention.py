"""Flash-attention Pallas kernel vs oracle, sweeping shapes/dtypes/GQA."""

import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.models.attention import chunked_attention


CASES = [
    # (B, Tq, Tk, H, KV, hd, causal, q_offset)
    (2, 64, 64, 4, 2, 16, True, 0),
    (1, 37, 53, 4, 4, 8, False, 0),
    (2, 128, 256, 8, 2, 32, True, 128),
    (1, 16, 512, 16, 16, 64, True, 496),
    (3, 100, 100, 6, 3, 24, True, 0),
]


@pytest.mark.parametrize("case", CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_matches_ref(case, dtype):
    b, tq, tk, h, kv, hd, causal, qo = case
    rng = np.random.default_rng(hash(case) % 2**32)
    q = jnp.asarray(rng.normal(size=(b, tq, h, hd)), dtype)
    k = jnp.asarray(rng.normal(size=(b, tk, kv, hd)), dtype)
    v = jnp.asarray(rng.normal(size=(b, tk, kv, hd)), dtype)
    out = flash_attention(q, k, v, causal=causal, q_offset=qo, bq=32, bk=32)
    ref = attention_ref(q, k, v, causal=causal, q_offset=qo)
    tol = 2e-6 if dtype == jnp.float32 else 2e-2
    assert float(jnp.abs(out.astype(jnp.float32) - ref.astype(jnp.float32)).max()) < tol


def test_flash_kv_valid_masking():
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(1, 8, 2, 16)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 64, 2, 16)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(1, 64, 2, 16)), jnp.float32)
    out = flash_attention(q, k, v, causal=False, kv_valid=40, bq=8, bk=16)
    ref = attention_ref(q, k, v, causal=False, kv_valid=40)
    assert float(jnp.abs(out - ref).max()) < 2e-6


@given(
    tq=st.integers(1, 48),
    tk=st.integers(8, 96),
    h=st.sampled_from([2, 4]),
    rep=st.sampled_from([1, 2]),
    seed=st.integers(0, 2**31 - 1),
)
@settings(max_examples=12, deadline=None)
def test_flash_equals_chunked_property(tq, tk, h, rep, seed):
    """The kernel and the scanned implementation agree on arbitrary shapes
    (same math, different memory residency)."""
    if tq > tk:
        tq = tk
    kv = h // rep
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(size=(1, tq, h, 8)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, tk, kv, 8)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(1, tk, kv, 8)), jnp.float32)
    qo = tk - tq
    fa = flash_attention(q, k, v, causal=True, q_offset=qo, bq=16, bk=16)
    ca = chunked_attention(q, k, v, causal=True, q_offset=qo, chunk=16)
    assert float(jnp.abs(fa - ca).max()) < 3e-6
