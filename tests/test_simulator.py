"""Tests for the CNN workloads, perf model, event simulator, and the HLO
collective parser."""

import numpy as np
import pytest

from repro.core.cnn_workloads import WORKLOADS, total_macs
from repro.core.perfmodel import AcceleratorConfig, area_matched_counts
from repro.core.simulator import evaluate_all, simulate
from repro.launch import hlo_analysis


class TestWorkloads:
    @pytest.mark.parametrize(
        "name,macs_g",
        [
            ("googlenet", 1.58),
            ("resnet50", 3.86),
            ("mobilenet_v2", 0.30),
            ("shufflenet_v2", 0.14),
        ],
    )
    def test_mac_counts_match_literature(self, name, macs_g):
        assert total_macs(name) / 1e9 == pytest.approx(macs_g, rel=0.15)

    def test_layer_shapes_positive(self):
        for name, fn in WORKLOADS.items():
            for l in fn():
                assert l.rows > 0 and l.k > 0 and l.cols > 0, (name, l)


class TestPerfModel:
    def test_from_paper_table_v(self):
        cfg = AcceleratorConfig.from_paper("SMWA", 1)
        assert (cfg.n, cfg.m, cfg.dpu_count) == (83, 83, 50)
        cfg = AcceleratorConfig.from_paper("ASMW", 10)
        assert (cfg.n, cfg.dpu_count) == (12, 291)

    def test_ring_count_ordering(self):
        # At equal N, M: MASW (shared input array) < ASMW < SMWA (hitless).
        a = AcceleratorConfig(organization="ASMW", n=40, m=40)
        m = AcceleratorConfig(organization="MASW", n=40, m=40)
        s = AcceleratorConfig(organization="SMWA", n=40, m=40)
        assert m.rings_per_dpu < a.rings_per_dpu < s.rings_per_dpu

    def test_areas_positive_and_monotone_in_count(self):
        import dataclasses

        cfg = AcceleratorConfig.from_paper("SMWA", 5)
        a1 = cfg.total_area_mm2()
        a2 = dataclasses.replace(cfg, dpu_count=cfg.dpu_count * 2).total_area_mm2()
        assert 0 < a1 < a2

    def test_area_matched_counts_direction(self):
        """Smaller-N orgs get MORE DPUs when area-matched (Table V trend)."""
        counts = area_matched_counts(1)
        assert counts["ASMW"] > counts["SMWA"]
        assert counts["MASW"] > counts["SMWA"]


class TestSimulator:
    def test_fig7_ordering_and_trend(self):
        res = evaluate_all()
        models = ("googlenet", "resnet50", "mobilenet_v2", "shufflenet_v2")

        def g(dr, other):
            r = [res[("SMWA", dr, m)].fps / res[(other, dr, m)].fps for m in models]
            return float(np.exp(np.mean(np.log(r))))

        # SMWA wins FPS at every datarate (paper Fig. 7a)
        for dr in (1, 5, 10):
            assert g(dr, "ASMW") > 1.0
            assert g(dr, "MASW") > 1.0
        # MASW slightly better than ASMW (paper: "MASW performs slightly
        # better than ASMW at all datarates")
        for dr in (1, 5, 10):
            for m in models:
                assert res[("MASW", dr, m)].fps >= res[("ASMW", dr, m)].fps
        # advantage grows with datarate (paper: 2.5x -> 3.9x -> 4.4x)
        assert g(10, "ASMW") > g(5, "ASMW") > g(1, "ASMW")

    def test_energy_and_time_positive(self):
        r = simulate("resnet50", AcceleratorConfig.from_paper("SMWA", 5))
        assert r.total_time_s > 0
        assert r.dynamic_energy_j > 0
        assert r.avg_power_w > r.static_power_w

    def test_fps_decreases_with_datarate(self):
        """Paper: 'as datarate increases the FPS of each accelerator
        decreases' (N shrinks, more psums)."""
        for org in ("ASMW", "MASW", "SMWA"):
            f1 = simulate("resnet50", AcceleratorConfig.from_paper(org, 1)).fps
            f10 = simulate("resnet50", AcceleratorConfig.from_paper(org, 10)).fps
            assert f10 < f1, org


SAMPLE_HLO = """
HloModule test

%add (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %r = f32[] add(%a, %b)
}

%cond (arg: (s32[], f32[16,128])) -> pred[] {
  %arg = (s32[], f32[16,128]) parameter(0)
  %i = s32[] get-tuple-element(%arg), index=0
  %k = s32[] constant(24)
  ROOT %lt = pred[] compare(%i, %k), direction=LT
}

%body (arg: (s32[], f32[16,128])) -> (s32[], f32[16,128]) {
  %arg = (s32[], f32[16,128]) parameter(0)
  %x = f32[16,128] get-tuple-element(%arg), index=1
  %ag = f32[16,2048] all-gather(%x), replica_groups=[32,16]<=[512], dimensions={1}
  %ar = f32[16,128] all-reduce(%x), replica_groups=[32,16]<=[512], to_apply=%add
  ROOT %t = (s32[], f32[16,128]) tuple(%i2, %ar)
}

ENTRY %main (p: f32[16,128]) -> f32[16,128] {
  %p = f32[16,128] parameter(0)
  %ar2 = f32[16,128] all-reduce(%p), replica_groups={{0,1},{2,3}}, to_apply=%add
  %w = (s32[], f32[16,128]) while(%init), condition=%cond, body=%body
  ROOT %o = f32[16,128] get-tuple-element(%w), index=1
}
"""


class TestHLOAnalysis:
    def test_multipliers_from_while_trip_count(self):
        mult = hlo_analysis.computation_multipliers(SAMPLE_HLO)
        assert mult["body"] == 24.0
        assert mult.get("main", 1.0) == 1.0

    def test_collective_bytes_loop_adjusted(self):
        s = hlo_analysis.collective_summary(SAMPLE_HLO)
        # entry all-reduce: 16*128*4 bytes * 2(ring) * 1/2 ... group=2
        ar_entry = 16 * 128 * 4 * 2 * (1 / 2)
        # body all-reduce: same shape, group 16 -> *2*(15/16), x24 trips
        ar_body = 16 * 128 * 4 * 2 * (15 / 16) * 24
        assert s["bytes_all-reduce"] == pytest.approx(ar_entry + ar_body, rel=1e-6)
        # body all-gather: out 16*2048*4 * (15/16) x24
        assert s["bytes_all-gather"] == pytest.approx(
            16 * 2048 * 4 * (15 / 16) * 24, rel=1e-6
        )
        assert s["count_all-reduce"] == 2
        assert s["count_all-gather"] == 1

    def test_group_size_parsing(self):
        assert hlo_analysis._group_size("replica_groups=[32,16]<=[512]") == 16
        assert hlo_analysis._group_size("replica_groups={{0,1,2,3},{4,5,6,7}}") == 4
        assert hlo_analysis._group_size("no groups here") is None
