"""Unit tests for logical-axis sharding resolution (divisibility fallback,
ZeRO-1 axes, rule overrides)."""

import jax
import numpy as np

from repro.compat import Mesh, PartitionSpec as P, abstract_mesh
from repro.runtime import sharding as shd


def _mesh_1dev():
    return Mesh(np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))


class TestResolveSpec:
    def test_basic_mapping(self):
        mesh = _mesh_1dev()
        spec = shd.resolve_spec((8, 16), ("batch", "heads"), mesh)
        # 1-device mesh: everything divides; batch -> data (pod filtered out)
        assert spec == P(("data",), "model")

    def test_divisibility_fallback_replicates(self):
        # fake a 4x2 mesh shape without devices: use an abstract mesh.
        mesh = abstract_mesh((4, 2), ("data", "model"))
        with shd.use_rules(mesh):
            spec = shd.resolve_spec((6, 7), ("batch", "heads"))
            # 6 % 4 != 0 -> batch replicated; 7 % 2 != 0 -> heads replicated
            assert spec == P()
            assert len(shd.fallback_log()) == 2

    def test_tuple_axis_prefix_fallback(self):
        mesh = abstract_mesh((2, 4, 2), ("pod", "data", "model"))
        with shd.use_rules(mesh):
            # batch=2 divides pod(2) but not pod*data(8) -> prefix ("pod",)
            spec = shd.resolve_spec((2, 16), ("batch", None))
            assert spec == P(("pod",))

    def test_axis_used_once(self):
        mesh = abstract_mesh((4, 2), ("data", "model"))
        with shd.use_rules(mesh):
            # batch -> data; kv_seq also wants data -> dropped (used)
            spec = shd.resolve_spec((8, 8, 4), ("batch", "kv_seq", "kv_heads"))
            assert spec == P(("data",), None, "model")

    def test_rule_override(self):
        mesh = abstract_mesh((4, 2), ("data", "model"))
        with shd.use_rules(mesh, {"inner": None}):
            spec = shd.resolve_spec((8, 8), (None, "inner"))
            assert spec == P()


class TestZero1:
    def test_picks_divisible_dim(self):
        import jax.numpy as jnp

        axes = {"w": (None, None, "d_ff")}
        shapes = {"w": jax.ShapeDtypeStruct((95, 8192, 1376), jnp.float32)}
        out = shd.zero1_axes(axes, shapes, 32)
        # dim0 (95) not divisible by 32; dim1 (8192) is
        assert out["w"] == (None, "zero1", "d_ff")

    def test_leaves_unshardable_alone(self):
        import jax.numpy as jnp

        axes = {"g": (None,)}
        shapes = {"g": jax.ShapeDtypeStruct((3,), jnp.float32)}
        assert shd.zero1_axes(axes, shapes, 32)["g"] == (None,)

    def test_skips_already_sharded(self):
        import jax.numpy as jnp

        axes = {"w": ("vocab", "zero-nope")}  # nonsense name stays put
        shapes = {"w": jax.ShapeDtypeStruct((64, 64), jnp.float32)}
        out = shd.zero1_axes(axes, shapes, 32)
        assert out["w"] == ("vocab", "zero-nope")
