"""PR-9 execution-mode + unified-GEMM-surface contracts (DESIGN.md §15).

Bit-sliced execution: int operands decomposed into ``plane_bits``-wide
signed-magnitude planes, each plane pair run through the analog channel
re-referred to the plane full-scale, recombined with exact digital
shifts.  Contracts under test:

1. ideal channel  => bit-identical to the unsliced exact GEMM, on both
   analog backends, eager and jit;
2. noisy channel  => deterministic per (engine, seed, site, fold, shard,
   plane) with decorrelated per-plane streams;
3. the unified ``epilogue=`` / ``slicing=`` surface is bitwise-identical
   to the legacy ``bias=``/``activation=`` shims it replaces.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from benchmarks import run as bench_run
from repro.core.dpu import DPUConfig
from repro.kernels.photonic_gemm.ref import exact_int_gemm
from repro.models.common import ModelConfig
from repro.noise import build_channel_model
from repro.photonic import (
    Epilogue,
    EpilogueSpec,
    PhotonicEngine,
    SlicingSpec,
    engine_for,
    pack_dense,
    resolve_slicing,
)

RNG = np.random.default_rng(7)
XQ = jnp.asarray(RNG.integers(-127, 128, (5, 40), dtype=np.int8))
WQ = jnp.asarray(RNG.integers(-127, 128, (40, 9), dtype=np.int8))
X = jnp.asarray(RNG.normal(size=(4, 40)), jnp.float32)
W = jnp.asarray(RNG.normal(size=(40, 24)), jnp.float32)
B = jnp.asarray(RNG.normal(size=(24,)), jnp.float32)


def _ideal_dpu(n=16):
    return DPUConfig(organization="SMWA", bits=4, dpe_size=n)


def _noisy_dpu(n=16, platform="SIN", seed=11):
    ch = build_channel_model(
        "SMWA", n=n, bits=4, datarate_gs=5.0, platform=platform
    )
    return DPUConfig(
        organization="SMWA", bits=4, dpe_size=n, channel=ch, noise_seed=seed
    )


# ---------------------------------------------------------------------------
# Contract 1: ideal channel => sliced == exact, bitwise
# ---------------------------------------------------------------------------
class TestIdealBitwise:
    @pytest.mark.parametrize("backend", ["ref", "pallas"])
    @pytest.mark.parametrize("plane_bits", [2, 4])
    @pytest.mark.parametrize("jitted", [False, True])
    def test_sliced_equals_exact(self, backend, plane_bits, jitted):
        eng = engine_for(_ideal_dpu(), backend, slicing=plane_bits)
        fn = eng.int_gemm
        if jitted:
            fn = jax.jit(lambda a, b: eng.int_gemm(a, b))
        out = fn(XQ, WQ)
        gold = exact_int_gemm(XQ, WQ)
        assert out.dtype == jnp.int32
        np.testing.assert_array_equal(np.asarray(out), np.asarray(gold))

    def test_per_call_slicing_override(self):
        eng = engine_for(_ideal_dpu(), "ref")
        out = eng.int_gemm(XQ, WQ, slicing=2)
        np.testing.assert_array_equal(
            np.asarray(out), np.asarray(exact_int_gemm(XQ, WQ))
        )
        # "none" forces the unsliced path on a sliced engine.
        sliced = eng.with_slicing(2)
        out2 = sliced.int_gemm(XQ, WQ, slicing="none")
        np.testing.assert_array_equal(
            np.asarray(out2), np.asarray(exact_int_gemm(XQ, WQ))
        )

    def test_exact_backend_ignores_slicing(self):
        a = engine_for(_ideal_dpu(), "exact").int_gemm(XQ, WQ)
        b = engine_for(_ideal_dpu(), "exact", slicing=2).int_gemm(XQ, WQ)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    @pytest.mark.parametrize("backend", ["ref", "pallas"])
    def test_sliced_matmul_with_epilogue_equals_exact_shim(self, backend):
        """Full float surface: sliced ideal engine with the unified
        epilogue == exact engine running the legacy keyword shim."""
        eng = engine_for(_ideal_dpu(), backend, slicing=2)
        gold_eng = engine_for(_ideal_dpu(), "exact")
        ep = Epilogue(EpilogueSpec(bias=True, activation="gelu"), B)
        out = eng.matmul_float(X, W, site="s", epilogue=ep)
        gold = gold_eng.matmul_float(X, W, site="s", bias=B, activation="gelu")
        np.testing.assert_array_equal(np.asarray(out), np.asarray(gold))


# ---------------------------------------------------------------------------
# Contract 2: noisy channel => deterministic, decorrelated planes
# ---------------------------------------------------------------------------
class TestNoisyDeterminism:
    @pytest.mark.parametrize("backend", ["ref", "pallas"])
    def test_same_seed_same_result(self, backend):
        eng = engine_for(_noisy_dpu(), backend, slicing=2)
        a = eng.int_gemm(XQ, WQ, site="s", fold=1)
        b = eng.int_gemm(XQ, WQ, site="s", fold=1)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        jit_out = jax.jit(lambda x, w: eng.int_gemm(x, w, site="s", fold=1))(
            XQ, WQ
        )
        np.testing.assert_array_equal(np.asarray(a), np.asarray(jit_out))

    def test_different_seed_differs(self):
        a = engine_for(_noisy_dpu(seed=11), "ref", slicing=2).int_gemm(XQ, WQ)
        b = engine_for(_noisy_dpu(seed=12), "ref", slicing=2).int_gemm(XQ, WQ)
        assert not np.array_equal(np.asarray(a), np.asarray(b))

    def test_plane_stream_decorrelated(self):
        """The plane index perturbs the stream seed: the same GEMM seeded
        at different plane indices draws different noise."""
        eng = engine_for(_noisy_dpu(), "ref")
        a = eng.int_gemm(XQ, WQ, site="s", plane=0)
        b = eng.int_gemm(XQ, WQ, site="s", plane=1)
        c = eng.int_gemm(XQ, WQ, site="s")  # no plane stream at all
        assert not np.array_equal(np.asarray(a), np.asarray(b))
        assert not np.array_equal(np.asarray(a), np.asarray(c))

    def test_sliced_noise_is_smaller(self):
        """The physics the mode buys: per-plane passes see the detector
        sigma re-referred to the plane full-scale, so the sliced result
        lands closer to exact than the unsliced one."""
        gold = np.asarray(exact_int_gemm(XQ, WQ), np.float64)
        base = engine_for(_noisy_dpu(platform="SOI"), "ref")
        err_full = np.abs(np.asarray(base.int_gemm(XQ, WQ), np.float64) - gold)
        err_sliced = np.abs(
            np.asarray(base.with_slicing(2).int_gemm(XQ, WQ), np.float64) - gold
        )
        assert err_sliced.mean() < err_full.mean()


# ---------------------------------------------------------------------------
# Contract 3: the unified surface == the legacy shims, bitwise
# ---------------------------------------------------------------------------
class TestUnifiedSurface:
    @pytest.mark.parametrize("backend", ["ref", "pallas", "exact"])
    def test_epilogue_keyword_equals_legacy_shim(self, backend):
        eng = engine_for(_ideal_dpu(), backend)
        ep = Epilogue(EpilogueSpec(bias=True, activation="gelu"), B)
        a = eng.matmul_float(X, W, site="s", epilogue=ep)
        b = eng.matmul_float(X, W, site="s", bias=B, activation="gelu")
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        packed = pack_dense({"w": W}, eng)["w"]
        c = eng.matmul(X, packed, site="s", epilogue=ep)
        d = eng.matmul(X, packed, site="s", bias=B, activation="gelu")
        np.testing.assert_array_equal(np.asarray(c), np.asarray(d))

    def test_bias_free_spec_accepted(self):
        eng = engine_for(_ideal_dpu(), "ref")
        a = eng.matmul_float(X, W, site="s", epilogue=EpilogueSpec(activation="gelu"))
        b = eng.matmul_float(X, W, site="s", activation="gelu")
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_mixing_epilogue_and_legacy_raises(self):
        eng = engine_for(_ideal_dpu(), "ref")
        with pytest.raises(TypeError, match="not both"):
            eng.matmul_float(
                X, W, epilogue=EpilogueSpec(activation="gelu"), bias=B
            )
        with pytest.raises(TypeError, match="not both"):
            eng.matmul_float(
                X, W, epilogue=EpilogueSpec(), activation="gelu"
            )

    def test_epilogue_validation(self):
        eng = engine_for(_ideal_dpu(), "ref")
        with pytest.raises(TypeError, match="bias"):
            eng.matmul_float(X, W, epilogue=EpilogueSpec(bias=True))
        with pytest.raises(TypeError, match="disagrees"):
            eng.matmul_float(X, W, epilogue=Epilogue(EpilogueSpec(bias=True), None))
        with pytest.raises(TypeError, match="EpilogueSpec"):
            eng.matmul_float(X, W, epilogue="gelu")

    def test_model_config_resolves_slicing_eagerly(self):
        cfg = ModelConfig(photonic=_ideal_dpu(), photonic_slicing="2")
        assert cfg.photonic_slicing == SlicingSpec(2)
        assert ModelConfig(photonic=_ideal_dpu()).photonic_slicing is None
        with pytest.raises(ValueError):
            ModelConfig(photonic=_ideal_dpu(), photonic_slicing="both")


# ---------------------------------------------------------------------------
# Mode resolution + structured describe()
# ---------------------------------------------------------------------------
class TestResolveSlicing:
    def test_round_trips(self):
        assert resolve_slicing(None) is None
        assert resolve_slicing("none") is None
        assert resolve_slicing(" off ") is None
        assert resolve_slicing("") is None
        assert resolve_slicing(2) == SlicingSpec(2)
        assert resolve_slicing("4") == SlicingSpec(4)
        spec = SlicingSpec(2)
        assert resolve_slicing(spec) is spec

    def test_invalid_rejected(self):
        with pytest.raises(ValueError):
            resolve_slicing(True)  # bool is an int; rejected explicitly
        with pytest.raises(ValueError):
            resolve_slicing(3)  # planes must tile operand widths
        with pytest.raises(ValueError):
            resolve_slicing("both")
        with pytest.raises(ValueError):
            SlicingSpec(plane_bits=5)

    def test_num_planes(self):
        assert SlicingSpec(2).num_planes(8) == 4
        assert SlicingSpec(4).num_planes(8) == 2
        assert SlicingSpec(8).num_planes(8) == 1

    def test_with_slicing_is_frozen_replace(self):
        eng = engine_for(_ideal_dpu(), "ref")
        assert eng.with_slicing(None) is eng
        sliced = eng.with_slicing(2)
        assert sliced is not eng
        assert sliced.slicing == SlicingSpec(2)
        assert sliced.with_slicing(SlicingSpec(2)) is sliced
        assert eng.slicing is None  # original untouched (frozen)

    def test_engine_constructor_normalizes(self):
        eng = PhotonicEngine(dpu=_ideal_dpu(), slicing="2")
        assert eng.slicing == SlicingSpec(2)
        with pytest.raises(ValueError):
            PhotonicEngine(dpu=_ideal_dpu(), slicing="both")


class TestEngineInfo:
    def test_str_preserves_legacy_text_at_defaults(self):
        eng = engine_for(_ideal_dpu(n=21), "ref")
        info = eng.describe()
        assert str(info) == (
            "ref backend, SMWA (blocks S->M->W->A->Sigma, through 2) "
            "B=4 N=21 @ 5.0 GS/s, channel=ideal, "
            "sites include=['*'] exclude=['router']"
        )

    def test_str_extends_for_platform_and_slicing(self):
        eng = engine_for(_noisy_dpu(n=21), "ref", slicing=2)
        text = str(eng.describe())
        assert "channel=analog, platform=SIN, slicing=2b planes, sites" in text

    def test_to_dict_round_trip(self):
        info = engine_for(_noisy_dpu(), "ref", slicing=2).describe()
        d = info.to_dict()
        assert d["platform"] == "SIN"
        assert d["slicing"] == 2
        assert d["organization"] == "SMWA"
        assert d["channel"] == "analog"
        # Frozen + hashable (rides jit closures / dry-run manifests).
        assert hash(info) == hash(dataclasses.replace(info))


# ---------------------------------------------------------------------------
# Benchmark registry contract (benchmarks/run.py)
# ---------------------------------------------------------------------------
class TestRegisterBenchmark:
    def test_valid_registration(self, monkeypatch):
        monkeypatch.setattr(bench_run, "_REGISTRY", {})

        @bench_run.register_benchmark("t1")
        def bench(smoke: bool = False):
            return {"ok": True}

        assert bench_run.registered_benchmarks() == {"t1": bench}

    def test_duplicate_name_raises(self, monkeypatch):
        monkeypatch.setattr(bench_run, "_REGISTRY", {})

        @bench_run.register_benchmark("dup")
        def bench(smoke: bool = False):
            return {}

        with pytest.raises(ValueError, match="already registered"):

            @bench_run.register_benchmark("dup")
            def bench2(smoke: bool = False):
                return {}

    def test_bad_signature_raises(self, monkeypatch):
        monkeypatch.setattr(bench_run, "_REGISTRY", {})
        with pytest.raises(TypeError, match="smoke"):

            @bench_run.register_benchmark("nosmoke")
            def bench(n: int = 3):
                return {}

        with pytest.raises(TypeError, match="smoke"):

            @bench_run.register_benchmark("wrongdefault")
            def bench3(smoke: bool = True):
                return {}

    def test_bad_name_raises(self):
        with pytest.raises(TypeError, match="non-empty str"):
            bench_run.register_benchmark("")
        with pytest.raises(TypeError, match="non-empty str"):
            bench_run.register_benchmark(3)

    def test_all_shipped_benchmarks_register(self):
        # Importing a benchmark module registers its entry point exactly
        # once (idempotent across repeated imports).
        import benchmarks.org_accuracy  # noqa: F401
        import benchmarks.tp_scaling  # noqa: F401

        names = set(bench_run.registered_benchmarks())
        assert {"org_accuracy", "tp_scaling"} <= names
