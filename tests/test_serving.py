"""Paged-KV continuous-batching serving — DESIGN.md §13.

Contracts under test:

* the block allocator is all-or-nothing and never double-books;
* gather/scatter round-trip through block tables exactly;
* chunked prefill interleaved with decode preserves per-request outputs
  bitwise vs one-shot prefill (float path, any chunking), and vs the
  legacy fixed-slot engine under an ideal photonic channel on both
  backends (lockstep waves), with and without a TP mesh;
* decode over prepacked params traces with zero weight-sized round ops
  (the PR-3 weight-stationary contract, via ``ContractChecker``);
* a recycled slot cannot replay a previous occupant's sampling stream
  (keys fold in the request uid, not the slot);
* a recycled KV block cannot leak stale rows into a new request
  (allocation-time zeroing; NaN sentinels would propagate loudly).
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.dpu import DPUConfig
from repro.launch import mesh as mesh_mod
from repro.models import registry
from repro.models.common import init_tree
from repro.runtime import serve
from repro.serving import NULL_BLOCK, BlockAllocator, Request, Scheduler, ServingConfig
from repro.serving import kv_cache as kvc

TP = mesh_mod.max_tp_degree()

ARCH = registry.get("qwen2-0.5b")


def _small_cfg(**kw):
    return dataclasses.replace(
        ARCH.smoke_config,
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        d_ff=128,
        vocab_size=64,
        remat=False,
        **kw,
    )


def _ideal_dpu(n=16):
    return DPUConfig(organization="SMWA", bits=4, dpe_size=n)


def _params(cfg, seed=0):
    return init_tree(ARCH.param_defs(cfg), jax.random.PRNGKey(seed), cfg.param_dtype)


def _reqs(lengths, cfg, max_new=4, uid0=0):
    rng = np.random.default_rng(42)
    return [
        Request(
            uid=uid0 + i,
            prompt=rng.integers(0, cfg.vocab_size, n).astype(np.int32),
            max_new_tokens=max_new,
        )
        for i, n in enumerate(lengths)
    ]


# ---------------------------------------------------------------------------
# Block allocator
# ---------------------------------------------------------------------------
class TestBlockAllocator:
    def test_all_or_nothing_and_recycle(self):
        a = BlockAllocator(8, 4, reserved=2)
        assert a.free_blocks == 6
        got = a.alloc(4)
        assert sorted(got) == [2, 3, 4, 5]
        assert a.alloc(3) is None  # only 2 left: no partial grant
        assert a.free_blocks == 2
        a.free(got)
        assert a.free_blocks == 6

    def test_blocks_needed_ceil(self):
        a = BlockAllocator(8, 4)
        assert [a.blocks_needed(n) for n in (1, 4, 5, 8, 9)] == [1, 1, 2, 2, 3]

    def test_reserved_blocks_never_granted_and_guarded(self):
        a = BlockAllocator(6, 4, reserved=3)
        assert sorted(a.alloc(3)) == [3, 4, 5]
        with pytest.raises(ValueError):
            a.free([NULL_BLOCK])
        with pytest.raises(ValueError):
            BlockAllocator(3, 4, reserved=3)  # nothing allocatable


# ---------------------------------------------------------------------------
# Pool primitives
# ---------------------------------------------------------------------------
class TestPoolOps:
    def test_scatter_gather_roundtrip(self):
        bs = 4
        pool = {"k": jnp.zeros((6, bs, 2, 3)), "v": jnp.zeros((6, bs, 2, 3))}
        table = jnp.asarray([[2, 5, NULL_BLOCK]], jnp.int32)  # one request
        rng = np.random.default_rng(0)
        rows = {
            "k": jnp.asarray(rng.normal(size=(6, 2, 3)), jnp.float32),
            "v": jnp.asarray(rng.normal(size=(6, 2, 3)), jnp.float32),
        }
        blocks, offsets = kvc.chunk_dest(table[0], jnp.int32(0), 6, bs)
        np.testing.assert_array_equal(blocks, [2, 2, 2, 2, 5, 5])
        np.testing.assert_array_equal(offsets, [0, 1, 2, 3, 0, 1])
        pool = kvc.scatter_kv(pool, blocks, offsets, rows)
        out = kvc.gather_kv(pool, table, 6)
        np.testing.assert_array_equal(np.asarray(out["k"][0]), np.asarray(rows["k"]))
        # beyond the written prefix: null block -> exact zeros
        full = kvc.gather_kv(pool, table, 12)
        assert np.all(np.asarray(full["v"][0, 8:]) == 0)

    def test_token_dest_redirects_inactive_rows_to_trash(self):
        table = jnp.asarray([[3, 4], [5, NULL_BLOCK]], jnp.int32)
        pos = jnp.asarray([6, 1], jnp.int32)
        active = jnp.asarray([True, False])
        trash = jnp.asarray([1, 2], jnp.int32)
        blocks, offsets = kvc.token_dest(table, pos, active, trash, 4)
        np.testing.assert_array_equal(blocks, [4, 2])
        np.testing.assert_array_equal(offsets, [2, 0])

    def test_zero_blocks_targets_only_given_blocks(self):
        pool = {"k": jnp.ones((2, 5, 3, 2))}  # stacked: (layers, blocks, ...)
        pool = kvc.zero_blocks(pool, [1, 3])
        k = np.asarray(pool["k"])
        assert np.all(k[:, [1, 3]] == 0)
        assert np.all(k[:, [0, 2, 4]] == 1)

    def test_init_pool_validates_paged_axes(self):
        good = {"k": ((4, 2, 3), ("batch", "kv_seq", None), jnp.float32)}
        assert kvc.init_pool(good)["k"].shape == (4, 2, 3)
        bad = {"k": ((4, 3, 2), ("batch", None, "kv_seq"), jnp.float32)}
        with pytest.raises(ValueError):
            kvc.init_pool(bad)


# ---------------------------------------------------------------------------
# Chunked prefill: bitwise vs one-shot (float path)
# ---------------------------------------------------------------------------
class TestChunkedPrefillBitwise:
    def test_chunked_interleaved_matches_one_shot_bitwise(self):
        """chunk_tokens=3 forces multi-chunk prefills interleaved with live
        decodes; every request's logits must match the one-shot run
        bit-for-bit (same KV block partition fed to attention)."""
        cfg = _small_cfg()
        params = _params(cfg)
        base = dict(batch_size=2, max_seq=32, block_size=4, record_logits=True)

        def run(chunk_tokens, lengths=(5, 11, 7)):
            sch = Scheduler(
                ARCH, cfg, params,
                ServingConfig(chunk_tokens=chunk_tokens, **base),
            )
            reqs = _reqs(lengths, cfg)
            sch.run(reqs)
            assert all(r.done for r in reqs)
            return reqs, sch

        chunked, sch = run(3)
        oneshot, _ = run(64)
        assert sch.stats["prefill_chunks"] > len(chunked)  # actually chunked
        for a, b in zip(chunked, oneshot):
            assert a.output == b.output
            for ra, rb in zip(a.logits, b.logits):
                np.testing.assert_array_equal(ra, rb)

    def test_chunked_matches_standalone_decode_loop(self):
        cfg = _small_cfg()
        params = _params(cfg)
        sch = Scheduler(
            ARCH, cfg, params,
            ServingConfig(batch_size=2, max_seq=32, block_size=4, chunk_tokens=4),
        )
        reqs = _reqs((9, 6), cfg, max_new=5)
        sch.run(reqs)
        for r in reqs:
            b = {"tokens": jnp.asarray(r.prompt)[None, :]}
            logits, cache = ARCH.prefill(params, b, cfg, 32)
            toks = [int(jnp.argmax(logits[0, -1, : cfg.vocab_size]))]
            for _ in range(4):
                logits, cache = ARCH.decode(
                    params, jnp.asarray([[toks[-1]]], jnp.int32), cache, cfg
                )
                toks.append(int(jnp.argmax(logits[0, -1, : cfg.vocab_size])))
            assert toks == r.output


# ---------------------------------------------------------------------------
# Photonic parity vs the legacy engine (ideal channel, both backends)
# ---------------------------------------------------------------------------
class TestPhotonicLegacyParity:
    @pytest.mark.parametrize("backend", ["ref", "pallas"])
    def test_lockstep_wave_matches_legacy_bitwise(self, backend):
        """Ideal channel, same-length lockstep wave (the regime where the
        legacy engine is exact): the paged scheduler must emit identical
        tokens — per-tensor activation scales see the same tensors."""
        cfg = _small_cfg(photonic=_ideal_dpu(), photonic_backend=backend)
        params = _params(cfg)
        legacy = serve.LegacyEngine(
            ARCH, cfg, params, serve.ServeConfig(batch_size=2, max_seq=32)
        )
        ref_reqs = _reqs((6, 6), cfg)
        legacy.run(ref_reqs)

        sch = Scheduler(
            ARCH, cfg, params,
            ServingConfig(batch_size=2, max_seq=32, block_size=8, chunk_tokens=64),
        )
        paged_reqs = _reqs((6, 6), cfg)
        sch.run(paged_reqs)
        assert [r.output for r in paged_reqs] == [r.output for r in ref_reqs]

    @pytest.mark.skipif(TP < 2, reason="needs a multi-device TP mesh")
    def test_lockstep_wave_matches_legacy_under_tp_mesh(self):
        mesh = mesh_mod.make_tp_smoke_mesh()
        cfg = _small_cfg(photonic=_ideal_dpu(), photonic_backend="ref")
        params = _params(cfg)
        legacy = serve.LegacyEngine(
            ARCH, cfg, params, serve.ServeConfig(batch_size=2, max_seq=32),
            mesh=mesh, tp_axis="model",
        )
        ref_reqs = _reqs((6, 6), cfg)
        legacy.run(ref_reqs)

        sch = Scheduler(
            ARCH, cfg, params,
            ServingConfig(batch_size=2, max_seq=32, block_size=8, chunk_tokens=64),
            mesh=mesh, tp_axis="model",
        )
        paged_reqs = _reqs((6, 6), cfg)
        sch.run(paged_reqs)
        assert [r.output for r in paged_reqs] == [r.output for r in ref_reqs]


# ---------------------------------------------------------------------------
# Weight-stationary decode (PR-3 contract over the stepped jaxpr)
# ---------------------------------------------------------------------------
class TestWeightStationaryDecode:
    def test_paged_decode_has_zero_weight_rounds(self):
        cfg = _small_cfg(photonic=_ideal_dpu(), photonic_backend="ref")
        params = _params(cfg)
        sch = Scheduler(
            ARCH, cfg, params,
            ServingConfig(batch_size=2, max_seq=32, block_size=8),
        )
        min_w = cfg.d_model * cfg.d_ff // 2
        sch.decode_checker().assert_zero_weight_rounds(min_w)
        # positive control: the same step over per-call (unpacked) params
        # quantizes weights every call
        sch.params = params
        assert sch.decode_checker().weight_round_ops(min_w) > 0


# ---------------------------------------------------------------------------
# Sampling streams: uid-keyed, slot-recycling safe
# ---------------------------------------------------------------------------
class TestSamplingStreams:
    CFG = dict(batch_size=1, max_seq=32, block_size=8, greedy=False, seed=0)

    def test_recycled_slot_does_not_replay_previous_stream(self):
        """batch_size=1 forces the second request through the recycled
        slot; its sample stream must depend only on (seed, uid, step) —
        identical to running it alone in a fresh engine."""
        cfg = _small_cfg()
        params = _params(cfg)
        sch = Scheduler(ARCH, cfg, params, ServingConfig(**self.CFG))
        first, second = _reqs((6, 6), cfg, max_new=8, uid0=11)
        second.prompt = first.prompt.copy()  # same prompt, different uid
        sch.run([first, second])

        fresh = Scheduler(ARCH, cfg, params, ServingConfig(**self.CFG))
        alone = Request(uid=second.uid, prompt=first.prompt, max_new_tokens=8)
        fresh.run([alone])
        assert second.output == alone.output
        # distinct uids on the same prompt sample distinct streams
        assert first.output != second.output

    def test_same_uid_same_prompt_reproduces(self):
        cfg = _small_cfg()
        params = _params(cfg)
        outs = []
        for _ in range(2):
            sch = Scheduler(ARCH, cfg, params, ServingConfig(**self.CFG))
            (r,) = _reqs((7,), cfg, max_new=6, uid0=5)
            sch.run([r])
            outs.append(r.output)
        assert outs[0] == outs[1]


# ---------------------------------------------------------------------------
# Stale-KV admission contract
# ---------------------------------------------------------------------------
class TestStaleKV:
    def test_recycled_blocks_cannot_leak_into_new_request(self):
        """Fill the pool with one request, then plant NaN sentinels in every
        allocatable block: if admission failed to zero the new request's
        blocks, NaN would reach the logits through 0 * v in attention.
        Logits must be bit-identical to a fresh engine."""
        cfg = _small_cfg()
        params = _params(cfg)
        scfg = ServingConfig(
            batch_size=1, max_seq=32, block_size=4, record_logits=True
        )
        sch = Scheduler(ARCH, cfg, params, scfg)
        (warm,) = _reqs((12,), cfg, max_new=4)
        sch.run([warm])
        res = sch.allocator.reserved

        def poison(p):
            if jnp.issubdtype(p.dtype, jnp.floating):
                return p.at[:, res:].set(jnp.nan)
            return p.at[:, res:].set(99)

        sch.kv_pool = jax.tree.map(poison, sch.kv_pool)
        victim = Request(
            uid=1,
            prompt=np.arange(5, dtype=np.int32) % cfg.vocab_size,
            max_new_tokens=4,
        )
        sch.run([victim])

        fresh = Scheduler(ARCH, cfg, params, scfg)
        clean = Request(uid=1, prompt=victim.prompt, max_new_tokens=4)
        fresh.run([clean])
        assert victim.output == clean.output
        for ra, rb in zip(victim.logits, clean.logits):
            assert np.all(np.isfinite(ra))
            np.testing.assert_array_equal(ra, rb)


# ---------------------------------------------------------------------------
# Admission control / backpressure
# ---------------------------------------------------------------------------
class TestAdmission:
    def test_block_backpressure_serializes_and_completes(self):
        cfg = _small_cfg()
        params = _params(cfg)
        # reserved = 1 + 2 (null + trash); 2 allocatable blocks = exactly one
        # request's worst case, so admissions serialize on memory.
        sch = Scheduler(
            ARCH, cfg, params,
            ServingConfig(batch_size=2, max_seq=32, block_size=8, num_blocks=5),
        )
        reqs = _reqs((6, 6, 6), cfg)
        sch.run(reqs)
        assert all(r.done for r in reqs)
        assert sch.stats["completed"] == 3
        assert sch.allocator.free_blocks == 2
        for r in reqs:
            assert r.t_submit <= r.t_first <= r.t_done

    def test_oversized_requests_rejected_at_submit(self):
        cfg = _small_cfg()
        params = _params(cfg)
        sch = Scheduler(
            ARCH, cfg, params,
            ServingConfig(batch_size=1, max_seq=16, block_size=8, num_blocks=3),
        )
        with pytest.raises(ValueError, match="max_seq"):
            sch.submit(Request(uid=0, prompt=np.zeros(15, np.int32)))
        with pytest.raises(ValueError, match="allocatable"):
            sch.submit(Request(uid=0, prompt=np.zeros(9, np.int32), max_new_tokens=2))

    def test_scheduler_rejects_unsupported_families(self):
        mla_arch = registry.get("deepseek-v2-lite-16b")
        mla_cfg = dataclasses.replace(mla_arch.smoke_config, remat=False)
        with pytest.raises(ValueError, match="LegacyEngine"):
            Scheduler(
                mla_arch, mla_cfg, {}, ServingConfig(batch_size=1, max_seq=16)
            )


# ---------------------------------------------------------------------------
# serve.Engine compatibility wrapper routing
# ---------------------------------------------------------------------------
class TestEngineRouting:
    def test_dense_family_routes_to_paged_scheduler(self):
        cfg = _small_cfg()
        eng = serve.Engine(
            ARCH, cfg, _params(cfg), serve.ServeConfig(batch_size=2, max_seq=32)
        )
        assert isinstance(eng.impl, Scheduler)

    def test_mla_family_falls_back_to_legacy(self):
        arch = registry.get("deepseek-v2-lite-16b")
        cfg = dataclasses.replace(arch.smoke_config, remat=False)
        params = init_tree(
            arch.param_defs(cfg), jax.random.PRNGKey(0), cfg.param_dtype
        )
        eng = serve.Engine(
            arch, cfg, params, serve.ServeConfig(batch_size=1, max_seq=16)
        )
        assert isinstance(eng.impl, serve.LegacyEngine)
