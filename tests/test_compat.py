"""Regression tests for the JAX version-compat layer (repro.compat).

These run against whichever JAX is installed — the whole point of the shim
is that the same call sites work on 0.4.x (experimental shard_map, pair-form
AbstractMesh, no AxisType) and on 0.5+/0.6.x (jax.shard_map, check_vma,
axis_types meshes).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import compat
from repro.compat import Mesh, PartitionSpec as P


class TestShardMap:
    def test_identity_on_singleton_mesh(self):
        mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
        x = jnp.arange(8.0).reshape(2, 4)
        out = compat.shard_map(
            lambda t: t * 2.0,
            mesh=mesh, in_specs=(P(),), out_specs=P(), check_vma=False,
        )(x)
        np.testing.assert_allclose(np.asarray(out), np.asarray(x) * 2.0)

    def test_collective_inside_body(self):
        mesh = Mesh(np.array(jax.devices()[:1]), ("pod",))
        x = jnp.ones((4,))
        out = compat.shard_map(
            lambda t: jax.lax.psum(t, "pod"),
            mesh=mesh, in_specs=(P(),), out_specs=P(),
        )(x)
        np.testing.assert_allclose(np.asarray(out), np.ones(4))

    def test_axis_size_concrete_inside_body(self):
        mesh = Mesh(np.array(jax.devices()[:1]), ("pod",))

        def body(t):
            # must be usable in Python control flow at trace time
            assert int(compat.axis_size("pod")) == 1
            return t

        out = compat.shard_map(
            body, mesh=mesh, in_specs=(P(),), out_specs=P(), check_vma=False,
        )(jnp.arange(3.0))
        np.testing.assert_allclose(np.asarray(out), [0.0, 1.0, 2.0])

    def test_default_check_flag_jittable(self):
        mesh = Mesh(np.array(jax.devices()[:1]), ("pod",))
        f = jax.jit(compat.shard_map(
            lambda t: t + 1.0, mesh=mesh, in_specs=(P(),), out_specs=P(),
        ))
        np.testing.assert_allclose(np.asarray(f(jnp.zeros(3))), np.ones(3))


class TestAbstractMesh:
    def test_construction_and_shape(self):
        mesh = compat.abstract_mesh((4, 2), ("data", "model"))
        assert tuple(mesh.axis_names) == ("data", "model")
        assert mesh.shape["data"] == 4
        assert mesh.shape["model"] == 2

    def test_three_axes(self):
        mesh = compat.abstract_mesh((2, 4, 2), ("pod", "data", "model"))
        assert dict(mesh.shape) == {"pod": 2, "data": 4, "model": 2}

    def test_mismatched_lengths_raise(self):
        with pytest.raises(ValueError, match="disagree"):
            compat.abstract_mesh((4, 2), ("data",))

    def test_usable_for_spec_resolution(self):
        from repro.runtime import sharding as shd

        mesh = compat.abstract_mesh((4, 2), ("data", "model"))
        with shd.use_rules(mesh):
            spec = shd.resolve_spec((8, 16), ("batch", "heads"))
        assert spec == P(("data",), "model")


class TestAxisType:
    def test_axis_type_has_auto(self):
        # Real enum on 0.5+, stub enum on 0.4.x — either way Auto must exist
        # because make_mesh defaults every axis to it.
        assert hasattr(compat.AxisType, "Auto")

    def test_make_mesh_singleton(self):
        mesh = compat.make_mesh((1, 1), ("data", "model"))
        assert tuple(mesh.axis_names) == ("data", "model")
        assert mesh.devices.size == 1

    def test_make_mesh_explicit_axis_types(self):
        # Passing axis_types must not crash on any version (it is dropped
        # where unsupported).
        mesh = compat.make_mesh((1,), ("data",), axis_types=(compat.AxisType.Auto,))
        assert mesh.shape["data"] == 1


class TestTreeAliases:
    def test_map_flatten_roundtrip(self):
        tree = {"a": jnp.arange(3), "b": (jnp.zeros(2), jnp.ones(1))}
        doubled = compat.tree_map(lambda x: x * 2, tree)
        np.testing.assert_array_equal(np.asarray(doubled["a"]), [0, 2, 4])
        leaves, treedef = compat.tree_flatten(tree)
        assert len(leaves) == 3
        rebuilt = compat.tree_unflatten(treedef, leaves)
        assert compat.tree_structure(rebuilt) == treedef
        assert len(compat.tree_leaves(tree)) == 3

    def test_version_tuple(self):
        assert isinstance(compat.JAX_VERSION, tuple)
        assert compat.JAX_VERSION >= (0, 4)
