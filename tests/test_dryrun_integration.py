"""Integration test: one real dry-run cell in a subprocess (512 host
devices, production mesh, lower+compile+analyses).  Uses the cheapest cell
(qwen2-0.5b decode) to keep runtime bounded."""

import json
import os
import subprocess
import sys
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parents[1]


@pytest.mark.parametrize("mesh", ["single"])
def test_dryrun_cell_end_to_end(tmp_path, mesh):
    cmd = [
        sys.executable, "-m", "repro.launch.dryrun",
        "--arch", "qwen2-0.5b", "--shape", "decode_32k", "--mesh", mesh,
        "--variant", "pytest", "--force",
    ]
    pythonpath = str(REPO / "src")
    if os.environ.get("PYTHONPATH"):
        pythonpath += os.pathsep + os.environ["PYTHONPATH"]
    r = subprocess.run(
        cmd,
        capture_output=True,
        text=True,
        timeout=900,
        cwd=REPO,
        env={**os.environ, "PYTHONPATH": pythonpath, "XLA_FLAGS": ""},
    )
    # Surface both streams: the cell writes its traceback to stdout (JSON)
    # and import-time crashes (e.g. mesh construction) to stderr.
    assert (
        r.returncode == 0
    ), f"stderr:\n{r.stderr[-3000:]}\nstdout:\n{r.stdout[-2000:]}"
    out = json.loads(
        (
            REPO
            / "results"
            / "dryrun"
            / f"qwen2-0_5b__decode_32k__{mesh}__pytest.json"
        ).read_text()
    )
    assert out["ok"]
    # compiled on 256 chips with analyses populated
    assert out["compile_s"] > 0
    assert out["hlo_flops_per_device"] > 0
    assert out["flops_per_device_exact"] > out["hlo_flops_per_device"] * 0.5
    assert out["argument_size_in_bytes"] > 0
    # per-device argument bytes must fit v5e HBM
    assert out["argument_size_in_bytes"] < 16e9
    # q-head padding recorded (14 -> 16 for TP=16)
    assert out["padded_heads"] == 16
    assert "total_wire_bytes" in out
