"""Quickstart: the photonic DPU GEMM in five minutes.

1. Ask the scalability model (paper Eq.1-3) what DPE size N each
   organization supports at your precision/datarate.
2. Build a DPUConfig and run a GEMM through the photonic datapath.
3. Compare against the exact result; flip organizations and noise.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import scalability as sc
from repro.core.dpu import DPUConfig, noise_sigma_from_snr, photonic_matmul
from repro.kernels.photonic_gemm.ops import photonic_gemm
from repro.noise import build_channel_model


def main():
    from repro.launch import profile

    profile.apply()  # tuned launch env + persistent compilation cache
    print("=== 1. scalability: achievable DPE size N (=M) ===")
    for org in ("ASMW", "MASW", "SMWA"):
        ns = [sc.calibrated_max_n(org, 4, dr) for dr in (1, 5, 10)]
        print(f"  {org}: N @ {{1,5,10}} GS/s = {ns}   (paper Table V: "
              f"{[sc.TABLE_V_N[(org, d)] for d in (1, 5, 10)]})")

    print("\n=== 2. GEMM through the SMWA DPU datapath ===")
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(64, 256)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(256, 128)), jnp.float32)
    exact = x @ w

    cfg = DPUConfig(organization="SMWA", bits=4, datarate_gs=5.0)
    print(
        f"  operating point: N={cfg.n}, M={cfg.m}, "
        f"{cfg.num_slices} slices x {cfg.num_slices} = {cfg.passes} passes, "
        f"{cfg.num_chunks(256)} psum chunks for k=256"
    )
    y = photonic_matmul(x, w, cfg)
    rel = float(jnp.linalg.norm(y - exact) / jnp.linalg.norm(exact))
    print(f"  ideal datapath rel-error vs float GEMM: {rel:.4f} (int8 quantization)")

    y_pallas = photonic_gemm(x, w, cfg, "pallas")  # interpret mode on CPU
    print(
        f"  pallas kernel == ref: "
        f"{bool(jnp.allclose(y_pallas, photonic_gemm(x, w, cfg, 'ref')))}"
    )

    print("\n=== 3. analog noise at the scalability budget ===")
    for mult in (1.0, 4.0):
        sigma = mult * noise_sigma_from_snr(cfg)
        ncfg = DPUConfig(
            organization="SMWA", bits=4, datarate_gs=5.0, noise_sigma_lsb=sigma
        )
        yn = photonic_matmul(x, w, ncfg, prng_key=jax.random.PRNGKey(0))
        rel = float(jnp.linalg.norm(yn - exact) / jnp.linalg.norm(exact))
        print(
            f"  noise {mult:>3.0f}x budget (sigma={sigma:.1f} LSB): rel-error {rel:.4f}"
        )

    print("\n=== 4. the organization-aware channel model (repro.noise) ===")
    for org in ("ASMW", "MASW", "SMWA"):
        ch = build_channel_model(org, n=17, bits=4, datarate_gs=5.0)
        ocfg = DPUConfig(
            organization=org, bits=4, dpe_size=17, channel=ch, noise_seed=0
        )
        yo = photonic_matmul(x, w, ocfg)
        rel = float(jnp.linalg.norm(yo - exact) / jnp.linalg.norm(exact))
        print(
            f"  {org}: through-loss {ch.through_loss_db:.2f} dB, "
            f"sigma {ch.detector_sigma_lsb:.1f} LSB, "
            f"xtalk (im/cw/filt) = ({ch.intermod_eps:.3f}/"
            f"{ch.crossweight_eps:.3f}/{ch.filter_alpha:.3f}) "
            f"-> rel-error {rel:.4f}"
        )

    print("\n=== 5. the execution engine: prepacked weight-stationary GEMM ===")
    from repro.photonic import engine_for, pack_dense  # noqa: E402

    eng = engine_for(cfg, "ref")
    print(f"  {eng.describe()}")
    packed = pack_dense({"w": w}, eng)["w"]
    y_pack = eng.matmul(x, packed, site="demo")
    y_call = eng.matmul_float(x, w, site="demo")
    print(
        f"  prepacked == per-call quantization: "
        f"{bool(jnp.array_equal(y_pack, y_call))}  ({packed})"
    )
    print(
        "  routing policy: "
        f"routes('ffn.wi')={eng.routes('ffn.wi')}, "
        f"routes('ffn.router')={eng.routes('ffn.router')} "
        "(MoE routing stays digital by default)"
    )


if __name__ == "__main__":
    main()
