"""Map real workloads onto an area-matched DPU pool with repro.mapper.

Two end-to-end mappings, printing the per-DPU utilization table each
time:

1. a CNN (ResNet50's im2col GEMM chain from ``core/cnn_workloads``) on
   the paper's best organization (SMWA) and on the unstudied MWAS pool
   that area matching makes much larger — showing how input batching
   turns MWAS's idle silicon into throughput;
2. an LM (qwen2-0.5b's per-layer GEMM sites, lowered with the real
   attention/FFN dependency structure) on the same SMWA pool.

Run:  PYTHONPATH=src python examples/map_workload.py
"""

from repro.core.cnn_workloads import WORKLOADS
from repro.mapper import DpuPool, MapperOptions, WorkloadGraph, map_workload
from repro.models import registry

DATARATE_GS = 5.0


def show(title: str, timeline) -> None:
    print("=" * 72)
    print(title)
    print("=" * 72)
    print(timeline.utilization_table())
    print()


def main():
    # -- CNN: the paper's winner vs the unstudied challenger ----------------
    cnn = WorkloadGraph.from_layers(WORKLOADS["resnet50"](), name="resnet50")
    smwa = DpuPool.area_matched("SMWA", DATARATE_GS)
    mwas = DpuPool.area_matched("MWAS", DATARATE_GS)

    show(
        "ResNet50 on SMWA, batch=1 (the paper's regime)",
        map_workload(cnn, smwa, MapperOptions(batch=1)),
    )
    show(
        "ResNet50 on MWAS, batch=1 (area matching packs in idle DPUs)",
        map_workload(cnn, mwas, MapperOptions(batch=1)),
    )
    show(
        "ResNet50 on MWAS, batch=64 (batching feeds the extra DPUs)",
        map_workload(cnn, mwas, MapperOptions(batch=64)),
    )

    # -- LM: per-layer GEMM sites with real dependency structure ------------
    lm_cfg = registry.get("qwen2-0.5b").config
    lm = WorkloadGraph.from_model_config(lm_cfg, seq_len=256)
    print(f"lowered {lm!r}")
    show(
        "qwen2-0.5b prefill (seq 256) on SMWA, batch=8",
        map_workload(lm, smwa, MapperOptions(batch=8)),
    )

    # The degenerate schedule is the legacy simulator, bit-for-bit.
    degenerate = map_workload(cnn, smwa, MapperOptions.degenerate())
    print(
        f"degenerate (legacy) schedule on SMWA: {degenerate.fps:.1f} FPS, "
        f"{degenerate.fps_per_w:.3f} FPS/W — the batch-1 baseline the "
        "mapper's schedules are measured against"
    )


if __name__ == "__main__":
    main()
