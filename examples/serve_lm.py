"""End-to-end serving driver (the paper's kind: inference).

Serves a reduced qwen2-0.5b through the paged continuous-batching
scheduler (``repro.serving``: block KV cache + chunked prefill
interleaved with decode, DESIGN.md §13), with every weight GEMM routed
through the photonic SMWA DPU datapath (int8, bit-sliced, psum-chunked)
— then repeats with the exact float path and reports agreement +
throughput.

The scheduler is weight-stationary: at construction it prepacks every
policy-routed weight once (``repro.photonic.packing``), so decode steps
stream activations against packed int8 banks and never re-quantize.
Prompts are mixed-length on purpose: the long ones prefill in
token-budgeted chunks while the short ones keep decoding, so no request
waits behind another's prompt.

Run:  PYTHONPATH=src python examples/serve_lm.py
"""

import dataclasses
import time

import jax
import numpy as np

from repro.core.dpu import DPUConfig
from repro.models import registry
from repro.models.common import init_tree
from repro.serving import Request, Scheduler, ServingConfig


def run(photonic: bool, params, arch, cfg, prompts):
    if photonic:
        cfg = dataclasses.replace(
            cfg,
            photonic=DPUConfig(organization="SMWA", bits=4, datarate_gs=5.0),
            photonic_backend="ref",
        )
    sch = Scheduler(
        arch,
        cfg,
        params,
        ServingConfig(batch_size=4, max_seq=64, block_size=16, chunk_tokens=16),
    )
    if sch.photonic is not None:
        print(f"  engine: {sch.photonic.describe()} (weights prepacked once)")
    reqs = [Request(uid=i, prompt=p, max_new_tokens=8) for i, p in enumerate(prompts)]
    t0 = time.time()
    sch.run(reqs)
    dt = time.time() - t0
    toks = sum(len(r.output) for r in reqs)
    return reqs, toks / dt, sch.stats


def main():
    from repro.launch import profile

    profile.apply()  # tuned launch env + persistent compilation cache
    arch = registry.get("qwen2-0.5b")
    cfg = dataclasses.replace(arch.smoke_config, remat=False)
    params = init_tree(arch.param_defs(cfg), jax.random.PRNGKey(0), cfg.param_dtype)
    rng = np.random.default_rng(0)
    lengths = [8, 40, 8, 8, 40, 8, 8, 8]  # long prompts chunk; short ones don't wait
    prompts = [rng.integers(0, cfg.vocab_size, n).astype(np.int32) for n in lengths]

    exact_reqs, exact_tps, stats = run(False, params, arch, cfg, prompts)
    print(f"float path:    {exact_tps:8.1f} tok/s  {stats}")
    photo_reqs, photo_tps, stats = run(True, params, arch, cfg, prompts)
    print(f"photonic path: {photo_tps:8.1f} tok/s  {stats}")

    agree = np.mean([
        np.mean(np.array(a.output) == np.array(b.output))
        for a, b in zip(exact_reqs, photo_reqs)
    ])
    print(f"token agreement photonic vs float: {agree:.2%}")
    print("sample output (req 0):", exact_reqs[0].output)


if __name__ == "__main__":
    main()
