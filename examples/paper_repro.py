"""One-shot paper reproduction: runs every paper experiment and prints a
side-by-side comparison with the published claims.

Run:  PYTHONPATH=src python examples/paper_repro.py
"""

import numpy as np

from repro.core import scalability as sc
from repro.core.simulator import evaluate_all

MODELS = ("googlenet", "resnet50", "mobilenet_v2", "shufflenet_v2")


def gmean(x):
    return float(np.exp(np.mean(np.log(x))))


def main():
    from repro.launch import profile

    profile.apply()  # tuned launch env + persistent compilation cache
    print("=" * 72)
    print("Table V — achievable DPU size N (B=4): ours vs paper")
    print("=" * 72)
    ours = sc.table_v()
    exact = 0
    for (org, dr), n_paper in sorted(sc.TABLE_V_N.items()):
        n = ours[(org, dr)]
        mark = "==" if n == n_paper else f"ours {n}"
        exact += n == n_paper
        print(f"  {org} @ {dr:>2} GS/s: paper N={n_paper:>3}   {mark}")
    print(
        f"  -> {exact}/9 cells exact, calibration residual "
        f"{sc.calibration().mean_abs_rel_err:.1%} mean abs"
    )

    print()
    print("=" * 72)
    print("Fig. 7 — SMWA advantage (gmean | max over 4 CNNs): ours vs paper")
    print("=" * 72)
    res = evaluate_all()
    paper_fps = {(1, "ASMW"): 2.5, (5, "ASMW"): 3.9, (10, "ASMW"): 4.4,
                 (1, "MASW"): 2.3, (5, "MASW"): 3.6, (10, "MASW"): 3.9}
    for dr in (1, 5, 10):
        for other in ("ASMW", "MASW"):
            r = [res[("SMWA", dr, m)].fps / res[(other, dr, m)].fps for m in MODELS]
            print(
                f"  FPS SMWA/{other} @ {dr:>2} GS/s: "
                f"ours g{gmean(r):.2f}/max{max(r):.2f}"
                f"   paper 'up to' {paper_fps[(dr, other)]}x"
            )
    # Trend checks the paper asserts:
    f = lambda o, dr: res[(o, dr, "resnet50")].fps  # noqa: E731
    print(
        "\n  trends: FPS decreases with DR for every org:",
        all(f(o, 1) > f(o, 5) > f(o, 10) for o in ("ASMW", "MASW", "SMWA")),
    )
    print(
        "  trends: MASW slightly beats ASMW everywhere:",
        all(f("MASW", d) >= f("ASMW", d) for d in (1, 5, 10)),
    )


if __name__ == "__main__":
    main()
