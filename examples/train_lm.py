"""Training driver: a small LM on the synthetic pipeline with the full
fault-tolerance stack (checkpoint/resume, straggler watchdog, preemption).

Pass --photonic to train *through* the photonic DPU forward path
(straight-through-estimator backward) — photonic-aware QAT.  Routing is
per-site (repro.photonic.SitePolicy): by default every weight GEMM goes
photonic except MoE routers; narrow it with e.g.
``photonic_include=("ffn.*",)`` on the ModelConfig.

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 200] [--photonic]
"""

import argparse
import dataclasses
import tempfile

from repro.core.dpu import DPUConfig
from repro.data.pipeline import DataConfig
from repro.models import registry
from repro.optim import adamw
from repro.runtime.train_loop import TrainConfig, train


def main():
    from repro.launch import profile

    profile.apply()  # tuned launch env + persistent compilation cache
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--photonic", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    arch = registry.get(args.arch)
    cfg = dataclasses.replace(
        arch.smoke_config,
        num_layers=4, d_model=128, d_ff=256, num_heads=4, num_kv_heads=2,
        vocab_size=512, remat=False,
    )
    if args.photonic:
        cfg = dataclasses.replace(
            cfg,
            photonic=DPUConfig(organization="SMWA", bits=4, datarate_gs=5.0),
            photonic_backend="ref",
        )
    data = DataConfig(vocab_size=cfg.vocab_size, seq_len=64, global_batch=8)
    ckpt_dir = args.ckpt_dir or tempfile.mkdtemp(prefix="repro_train_")
    out = train(
        arch=arch,
        model_cfg=cfg,
        data_cfg=data,
        train_cfg=TrainConfig(steps=args.steps, ckpt_every=50, ckpt_dir=ckpt_dir),
        opt_cfg=adamw.AdamWConfig(lr=1e-3, warmup_steps=20, total_steps=args.steps),
    )
    print(
        f"done: loss {out['losses'][0]:.3f} -> {out['losses'][-1]:.3f}, "
        f"{len(out['straggler_events'])} straggler events, ckpts in {ckpt_dir}"
    )


if __name__ == "__main__":
    main()
