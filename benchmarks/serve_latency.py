"""Decode/serving latency under load: paged scheduler vs legacy engine.

Drives both serving paths (``repro.serving.Scheduler`` with chunked
prefill + paged KV, and ``repro.runtime.serve.LegacyEngine``, the
fixed-slot baseline) through Poisson request arrivals and reports TTFT
(time to first token) and TPOT (per-token decode latency) percentiles.

Grid: batch_size x prompt-length mix x TP degree, at two Poisson load
points calibrated from a measured capacity probe (a moderate point below
capacity and a saturated point above it).  The full sweep asserts the
paged scheduler's p99 TTFT beats the legacy engine on the mixed
long/short workload at the saturated load point — the legacy engine
prefills every admission tiled to the full batch and cannot admit behind
a long prompt, exactly the head-of-line cost paged serving removes.

``--smoke`` shrinks the grid for CI; both modes assert every declared
grid cell produced both arms' metrics (no silent coverage loss).
"""

import argparse
import dataclasses
import time

import jax
import numpy as np

from repro.core.dpu import DPUConfig
from repro.launch import mesh as mesh_mod
from repro.models import registry
from repro.models.common import init_tree
from repro.runtime import serve
from repro.serving import Request, Scheduler, ServingConfig

from benchmarks.run import register_benchmark

MAX_SEQ = 64
BLOCK_SIZE = 16
CHUNK_TOKENS = 32
MAX_NEW = 8
MIXES = {"short": (8, 8), "mixed": (8, 24)}


def _model(smoke):
    arch = registry.get("qwen2-0.5b")
    cfg = dataclasses.replace(
        arch.smoke_config,
        remat=False,
        num_layers=2,
        d_model=64 if smoke else 128,
        num_heads=4,
        num_kv_heads=2,
        d_ff=128 if smoke else 256,
        vocab_size=64 if smoke else 256,
        photonic=DPUConfig(organization="SMWA", bits=4, datarate_gs=5.0),
        photonic_backend="ref",
    )
    params = init_tree(arch.param_defs(cfg), jax.random.PRNGKey(0), cfg.param_dtype)
    return arch, cfg, params


def _workload(mix, n, rate, cfg, seed, uid0=0):
    """(arrival offsets, request factory): lengths and Poisson gaps are
    drawn once per cell so both arms see the identical trace."""
    rng = np.random.default_rng(seed)
    lengths = rng.choice(MIXES[mix], size=n)
    arrivals = np.cumsum(rng.exponential(1.0 / rate, size=n))
    arrivals[0] = 0.0
    prompts = [
        rng.integers(0, cfg.vocab_size, int(n_tok)).astype(np.int32)
        for n_tok in lengths
    ]

    def make():
        return [
            Request(uid=uid0 + i, prompt=p, max_new_tokens=MAX_NEW)
            for i, p in enumerate(prompts)
        ]

    return arrivals, make


def _drive_paged(sch, arrivals, reqs):
    t0 = time.monotonic()
    i = 0
    while i < len(reqs) or sch.pending:
        now = time.monotonic() - t0
        while i < len(reqs) and arrivals[i] <= now:
            sch.submit(reqs[i], t_submit=t0 + arrivals[i])
            i += 1
        if sch.pending:
            sch.step()
        else:
            time.sleep(min(5e-4, max(0.0, arrivals[i] - now)))
    return time.monotonic() - t0


def _drive_legacy(eng, arrivals, reqs):
    t0 = time.monotonic()
    i = 0
    queue = []

    def live():
        return queue or any(s is not None for s in eng.slots)

    while i < len(reqs) or live():
        now = time.monotonic() - t0
        while i < len(reqs) and arrivals[i] <= now:
            reqs[i].t_submit = t0 + arrivals[i]
            queue.append(reqs[i])
            i += 1
        if live():
            eng.step(queue)
        else:
            time.sleep(min(5e-4, max(0.0, arrivals[i] - now)))
    return time.monotonic() - t0


def _metrics(reqs, wall_s):
    ttft = np.asarray([r.t_first - r.t_submit for r in reqs]) * 1e3
    tpot = (
        np.asarray(
            [(r.t_done - r.t_first) / max(len(r.output) - 1, 1) for r in reqs]
        )
        * 1e3
    )
    toks = sum(len(r.output) for r in reqs)
    pct = lambda a, q: round(float(np.percentile(a, q)), 2)  # noqa: E731
    return {
        "ttft_p50_ms": pct(ttft, 50),
        "ttft_p99_ms": pct(ttft, 99),
        "tpot_p50_ms": pct(tpot, 50),
        "tpot_p99_ms": pct(tpot, 99),
        "throughput_tok_s": round(toks / wall_s, 1),
        "ttft_ms": [round(float(x), 3) for x in ttft],
    }


def _paged_engine(arch, cfg, params, bs, mesh):
    return Scheduler(
        arch, cfg, params,
        ServingConfig(
            batch_size=bs, max_seq=MAX_SEQ, block_size=BLOCK_SIZE,
            chunk_tokens=CHUNK_TOKENS,
        ),
        mesh=mesh, tp_axis="model",
    )


def _legacy_engine(arch, cfg, params, bs, mesh):
    return serve.LegacyEngine(
        arch, cfg, params, serve.ServeConfig(batch_size=bs, max_seq=MAX_SEQ),
        mesh=mesh, tp_axis="model",
    )


def _probe_capacity(arch, cfg, params, bs, n):
    """Requests/s the paged engine sustains on an all-at-once burst — the
    anchor for the Poisson load points (also warms the compile caches)."""
    sch = _paged_engine(arch, cfg, params, bs, None)
    arrivals, make = _workload("mixed", n, 1e9, cfg, seed=7)
    reqs = make()
    wall = _drive_paged(sch, np.zeros_like(arrivals), reqs)
    return n / wall


def _grid(smoke, tp_max):
    batch_sizes = [2] if smoke else [2, 4]
    mixes = ["mixed"] if smoke else ["short", "mixed"]
    n_loads = 1 if smoke else 2
    tps = [1] + ([tp_max] if tp_max > 1 else [])
    cells = []
    for tp in tps:
        for bs in batch_sizes:
            for mix in mixes:
                # TP cells: reduced subgrid (largest batch, mixed only)
                if tp > 1 and (bs != batch_sizes[-1] or mix != "mixed"):
                    continue
                for load in range(n_loads):
                    cells.append((tp, bs, mix, load))
    return cells


def _cell_key(tp, bs, mix, load):
    return f"tp{tp}/bs{bs}/{mix}/load{load}"


@register_benchmark("serve_latency")
def main(smoke=False):
    arch, cfg, params = _model(smoke)
    tp_max = mesh_mod.max_tp_degree()
    n_req = 4 if smoke else 12

    capacity = _probe_capacity(arch, cfg, params, bs=2, n=3 if smoke else 6)
    load_factors = [1.5] if smoke else [0.7, 1.5]
    rates = [capacity * f for f in load_factors]

    cells = _grid(smoke, tp_max)
    paged_engines, legacy_engines = {}, {}
    results = {}
    print("serve_latency,cell,arm,ttft_p50_ms,ttft_p99_ms,tpot_p50_ms,tok_s")
    for idx, (tp, bs, mix, load) in enumerate(cells):
        mesh = mesh_mod.make_tp_smoke_mesh() if tp > 1 else None
        key = _cell_key(tp, bs, mix, load)
        if (tp, bs) not in paged_engines:
            paged_engines[(tp, bs)] = _paged_engine(arch, cfg, params, bs, mesh)
            legacy_engines[(tp, bs)] = _legacy_engine(arch, cfg, params, bs, mesh)
        rate = rates[load]
        arrivals, make = _workload(mix, n_req, rate, cfg, seed=100 + idx)

        paged_reqs = make()
        paged_wall = _drive_paged(paged_engines[(tp, bs)], arrivals, paged_reqs)
        legacy_reqs = make()
        legacy_wall = _drive_legacy(legacy_engines[(tp, bs)], arrivals, legacy_reqs)

        cell = {
            "rate_req_s": round(rate, 2),
            "paged": _metrics(paged_reqs, paged_wall),
            "legacy": _metrics(legacy_reqs, legacy_wall),
        }
        results[key] = cell
        for arm in ("paged", "legacy"):
            m = cell[arm]
            print(
                f"serve_latency,{key},{arm},{m['ttft_p50_ms']},"
                f"{m['ttft_p99_ms']},{m['tpot_p50_ms']},{m['throughput_tok_s']}"
            )

    # -- grid coverage: every declared cell produced both arms' metrics ------
    expected = {_cell_key(*c) for c in cells}
    missing = expected - set(results)
    assert not missing, f"serve_latency grid cells missing: {sorted(missing)}"
    for key, cell in results.items():
        for arm in ("paged", "legacy"):
            for field in ("ttft_p50_ms", "ttft_p99_ms", "tpot_p50_ms"):
                assert field in cell[arm], f"{key}/{arm} lacks {field}"

    # -- headline: mixed workload at the saturated load point ----------------
    sat = len(load_factors) - 1
    pool_paged, pool_legacy = [], []
    for (tp, bs, mix, load) in cells:
        if mix == "mixed" and load == sat:
            cell = results[_cell_key(tp, bs, mix, load)]
            pool_paged += cell["paged"]["ttft_ms"]
            pool_legacy += cell["legacy"]["ttft_ms"]
    p99_paged = round(float(np.percentile(pool_paged, 99)), 2)
    p99_legacy = round(float(np.percentile(pool_legacy, 99)), 2)
    ratio = round(p99_legacy / p99_paged, 3) if p99_paged else float("inf")
    print(
        f"# mixed@saturated p99 TTFT: paged={p99_paged}ms "
        f"legacy={p99_legacy}ms ({ratio}x)"
    )
    if not smoke:
        assert p99_paged < p99_legacy, (
            f"paged p99 TTFT ({p99_paged}ms) not below legacy "
            f"({p99_legacy}ms) on the mixed saturated workload"
        )

    for cell in results.values():  # samples stay out of the committed report
        for arm in ("paged", "legacy"):
            cell[arm].pop("ttft_ms")
    return {
        "capacity_req_s": round(capacity, 2),
        "load_factors": load_factors,
        "n_requests_per_cell": n_req,
        "mixed_saturated_p99_ttft_ms": {
            "paged": p99_paged, "legacy": p99_legacy, "legacy_over_paged": ratio
        },
        "cells": results,
    }


if __name__ == "__main__":
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--smoke", action="store_true")
    main(smoke=parser.parse_args().smoke)
