"""Paper Fig. 5 — supported DPU size N (=M) vs bit precision B at
DR in {1, 5, 10} GS/s for ASMW / MASW / SMWA."""

import time

from repro.core import scalability as sc

from benchmarks.run import register_benchmark


def run(csv=True, drs=(1, 5, 10), bits=tuple(range(1, 9))):
    rows = []
    t0 = time.time()
    for dr in drs:
        for b in bits:
            n = {
                org: sc.calibrated_max_n(org, b, dr)
                for org in ("ASMW", "MASW", "SMWA")
            }
            rows.append((dr, b, n["ASMW"], n["MASW"], n["SMWA"]))
    us = (time.time() - t0) * 1e6 / len(rows)
    if csv:
        print("fig5_scalability,N_vs_B_per_DR")
        print("dr_gs,bits,N_ASMW,N_MASW,N_SMWA")
        for r in rows:
            print(",".join(str(x) for x in r))
        print(f"# us_per_cell={us:.1f}")
    return rows


@register_benchmark("fig5_scalability")
def main(smoke=False):
    rows = run(drs=(5,), bits=(2, 4, 8)) if smoke else run()
    # validation hooks (also asserted in tests)
    for dr, b, a, m, s in rows:
        assert s >= m >= a, (dr, b, a, m, s)
    return {
        "cells": len(rows),
        "n_at_b4": {
            f"dr{dr}": {"ASMW": a, "MASW": m, "SMWA": s}
            for dr, b, a, m, s in rows
            if b == 4
        },
    }


if __name__ == "__main__":
    main()
