"""Paper Table V — DPU size N and area-proportionate DPU count at B=4
across datarates, plus our independent area-model cross-check."""

import time

from repro.core import scalability as sc
from repro.core.perfmodel import area_matched_counts

from benchmarks.run import register_benchmark


def run():
    print("table5,ours_vs_paper")
    print("org,dr_gs,N_ours,N_paper,count_paper,count_area_model")
    t0 = time.time()
    ours = sc.table_v()
    for (org, dr), n_paper in sorted(sc.TABLE_V_N.items()):
        matched = area_matched_counts(dr)
        print(
            f"{org},{dr},{ours[(org, dr)]},{n_paper},"
            f"{sc.TABLE_V_COUNT[(org, dr)]},{matched[org]}"
        )
    print(f"# us_total={(time.time()-t0)*1e6:.0f}")
    return ours


@register_benchmark("table5_dpu")
def main(smoke=False):
    del smoke  # already CI-sized (9 closed-form cells)
    ours = run()
    exact = sum(ours[k] == v for k, v in sc.TABLE_V_N.items())
    print(f"# exact_cells={exact}/9")
    assert exact >= 7
    return {
        "exact_cells": exact,
        "n": {f"{org}_dr{dr}": n for (org, dr), n in ours.items()},
    }


if __name__ == "__main__":
    main()
