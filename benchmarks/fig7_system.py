"""Paper Fig. 7 — normalized FPS, FPS/W, FPS/W/mm^2 for the four CNNs on
ASMW/MASW/SMWA at 1/5/10 GS/s (area-proportionate configuration).

Normalization matches the paper: ASMW running ResNet50 at 10 GS/s = 1.
Area efficiency uses the paper's equal-area construction (all accelerators
matched to SMWA's area at that DR), so FPS/W/mm^2 ratios track FPS/W; our
independent area model is reported by table5_dpu.py.
"""

import time

import numpy as np

from repro.core.perfmodel import AcceleratorConfig
from repro.core.simulator import evaluate_all

from benchmarks.run import register_benchmark

MODELS = ("googlenet", "resnet50", "mobilenet_v2", "shufflenet_v2")
ORGS = ("ASMW", "MASW", "SMWA")
DRS = (1, 5, 10)


def gmean(xs):
    return float(np.exp(np.mean(np.log(xs))))


def run(models=MODELS, drs=DRS):
    t0 = time.time()
    res = evaluate_all(models=models, datarates=drs)
    sim_us = (time.time() - t0) * 1e6 / len(res)

    base = res[
        ("ASMW", max(drs), models[0] if "resnet50" not in models else "resnet50")
    ]
    matched_area = {
        dr: AcceleratorConfig.from_paper("SMWA", dr).total_area_mm2() for dr in drs
    }

    print("fig7_system,normalized_to_ASMW_resnet50_10GS")
    print("org,dr_gs,model,norm_fps,norm_fps_per_w,norm_fps_per_w_per_mm2")
    for (org, dr, m), r in sorted(res.items()):
        nf = r.fps / base.fps
        nw = r.fps_per_w / base.fps_per_w
        na = (r.fps_per_w / matched_area[dr]) / (
            base.fps_per_w / matched_area[max(drs)]
        )
        print(f"{org},{dr},{m},{nf:.3f},{nw:.3f},{na:.3f}")

    print("ratios,SMWA_vs_other (gmean over CNNs | max)")
    summary = {}
    for dr in drs:
        for other in ("ASMW", "MASW"):
            rf = [res[("SMWA", dr, m)].fps / res[(other, dr, m)].fps for m in models]
            rw = [
                res[("SMWA", dr, m)].fps_per_w / res[(other, dr, m)].fps_per_w
                for m in models
            ]
            summary[(dr, other)] = (gmean(rf), max(rf), gmean(rw), max(rw))
            print(
                f"SMWA/{other}@{dr}GS/s,fps_g={gmean(rf):.2f},fps_max={max(rf):.2f},"
                f"fpw_g={gmean(rw):.2f},fpw_max={max(rw):.2f}"
            )
    print(f"# us_per_sim={sim_us:.0f}")
    return summary


@register_benchmark("fig7_system")
def main(smoke=False):
    if smoke:
        summary = run(models=("shufflenet_v2", "resnet50"), drs=(1, 10))
    else:
        summary = run()
    # Paper-claim direction checks (magnitude comparison in EXPERIMENTS.md):
    for (dr, other), (fg, fm, wg, wm) in summary.items():
        assert fg > 1.0, f"SMWA must beat {other} on FPS at {dr} GS/s"
    # ratio grows with datarate (paper: 2.5x -> 3.9x -> 4.4x vs ASMW)
    assert summary[(10, "ASMW")][0] > summary[(1, "ASMW")][0]
    return {
        f"SMWA_vs_{other}_dr{dr}": {
            "fps_gmean": round(fg, 3),
            "fps_max": round(fm, 3),
            "fps_per_w_gmean": round(wg, 3),
            "fps_per_w_max": round(wm, 3),
        }
        for (dr, other), (fg, fm, wg, wm) in sorted(summary.items())
    }


if __name__ == "__main__":
    main()
