"""Benchmark harness — one entry per paper table/figure (deliverable d),
plus the dry-run roofline report and the organization-accuracy sweep.

Prints ``name,us_per_call,derived`` CSV blocks per benchmark and writes a
machine-readable ``results/BENCH_photonic.json`` (per-bench wall time +
derived metrics) so the perf/accuracy trajectory is tracked across PRs.

``--smoke`` shrinks every sweep to a CI-sized subset (used by the CI
benchmark-smoke step to catch bit-rot without the full runtime).
"""

import argparse
import json
import sys
import time
import traceback
from pathlib import Path

RESULTS_DIR = Path(__file__).resolve().parents[1] / "results"


def main(argv=None) -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument(
        "--smoke", action="store_true", help="shrink sweeps to a CI-sized subset"
    )
    args = parser.parse_args(argv)

    # One tuned launch profile for every bench (allocator detection, log
    # hygiene, persistent JAX compilation cache) — recorded in the JSON so
    # committed numbers name the environment that produced them.
    from repro.launch import profile

    launch_profile = profile.apply()

    from benchmarks import (
        fig5_scalability,
        fig7_system,
        fused_hotpath,
        noise_accuracy,
        org_accuracy,
        org_design_space,
        prepack_decode,
        serve_latency,
        table5_dpu,
        tp_scaling,
    )

    benches = [
        ("fig5_scalability", fig5_scalability.main),
        ("table5_dpu", table5_dpu.main),
        ("fig7_system", fig7_system.main),
        ("noise_accuracy", noise_accuracy.main),
        ("org_accuracy", org_accuracy.main),
        ("org_design_space", org_design_space.main),
        ("prepack_decode", prepack_decode.main),
        ("fused_hotpath", fused_hotpath.main),
        ("serve_latency", serve_latency.main),
        ("tp_scaling", tp_scaling.main),
    ]
    # roofline report requires dry-run results; degrade gracefully.
    try:
        from benchmarks import roofline_report

        benches.append(("roofline_report", roofline_report.main))
    except Exception:
        pass

    failures = []
    report = {"smoke": args.smoke, "launch_profile": launch_profile, "benches": {}}
    for name, fn in benches:
        print(f"\n===== {name} =====")
        t0 = time.time()
        derived = None
        try:
            derived = fn(smoke=args.smoke)
            status = "ok"
            print(f"{name},{(time.time()-t0)*1e6:.0f},ok")
        except Exception:
            failures.append(name)
            status = "failed"
            traceback.print_exc()
            print(f"{name},{(time.time()-t0)*1e6:.0f},FAILED")
        report["benches"][name] = {
            "wall_s": round(time.time() - t0, 3),
            "status": status,
            "derived": derived,
        }

    RESULTS_DIR.mkdir(parents=True, exist_ok=True)
    # Smoke runs land in a separate (gitignored) file so the committed
    # trajectory only ever contains full-sweep numbers.
    name = "BENCH_photonic_smoke.json" if args.smoke else "BENCH_photonic.json"
    out_path = RESULTS_DIR / name
    out_path.write_text(json.dumps(report, indent=1, default=str))
    print(f"\nwrote {out_path}")

    if failures:
        print("FAILED:", failures)
        sys.exit(1)
    print("all benchmarks ok")


if __name__ == "__main__":
    main()
