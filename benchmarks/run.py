"""Benchmark harness — one entry per paper table/figure (deliverable d),
plus the dry-run roofline report and the organization-accuracy sweep.

Benchmarks self-register: each module decorates its entry point with
:func:`register_benchmark`, which validates the ``main(smoke=False) ->
dict`` contract at registration time (a bad signature fails at import,
not halfway through a sweep).  The harness imports the benchmark modules
and iterates the registry in registration order — there is no
hand-maintained dispatch table to drift out of sync.

Prints ``name,us_per_call,derived`` CSV blocks per benchmark and writes a
machine-readable ``results/BENCH_photonic.json`` (per-bench wall time +
derived metrics) so the perf/accuracy trajectory is tracked across PRs.

``--smoke`` shrinks every sweep to a CI-sized subset (used by the CI
benchmark-smoke step to catch bit-rot without the full runtime).
"""

import argparse
import inspect
import json
import sys
import time
import traceback
from pathlib import Path

RESULTS_DIR = Path(__file__).resolve().parents[1] / "results"

# name -> main, in registration (== module import) order.
_REGISTRY: "dict[str, object]" = {}


def register_benchmark(name: str):
    """Register ``fn`` as the benchmark ``name``'s entry point.

    Validates the harness contract eagerly: ``fn`` must accept a
    ``smoke`` keyword defaulting to ``False`` (the CI-sized subset
    switch) — and at run time must return a ``dict`` of derived metrics
    (the CI coverage asserts read ``report["benches"][name]["derived"]``).
    Duplicate names raise at import so two modules cannot silently fight
    over one report key.
    """
    if not isinstance(name, str) or not name:
        raise TypeError(f"benchmark name must be a non-empty str, got {name!r}")

    def deco(fn):
        if name in _REGISTRY:
            raise ValueError(f"benchmark {name!r} is already registered")
        try:
            param = inspect.signature(fn).parameters.get("smoke")
        except (TypeError, ValueError):  # builtins/partials without a signature
            param = None
        if (
            param is None
            or param.default is not False
            or param.kind
            not in (
                inspect.Parameter.POSITIONAL_OR_KEYWORD,
                inspect.Parameter.KEYWORD_ONLY,
            )
        ):
            raise TypeError(
                f"benchmark {name!r} entry point must accept a smoke= keyword "
                f"defaulting to False (got signature {fn})"
            )
        _REGISTRY[name] = fn
        return fn

    return deco


def registered_benchmarks() -> "dict[str, object]":
    """The canonical registry — read from the ``benchmarks.run`` module
    instance the benchmark modules decorated into, which is NOT this
    module's globals when run.py executes as ``__main__``."""
    from benchmarks import run as canonical

    return dict(canonical._REGISTRY)


def main(argv=None) -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument(
        "--smoke", action="store_true", help="shrink sweeps to a CI-sized subset"
    )
    parser.add_argument(
        "--select",
        metavar="NAME",
        help="run a single registered benchmark (its report lands in "
        "results/BENCH_photonic.NAME[_smoke].json so a partial run never "
        "clobbers the committed full-sweep trajectory)",
    )
    args = parser.parse_args(argv)

    # One tuned launch profile for every bench (allocator detection, log
    # hygiene, persistent JAX compilation cache) — recorded in the JSON so
    # committed numbers name the environment that produced them.
    from repro.launch import profile

    launch_profile = profile.apply()

    # Importing a benchmark module registers its entry point.
    from benchmarks import (  # noqa: F401
        fig5_scalability,
        fig7_system,
        fused_hotpath,
        mapper_throughput,
        noise_accuracy,
        org_accuracy,
        org_design_space,
        prepack_decode,
        serve_latency,
        table5_dpu,
        tp_scaling,
    )

    # roofline report requires dry-run results; degrade gracefully.
    try:
        from benchmarks import roofline_report  # noqa: F401
    except Exception:
        pass

    selected = registered_benchmarks()
    if args.select is not None:
        if args.select not in selected:
            parser.error(
                f"unknown benchmark {args.select!r}; registered: "
                f"{', '.join(selected)}"
            )
        selected = {args.select: selected[args.select]}

    failures = []
    report = {"smoke": args.smoke, "launch_profile": launch_profile, "benches": {}}
    for name, fn in selected.items():
        print(f"\n===== {name} =====")
        t0 = time.time()
        derived = None
        try:
            derived = fn(smoke=args.smoke)
            if not isinstance(derived, dict):
                raise TypeError(
                    f"benchmark {name!r} returned {type(derived).__name__}, "
                    f"expected a dict of derived metrics"
                )
            status = "ok"
            print(f"{name},{(time.time()-t0)*1e6:.0f},ok")
        except Exception:
            failures.append(name)
            status = "failed"
            traceback.print_exc()
            print(f"{name},{(time.time()-t0)*1e6:.0f},FAILED")
        report["benches"][name] = {
            "wall_s": round(time.time() - t0, 3),
            "status": status,
            "derived": derived,
        }

    RESULTS_DIR.mkdir(parents=True, exist_ok=True)
    # Smoke runs land in a separate (gitignored) file so the committed
    # trajectory only ever contains full-sweep numbers; --select runs are
    # likewise namespaced (and gitignored) so a single-bench rerun never
    # rewrites the committed report.
    suffix = "_smoke" if args.smoke else ""
    if args.select is not None:
        name = f"BENCH_photonic.{args.select}{suffix}.json"
    else:
        name = f"BENCH_photonic{suffix}.json"
    out_path = RESULTS_DIR / name
    out_path.write_text(json.dumps(report, indent=1, default=str))
    print(f"\nwrote {out_path}")

    if failures:
        print("FAILED:", failures)
        sys.exit(1)
    print("all benchmarks ok")


if __name__ == "__main__":
    main()
