"""Benchmark harness — one entry per paper table/figure (deliverable d),
plus the dry-run roofline report.  Prints ``name,us_per_call,derived`` CSV
blocks per benchmark.
"""

import sys
import time
import traceback


def main() -> None:
    from benchmarks import fig5_scalability, fig7_system, noise_accuracy, table5_dpu

    benches = [
        ("fig5_scalability", fig5_scalability.main),
        ("table5_dpu", table5_dpu.main),
        ("fig7_system", fig7_system.main),
        ("noise_accuracy", noise_accuracy.main),
    ]
    # roofline report requires dry-run results; degrade gracefully.
    try:
        from benchmarks import roofline_report

        benches.append(("roofline_report", roofline_report.main))
    except Exception:
        pass

    failures = []
    for name, fn in benches:
        print(f"\n===== {name} =====")
        t0 = time.time()
        try:
            fn()
            print(f"{name},{(time.time()-t0)*1e6:.0f},ok")
        except Exception:
            failures.append(name)
            traceback.print_exc()
            print(f"{name},{(time.time()-t0)*1e6:.0f},FAILED")
    if failures:
        print("FAILED:", failures)
        sys.exit(1)
    print("\nall benchmarks ok")


if __name__ == "__main__":
    main()
