"""Org design space re-run under the mapper: does batching dethrone SMWA?

PR 5's sweep (``org_design_space.py``) found that MWAS — an unstudied
ordering — beats SMWA on the physics (fewer through devices, a fraction
of the rings, better SNR at matched N) but loses on FPS/W because the
batch-1, layer-at-a-time schedule cannot feed the extra cheap DPUs that
area matching packs in: idle silicon burns laser power.  That is a
*schedule* conclusion, not a physics one.  This benchmark re-decides it
with the real scheduler: ``repro.mapper`` maps ResNet50 onto equal-area
pools (every ordering matched to the paper's SOI SMWA silicon at DR=5)
with input batching, amortization-priced replication, double-buffered
psum accumulation and cross-layer DAG dispatch, at batch ∈ {1, 4, 16,
64} x all 12 S/A/M/W orderings x {SOI, SiN}.

Headline finding (committed in results/BENCH_photonic.json): the winner
table reports, per (batch, platform), the FPS/W-best ordering and
whether any unstudied order overtakes SMWA once its DPUs can actually be
fed — either outcome is a result; the assert is grid completeness.

Also asserted here: the degenerate-schedule contract — the mapper with
``MapperOptions.degenerate()`` reproduces ``core/simulator.simulate``
exactly for the paper orgs (the bitwise pin lives in
``tests/test_mapper.py``; this is the in-benchmark cross-check).

``--smoke`` shrinks to {1, 16} x (3 paper orders + MWAS) x both
platforms; CI asserts that coverage and uploads the timeline artifact
(``results/mapper_timeline[_smoke].json``).
"""

import json
import time

from repro.core.cnn_workloads import WORKLOADS
from repro.core.perfmodel import AcceleratorConfig
from repro.core.simulator import simulate
from repro.mapper import DpuPool, MapperOptions, WorkloadGraph, map_workload
from repro.orgs import ORGANIZATIONS, valid_orderings

from benchmarks.run import RESULTS_DIR, register_benchmark

BITS = 4
MODEL = "resnet50"
DATARATE_GS = 5.0
BATCHES = (1, 4, 16, 64)
PLATFORMS = ("SOI", "SIN")
SMOKE_BATCHES = (1, 16)
SMOKE_ORDERS = ("ASMW", "MASW", "SMWA", "MWAS")


def _cell(graph: WorkloadGraph, order: str, platform: str, batch: int) -> dict:
    pool = DpuPool.area_matched(
        order, DATARATE_GS, bits=BITS, platform=platform
    )
    timeline = map_workload(graph, pool, MapperOptions(batch=batch))
    d = timeline.to_dict()
    return {
        "order": order,
        "platform": platform,
        "batch": batch,
        "paper_org": order in ORGANIZATIONS,
        "n": d["n"],
        "pool_size": d["pool_size"],
        "fps": round(d["fps"], 3),
        "fps_per_w": round(d["fps_per_w"], 5),
        "avg_power_w": round(d["avg_power_w"], 3),
        "mean_utilization": round(d["mean_utilization"], 5),
        "makespan_ms": round(d["makespan_s"] * 1e3, 6),
    }


def _degenerate_crosscheck() -> dict:
    """Mapper degenerate schedule == legacy simulator, exactly (SOI paper
    orgs at the Table V operating points; the full 36-cell bitwise pin is
    in tests/test_mapper.py)."""
    graph = WorkloadGraph.from_layers(WORKLOADS[MODEL](), name=MODEL)
    checked = {}
    for order in ORGANIZATIONS:
        cfg = AcceleratorConfig.from_paper(order, DATARATE_GS)
        ref = simulate(MODEL, cfg)
        timeline = map_workload(
            graph, DpuPool.from_config(cfg), MapperOptions.degenerate()
        )
        assert timeline.fps == ref.fps, (order, timeline.fps, ref.fps)
        assert timeline.fps_per_w == ref.fps_per_w, order
        assert timeline.dynamic_energy_j == ref.dynamic_energy_j, order
        checked[order] = round(ref.fps, 3)
    return checked


@register_benchmark("mapper_throughput")
def main(smoke: bool = False) -> dict:
    batches = SMOKE_BATCHES if smoke else BATCHES
    orders = (
        SMOKE_ORDERS if smoke else tuple(s.name for s in valid_orderings())
    )
    t0 = time.time()
    graph = WorkloadGraph.from_layers(WORKLOADS[MODEL](), name=MODEL)

    cells = {}
    print("mapper_throughput,org_design_space_under_the_mapper")
    print("order,platform,batch,n,pool,fps,fps_per_w,util,makespan_ms")
    for platform in PLATFORMS:
        for order in orders:
            for batch in batches:
                c = _cell(graph, order, platform, batch)
                cells[f"{order}_{platform}_b{batch}"] = c
                print(
                    f"{order},{platform},{batch},{c['n']},{c['pool_size']},"
                    f"{c['fps']},{c['fps_per_w']},{c['mean_utilization']},"
                    f"{c['makespan_ms']}"
                )

    # -- winner table: per (batch, platform), the FPS/W-best ordering -------
    winners = {}
    smwa_dethroned = {}
    for platform in PLATFORMS:
        for batch in batches:
            group = [
                c
                for c in cells.values()
                if c["platform"] == platform and c["batch"] == batch
            ]
            best = max(group, key=lambda c: c["fps_per_w"])
            smwa = next(c for c in group if c["order"] == "SMWA")
            key = f"{platform}_b{batch}"
            winners[key] = {
                "order": best["order"],
                "fps_per_w": best["fps_per_w"],
                "paper_org": best["paper_org"],
                "vs_smwa": round(best["fps_per_w"] / smwa["fps_per_w"], 4),
            }
            smwa_dethroned[key] = best["order"] != "SMWA"
            print(
                f"# winner {key}: {best['order']} "
                f"({best['fps_per_w']} FPS/W, "
                f"{winners[key]['vs_smwa']}x SMWA)"
            )

    mwas_vs_smwa = {
        f"{platform}_b{batch}": round(
            cells[f"MWAS_{platform}_b{batch}"]["fps_per_w"]
            / cells[f"SMWA_{platform}_b{batch}"]["fps_per_w"],
            4,
        )
        for platform in PLATFORMS
        for batch in batches
        if f"MWAS_{platform}_b{batch}" in cells
    }
    degenerate_fps = _degenerate_crosscheck()
    print(f"# smwa_dethroned: {smwa_dethroned}")
    print(f"# mwas_vs_smwa_fps_per_w: {mwas_vs_smwa}")
    print(f"# degenerate_crosscheck_fps: {degenerate_fps}")
    print(f"# total_s={time.time() - t0:.1f}")

    # -- timeline artifact (per-DPU schedules; CI uploads it) ---------------
    artifact = {
        f"{order}_{platform}": map_workload(
            graph,
            DpuPool.area_matched(
                order, DATARATE_GS, bits=BITS, platform=platform
            ),
            MapperOptions(batch=max(batches)),
        ).to_dict()
        for platform in PLATFORMS
        for order in ("SMWA", "MWAS")
    }
    RESULTS_DIR.mkdir(parents=True, exist_ok=True)
    artifact_path = (
        RESULTS_DIR / f"mapper_timeline{'_smoke' if smoke else ''}.json"
    )
    artifact_path.write_text(json.dumps(artifact, indent=1))
    print(f"# wrote {artifact_path}")

    # Acceptance: the grid is complete — every requested (order, platform,
    # batch) cell is present; batch 1 AND a batch > 1 ran on both
    # platforms; at least one novel ordering is in the grid.
    assert len(cells) == len(orders) * len(PLATFORMS) * len(batches), cells
    assert any(not c["paper_org"] for c in cells.values()), orders
    assert {1} < set(batches), batches

    return {
        "bits": BITS,
        "model": MODEL,
        "datarate_gs": DATARATE_GS,
        "batches": list(batches),
        "platforms": list(PLATFORMS),
        "orders": sorted(set(orders)),
        "winners": winners,
        "smwa_dethroned": smwa_dethroned,
        "mwas_vs_smwa_fps_per_w": mwas_vs_smwa,
        "degenerate_crosscheck_fps": degenerate_fps,
        "cells": cells,
    }


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true")
    main(smoke=ap.parse_args().smoke)
