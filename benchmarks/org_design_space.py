"""Beyond-paper design-space sweep: every valid S/A/M/W ordering.

The paper studies three block orders (ASMW / MASW / SMWA) out of the
twelve valid orderings of Splitting, Aggregation, Modulation, Weighting
(M before W, terminal Σ).  With :class:`repro.orgs.OrgSpec` deriving the
Table II/III/IV profiles structurally from the order, the other nine
become *evaluable*: this sweep runs the full space at the Table V
operating points (B=4, DR in {1, 5, 10} GS/s) and reports, per ordering:

* the derived circuit profile (crosstalk mechanisms, through-device
  formula, waveguide-length factor, lumped penalty);
* the achievable DPE size N from the calibrated Eq. 1–3 solver;
* the delivered-power SNR of the channel model at that N;
* ResNet50 FPS and FPS/W from the event-driven simulator, with the DPU
  count *area-matched* to the paper's SMWA configuration at each DR (the
  paper's own area-proportionate comparison, extended to the full space).

Headline question: does any unstudied ordering beat the paper's best
(SMWA)?  Finding (quantified below, asserted structurally): **no**.  The
filter-only family {SMWA, MSWA, MWSA, MWAS} jointly maximizes achievable
N; **MWAS** (weighting before aggregation AND a non-terminal mux) even
edges SMWA on the physics — one out-of-resonance through device instead
of two, a fraction of the rings (2N per DPU vs 3N·M) and marginally
better SNR at matched N — but its sparse DPUs are so small that area
matching packs in far more of them than the batch-1 output-stationary
schedule can feed, and the per-DPU laser power of the idle columns sinks
its FPS/W below SMWA's.  The paper's choice is the optimum of the full
order space under its own area-proportionate comparison; the margin by
which, and the laser-bound reason why, are what this sweep adds.

``--smoke`` shrinks the grid (DR=5 only) for the CI leg; the smoke JSON
still contains every ordering — all 3 paper orgs plus the 9 novel ones —
which CI asserts.
"""

import dataclasses
import time

from repro.core import scalability as sc
from repro.core.perfmodel import AcceleratorConfig, area_matched_count
from repro.core.simulator import simulate
from repro.noise import build_channel_model
from repro.orgs import ORGANIZATIONS, valid_orderings

from benchmarks.run import register_benchmark

BITS = 4
MODEL = "resnet50"


def sweep_cell(spec, dr: int, target_area_mm2: float) -> dict:
    """One (ordering, datarate) cell of the design space."""
    n = sc.calibrated_max_n(spec, BITS, dr)
    cell = {
        "order": spec.name,
        "paper_org": spec.name in ORGANIZATIONS,
        "crosstalk": {
            "inter_modulation": spec.inter_modulation,
            "cross_weight": spec.cross_weight,
            "filter_truncation": spec.filter_truncation,
        },
        "through_devices": spec.through_devices,
        "waveguide_length_factor": spec.waveguide_length_factor,
        "penalty_db": spec.derived_penalty_db,
        "rings_per_dpu_at_n": None,
        "n": n,
    }
    if n <= 0:
        cell["feasible"] = False
        return cell
    cell["feasible"] = True
    ch = build_channel_model(spec, n=n, bits=BITS, datarate_gs=dr)
    cell["snr_db"] = round(ch.snr_db, 3)
    cell["delivered_dbm"] = round(ch.delivered_dbm, 3)
    cell["detector_sigma_lsb"] = round(ch.detector_sigma_lsb, 5)

    # Area-matched system: same silicon as the paper's SMWA point at this DR.
    cfg = AcceleratorConfig(
        organization=spec.name, datarate_gs=dr, bits=BITS, n=n, m=n
    )
    cfg = dataclasses.replace(
        cfg, dpu_count=area_matched_count(cfg, target_area_mm2)
    )
    cell["rings_per_dpu_at_n"] = spec.rings_per_dpu(n, n)
    cell["dpu_count_area_matched"] = cfg.dpu_count
    res = simulate(MODEL, cfg)
    cell["fps"] = round(res.fps, 3)
    cell["fps_per_w"] = round(res.fps_per_w, 5)
    return cell


def run(datarates):
    table = {}
    targets = {
        dr: AcceleratorConfig.from_paper("SMWA", dr).total_area_mm2()
        for dr in datarates
    }
    for spec in valid_orderings():
        for dr in datarates:
            table[f"{spec.name}_dr{dr}"] = sweep_cell(spec, dr, targets[dr])
    return table


@register_benchmark("org_design_space")
def main(smoke: bool = False) -> dict:
    datarates = (5,) if smoke else (1, 5, 10)
    t0 = time.time()
    table = run(datarates)

    print("org_design_space,full_SAMW_ordering_sweep")
    print("order,dr_gs,paper,through,penalty_db,N,snr_db,dpus,fps,fps_per_w")
    for key, c in sorted(table.items()):
        dr = key.rsplit("_dr", 1)[1]
        print(
            f"{c['order']},{dr},{int(c['paper_org'])},{c['through_devices']},"
            f"{c['penalty_db']},{c['n']},{c.get('snr_db', '-')},"
            f"{c.get('dpu_count_area_matched', '-')},"
            f"{c.get('fps', '-')},{c.get('fps_per_w', '-')}"
        )

    # -- headline: the paper's best vs the unstudied space -------------------
    dr0 = datarates[-1] if smoke else 5
    at_dr = {c["order"]: c for k, c in table.items() if k.endswith(f"_dr{dr0}")}
    smwa = at_dr["SMWA"]
    novel = {o: c for o, c in at_dr.items() if not c["paper_org"]}
    best_n_order = max(at_dr, key=lambda o: at_dr[o]["n"])
    beats = {
        o: {
            "n_gain": c["n"] - smwa["n"],
            "fps_per_w_ratio": (
                round(c["fps_per_w"] / smwa["fps_per_w"], 4)
                if c.get("fps_per_w")
                else None
            ),
        }
        for o, c in novel.items()
        if c["feasible"]
        and (
            c["n"] > smwa["n"]
            or (c.get("fps_per_w") or 0.0) > smwa["fps_per_w"]
        )
    }
    print(f"# best_achievable_N: {best_n_order} (N={at_dr[best_n_order]['n']})")
    print(f"# novel orderings beating SMWA on N or FPS/W at DR={dr0}: {beats}")
    print(f"# total_s={time.time() - t0:.1f}")

    # Acceptance: the whole space is present (3 paper + 9 novel), profiles
    # derive, and the structural ordering holds — achievable N never
    # improves when a crosstalk mechanism is *added*, so the best N lives
    # in the filter-only (hitless-family) region of the space.
    orders = {c["order"] for c in table.values()}
    assert set(ORGANIZATIONS) <= orders, orders
    assert len(orders) == 12, orders
    assert not at_dr[best_n_order]["crosstalk"]["inter_modulation"], at_dr
    assert not at_dr[best_n_order]["crosstalk"]["cross_weight"], at_dr
    for o, c in at_dr.items():
        if c["feasible"]:
            assert c["n"] <= at_dr[best_n_order]["n"], (o, c)

    return {
        "bits": BITS,
        "model": MODEL,
        "datarates_gs": list(datarates),
        "orderings": len(orders),
        "novel_orderings": sorted(o for o, c in at_dr.items() if not c["paper_org"]),
        "best_achievable_n": {
            "order": best_n_order,
            "n": at_dr[best_n_order]["n"],
            "dr_gs": dr0,
        },
        "novel_beating_smwa": beats,
        # The closest unstudied challenger, spelled out (see docstring).
        "mwas_vs_smwa": {
            "through_devices": [
                at_dr["MWAS"]["through_devices"],
                smwa["through_devices"],
            ],
            "snr_delta_db": round(
                at_dr["MWAS"].get("snr_db", 0.0) - smwa.get("snr_db", 0.0), 3
            ),
            "rings_per_dpu": [
                at_dr["MWAS"]["rings_per_dpu_at_n"],
                smwa["rings_per_dpu_at_n"],
            ],
            "fps_per_w_ratio": (
                round(at_dr["MWAS"]["fps_per_w"] / smwa["fps_per_w"], 4)
                if at_dr["MWAS"].get("fps_per_w") and smwa.get("fps_per_w")
                else None
            ),
        },
        "cells": table,
    }


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true")
    main(smoke=ap.parse_args().smoke)
