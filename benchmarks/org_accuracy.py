"""Per-organization end-to-end accuracy vs DPE size N (paper §V-B claim,
quantified through `repro.noise`).

The paper asserts "minimal or no loss in inference accuracy" for prior
photonic GEMM accelerators but never connects its circuit-level analysis
(Tables II–IV) to workload accuracy.  This benchmark does:

1. **CNN proxy** — a small im2col conv net (conv3x3 -> relu -> pool ->
   linear readout) on synthetic 10-class images, every GEMM routed through
   ``photonic_matmul`` under each organization's ``ChannelModel`` at each N.
   Reports classification accuracy vs the float model.
2. **CNN workload GEMM fidelity** — for each paper CNN workload
   (GoogleNet/ResNet50/MobileNetV2/ShuffleNetV2), the largest-MAC layer's
   GEMM is run through the channel and reported as SQNR [dB] vs the exact
   int8 GEMM.
3. **LM config** — qwen2-0.5b (smoke config) served with photonic int8
   weights under each organization's channel; reports top-1 logit agreement
   with the float model.

Expected structure (asserted): SMWA — the "hitless" organization with the
smallest loss/penalty chain and no inter-modulation / cross-weight
crosstalk — degrades no faster than ASMW/MASW at matched N.
"""

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.cnn_workloads import WORKLOADS
from repro.core.dpu import DPUConfig, photonic_matmul
from repro.kernels.photonic_gemm.ops import photonic_gemm_int
from repro.kernels.photonic_gemm.ref import exact_int_gemm
from repro.noise import build_channel_model
from repro.orgs import ORGANIZATIONS

from benchmarks.run import register_benchmark

N_SWEEP = (8, 16, 32, 64)
N_SWEEP_SMOKE = (16,)


# ---------------------------------------------------------------------------
# 1. CNN proxy: im2col conv net on synthetic images
# ---------------------------------------------------------------------------
def _make_images(key, n, classes=10, hw=8):
    """Class-templated 8x8 images + pixel noise."""
    kt, kl, kn = jax.random.split(key, 3)
    templates = jax.random.normal(kt, (classes, hw, hw)) * 2.0
    labels = jax.random.randint(kl, (n,), 0, classes)
    imgs = templates[labels] + jax.random.normal(kn, (n, hw, hw))
    return imgs, labels


def _im2col(x, kh=3, kw=3):
    """(B, H, W) -> (B, H-2, W-2, kh*kw) valid patches."""
    b, h, w = x.shape
    patches = [
        x[:, i : i + h - kh + 1, j : j + w - kw + 1]
        for i in range(kh)
        for j in range(kw)
    ]
    return jnp.stack(patches, axis=-1)


def _cnn_forward(params, imgs, matmul):
    b = imgs.shape[0]
    patches = _im2col(imgs)                      # (B, 6, 6, 9)
    h = matmul(patches.reshape(-1, 9), params["conv"])  # (B*36, 8)
    h = jax.nn.relu(h.reshape(b, 6, 6, -1))
    h = h.reshape(b, 3, 2, 3, 2, -1).mean(axis=(2, 4))  # 2x2 avg pool -> 3x3
    feats = h.reshape(b, -1)                     # (B, 72)
    return matmul(feats, params["readout"])


def _train_cnn(key, imgs, labels, classes=10):
    kc = jax.random.fold_in(key, 1)
    conv = jax.random.normal(kc, (9, 8)) / 3.0
    params = {"conv": conv, "readout": jnp.zeros((72, classes))}
    # Closed-form readout on float features (lstsq ridge).
    b = imgs.shape[0]
    patches = _im2col(imgs)
    h = jax.nn.relu((patches.reshape(-1, 9) @ conv).reshape(b, 6, 6, -1))
    feats = h.reshape(b, 3, 2, 3, 2, -1).mean(axis=(2, 4)).reshape(b, -1)
    onehot = jax.nn.one_hot(labels, classes)
    readout, *_ = jnp.linalg.lstsq(feats, onehot, rcond=None)
    params["readout"] = readout
    return params


def cnn_proxy_accuracy(n_sweep, samples=512):
    key = jax.random.PRNGKey(0)
    imgs, labels = _make_images(key, samples)
    params = _train_cnn(key, imgs, labels)

    float_pred = jnp.argmax(_cnn_forward(params, imgs, jnp.matmul), -1)
    acc_float = float((float_pred == labels).mean())

    table = {}
    for org in ORGANIZATIONS:
        for n in n_sweep:
            ch = build_channel_model(org, n=n, bits=4, datarate_gs=5.0)
            cfg = DPUConfig(
                organization=org, bits=4, dpe_size=n, channel=ch, noise_seed=7
            )
            mm = lambda a, b: photonic_matmul(a, b, cfg)  # noqa: E731
            pred = jnp.argmax(_cnn_forward(params, imgs, mm), -1)
            table[(org, n)] = float((pred == labels).mean())
    return acc_float, table


# ---------------------------------------------------------------------------
# 2. Workload GEMM fidelity (largest-MAC layer per paper CNN)
# ---------------------------------------------------------------------------
def _sqnr_db(exact, noisy):
    err = noisy.astype(np.float64) - exact.astype(np.float64)
    p_sig = (exact.astype(np.float64) ** 2).mean()
    p_err = max((err**2).mean(), 1e-30)
    return 10.0 * np.log10(p_sig / p_err)


def workload_gemm_sqnr(n_sweep, max_rows=32, max_cols=64, max_k=512):
    rng = np.random.default_rng(0)
    out = {}
    for wname, fn in WORKLOADS.items():
        layer = max(fn(), key=lambda lay: lay.macs)
        r = min(layer.rows, max_rows)
        k = min(layer.k, max_k)
        c = min(layer.cols, max_cols)
        xq = jnp.asarray(rng.integers(-127, 128, (r, k), dtype=np.int8))
        wq = jnp.asarray(rng.integers(-127, 128, (k, c), dtype=np.int8))
        gold = np.asarray(exact_int_gemm(xq, wq))
        for org in ORGANIZATIONS:
            for n in n_sweep:
                ch = build_channel_model(org, n=n, bits=4, datarate_gs=5.0)
                cfg = DPUConfig(
                    organization=org, bits=4, dpe_size=n, channel=ch,
                    noise_seed=3,
                )
                noisy = np.asarray(photonic_gemm_int(xq, wq, cfg, backend="ref"))
                out[(wname, layer.name, org, n)] = _sqnr_db(gold, noisy)
    return out


# ---------------------------------------------------------------------------
# 3. LM config: photonic int8 serving under each organization's channel
# ---------------------------------------------------------------------------
def _lm_setup(tokens=16, batch=2):
    """Shared LM fixture: qwen2-0.5b smoke config, float reference logits."""
    from repro.models import registry
    from repro.models.common import init_tree

    arch = registry.get("qwen2-0.5b")
    cfg = dataclasses.replace(arch.smoke_config, remat=False)
    params = init_tree(arch.param_defs(cfg), jax.random.PRNGKey(0), cfg.param_dtype)
    rng = np.random.default_rng(1)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (batch, tokens)), jnp.int32)
    ref_logits, _ = arch.prefill(params, {"tokens": toks}, cfg, tokens)
    return arch, cfg, params, toks, tokens, ref_logits


def _lm_fidelity(setup, channel, seed, n, slicing=None):
    """(rel logit err, top-1 agreement) of photonic int8 serving vs float."""
    from repro.models.common import quantize_params

    arch, cfg, params, toks, tokens, ref_logits = setup
    dpu = DPUConfig(
        organization=channel.organization if channel else "SMWA",
        bits=4,
        dpe_size=n,
        channel=channel,
        noise_seed=seed,
    )
    cfg_q = dataclasses.replace(
        cfg,
        photonic=dpu,
        photonic_backend="ref",
        photonic_scope="weights_int8",
        photonic_slicing=slicing,
    )
    params_q = quantize_params(params, arch.param_defs(cfg_q))
    logits, _ = arch.prefill(params_q, {"tokens": toks}, cfg_q, tokens)
    rel = float(jnp.linalg.norm(logits - ref_logits) / jnp.linalg.norm(ref_logits))
    top1 = float(
        (jnp.argmax(logits, -1) == jnp.argmax(ref_logits, -1)).mean()
    )
    return rel, top1


def lm_logit_fidelity(n, tokens=16, batch=2, seeds=(5, 6, 7)):
    """Relative logit error + top-1 agreement of photonic int8 serving vs
    the float model (qwen2-0.5b smoke config, random init — logit error is
    the meaningful metric there; top-1 on near-uniform random-init logits
    flips under any perturbation).  rel_logit_err averages over ``seeds``.

    Finding: at the budgeted per-symbol SNR the LM path is noise-dominated
    for EVERY organization (rel err saturates near/above 1 — global int8
    scaling leaves LM activations far below the modulator full scale, so
    fullscale-referred analog noise swamps them).  The organization
    ordering is carried by the CNN-proxy / SQNR axes; here we check the
    saturation bound and that noise, not quantization, is responsible."""
    setup = _lm_setup(tokens=tokens, batch=batch)
    out = {"ideal": _lm_fidelity(setup, None, seeds[0], n)}
    for org in ORGANIZATIONS:
        ch = build_channel_model(org, n=n, bits=4, datarate_gs=5.0)
        rels, top1s = zip(*(_lm_fidelity(setup, ch, s, n) for s in seeds))
        out[org] = (float(np.mean(rels)), float(np.mean(top1s)))
    return out


PLATFORM_SWEEP = ("SOI", "SIN")
SLICING_SWEEP = (None, 2)


def lm_platform_slicing_grid(
    n,
    tokens=16,
    batch=2,
    seeds=(5, 6, 7),
    platforms=PLATFORM_SWEEP,
    slicings=SLICING_SWEEP,
):
    """Platform x slicing x org LM logit fidelity grid (PR-9 tentpole).

    The escape hatches from the ENOB-saturated baseline measured by
    :func:`lm_logit_fidelity`:

    * **platform** — SiN's ~10x lower propagation loss raises the
      received per-channel power, shrinking the fullscale-referred
      detector sigma (and roughly doubling the achievable N, though this
      grid holds N fixed to isolate the noise effect);
    * **slicing** — 2-bit plane passes shrink the product full-scale by
      ``(2^2-1)^2 / (2^4-1)^2 = 0.04``, and the per-plane noise draws
      recombine with exact digital shifts.

    Keys are ``"{platform}|{plane_bits or 'none'}|{org}"``; values are
    seed-averaged relative logit errors (lower = higher fidelity).
    """
    setup = _lm_setup(tokens=tokens, batch=batch)
    grid = {}
    for platform in platforms:
        for slicing in slicings:
            for org in ORGANIZATIONS:
                ch = build_channel_model(
                    org, n=n, bits=4, datarate_gs=5.0, platform=platform
                )
                rels = [
                    _lm_fidelity(setup, ch, s, n, slicing=slicing)[0]
                    for s in seeds
                ]
                plane = "none" if slicing is None else str(slicing)
                grid[f"{platform}|{plane}|{org}"] = float(np.mean(rels))
    return grid


# ---------------------------------------------------------------------------
def run(smoke=False):
    n_sweep = N_SWEEP_SMOKE if smoke else N_SWEEP
    samples = 128 if smoke else 512
    t0 = time.time()

    acc_float, cnn = cnn_proxy_accuracy(n_sweep, samples=samples)
    print("org_accuracy,cnn_proxy_accuracy_vs_N")
    print("org,n,accuracy,delta_vs_float")
    print(f"float,-,{acc_float:.4f},0.0000")
    for (org, n), acc in sorted(cnn.items()):
        print(f"{org},{n},{acc:.4f},{acc - acc_float:+.4f}")

    sqnr = workload_gemm_sqnr(n_sweep)
    print("org_accuracy,workload_gemm_sqnr_db")
    print("workload,layer,org,n,sqnr_db")
    for (wname, lname, org, n), v in sorted(sqnr.items()):
        print(f"{wname},{lname},{org},{n},{v:.1f}")

    lm_n = min(n_sweep)
    lm = lm_logit_fidelity(lm_n)
    print("org_accuracy,lm_qwen2_0.5b_logit_fidelity")
    print("org,n,rel_logit_err,top1_agreement")
    for org, (rel, top1) in sorted(lm.items()):
        print(f"{org},{lm_n},{rel:.4f},{top1:.4f}")

    grid_kwargs = dict(tokens=8, seeds=(5,)) if smoke else {}
    grid = lm_platform_slicing_grid(lm_n, **grid_kwargs)
    print("org_accuracy,lm_platform_slicing_rel_logit_err")
    print("platform,slicing,org,n,rel_logit_err")
    for key, rel in sorted(grid.items()):
        platform, plane, org = key.split("|")
        print(f"{platform},{plane},{org},{lm_n},{rel:.4f}")

    print(f"# total_s={time.time() - t0:.1f}")
    return {
        "float_accuracy": acc_float,
        "cnn_proxy": {f"{o}_n{n}": v for (o, n), v in cnn.items()},
        "workload_sqnr_db": {
            f"{w}_{o}_n{n}": round(v, 2) for (w, _l, o, n), v in sqnr.items()
        },
        "lm_n": lm_n,
        "lm_rel_logit_err": {o: rel for o, (rel, _) in lm.items()},
        "lm_top1": {o: t for o, (_, t) in lm.items()},
        "lm_platform_slicing": grid,
    }


@register_benchmark("org_accuracy")
def main(smoke=False):
    derived = run(smoke=smoke)
    # Acceptance: SMWA (hitless) degrades no faster than ASMW/MASW at
    # matched N, on every axis we measure.
    cnn = derived["cnn_proxy"]
    n_sweep = N_SWEEP_SMOKE if smoke else N_SWEEP
    for n in n_sweep:
        tol = 0.02
        assert cnn[f"SMWA_n{n}"] >= cnn[f"ASMW_n{n}"] - tol, (n, cnn)
        assert cnn[f"SMWA_n{n}"] >= cnn[f"MASW_n{n}"] - tol, (n, cnn)
    # LM serving is noise-saturated for every organization (see
    # lm_logit_fidelity docstring): check that quantization alone is benign,
    # that the degradation is noise-driven, and a generous saturation bound
    # on SMWA (guards regression to "hitless catastrophically worse").
    lm = derived["lm_rel_logit_err"]
    assert lm["ideal"] < 0.1, lm
    for org in ("ASMW", "MASW", "SMWA"):
        assert lm[org] > lm["ideal"], lm
    assert lm["SMWA"] <= min(lm["ASMW"], lm["MASW"]) + 0.2, lm
    # PR-9 tentpole: the SiN + bit-sliced arm must beat the ENOB-saturated
    # SOI unsliced baseline for every organization — lower-loss platform
    # and plane-referred noise are real fidelity levers, not no-ops.
    grid = derived["lm_platform_slicing"]
    for org in ("ASMW", "MASW", "SMWA"):
        assert grid[f"SIN|2|{org}"] < grid[f"SOI|none|{org}"], (org, grid)
    return derived


if __name__ == "__main__":
    main()
