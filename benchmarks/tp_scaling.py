"""Tensor-parallel scaling of the photonic engine (DESIGN.md §10).

Two sweeps, per organization (ASMW / MASW / SMWA):

* **Per-shard analog quality vs TP degree.**  K-sharding a GEMM's
  reduction axis gives every shard a local DPE fan-in
  ``N_local = K / shards``; the Table III loss chain and the detector
  sigma are re-evaluated there (``repro.noise.shard_local_channel``), so
  sharding *buys SNR back* — and by organization-dependent amounts: the
  ASMW through loss scales with ``2(N-1)`` rings, MASW with ``N``, the
  hitless SMWA with a constant 2.  The sweep reports each organization's
  minimum TP degree whose shard-local SNR covers the B-bit ENOB
  requirement — the paper's "organization choice changes achievable
  parallelism" claim, quantified at the system-sharding level.

* **Sharded GEMM throughput vs mesh size.**  Wall-clock tokens/s of the
  prepacked, shard-mapped ``dense`` path over the host devices actually
  present (1 on a bare CPU runner; 8 in the multi-device CI tier).

``--smoke`` shrinks the sweeps to a CI-sized subset.
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.dpu import DPUConfig
from repro.core.scalability import calibrated_max_n
from repro.launch import mesh as mesh_mod
from repro.models.common import ModelConfig, dense
from repro.noise import build_channel_model, shard_local_channel, sliced_channel
from repro.orgs import ORGANIZATIONS as ORGS
from repro.photonic import engine_for, prepack_params, tensor_parallel
from repro.platforms import PLATFORMS

from benchmarks.run import register_benchmark

BITS = 4


def enob_requirement_db(bits: int) -> float:
    """SNR an ideal ``bits``-bit quantizer needs (6.02 B + 1.76 dB)."""
    return 6.02 * bits + 1.76


def snr_sweep(k: int, shard_counts) -> dict:
    """Shard-local channel quality per organization and TP degree."""
    out = {}
    for org in ORGS:
        rows = {}
        base = build_channel_model(org, n=k, bits=BITS, datarate_gs=5.0)
        for s in shard_counts:
            n_local = k // s
            ch = shard_local_channel(base, n_local)
            rows[s] = {
                "n_local": n_local,
                "snr_db": round(ch.snr_db, 3),
                "detector_sigma_lsb": round(ch.detector_sigma_lsb, 5),
                "through_loss_db": round(ch.through_loss_db, 4),
                "total_loss_db": round(ch.total_loss_db(), 3),
            }
        need = enob_requirement_db(BITS)
        feasible = [s for s in shard_counts if rows[s]["snr_db"] >= need]
        out[org] = {
            "per_shards": rows,
            "min_shards_for_enob": feasible[0] if feasible else None,
        }
    return out


def platform_sweep(k: int, plane_bits: int = 2) -> dict:
    """Platform × organization scaling: how the material system moves the
    achievable fan-in and the per-pass analog quality (PR-9 tentpole).

    Per (platform, org): the calibrated max N (Fig. 5 operating point on
    that platform's loss chain), the k-fan-in channel SNR/sigma, and the
    detector sigma one ``plane_bits``-bit sliced pass sees on the same
    hardware.  SiN's lower propagation/through loss must buy a larger
    calibrated N than SOI, and a sliced plane must always see less
    detector sigma than the full-width pass it replaces.
    """
    out = {}
    for platform in PLATFORMS:
        rows = {}
        for org in ORGS:
            ch = build_channel_model(
                org, n=k, bits=BITS, datarate_gs=5.0, platform=platform
            )
            plane = sliced_channel(ch, plane_bits)
            rows[org] = {
                "calibrated_max_n": calibrated_max_n(
                    org, BITS, 5.0, platform=platform
                ),
                "snr_db": round(ch.snr_db, 3),
                "detector_sigma_lsb": round(ch.detector_sigma_lsb, 5),
                "plane_detector_sigma_lsb": round(plane.detector_sigma_lsb, 5),
                "total_loss_db": round(ch.total_loss_db(), 3),
            }
        out[platform] = rows
    return out


def throughput_sweep(k: int, c: int, tokens: int, iters: int) -> dict:
    """tokens/s of the prepacked TP dense path per available mesh size."""
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(tokens, k)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(k, c)), jnp.float32)
    defs = {"proj": {"w": w}}
    dpu = DPUConfig(organization="SMWA", bits=BITS, dpe_size=min(16, k))
    cfg = ModelConfig(photonic=dpu, photonic_backend="ref")
    eng = engine_for(dpu, "ref")

    sizes = []
    tp = 1
    while tp <= mesh_mod.max_tp_degree():
        sizes.append(tp)
        tp *= 2

    out = {}
    for s in sizes:
        mesh = mesh_mod.make_tp_smoke_mesh(s)
        packed = prepack_params(
            {"proj": {"w": w}}, defs, eng, mesh=mesh if s > 1 else None
        )["proj"]

        def run(xin, packed=packed, mesh=mesh):
            with tensor_parallel(mesh, "model"):
                return dense(packed, xin, cfg, site="proj")

        step = jax.jit(run)
        jax.block_until_ready(step(x))  # compile
        t0 = time.time()
        for _ in range(iters):
            y = step(x)
        jax.block_until_ready(y)
        dt = (time.time() - t0) / iters
        out[s] = {
            "us_per_call": round(dt * 1e6, 1),
            "tokens_per_s": round(tokens / dt, 1),
        }
    return out


@register_benchmark("tp_scaling")
def main(smoke: bool = False) -> dict:
    k = 128 if smoke else 256
    shard_counts = [1, 2, 4, 8] if smoke else [1, 2, 4, 8, 16, 32]
    shard_counts = [s for s in shard_counts if k % s == 0 and k // s >= 1]
    snr = snr_sweep(k, shard_counts)
    platforms = platform_sweep(k)
    thr = throughput_sweep(
        k=k,
        c=64 if smoke else 128,
        tokens=32 if smoke else 128,
        iters=3 if smoke else 10,
    )

    for org in ORGS:
        row = snr[org]
        print(
            f"{org}: min_shards_for_{BITS}b_enob={row['min_shards_for_enob']} "
            + " ".join(
                f"s={s}:snr={row['per_shards'][s]['snr_db']}dB"
                for s in shard_counts
            )
        )
    for s, row in thr.items():
        print(f"tp={s}: {row['tokens_per_s']} tokens/s")
    for platform, rows in platforms.items():
        print(
            f"{platform}: "
            + " ".join(
                f"{org}:maxN={r['calibrated_max_n']},snr={r['snr_db']}dB"
                for org, r in rows.items()
            )
        )

    # SiN's lower loss chain buys fan-in on every organization, and a
    # bit-plane pass always sees less detector sigma than the full pass.
    for org in ORGS:
        assert (
            platforms["SIN"][org]["calibrated_max_n"]
            > platforms["SOI"][org]["calibrated_max_n"]
        ), (org, platforms)
        for platform in platforms:
            r = platforms[platform][org]
            assert (
                r["plane_detector_sigma_lsb"] < r["detector_sigma_lsb"]
            ), (platform, org, r)

    # The hitless SMWA needs the least sharding to reach the ENOB target;
    # ASMW (2(N-1) through rings) gains the most SNR per doubling.
    gain = {
        org: round(
            snr[org]["per_shards"][shard_counts[-1]]["snr_db"]
            - snr[org]["per_shards"][1]["snr_db"],
            3,
        )
        for org in ORGS
    }
    assert gain["ASMW"] >= gain["SMWA"], gain
    return {
        "k": k,
        "bits": BITS,
        "enob_requirement_db": enob_requirement_db(BITS),
        "devices": len(jax.devices()),
        "snr_vs_shards": snr,
        "snr_gain_db_at_max_shards": gain,
        "platform_scaling": platforms,
        "throughput_vs_tp": thr,
    }


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true")
    print(main(smoke=ap.parse_args().smoke))
