"""Paper §V-B claim check: "prior optical GEMM accelerators show minimal or
no loss in inference accuracy".

We test the claim numerically: a small MLP classifier (synthetic gaussian
clusters) evaluated with (a) exact float GEMMs, (b) the ideal photonic DPU
datapath (int8, bit-sliced, psum-chunked), and (c) the photonic datapath
with analog noise at the level the scalability analysis budgets for
(sigma = sqrt(N)/2 psum LSBs) and beyond.
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.dpu import DPUConfig, noise_sigma_from_snr, photonic_matmul

from benchmarks.run import register_benchmark


def make_data(key, n=2048, d=64, classes=10):
    kc, kx = jax.random.split(key)
    centers = jax.random.normal(kc, (classes, d)) * 2.0
    labels = jax.random.randint(kx, (n,), 0, classes)
    x = centers[labels] + jax.random.normal(jax.random.fold_in(kx, 1), (n, d))
    return x, labels


def make_mlp(key, d=64, h=128, classes=10):
    k1, k2 = jax.random.split(key)
    return {
        "w1": jax.random.normal(k1, (d, h)) / np.sqrt(d),
        "w2": jax.random.normal(k2, (h, classes)) / np.sqrt(h),
    }


def forward(params, x, matmul):
    h = jax.nn.relu(matmul(x, params["w1"]))
    return matmul(h, params["w2"])


def run(smoke=False):
    key = jax.random.PRNGKey(0)
    x, y = make_data(key, n=512 if smoke else 2048)
    params = make_mlp(jax.random.fold_in(key, 7))

    # "train" the readout cheaply: least squares on the hidden features
    h = jax.nn.relu(x @ params["w1"])
    w2, *_ = jnp.linalg.lstsq(h, jax.nn.one_hot(y, 10), rcond=None)
    params["w2"] = w2

    exact_pred = jnp.argmax(forward(params, x, jnp.matmul), -1)
    acc_exact = float((exact_pred == y).mean())

    print("noise_accuracy,exact_vs_photonic")
    print("config,accuracy,agreement_with_exact")
    print(f"float_exact,{acc_exact:.4f},1.0000")
    t0 = time.time()
    derived = {"float_exact": acc_exact}
    orgs = (("SMWA", 5),) if smoke else (("SMWA", 5), ("ASMW", 5))
    mults = (0.0, 4.0) if smoke else (0.0, 1.0, 4.0, 16.0)
    for org, dr in orgs:
        for noise_mult in mults:
            cfg = DPUConfig(organization=org, bits=4, datarate_gs=dr)
            sigma = noise_mult * noise_sigma_from_snr(cfg)
            cfg = DPUConfig(
                organization=org, bits=4, datarate_gs=dr, noise_sigma_lsb=sigma
            )
            mm = lambda a, b: photonic_matmul(  # noqa: E731
                a, b, cfg, prng_key=jax.random.PRNGKey(3)
            )
            pred = jnp.argmax(forward(params, x, mm), -1)
            acc = float((pred == y).mean())
            agree = float((pred == exact_pred).mean())
            derived[f"{org}_dr{dr}_noise{noise_mult:g}x"] = acc
            print(f"{org}_dr{dr}_noise{noise_mult:g}x,{acc:.4f},{agree:.4f}")
    n_evals = len(orgs) * len(mults)
    print(f"# us_per_eval={(time.time()-t0)*1e6/n_evals:.0f}")
    return derived


@register_benchmark("noise_accuracy")
def main(smoke=False):
    return run(smoke=smoke)


if __name__ == "__main__":
    main()
