"""Prepack-vs-per-call photonic decode throughput (DESIGN.md §9, HC-D).

The weight-stationary claim, measured: a photonic LM decode step with
weights prepacked once (``repro.photonic.packing.prepack_params``) must be
at least as fast as the legacy path that re-quantizes every float weight
on every call — and bitwise-identical, since prepacking only hoists the
(deterministic) quantization out of the step.

Reports per-step wall time for both variants on a small dense LM with
every weight GEMM routed through the SMWA DPU (ref backend: the portable
jnp oracle, which is also what CPU CI exercises), plus the jaxpr-level
count of weight-sized rounding ops (0 after prepack — the quantization
work provably left the hot path, not just got cheaper).
"""

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.dpu import DPUConfig
from repro.models import registry
from repro.models.common import engine_from_model_config, init_tree
from repro.photonic.engine import count_weight_round_ops
from repro.photonic.packing import prepack_params

from benchmarks.run import register_benchmark


def _time_steps(step, params, tok, cache, iters: int) -> float:
    logits, cache = step(params, tok, cache)  # warmup/compile
    jax.block_until_ready(logits)
    t0 = time.time()
    for _ in range(iters):
        logits, cache = step(params, tok, cache)
    jax.block_until_ready(logits)
    return (time.time() - t0) / iters * 1e6  # us/step


@register_benchmark("prepack_decode")
def main(smoke=False):
    arch = registry.get("qwen2-0.5b")
    cfg = dataclasses.replace(
        arch.smoke_config,
        remat=False,
        tie_embeddings=False,  # exercise the lm_head site too
        num_layers=2 if smoke else 4,
        d_model=64 if smoke else 256,
        num_heads=4,
        num_kv_heads=2,
        d_ff=128 if smoke else 1024,
        vocab_size=256 if smoke else 1024,
        photonic=DPUConfig(organization="SMWA", bits=4, datarate_gs=5.0),
        photonic_backend="ref",
    )
    eng = engine_from_model_config(cfg)
    params = init_tree(arch.param_defs(cfg), jax.random.PRNGKey(0), cfg.param_dtype)
    packed = prepack_params(params, arch.param_defs(cfg), eng)

    rng = np.random.default_rng(0)
    max_seq = 32
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 8)), jnp.int32)
    _, cache = arch.prefill(params, {"tokens": toks}, cfg, max_seq)
    tok = toks[:, :1]
    step = jax.jit(lambda p, t, c: arch.decode(p, t, c, cfg))

    # Weight-sized round ops in the decode jaxpr: the per-call path rounds
    # every weight every step; the prepacked path must round none.
    min_w = cfg.d_model * cfg.d_ff // 2
    rounds_percall = count_weight_round_ops(
        jax.make_jaxpr(lambda p, t, c: arch.decode(p, t, c, cfg))(
            params, tok, cache
        ).jaxpr,
        min_w,
    )
    rounds_packed = count_weight_round_ops(
        jax.make_jaxpr(lambda p, t, c: arch.decode(p, t, c, cfg))(
            packed, tok, cache
        ).jaxpr,
        min_w,
    )

    iters = 3 if smoke else 20
    repeats = 1 if smoke else 3
    us_percall = min(
        _time_steps(step, params, tok, cache, iters) for _ in range(repeats)
    )
    us_packed = min(
        _time_steps(step, packed, tok, cache, iters) for _ in range(repeats)
    )

    # Correctness: prepack is a pure hoist — decode logits bitwise equal.
    l1, _ = step(params, tok, cache)
    l2, _ = step(packed, tok, cache)
    bitwise = bool(jnp.array_equal(l1, l2))

    speedup = us_percall / us_packed
    print("prepack_decode,per_call_vs_prepacked")
    print("variant,us_per_step,weight_round_ops")
    print(f"per_call,{us_percall:.0f},{rounds_percall}")
    print(f"prepacked,{us_packed:.0f},{rounds_packed}")
    print(f"# speedup={speedup:.2f}x bitwise_equal={bitwise}")

    assert bitwise, "prepacked decode diverged from per-call decode"
    assert rounds_packed == 0, (
        f"prepacked decode still rounds weights ({rounds_packed} ops)"
    )
    assert rounds_percall > 0, "baseline unexpectedly free of weight rounds"
    if not smoke:
        assert speedup >= 1.0, f"prepacked slower than per-call: {speedup:.2f}x"
    return {
        "per_call_us_per_step": round(us_percall, 1),
        "prepacked_us_per_step": round(us_packed, 1),
        "speedup": round(speedup, 3),
        "weight_round_ops_per_call": rounds_percall,
        "weight_round_ops_prepacked": rounds_packed,
        "bitwise_equal": bitwise,
    }


if __name__ == "__main__":
    main()
