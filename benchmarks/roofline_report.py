"""Roofline analysis (deliverable g) from the dry-run JSON cache.

Per (arch x shape), single-pod mesh (256 chips):

  compute term    = exact FLOPs/device   / 197 TF/s   (bf16 peak, v5e)
  memory term     = exact bytes/device   / 819 GB/s   (HBM)
  collective term = wire bytes/device    / 50 GB/s    (ICI per link)

"exact" FLOPs/bytes come from the layer-ladder cost analysis (XLA counts
scan bodies once; the ladder recovers per-layer cost — see
repro.models.registry.Arch.ladder).  Wire bytes come from the HLO collective
parser with while-loop trip multipliers.  MODEL_FLOPS = 6*N_active*D (train)
or 2*N_active*D (inference) gives the useful-compute ratio.

Upper-bound MFU ("roofline fraction", assuming perfect overlap):
  frac = compute_term / max(compute, memory, collective)
"""

from __future__ import annotations

import json
from pathlib import Path

from repro.core.params import TPU_V5E
from repro.models import registry

from benchmarks.run import register_benchmark

RESULTS_DIR = Path(__file__).resolve().parents[1] / "results" / "dryrun"
CHIPS = {"single": 256, "multi": 512}


def active_params(arch_name: str) -> float:
    """Parameters touched per token (MoE: shared + top-k routed only)."""
    arch = registry.get(arch_name)
    cfg = arch.config
    defs = arch.param_defs(cfg)
    total = 0

    def walk(node):
        nonlocal total
        if isinstance(node, dict):
            for v in node.values():
                walk(v)
        else:
            n = 1
            for s in node.shape:
                n *= s
            total += n

    walk(defs)
    if cfg.num_experts:
        # routed expert params: stacked wi (E,d,2f) + wo (E,f,d) per MoE layer
        d, f, e, k = (
            cfg.d_model,
            cfg.moe_hidden,
            cfg.num_experts,
            cfg.num_experts_per_tok,
        )
        n_moe_layers = cfg.num_layers - (1 if (cfg.mla and cfg.num_experts) else 0)
        routed = n_moe_layers * e * 3 * d * f
        total -= routed * (1.0 - k / e)
    return float(total)


def model_flops(arch_name: str, shape_name: str) -> float:
    shape = registry.SHAPES[shape_name]
    p_act = active_params(arch_name)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * p_act * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * p_act * tokens
    return 2.0 * p_act * shape.global_batch  # decode: one token per sequence


def load_cell(arch: str, shape: str, mesh: str, variant: str = "base") -> dict | None:
    safe = arch.replace("/", "_").replace(".", "_")
    p = RESULTS_DIR / f"{safe}__{shape}__{mesh}__{variant}.json"
    if not p.exists():
        return None
    return json.loads(p.read_text())


def roofline_terms(cell: dict) -> dict | None:
    if not cell.get("ok") or cell.get("skipped"):
        return None
    flops = cell.get("flops_per_device_exact") or cell.get("hlo_flops_per_device")
    # memory term: fusion-optimal dot traffic (TPU-realistic); XLA-CPU's raw
    # unfused 'bytes accessed' is reported alongside as the pessimistic bound.
    byts = cell.get("dot_bytes_per_device_exact")
    raw_bytes = cell.get("bytes_per_device_exact") or cell.get("hlo_bytes_per_device")
    if byts is None:
        byts = raw_bytes
    wire = cell.get("total_wire_bytes", 0.0)
    if flops is None or byts is None:
        return None
    t_c = flops / TPU_V5E.peak_flops_bf16
    t_m = byts / TPU_V5E.hbm_bandwidth
    t_x = wire / TPU_V5E.ici_bandwidth
    credit = flash_credit(cell["arch"], cell["shape"], cell["mesh"])
    t_m_flash = max(byts - credit, 0.0) / TPU_V5E.hbm_bandwidth
    dom = max(
        ("compute", t_c), ("memory", t_m), ("collective", t_x), key=lambda kv: kv[1]
    )
    bound_flash = max(t_c, t_m_flash, t_x)
    mf = model_flops(cell["arch"], cell["shape"]) / CHIPS[cell["mesh"]]
    return {
        "compute_s": t_c,
        "memory_s": t_m,
        "memory_s_flash": t_m_flash,
        "memory_s_pessimistic": (raw_bytes or 0.0) / TPU_V5E.hbm_bandwidth,
        "collective_s": t_x,
        "dominant": dom[0],
        "bound_s": dom[1],
        "mfu_upper_bound": t_c / dom[1] if dom[1] > 0 else 0.0,
        "mfu_ub_flash": t_c / bound_flash if bound_flash > 0 else 0.0,
        "model_flops_per_device": mf,
        "useful_ratio": mf / flops if flops else 0.0,
    }


def flash_credit(arch_name: str, shape_name: str, mesh: str) -> float:
    """Removable attention-score HBM traffic per device, assuming the
    flash-attention Pallas kernel (kernels/flash_attention) replaces the
    scanned implementation: score/probability matrices stay in VMEM.

    Dot-parser accounting of the as-written model counts ~8x the score
    matrix for train (s out + p in, x2 for remat recompute, + ds/dp in
    backward) and 2x for prefill; scores are f32 as compiled.
    """
    arch = registry.get(arch_name)
    cfg = arch.config.pad_for_mesh(16)
    shape = registry.SHAPES[shape_name]
    if shape.kind == "decode":
        return 0.0
    data_ax = CHIPS[mesh] // 16
    b_dev = max(shape.global_batch // data_ax, 1)
    h_dev = max(cfg.n_q_heads // 16, 1)
    factor = 8.0 if shape.kind == "train" else 2.0
    t = shape.seq_len

    def score_bytes(layers, tq, tk, heads_dev):
        return layers * b_dev * heads_dev * tq * tk * 4.0

    fam = arch.family
    if fam in ("dense", "moe"):
        return factor * score_bytes(cfg.num_layers, t, t, h_dev)
    if fam == "vlm":
        n_cross = cfg.num_layers // cfg.cross_attn_every
        n_self = cfg.num_layers - n_cross
        return factor * (
            score_bytes(n_self, t, t, h_dev)
            + score_bytes(n_cross, t, cfg.vision_seq, h_dev)
        )
    if fam == "audio":
        td = t // cfg.decoder_ratio
        return factor * (
            score_bytes(cfg.encoder_layers, t, t, h_dev)      # encoder self
            + score_bytes(cfg.num_layers, td, td, h_dev)      # decoder self
            + score_bytes(cfg.num_layers, td, t, h_dev)       # cross
        )
    if fam == "ssm":  # mLSTM chunkwise scores (T x chunk), heads replicated
        n_m = cfg.num_layers - cfg.num_layers // cfg.slstm_every
        return factor * score_bytes(n_m, t, cfg.ssm_chunk, cfg.num_heads)
    if fam == "hybrid":  # SSD chunk scores + shared attn invocations
        n_groups = cfg.num_layers // cfg.attn_every
        ssd = score_bytes(
            cfg.num_layers, t, cfg.ssm_chunk, 1
        )  # (C.B) per head pair-free
        attn_b = score_bytes(n_groups, t, t, h_dev)
        return factor * (ssd + attn_b)
    return 0.0


RECOMMEND = {
    "compute": "compute-bound: raise per-chip efficiency (fusion, int8/bf16 "
    "mix, photonic offload of weight GEMMs)",
    "memory": "HBM-bound: cut activation traffic (flash-attention kernel, "
    "chunked CE loss, wider remat, f32->bf16 intermediates)",
    "collective": "ICI-bound: reshard to cut all-gathers (SP residual), "
    "overlap collectives with compute, int8-compress gradients",
}


def render(write_experiments: bool = False) -> str:
    lines = []
    lines.append(
        "| arch | shape | FLOPs/dev | compute s | memory s | mem+flash s "
        "| collective s | dominant | MFU-UB | UB+flash | useful | note |"
    )
    lines.append("|---|---|---|---|---|---|---|---|---|---|---|---|")
    incomplete = 0
    for arch in registry.names():
        a = registry.get(arch)
        for shape in registry.SHAPES:
            cell = load_cell(arch, shape, "single")
            if cell is None:
                incomplete += 1
                continue
            if cell.get("skipped"):
                lines.append(
                    f"| {arch} | {shape} | — | — | — | — | — | skipped "
                    f"| — | — | — | {a.notes.split(';')[0][:40]} |"
                )
                continue
            t = roofline_terms(cell)
            if t is None:
                lines.append(f"| {arch} | {shape} | FAILED | | | | | | | | | |")
                continue
            lines.append(
                f"| {arch} | {shape} | {cell.get('flops_per_device_exact', 0)/1e12:.2f}T "
                f"| {t['compute_s']:.3g} | {t['memory_s']:.3g} | {t['memory_s_flash']:.3g} "
                f"| {t['collective_s']:.3g} "
                f"| {t['dominant']} | {t['mfu_upper_bound']:.2f} | {t['mfu_ub_flash']:.2f} "
                f"| {t['useful_ratio']:.2f} "
                f"| {RECOMMEND[t['dominant']][:48]} |"
            )
    table = "\n".join(lines)
    if incomplete:
        table += f"\n\n({incomplete} cells pending in the dry-run sweep)"
    return table


@register_benchmark("roofline_report")
def main(smoke=False):
    del smoke  # pure post-processing of cached dry-run JSON
    print("roofline_report,per_cell_terms")
    print(render())
    # summary stats for §Perf selection
    worst = None
    most_coll = None
    for arch in registry.names():
        for shape in registry.SHAPES:
            cell = load_cell(arch, shape, "single")
            if not cell or cell.get("skipped") or not cell.get("ok"):
                continue
            t = roofline_terms(cell)
            if t is None:
                continue
            if worst is None or t["mfu_upper_bound"] < worst[2]:
                worst = (arch, shape, t["mfu_upper_bound"])
            ratio = t["collective_s"] / max(t["bound_s"], 1e-30)
            if most_coll is None or ratio > most_coll[2]:
                most_coll = (arch, shape, ratio)
    if worst:
        print(f"# worst_mfu_ub={worst}")
    if most_coll:
        print(f"# most_collective_bound={most_coll}")
    return {
        "worst_mfu_upper_bound": list(worst) if worst else None,
        "most_collective_bound": list(most_coll) if most_coll else None,
    }


if __name__ == "__main__":
    main()
