"""Fused hot path vs legacy shoulder ops (DESIGN.md §14).

Measures what PR-8's kernel fusion removed from the attention hot block
— the path `models/attention.py` now routes through one fused-QKV bank:
per-site activation quantization as separate XLA ops, the digital
``acc * sx * w_scale`` rescale and bias add after every GEMM, and three
separate engine dispatches for the Q/K/V projections.

Two arms over the same prepacked int8 weights, both jitted, both ending
in the identical chunked-attention core (so the measurement isolates
the projection fusion):

* **legacy** — the pre-fusion composition, op for op:
  ``quantize_symmetric`` per site, unfused ``engine.int_gemm``, digital
  rescale, post-GEMM bias add, Q/K/V as three sites.
* **fused** — the current hot path: one fused-QKV bank
  (``fuse_qkv_params``), ``engine.matmul`` with the bias riding the
  in-kernel :class:`~repro.photonic.EpilogueSpec` epilogue.

Timing runs on the ``pallas`` backend — the kernel this PR fused — on
the decode shape (R=1) and a prefill chunk (R=128).  Beyond wall-clock,
the win is asserted *structurally*: ``hlo_analysis.dispatch_summary``
of the compiled modules must show the fused entry op sequence strictly
shorter — fewer dispatches by construction, not by benchmarking luck.
A ref-backend run asserts the fused path's bitwise agreement across
backends on the same operands (the engine contract).

The flash-attention core (``repro.photonic.flash``) is deliberately
*not* timed here: under CPU interpret mode it is an accelerator-kernel
prototype, slower than the chunked oracle (see DESIGN.md §14).
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.dpu import DPUConfig, quantize_symmetric
from repro.launch import hlo_analysis
from repro.models.attention import chunked_attention
from repro.photonic import (
    Epilogue,
    EpilogueSpec,
    engine_for,
    fuse_qkv_params,
    pack_dense,
)

from benchmarks.run import register_benchmark

HEADS = 4


def _legacy_site(eng, x2, pack, bias=None):
    """The pre-fusion per-site composition, op for op (quantize, unfused
    integer GEMM, digital rescale, post-GEMM bias add)."""
    xq, sx = quantize_symmetric(x2, eng.dpu.operand_bits)
    acc = eng.int_gemm(
        xq, pack.wq, logical_kc=(pack.k, pack.c), tiling=pack.tiling
    )
    y = acc.astype(jnp.float32) * sx * pack.w_scale.astype(jnp.float32)[None, :]
    return y if bias is None else y + bias


def _core(q, k, v, d):
    """The shared attention core: identical in both arms, so the timed
    difference is the projection hot path alone."""
    hd = d // HEADS
    split = lambda a: a.reshape(1, a.shape[0], HEADS, hd)  # noqa: E731
    y = chunked_attention(
        split(q), split(k), split(v), causal=True, chunk=64, unroll=1,
        acc_dtype=jnp.float32,
    )
    return y.reshape(-1, d)


def _build(d, eng):
    """Prepacked weights for one attention block, as both the per-site
    dict (legacy arm) and the fused-QKV dict (fused arm)."""
    rng = np.random.default_rng(0)

    def w(k, c):
        return jnp.asarray(rng.normal(size=(k, c), scale=k**-0.5), jnp.float32)

    attn = {
        name: dict(
            pack_dense({"w": w(d, d)}, eng),
            b=jnp.asarray(rng.normal(size=(d,), scale=0.02), jnp.float32),
        )
        for name in ("wq", "wk", "wv")
    }
    fused_attn = fuse_qkv_params(attn, eng)
    wo = pack_dense({"w": w(d, d)}, eng)["w"]
    return attn, fused_attn, wo


def _make_steps(eng, attn, fused_attn, wo, d):
    def legacy(x):
        q = _legacy_site(eng, x, attn["wq"]["w"], attn["wq"]["b"])
        k = _legacy_site(eng, x, attn["wk"]["w"], attn["wk"]["b"])
        v = _legacy_site(eng, x, attn["wv"]["w"], attn["wv"]["b"])
        return _legacy_site(eng, _core(q, k, v, d), wo)

    def fused(x):
        y = eng.matmul(
            x, fused_attn["wqkv"]["w"], site="attn.wqkv",
            epilogue=Epilogue(EpilogueSpec(bias=True), fused_attn["wqkv"]["b"]),
        )
        q, k, v = jnp.split(y, 3, axis=-1)
        return eng.matmul(_core(q, k, v, d), wo, site="attn.wo")

    return jax.jit(legacy), jax.jit(fused)


def _time(step, x, iters: int) -> float:
    y = step(x)  # warmup/compile
    jax.block_until_ready(y)
    t0 = time.time()
    for _ in range(iters):
        y = step(x)
    jax.block_until_ready(y)
    return (time.time() - t0) / iters * 1e6  # us/step


@register_benchmark("fused_hotpath")
def main(smoke=False):
    d = 64  # the smoke-model hot-block width (HEADS heads of d/HEADS)
    dpu = DPUConfig(organization="SMWA", bits=4, datarate_gs=5.0)
    eng = engine_for(dpu, "pallas")
    attn, fused_attn, wo = _build(d, eng)
    legacy, fused = _make_steps(eng, attn, fused_attn, wo, d)

    rng = np.random.default_rng(1)
    shapes = {
        "decode": jnp.asarray(rng.normal(size=(1, d)), jnp.float32),
        "prefill": jnp.asarray(rng.normal(size=(128, d)), jnp.float32),
    }
    iters = {"decode": 3 if smoke else 100, "prefill": 2 if smoke else 20}
    repeats = 1 if smoke else 3

    derived = {"cells": []}
    print("fused_hotpath,attention_hot_block,backend=pallas")
    print("path,variant,us_per_step,dispatch_count,entry_fusions")
    for path, x in shapes.items():
        # Structural dispatch summary of both compiled modules.
        summ = {}
        for name, step in (("legacy", legacy), ("fused", fused)):
            hlo = step.lower(x).compile().as_text()
            summ[name] = hlo_analysis.dispatch_summary(hlo)
        # Numeric agreement: rescale stage bitwise, bias to last-ulp
        # (FMA-contraction regimes differ — see the epilogue module doc).
        np.testing.assert_allclose(
            np.asarray(legacy(x)), np.asarray(fused(x)), rtol=1e-5, atol=1e-5
        )
        us = {}
        for name, step in (("legacy", legacy), ("fused", fused)):
            us[name] = min(_time(step, x, iters[path]) for _ in range(repeats))
            print(
                f"{path},{name},{us[name]:.0f},"
                f"{summ[name]['dispatch_count']},{summ[name]['entry_fusions']}"
            )
            derived["cells"].append(f"{path}:{name}")
        speedup = us["legacy"] / us["fused"]
        shrink = (
            summ["legacy"]["dispatch_count"] / summ["fused"]["dispatch_count"]
        )
        print(f"# {path}: speedup={speedup:.2f}x dispatch_shrink={shrink:.2f}x")
        assert (
            summ["fused"]["dispatch_count"] < summ["legacy"]["dispatch_count"]
        ), (
            f"{path}: fused entry op sequence not shorter: "
            f"{summ['fused']['dispatch_count']} vs "
            f"{summ['legacy']['dispatch_count']}"
        )
        derived[path] = {
            "legacy_us": round(us["legacy"], 1),
            "fused_us": round(us["fused"], 1),
            "speedup": round(speedup, 3),
            "legacy_dispatch_count": summ["legacy"]["dispatch_count"],
            "fused_dispatch_count": summ["fused"]["dispatch_count"],
        }

    # Cross-backend bitwise check of the fused path on the decode operand:
    # the ref oracle must agree with the pallas kernel exactly.
    eng_r = engine_for(dpu, "ref")
    attn_r, fused_attn_r, wo_r = _build(d, eng_r)
    _, fused_r = _make_steps(eng_r, attn_r, fused_attn_r, wo_r, d)
    x = shapes["decode"]
    same = bool(jnp.array_equal(fused(x), fused_r(x)))
    derived["ref_bitwise_equal"] = same
    assert same, "fused pallas path diverged from the ref oracle"

    # Grid coverage: CI's smoke step asserts this exact cell set survived.
    derived["grid_complete"] = sorted(derived["cells"]) == sorted(
        f"{p}:{v}" for p in ("decode", "prefill") for v in ("legacy", "fused")
    )
    assert derived["grid_complete"], derived["cells"]

    if not smoke:
        best = max(derived["decode"]["speedup"], derived["prefill"]["speedup"])
        assert best >= 1.2, (
            f"fused hot path under 1.2x on both shapes "
            f"(decode {derived['decode']['speedup']}x, "
            f"prefill {derived['prefill']['speedup']}x)"
        )
    return derived


if __name__ == "__main__":
    main()
